"""L1 correctness: Pallas kernels vs pure-jnp reference (`ref.py`),
including hypothesis sweeps over shapes — the core correctness signal for
the SOAP hot path that the Rust runtime executes."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels import soap_kernels as K

DIMS = st.sampled_from([1, 2, 3, 4, 8, 12, 16, 24, 64, 96, 128, 160, 256])


def rand_orth(rng, n):
    q, _ = np.linalg.qr(rng.normal(size=(n, n)))
    return q.astype(np.float32)


def rand(rng, *shape):
    return rng.normal(size=shape).astype(np.float32)


@settings(max_examples=25, deadline=None)
@given(m=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
def test_rotate_pair_matches_ref(m, n, seed):
    rng = np.random.default_rng(seed)
    ql, qr = rand_orth(rng, m), rand_orth(rng, n)
    g, mm = rand(rng, m, n), rand(rng, m, n)
    got_g, got_m = K.rotate_pair(ql, qr, g, mm)
    want_g, want_m = ref.rotate_pair_ref(ql, qr, g, mm)
    np.testing.assert_allclose(got_g, want_g, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(got_m, want_m, atol=1e-4, rtol=1e-4)


@settings(max_examples=25, deadline=None)
@given(m=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
def test_rotate_back_matches_ref(m, n, seed):
    rng = np.random.default_rng(seed)
    ql, qr = rand_orth(rng, m), rand_orth(rng, n)
    x = rand(rng, m, n)
    np.testing.assert_allclose(
        K.rotate_back(ql, qr, x), ref.rotate_back_ref(ql, qr, x),
        atol=1e-4, rtol=1e-4)


def test_rotate_roundtrip_identity():
    rng = np.random.default_rng(0)
    m, n = 32, 48
    ql, qr = rand_orth(rng, m), rand_orth(rng, n)
    g = rand(rng, m, n)
    g_rot, _ = K.rotate_pair(ql, qr, g, g)
    back = K.rotate_back(ql, qr, g_rot)
    np.testing.assert_allclose(back, g, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(m=DIMS, n=DIMS, t=st.integers(1, 1000), seed=st.integers(0, 2**31 - 1))
def test_adam_dir_matches_ref(m, n, t, seed):
    rng = np.random.default_rng(seed)
    g, mh = rand(rng, m, n), rand(rng, m, n)
    v = np.abs(rand(rng, m, n))
    tf = jnp.float32(t)
    v1, n1 = K.adam_dir(g, mh, v, 0.95, 1e-8, tf)
    v2, n2 = ref.adam_dir_ref(g, mh, v, 0.95, 1e-8, tf)
    np.testing.assert_allclose(v1, v2, atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(n1, n2, atol=1e-4, rtol=1e-3)


@settings(max_examples=20, deadline=None)
@given(m=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1),
       transpose=st.booleans())
def test_factor_ema_matches_ref(m, n, seed, transpose):
    rng = np.random.default_rng(seed)
    g = rand(rng, m, n)
    d = n if transpose else m
    l = rand(rng, d, d)
    l = (l + l.T) / 2
    got = K.factor_ema(l, g, 0.95, transpose=transpose)
    want_l, want_r = ref.factor_ema_ref(
        l if not transpose else np.zeros((m, m), np.float32),
        l if transpose else np.zeros((n, n), np.float32), g, 0.95)
    want = want_r if transpose else want_l
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(m=st.sampled_from([2, 3, 8, 24, 64]),
       n=st.sampled_from([2, 4, 16, 96]),
       seed=st.integers(0, 2**31 - 1))
def test_soap_step_matches_ref(m, n, seed):
    rng = np.random.default_rng(seed)
    ql, qr = rand_orth(rng, m), rand_orth(rng, n)
    w, g, mm = rand(rng, m, n), rand(rng, m, n), rand(rng, m, n)
    v = np.abs(rand(rng, m, n))
    l = rand(rng, m, m); l = l @ l.T
    r = rand(rng, n, n); r = r @ r.T
    t = jnp.float32(5.0)
    hp = dict(beta1=0.95, beta2=0.95, shampoo_beta=0.95, eps=1e-8,
              weight_decay=1e-4)
    got = K.soap_step(w, mm, v, l, r, ql, qr, g, t, 0.01, **hp)
    want = ref.soap_step_ref(w, mm, v, l, r, ql, qr, g, t, 0.01, **hp)
    for a, b, name in zip(got, want, "w m v l r".split()):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4,
                                   rtol=1e-3, err_msg=name)


# ---------------------------------------------------------------------------
# Householder QR (the LAPACK-free refresh path)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(n=st.sampled_from([1, 2, 3, 5, 8, 16, 33, 64]),
       seed=st.integers(0, 2**31 - 1))
def test_householder_qr_orthogonal(n, seed):
    rng = np.random.default_rng(seed)
    a = rand(rng, n, n)
    q = np.asarray(ref.householder_qr_q(jnp.asarray(a)))
    np.testing.assert_allclose(q @ q.T, np.eye(n), atol=5e-4)


@settings(max_examples=10, deadline=None)
@given(n=st.sampled_from([2, 4, 8, 24]), seed=st.integers(0, 2**31 - 1))
def test_householder_qr_spans_input(n, seed):
    # Q R = A for some upper-triangular R  ⇔  Qᵀ A is upper triangular.
    rng = np.random.default_rng(seed)
    a = rand(rng, n, n)
    q = np.asarray(ref.householder_qr_q(jnp.asarray(a)))
    r = q.T @ a
    lower = np.tril(r, -1)
    assert np.abs(lower).max() < 5e-4, np.abs(lower).max()


def test_householder_qr_positive_diag():
    rng = np.random.default_rng(3)
    a = rand(rng, 12, 12)
    q = np.asarray(ref.householder_qr_q(jnp.asarray(a)))
    r = q.T @ a
    assert (np.diagonal(r) >= -1e-4).all()


def test_power_iter_converges_to_eigenbasis():
    # Symmetric PSD with distinct eigenvalues: repeated Algorithm 4 steps
    # must converge to the true eigenvectors (up to sign).
    rng = np.random.default_rng(7)
    n = 8
    q_true = rand_orth(rng, n)
    lam = np.diag(np.linspace(8.0, 1.0, n).astype(np.float32))
    p = q_true @ lam @ q_true.T
    q = rand_orth(rng, n)
    for _ in range(300):
        q = np.asarray(ref.power_iter_refresh_ref(jnp.asarray(p), jnp.asarray(q)))
    # Columns should match ±q_true's columns.
    overlap = np.abs(q_true.T @ q)
    np.testing.assert_allclose(np.diagonal(overlap), 1.0, atol=1e-2)


def test_power_iter_fixed_point_at_eigenbasis():
    rng = np.random.default_rng(9)
    n = 6
    q_true = rand_orth(rng, n)
    lam = np.diag(np.linspace(5.0, 0.5, n).astype(np.float32))
    p = q_true @ lam @ q_true.T
    # Fix signs the same way the kernel does (diag(R) ≥ 0).
    q1 = np.asarray(ref.power_iter_refresh_ref(jnp.asarray(p), jnp.asarray(q_true)))
    q2 = np.asarray(ref.power_iter_refresh_ref(jnp.asarray(p), jnp.asarray(q1)))
    np.testing.assert_allclose(q1, q2, atol=1e-4)


# ---------------------------------------------------------------------------
# Block helper
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dim,expect", [(128, 128), (256, 128), (64, 64),
                                        (96, 96), (176, 88), (1, 1), (3, 3)])
def test_block_divides(dim, expect):
    b = K._block(dim)
    assert b == expect
    assert dim % b == 0
