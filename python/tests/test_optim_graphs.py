"""Optimizer update graphs: the exact computations the Rust runtime executes.
Checks Pallas-built graphs against pure-jnp refs and basic semantics
(descent, weight decay, bias correction at t=1)."""

import numpy as np

import jax.numpy as jnp

from compile import optim_graphs as og
from compile.kernels import ref


def rand(rng, *shape):
    return rng.normal(size=shape).astype(np.float32)


def rand_orth(rng, n):
    q, _ = np.linalg.qr(rng.normal(size=(n, n)))
    return q.astype(np.float32)


def soap_inputs(rng, m, n):
    w, g, mm = rand(rng, m, n), rand(rng, m, n), rand(rng, m, n)
    v = np.abs(rand(rng, m, n))
    l = rand(rng, m, m); l = l @ l.T
    r = rand(rng, n, n); r = r @ r.T
    ql, qr = rand_orth(rng, m), rand_orth(rng, n)
    return w, mm, v, l, r, ql, qr, g


def test_soap_update_pallas_equals_jnp():
    rng = np.random.default_rng(0)
    w, m, v, l, r, ql, qr, g = soap_inputs(rng, 24, 16)
    t, lr = jnp.float32(3.0), jnp.float32(0.01)
    got = og.soap_update(w, m, v, l, r, ql, qr, g, t, lr)
    want = og.soap_update_jnp(w, m, v, l, r, ql, qr, g, t, lr)
    for a, b, name in zip(got, want, "w m v l r".split()):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4,
                                   rtol=1e-3, err_msg=name)


def test_adamw_update_matches_numpy():
    rng = np.random.default_rng(1)
    w, m, g = rand(rng, 4, 6), rand(rng, 4, 6), rand(rng, 4, 6)
    v = np.abs(rand(rng, 4, 6))
    t, lr = jnp.float32(5.0), jnp.float32(0.1)
    h = og.HYPER
    w2, m2, v2 = og.adamw_update(w, m, v, g, t, lr)
    m_np = h["beta1"] * m + (1 - h["beta1"]) * g
    v_np = h["beta2"] * v + (1 - h["beta2"]) * g * g
    bc1, bc2 = 1 - h["beta1"] ** 5, 1 - h["beta2"] ** 5
    d = (m_np / bc1) / (np.sqrt(v_np / bc2) + h["eps"])
    w_np = (w - 0.1 * d) * (1 - 0.1 * h["weight_decay"])
    np.testing.assert_allclose(np.asarray(w2), w_np, atol=1e-5)
    np.testing.assert_allclose(np.asarray(m2), m_np, atol=1e-6)
    np.testing.assert_allclose(np.asarray(v2), v_np, atol=1e-6)


def test_soap_update_identity_basis_is_adamw():
    """Paper: SOAP with Q_L = Q_R = I reduces to AdamW exactly."""
    rng = np.random.default_rng(2)
    m_, n_ = 8, 12
    w, g, mm = rand(rng, m_, n_), rand(rng, m_, n_), rand(rng, m_, n_)
    v = np.abs(rand(rng, m_, n_))
    l = np.zeros((m_, m_), np.float32)
    r = np.zeros((n_, n_), np.float32)
    eye_l, eye_r = np.eye(m_, dtype=np.float32), np.eye(n_, dtype=np.float32)
    t, lr = jnp.float32(4.0), jnp.float32(0.05)
    w_s, m_s, v_s, _, _ = og.soap_update(w, mm, v, l, r, eye_l, eye_r, g, t, lr)
    w_a, m_a, v_a = og.adamw_update(w, mm, v, g, t, lr)
    np.testing.assert_allclose(np.asarray(w_s), np.asarray(w_a), atol=2e-5)
    np.testing.assert_allclose(np.asarray(m_s), np.asarray(m_a), atol=1e-6)
    np.testing.assert_allclose(np.asarray(v_s), np.asarray(v_a), atol=1e-6)


def test_one_sided_updates_consistent_with_full_when_other_side_identity():
    rng = np.random.default_rng(3)
    m_, n_ = 8, 6
    w, mm, v, l, r, ql, qr, g = soap_inputs(rng, m_, n_)
    t, lr = jnp.float32(2.0), jnp.float32(0.01)
    # Left-only artifact vs full artifact with Q_R = I.
    w1, m1, v1, l1 = og.soap_update_onesided_left(w, mm, v, l, ql, g, t, lr)
    w2, m2, v2, l2, _ = og.soap_update(
        w, mm, v, l, r, ql, np.eye(n_, dtype=np.float32), g, t, lr)
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)
    # Right-only artifact vs full with Q_L = I.
    w3, m3, v3, r3 = og.soap_update_onesided_right(w, mm, v, r, qr, g, t, lr)
    w4, _, _, _, r4 = og.soap_update(
        w, mm, v, l, r, np.eye(m_, dtype=np.float32), qr, g, t, lr)
    np.testing.assert_allclose(np.asarray(w3), np.asarray(w4), atol=1e-5)
    np.testing.assert_allclose(np.asarray(r3), np.asarray(r4), atol=1e-5)


def test_shampoo_update_grafting_norm():
    rng = np.random.default_rng(4)
    m_, n_ = 6, 6
    w, g, mm = rand(rng, m_, n_), rand(rng, m_, n_), rand(rng, m_, n_)
    v = np.abs(rand(rng, m_, n_))
    l_inv = np.eye(m_, dtype=np.float32) * 3.0  # arbitrary scaling
    r_inv = np.eye(n_, dtype=np.float32)
    t, lr = jnp.float32(1.0), jnp.float32(1.0)
    w2, m2, v2 = og.shampoo_update(w, mm, v, l_inv, r_inv, g, t, lr)
    # Grafting: step norm equals the AdamW step norm, independent of the
    # 3× inflation of l_inv.
    w_a, _, _ = og.adamw_update(w, mm, v, g, t, lr)
    h = og.HYPER
    step_sh = np.asarray(w2) / (1 - 1.0 * h["weight_decay"]) - w
    step_ad = np.asarray(w_a) / (1 - 1.0 * h["weight_decay"]) - w
    np.testing.assert_allclose(np.linalg.norm(step_sh),
                               np.linalg.norm(step_ad), rtol=1e-3)


def test_factor_pair_update():
    rng = np.random.default_rng(5)
    g = rand(rng, 8, 4)
    l = rand(rng, 8, 8); l = l @ l.T
    r = rand(rng, 4, 4); r = r @ r.T
    l2, r2 = og.factor_pair_update(l, r, g)
    wl, wr = ref.factor_ema_ref(l, r, g, og.HYPER["shampoo_beta"])
    np.testing.assert_allclose(np.asarray(l2), np.asarray(wl), atol=1e-4)
    np.testing.assert_allclose(np.asarray(r2), np.asarray(wr), atol=1e-4)


def test_soap_refresh_improves_eigen_alignment():
    # One power-iteration step from a perturbed basis should reduce the
    # off-diagonality of QᵀPQ.
    rng = np.random.default_rng(6)
    n = 8
    q_true = rand_orth(rng, n)
    lam = np.diag(np.linspace(9.0, 1.0, n).astype(np.float32))
    p = q_true @ lam @ q_true.T
    q0 = rand_orth(rng, n)

    def offdiag(q):
        a = q.T @ p @ q
        return np.abs(a - np.diag(np.diagonal(a))).sum()

    q1 = np.asarray(og.soap_refresh(p, q0)[0]) if isinstance(
        og.soap_refresh(p, q0), tuple) else np.asarray(og.soap_refresh(p, q0))
    assert offdiag(q1) < offdiag(q0)


def test_hyper_matches_rust_defaults():
    """The baked hyper block is the cross-language ABI — pin it."""
    assert og.HYPER == dict(beta1=0.95, beta2=0.95, eps=1e-8,
                            weight_decay=1e-4, shampoo_beta=0.95)
