"""L2 correctness: transformer LM forward/backward vs finite differences,
architecture invariants (causality, RoPE shift behaviour), and config ABI."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import configs, model

TINY = configs.ModelConfig("tiny_test", vocab=32, dim=16, depth=2, heads=2,
                           seq=8, batch=2)


def make_batch(cfg, seed=0):
    rng = np.random.default_rng(seed)
    tokens = rng.integers(0, cfg.vocab, size=(cfg.batch, cfg.seq),
                          dtype=np.int32)
    targets = rng.integers(0, cfg.vocab, size=(cfg.batch, cfg.seq),
                           dtype=np.int32)
    return jnp.asarray(tokens), jnp.asarray(targets)


@pytest.fixture(scope="module")
def tiny_params():
    return model.init_params(TINY, jax.random.PRNGKey(0))


def test_initial_loss_near_log_vocab(tiny_params):
    tokens, targets = make_batch(TINY)
    loss = model.loss_fn(TINY, tiny_params, tokens, targets)
    assert abs(float(loss) - np.log(TINY.vocab)) < 0.7


def test_loss_and_grads_shapes(tiny_params):
    tokens, targets = make_batch(TINY)
    out = model.loss_and_grads(TINY, tiny_params, tokens, targets)
    assert len(out) == 1 + len(tiny_params)
    for g, p in zip(out[1:], tiny_params):
        assert g.shape == p.shape


def test_grads_match_finite_differences(tiny_params):
    tokens, targets = make_batch(TINY, seed=1)
    out = model.loss_and_grads(TINY, tiny_params, tokens, targets)
    grads = out[1:]
    loss_of = lambda ps: float(model.loss_fn(TINY, ps, tokens, targets))
    eps = 1e-2
    rng = np.random.default_rng(2)
    for pi in [0, 3, len(tiny_params) - 1]:  # embed, a weight, unembed
        p = np.asarray(tiny_params[pi])
        i = rng.integers(0, p.shape[0])
        j = rng.integers(0, p.shape[1])
        pp = [jnp.asarray(np.array(x)) for x in tiny_params]
        base = np.array(pp[pi])
        base[i, j] += eps
        pp[pi] = jnp.asarray(base)
        lp = loss_of(pp)
        base[i, j] -= 2 * eps
        pp[pi] = jnp.asarray(base)
        lm = loss_of(pp)
        fd = (lp - lm) / (2 * eps)
        an = float(grads[pi][i, j])
        assert abs(fd - an) < 3e-2 * (1.0 + abs(fd) + abs(an)), \
            f"param {pi} ({i},{j}): fd {fd} vs analytic {an}"


def test_causality(tiny_params):
    # Changing a future token must not change logits at earlier positions.
    tokens, _ = make_batch(TINY, seed=3)
    logits1 = model.forward(TINY, tiny_params, tokens)
    toks2 = np.array(tokens)
    toks2[:, -1] = (toks2[:, -1] + 1) % TINY.vocab
    logits2 = model.forward(TINY, tiny_params, jnp.asarray(toks2))
    np.testing.assert_allclose(logits1[:, :-1], logits2[:, :-1], atol=1e-5)
    assert np.abs(np.asarray(logits1[:, -1] - logits2[:, -1])).max() > 1e-4


def test_zloss_contributes():
    cfg0 = configs.ModelConfig("z0", vocab=32, dim=16, depth=1, heads=2,
                               seq=8, batch=2, zloss=0.0)
    cfg1 = configs.ModelConfig("z1", vocab=32, dim=16, depth=1, heads=2,
                               seq=8, batch=2, zloss=1.0)
    params = model.init_params(cfg0, jax.random.PRNGKey(1))
    tokens, targets = make_batch(cfg0, seed=4)
    l0 = float(model.loss_fn(cfg0, params, tokens, targets))
    l1 = float(model.loss_fn(cfg1, params, tokens, targets))
    assert l1 > l0 + 1e-4


def test_rope_is_relative():
    # RoPE: rotating two positions by the same offset preserves dot products
    # of the rotated vectors (relative-position property).
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.normal(size=(1, 4, 1, 8)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 4, 1, 8)).astype(np.float32))
    pos_a = jnp.arange(4)
    pos_b = jnp.arange(4) + 7
    qa, ka = model.rope(q, pos_a), model.rope(k, pos_a)
    qb, kb = model.rope(q, pos_b), model.rope(k, pos_b)
    dots_a = np.einsum("bshd,bthd->st", np.asarray(qa), np.asarray(ka))
    dots_b = np.einsum("bshd,bthd->st", np.asarray(qb), np.asarray(kb))
    np.testing.assert_allclose(dots_a, dots_b, atol=1e-4)


def test_rms_norm_unit_scale():
    x = jnp.asarray(np.random.default_rng(6).normal(
        size=(2, 3, 16)).astype(np.float32) * 5.0)
    y = model.rms_norm(x, jnp.ones(16))
    ms = np.mean(np.asarray(y) ** 2, axis=-1)
    np.testing.assert_allclose(ms, 1.0, atol=1e-3)


def test_param_specs_abi():
    cfg = configs.get("nano")
    specs = cfg.param_specs()
    assert specs[0] == ("embed", cfg.vocab, cfg.dim)
    assert specs[-1] == ("unembed", cfg.dim, cfg.vocab)
    assert len(specs) == 2 + 8 * cfg.depth + 1
    # 360m:660m analogue pair exists and keeps ordering.
    assert configs.get("small").num_params() < configs.get("medium").num_params()


def test_big100m_is_about_100m():
    cfg = configs.get("big100m")
    assert 7e7 < cfg.non_embedding_params() < 1.3e8, cfg.non_embedding_params()
