"""Model configurations (Appendix A analogues, scaled for CPU PJRT).

The paper trains 210m/360m/660m-non-embedding-param decoder-only
transformers (widths 1024/1024/1408, depths 12/24/24) on 2m-token batches.
Scaled to this testbed we keep the *pair structure* (two sizes with the same
width:depth scaling ratio), the architecture choices (RoPE, QK-norm, GeLU,
4× MLP, no biases, z-loss 1e-4), and shrink width/depth/batch. The `big100m`
config is the ~100M-parameter end-to-end driver target.
"""

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    dim: int
    depth: int
    heads: int
    seq: int
    batch: int
    mlp_mult: int = 4
    zloss: float = 1e-4

    @property
    def head_dim(self):
        assert self.dim % self.heads == 0
        return self.dim // self.heads

    def param_specs(self):
        """Ordered (name, rows, cols) — 1-D params are (1, n). This ordering
        is the ABI between aot.py, manifest.json, and the Rust coordinator.
        """
        d, h = self.dim, self.mlp_mult * self.dim
        specs = [("embed", self.vocab, d)]
        for i in range(self.depth):
            specs += [
                (f"blk{i}.ln1", 1, d),
                (f"blk{i}.wq", d, d),
                (f"blk{i}.wk", d, d),
                (f"blk{i}.wv", d, d),
                (f"blk{i}.wo", d, d),
                (f"blk{i}.ln2", 1, d),
                (f"blk{i}.mlp_in", d, h),
                (f"blk{i}.mlp_out", h, d),
            ]
        specs += [("ln_f", 1, d), ("unembed", d, self.vocab)]
        return specs

    def num_params(self):
        return sum(r * c for _, r, c in self.param_specs())

    def non_embedding_params(self):
        return sum(
            r * c for n, r, c in self.param_specs()
            if n not in ("embed", "unembed"))


# Registry. `small`/`medium` are the 360m/660m analogues (same width-ratio
# family); `nano` drives fast tests; `big100m` is the ~100M e2e target.
CONFIGS = {
    c.name: c
    for c in [
        ModelConfig("nano", vocab=256, dim=64, depth=2, heads=2, seq=64,
                    batch=8),
        ModelConfig("small", vocab=512, dim=128, depth=4, heads=4, seq=128,
                    batch=16),
        ModelConfig("medium", vocab=512, dim=176, depth=6, heads=4, seq=128,
                    batch=16),
        ModelConfig("big100m", vocab=8192, dim=768, depth=12, heads=12,
                    seq=256, batch=4),
    ]
}


def get(name: str) -> ModelConfig:
    return CONFIGS[name]
