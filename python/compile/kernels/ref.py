"""Pure-jnp reference implementations (correctness oracles).

Every Pallas kernel in this package has its semantics defined here; pytest
(`python/tests/test_kernels.py`) asserts allclose between kernel and
reference over hypothesis-swept shapes, and the Rust native implementations
mirror the same math (checked end-to-end by the integration tests).
"""

import jax
import jax.numpy as jnp


def rotate_pair_ref(ql, qr, g, m):
    """Rotate gradient and momentum into the eigenbasis: X' = QLᵀ X QR.

    Returns (g_rot, m_rot).
    """
    g_rot = ql.T @ g @ qr
    m_rot = ql.T @ m @ qr
    return g_rot, m_rot


def adam_dir_ref(g_rot, m_rot_hat, v, beta2, eps, t):
    """Adam-in-eigenbasis second-moment update + direction (Alg 3 lines 7-8).

    `m_rot_hat` is the rotated momentum already bias-corrected by 1/(1−β₁ᵗ);
    the β₂ correction for V is applied here. Returns (v_new, n_rot).
    """
    v_new = beta2 * v + (1.0 - beta2) * g_rot * g_rot
    bc2 = 1.0 - beta2**t
    n_rot = m_rot_hat / (jnp.sqrt(jnp.maximum(v_new / bc2, 0.0)) + eps)
    return v_new, n_rot


def rotate_back_ref(ql, qr, n_rot):
    """Rotate the direction back to parameter space: N = QL N' QRᵀ."""
    return ql @ n_rot @ qr.T


def factor_ema_ref(l, r, g, beta):
    """Kronecker-factor EMAs: L ← βL + (1−β)GGᵀ, R ← βR + (1−β)GᵀG."""
    l_new = beta * l + (1.0 - beta) * (g @ g.T)
    r_new = beta * r + (1.0 - beta) * (g.T @ g)
    return l_new, r_new


def soap_step_ref(w, m, v, l, r, ql, qr, g, t, lr, *, beta1, beta2,
                  shampoo_beta, eps, weight_decay):
    """One full SOAP update for a 2-D layer (paper Algorithm 3), composed
    from the reference pieces. Returns (w', m', v', l', r').

    Matches `rust/src/optim/soap.rs::Soap::update` step-for-step (same
    bias-correction and decoupled weight-decay conventions).
    """
    m_new = beta1 * m + (1.0 - beta1) * g
    g_rot, m_rot = rotate_pair_ref(ql, qr, g, m_new)
    bc1 = 1.0 - beta1**t
    v_new, n_rot = adam_dir_ref(g_rot, m_rot / bc1, v, beta2, eps, t)
    n = rotate_back_ref(ql, qr, n_rot)
    w_new = (w - lr * n) * (1.0 - lr * weight_decay)
    l_new, r_new = factor_ema_ref(l, r, g, shampoo_beta)
    return w_new, m_new, v_new, l_new, r_new


def adamw_step_ref(w, m, v, g, t, lr, *, beta1, beta2, eps, weight_decay):
    """One AdamW update (PyTorch semantics; matches rust/src/optim/adamw.rs)."""
    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * g * g
    bc1 = 1.0 - beta1**t
    bc2 = 1.0 - beta2**t
    direction = (m_new / bc1) / (jnp.sqrt(jnp.maximum(v_new / bc2, 0.0)) + eps)
    w_new = (w - lr * direction) * (1.0 - lr * weight_decay)
    return w_new, m_new, v_new


def shampoo_step_ref(w, m, v, l_inv, r_inv, g, t, lr, *, beta1, beta2, eps,
                     weight_decay):
    """One Shampoo step given *cached* inverse roots, with AdamW grafting
    (matches rust/src/optim/shampoo.rs between refreshes)."""
    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * g * g
    bc1 = 1.0 - beta1**t
    bc2 = 1.0 - beta2**t
    m_hat = m_new / bc1
    direction = l_inv @ m_hat @ r_inv
    adam_dir = m_hat / (jnp.sqrt(jnp.maximum(v_new / bc2, 0.0)) + eps)
    target = jnp.sqrt(jnp.sum(adam_dir * adam_dir))
    actual = jnp.sqrt(jnp.sum(direction * direction))
    direction = direction * (target / jnp.maximum(actual, 1e-30))
    w_new = (w - lr * direction) * (1.0 - lr * weight_decay)
    return w_new, m_new, v_new


def householder_qr_q(a):
    """Orthonormal Q of the Householder QR of a square matrix, written with
    pure jnp ops (fori_loop + masking) so the lowered HLO contains **no
    LAPACK custom-calls** (the image's XLA runtime rejects them; DESIGN.md
    §2). Sign-fixed so diag(R) ≥ 0, matching `rust/src/linalg/qr.rs`.
    """
    n = a.shape[0]
    dtype = a.dtype

    def body(k, carry):
        r, q = carry
        idx = jnp.arange(n)
        col = r[:, k]
        col = jnp.where(idx >= k, col, 0.0)
        norm = jnp.sqrt(jnp.sum(col * col))
        x0 = col[k]
        alpha = jnp.where(x0 >= 0.0, -norm, norm)
        e = (idx == k).astype(dtype)
        v = col - alpha * e
        vnorm = jnp.sqrt(jnp.sum(v * v))
        v = jnp.where(vnorm > 1e-30, v / vnorm, e)
        r = r - 2.0 * jnp.outer(v, v @ r)
        q = q - 2.0 * jnp.outer(q @ v, v)
        return r, q

    r, q = jax.lax.fori_loop(0, max(n - 1, 0), body,
                             (a, jnp.eye(n, dtype=dtype)))
    # Sign fix: columns with negative R diagonal flip.
    d = jnp.sign(jnp.diagonal(r))
    d = jnp.where(d == 0.0, 1.0, d)
    return q * d[None, :]


def power_iter_refresh_ref(p, q_prev):
    """Paper Algorithm 4: Q ← QR(P·Q).Q, via the custom Householder QR."""
    return householder_qr_q(p @ q_prev)
