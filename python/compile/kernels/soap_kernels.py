"""L1 — Pallas kernels for the SOAP per-step hot path.

Hardware adaptation (DESIGN.md §4): the paper's PyTorch/H100 implementation
issues four separate cuBLAS GEMMs (rotate G, rotate M, rotate back, factor
update) plus unfused elementwise Adam ops. On a TPU-shaped memory hierarchy
the wins come from

  * **sharing Q tiles**: G and M are rotated in one batched kernel, so each
    Q_L/Q_R tile is streamed from HBM once per pair instead of twice;
  * **fusing the elementwise chain**: V-update + bias correction + normalize
    happen in a single VMEM-resident pass (no HBM round-trip for G'⊙G');
  * **fusing the factor EMA** into the GGᵀ matmul epilogue, so L is read
    once and GGᵀ never hits HBM;
  * **MXU-shaped tiles**: 128×128 blocks (the MXU systolic array is 128×128)
    with the K-reduction as the innermost grid dimension.

All kernels run with `interpret=True` — the CPU PJRT plugin cannot execute
Mosaic custom-calls (see /opt/xla-example/README.md) — so on this image the
BlockSpecs document the intended TPU schedule and define the HLO that the
Rust runtime executes. Correctness is pinned to `ref.py` by pytest
(`python/tests/test_kernels.py`), including hypothesis sweeps over shapes.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default MXU-shaped tile. Dims that the tile does not divide fall back to
# the largest divisor ≤ 128 (model dims in configs.py are powers of two, so
# in practice this is 128 or the whole dim).
TILE = 128


def _block(dim, tile=TILE):
    """Largest divisor of `dim` that is ≤ `tile`."""
    b = min(dim, tile)
    while dim % b != 0:
        b -= 1
    return b


# --------------------------------------------------------------------------
# Batched tiled matmul: out[s] = a[s] @ b — the b tile is shared across s.
# --------------------------------------------------------------------------

def _bmm_kernel(a_ref, b_ref, o_ref):
    @pl.when(pl.program_id(3) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[0], b_ref[...],
                          preferred_element_type=jnp.float32)[None]


def batched_matmul(a, b):
    """(S, M, K) @ (K, N) -> (S, M, N).

    Grid (S, M/bm, N/bn, K/bk); the `b` BlockSpec ignores the batch index,
    so each b tile is fetched once and reused for every batch element — the
    Q-tile-sharing optimization for rotating (G, M) pairs.
    """
    s, m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bn, bk = _block(m), _block(n), _block(k)
    return pl.pallas_call(
        _bmm_kernel,
        grid=(s, m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda si, i, j, kk: (si, i, kk)),
            pl.BlockSpec((bk, bn), lambda si, i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda si, i, j, kk: (si, i, j)),
        out_shape=jax.ShapeDtypeStruct((s, m, n), jnp.float32),
        interpret=True,
    )(a, b)


def matmul(a, b):
    """Plain (M,K)@(K,N) tiled Pallas matmul."""
    return batched_matmul(a[None], b)[0]


def rotate_pair(ql, qr, g, m):
    """G' = QLᵀ G QR and M' = QLᵀ M QR in one batched pass
    (ref: `ref.rotate_pair_ref`). `ql`/`qr` may be None (identity side:
    one-sided SOAP or dims over max_precond_dim)."""
    x = jnp.stack([g, m])  # (2, M, N)
    if ql is not None:
        # QLᵀ X = (Xᵀ QL)ᵀ — lowers to free HLO transposes around the kernel.
        xt = jnp.swapaxes(x, 1, 2)
        x = jnp.swapaxes(batched_matmul(xt, ql), 1, 2)
    if qr is not None:
        x = batched_matmul(x, qr)
    return x[0], x[1]


def rotate_back(ql, qr, n_rot):
    """N = QL N' QRᵀ (ref: `ref.rotate_back_ref`)."""
    x = n_rot
    if ql is not None:
        x = matmul(ql, x)
    if qr is not None:
        # X QRᵀ = (QR Xᵀ)ᵀ
        x = matmul(x, qr.T)
    return x


# --------------------------------------------------------------------------
# Fused elementwise Adam-in-eigenbasis kernel
# --------------------------------------------------------------------------

def _adam_kernel(beta2, eps, g_ref, m_ref, v_ref, bc2_ref, v_out, n_out):
    g = g_ref[...]
    v_new = beta2 * v_ref[...] + (1.0 - beta2) * g * g
    v_out[...] = v_new
    bc2 = bc2_ref[0, 0]
    n_out[...] = m_ref[...] / (jnp.sqrt(jnp.maximum(v_new / bc2, 0.0)) + eps)


def adam_dir(g_rot, m_rot_hat, v, beta2, eps, t):
    """Fused V update + normalized direction (ref: `ref.adam_dir_ref`).

    `t` is a traced f32 scalar (global step); β₂/ε are compile-time
    constants baked into the kernel. The 1−β₂ᵗ correction is computed once
    outside and broadcast via a (1,1) SMEM-style operand.
    """
    m_, n_ = g_rot.shape
    bm, bn = _block(m_), _block(n_)
    bc2 = (1.0 - beta2 ** t).reshape(1, 1).astype(jnp.float32)
    kern = functools.partial(_adam_kernel, beta2, eps)
    v_new, n_rot = pl.pallas_call(
        kern,
        grid=(m_ // bm, n_ // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m_, n_), jnp.float32),
            jax.ShapeDtypeStruct((m_, n_), jnp.float32),
        ],
        interpret=True,
    )(g_rot, m_rot_hat, v, bc2)
    return v_new, n_rot


# --------------------------------------------------------------------------
# Kronecker-factor EMA: L' = βL + (1−β)·A Aᵀ fused into the matmul epilogue
# --------------------------------------------------------------------------

def _factor_kernel(beta, a_ref, at_ref, l_ref, o_ref):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = beta * l_ref[...]

    o_ref[...] += (1.0 - beta) * jnp.dot(
        a_ref[...], at_ref[...], preferred_element_type=jnp.float32)


def factor_ema(l, g, beta, transpose=False):
    """L' = βL + (1−β)·GGᵀ (or GᵀG when `transpose=True`).

    Ref: `ref.factor_ema_ref`. The EMA blend happens in the matmul prologue/
    accumulate so L streams through VMEM exactly once.
    """
    a = g.T if transpose else g          # (M, K)
    m_, k_ = a.shape
    bm, bk = _block(m_), _block(k_)
    kern = functools.partial(_factor_kernel, beta)
    return pl.pallas_call(
        kern,
        grid=(m_ // bm, m_ // bm, k_ // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),   # A tile
            pl.BlockSpec((bk, bm), lambda i, j, kk: (kk, j)),   # Aᵀ tile
            pl.BlockSpec((bm, bm), lambda i, j, kk: (i, j)),    # L tile
        ],
        out_specs=pl.BlockSpec((bm, bm), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_, m_), jnp.float32),
        interpret=True,
    )(a, a.T, l)


# --------------------------------------------------------------------------
# Full fused SOAP step for one layer (compose the kernels; Alg 3 lines 3-14)
# --------------------------------------------------------------------------

def soap_step(w, m, v, l, r, ql, qr, g, t, lr, *, beta1, beta2, shampoo_beta,
              eps, weight_decay, sides=(True, True)):
    """One SOAP update built entirely from the Pallas kernels
    (ref: `ref.soap_step_ref`). Returns (w', m', v', l', r').

    `sides` = (rotate_left, rotate_right) supports the one-sided variant.
    """
    use_l, use_r = sides
    m_new = beta1 * m + (1.0 - beta1) * g
    bc1 = 1.0 - beta1 ** t
    g_rot, m_rot = rotate_pair(ql if use_l else None, qr if use_r else None,
                               g, m_new)
    v_new, n_rot = adam_dir(g_rot, m_rot / bc1, v, beta2, eps, t)
    n = rotate_back(ql if use_l else None, qr if use_r else None, n_rot)
    w_new = (w - lr * n) * (1.0 - lr * weight_decay)
    l_new = factor_ema(l, g, shampoo_beta) if use_l else l
    r_new = factor_ema(r, g, shampoo_beta, transpose=True) if use_r else r
    return w_new, m_new, v_new, l_new, r_new
