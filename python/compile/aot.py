"""AOT compiler: lower every compute graph to HLO *text* + write the
artifact manifest.

Run once by `make artifacts`; Python never runs on the training path.

HLO text (not `.serialize()`) is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids that the image's xla_extension
0.5.1 rejects; the text parser reassigns ids (see /opt/xla-example/README).

Outputs under --out (default ../artifacts):
  lm_grads_<cfg>.hlo.txt     (params…, tokens, targets) → (loss, grads…)
  lm_loss_<cfg>.hlo.txt      (params…, tokens, targets) → (loss,)
  adamw_update_MxN.hlo.txt   (w,m,v,g,t,lr) → (w',m',v')
  soap_update_MxN.hlo.txt    (w,m,v,l,r,ql,qr,g,t,lr) → (w',m',v',l',r')
  soap_left_MxN.hlo.txt      (w,m,v,l,ql,g,t,lr) → (w',m',v',l')
  soap_right_MxN.hlo.txt     (w,m,v,r,qr,g,t,lr) → (w',m',v',r')
  shampoo_update_MxN.hlo.txt (w,m,v,linv,rinv,g,t,lr) → (w',m',v')
  factor_pair_MxN.hlo.txt    (l,r,g) → (l',r')
  soap_refresh_N.hlo.txt     (p,q) → (q',)
  manifest.json              configs + artifact registry (ABI for Rust)
"""

import argparse
import functools
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import configs, model, optim_graphs

F32 = jnp.float32
I32 = jnp.int32

# Sides whose dimension exceeds this keep Q = I (paper implementation
# detail 3). Must match rust Hyper::default().max_precond_dim.
MAX_PRECOND_DIM = 4096


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def to_hlo_text(fn, arg_specs):
    lowered = jax.jit(fn).lower(*arg_specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    text = comp.as_hlo_text()
    assert "custom-call" not in text.lower().replace("custom_call", "custom-call"), \
        "artifact contains a custom call the rust runtime cannot execute"
    return text


def emit(out_dir, name, fn, arg_specs, manifest, meta=None):
    t0 = time.time()
    text = to_hlo_text(fn, arg_specs)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    manifest["artifacts"][name] = {
        "file": f"{name}.hlo.txt",
        "num_inputs": len(arg_specs),
        **(meta or {}),
    }
    print(f"  {name}: {len(text)/1e6:.2f} MB HLO in {time.time()-t0:.1f}s",
          flush=True)


def tuple_fn(fn):
    """Wrap so the output is always a tuple (required for return_tuple)."""
    @functools.wraps(fn)
    def wrapped(*args):
        out = fn(*args)
        return out if isinstance(out, tuple) else (out,)
    return wrapped


def emit_model_artifacts(out_dir, cfg, manifest):
    pspecs = [spec((r, c)) for _, r, c in cfg.param_specs()]
    tok = spec((cfg.batch, cfg.seq), I32)

    def grads_fn(*args):
        params = list(args[:-2])
        tokens, targets = args[-2], args[-1]
        return model.loss_and_grads(cfg, params, tokens, targets)

    def loss_fn(*args):
        params = list(args[:-2])
        tokens, targets = args[-2], args[-1]
        return (model.loss_fn(cfg, params, tokens, targets),)

    emit(out_dir, f"lm_grads_{cfg.name}", tuple_fn(grads_fn),
         [*pspecs, tok, tok], manifest,
         meta={"config": cfg.name, "outputs": 1 + len(pspecs)})
    emit(out_dir, f"lm_loss_{cfg.name}", tuple_fn(loss_fn),
         [*pspecs, tok, tok], manifest, meta={"config": cfg.name})

    manifest["configs"][cfg.name] = {
        "vocab": cfg.vocab, "dim": cfg.dim, "depth": cfg.depth,
        "heads": cfg.heads, "seq": cfg.seq, "batch": cfg.batch,
        "zloss": cfg.zloss,
        "params": [[n, r, c] for n, r, c in cfg.param_specs()],
        "num_params": cfg.num_params(),
        "non_embedding_params": cfg.non_embedding_params(),
    }


def emit_optimizer_artifacts(out_dir, shapes_2d, refresh_dims, all_shapes,
                             manifest):
    sc = spec((), F32)
    for (m, n) in sorted(all_shapes):
        s = spec((m, n))
        emit(out_dir, f"adamw_update_{m}x{n}", tuple_fn(optim_graphs.adamw_update),
             [s, s, s, s, sc, sc], manifest)
    for (m, n) in sorted(shapes_2d):
        s = spec((m, n))
        sl = spec((m, m))
        sr = spec((n, n))
        both = m <= MAX_PRECOND_DIM and n <= MAX_PRECOND_DIM
        if both:
            emit(out_dir, f"soap_update_{m}x{n}", tuple_fn(optim_graphs.soap_update),
                 [s, s, s, sl, sr, sl, sr, s, sc, sc], manifest)
            emit(out_dir, f"shampoo_update_{m}x{n}",
                 tuple_fn(optim_graphs.shampoo_update),
                 [s, s, s, sl, sr, s, sc, sc], manifest)
            emit(out_dir, f"factor_pair_{m}x{n}",
                 tuple_fn(optim_graphs.factor_pair_update),
                 [sl, sr, s], manifest)
        if m <= MAX_PRECOND_DIM:
            emit(out_dir, f"soap_left_{m}x{n}",
                 tuple_fn(optim_graphs.soap_update_onesided_left),
                 [s, s, s, sl, sl, s, sc, sc], manifest)
        if n <= MAX_PRECOND_DIM:
            emit(out_dir, f"soap_right_{m}x{n}",
                 tuple_fn(optim_graphs.soap_update_onesided_right),
                 [s, s, s, sr, sr, s, sc, sc], manifest)
    for d in sorted(refresh_dims):
        sd = spec((d, d))
        emit(out_dir, f"soap_refresh_{d}", tuple_fn(optim_graphs.soap_refresh),
             [sd, sd], manifest)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", default="nano,small,medium",
                    help="comma-separated model configs to compile "
                         "(big100m is opt-in: large HLO, slow lowering)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    names = [c for c in args.configs.split(",") if c]
    cfgs = [configs.get(n) for n in names]

    manifest = {
        "hyper": optim_graphs.HYPER,
        "max_precond_dim": MAX_PRECOND_DIM,
        "configs": {},
        "artifacts": {},
    }

    shapes_2d, refresh_dims, all_shapes = set(), set(), set()
    for cfg in cfgs:
        for _, r, c in cfg.param_specs():
            all_shapes.add((r, c))
            if r > 1 and c > 1:
                shapes_2d.add((r, c))
                if r <= MAX_PRECOND_DIM:
                    refresh_dims.add(r)
                if c <= MAX_PRECOND_DIM:
                    refresh_dims.add(c)

    print(f"compiling {len(cfgs)} model configs, {len(shapes_2d)} 2-D shapes")
    for cfg in cfgs:
        emit_model_artifacts(args.out, cfg, manifest)
    emit_optimizer_artifacts(args.out, shapes_2d, refresh_dims, all_shapes,
                             manifest)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote manifest with {len(manifest['artifacts'])} artifacts")
    return 0


if __name__ == "__main__":
    sys.exit(main())
