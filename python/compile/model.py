"""L2 — JAX decoder-only transformer LM (the paper's workload, Appendix A).

Architecture, following the paper's OLMo-derived setup: RMS LayerNorm
without biases, RoPE positional encoding, QK layer norm (Dehghani et al.),
GeLU MLP at 4× width, no linear biases, z-loss 1e-4, untied unembedding.

Params travel as a flat ordered list of 2-D arrays (1-D params as (1, n))
— the ordering is `configs.ModelConfig.param_specs()`, which is the ABI
shared with the Rust coordinator via manifest.json.

Everything lowers to pure HLO (no LAPACK/FFI custom calls), so the Rust
PJRT CPU client can execute the artifacts directly.
"""

import jax
import jax.numpy as jnp

from . import configs


def rms_norm(x, scale, eps=1e-5):
    """RMSNorm with learnable scale, no bias (paper: no biases anywhere)."""
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * scale


def qk_norm(x, eps=1e-5):
    """Per-head RMS normalization of queries/keys (QK layer norm)."""
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps)


def rope(x, positions):
    """Rotary position embedding over the last (head) dimension.

    x: (B, S, H, Dh) with even Dh; positions: (S,).
    """
    dh = x.shape[-1]
    assert dh % 2 == 0
    half = dh // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[:, None].astype(jnp.float32) * freqs[None, :]  # (S, half)
    cos = jnp.cos(angles)[None, :, None, :]
    sin = jnp.sin(angles)[None, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                           axis=-1)


def gelu(x):
    """tanh-approximated GeLU (matches rust/src/model/nplm.rs)."""
    c = 0.7978845608028654
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))


def attention(x, wq, wk, wv, wo, cfg, positions):
    b, s, d = x.shape
    h, dh = cfg.heads, cfg.head_dim
    q = (x @ wq).reshape(b, s, h, dh)
    k = (x @ wk).reshape(b, s, h, dh)
    v = (x @ wv).reshape(b, s, h, dh)
    q, k = qk_norm(q), qk_norm(k)
    q, k = rope(q, positions), rope(k, positions)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(float(dh))
    causal = jnp.tril(jnp.ones((s, s), dtype=bool))
    scores = jnp.where(causal[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(b, s, d)
    return out @ wo


def forward(cfg: configs.ModelConfig, params, tokens):
    """Logits for a token batch. `params` is the ordered flat list."""
    specs = cfg.param_specs()
    assert len(params) == len(specs), (len(params), len(specs))
    p = {name: arr for (name, _, _), arr in zip(specs, params)}

    x = p["embed"][tokens]  # (B, S, D)
    positions = jnp.arange(cfg.seq)
    for i in range(cfg.depth):
        pre = rms_norm(x, p[f"blk{i}.ln1"][0])
        x = x + attention(pre, p[f"blk{i}.wq"], p[f"blk{i}.wk"],
                          p[f"blk{i}.wv"], p[f"blk{i}.wo"], cfg, positions)
        pre = rms_norm(x, p[f"blk{i}.ln2"][0])
        x = x + (gelu(pre @ p[f"blk{i}.mlp_in"]) @ p[f"blk{i}.mlp_out"])
    x = rms_norm(x, p["ln_f"][0])
    return x @ p["unembed"]  # (B, S, V)


def loss_fn(cfg: configs.ModelConfig, params, tokens, targets):
    """Mean next-token cross-entropy (nats) + z-loss (coefficient
    cfg.zloss, as in Appendix A)."""
    logits = forward(cfg, params, tokens)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)  # (B, S)
    tgt_logit = jnp.take_along_axis(
        logits, targets[..., None], axis=-1)[..., 0]
    ce = jnp.mean(lse - tgt_logit)
    z = cfg.zloss * jnp.mean(lse * lse)
    return ce + z


def loss_and_grads(cfg: configs.ModelConfig, params, tokens, targets):
    """(loss, grads) — the training-step compute graph that aot.py lowers."""
    loss, grads = jax.value_and_grad(
        lambda ps: loss_fn(cfg, ps, tokens, targets))(list(params))
    return (loss, *grads)


def init_params(cfg: configs.ModelConfig, key):
    """1/√fan_in normal init; RMSNorm scales start at 1."""
    params = []
    for name, r, c in cfg.param_specs():
        key, sub = jax.random.split(key)
        if name.endswith(("ln1", "ln2")) or name == "ln_f":
            params.append(jnp.ones((r, c), jnp.float32))
        else:
            params.append(
                jax.random.normal(sub, (r, c), jnp.float32) /
                jnp.sqrt(float(r)))
    return params
