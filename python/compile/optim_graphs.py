"""L2 — per-layer optimizer update graphs, lowered shape-specialized to HLO.

These are the compute graphs the Rust coordinator executes on its hot path:
one `*_update` artifact per distinct parameter shape per optimizer, plus the
`soap_refresh` eigenbasis power-iteration artifact (paper Algorithm 4).

The SOAP update calls the L1 Pallas kernels (`kernels.soap_kernels`), so the
rotate→Adam→rotate-back hot path lowers into the same HLO module the Rust
runtime loads. Hyperparameters β₁/β₂/β_shampoo/ε/wd are baked at lowering
(they are fixed per training run — Appendix A); `t` (global step, for bias
correction and β-powers) and `lr` (the schedule lives in Rust) are runtime
scalar inputs.
"""

import jax.numpy as jnp

from .kernels import ref
from .kernels import soap_kernels as K

# Baked hyperparameters — must match rust/src/optim/hyper.rs::Hyper::default.
HYPER = dict(beta1=0.95, beta2=0.95, eps=1e-8, weight_decay=1e-4,
             shampoo_beta=0.95)


def adamw_update(w, m, v, g, t, lr):
    """(w,m,v,g,t,lr) → (w',m',v') — elementwise AdamW."""
    return ref.adamw_step_ref(
        w, m, v, g, t, lr, beta1=HYPER["beta1"], beta2=HYPER["beta2"],
        eps=HYPER["eps"], weight_decay=HYPER["weight_decay"])


def soap_update(w, m, v, l, r, ql, qr, g, t, lr):
    """(w,m,v,l,r,ql,qr,g,t,lr) → (w',m',v',l',r') — full SOAP step built
    from the Pallas kernels (Algorithm 3 minus the refresh)."""
    return K.soap_step(
        w, m, v, l, r, ql, qr, g, t, lr, beta1=HYPER["beta1"],
        beta2=HYPER["beta2"], shampoo_beta=HYPER["shampoo_beta"],
        eps=HYPER["eps"], weight_decay=HYPER["weight_decay"],
        sides=(True, True))


def soap_update_onesided_left(w, m, v, l, ql, g, t, lr):
    """One-sided SOAP (§7.1), rotating the LEFT (row) side only; the R/Q_R
    state does not exist. Returns (w',m',v',l')."""
    m_new = HYPER["beta1"] * m + (1.0 - HYPER["beta1"]) * g
    bc1 = 1.0 - HYPER["beta1"] ** t
    g_rot, m_rot = K.rotate_pair(ql, None, g, m_new)
    v_new, n_rot = K.adam_dir(g_rot, m_rot / bc1, v, HYPER["beta2"],
                              HYPER["eps"], t)
    n = K.rotate_back(ql, None, n_rot)
    w_new = (w - lr * n) * (1.0 - lr * HYPER["weight_decay"])
    l_new = K.factor_ema(l, g, HYPER["shampoo_beta"])
    return w_new, m_new, v_new, l_new


def soap_update_onesided_right(w, m, v, r, qr, g, t, lr):
    """One-sided SOAP rotating the RIGHT (column) side only — used both for
    the §7.1 variant on tall layers and for layers whose row dimension
    exceeds max_precond_dim (embeddings). Returns (w',m',v',r')."""
    m_new = HYPER["beta1"] * m + (1.0 - HYPER["beta1"]) * g
    bc1 = 1.0 - HYPER["beta1"] ** t
    g_rot, m_rot = K.rotate_pair(None, qr, g, m_new)
    v_new, n_rot = K.adam_dir(g_rot, m_rot / bc1, v, HYPER["beta2"],
                              HYPER["eps"], t)
    n = K.rotate_back(None, qr, n_rot)
    w_new = (w - lr * n) * (1.0 - lr * HYPER["weight_decay"])
    r_new = K.factor_ema(r, g, HYPER["shampoo_beta"], transpose=True)
    return w_new, m_new, v_new, r_new


def shampoo_update(w, m, v, l_inv, r_inv, g, t, lr):
    """(w,m,v,l_inv,r_inv,g,t,lr) → (w',m',v') — Shampoo step with *cached*
    inverse roots and AdamW grafting. Root refreshes run natively in Rust
    (mirroring DistributedShampoo's CPU-offloaded root computation)."""
    return ref.shampoo_step_ref(
        w, m, v, l_inv, r_inv, g, t, lr, beta1=HYPER["beta1"],
        beta2=HYPER["beta2"], eps=HYPER["eps"],
        weight_decay=HYPER["weight_decay"])


def soap_refresh(p, q_prev):
    """(P, Q) → Q' — Algorithm 4: one power-iteration step + Householder QR
    (hand-rolled, LAPACK-free — DESIGN.md §2)."""
    return ref.power_iter_refresh_ref(p, q_prev)


def factor_pair_update(l, r, g):
    """(L, R, G) → (L', R') — standalone Kronecker-factor EMA artifact, used
    by the Shampoo PJRT path between refreshes (Pallas fused epilogue)."""
    l_new = K.factor_ema(l, g, HYPER["shampoo_beta"])
    r_new = K.factor_ema(r, g, HYPER["shampoo_beta"], transpose=True)
    return l_new, r_new


def soap_update_jnp(w, m, v, l, r, ql, qr, g, t, lr):
    """Pure-jnp SOAP step (no Pallas) — the L2-only variant kept for the
    §Perf L1-vs-L2 comparison bench."""
    return ref.soap_step_ref(
        w, m, v, l, r, ql, qr, g, t, lr, beta1=HYPER["beta1"],
        beta2=HYPER["beta2"], shampoo_beta=HYPER["shampoo_beta"],
        eps=HYPER["eps"], weight_decay=HYPER["weight_decay"])
