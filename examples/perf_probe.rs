//! §Perf probe: raw substrate timings (gemm, cold/warm eigh, QR) used for
//! the EXPERIMENTS.md §Perf iteration log, plus a trainer-level refresh
//! breakdown (inline vs async) read entirely from `TrainLog` — no reaching
//! into optimizer internals.
//!
//! The trainer probe accepts any optimizer — preset name or composition
//! spec — as the first CLI argument or `SOAP_PROBE_OPT`, so novel combos
//! can be profiled without code changes:
//!
//! ```sh
//! cargo run --release --example perf_probe -- basis=eigen:one-sided,inner=adafactor
//! ```
fn main() {
    use soap_lab::coordinator::{Trainer, TrainerConfig};
    use soap_lab::linalg::{eigh, eigh_warm, qr_positive, Matrix};
    use soap_lab::model::NplmConfig;
    use soap_lab::optim::{Hyper, OptKind, RefreshMode, Schedule};
    use soap_lab::util::rng::Rng;
    let mut rng = Rng::new(1);
    for n in [128usize, 256, 512] {
        let a = Matrix::randn(&mut rng, n, n, 1.0);
        let b = Matrix::randn(&mut rng, n, n, 1.0);
        let t0 = std::time::Instant::now();
        let iters = (256 * 1024 * 1024) / (n * n * n) + 1;
        for _ in 0..iters {
            let _ = a.matmul(&b);
        }
        let dt = t0.elapsed().as_secs_f64() / iters as f64;
        println!("gemm n={n}: {:.3} ms, {:.2} GFLOP/s", dt * 1e3, 2.0 * (n * n * n) as f64 / dt / 1e9);
    }
    for n in [64usize, 128, 256] {
        let p = Matrix::rand_psd(&mut rng, n);
        let t0 = std::time::Instant::now();
        let (_, v) = eigh(&p);
        let cold = t0.elapsed().as_secs_f64() * 1e3;
        // Perturb and warm-start.
        let p2 = p.add(&Matrix::rand_psd(&mut rng, n).scale(0.02));
        let t0 = std::time::Instant::now();
        let _ = eigh_warm(&p2, &v);
        let warm = t0.elapsed().as_secs_f64() * 1e3;
        let t0 = std::time::Instant::now();
        let _ = qr_positive(&p2);
        let qr = t0.elapsed().as_secs_f64() * 1e3;
        println!("n={n}: eigh cold {cold:.1} ms, warm {warm:.1} ms, qr {qr:.1} ms");
    }

    // Trainer-level refresh accounting straight off the TrainLog — the
    // numbers the Fig 7 benches consume (refresh_seconds_total/refresh_frac)
    // plus the async-mode split (bg_refresh + staleness).
    let opt_spec = std::env::args()
        .nth(1)
        .or_else(|| std::env::var("SOAP_PROBE_OPT").ok())
        .unwrap_or_else(|| "soap".to_string());
    let opt = OptKind::parse(&opt_spec).unwrap_or_else(|e| {
        eprintln!("error: {e:#}");
        std::process::exit(2);
    });
    println!("\n== {} refresh accounting (native NPLM, f=10, 120 steps) ==", opt.name());
    for mode in [RefreshMode::Inline, RefreshMode::Async] {
        let cfg = TrainerConfig {
            opt,
            hyper: Hyper::default().with_refresh_mode(mode),
            schedule: Schedule::Constant { lr: 0.01 },
            steps: 120,
            seed: 3,
            grad_accum: 1,
            workers: 4,
            log_every: 0,
            vocab: 128,
            zipf_alpha: 1.2,
        };
        let mut t = Trainer::new_native(
            NplmConfig { vocab: 128, context: 4, dim: 48, hidden: 96 },
            cfg,
            32,
            16,
        );
        let log = t.run().expect("probe run");
        t.wait_refresh_idle(); // fold in refreshes still in flight at the end
        println!(
            "{:<7} hot-path refresh {:>7.1} ms ({:>4.1}% of step)  background {:>7.1} ms  \
             mean staleness {:>4.1} steps  p99 step {:>6.2} ms",
            mode.name(),
            1e3 * log.refresh_seconds_total(),
            100.0 * log.refresh_frac(),
            1e3 * t.async_refresh_seconds(),
            log.mean_staleness(),
            1e3 * log.step_time_quantile(0.99),
        );
    }
}
