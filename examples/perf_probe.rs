//! §Perf probe: raw substrate timings (gemm, cold/warm eigh, QR) used for
//! the EXPERIMENTS.md §Perf iteration log.
fn main() {
    use soap_lab::linalg::{eigh, eigh_warm, qr_positive, Matrix};
    use soap_lab::util::rng::Rng;
    let mut rng = Rng::new(1);
    for n in [128usize, 256, 512] {
        let a = Matrix::randn(&mut rng, n, n, 1.0);
        let b = Matrix::randn(&mut rng, n, n, 1.0);
        let t0 = std::time::Instant::now();
        let iters = (256 * 1024 * 1024) / (n * n * n) + 1;
        for _ in 0..iters {
            let _ = a.matmul(&b);
        }
        let dt = t0.elapsed().as_secs_f64() / iters as f64;
        println!("gemm n={n}: {:.3} ms, {:.2} GFLOP/s", dt * 1e3, 2.0 * (n * n * n) as f64 / dt / 1e9);
    }
    for n in [64usize, 128, 256] {
        let p = Matrix::rand_psd(&mut rng, n);
        let t0 = std::time::Instant::now();
        let (_, v) = eigh(&p);
        let cold = t0.elapsed().as_secs_f64() * 1e3;
        // Perturb and warm-start.
        let p2 = p.add(&Matrix::rand_psd(&mut rng, n).scale(0.02));
        let t0 = std::time::Instant::now();
        let _ = eigh_warm(&p2, &v);
        let warm = t0.elapsed().as_secs_f64() * 1e3;
        let t0 = std::time::Instant::now();
        let _ = qr_positive(&p2);
        let qr = t0.elapsed().as_secs_f64() * 1e3;
        println!("n={n}: eigh cold {cold:.1} ms, warm {warm:.1} ms, qr {qr:.1} ms");
    }
}
