//! §Perf probe: raw substrate timings (gemm, cold/warm eigh, QR) used for
//! the EXPERIMENTS.md §Perf iteration log, plus a trainer-level refresh
//! breakdown (inline vs async) read entirely from `TrainLog` — no reaching
//! into optimizer internals.
//!
//! The trainer probe accepts any optimizer — preset name or composition
//! spec — as the first CLI argument or `SOAP_PROBE_OPT`, so novel combos
//! can be profiled without code changes:
//!
//! ```sh
//! cargo run --release --example perf_probe -- basis=eigen:one-sided,inner=adafactor
//! ```
fn main() {
    use soap_lab::linalg::{eigh, eigh_warm, qr_positive, Matrix};
    use soap_lab::optim::{Hyper, OptKind, RefreshMode, Schedule};
    use soap_lab::session::{ModelSpec, TrainSession};
    use soap_lab::util::rng::Rng;
    let mut rng = Rng::new(1);
    for n in [128usize, 256, 512] {
        let a = Matrix::randn(&mut rng, n, n, 1.0);
        let b = Matrix::randn(&mut rng, n, n, 1.0);
        let t0 = std::time::Instant::now();
        let iters = (256 * 1024 * 1024) / (n * n * n) + 1;
        for _ in 0..iters {
            let _ = a.matmul(&b);
        }
        let dt = t0.elapsed().as_secs_f64() / iters as f64;
        println!("gemm n={n}: {:.3} ms, {:.2} GFLOP/s", dt * 1e3, 2.0 * (n * n * n) as f64 / dt / 1e9);
    }

    // The serial `*_into` kernel family (the zero-allocation step path) vs
    // the allocating parallel entries, per transpose variant.
    for n in [128usize, 256] {
        let a = Matrix::randn(&mut rng, n, n, 1.0);
        let b = Matrix::randn(&mut rng, n, n, 1.0);
        let mut out = Matrix::zeros(n, n);
        let mut pack = Vec::new();
        let iters = (128 * 1024 * 1024) / (n * n * n) + 1;
        let flops = 2.0 * (n * n * n) as f64;
        fn time_kernel(iters: usize, flops: f64, mut f: impl FnMut()) -> f64 {
            let t0 = std::time::Instant::now();
            for _ in 0..iters {
                f();
            }
            flops / (t0.elapsed().as_secs_f64() / iters as f64) / 1e9
        }
        let nn = time_kernel(iters, flops, || a.matmul_into(&b, &mut out));
        let tn = time_kernel(iters, flops, || a.matmul_tn_into(&b, &mut out));
        let nt = time_kernel(iters, flops, || a.matmul_nt_into(&b, &mut out, &mut pack));
        let par_nn = time_kernel(iters, flops, || {
            let _ = a.matmul(&b);
        });
        println!(
            "kernels n={n}: nn_into {nn:.2}  tn_into {tn:.2}  nt_into(packed) {nt:.2}  \
             par nn {par_nn:.2} GFLOP/s"
        );
    }

    // Workspace step path vs the allocating-engine reference on one SOAP
    // layer (same basis hooks in both arms — the true pre-PR baseline is
    // the step_latency bench's `--legacy-alloc` arm; full sweep there).
    {
        use soap_lab::optim::compose::presets;
        let (m, n) = (64usize, 256usize);
        let h = Hyper::default();
        let grads: Vec<Matrix> =
            (0..16).map(|_| Matrix::randn(&mut rng, m, n, 0.5)).collect();
        let steps = 60;
        let mut run = |legacy: bool| -> f64 {
            let mut opt = presets::soap(m, n, h.clone());
            let mut w = Matrix::zeros(m, n);
            let t0 = std::time::Instant::now();
            for i in 0..steps {
                let g = &grads[i % grads.len()];
                if legacy {
                    opt.update_legacy_alloc(&mut w, g, i as u64 + 1, 1e-3);
                } else {
                    use soap_lab::optim::LayerOptimizer;
                    opt.update(&mut w, g, i as u64 + 1, 1e-3);
                }
            }
            steps as f64 / t0.elapsed().as_secs_f64()
        };
        let alloc_sps = run(true);
        let ws_sps = run(false);
        println!(
            "soap {m}x{n} step: workspace {ws_sps:.1} steps/s vs allocating {alloc_sps:.1} \
             ({:.2}x)",
            ws_sps / alloc_sps.max(1e-12)
        );
    }
    for n in [64usize, 128, 256] {
        let p = Matrix::rand_psd(&mut rng, n);
        let t0 = std::time::Instant::now();
        let (_, v) = eigh(&p);
        let cold = t0.elapsed().as_secs_f64() * 1e3;
        // Perturb and warm-start.
        let p2 = p.add(&Matrix::rand_psd(&mut rng, n).scale(0.02));
        let t0 = std::time::Instant::now();
        let _ = eigh_warm(&p2, &v);
        let warm = t0.elapsed().as_secs_f64() * 1e3;
        let t0 = std::time::Instant::now();
        let _ = qr_positive(&p2);
        let qr = t0.elapsed().as_secs_f64() * 1e3;
        println!("n={n}: eigh cold {cold:.1} ms, warm {warm:.1} ms, qr {qr:.1} ms");
    }

    // Trainer-level refresh accounting straight off the TrainLog — the
    // numbers the Fig 7 benches consume (refresh_seconds_total/refresh_frac)
    // plus the async-mode split (bg_refresh + staleness).
    let opt_spec = std::env::args()
        .nth(1)
        .or_else(|| std::env::var("SOAP_PROBE_OPT").ok())
        .unwrap_or_else(|| "soap".to_string());
    let opt = OptKind::parse(&opt_spec).unwrap_or_else(|e| {
        eprintln!("error: {e:#}");
        std::process::exit(2);
    });
    println!("\n== {} refresh accounting (native NPLM, f=10, 120 steps) ==", opt.name());
    for mode in [RefreshMode::Inline, RefreshMode::Async] {
        let mut session = TrainSession::builder()
            .model(ModelSpec::parse("nplm").expect("builtin model"))
            .optimizer(opt)
            .hyper(Hyper::default().with_refresh_mode(mode))
            .schedule(Schedule::Constant { lr: 0.01 })
            .steps(120)
            .seed(3)
            .build()
            .expect("probe session");
        let log = session.run().expect("probe run");
        session.wait_refresh_idle(); // fold in refreshes still in flight at the end
        println!(
            "{:<7} hot-path refresh {:>7.1} ms ({:>4.1}% of step)  background {:>7.1} ms  \
             mean staleness {:>4.1} steps  p99 step {:>6.2} ms  workspace {:>6.1} KiB",
            mode.name(),
            1e3 * log.refresh_seconds_total(),
            100.0 * log.refresh_frac(),
            1e3 * session.async_refresh_seconds(),
            log.mean_staleness(),
            1e3 * log.step_time_quantile(0.99),
            session.scratch_bytes() as f64 / 1024.0,
        );
    }
}
