//! Example: the §7 space/time-saving SOAP variants — what you trade when
//! you drop to one-sided rotation or a factorized second moment, including
//! the optimizer-state memory each variant actually allocates.
//!
//! ```bash
//! cargo run --release --example soap_variants
//! ```

use soap_lab::coordinator::{Trainer, TrainerConfig};
use soap_lab::optim::{Hyper, OptKind, Schedule};

fn main() -> anyhow::Result<()> {
    let steps = 150u64;
    let variants: Vec<(&str, Hyper)> = vec![
        ("soap", Hyper::default()),
        ("soap one-sided", Hyper::default().one_sided()),
        ("soap factorized", Hyper::default().factorized()),
        ("soap both", Hyper::default().one_sided().factorized()),
    ];

    // AdamW reference for the memory comparison.
    let adamw_cfg = TrainerConfig {
        opt: OptKind::AdamW,
        schedule: Schedule::paper(3.16e-3, steps / 5, steps),
        steps,
        log_every: 0,
        ..TrainerConfig::default()
    };
    let mut adamw = Trainer::new_pjrt("nano", adamw_cfg, "artifacts")?;
    let adamw_log = adamw.run()?;
    let adamw_bytes = adamw.state_bytes();
    println!(
        "{:<18} {:>12} {:>16}\n{:<18} {:>12.4} {:>16}",
        "variant", "tail loss", "state bytes", "adamw", adamw_log.tail_loss(15), adamw_bytes
    );

    for (name, hyper) in variants {
        let cfg = TrainerConfig {
            opt: OptKind::Soap,
            hyper,
            schedule: Schedule::paper(0.01, steps / 5, steps),
            steps,
            log_every: 0,
            ..TrainerConfig::default()
        };
        let mut t = Trainer::new_pjrt("nano", cfg, "artifacts")?;
        let log = t.run()?;
        let bytes = t.state_bytes();
        println!(
            "{name:<18} {:>12.4} {:>16}{}",
            log.tail_loss(15),
            bytes,
            if bytes < adamw_bytes { "  ← smaller than AdamW (§7.2)" } else { "" }
        );
    }
    Ok(())
}
