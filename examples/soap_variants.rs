//! Example: the §7 space/time-saving SOAP variants — what you trade when
//! you drop to one-sided rotation or a factorized second moment, including
//! the optimizer-state memory each variant actually allocates.
//!
//! ```bash
//! cargo run --release --example soap_variants
//! ```

use soap_lab::optim::{Hyper, OptKind, Schedule};
use soap_lab::session::{ModelSpec, TrainSession};

fn run(opt: OptKind, hyper: Hyper, lr: f32, steps: u64) -> anyhow::Result<(f32, usize)> {
    let mut session = TrainSession::builder()
        .model(ModelSpec::artifact("nano"))
        .optimizer(opt)
        .hyper(hyper)
        .schedule(Schedule::paper(lr, steps / 5, steps))
        .steps(steps)
        .build()?;
    let log = session.run()?;
    Ok((log.tail_loss(15), session.state_bytes()))
}

fn main() -> anyhow::Result<()> {
    let steps = 150u64;
    let variants: Vec<(&str, Hyper)> = vec![
        ("soap", Hyper::default()),
        ("soap one-sided", Hyper::default().one_sided()),
        ("soap factorized", Hyper::default().factorized()),
        ("soap both", Hyper::default().one_sided().factorized()),
    ];

    // AdamW reference for the memory comparison.
    let (adamw_loss, adamw_bytes) = run(OptKind::AdamW, Hyper::default(), 3.16e-3, steps)?;
    println!(
        "{:<18} {:>12} {:>16}\n{:<18} {:>12.4} {:>16}",
        "variant", "tail loss", "state bytes", "adamw", adamw_loss, adamw_bytes
    );

    for (name, hyper) in variants {
        let (loss, bytes) = run(OptKind::Soap, hyper, 0.01, steps)?;
        println!(
            "{name:<18} {:>12.4} {:>16}{}",
            loss,
            bytes,
            if bytes < adamw_bytes { "  ← smaller than AdamW (§7.2)" } else { "" }
        );
    }
    Ok(())
}
