//! Example: batch-size scaling (paper §6.3) via gradient accumulation —
//! how many steps each optimizer needs to hit a fixed loss as the token
//! batch grows, and how far each tracks ideal linear scaling.
//!
//! ```bash
//! cargo run --release --example critical_batch
//! ```

use soap_lab::experiments::batch_scaling_analysis;
use soap_lab::optim::{Hyper, OptKind, Schedule};
use soap_lab::session::{ModelSpec, TrainSession};

use soap_lab::coordinator::TrainLog;

fn run(opt: OptKind, lr: f32, accum: usize, steps: u64, f: u64) -> anyhow::Result<TrainLog> {
    TrainSession::builder()
        .model(ModelSpec::artifact("nano"))
        .optimizer(opt)
        .hyper(Hyper::default().with_freq(f))
        .schedule(Schedule::Constant { lr })
        .steps(steps)
        .grad_accum(accum)
        .build()?
        .run()
}

fn main() -> anyhow::Result<()> {
    let base_steps = 200u64;
    let target = {
        let log = run(OptKind::AdamW, 3.16e-3, 1, base_steps, 10)?;
        log.tail_loss(15) * 1.002
    };
    println!("target loss (AdamW @ 1× batch, {base_steps} steps): {target:.4}\n");

    for (opt, lr) in [(OptKind::AdamW, 3.16e-3f32), (OptKind::Soap, 1e-2)] {
        let mut pts = Vec::new();
        for accum in [1usize, 2, 4] {
            // Keep batch × frequency constant for SOAP (paper §6.3).
            let f = (32 / accum as u64).max(1);
            let budget = (base_steps as f64 * 1.5 / accum as f64).ceil() as u64 + 30;
            let log = run(opt, lr, accum, budget, f)?;
            match log.steps_to_loss(target, 8) {
                Some(s) => {
                    println!("{:<6} batch×{accum}: reached target in {s} steps", opt.name());
                    pts.push((accum as f64, s as f64));
                }
                None => println!(
                    "{:<6} batch×{accum}: not reached in {budget} steps (tail {:.4})",
                    opt.name(),
                    log.tail_loss(8)
                ),
            }
        }
        for p in batch_scaling_analysis(&pts) {
            println!(
                "       batch×{}: {:.2}× the ideal linear-scaling step count",
                p.batch, p.scaling_inefficiency
            );
        }
        println!();
    }
    println!("paper: SOAP stays closer to ideal scaling → larger critical batch size");
    Ok(())
}
