//! Quickstart: train a tiny transformer LM with SOAP through the full
//! three-layer stack (JAX-lowered HLO transformer + Pallas-built SOAP
//! artifacts where enabled + rust coordinator), in ~15 lines of API.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use soap_lab::optim::Schedule;
use soap_lab::session::{ModelSpec, TrainSession};

fn main() -> anyhow::Result<()> {
    let steps = 100;
    let mut session = TrainSession::builder()
        .model(ModelSpec::artifact("nano"))
        .schedule(Schedule::paper(0.01, 20, steps)) // warmup → cosine to 0.1×
        .steps(steps)
        .log_every(10)
        .build()?; // SOAP with paper Appendix A defaults (f = 10)

    println!(
        "training nano ({} params) with SOAP; data entropy floor {:.3} nats",
        session.params.iter().map(|p| p.numel()).sum::<usize>(),
        session.entropy_floor()
    );

    let log = session.run()?;

    println!(
        "\nloss {:.4} → {:.4} over {} steps  ({:.0} tokens/s, optimizer overhead {:.1}%)",
        log.losses.first().unwrap().1,
        log.tail_loss(10),
        steps,
        log.tokens_per_second(),
        100.0 * log.optimizer_overhead_frac()
    );
    Ok(())
}
