//! Quickstart: train a tiny transformer LM with SOAP through the full
//! three-layer stack (JAX-lowered HLO transformer + Pallas-built SOAP
//! artifacts where enabled + rust coordinator), in ~20 lines of API.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use soap_lab::coordinator::{Trainer, TrainerConfig};
use soap_lab::optim::{Hyper, OptKind, Schedule};

fn main() -> anyhow::Result<()> {
    let steps = 100;
    let cfg = TrainerConfig {
        opt: OptKind::Soap,
        hyper: Hyper::default(),                       // paper Appendix A defaults, f = 10
        schedule: Schedule::paper(0.01, 20, steps),    // warmup → cosine to 0.1×
        steps,
        seed: 0,
        grad_accum: 1,
        workers: 4,
        log_every: 10,
        ..TrainerConfig::default()
    };

    let mut trainer = Trainer::new_pjrt("nano", cfg, "artifacts")?;
    println!(
        "training nano ({} params) with SOAP; data entropy floor {:.3} nats",
        trainer.params.iter().map(|p| p.numel()).sum::<usize>(),
        trainer.entropy_floor()
    );

    let log = trainer.run()?;

    println!(
        "\nloss {:.4} → {:.4} over {} steps  ({:.0} tokens/s, optimizer overhead {:.1}%)",
        log.losses.first().unwrap().1,
        log.tail_loss(10),
        steps,
        log.tokens_per_second(),
        100.0 * log.optimizer_overhead_frac()
    );
    Ok(())
}
