//! Example: the preconditioning-frequency trade-off (paper §6.2) as a user
//! would explore it — sweep f for SOAP and Shampoo on one model and see
//! both the quality and the overhead sides of the trade.
//!
//! ```bash
//! cargo run --release --example precond_frequency
//! ```

use soap_lab::optim::{Hyper, OptKind, Schedule};
use soap_lab::session::{ModelSpec, TrainSession};

fn main() -> anyhow::Result<()> {
    let steps = 150u64;
    println!("{:<10} {:>5} {:>12} {:>14} {:>16}", "optimizer", "f", "tail loss", "tokens/s", "refresh secs");
    for opt in [OptKind::Soap, OptKind::Shampoo] {
        for f in [1u64, 10, 100] {
            let mut session = TrainSession::builder()
                .model(ModelSpec::artifact("nano"))
                .optimizer(opt)
                .hyper(Hyper::default().with_freq(f))
                .schedule(Schedule::paper(0.01, steps / 5, steps))
                .steps(steps)
                .build()?;
            let log = session.run()?;
            println!(
                "{:<10} {:>5} {:>12.4} {:>14.0} {:>16.2}",
                opt.name(),
                f,
                log.tail_loss(15),
                log.tokens_per_second(),
                session.refresh_seconds()
            );
        }
    }
    println!("\npaper: both beat AdamW at every f; SOAP degrades far slower as f grows");
    Ok(())
}
