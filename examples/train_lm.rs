//! End-to-end training driver — the EXPERIMENTS.md §E2E workload.
//!
//! Trains a transformer LM through the full stack for a few hundred steps
//! with the paper's schedule, comparing SOAP against AdamW head-to-head,
//! logging both loss curves, throughput, the step-time breakdown, and
//! writing results to bench_results/e2e_<model>.csv + a checkpoint.
//!
//! ```bash
//! cargo run --release --example train_lm                        # small model
//! E2E_MODEL=medium E2E_STEPS=400 cargo run --release --example train_lm
//! E2E_MODEL=big100m cargo run --release --example train_lm      # ~100M params
//! #   (big100m needs: cd python && python -m compile.aot --out ../artifacts \
//! #    --configs nano,small,medium,big100m)
//! ```

use soap_lab::optim::{OptKind, Schedule};
use soap_lab::session::{Backend, ModelSpec, TrainSession};
use soap_lab::util::bench::Report;

fn env_or<T: std::str::FromStr>(key: &str, default: T) -> T {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let model: String = env_or("E2E_MODEL", "small".to_string());
    let steps: u64 = env_or("E2E_STEPS", 300);
    let pjrt_opt: bool = env_or("E2E_PJRT_OPTIMIZER", 0u32) != 0;

    let mut report = Report::new(
        &format!("E2E: SOAP vs AdamW on {model}"),
        "step",
        "train loss",
    );
    let mut summary = Vec::new();

    for (opt, lr) in [(OptKind::AdamW, 3.16e-3f32), (OptKind::Soap, 1e-2)] {
        let backend =
            if pjrt_opt && opt == OptKind::Soap { Backend::Pjrt } else { Backend::Sharded };
        let mut session = TrainSession::builder()
            .model(ModelSpec::artifact(&model))
            .optimizer(opt)
            .schedule(Schedule::paper(lr, steps / 5, steps))
            .steps(steps)
            .backend(backend)
            .log_every(25)
            .build()?;
        println!(
            "\n=== {} on {model}: {} params, floor {:.3} nats ===",
            session.opt_label(),
            session.params.iter().map(|p| p.numel()).sum::<usize>(),
            session.entropy_floor()
        );
        let t0 = std::time::Instant::now();
        let log = session.run()?;
        let wall = t0.elapsed().as_secs_f64();
        let eval = session.eval_loss(4)?;

        println!(
            "{}: train tail {:.4} | eval {:.4} | {:.0} tok/s | {:.1}% optimizer overhead | {:.1}s wall",
            session.opt_label(),
            log.tail_loss(20),
            eval,
            log.tokens_per_second(),
            100.0 * log.optimizer_overhead_frac(),
            wall
        );
        summary.push((session.opt_label(), log.tail_loss(20), eval, log.tokens_per_second()));
        report.add_series(&session.opt_label(), log.loss_series());

        // Persist the SOAP run for resumption demos (native backends only —
        // the pjrt executor has no checkpoint support).
        if opt == OptKind::Soap && backend != Backend::Pjrt {
            let path = format!("bench_results/e2e_{model}.ckpt");
            std::fs::create_dir_all("bench_results").ok();
            session.save_checkpoint(&path)?;
            println!("checkpoint → {path}");
        }
    }

    let (adamw, soap) = (&summary[0], &summary[1]);
    report.note(format!(
        "SOAP vs AdamW at {steps} steps: train {:.4} vs {:.4} (Δ {:+.4}), eval {:.4} vs {:.4}",
        soap.1, adamw.1, soap.1 - adamw.1, soap.2, adamw.2
    ));
    report.render_and_save();
    Ok(())
}
