//! Offline stand-in for the `anyhow` crate.
//!
//! The training image has no crates.io registry, so this vendored path
//! dependency provides the subset of anyhow's API the workspace uses:
//! [`Error`], [`Result`], the [`anyhow!`], [`bail!`] and [`ensure!`] macros,
//! and the [`Context`] extension trait for `Result`/`Option`. Semantics match
//! the real crate for these entry points (message-carrying dynamic error with
//! an optional source), minus backtraces and downcasting.

use std::error::Error as StdError;
use std::fmt;

/// Dynamic error: a message plus an optional source error.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

/// `anyhow::Result<T>` — `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from a displayable message.
    pub fn msg(msg: impl fmt::Display) -> Self {
        Self { msg: msg.to_string(), source: None }
    }

    /// Wrap `self` with an outer context message (the chain is preserved as
    /// the new error's source).
    pub fn context(self, context: impl fmt::Display) -> Self {
        Self { msg: context.to_string(), source: Some(Box::new(Wrapped(self))) }
    }

    /// Walk the source chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &(dyn StdError + 'static)> {
        let mut next = self.source.as_ref().map(|s| s.as_ref() as &(dyn StdError + 'static));
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source();
            Some(cur)
        })
    }
}

/// Adapter so an [`Error`] can sit inside another error's `source` slot
/// (`Error` itself deliberately does not implement `std::error::Error`,
/// mirroring the real anyhow, which keeps the blanket `From` below coherent).
struct Wrapped(Error);

impl fmt::Display for Wrapped {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)
    }
}

impl fmt::Debug for Wrapped {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(&self.0, f)
    }
}

impl StdError for Wrapped {
    fn source(&self) -> Option<&(dyn StdError + 'static)> {
        self.0.source.as_ref().map(|s| s.as_ref() as &(dyn StdError + 'static))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        // `{:#}` prints the full cause chain inline, like anyhow.
        if f.alternate() {
            for cause in self.chain() {
                write!(f, ": {cause}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let causes: Vec<String> = self.chain().map(|c| c.to_string()).collect();
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for c in causes {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Self { msg: e.to_string(), source: Some(Box::new(e)) }
    }
}

/// Conversion-to-[`Error`] bound for [`Context`] — implemented for both
/// `anyhow::Error` itself and std errors (the disjointness holds because
/// `Error` deliberately does not implement `std::error::Error`; this is the
/// real anyhow's coherence pattern).
pub trait IntoAnyhow {
    fn into_anyhow(self) -> Error;
}

impl IntoAnyhow for Error {
    fn into_anyhow(self) -> Error {
        self
    }
}

impl<E: StdError + Send + Sync + 'static> IntoAnyhow for E {
    fn into_anyhow(self) -> Error {
        Error::from(self)
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: IntoAnyhow> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into_anyhow().context(context))
    }
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_anyhow().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// `return Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Early-return an error when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(format!(
                "condition failed: {}",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<()> {
        let _ = std::fs::read("/definitely/not/a/path")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = fails_io().unwrap_err();
        assert!(!e.to_string().is_empty());
        assert!(e.source.is_some());
    }

    #[test]
    fn macros_build_messages() {
        let x = 41;
        let e = anyhow!("x was {x}");
        assert_eq!(e.to_string(), "x was 41");
        let e = anyhow!("{} {}", "a", "b");
        assert_eq!(e.to_string(), "a b");

        fn f(flag: bool) -> Result<u32> {
            ensure!(flag, "flag must hold");
            ensure!(flag);
            if !flag {
                bail!("unreachable {}", 1);
            }
            Ok(7)
        }
        assert_eq!(f(true).unwrap(), 7);
        assert_eq!(f(false).unwrap_err().to_string(), "flag must hold");
    }

    #[test]
    fn context_on_option_and_result() {
        let none: Option<u32> = None;
        assert_eq!(none.context("empty").unwrap_err().to_string(), "empty");
        let e = fails_io().context("loading config").unwrap_err();
        assert_eq!(e.to_string(), "loading config");
        assert!(format!("{e:#}").contains("loading config: "));
        assert!(e.chain().count() >= 1);
    }
}
