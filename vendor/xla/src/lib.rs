//! No-runtime stand-in for the `xla-rs` PJRT bindings.
//!
//! The training image does not ship the XLA shared library, so this vendored
//! crate provides the API surface `soap_lab::runtime` compiles against:
//!
//! - [`Literal`] is **fully functional** — an in-memory typed tensor with the
//!   `vec1`/`reshape`/`to_vec`/`scalar`/`to_tuple` operations the engine's
//!   host-side conversions use (and the engine's unit tests exercise).
//! - [`PjRtClient::cpu`] returns a descriptive error, so every artifact code
//!   path fails fast and gracefully: callers already gate on
//!   `artifacts/manifest.json` existing and propagate `anyhow` errors.
//!
//! Swapping in the real bindings is a Cargo.toml change only; no source edits.

use std::fmt;
use std::path::Path;

/// Error type mirroring xla-rs' (only `Display` is consumed by the engine).
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn new(msg: impl Into<String>) -> Self {
        Self(msg.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const NO_RUNTIME: &str = "XLA/PJRT runtime unavailable: soap-lab was built against the vendored \
     no-op `xla` stub (this image carries no libxla). Native paths \
     (`Trainer::new_native`, sharded optimizers, all unit/property tests) are \
     unaffected; artifact paths need the real xla-rs bindings.";

/// Element types a [`Literal`] can hold.
mod sealed {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for i32 {}
}

pub trait NativeType: sealed::Sealed + Copy {
    fn store(data: Vec<Self>) -> Storage;
    fn load(s: &Storage) -> Option<Vec<Self>>;
    const NAME: &'static str;
}

#[derive(Debug, Clone, PartialEq)]
pub enum Storage {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

impl NativeType for f32 {
    fn store(data: Vec<Self>) -> Storage {
        Storage::F32(data)
    }
    fn load(s: &Storage) -> Option<Vec<Self>> {
        match s {
            Storage::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
    const NAME: &'static str = "f32";
}

impl NativeType for i32 {
    fn store(data: Vec<Self>) -> Storage {
        Storage::I32(data)
    }
    fn load(s: &Storage) -> Option<Vec<Self>> {
        match s {
            Storage::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
    const NAME: &'static str = "i32";
}

/// In-memory typed tensor (host side of xla-rs' `Literal`).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    storage: Storage,
    dims: Vec<i64>,
}

impl Literal {
    /// 1-D literal from a native slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], storage: T::store(data.to_vec()) }
    }

    /// Rank-0 f32 literal.
    pub fn scalar(x: f32) -> Literal {
        Literal { dims: Vec::new(), storage: Storage::F32(vec![x]) }
    }

    /// Reshape to `dims` (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have = self.element_count() as i64;
        if want != have {
            return Err(Error::new(format!(
                "reshape {:?} -> {dims:?}: {have} elements != {want}",
                self.dims
            )));
        }
        Ok(Literal { storage: self.storage.clone(), dims: dims.to_vec() })
    }

    /// Flat element buffer as `Vec<T>`; errors on dtype mismatch.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::load(&self.storage)
            .ok_or_else(|| Error::new(format!("literal is not {}", T::NAME)))
    }

    pub fn element_count(&self) -> usize {
        match &self.storage {
            Storage::F32(v) => v.len(),
            Storage::I32(v) => v.len(),
            Storage::Tuple(parts) => parts.iter().map(|p| p.element_count()).sum(),
        }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Destructure a tuple literal into its parts.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.storage {
            Storage::Tuple(parts) => Ok(parts),
            _ => Err(Error::new("literal is not a tuple")),
        }
    }

    /// Build a tuple literal (host-side convenience, used by tests).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal { dims: vec![parts.len() as i64], storage: Storage::Tuple(parts) }
    }
}

/// Parsed HLO module handle (stub: retains the path for error messages).
pub struct HloModuleProto {
    path: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        if !path.exists() {
            return Err(Error::new(format!("no such artifact file: {path:?}")));
        }
        Ok(Self { path: path.display().to_string() })
    }
}

/// Computation handle (stub).
pub struct XlaComputation {
    #[allow(dead_code)]
    origin: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { origin: proto.path.clone() }
    }
}

/// PJRT client handle. `cpu()` always errors in the stub build.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(Error::new(NO_RUNTIME))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::new(NO_RUNTIME))
    }
}

/// Compiled executable handle (unreachable in the stub build — constructing a
/// client already fails — but the types must line up for the engine).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(NO_RUNTIME))
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::new(NO_RUNTIME))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_vec_reshape_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let m = l.reshape(&[2, 3]).unwrap();
        assert_eq!(m.dims(), &[2, 3]);
        assert_eq!(m.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn dtype_mismatch_errors() {
        let l = Literal::vec1(&[1i32, 2, 3]);
        assert!(l.to_vec::<f32>().is_err());
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn scalar_and_tuple() {
        let s = Literal::scalar(2.5);
        assert_eq!(s.element_count(), 1);
        let t = Literal::tuple(vec![s.clone(), Literal::vec1(&[1i32])]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].to_vec::<f32>().unwrap(), vec![2.5]);
        assert!(s.to_tuple().is_err());
    }

    #[test]
    fn client_fails_gracefully() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("unavailable"));
    }
}
