#!/usr/bin/env python3
"""Step-latency regression gate.

Compares a fresh `cargo bench --bench step_latency` result
(`bench_results/step_latency.json`) against the tracked baseline
(`BENCH_step_latency.json` at the repo root) and fails when any
workspace-path cell's p50 step latency regressed by more than the
threshold (default 15%).

Two checks always run, baseline or not:

  * the result document has the expected shape (non-empty rows, required
    keys, `timed_steps > 0` — a doc that timed nothing gates nothing);
  * every workspace-path row reports `allocs_per_step_p50 == 0` — the
    zero-allocation steady-state invariant, measured.

A baseline with `"provisional": true` (the checked-in placeholder
awaiting real numbers) FAILS the gate loudly — a gate that silently
skips is indistinguishable from one that passed. Produce a real
baseline first: `cargo bench --bench step_latency &&
scripts/check_step_latency.py --update` (which drops the provisional
marker). CI bootstraps exactly this way before gating.

Usage:
  scripts/check_step_latency.py                      # gate current vs baseline
  scripts/check_step_latency.py --update             # rewrite the baseline
  scripts/check_step_latency.py --threshold 0.25     # looser gate
"""

import argparse
import json
import sys

REQUIRED_ROW_KEYS = (
    "preset",
    "path",
    "rows",
    "cols",
    "p50_step_us",
    "p99_step_us",
    "steps_per_sec",
    "allocs_per_step_p50",
)


def fail(msg):
    print(f"FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except FileNotFoundError:
        fail(f"{path} not found")
    except json.JSONDecodeError as e:
        fail(f"{path} is not valid JSON: {e}")


def validate(doc, path):
    if doc.get("bench") != "step_latency":
        fail(f"{path}: bench != step_latency")
    rows = doc.get("rows")
    if not isinstance(rows, list):
        fail(f"{path}: missing rows array")
    if not rows:
        fail(f"{path}: rows is empty — the bench measured nothing, "
             "so there is nothing to gate")
    timed = doc.get("timed_steps", 0)
    if not isinstance(timed, (int, float)) or timed <= 0:
        fail(f"{path}: timed_steps is {timed!r} — a document that timed "
             "zero steps cannot anchor the latency gate")
    for row in rows:
        for key in REQUIRED_ROW_KEYS:
            if key not in row:
                fail(f"{path}: row missing key {key!r}: {row}")
    return rows


def cell_key(row):
    return (row["preset"], row["path"], row["rows"], row["cols"])


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--current", default="bench_results/step_latency.json")
    ap.add_argument("--baseline", default="BENCH_step_latency.json")
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="max allowed p50 regression fraction (default 0.15)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from the current results")
    args = ap.parse_args()

    current = load(args.current)
    rows = validate(current, args.current)

    # The measured zero-allocation invariant: no workspace cell may allocate
    # in its median (steady-state) step.
    for row in rows:
        if row["path"] == "workspace" and row["allocs_per_step_p50"] != 0:
            fail(
                f"{row['preset']} {row['rows']}x{row['cols']}: "
                f"allocs_per_step_p50 = {row['allocs_per_step_p50']} (want 0)"
            )
    print(f"OK: {sum(r['path'] == 'workspace' for r in rows)} workspace cells at 0 allocs/step")

    if args.update:
        current.pop("provisional", None)
        with open(args.baseline, "w") as f:
            json.dump(current, f, indent=2, sort_keys=False)
            f.write("\n")
        print(f"baseline updated: {args.baseline}")
        return

    baseline = load(args.baseline)
    if baseline.get("provisional"):
        fail(
            f"{args.baseline} is provisional — the latency-ratio gate has no "
            "real numbers to compare against. Produce a baseline first: "
            "`cargo bench --bench step_latency && "
            "scripts/check_step_latency.py --update`"
        )
    base_rows = validate(baseline, args.baseline)

    base = {cell_key(r): r for r in base_rows}
    worst = None
    compared = 0
    for row in rows:
        if row["path"] != "workspace":
            continue
        ref = base.get(cell_key(row))
        if ref is None or ref["p50_step_us"] <= 0:
            continue
        compared += 1
        ratio = row["p50_step_us"] / ref["p50_step_us"]
        if worst is None or ratio > worst[0]:
            worst = (ratio, row)
        if ratio > 1.0 + args.threshold:
            fail(
                f"{row['preset']} {row['rows']}x{row['cols']}: p50 "
                f"{row['p50_step_us']:.1f}us vs baseline {ref['p50_step_us']:.1f}us "
                f"({(ratio - 1.0) * 100:+.1f}% > +{args.threshold * 100:.0f}%)"
            )
    if compared == 0:
        fail("no comparable workspace cells between current and baseline")
    ratio, row = worst
    print(
        f"OK: {compared} cells within +{args.threshold * 100:.0f}% of baseline "
        f"(worst {row['preset']} {row['rows']}x{row['cols']}: {(ratio - 1.0) * 100:+.1f}%)"
    )


if __name__ == "__main__":
    main()
