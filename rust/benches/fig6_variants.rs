//! FIG6 (paper Fig 6 + §7): space/time-saving SOAP variants —
//! factorized (Adafactor second moment in the eigenbasis), one-sided
//! (identity on the large side), and both — against SOAP, Shampoo, AdamW.
//! The six runs go through the sweep orchestrator as one job list; loss
//! trajectories and state sizes come back in the result rows (also left in
//! `bench_results/fig6_variants_sweep/`).
//!
//! Expected shape (paper): factorized ≈ SOAP (negligible loss increase);
//! one-sided costs more but still ≥ Shampoo; all variants beat AdamW while
//! the combined variant uses LESS optimizer memory than AdamW.

use soap_lab::experiments::harness::{artifacts_available, bench_model, bench_steps};
use soap_lab::optim::{Hyper, OptKind};
use soap_lab::sweep::{run_sweep, JobSpec, SweepOptions, SweepSpec};
use soap_lab::util::bench::Report;
use soap_lab::util::json::Json;

fn loss_series(row: &Json) -> Vec<(f64, f64)> {
    row.get("losses")
        .as_arr()
        .map(|arr| {
            arr.iter()
                .filter_map(|p| {
                    let p = p.as_arr()?;
                    Some((p.first()?.as_f64()?, p.get(1)?.as_f64()?))
                })
                .collect()
        })
        .unwrap_or_default()
}

fn main() {
    if !artifacts_available() {
        println!("fig6_variants: artifacts missing — run `make artifacts`");
        return;
    }
    let model = bench_model();
    let steps = bench_steps(300);
    println!("fig6: model={model} steps={steps}");

    let h = Hyper::default();
    let cases: Vec<(&str, &str, OptKind, Hyper)> = vec![
        ("adamw", "adamw", OptKind::AdamW, h.clone()),
        ("shampoo", "shampoo", OptKind::Shampoo, h.clone()),
        ("soap", "soap", OptKind::Soap, h.clone()),
        ("soap-fact", "soap (factorized)", OptKind::Soap, h.clone().factorized()),
        ("soap-1side", "soap (one-sided)", OptKind::Soap, h.clone().one_sided()),
        (
            "soap-fact-1side",
            "soap (factorized, one-sided)",
            OptKind::Soap,
            h.clone().factorized().one_sided(),
        ),
    ];
    let jobs: Vec<JobSpec> = cases
        .iter()
        .map(|(id, name, opt, hyper)| {
            JobSpec::new(*id, &model, *opt, steps)
                .with_hyper(hyper.clone())
                .with_assign("variant", *name)
        })
        .collect();
    let spec = SweepSpec::from_jobs("fig6-variants", jobs);
    let outcome = run_sweep(
        &spec,
        &SweepOptions {
            out_dir: "bench_results/fig6_variants_sweep".into(),
            max_concurrency: 2,
            ..SweepOptions::default()
        },
    )
    .expect("sweep");

    let mut report = Report::new(
        &format!("Fig 6: SOAP variants, loss curves [{model}]"),
        "step",
        "loss",
    );
    let mut rows = Vec::new();
    for (id, name, _, _) in &cases {
        let row = outcome.row(id).unwrap_or_else(|| panic!("missing sweep row {id}"));
        assert_eq!(
            row.get("status").as_str(),
            Some("done"),
            "job {id} failed: {}",
            row.get("error").as_str().unwrap_or("unknown error")
        );
        let tail = row.get("tail_loss").as_f64().expect("tail_loss");
        let state_mb = row.get("state_bytes").as_f64().unwrap_or(0.0) / 1e6;
        println!("{name:<30} tail loss {tail:.4}  optimizer state {state_mb:.2} MB");
        rows.push((name.to_string(), tail, state_mb));
        report.add_series(name, loss_series(row));
    }

    let soap = rows.iter().find(|r| r.0 == "soap").unwrap().1;
    let fact = rows.iter().find(|r| r.0 == "soap (factorized)").unwrap().1;
    let adamw_row = rows.iter().find(|r| r.0 == "adamw").unwrap().clone();
    let combo = rows.iter().find(|r| r.0.contains("factorized, one-sided")).unwrap().clone();
    report.note(format!(
        "factorized vs soap: {:+.4} (paper: negligible); combined vs adamw loss {:+.4} with state {:.2} vs {:.2} MB",
        fact - soap,
        combo.1 - adamw_row.1,
        combo.2,
        adamw_row.2
    ));
    report.render_and_save();
}
