//! FIG6 (paper Fig 6 + §7): space/time-saving SOAP variants —
//! factorized (Adafactor second moment in the eigenbasis), one-sided
//! (identity on the large side), and both — against SOAP, Shampoo, AdamW.
//!
//! Expected shape (paper): factorized ≈ SOAP (negligible loss increase);
//! one-sided costs more but still ≥ Shampoo; all variants beat AdamW while
//! the combined variant uses LESS optimizer memory than AdamW.

use soap_lab::experiments::harness::{artifacts_available, bench_model, bench_steps, RunSpec};
use soap_lab::optim::{Hyper, OptKind};
use soap_lab::util::bench::Report;

fn main() {
    if !artifacts_available() {
        println!("fig6_variants: artifacts missing — run `make artifacts`");
        return;
    }
    let model = bench_model();
    let steps = bench_steps(300);
    println!("fig6: model={model} steps={steps}");

    let h = Hyper::default();
    let cases: Vec<(&str, OptKind, Hyper)> = vec![
        ("adamw", OptKind::AdamW, h.clone()),
        ("shampoo", OptKind::Shampoo, h.clone()),
        ("soap", OptKind::Soap, h.clone()),
        ("soap (factorized)", OptKind::Soap, h.clone().factorized()),
        ("soap (one-sided)", OptKind::Soap, h.clone().one_sided()),
        ("soap (factorized, one-sided)", OptKind::Soap, h.clone().factorized().one_sided()),
    ];

    let mut report = Report::new(
        &format!("Fig 6: SOAP variants, loss curves [{model}]"),
        "step",
        "loss",
    );
    let mut rows = Vec::new();
    for (name, opt, hyper) in cases {
        let spec = RunSpec::new(&model, opt, steps).with_hyper(hyper);
        let (log, secs) = spec.run().expect("run");
        // A fresh one-step session for the state-bytes accounting.
        let mut probe = spec.build_session().expect("probe session");
        let _ = probe.step();
        let state_mb = probe.state_bytes() as f64 / 1e6;
        println!(
            "{name:<30} tail loss {:.4}  {:.2}s/step  optimizer state {:.2} MB",
            log.tail_loss(20),
            secs,
            state_mb
        );
        rows.push((name.to_string(), log.tail_loss(20), state_mb));
        report.add_series(name, log.loss_series());
    }

    let soap = rows.iter().find(|r| r.0 == "soap").unwrap().1;
    let fact = rows.iter().find(|r| r.0 == "soap (factorized)").unwrap().1;
    let adamw_row = rows.iter().find(|r| r.0 == "adamw").unwrap().clone();
    let combo = rows.iter().find(|r| r.0.contains("factorized, one-sided")).unwrap().clone();
    report.note(format!(
        "factorized vs soap: {:+.4} (paper: negligible); combined vs adamw loss {:+.4} with state {:.2} vs {:.2} MB",
        fact - soap,
        combo.1 - adamw_row.1,
        combo.2,
        adamw_row.2
    ));
    report.render_and_save();
}
