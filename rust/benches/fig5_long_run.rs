//! FIG5 (paper Fig 5 + §6.4): longer-duration training — 5× the
//! chinchilla-analogue budget (paper: 100× model size instead of 20×) —
//! checking that SOAP's advantage over AdamW persists beyond the
//! compute-optimal regime.

use soap_lab::experiments::harness::{artifacts_available, bench_model, bench_steps, RunSpec};
use soap_lab::optim::OptKind;
use soap_lab::util::bench::Report;

fn main() {
    if !artifacts_available() {
        println!("fig5_long_run: artifacts missing — run `make artifacts`");
        return;
    }
    let model = bench_model();
    let steps = bench_steps(300) * 5;
    println!("fig5: model={model} steps={steps} (5× the fig1 budget)");

    let mut report = Report::new(
        &format!("Fig 5: long-duration loss, SOAP vs AdamW [{model}]"),
        "step",
        "loss",
    );
    let mut tails = Vec::new();
    for opt in [OptKind::AdamW, OptKind::Soap] {
        let (log, _) = RunSpec::new(&model, opt, steps).run().expect("run");
        let tail = log.tail_loss(30);
        println!("{:<6} tail loss {:.4}", opt.name(), tail);
        tails.push((opt, tail));
        report.add_series(opt.name(), log.loss_series());
    }
    let gap = tails[0].1 - tails[1].1;
    report.note(format!(
        "SOAP advantage at 5× budget: {gap:+.4} nats (paper: advantage maintained at 100× model size)"
    ));
    report.render_and_save();
}
