//! FIG7-L (paper Fig 7 left + §7.3): SOAP's wall-clock overhead over AdamW
//! as a function of preconditioning frequency.
//!
//! Expected shape (paper): overhead falls as f grows but approaches a
//! POSITIVE asymptote — the per-step projections (2m²n+2mn²) and factor
//! updates (m³+n³) remain even when the QR refresh amortizes away.

use soap_lab::experiments::harness::{artifacts_available, bench_model, bench_steps, RunSpec};
use soap_lab::optim::OptKind;
use soap_lab::util::bench::Report;

fn main() {
    if !artifacts_available() {
        println!("fig7_overhead: artifacts missing — run `make artifacts`");
        return;
    }
    let model = bench_model();
    let steps = bench_steps(60); // timing-only: short runs suffice
    let freqs = [1u64, 2, 5, 10, 32, 100, 1000];
    println!("fig7 (left): model={model} steps={steps} freqs={freqs:?}");

    // AdamW reference time per step.
    let (adamw_log, adamw_secs) = RunSpec::new(&model, OptKind::AdamW, steps).run().unwrap();
    let _ = adamw_log;
    println!("adamw: {adamw_secs:.3}s/step");

    let mut report = Report::new(
        &format!("Fig 7 (left): SOAP overhead over AdamW vs frequency [{model}]"),
        "precond frequency",
        "step time multiple of AdamW",
    );
    let mut pts = Vec::new();
    let mut refresh_pts = Vec::new();
    for &f in &freqs {
        let (log, secs) = RunSpec::new(&model, OptKind::Soap, steps).with_freq(f).run().unwrap();
        let mult = secs / adamw_secs;
        let refresh_frac = log.refresh_frac();
        println!(
            "soap f={f:<5} {secs:.3}s/step = {mult:.2}× adamw   (refresh {:.1}% of step)",
            100.0 * refresh_frac
        );
        pts.push((f as f64, mult));
        refresh_pts.push((f as f64, refresh_frac));
    }
    let asymptote = pts.last().unwrap().1;
    report.add_series("soap step-time multiple", pts.clone());
    report.add_series(
        "adamw baseline (1.0)",
        freqs.iter().map(|&f| (f as f64, 1.0)).collect(),
    );
    report.note(format!(
        "asymptote ≈ {asymptote:.2}× at f=1000 — {} (paper: overhead approaches an asymptote > 0 \
         from per-step projections/factor updates)",
        if asymptote > 1.02 { "positive residual overhead ✓" } else { "projections negligible at this scale" }
    ));
    report.render_and_save();

    let mut r2 = Report::new(
        &format!("Fig 7 (left, companion): refresh share of step time [{model}]"),
        "precond frequency",
        "refresh fraction",
    );
    r2.add_series("refresh fraction", refresh_pts);
    r2.render_and_save();
}
