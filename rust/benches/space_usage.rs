//! TAB-SPACE (paper §7.2): optimizer memory accounting — measured state
//! bytes per optimizer/variant over a model's parameter shapes, checked
//! against the paper's closed-form expressions:
//!
//!   AdamW                        3mn  (incl. gradient; 2mn optimizer-owned)
//!   Shampoo               3m²+3n²+3mn  (incl. warm-start eigvec caches)
//!   SOAP                  2m²+2n²+3mn
//!   SOAP one-sided       2min²   +3mn
//!   SOAP factorized      2m²+2n²+2mn+m+n
//!   SOAP fact.+one-sided 2min²+2mn+m+n
//!
//! (The gradient's `mn` is charged to the training loop, not the optimizer,
//! so the measured numbers are the paper's formulas minus one `mn`. Shampoo's
//! warm-start eigenvector caches — held to make the periodic root recompute a
//! warm `eigh` — are real optimizer-owned state and counted since the
//! composed-core refactor; the paper's table omits them.)
//!
//! Every case is verified at both `--state-dtype` settings: under bf16 the
//! dtype-routed buffers (Kronecker-factor EMAs, Adam/Adafactor second
//! moments) take 2 bytes per element while momentum, grafting state, and
//! eigenvector/root/projection caches stay at 4 — the formulas here carry
//! that split explicitly, so `state_bytes` is checked to halve exactly the
//! buffers the docs claim it halves.

use soap_lab::coordinator::ShardedOptimizer;
use soap_lab::optim::{Hyper, OptKind, StateDtype};
use soap_lab::runtime::Manifest;
use soap_lab::util::bench::Report;

/// Closed-form §7.2 bytes. `f(m, n)` returns `(dtype_routed, always_f32)`
/// element counts; routed elements take `b` bytes each (4 or 2).
fn formula_bytes(
    shapes: &[(usize, usize)],
    b: usize,
    f: impl Fn(usize, usize) -> (usize, usize),
) -> usize {
    shapes
        .iter()
        .map(|&(m, n)| {
            let (d, s) = f(m, n);
            d * b + s * 4
        })
        .sum()
}

fn main() {
    // Shapes from the manifest when available, else the small-config shapes.
    let shapes: Vec<(usize, usize)> = match Manifest::load(std::path::Path::new("artifacts")) {
        Ok(m) => {
            let cfg = m.configs.values().next().expect("config").clone();
            println!("shapes from manifest config '{}'", cfg.name);
            cfg.shapes()
        }
        Err(_) => {
            println!("artifacts missing — using synthetic shape set");
            vec![(256, 64), (1, 64), (64, 64), (64, 256), (256, 64), (64, 256)]
        }
    };

    let h = Hyper::default();
    let cases: Vec<(&str, OptKind, Hyper)> = vec![
        ("adamw", OptKind::AdamW, h.clone()),
        ("adafactor", OptKind::Adafactor, h.clone()),
        ("shampoo", OptKind::Shampoo, h.clone()),
        ("soap", OptKind::Soap, h.clone()),
        ("soap-onesided", OptKind::Soap, h.clone().one_sided()),
        ("soap-factorized", OptKind::Soap, h.clone().factorized()),
        ("soap-both", OptKind::Soap, h.clone().factorized().one_sided()),
        ("galore", OptKind::Galore, h.clone()),
    ];

    println!(
        "\n{:<18} {:>6} {:>14} {:>14} {:>9}",
        "optimizer", "dtype", "measured", "paper formula", "ratio"
    );
    let mut report = Report::new(
        "§7.2 space usage: measured vs paper formulas",
        "case index",
        "bytes",
    );
    let mut measured_series = Vec::new();
    let mut formula_series = Vec::new();

    let mut case_idx = 0usize;
    for dtype in [StateDtype::F32, StateDtype::Bf16] {
        let b = dtype.bytes();
        for (name, kind, hyper) in &cases {
            let hyper = hyper.clone().with_state_dtype(dtype);
            // Drive one step so lazily-allocated state (Q_L/Q_R, GaLore P)
            // exists.
            let mut opt = ShardedOptimizer::new(*kind, &hyper, &shapes, 2);
            let mut rng = soap_lab::util::rng::Rng::new(7);
            let mut params: Vec<_> = shapes
                .iter()
                .map(|&(m, n)| soap_lab::linalg::Matrix::randn(&mut rng, m, n, 0.1))
                .collect();
            let grads: Vec<_> = shapes
                .iter()
                .map(|&(m, n)| soap_lab::linalg::Matrix::randn(&mut rng, m, n, 0.1))
                .collect();
            opt.step(&mut params, &grads, 1, 0.0);
            let measured = opt.state_bytes();

            // Paper formula, minus the gradient mn (see module docs), per
            // layer, split as (dtype-routed elements, always-f32 elements).
            // 1-D layers always run AdamW under SOAP/GaLore.
            let formula = match *name {
                // M stays f32, V routes.
                "adamw" => formula_bytes(&shapes, b, |m, n| (m * n, m * n)),
                // a, c (and the 1-D full V) route; M stays f32.
                "adafactor" => formula_bytes(&shapes, b, |m, n| {
                    if m == 1 || n == 1 { (m * n + m + n, m * n) } else { (m + n, m * n) }
                }),
                // L, R route; L^{-1/e}, R^{-1/e} + warm-start eigenvector
                // caches (allocated at the first root recompute and honestly
                // counted since the composed-core refactor) + M, V_graft
                // stay f32.
                "shampoo" => formula_bytes(&shapes, b, |m, n| {
                    (m * m + n * n, 2 * m * m + 2 * n * n + 2 * m * n)
                }),
                // L, R, V route; Q_L, Q_R, M stay f32.
                "soap" => formula_bytes(&shapes, b, |m, n| {
                    if m == 1 || n == 1 {
                        (m * n, m * n)
                    } else {
                        (m * m + n * n + m * n, m * m + n * n + m * n)
                    }
                }),
                "soap-onesided" => formula_bytes(&shapes, b, |m, n| {
                    let k = m.min(n);
                    if m == 1 || n == 1 { (m * n, m * n) } else { (k * k + m * n, k * k + m * n) }
                }),
                // L, R, a, c route; Q_L, Q_R, M stay f32.
                "soap-factorized" => formula_bytes(&shapes, b, |m, n| {
                    if m == 1 || n == 1 {
                        (m * n, m * n)
                    } else {
                        (m * m + n * n + m + n, m * m + n * n + m * n)
                    }
                }),
                "soap-both" => formula_bytes(&shapes, b, |m, n| {
                    let k = m.min(n);
                    if m == 1 || n == 1 { (m * n, m * n) } else { (k * k + m + n, k * k + m * n) }
                }),
                // V routes; the SVD projection P and M stay f32.
                "galore" => formula_bytes(&shapes, b, |m, n| {
                    let k = m.min(n);
                    if m == 1 || n == 1 { (m * n, m * n) } else { (m * n, k * k + m * n) }
                }),
                _ => 0,
            };
            let ratio = measured as f64 / formula as f64;
            println!("{name:<18} {:>6} {measured:>14} {formula:>14} {ratio:>9.4}", dtype.name());
            assert!(
                (ratio - 1.0).abs() < 1e-6,
                "{name} ({}): measured {measured} ≠ formula {formula}",
                dtype.name()
            );
            measured_series.push((case_idx as f64, measured as f64));
            formula_series.push((case_idx as f64, formula as f64));
            case_idx += 1;
        }
    }
    report.add_series("measured", measured_series);
    report.add_series("paper formula", formula_series);
    report.note("paper §7.2: soap-both < adamw in optimizer-owned state ✓".to_string());
    report.render_and_save();
    println!("\nall formulas verified exactly ✓");
}
