//! TAB-SPACE (paper §7.2): optimizer memory accounting — measured state
//! bytes per optimizer/variant over a model's parameter shapes, checked
//! against the paper's closed-form expressions:
//!
//!   AdamW                        3mn  (incl. gradient; 2mn optimizer-owned)
//!   Shampoo               3m²+3n²+3mn  (incl. warm-start eigvec caches)
//!   SOAP                  2m²+2n²+3mn
//!   SOAP one-sided       2min²   +3mn
//!   SOAP factorized      2m²+2n²+2mn+m+n
//!   SOAP fact.+one-sided 2min²+2mn+m+n
//!
//! (The gradient's `mn` is charged to the training loop, not the optimizer,
//! so the measured numbers are the paper's formulas minus one `mn`. Shampoo's
//! warm-start eigenvector caches — held to make the periodic root recompute a
//! warm `eigh` — are real optimizer-owned state and counted since the
//! composed-core refactor; the paper's table omits them.)

use soap_lab::coordinator::ShardedOptimizer;
use soap_lab::optim::{Hyper, OptKind};
use soap_lab::runtime::Manifest;
use soap_lab::util::bench::Report;

fn formula_bytes(shapes: &[(usize, usize)], f: impl Fn(usize, usize) -> usize) -> usize {
    shapes.iter().map(|&(m, n)| f(m, n) * 4).sum()
}

fn main() {
    // Shapes from the manifest when available, else the small-config shapes.
    let shapes: Vec<(usize, usize)> = match Manifest::load(std::path::Path::new("artifacts")) {
        Ok(m) => {
            let cfg = m.configs.values().next().expect("config").clone();
            println!("shapes from manifest config '{}'", cfg.name);
            cfg.shapes()
        }
        Err(_) => {
            println!("artifacts missing — using synthetic shape set");
            vec![(256, 64), (1, 64), (64, 64), (64, 256), (256, 64), (64, 256)]
        }
    };

    let h = Hyper::default();
    let cases: Vec<(&str, OptKind, Hyper)> = vec![
        ("adamw", OptKind::AdamW, h.clone()),
        ("adafactor", OptKind::Adafactor, h.clone()),
        ("shampoo", OptKind::Shampoo, h.clone()),
        ("soap", OptKind::Soap, h.clone()),
        ("soap-onesided", OptKind::Soap, h.clone().one_sided()),
        ("soap-factorized", OptKind::Soap, h.clone().factorized()),
        ("soap-both", OptKind::Soap, h.clone().factorized().one_sided()),
        ("galore", OptKind::Galore, h.clone()),
    ];

    println!("\n{:<18} {:>14} {:>14} {:>9}", "optimizer", "measured", "paper formula", "ratio");
    let mut report = Report::new(
        "§7.2 space usage: measured vs paper formulas",
        "case index",
        "bytes",
    );
    let mut measured_series = Vec::new();
    let mut formula_series = Vec::new();

    for (i, (name, kind, hyper)) in cases.iter().enumerate() {
        // Drive one step so lazily-allocated state (Q_L/Q_R, GaLore P) exists.
        let mut opt = ShardedOptimizer::new(*kind, hyper, &shapes, 2);
        let mut rng = soap_lab::util::rng::Rng::new(7);
        let mut params: Vec<_> = shapes
            .iter()
            .map(|&(m, n)| soap_lab::linalg::Matrix::randn(&mut rng, m, n, 0.1))
            .collect();
        let grads: Vec<_> = shapes
            .iter()
            .map(|&(m, n)| soap_lab::linalg::Matrix::randn(&mut rng, m, n, 0.1))
            .collect();
        opt.step(&mut params, &grads, 1, 0.0);
        let measured = opt.state_bytes();

        // Paper formula, minus the gradient mn (see module docs), per layer.
        // 1-D layers always run AdamW under SOAP/GaLore.
        let formula = match *name {
            "adamw" => formula_bytes(&shapes, |m, n| 2 * m * n),
            "adafactor" => formula_bytes(&shapes, |m, n| {
                if m == 1 || n == 1 { 2 * m * n + m + n } else { m * n + m + n }
            }),
            // L, R, L^{-1/e}, R^{-1/e} + warm-start eigenvector caches
            // (allocated at the first root recompute and honestly counted
            // since the composed-core refactor) + M, V_graft.
            "shampoo" => formula_bytes(&shapes, |m, n| 3 * m * m + 3 * n * n + 2 * m * n),
            "soap" => formula_bytes(&shapes, |m, n| {
                if m == 1 || n == 1 { 2 * m * n } else { 2 * m * m + 2 * n * n + 2 * m * n }
            }),
            "soap-onesided" => formula_bytes(&shapes, |m, n| {
                if m == 1 || n == 1 { 2 * m * n } else { 2 * m.min(n) * m.min(n) + 2 * m * n }
            }),
            "soap-factorized" => formula_bytes(&shapes, |m, n| {
                if m == 1 || n == 1 { 2 * m * n } else { 2 * m * m + 2 * n * n + m * n + m + n }
            }),
            "soap-both" => formula_bytes(&shapes, |m, n| {
                if m == 1 || n == 1 { 2 * m * n } else { 2 * m.min(n) * m.min(n) + m * n + m + n }
            }),
            "galore" => formula_bytes(&shapes, |m, n| {
                if m == 1 || n == 1 { 2 * m * n } else { m.min(n) * m.min(n) + 2 * m * n }
            }),
            _ => 0,
        };
        let ratio = measured as f64 / formula as f64;
        println!("{name:<18} {measured:>14} {formula:>14} {ratio:>9.4}");
        assert!(
            (ratio - 1.0).abs() < 1e-6,
            "{name}: measured {measured} ≠ formula {formula}"
        );
        measured_series.push((i as f64, measured as f64));
        formula_series.push((i as f64, formula as f64));
    }
    report.add_series("measured", measured_series);
    report.add_series("paper formula", formula_series);
    report.note("paper §7.2: soap-both < adamw in optimizer-owned state ✓".to_string());
    report.render_and_save();
    println!("\nall formulas verified exactly ✓");
}
