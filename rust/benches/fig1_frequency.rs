//! FIG1-R (paper Fig 1 right): preconditioning-frequency ablation.
//! SOAP and Shampoo at f ∈ {1, 10, 32, 100}, with AdamW as the horizontal
//! reference — all nine runs scheduled as one sweep through the
//! orchestrator (`soap_lab::sweep`), which also leaves the per-job loss
//! trajectories in `bench_results/fig1_frequency_sweep/`.
//!
//! Expected shape (paper §6.2): both beat AdamW at every f; at f = 1 SOAP ≈
//! Shampoo; as f grows both degrade but Shampoo degrades much faster —
//! SOAP's Adam second moment keeps adapting between refreshes, Shampoo's
//! preconditioner is simply stale.

use soap_lab::experiments::harness::{artifacts_available, bench_model, bench_steps};
use soap_lab::optim::{Hyper, OptKind};
use soap_lab::sweep::{run_sweep, JobSpec, SweepOptions, SweepOutcome, SweepSpec};
use soap_lab::util::bench::Report;

fn tail_of(outcome: &SweepOutcome, id: &str) -> f64 {
    let row = outcome.row(id).unwrap_or_else(|| panic!("missing sweep row {id}"));
    assert_eq!(
        row.get("status").as_str(),
        Some("done"),
        "job {id} failed: {}",
        row.get("error").as_str().unwrap_or("unknown error")
    );
    row.get("tail_loss").as_f64().expect("tail_loss")
}

fn main() {
    if !artifacts_available() {
        println!("fig1_frequency: artifacts missing — run `make artifacts`");
        return;
    }
    let model = bench_model();
    let steps = bench_steps(250);
    let freqs = [1u64, 10, 32, 100];
    println!("fig1 (right): model={model} steps={steps} freqs={freqs:?}");

    let mut jobs =
        vec![JobSpec::new("adamw", &model, OptKind::AdamW, steps).with_assign("optimizer", "adamw")];
    for opt in [OptKind::Soap, OptKind::Shampoo] {
        for &f in &freqs {
            jobs.push(
                JobSpec::new(format!("{}-f{f:03}", opt.name()), &model, opt, steps)
                    .with_hyper(Hyper::default().with_freq(f))
                    .with_assign("optimizer", opt.name())
                    .with_assign("freq", format!("{f}")),
            );
        }
    }
    let spec = SweepSpec::from_jobs("fig1-frequency", jobs);
    let outcome = run_sweep(
        &spec,
        &SweepOptions {
            out_dir: "bench_results/fig1_frequency_sweep".into(),
            max_concurrency: 2,
            ..SweepOptions::default()
        },
    )
    .expect("sweep");

    let adamw = tail_of(&outcome, "adamw");
    println!("adamw reference: {adamw:.4}");

    let mut report = Report::new(
        &format!("Fig 1 (right): final loss vs preconditioning frequency [{model}]"),
        "frequency",
        "final loss",
    );
    for opt in [OptKind::Soap, OptKind::Shampoo] {
        let mut pts: Vec<(f64, f64)> = Vec::new();
        for &f in &freqs {
            let tail = tail_of(&outcome, &format!("{}-f{f:03}", opt.name()));
            println!(
                "{:<8} f={f:<4} loss {tail:.4} (Δ vs adamw {:+.4})",
                opt.name(),
                tail - adamw
            );
            pts.push((f as f64, tail));
        }
        report.add_series(opt.name(), pts.clone());
        // Degradation = loss(f_max) − loss(f_min).
        let degr = pts.last().unwrap().1 - pts.first().unwrap().1;
        report.note(format!("{} degradation f=1→100: {degr:+.4}", opt.name()));
    }
    report.add_series(
        "adamw (f-independent)",
        freqs.iter().map(|&f| (f as f64, adamw)).collect(),
    );
    report.note("paper: SOAP degrades significantly slower than Shampoo".to_string());
    report.render_and_save();
}
