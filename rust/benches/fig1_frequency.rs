//! FIG1-R (paper Fig 1 right): preconditioning-frequency ablation.
//! SOAP and Shampoo at f ∈ {1, 10, 32, 100}, with AdamW as the horizontal
//! reference.
//!
//! Expected shape (paper §6.2): both beat AdamW at every f; at f = 1 SOAP ≈
//! Shampoo; as f grows both degrade but Shampoo degrades much faster —
//! SOAP's Adam second moment keeps adapting between refreshes, Shampoo's
//! preconditioner is simply stale.

use soap_lab::experiments::harness::{artifacts_available, bench_model, bench_steps, RunSpec};
use soap_lab::optim::OptKind;
use soap_lab::util::bench::Report;

fn main() {
    if !artifacts_available() {
        println!("fig1_frequency: artifacts missing — run `make artifacts`");
        return;
    }
    let model = bench_model();
    let steps = bench_steps(250);
    let freqs = [1u64, 10, 32, 100];
    println!("fig1 (right): model={model} steps={steps} freqs={freqs:?}");

    let (adamw_log, _) = RunSpec::new(&model, OptKind::AdamW, steps).run().expect("adamw");
    let adamw = adamw_log.tail_loss(20);
    println!("adamw reference: {adamw:.4}");

    let mut report = Report::new(
        &format!("Fig 1 (right): final loss vs preconditioning frequency [{model}]"),
        "frequency",
        "final loss",
    );
    let mut series: Vec<(OptKind, Vec<(f64, f64)>)> =
        vec![(OptKind::Soap, Vec::new()), (OptKind::Shampoo, Vec::new())];
    for &f in &freqs {
        for (opt, pts) in series.iter_mut() {
            let (log, _) = RunSpec::new(&model, *opt, steps).with_freq(f).run().expect("run");
            let tail = log.tail_loss(20);
            println!("{:<8} f={f:<4} loss {tail:.4} (Δ vs adamw {:+.4})", opt.name(), tail - adamw);
            pts.push((f as f64, tail as f64));
        }
    }
    for (opt, pts) in series {
        report.add_series(opt.name(), pts.clone());
        // Degradation = loss(f_max) − loss(f_min).
        let degr = pts.last().unwrap().1 - pts.first().unwrap().1;
        report.note(format!("{} degradation f=1→100: {degr:+.4}", opt.name()));
    }
    report.add_series(
        "adamw (f-independent)",
        freqs.iter().map(|&f| (f as f64, adamw as f64)).collect(),
    );
    report.note("paper: SOAP degrades significantly slower than Shampoo".to_string());
    report.render_and_save();
}
