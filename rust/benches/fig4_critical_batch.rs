//! FIG4 (paper Fig 4 + §6.3): critical-batch-size comparison.
//!
//! A target loss is fixed from an AdamW run at the base batch size; for each
//! batch size (realized via gradient accumulation) we measure steps-to-target
//! for AdamW and SOAP, keeping batch × preconditioning-frequency constant
//! for SOAP exactly as the paper does (so eigendecomposition overhead per
//! token is batch-independent).
//!
//! Expected shape (paper): SOAP needs fewer steps everywhere, tracks the
//! ideal (halve-steps-per-doubled-batch) line further, i.e. has a larger
//! critical batch size.

use soap_lab::experiments::batch_scaling_analysis;
use soap_lab::experiments::harness::{artifacts_available, bench_model, bench_steps, RunSpec};
use soap_lab::optim::OptKind;
use soap_lab::util::bench::Report;

fn main() {
    if !artifacts_available() {
        println!("fig4_critical_batch: artifacts missing — run `make artifacts`");
        return;
    }
    let model = bench_model();
    let base_steps = bench_steps(400);
    // batch multipliers via grad accumulation; base SOAP frequency scaled so
    // accum × f = const (paper §6.3).
    let accums = [1usize, 2, 4, 8];
    let f_base = 32u64;

    println!("fig4: model={model} base_steps={base_steps} accums={accums:?}");

    // Target: AdamW tail loss at the base batch with the full budget.
    let (target_log, _) = RunSpec::new(&model, OptKind::AdamW, base_steps).run().unwrap();
    let target = target_log.tail_loss(20) * 1.002; // slight slack for noise
    println!("target loss (AdamW @ accum=1): {target:.4}");

    let mut report = Report::new(
        &format!("Fig 4 (left): steps to target loss vs batch size [{model}]"),
        "batch multiplier",
        "steps to target",
    );

    for opt in [OptKind::AdamW, OptKind::Soap] {
        let mut pts = Vec::new();
        for &accum in &accums {
            // Larger batches should need ~1/accum the steps; budget 1.2×
            // the ideal so the target is reachable without waste.
            let budget = ((base_steps as f64 / accum as f64) * 1.5).ceil() as u64 + 40;
            let f = (f_base as f64 / accum as f64).ceil().max(1.0) as u64;
            let spec = RunSpec::new(&model, opt, budget)
                .with_accum(accum)
                .with_freq(f);
            let (log, _) = spec.run().expect("run");
            match log.steps_to_loss(target, 10) {
                Some(s) => {
                    println!("{:<6} accum={accum} f={f}: {s} steps to {target:.4}", opt.name());
                    pts.push((accum as f64, s as f64));
                }
                None => {
                    println!(
                        "{:<6} accum={accum} f={f}: target not reached in {budget} steps (tail {:.4})",
                        opt.name(),
                        log.tail_loss(10)
                    );
                }
            }
        }
        if pts.len() >= 2 {
            let analysis = batch_scaling_analysis(&pts);
            for p in &analysis {
                report.note(format!(
                    "{} batch×{}: {:.0} steps ({:.2}× ideal)",
                    opt.name(),
                    p.batch,
                    p.steps_to_target,
                    p.scaling_inefficiency
                ));
            }
            report.add_series(
                &format!("{} ideal linear", opt.name()),
                analysis.iter().map(|p| (p.batch, p.ideal_steps)).collect(),
            );
        }
        report.add_series(opt.name(), pts);
    }
    report.note("paper: SOAP tracks ideal linear scaling further than AdamW".to_string());
    report.render_and_save();
}
