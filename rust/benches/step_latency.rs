//! Step-latency bench: the PR-3 zero-allocation step path, measured.
//!
//! For every optimizer preset × layer shape this reports p50/p99 step
//! latency (µs), steps/sec, allocations per step (counting-allocator shim;
//! the p50 row is the steady-state figure — refresh steps allocate by
//! design), and the workspace arena size. With `--legacy-alloc` it ALSO
//! measures, in the same run, the **pre-PR allocating path**: the frozen
//! seed kernels (`matmul_tn`/`matmul_nt` per-element dot loops, the
//! zero-skipping blocked NN kernel) driving the allocating clone/map/zip
//! step math — and emits the workspace-vs-legacy steps/sec speedups.
//!
//! Results go to `bench_results/step_latency.json`. Knobs:
//! `SOAP_BENCH_STEPS` (timed steps per cell, default 150),
//! `SOAP_BENCH_TELEMETRY=1` (measure with span tracing + metrics enabled,
//! to quantify the telemetry overhead against the default-off run), and
//! `--state-dtype <f32|bf16>` (second-moment storage precision; each
//! workspace row reports the resulting `state_bytes`). The document also
//! records the GEMM kernel that actually ran (`SOAP_GEMM_KERNEL` dispatch).
//!
//! ```sh
//! cargo bench --bench step_latency -- --legacy-alloc
//! cargo bench --bench step_latency -- --state-dtype bf16
//! ```

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

use soap_lab::linalg::{active_gemm_kernel_name, Matrix};
use soap_lab::optim::compose::presets;
use soap_lab::optim::{DynComposed, Hyper, LayerOptimizer, StateDtype};
use soap_lab::util::bench::fmt_duration;
use soap_lab::util::json::Json;
use soap_lab::util::rng::Rng;
use soap_lab::util::stats::Samples;

/// Counts every alloc/realloc so `allocs/step` is measured, not inferred.
struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> usize {
    ALLOCS.load(Ordering::Relaxed)
}

/// The pre-PR substrate and step math, frozen verbatim from the seed so the
/// `--legacy-alloc` arm measures what the repo actually shipped before this
/// PR — not the new kernels driven allocating-ly. Refresh-time
/// decompositions go through the live crate (they are amortized over `f`
/// steps and not what this bench isolates).
mod prepr {
    use soap_lab::linalg::{eigh, power_iter_refresh, Matrix};
    use soap_lab::optim::Hyper;

    /// Seed NN kernel: k-blocked axpy WITH the `av == 0.0` skip.
    pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
        assert_eq!(a.cols, b.rows, "matmul shape mismatch");
        let (m, k, n) = (a.rows, a.cols, b.cols);
        let mut c = Matrix::zeros(m, n);
        const KB: usize = 256;
        for k0 in (0..k).step_by(KB) {
            let k1 = (k0 + KB).min(k);
            for i in 0..m {
                let arow = &a.data[i * k..(i + 1) * k];
                let crow = &mut c.data[i * n..(i + 1) * n];
                for p in k0..k1 {
                    let av = arow[p];
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &b.data[p * n..(p + 1) * n];
                    for (cj, &bj) in crow.iter_mut().zip(brow) {
                        *cj += av * bj;
                    }
                }
            }
        }
        c
    }

    /// Seed TN kernel: index-based axpy with the zero skip, no blocking.
    #[allow(clippy::needless_range_loop)]
    pub fn matmul_tn(a: &Matrix, b: &Matrix) -> Matrix {
        assert_eq!(a.rows, b.rows, "matmul_tn shape mismatch");
        let (k, m, n) = (a.rows, a.cols, b.cols);
        let mut c = Matrix::zeros(m, n);
        for p in 0..k {
            let arow = a.row(p);
            let brow = b.row(p);
            for i in 0..m {
                let av = arow[i];
                if av == 0.0 {
                    continue;
                }
                let crow = &mut c.data[i * n..(i + 1) * n];
                for j in 0..n {
                    crow[j] += av * brow[j];
                }
            }
        }
        c
    }

    /// Seed NT kernel: per-element serial dot product (the accumulation
    /// chain that cannot vectorize — the panel-packing rationale).
    pub fn matmul_nt(a: &Matrix, b: &Matrix) -> Matrix {
        assert_eq!(a.cols, b.cols, "matmul_nt shape mismatch");
        let (m, k, n) = (a.rows, a.cols, b.rows);
        let mut c = Matrix::zeros(m, n);
        for i in 0..m {
            let arow = a.row(i);
            for j in 0..n {
                let brow = b.row(j);
                let mut acc = 0.0f32;
                for p in 0..k {
                    acc += arow[p] * brow[p];
                }
                c.data[i * n + j] = acc;
            }
        }
        c
    }

    fn factored_normalize(num: &Matrix, a: &[f32], c: &[f32], eps: f32) -> Matrix {
        let sum_a: f32 = a.iter().map(|&x| x as f64).sum::<f64>() as f32;
        let inv_sum = if sum_a > 0.0 { 1.0 / sum_a } else { 0.0 };
        Matrix::from_fn(num.rows, num.cols, |i, j| {
            let vhat = (a[i] * c[j] * inv_sum).max(0.0);
            num.at(i, j) / (vhat + eps).sqrt()
        })
    }

    /// Pre-PR SOAP (inline mode): allocating rotations/EMAs over the seed
    /// kernels. `h.factorized` selects the rank-1 second moment.
    pub struct Soap {
        h: Hyper,
        m: Matrix,
        l: Option<Matrix>,
        r: Option<Matrix>,
        ql: Option<Matrix>,
        qr: Option<Matrix>,
        v: Option<Matrix>,
        va: Vec<f32>,
        vc: Vec<f32>,
        initialized: bool,
    }

    impl Soap {
        pub fn new(rows: usize, cols: usize, h: Hyper) -> Self {
            let factorized = h.factorized;
            Self {
                m: Matrix::zeros(rows, cols),
                l: Some(Matrix::zeros(rows, rows)),
                r: Some(Matrix::zeros(cols, cols)),
                ql: None,
                qr: None,
                v: (!factorized).then(|| Matrix::zeros(rows, cols)),
                va: if factorized { vec![0.0; rows] } else { Vec::new() },
                vc: if factorized { vec![0.0; cols] } else { Vec::new() },
                initialized: false,
                h,
            }
        }

        fn project(&self, x: &Matrix) -> Matrix {
            let mut y = match &self.ql {
                Some(ql) => matmul_tn(ql, x),
                None => x.clone(),
            };
            if let Some(qr) = &self.qr {
                y = matmul(&y, qr);
            }
            y
        }

        fn project_back(&self, x: &Matrix) -> Matrix {
            let mut y = match &self.ql {
                Some(ql) => matmul(ql, x),
                None => x.clone(),
            };
            if let Some(qr) = &self.qr {
                y = matmul_nt(&y, qr);
            }
            y
        }

        pub fn update(&mut self, w: &mut Matrix, g: &Matrix, t: u64, lr: f32) {
            let h = self.h.clone();
            if !self.initialized {
                if let Some(l) = &mut self.l {
                    *l = matmul_nt(g, g);
                    let (_, v) = eigh(l);
                    self.ql = Some(v);
                }
                if let Some(r) = &mut self.r {
                    *r = matmul_tn(g, g);
                    let (_, v) = eigh(r);
                    self.qr = Some(v);
                }
                self.initialized = true;
            }

            self.m.ema_inplace(g, h.beta1);
            let g_rot = self.project(g);
            let m_rot = self.project(&self.m);

            let bc1 = 1.0 - h.beta1.powi(t as i32);
            let bc2 = 1.0 - h.beta2.powi(t as i32);
            let m_hat = m_rot.scale(1.0 / bc1);

            let n_rot = if let Some(v) = &mut self.v {
                let g2 = g_rot.hadamard(&g_rot);
                v.ema_inplace(&g2, h.beta2);
                m_hat.zip(v, |mi, vi| mi / ((vi / bc2).max(0.0).sqrt() + h.eps))
            } else {
                let g2 = g_rot.hadamard(&g_rot);
                let rows = g2.row_sums();
                let cols = g2.col_sums();
                for (ai, ri) in self.va.iter_mut().zip(&rows) {
                    *ai = h.beta2 * *ai + (1.0 - h.beta2) * ri;
                }
                for (ci, cj) in self.vc.iter_mut().zip(&cols) {
                    *ci = h.beta2 * *ci + (1.0 - h.beta2) * cj;
                }
                let a_hat: Vec<f32> = self.va.iter().map(|&x| x / bc2).collect();
                let c_hat: Vec<f32> = self.vc.iter().map(|&x| x / bc2).collect();
                factored_normalize(&m_hat, &a_hat, &c_hat, h.eps)
            };

            let n = self.project_back(&n_rot);
            w.axpy_inplace(-lr, &n);
            if h.weight_decay != 0.0 {
                w.scale_inplace(1.0 - lr * h.weight_decay);
            }

            if let Some(l) = &mut self.l {
                let ggt = matmul_nt(g, g);
                l.ema_inplace(&ggt, h.shampoo_beta);
            }
            if let Some(r) = &mut self.r {
                let gtg = matmul_tn(g, g);
                r.ema_inplace(&gtg, h.shampoo_beta);
            }
            if h.is_refresh_step(t) {
                if let (Some(l), Some(ql)) = (&self.l, &self.ql) {
                    self.ql = Some(power_iter_refresh(l, ql));
                }
                if let (Some(r), Some(qr)) = (&self.r, &self.qr) {
                    self.qr = Some(power_iter_refresh(r, qr));
                }
            }
        }
    }

    /// Pre-PR AdamW: the allocating hadamard/zip chain.
    pub struct AdamW {
        h: Hyper,
        m: Matrix,
        v: Matrix,
    }

    impl AdamW {
        pub fn new(rows: usize, cols: usize, h: Hyper) -> Self {
            Self { m: Matrix::zeros(rows, cols), v: Matrix::zeros(rows, cols), h }
        }

        pub fn update(&mut self, w: &mut Matrix, g: &Matrix, t: u64, lr: f32) {
            let h = &self.h;
            self.m.ema_inplace(g, h.beta1);
            let g2 = g.hadamard(g);
            self.v.ema_inplace(&g2, h.beta2);
            let bc1 = 1.0 - h.beta1.powi(t as i32);
            let bc2 = 1.0 - h.beta2.powi(t as i32);
            let dir = self
                .m
                .zip(&self.v, |mi, vi| (mi / bc1) / ((vi / bc2).max(0.0).sqrt() + h.eps));
            w.axpy_inplace(-lr, &dir);
            if h.weight_decay != 0.0 {
                w.scale_inplace(1.0 - lr * h.weight_decay);
            }
        }
    }

    /// Pre-PR Adafactor (2-D path): allocating factored chain.
    pub struct Adafactor {
        h: Hyper,
        m: Matrix,
        va: Vec<f32>,
        vc: Vec<f32>,
    }

    impl Adafactor {
        pub fn new(rows: usize, cols: usize, h: Hyper) -> Self {
            Self { m: Matrix::zeros(rows, cols), va: vec![0.0; rows], vc: vec![0.0; cols], h }
        }

        pub fn update(&mut self, w: &mut Matrix, g: &Matrix, t: u64, lr: f32) {
            let h = self.h.clone();
            let bc1 = 1.0 - h.beta1.powi(t as i32);
            let bc2 = 1.0 - h.beta2.powi(t as i32);
            self.m.ema_inplace(g, h.beta1);
            let g2 = g.hadamard(g);
            let rows = g2.row_sums();
            let cols = g2.col_sums();
            for (ai, ri) in self.va.iter_mut().zip(&rows) {
                *ai = h.beta2 * *ai + (1.0 - h.beta2) * ri;
            }
            for (ci, cj) in self.vc.iter_mut().zip(&cols) {
                *ci = h.beta2 * *ci + (1.0 - h.beta2) * cj;
            }
            let a_hat: Vec<f32> = self.va.iter().map(|&x| x / bc2).collect();
            let c_hat: Vec<f32> = self.vc.iter().map(|&x| x / bc2).collect();
            let m_hat = self.m.scale(1.0 / bc1);
            let dir = factored_normalize(&m_hat, &a_hat, &c_hat, h.eps);
            w.axpy_inplace(-lr, &dir);
            if h.weight_decay != 0.0 {
                w.scale_inplace(1.0 - lr * h.weight_decay);
            }
        }
    }
}

struct Row {
    preset: &'static str,
    path: &'static str,
    rows: usize,
    cols: usize,
    p50_us: f64,
    p99_us: f64,
    steps_per_sec: f64,
    /// Median per-step allocation count — the steady-state figure (refresh
    /// steps allocate by design and land in the tail).
    allocs_per_step_p50: f64,
    allocs_per_step_mean: f64,
    scratch_bytes: usize,
    /// Persistent optimizer state bytes (§7.2 accounting) — halves for the
    /// dtype-routed buffers under `--state-dtype bf16`. 0 for legacy rows.
    state_bytes: usize,
}

/// Drive `step` over a fixed gradient stream and measure per-step latency
/// and allocation counts. Measurement buffers are pre-reserved so the
/// harness itself allocates nothing inside the timed window.
fn drive(
    rows: usize,
    cols: usize,
    warmup: usize,
    steps: usize,
    mut step: impl FnMut(&mut Matrix, &Matrix, u64),
) -> (f64, f64, f64, f64, f64) {
    let mut rng = Rng::new(7);
    let grads: Vec<Matrix> = (0..32).map(|_| Matrix::randn(&mut rng, rows, cols, 0.5)).collect();
    let mut w = Matrix::zeros(rows, cols);
    for i in 0..warmup {
        step(&mut w, &grads[i % grads.len()], i as u64 + 1);
    }
    let mut times_us: Vec<f64> = Vec::with_capacity(steps);
    let mut step_allocs: Vec<f64> = Vec::with_capacity(steps);
    let t_all = Instant::now();
    for i in 0..steps {
        let t = (warmup + i) as u64 + 1;
        let g = &grads[(warmup + i) % grads.len()];
        let a0 = allocs();
        let t0 = Instant::now();
        step(&mut w, g, t);
        times_us.push(t0.elapsed().as_secs_f64() * 1e6);
        step_allocs.push((allocs() - a0) as f64);
    }
    let total = t_all.elapsed().as_secs_f64();
    let mut ts = Samples::new();
    for &x in &times_us {
        ts.push(x);
    }
    let mut asamp = Samples::new();
    let mut amean = 0.0;
    for &x in &step_allocs {
        asamp.push(x);
        amean += x;
    }
    amean /= steps as f64;
    (ts.quantile(0.50), ts.quantile(0.99), steps as f64 / total, asamp.quantile(0.50), amean)
}

fn row_json(r: &Row) -> Json {
    Json::obj(vec![
        ("preset", Json::str(r.preset)),
        ("path", Json::str(r.path)),
        ("rows", Json::num(r.rows as f64)),
        ("cols", Json::num(r.cols as f64)),
        ("p50_step_us", Json::num(r.p50_us)),
        ("p99_step_us", Json::num(r.p99_us)),
        ("steps_per_sec", Json::num(r.steps_per_sec)),
        ("allocs_per_step_p50", Json::num(r.allocs_per_step_p50)),
        ("allocs_per_step_mean", Json::num(r.allocs_per_step_mean)),
        ("scratch_bytes", Json::num(r.scratch_bytes as f64)),
        ("state_bytes", Json::num(r.state_bytes as f64)),
    ])
}

/// `--flag value` or `--flag=value` from the bench argv.
fn arg_value(name: &str) -> Option<String> {
    let mut args = std::env::args();
    while let Some(a) = args.next() {
        if a == name {
            return args.next();
        }
        if let Some(v) = a.strip_prefix(name).and_then(|r| r.strip_prefix('=')) {
            return Some(v.to_string());
        }
    }
    None
}

fn main() {
    let legacy = std::env::args().any(|a| a == "--legacy-alloc");
    let telemetry = std::env::var("SOAP_BENCH_TELEMETRY").map(|v| v == "1").unwrap_or(false);
    soap_lab::telemetry::set_enabled(telemetry);
    let steps: usize = std::env::var("SOAP_BENCH_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(150);
    let warmup = (steps / 5).clamp(10, 50);
    let state_dtype = match arg_value("--state-dtype") {
        Some(v) => StateDtype::parse(&v).expect("--state-dtype"),
        None => StateDtype::F32,
    };
    let h = Hyper::default().with_state_dtype(state_dtype); // f = 10, phase 0
    let shapes: [(usize, usize); 3] = [(64, 256), (128, 128), (32, 1024)];

    type Build = fn(usize, usize, Hyper) -> DynComposed;
    let builds: [(&str, Build); 6] = [
        ("soap", presets::soap),
        ("soap-factorized", |r, c, h| presets::soap(r, c, Hyper { factorized: true, ..h })),
        ("shampoo", presets::shampoo),
        ("galore", presets::galore),
        ("adamw", presets::adamw),
        ("adafactor", presets::adafactor),
    ];

    let mut rows_out: Vec<Row> = Vec::new();
    println!(
        "{:<18} {:<13} {:>9} {:>10} {:>10} {:>11} {:>11}",
        "preset", "path", "shape", "p50", "p99", "steps/s", "allocs/step"
    );
    let mut emit = |r: Row| {
        println!(
            "{:<18} {:<13} {:>9} {:>10} {:>10} {:>11.1} {:>11.1}",
            r.preset,
            r.path,
            format!("{}x{}", r.rows, r.cols),
            fmt_duration(r.p50_us * 1e-6),
            fmt_duration(r.p99_us * 1e-6),
            r.steps_per_sec,
            r.allocs_per_step_p50,
        );
        rows_out.push(r);
    };

    for &(m, n) in &shapes {
        for (preset, build) in builds {
            let mut opt = build(m, n, h.clone());
            let (p50, p99, sps, ap50, amean) =
                drive(m, n, warmup, steps, |w, g, t| opt.update(w, g, t, 1e-3));
            emit(Row {
                preset,
                path: "workspace",
                rows: m,
                cols: n,
                p50_us: p50,
                p99_us: p99,
                steps_per_sec: sps,
                allocs_per_step_p50: ap50,
                allocs_per_step_mean: amean,
                scratch_bytes: opt.scratch_bytes(),
                state_bytes: opt.state_bytes(),
            });
        }
        if legacy {
            let mut soap = prepr::Soap::new(m, n, h.clone());
            let (p50, p99, sps, ap50, amean) =
                drive(m, n, warmup, steps, |w, g, t| soap.update(w, g, t, 1e-3));
            emit(Row {
                preset: "soap",
                path: "legacy-alloc",
                rows: m,
                cols: n,
                p50_us: p50,
                p99_us: p99,
                steps_per_sec: sps,
                allocs_per_step_p50: ap50,
                allocs_per_step_mean: amean,
                scratch_bytes: 0,
                state_bytes: 0,
            });
            let mut soap_f =
                prepr::Soap::new(m, n, Hyper { factorized: true, ..h.clone() });
            let (p50, p99, sps, ap50, amean) =
                drive(m, n, warmup, steps, |w, g, t| soap_f.update(w, g, t, 1e-3));
            emit(Row {
                preset: "soap-factorized",
                path: "legacy-alloc",
                rows: m,
                cols: n,
                p50_us: p50,
                p99_us: p99,
                steps_per_sec: sps,
                allocs_per_step_p50: ap50,
                allocs_per_step_mean: amean,
                scratch_bytes: 0,
                state_bytes: 0,
            });
            let mut adamw = prepr::AdamW::new(m, n, h.clone());
            let (p50, p99, sps, ap50, amean) =
                drive(m, n, warmup, steps, |w, g, t| adamw.update(w, g, t, 1e-3));
            emit(Row {
                preset: "adamw",
                path: "legacy-alloc",
                rows: m,
                cols: n,
                p50_us: p50,
                p99_us: p99,
                steps_per_sec: sps,
                allocs_per_step_p50: ap50,
                allocs_per_step_mean: amean,
                scratch_bytes: 0,
                state_bytes: 0,
            });
            let mut adafactor = prepr::Adafactor::new(m, n, h.clone());
            let (p50, p99, sps, ap50, amean) =
                drive(m, n, warmup, steps, |w, g, t| adafactor.update(w, g, t, 1e-3));
            emit(Row {
                preset: "adafactor",
                path: "legacy-alloc",
                rows: m,
                cols: n,
                p50_us: p50,
                p99_us: p99,
                steps_per_sec: sps,
                allocs_per_step_p50: ap50,
                allocs_per_step_mean: amean,
                scratch_bytes: 0,
                state_bytes: 0,
            });
        }
    }

    // Workspace-vs-legacy speedups (same run, same gradient streams).
    let mut speedups: Vec<Json> = Vec::new();
    if legacy {
        println!();
        for ws_row in rows_out.iter().filter(|r| r.path == "workspace") {
            if let Some(lg) = rows_out.iter().find(|r| {
                r.path == "legacy-alloc"
                    && r.preset == ws_row.preset
                    && (r.rows, r.cols) == (ws_row.rows, ws_row.cols)
            }) {
                let ratio = ws_row.steps_per_sec / lg.steps_per_sec.max(1e-12);
                println!(
                    "speedup {:<18} {}x{}: {:.2}x steps/sec vs pre-PR allocating path{}",
                    ws_row.preset,
                    ws_row.rows,
                    ws_row.cols,
                    ratio,
                    if ws_row.preset == "soap" && ratio >= 2.0 { "  [acceptance PASS]" } else { "" },
                );
                speedups.push(Json::obj(vec![
                    ("preset", Json::str(ws_row.preset)),
                    ("rows", Json::num(ws_row.rows as f64)),
                    ("cols", Json::num(ws_row.cols as f64)),
                    ("steps_per_sec_ratio", Json::num(ratio)),
                ]));
            }
        }
    }

    let doc = Json::obj(vec![
        ("bench", Json::str("step_latency")),
        ("timed_steps", Json::num(steps as f64)),
        ("warmup_steps", Json::num(warmup as f64)),
        ("legacy_measured", Json::Bool(legacy)),
        ("telemetry", Json::Bool(telemetry)),
        ("state_dtype", Json::str(state_dtype.name())),
        ("gemm_kernel", Json::str(active_gemm_kernel_name())),
        (
            "cpus",
            Json::num(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) as f64),
        ),
        ("rows", Json::arr(rows_out.iter().map(row_json))),
        ("speedups_vs_legacy_alloc", Json::Arr(speedups)),
    ]);
    std::fs::create_dir_all("bench_results").expect("create bench_results/");
    std::fs::write("bench_results/step_latency.json", doc.pretty())
        .expect("write step_latency.json");
    println!("\nwrote bench_results/step_latency.json");
}
