//! TAB-TIME (paper §7.3): per-step optimizer cost — measured per-layer
//! update time for SOAP/Shampoo/variants vs the paper's FLOP model
//! (m³+n³+2m²n+2mn² for SOAP; m³+n³+m²n+mn² for Shampoo), plus the
//! native-vs-PJRT(Pallas) hot-path comparison for the §Perf log.

use std::time::Instant;

use soap_lab::linalg::Matrix;
use soap_lab::optim::{Hyper, OptKind};
use soap_lab::util::bench::{fmt_duration, print_table, Bencher, Measurement};
use soap_lab::util::rng::Rng;

fn time_updates(kind: OptKind, hyper: &Hyper, m: usize, n: usize, iters: usize) -> f64 {
    let mut opt = kind.build(m, n, hyper);
    let mut rng = Rng::new(1);
    let mut w = Matrix::randn(&mut rng, m, n, 0.1);
    let g = Matrix::randn(&mut rng, m, n, 0.1);
    // Warm up (first step pays eigh init for SOAP).
    opt.update(&mut w, &g, 1, 1e-4);
    let t0 = Instant::now();
    for t in 0..iters {
        opt.update(&mut w, &g, t as u64 + 2, 1e-4);
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

fn main() {
    let hyper = Hyper { precond_freq: 1_000_000, ..Hyper::default() }; // per-step cost only
    let shapes = [(64usize, 64usize), (128, 128), (256, 256), (128, 512)];
    let iters = 30;

    println!("== §7.3 per-step optimizer cost (refresh excluded via huge f) ==");
    println!(
        "{:<10} {:>9} {:>12} {:>12} {:>12} {:>16}",
        "shape", "adamw", "shampoo", "soap", "soap-1sided", "soap/shampoo"
    );
    let mut soap_per_flop = Vec::new();
    for &(m, n) in &shapes {
        let t_adam = time_updates(OptKind::AdamW, &hyper, m, n, iters);
        let t_sham = time_updates(OptKind::Shampoo, &hyper, m, n, iters);
        let t_soap = time_updates(OptKind::Soap, &hyper, m, n, iters);
        let t_one = time_updates(OptKind::Soap, &Hyper { one_sided: true, ..hyper.clone() }, m, n, iters);
        println!(
            "{:<10} {:>9} {:>12} {:>12} {:>12} {:>15.2}x",
            format!("{m}x{n}"),
            fmt_duration(t_adam),
            fmt_duration(t_sham),
            fmt_duration(t_soap),
            fmt_duration(t_one),
            t_soap / t_sham
        );
        let flops = (m * m * m + n * n * n + 2 * m * m * n + 2 * m * n * n) as f64;
        soap_per_flop.push((format!("{m}x{n}"), t_soap / flops));
    }

    // The paper's claim: SOAP per-step cost exceeds Shampoo's
    // (2m²n+2mn² vs m²n+mn² projection terms). Check the trend holds.
    println!("\nSOAP seconds-per-model-FLOP (should be ~constant if the FLOP model fits):");
    for (shape, spf) in &soap_per_flop {
        println!("  {shape:<10} {:.3e} s/FLOP", spf);
    }

    // Native vs PJRT/Pallas hot path for the 64x64 update.
    if std::path::Path::new("artifacts/manifest.json").exists() {
        use soap_lab::runtime::{literal_from_matrix, literal_scalar, Engine};
        let engine = Engine::load("artifacts").unwrap();
        let mut rng = Rng::new(2);
        let (m, n) = (64, 64);
        let w = Matrix::randn(&mut rng, m, n, 0.1);
        let g = Matrix::randn(&mut rng, m, n, 0.1);
        let mm = Matrix::zeros(m, n);
        let v = Matrix::zeros(m, n);
        let l = Matrix::rand_psd(&mut rng, m);
        let r = Matrix::rand_psd(&mut rng, n);
        let (ql, _) = soap_lab::linalg::qr_positive(&Matrix::randn(&mut rng, m, m, 1.0));
        let (qr, _) = soap_lab::linalg::qr_positive(&Matrix::randn(&mut rng, n, n, 1.0));

        let b = Bencher::new(3, 15);
        let mut rows: Vec<Measurement> = Vec::new();
        rows.push(b.measure("native soap update 64x64", || {
            let hyper = Hyper { precond_freq: 1_000_000, ..Hyper::default() };
            let mut opt = OptKind::Soap.build(m, n, &hyper);
            let mut w2 = w.clone();
            opt.update(&mut w2, &g, 2, 1e-4);
        }));
        rows.push(b.measure("pjrt/pallas soap_update_64x64", || {
            engine
                .run(
                    "soap_update_64x64",
                    &[
                        literal_from_matrix(&w).unwrap(),
                        literal_from_matrix(&mm).unwrap(),
                        literal_from_matrix(&v).unwrap(),
                        literal_from_matrix(&l).unwrap(),
                        literal_from_matrix(&r).unwrap(),
                        literal_from_matrix(&ql).unwrap(),
                        literal_from_matrix(&qr).unwrap(),
                        literal_from_matrix(&g).unwrap(),
                        literal_scalar(2.0),
                        literal_scalar(1e-4),
                    ],
                )
                .unwrap();
        }));
        rows.push(b.measure("pjrt soap_refresh_64 (Alg 4)", || {
            engine
                .run(
                    "soap_refresh_64",
                    &[literal_from_matrix(&l).unwrap(), literal_from_matrix(&ql).unwrap()],
                )
                .unwrap();
        }));
        print_table("hot path: native vs PJRT/Pallas artifacts", &rows);
    } else {
        println!("\n(artifacts missing — skipping PJRT hot-path comparison)");
    }
}
