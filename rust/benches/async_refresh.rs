//! ASYNC-REFRESH: inline vs background eigenbasis refresh on the native
//! NPLM workload (artifact-free, so it runs on any checkout).
//!
//! The claim under test (ISSUE 1 acceptance): with `RefreshMode::Async` at
//! f = 10, the eigenbasis refresh no longer appears in hot-path step timing,
//! p99 step latency drops vs `Inline` at equal f, and final loss matches
//! within 1%. Emits the human-readable comparison plus
//! `bench_results/async_refresh.json` for the record.
//!
//! Env knobs: `SOAP_BENCH_STEPS` (default 500), `SOAP_ASYNC_BENCH_F`
//! (default 10), and `SOAP_BENCH_OPT` (or the first CLI arg) — any preset
//! name or `basis=…,inner=…[,graft=…]` composition spec, so novel combos
//! can be benchmarked without code changes:
//!
//! ```sh
//! cargo bench --bench async_refresh -- basis=eigen:one-sided,inner=adafactor
//! ```

use soap_lab::coordinator::TrainLog;
use soap_lab::experiments::harness::bench_steps;
use soap_lab::optim::{Hyper, OptKind, RefreshMode, Schedule};
use soap_lab::session::{ModelSpec, TrainSession};
use soap_lab::util::bench::{fmt_duration, Report};
use soap_lab::util::json::Json;

struct Arm {
    log: TrainLog,
    bg_secs: f64,
    staleness: f64,
}

fn run(opt: OptKind, mode: RefreshMode, steps: u64, freq: u64) -> Arm {
    // The `nplm` preset is large-ish so the refresh actually costs
    // something: layer shapes (128×48), (192×96), (96×128) ⇒ eigenbases up
    // to 192×192.
    let mut session = TrainSession::builder()
        .model(ModelSpec::parse("nplm").expect("builtin model"))
        .optimizer(opt)
        .hyper(Hyper { precond_freq: freq, ..Hyper::default() }.with_refresh_mode(mode))
        .schedule(Schedule::Constant { lr: 0.01 })
        .steps(steps)
        .seed(7)
        .build()
        .expect("bench session");
    let log = session.run().expect("bench run");
    session.wait_refresh_idle();
    Arm {
        bg_secs: session.async_refresh_seconds(),
        staleness: log.mean_staleness(),
        log,
    }
}

fn arm_json(arm: &Arm) -> Json {
    Json::obj(vec![
        ("final_loss", Json::num(arm.log.final_loss() as f64)),
        ("tail_loss", Json::num(arm.log.tail_loss(20) as f64)),
        ("tokens_per_second", Json::num(arm.log.tokens_per_second())),
        ("p50_step_s", Json::num(arm.log.step_time_quantile(0.50))),
        ("p99_step_s", Json::num(arm.log.step_time_quantile(0.99))),
        ("hot_refresh_s", Json::num(arm.log.refresh_seconds_total())),
        ("bg_refresh_s", Json::num(arm.bg_secs)),
        ("refresh_frac", Json::num(arm.log.refresh_frac())),
        ("mean_staleness_steps", Json::num(arm.staleness)),
    ])
}

fn main() {
    let steps = bench_steps(500);
    let freq: u64 = std::env::var("SOAP_ASYNC_BENCH_F")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(10);
    // Optimizer under test: preset name or composition spec (first non-flag
    // CLI arg, else SOAP_BENCH_OPT, else soap).
    let opt_spec = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with('-'))
        .or_else(|| std::env::var("SOAP_BENCH_OPT").ok())
        .unwrap_or_else(|| "soap".to_string());
    let opt = OptKind::parse(&opt_spec).unwrap_or_else(|e| {
        eprintln!("error: {e:#}");
        std::process::exit(2);
    });
    println!("async_refresh: native NPLM, optimizer={} steps={steps} f={freq}", opt.name());

    let inline = run(opt, RefreshMode::Inline, steps, freq);
    let asynced = run(opt, RefreshMode::Async, steps, freq);

    let row = |name: &str, a: &Arm| {
        println!(
            "{name:<8} p50 {:>9}  p99 {:>9}  {:>8.0} tok/s  hot-refresh {:>9} ({:>4.1}%)  bg {:>9}  stale {:>4.1}  tail loss {:.4}",
            fmt_duration(a.log.step_time_quantile(0.50)),
            fmt_duration(a.log.step_time_quantile(0.99)),
            a.log.tokens_per_second(),
            fmt_duration(a.log.refresh_seconds_total()),
            100.0 * a.log.refresh_frac(),
            fmt_duration(a.bg_secs),
            a.staleness,
            a.log.tail_loss(20),
        );
    };
    row("inline", &inline);
    row("async", &asynced);

    let p99_inline = inline.log.step_time_quantile(0.99);
    let p99_async = asynced.log.step_time_quantile(0.99);
    let loss_gap = (asynced.log.tail_loss(20) - inline.log.tail_loss(20)).abs()
        / inline.log.tail_loss(20).abs().max(1e-9);
    let hot_refresh_gone = asynced.log.refresh_frac() < 0.1 * inline.log.refresh_frac().max(1e-12)
        || asynced.log.refresh_seconds_total() < 0.05 * inline.log.refresh_seconds_total().max(1e-12)
        || inline.log.refresh_seconds_total() == 0.0;

    println!();
    println!(
        "p99 step: inline {} -> async {} ({:+.1}%)",
        fmt_duration(p99_inline),
        fmt_duration(p99_async),
        100.0 * (p99_async / p99_inline.max(1e-12) - 1.0)
    );
    println!(
        "acceptance: refresh off hot path: {}   p99 drop: {}   loss gap {:.2}% (<1%: {})",
        if hot_refresh_gone { "PASS" } else { "FAIL" },
        if p99_async < p99_inline { "PASS" } else { "FAIL" },
        100.0 * loss_gap,
        if loss_gap < 0.01 { "PASS" } else { "FAIL" },
    );

    let mut report = Report::new(
        "ASYNC-REFRESH: inline vs background eigenbasis refresh [nplm]",
        "step",
        "step time (s)",
    );
    report.add_series(
        "inline step time",
        inline.log.timings.iter().enumerate().map(|(i, t)| (i as f64, t.total())).collect(),
    );
    report.add_series(
        "async step time",
        asynced.log.timings.iter().enumerate().map(|(i, t)| (i as f64, t.total())).collect(),
    );
    report.note(format!(
        "async mean staleness {:.1} steps (inline {:.1}); background refresh {:.3}s overlapped",
        asynced.staleness, inline.staleness, asynced.bg_secs
    ));
    report.render_and_save();

    let out = Json::obj(vec![
        ("bench", Json::str("async_refresh")),
        ("optimizer", Json::str(opt.name())),
        ("model", Json::str(inline.log.model.clone())),
        ("steps", Json::num(steps as f64)),
        ("precond_freq", Json::num(freq as f64)),
        ("inline", arm_json(&inline)),
        ("async", arm_json(&asynced)),
        ("p99_speedup", Json::num(p99_inline / p99_async.max(1e-12))),
        ("tail_loss_gap_frac", Json::num(loss_gap)),
    ]);
    std::fs::create_dir_all("bench_results").ok();
    let path = "bench_results/async_refresh.json";
    match std::fs::write(path, out.pretty()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => println!("warn: could not write {path}: {e}"),
    }
}
