//! FIG7-R (paper Fig 7 right): eigenbasis refresh method — one
//! power-iteration step + QR (Algorithm 4, `torch.linalg.qr` analogue)
//! versus a fresh eigendecomposition every refresh (`torch.linalg.eigh`
//! analogue, our Jacobi solver).
//!
//! Expected shape (paper): the two perform comparably across the frequency
//! spectrum while QR is computationally cheaper.

use soap_lab::experiments::harness::{artifacts_available, bench_model, bench_steps, RunSpec};
use soap_lab::optim::{Hyper, OptKind, RefreshMethod};
use soap_lab::util::bench::Report;

fn main() {
    if !artifacts_available() {
        println!("fig7_qr_vs_eigh: artifacts missing — run `make artifacts`");
        return;
    }
    let model = bench_model();
    let steps = bench_steps(250);
    let freqs = [10u64, 32, 100];
    println!("fig7 (right): model={model} steps={steps} freqs={freqs:?}");

    let mut report = Report::new(
        &format!("Fig 7 (right): QR power iteration vs eigh refresh [{model}]"),
        "precond frequency",
        "final loss",
    );
    for (label, method) in [
        ("qr power-iteration (Alg 4)", RefreshMethod::QrPowerIteration),
        ("eigh (fresh decomposition)", RefreshMethod::Eigh),
    ] {
        let mut pts = Vec::new();
        let mut refresh_total = 0.0;
        for &f in &freqs {
            let hyper = Hyper { refresh: method, precond_freq: f, ..Hyper::default() };
            let (log, _) = RunSpec::new(&model, OptKind::Soap, steps)
                .with_hyper(hyper)
                .run()
                .expect("run");
            let tail = log.tail_loss(20);
            let refresh: f64 = log.timings.iter().map(|t| t.refresh_s).sum();
            refresh_total += refresh;
            println!("{label:<28} f={f:<4} loss {tail:.4}  refresh {refresh:.2}s total");
            pts.push((f as f64, tail as f64));
        }
        report.add_series(label, pts);
        report.note(format!("{label}: total refresh seconds {refresh_total:.2}"));
    }
    report.note("paper: both comparable across the frequency spectrum; QR cheaper".to_string());
    report.render_and_save();
}
