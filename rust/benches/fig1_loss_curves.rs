//! FIG1-LM (paper Fig 1 left/middle + Fig 3): tuned training-loss curves for
//! AdamW vs Shampoo vs SOAP at preconditioning frequency 10, plus the
//! "shorter LR schedule" SOAP run that pins down the iteration savings.
//!
//! Expected shape (paper): SOAP < Shampoo < AdamW at equal steps; the
//! shortened SOAP run matches AdamW's final loss with ≳40% fewer steps.

use soap_lab::experiments::harness::{artifacts_available, bench_model, bench_steps, RunSpec};
use soap_lab::optim::OptKind;
use soap_lab::util::bench::Report;

fn main() {
    if !artifacts_available() {
        println!("fig1_loss_curves: artifacts missing — run `make artifacts`");
        return;
    }
    let model = bench_model();
    let steps = bench_steps(300);
    println!("fig1: model={model} steps={steps} (override via SOAP_BENCH_STEPS/MODEL)");

    let mut by_step = Report::new(
        &format!("Fig 1 (left): train loss vs steps [{model}]"),
        "step",
        "loss",
    );
    let mut by_time = Report::new(
        &format!("Fig 1 (middle): train loss vs wall-clock [{model}]"),
        "seconds",
        "loss",
    );

    let mut finals = Vec::new();
    for opt in [OptKind::AdamW, OptKind::Shampoo, OptKind::Soap] {
        let (log, secs) = RunSpec::new(&model, opt, steps).run().expect("run");
        println!(
            "{:<10} tail loss {:.4}  {:.2}s/step  overhead {:.1}%",
            opt.name(),
            log.tail_loss(20),
            secs,
            100.0 * log.optimizer_overhead_frac()
        );
        finals.push((opt, log.tail_loss(20)));
        by_step.add_series(opt.name(), log.loss_series());
        by_time.add_series(opt.name(), log.loss_vs_time());
    }

    // "Shorter LR schedule": SOAP with the cosine compressed to 60% of the
    // budget — the run the paper uses to read off iteration savings.
    let short = (steps as f64 * 0.6) as u64;
    let (log, _) = RunSpec::new(&model, OptKind::Soap, short).run().expect("short run");
    println!("soap-short ({short} steps) tail loss {:.4}", log.tail_loss(20));
    by_step.add_series("soap (shorter schedule)", log.loss_series());

    let adamw_final = finals.iter().find(|(o, _)| *o == OptKind::AdamW).unwrap().1;
    let soap_short_final = log.tail_loss(20);
    by_step.note(format!(
        "SOAP@{short} vs AdamW@{steps}: {:.4} vs {:.4} ({})",
        soap_short_final,
        adamw_final,
        if soap_short_final <= adamw_final {
            "SOAP matches AdamW with 40% fewer steps ✓ (paper: ≥40%)"
        } else {
            "shorter run did not fully match — see fig2 for the precise fit"
        }
    ));

    by_step.render_and_save();
    by_time.render_and_save();
}
