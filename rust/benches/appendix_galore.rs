//! APP-B (paper Appendix B): GaLore (full-rank, α = 1) vs AdamW vs Shampoo
//! vs SOAP on the smallest model — the paper's negative result motivating
//! SOAP's design choices (EMA'd factors instead of per-gradient SVD bases,
//! momentum in the original space).
//!
//! Expected shape (paper): AdamW < GaLore < Shampoo ≤ SOAP (in quality;
//! losses the other way), with GaLore preferring large f (200 in the paper).

use soap_lab::experiments::harness::{artifacts_available, bench_model, bench_steps, RunSpec};
use soap_lab::optim::OptKind;
use soap_lab::util::bench::Report;

fn main() {
    if !artifacts_available() {
        println!("appendix_galore: artifacts missing — run `make artifacts`");
        return;
    }
    let model = bench_model();
    let steps = bench_steps(300);
    println!("appendix B: model={model} steps={steps}");

    let mut report = Report::new(
        &format!("Appendix B: GaLore vs baselines [{model}]"),
        "step",
        "loss",
    );
    let mut tails: Vec<(String, f32)> = Vec::new();

    for opt in [OptKind::AdamW, OptKind::Shampoo, OptKind::Soap] {
        let (log, _) = RunSpec::new(&model, opt, steps).run().expect("run");
        println!("{:<12} tail loss {:.4}", opt.name(), log.tail_loss(20));
        tails.push((opt.name().to_string(), log.tail_loss(20)));
        report.add_series(opt.name(), log.loss_series());
    }
    // GaLore frequency sweep (paper: 200 was best; our runs are shorter so
    // sweep proportionally smaller values too).
    let mut best: Option<(u64, f32)> = None;
    for f in [50u64, 100, 200] {
        let (log, _) = RunSpec::new(&model, OptKind::Galore, steps)
            .with_freq(f)
            .run()
            .expect("galore");
        let tail = log.tail_loss(20);
        println!("galore f={f:<4} tail loss {tail:.4}");
        if best.map(|(_, b)| tail < b).unwrap_or(true) {
            best = Some((f, tail));
        }
        if f == 200 {
            report.add_series(&format!("galore f={f}"), log.loss_series());
        }
    }
    let (bf, bl) = best.unwrap();
    tails.push((format!("galore (f={bf})"), bl));

    tails.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    println!("\nranking (best→worst):");
    for (name, loss) in &tails {
        println!("  {name:<16} {loss:.4}");
    }
    report.note(format!(
        "best GaLore f={bf}: {bl:.4} — paper: GaLore beats AdamW but loses to Shampoo/SOAP"
    ));
    report.render_and_save();
}
