//! FIG2 (paper Fig 2): precise efficiency benefit of SOAP over AdamW and
//! Shampoo via the §5 scaling-law methodology — SOAP runs on {.5, .625,
//! .75, .875, 1.0} of the step budget (each with its own cosine schedule),
//! a fit of `a + b·N^(−β)` through the final losses, and the % iteration /
//! wall-clock reductions read off the fit at the baselines' final losses.
//!
//! Expected shape (paper): ≥40%/≥35% iter/wall-clock savings vs AdamW,
//! ≈20%/20% vs Shampoo (2m-batch analogue).

use soap_lab::experiments::harness::{artifacts_available, bench_model, bench_steps, RunSpec};
use soap_lab::experiments::{efficiency_benefit, fit_scaling_law, Baseline};
use soap_lab::optim::OptKind;
use soap_lab::util::bench::Report;

fn main() {
    if !artifacts_available() {
        println!("fig2_efficiency: artifacts missing — run `make artifacts`");
        return;
    }
    let model = bench_model();
    let steps = bench_steps(300);
    println!("fig2: model={model} budget={steps}");

    // Baselines at full budget.
    let (adamw_log, adamw_secs) = RunSpec::new(&model, OptKind::AdamW, steps).run().unwrap();
    let (shampoo_log, shampoo_secs) = RunSpec::new(&model, OptKind::Shampoo, steps).run().unwrap();

    // SOAP at budget fractions.
    let fractions = [0.5, 0.625, 0.75, 0.875, 1.0];
    let mut points = Vec::new();
    let mut soap_secs = 0.0;
    let mut report = Report::new(
        &format!("Fig 2: SOAP scaling-law points + baselines [{model}]"),
        "steps",
        "final loss",
    );
    for &f in &fractions {
        let n = (steps as f64 * f) as u64;
        let (log, secs) = RunSpec::new(&model, OptKind::Soap, n).run().unwrap();
        let tail = log.tail_loss(20) as f64;
        println!("soap {n:>5} steps → {tail:.4}  ({secs:.2}s/step)");
        points.push((n as f64, tail));
        soap_secs = secs; // full-budget run overwrites; any is representative
    }
    report.add_series("soap fraction runs", points.clone());

    let law = fit_scaling_law(&points).expect("scaling fit");
    println!(
        "scaling law: loss(N) = {:.4} + {:.3}·N^(−{:.3})   (sse {:.2e})",
        law.a, law.b, law.beta, law.sse
    );
    let fit_curve: Vec<(f64, f64)> = (1..=40)
        .map(|i| {
            let n = steps as f64 * 0.45 + i as f64 * steps as f64 * 0.015;
            (n, law.predict(n))
        })
        .collect();
    report.add_series("fitted a+b·N^-beta", fit_curve);

    for (log, secs, name) in [
        (&adamw_log, adamw_secs, "adamw"),
        (&shampoo_log, shampoo_secs, "shampoo"),
    ] {
        let baseline = Baseline {
            name: name.to_string(),
            steps: steps as f64,
            final_loss: log.tail_loss(20) as f64,
            secs_per_step: secs,
        };
        report.add_series(
            &format!("{name} final loss"),
            vec![(steps as f64 * 0.5, baseline.final_loss), (steps as f64, baseline.final_loss)],
        );
        match efficiency_benefit(&law, soap_secs, &baseline) {
            Some(e) => {
                println!(
                    "vs {name}: SOAP needs {:.0} steps → {:.1}% fewer iterations, {:.1}% less wall-clock",
                    e.soap_steps,
                    100.0 * e.iter_reduction,
                    100.0 * e.wallclock_reduction
                );
                report.note(format!(
                    "vs {name}: {:.1}% iters, {:.1}% wall-clock (paper: ≥40/35% vs AdamW, ≈20/20% vs Shampoo)",
                    100.0 * e.iter_reduction,
                    100.0 * e.wallclock_reduction
                ));
            }
            None => report.note(format!(
                "vs {name}: baseline loss {:.4} below the SOAP fit asymptote {:.4}",
                baseline.final_loss, law.a
            )),
        }
    }
    report.render_and_save();
}
