//! CLAIM1 (paper §4.1, Claim 1): idealized Shampoo (power 1/2, dataset
//! factors, trace correction) is EQUIVALENT to idealized Adafactor run in
//! Shampoo's eigenbasis. This bench quantifies the numerical residual over
//! random gradient datasets at increasing sizes (exact up to fp32 rounding
//! and Jacobi tolerance), and reports the A_i = λ_i identity from the proof.

use soap_lab::linalg::Matrix;
use soap_lab::optim::idealized::{
    claim1_row_identity, dataset_factors, idealized_adafactor_dir, idealized_shampoo_dir,
};
use soap_lab::util::bench::Report;
use soap_lab::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(0xC1A1);
    let mut report = Report::new(
        "Claim 1: ||Alg1 − Alg2|| / ||Alg1|| over random gradient datasets",
        "matrix dim",
        "relative error",
    );

    let dims = [2usize, 4, 8, 16, 32, 48];
    let mut pts = Vec::new();
    println!("{:>5} {:>14} {:>14}", "dim", "rel err", "A=λ err");
    for &d in &dims {
        let grads: Vec<Matrix> = (0..3 * d).map(|_| Matrix::randn(&mut rng, d, d, 1.0)).collect();
        let g = grads[0].clone();
        let d1 = idealized_shampoo_dir(&grads, &g);
        let d2 = idealized_adafactor_dir(&grads, &g, 0.0);
        let rel = (d1.max_abs_diff(&d2) / d1.max_abs().max(1e-12)) as f64;

        let (a, lambda) = claim1_row_identity(&grads);
        let id_err: f64 = a
            .iter()
            .zip(&lambda)
            .map(|(x, y)| ((x - y).abs() / (1.0 + y.abs())) as f64)
            .fold(0.0, f64::max);

        println!("{d:>5} {rel:>14.3e} {id_err:>14.3e}");
        assert!(rel < 0.05, "Claim 1 violated at dim {d}: rel {rel}");
        assert!(id_err < 0.05, "A=λ identity violated at dim {d}");
        pts.push((d as f64, rel));
    }
    report.add_series("relative error (fp32 + Jacobi tol)", pts);
    report.note("Claim 1 equivalence holds to numerical precision ✓".to_string());
    report.render_and_save();

    // Also verify the trace factor: Tr(L) equals Σλ.
    let grads: Vec<Matrix> = (0..32).map(|_| Matrix::randn(&mut rng, 12, 12, 1.0)).collect();
    let (l, _) = dataset_factors(&grads);
    let (_, lambda) = claim1_row_identity(&grads);
    let tr = l.trace();
    let sum_l: f32 = lambda.iter().sum();
    println!("\nTr(L) = {tr:.4} vs Σλ = {sum_l:.4} (Δ {:.2e})", (tr - sum_l).abs());
    assert!((tr - sum_l).abs() / tr.abs() < 1e-3);
    println!("claim1_equiv: all checks passed ✓");
}
