//! Steady-state allocation count of the composed optimizer step path.
//!
//! A counting [`GlobalAlloc`] shim wraps the system allocator for this test
//! binary. After a warm-up window has initialized every basis and grown
//! every workspace buffer to its steady-state size, a non-refresh
//! `Composed::update` must perform **zero** heap allocations — the PR-3
//! tentpole invariant that makes step latency allocation-noise-free.
//!
//! Kept as a single `#[test]` on purpose: the default harness runs tests on
//! multiple threads, and a sibling test's allocations would pollute the
//! global counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use soap_lab::linalg::{force_gemm_kernel, GemmKernel, Matrix, TensorShape};
use soap_lab::optim::compose::presets;
use soap_lab::optim::{DynComposed, Hyper, LayerOptimizer, StateDtype};
use soap_lab::util::rng::Rng;

/// Counts every `alloc`/`realloc` (the events that would show up as
/// per-step latency noise); `dealloc` is free of arena growth and untracked.
struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> usize {
    ALLOCS.load(Ordering::Relaxed)
}

#[test]
fn steady_state_composed_step_allocates_zero() {
    type Build = fn(usize, usize, Hyper) -> DynComposed;
    let builds: [(&str, Build); 6] = [
        ("soap", presets::soap),
        ("soap-factorized", |r, c, h| presets::soap(r, c, Hyper { factorized: true, ..h })),
        ("shampoo", presets::shampoo),
        ("galore", presets::galore),
        ("adamw", presets::adamw),
        ("adafactor", presets::adafactor),
    ];
    // f = 10, phase 0: refreshes land on t ∈ {10, 20, 30, …}; t = 23..=26
    // below is pure steady state.
    let h = Hyper { precond_freq: 10, ..Hyper::default() };
    let (rows, cols) = (12, 8);
    for (label, build) in builds {
        let mut opt = build(rows, cols, h.clone());
        let mut rng = Rng::new(41);
        let grads: Vec<Matrix> =
            (0..26).map(|_| Matrix::randn(&mut rng, rows, cols, 1.0)).collect();
        let mut w = Matrix::zeros(rows, cols);
        // Warm-up: basis init, two refresh cycles, every arena buffer grown.
        for (i, g) in grads.iter().take(22).enumerate() {
            opt.update(&mut w, g, i as u64 + 1, 0.01);
        }
        let scratch = opt.scratch_bytes();
        let before = allocs();
        for (i, g) in grads.iter().enumerate().take(26).skip(22) {
            opt.update(&mut w, g, i as u64 + 1, 0.01);
        }
        let n = allocs() - before;
        assert_eq!(n, 0, "{label}: steady-state step performed {n} heap allocations");
        assert_eq!(
            opt.scratch_bytes(),
            scratch,
            "{label}: workspace arena changed size in steady state"
        );
    }

    // Rank-3 per-mode path: the zero-allocation invariant extends to tensor
    // parameters — mode grams, unfolds, and the mode-product ping-pong all
    // run through the grow-only arena. Interior mode (5) exercises the
    // unfold buffer; SOAP covers rotate/rotate-back chains, Shampoo the
    // inverse-root sandwich + grafting.
    let shape = TensorShape::new(vec![4, 5, 6]);
    let carrier = shape.carrier();
    type BuildNd = fn((usize, usize), &TensorShape, Hyper) -> DynComposed;
    let nd_builds: [(&str, BuildNd); 3] = [
        ("soap-rank3", presets::soap_nd),
        ("soap-rank3-factorized", |c, s, h| {
            presets::soap_nd(c, s, Hyper { factorized: true, ..h })
        }),
        ("shampoo-rank3", presets::shampoo_nd),
    ];
    for (label, build) in nd_builds {
        let mut opt = build(carrier, &shape, h.clone());
        let mut rng = Rng::new(42);
        let grads: Vec<Matrix> =
            (0..26).map(|_| Matrix::randn(&mut rng, carrier.0, carrier.1, 1.0)).collect();
        let mut w = Matrix::zeros(carrier.0, carrier.1);
        for (i, g) in grads.iter().take(22).enumerate() {
            opt.update(&mut w, g, i as u64 + 1, 0.01);
        }
        let scratch = opt.scratch_bytes();
        let before = allocs();
        for (i, g) in grads.iter().enumerate().take(26).skip(22) {
            opt.update(&mut w, g, i as u64 + 1, 0.01);
        }
        let n = allocs() - before;
        assert_eq!(n, 0, "{label}: steady-state rank-3 step performed {n} heap allocations");
        assert_eq!(
            opt.scratch_bytes(),
            scratch,
            "{label}: workspace arena changed size in steady state"
        );
    }

    // Guard path (PR-8): every section above already runs with the default
    // `guard = skip-step` armed — the per-step non-finiteness scan is part
    // of the measured zero. This section exercises the SKIP branch itself: a
    // NaN gradient poisons the engine moments, so every subsequent update
    // direction is non-finite and the guard skips the weight write each
    // step. One poisoned warm-up step initializes the skip counter's
    // OnceLock slot (its only allocation); the measured skips must be free.
    {
        let mut opt = presets::soap(rows, cols, h.clone());
        let mut rng = Rng::new(44);
        let grads: Vec<Matrix> =
            (0..26).map(|_| Matrix::randn(&mut rng, rows, cols, 1.0)).collect();
        let mut bad = Matrix::zeros(rows, cols);
        bad.data[0] = f32::NAN;
        let mut w = Matrix::zeros(rows, cols);
        for (i, g) in grads.iter().take(21).enumerate() {
            opt.update(&mut w, g, i as u64 + 1, 0.01);
        }
        opt.update(&mut w, &bad, 22, 0.01);
        let before = allocs();
        for (i, g) in grads.iter().enumerate().take(26).skip(22) {
            opt.update(&mut w, g, i as u64 + 1, 0.01);
        }
        let n = allocs() - before;
        assert_eq!(n, 0, "guarded skip path performed {n} heap allocations");
        assert!(
            w.data.iter().all(|x| x.is_finite()),
            "skip-step guard let a non-finite update reach the weights"
        );
    }

    // Telemetry-enabled rerun: span recording must also be allocation-free
    // in steady state. The per-thread ring registers (and allocates) on the
    // first enabled span — during warm-up — after which every recorded span
    // is a fixed-slot write. Whitening sampling allocates only on refresh
    // steps, which the measured window excludes by construction.
    {
        let _g = soap_lab::telemetry::trace::test_lock();
        soap_lab::telemetry::set_enabled(true);
        for (label, build) in builds {
            let mut opt = build(rows, cols, h.clone());
            let mut rng = Rng::new(43);
            let grads: Vec<Matrix> =
                (0..26).map(|_| Matrix::randn(&mut rng, rows, cols, 1.0)).collect();
            let mut w = Matrix::zeros(rows, cols);
            for (i, g) in grads.iter().take(22).enumerate() {
                opt.update(&mut w, g, i as u64 + 1, 0.01);
            }
            let before = allocs();
            for (i, g) in grads.iter().enumerate().take(26).skip(22) {
                opt.update(&mut w, g, i as u64 + 1, 0.01);
            }
            let n = allocs() - before;
            assert_eq!(
                n, 0,
                "{label}: steady-state step with telemetry ENABLED performed {n} heap allocations"
            );
        }
        soap_lab::telemetry::set_enabled(false);
        soap_lab::telemetry::trace::drain();
    }

    // SIMD-kernel rerun: the register-tiled kernels write into the same
    // caller-owned workspace buffers as the scalar path — dispatch must not
    // reintroduce heap traffic. `force_gemm_kernel` clamps to scalar on a
    // CPU without AVX2/NEON, so on such hosts this degrades to a scalar
    // re-check rather than silently skipping the section.
    {
        force_gemm_kernel(Some(GemmKernel::Simd));
        for (label, build) in builds {
            let mut opt = build(rows, cols, h.clone());
            let mut rng = Rng::new(45);
            let grads: Vec<Matrix> =
                (0..26).map(|_| Matrix::randn(&mut rng, rows, cols, 1.0)).collect();
            let mut w = Matrix::zeros(rows, cols);
            for (i, g) in grads.iter().take(22).enumerate() {
                opt.update(&mut w, g, i as u64 + 1, 0.01);
            }
            let before = allocs();
            for (i, g) in grads.iter().enumerate().take(26).skip(22) {
                opt.update(&mut w, g, i as u64 + 1, 0.01);
            }
            let n = allocs() - before;
            assert_eq!(
                n, 0,
                "{label}: steady-state step under the SIMD kernel performed {n} heap allocations"
            );
        }
        // Single-test binary: nothing else shares the process, so restoring
        // here (not on unwind) is sufficient.
        force_gemm_kernel(None);
    }

    // bf16-state rerun: the u16-backed second moments decode/encode in
    // place (`ema_then` / `ema_update`), so the steady-state zero must hold
    // at half state width too — no hidden f32 staging buffers.
    {
        let hb = Hyper { state_dtype: StateDtype::Bf16, ..h.clone() };
        for (label, build) in builds {
            let mut opt = build(rows, cols, hb.clone());
            let mut opt_f32 = build(rows, cols, h.clone());
            let mut rng = Rng::new(46);
            let grads: Vec<Matrix> =
                (0..26).map(|_| Matrix::randn(&mut rng, rows, cols, 1.0)).collect();
            let mut w = Matrix::zeros(rows, cols);
            let mut w_f32 = Matrix::zeros(rows, cols);
            // Warm BOTH dtypes through the same schedule so lazily-allocated
            // caches (Q, warm-start eigvecs) exist in both accountings.
            for (i, g) in grads.iter().take(22).enumerate() {
                opt.update(&mut w, g, i as u64 + 1, 0.01);
                opt_f32.update(&mut w_f32, g, i as u64 + 1, 0.01);
            }
            let f32_bytes = opt_f32.state_bytes();
            let before = allocs();
            for (i, g) in grads.iter().enumerate().take(26).skip(22) {
                opt.update(&mut w, g, i as u64 + 1, 0.01);
            }
            let n = allocs() - before;
            assert_eq!(
                n, 0,
                "{label}: steady-state step with bf16 state performed {n} heap allocations"
            );
            assert!(
                opt.state_bytes() < f32_bytes,
                "{label}: bf16 state_bytes {} not below the f32 figure {f32_bytes}",
                opt.state_bytes()
            );
        }
    }
}
