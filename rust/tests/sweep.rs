//! Sweep-orchestrator acceptance pins:
//!
//! 1. an 8-job grid runs concurrently under an explicit memory budget,
//!    streaming `job_id`/`assign`-tagged JSONL and writing a complete
//!    `SWEEP_results.json`;
//! 2. a sweep killed mid-flight (≥1 job done, ≥1 in flight) and resumed
//!    produces a `SWEEP_results.json` BITWISE-identical to an
//!    uninterrupted sweep — per-job loss trajectories included — and the
//!    deterministic projection of the metrics stream (step/loss/lr per
//!    job) matches line for line;
//! 3. admission control never exceeds the memory budget (property test,
//!    hand-rolled xorshift);
//! 4. per-job failures (unresolvable model, over-budget footprint) are
//!    isolated as failed rows — the rest of the sweep completes.
//!
//! Everything runs `nplm-tiny` native jobs: artifact-free, seconds-fast.

use std::path::PathBuf;

use soap_lab::sweep::{
    plan, run_sweep, Admission, Admit, Journal, SweepOptions, SweepSpec,
};
use soap_lab::util::json::Json;

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("soap_sweep_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn opts(out_dir: &std::path::Path) -> SweepOptions {
    SweepOptions { out_dir: out_dir.to_path_buf(), ..SweepOptions::default() }
}

/// The deterministic projection of one metrics line: wall-clock timing
/// fields vary run to run, but (job, step, loss, lr) must not.
fn projected_lines(path: &std::path::Path) -> Vec<String> {
    std::fs::read_to_string(path)
        .unwrap()
        .lines()
        .map(|line| {
            let v = Json::parse(line).unwrap_or_else(|e| panic!("bad JSONL line: {e}: {line}"));
            format!(
                "{} {} {} {} {}",
                v.get("job_id").as_str().unwrap_or("?"),
                v.get("kind").as_str().unwrap_or("step"),
                v.get("step").dump(),
                v.get("loss").dump(),
                v.get("lr").dump(),
            )
        })
        .collect()
}

#[test]
fn eight_job_grid_runs_under_budget_with_tagged_stream() {
    let dir = tmpdir("grid");
    let spec = SweepSpec::parse(
        r#"{
            "name": "grid8",
            "model": "nplm-tiny",
            "steps": 5,
            "constant-lr": true,
            "precond-freq": 4,
            "grid": {
                "lr": [0.02, 0.01, 0.005, 0.002],
                "optimizer": ["soap", "adamw"]
            }
        }"#,
    )
    .unwrap();
    assert_eq!(spec.jobs.len(), 8);

    let outcome = run_sweep(
        &spec,
        &SweepOptions {
            max_mem_bytes: 64 << 20, // explicit budget, roomy for tiny jobs
            max_concurrency: 2,
            ..opts(&dir)
        },
    )
    .unwrap();

    assert!(!outcome.halted);
    assert_eq!(outcome.rows.len(), 8);
    assert!(outcome.rows.iter().all(|r| r.get("status").as_str() == Some("done")));

    // Results file: all 8 rows in job-id order, losses present.
    let results = Json::parse(
        &std::fs::read_to_string(outcome.results_path.as_ref().unwrap()).unwrap(),
    )
    .unwrap();
    let rows = results.get("jobs").as_arr().unwrap();
    assert_eq!(rows.len(), 8);
    let ids: Vec<&str> = rows.iter().filter_map(|r| r.get("job_id").as_str()).collect();
    let mut sorted = ids.clone();
    sorted.sort_unstable();
    assert_eq!(ids, sorted, "rows must be in job-id order");
    for row in rows {
        assert_eq!(row.get("losses").as_arr().unwrap().len(), 5);
        assert!(row.get("final_loss").as_f64().unwrap().is_finite());
    }

    // Manifest records the plan with nonzero estimates.
    let manifest =
        Json::parse(&std::fs::read_to_string(&outcome.manifest_path).unwrap()).unwrap();
    assert_eq!(manifest.get("jobs").as_arr().unwrap().len(), 8);
    assert!(manifest
        .get("jobs")
        .as_arr()
        .unwrap()
        .iter()
        .all(|j| j.get("est_bytes").as_f64().unwrap() > 0.0));

    // Every metrics line is tagged; every job streamed every step.
    let text = std::fs::read_to_string(&outcome.metrics_path).unwrap();
    let mut per_job = std::collections::BTreeMap::<String, usize>::new();
    for line in text.lines() {
        let v = Json::parse(line).unwrap_or_else(|e| panic!("bad JSONL line: {e}: {line}"));
        let id = v.get("job_id").as_str().expect("line missing job_id tag");
        assert!(v.get("assign").get("lr").as_str().is_some(), "line missing assign tag");
        assert!(v.get("assign").get("optimizer").as_str().is_some());
        assert!(v.get("loss").as_f64().is_some());
        *per_job.entry(id.to_string()).or_default() += 1;
    }
    assert_eq!(per_job.len(), 8);
    assert!(per_job.values().all(|&n| n == 5), "per-job line counts: {per_job:?}");

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn interrupted_sweep_resumes_bitwise_identical() {
    let spec_text = r#"{
        "name": "resume-pin",
        "model": "nplm-tiny",
        "steps": 8,
        "constant-lr": true,
        "precond-freq": 4,
        "grid": {"lr": [0.02, 0.015, 0.01]}
    }"#;
    let spec = SweepSpec::parse(spec_text).unwrap();
    assert_eq!(spec.jobs.len(), 3);

    // Reference: uninterrupted, concurrency 1 (deterministic scheduling).
    let ref_dir = tmpdir("resume_ref");
    let reference = run_sweep(
        &spec,
        &SweepOptions { max_concurrency: 1, ..opts(&ref_dir) },
    )
    .unwrap();
    assert!(!reference.halted);

    // Interrupted: halt after 12 global steps — job 1 of 3 is done (8
    // steps), job 2 is mid-flight at step 4, job 3 hasn't started.
    let dir = tmpdir("resume_cut");
    let halted = run_sweep(
        &spec,
        &SweepOptions {
            max_concurrency: 1,
            halt_after_steps: Some(12),
            ..opts(&dir)
        },
    )
    .unwrap();
    assert!(halted.halted);
    assert!(halted.results_path.is_none(), "no results file for a halted sweep");

    let journal = Journal::load(&halted.journal_path).unwrap();
    assert_eq!(journal.rows.len(), 1, "exactly one job finished before the halt");
    assert_eq!(journal.ckpts.len(), 1, "exactly one job was in flight");
    let (ckpt_job, ckpt) = journal.ckpts.iter().next().unwrap();
    assert_eq!(ckpt.step, 4);
    assert_eq!(ckpt.losses.len(), 4);
    assert!(dir.join(format!("job_{ckpt_job}.ckpt")).exists());

    // Resume to completion.
    let resumed = run_sweep(
        &spec,
        &SweepOptions { max_concurrency: 1, resume: true, ..opts(&dir) },
    )
    .unwrap();
    assert!(!resumed.halted);
    assert_eq!(resumed.rows.len(), 3);

    // THE pin: results files are byte-identical — trajectories included.
    let ref_bytes = std::fs::read(reference.results_path.as_ref().unwrap()).unwrap();
    let res_bytes = std::fs::read(resumed.results_path.as_ref().unwrap()).unwrap();
    assert!(
        ref_bytes == res_bytes,
        "resumed SWEEP_results.json differs from uninterrupted run"
    );

    // And the deterministic projection of the metrics stream matches line
    // for line (timing fields are wall-clock and excluded).
    assert_eq!(
        projected_lines(&reference.metrics_path),
        projected_lines(&resumed.metrics_path),
        "resumed metrics stream diverges from uninterrupted run"
    );

    // Resume validates the job set: a different spec must be rejected.
    let other = SweepSpec::parse(
        r#"{"name": "other", "model": "nplm-tiny", "steps": 8,
            "grid": {"lr": [0.02, 0.015]}}"#,
    )
    .unwrap();
    let err = run_sweep(
        &other,
        &SweepOptions { max_concurrency: 1, resume: true, ..opts(&dir) },
    )
    .unwrap_err()
    .to_string();
    assert!(err.contains("job set"), "{err}");

    let _ = std::fs::remove_dir_all(&ref_dir);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Minimal xorshift64* — deterministic, no external crates.
struct Rng(u64);
impl Rng {
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }
    fn below(&mut self, n: u64) -> u64 {
        self.next() % n.max(1)
    }
}

#[test]
fn admission_never_exceeds_budget_property() {
    let mut rng = Rng(0x5EED_CAFE);
    for case in 0..200 {
        let budget = 1 + rng.below(1 << 20);
        let cap = 1 + rng.below(8) as usize;
        let mut adm = Admission::new(budget, cap);
        let mut live: Vec<String> = Vec::new();
        for op in 0..200 {
            if rng.below(3) == 0 && !live.is_empty() {
                let idx = rng.below(live.len() as u64) as usize;
                let id = live.swap_remove(idx);
                adm.release(&id);
            } else {
                let id = format!("c{case}o{op}");
                // Bias sizes around the budget so TooBig/Wait/Start all hit.
                let bytes = rng.below(budget + budget / 2 + 1);
                if adm.admit(&id, bytes) == Admit::Start {
                    live.push(id);
                }
            }
            assert!(
                adm.check_invariant(),
                "invariant violated: budget={budget} cap={cap} used={} running={}",
                adm.used_bytes(),
                adm.running()
            );
            assert!(adm.used_bytes() <= budget);
            assert!(adm.running() <= cap);
        }
    }
}

#[test]
fn failed_jobs_are_isolated_rows() {
    let dir = tmpdir("failures");
    // j000/j001: one unresolvable artifact model (fails at session build),
    // one healthy native job. The sweep must finish with both rows.
    let spec = SweepSpec::parse(
        r#"{
            "name": "failures",
            "steps": 4,
            "constant-lr": true,
            "grid": {"model": ["no-such-artifact-model", "nplm-tiny"]}
        }"#,
    )
    .unwrap();
    let outcome = run_sweep(&spec, &SweepOptions { max_concurrency: 1, ..opts(&dir) }).unwrap();
    assert!(!outcome.halted);
    assert_eq!(outcome.rows.len(), 2);
    let failed = outcome.row("j000").unwrap();
    assert_eq!(failed.get("status").as_str(), Some("failed"));
    assert!(failed.get("error").as_str().is_some());
    let ok = outcome.row("j001").unwrap();
    assert_eq!(ok.get("status").as_str(), Some("done"));
    // A completed sweep writes results even when some rows failed.
    assert!(outcome.results_path.is_some());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn oversized_job_rejected_with_budget_error() {
    let dir = tmpdir("toobig");
    let spec = SweepSpec::parse(
        r#"{"name": "toobig", "model": "nplm-tiny", "optimizer": "soap",
            "steps": 3, "constant-lr": true, "grid": {"seed": [0, 1]}}"#,
    )
    .unwrap();
    // Budget one byte below the smaller job's estimated footprint: every
    // job is TooBig, rejected up front, and the sweep still completes.
    let plans = plan(&spec.jobs, &spec.artifacts_dir);
    let min_est = plans.iter().map(|p| p.est_bytes).min().unwrap();
    assert!(min_est > 0);
    let outcome = run_sweep(
        &spec,
        &SweepOptions { max_mem_bytes: min_est - 1, max_concurrency: 2, ..opts(&dir) },
    )
    .unwrap();
    assert!(!outcome.halted);
    assert_eq!(outcome.rows.len(), 2);
    for row in &outcome.rows {
        assert_eq!(row.get("status").as_str(), Some("failed"));
        let err = row.get("error").as_str().unwrap();
        assert!(err.contains("exceeds memory budget"), "{err}");
    }
    assert!(outcome.results_path.is_some());
    let _ = std::fs::remove_dir_all(&dir);
}
