//! Telemetry acceptance pins (the observability tentpole):
//!
//! 1. `trace_out` produces a VALID Chrome trace-event JSON document — every
//!    `"B"` has a matching `"E"`, in-step phase spans and per-layer refresh
//!    spans are present — for the serial AND sharded backends;
//! 2. per-layer health (grad/update norms, staleness, whitening
//!    off-diagonality) plus refresh-service introspection (queue depth,
//!    shed count, latency quantiles, pool utilization) reach an attached
//!    [`MetricsSink`] every `metrics_every` steps;
//! 3. telemetry off ≡ telemetry on, bitwise: the recorder observes the
//!    trajectory, it never perturbs one bit of it.
//!
//! Every test takes [`soap_lab::telemetry::trace::test_lock`]: the enabled
//! flag and the span rings are process-global, and the default harness runs
//! tests on multiple threads.

use std::path::Path;
use std::sync::{Arc, Mutex};

use soap_lab::model::NplmConfig;
use soap_lab::optim::{Hyper, OptKind, RefreshMode, Schedule};
use soap_lab::session::{
    Backend, HealthSnapshot, MetricsSink, ModelSpec, SessionBuilder, StepRecord, TrainSession,
};
use soap_lab::telemetry;
use soap_lab::util::json::Json;

const SEQ: usize = 24;
const BATCH: usize = 8;

fn nplm() -> NplmConfig {
    NplmConfig { vocab: 64, context: 3, dim: 12, hidden: 24, conv: false }
}

fn builder(steps: u64, mode: RefreshMode) -> SessionBuilder {
    TrainSession::builder()
        .model(ModelSpec::nplm(nplm(), SEQ, BATCH))
        .optimizer(OptKind::Soap)
        .hyper(Hyper { precond_freq: 4, ..Hyper::default() }.with_refresh_mode(mode))
        .schedule(Schedule::Constant { lr: 0.02 })
        .steps(steps)
        .seed(5)
        .workers(2)
        .drain_refresh_each_step(mode == RefreshMode::Async)
}

/// Parse `path` as Chrome trace-event JSON and hand back the event list
/// after checking the structural invariants a trace viewer relies on.
fn checked_trace_events(path: &Path, label: &str) -> Vec<Json> {
    let text = std::fs::read_to_string(path).unwrap();
    let doc = Json::parse(&text).unwrap_or_else(|e| panic!("{label}: invalid JSON: {e}"));
    let events = doc.get("traceEvents").as_arr().unwrap_or_else(|| {
        panic!("{label}: missing traceEvents array");
    });
    assert!(!events.is_empty(), "{label}: trace has no events");
    let mut begins = 0usize;
    let mut ends = 0usize;
    for ev in events {
        assert!(ev.get("name").as_str().is_some(), "{label}: event without name");
        assert!(ev.get("ts").as_f64().is_some(), "{label}: event without ts");
        assert_eq!(ev.get("pid").as_f64(), Some(1.0), "{label}: bad pid");
        assert!(ev.get("tid").as_f64().is_some(), "{label}: event without tid");
        match ev.get("ph").as_str() {
            Some("B") => begins += 1,
            Some("E") => ends += 1,
            other => panic!("{label}: unexpected ph {other:?}"),
        }
    }
    assert_eq!(begins, ends, "{label}: unmatched B/E events");
    events.to_vec()
}

fn has_begin(events: &[Json], name: &str) -> bool {
    events
        .iter()
        .any(|e| e.get("ph").as_str() == Some("B") && e.get("name").as_str() == Some(name))
}

#[test]
fn trace_out_writes_valid_chrome_trace_serial_and_sharded() {
    let _g = telemetry::trace::test_lock();
    for (backend, label) in [(Backend::Serial, "serial"), (Backend::Sharded, "sharded")] {
        telemetry::trace::drain(); // spans left over from sibling tests
        let path = std::env::temp_dir()
            .join(format!("soap_trace_{label}_{}.json", std::process::id()));
        let mut session = builder(10, RefreshMode::Inline)
            .backend(backend)
            .telemetry(true)
            .trace_out(&path)
            .build()
            .unwrap();
        session.run().unwrap();
        let events = checked_trace_events(&path, label);
        std::fs::remove_file(&path).ok();

        // In-step phase spans...
        for name in ["step.data", "step.grad", "step.update"] {
            assert!(has_begin(&events, name), "{label}: missing {name} span");
        }
        // ...the spans inside Composed::update...
        for name in ["engine.project", "engine.moment", "engine.project_back"] {
            assert!(has_begin(&events, name), "{label}: missing {name} span");
        }
        // ...and per-layer refresh spans (basis init + the f=4 refreshes),
        // tagged with the basis id so a trace viewer can tell layers apart.
        let layer_tagged_refresh = events.iter().any(|e| {
            e.get("ph").as_str() == Some("B")
                && e.get("cat").as_str() == Some("refresh")
                && e.get("args").get("layer").as_f64().is_some()
        });
        assert!(layer_tagged_refresh, "{label}: no layer-tagged refresh span");
    }
    telemetry::set_enabled(false);
}

/// Forwards health snapshots out of the boxed-sink seam for inspection.
struct ShareSink {
    health: Arc<Mutex<Vec<HealthSnapshot>>>,
}

impl MetricsSink for ShareSink {
    fn on_step(&mut self, _rec: &StepRecord<'_>) {}

    fn on_health(&mut self, h: &HealthSnapshot) {
        self.health.lock().unwrap().push(h.clone());
    }
}

#[test]
fn health_snapshots_reach_sinks_with_per_layer_metrics() {
    let _g = telemetry::trace::test_lock();
    telemetry::trace::drain();
    let health = Arc::new(Mutex::new(Vec::new()));
    let mut session = builder(12, RefreshMode::Async)
        .backend(Backend::Sharded)
        .telemetry(true)
        .metrics_every(3)
        .sink(Box::new(ShareSink { health: Arc::clone(&health) }))
        .build()
        .unwrap();
    session.run().unwrap();
    telemetry::set_enabled(false);
    telemetry::trace::drain();

    let snaps = health.lock().unwrap();
    // Steps 3, 6, 9, 12.
    assert_eq!(snaps.len(), 4, "expected a snapshot every metrics_every steps");
    let last = snaps.last().unwrap();
    assert_eq!(last.step, 12);
    assert!(!last.layers.is_empty(), "snapshot carries no per-layer health");
    assert!(last.refresh_count > 0, "drained async run completed no background refreshes");
    assert!(last.refresh_p50_s.is_finite() && last.refresh_p50_s >= 0.0);
    assert!(last.pool_jobs.unwrap_or(0) > 0, "refresh pool utilization missing");

    // Every SOAP layer has an eigenbasis: per-layer (not just mean)
    // staleness and an update norm must be reported for each.
    for l in &last.layers {
        assert!(l.grad_norm.unwrap_or(0.0) > 0.0, "layer {}: zero grad norm", l.layer);
        assert!(l.update_norm.is_some(), "layer {}: no update norm", l.layer);
        assert!(l.staleness.is_some(), "layer {}: no staleness", l.layer);
    }
    // With f=4 every basis refreshed at t=12, so staleness is small and
    // differs from a global mean only by per-layer stagger.
    assert!(last.layers.iter().all(|l| l.staleness.unwrap() <= 4));
    // Whitening off-diagonality is sampled on the 1st/5th/… completed
    // refresh of each basis; by step 12 every basis sampled at least once.
    assert!(
        last.layers.iter().any(|l| {
            l.whitening_offdiag.map(|w| (0.0..=1.0).contains(&w)).unwrap_or(false)
        }),
        "no layer reported a whitening off-diagonality sample"
    );
}

#[test]
fn telemetry_on_is_bitwise_invisible_to_the_trajectory() {
    let _g = telemetry::trace::test_lock();
    let run = |on: bool| {
        telemetry::trace::drain();
        let b = builder(14, RefreshMode::Inline).backend(Backend::Serial);
        let b = if on { b.telemetry(true).metrics_every(2) } else { b.telemetry(false) };
        let mut session = b.build().unwrap();
        let log = session.run().unwrap();
        telemetry::set_enabled(false);
        telemetry::trace::drain();
        (session.params.clone(), log.losses)
    };
    let (params_off, losses_off) = run(false);
    let (params_on, losses_on) = run(true);
    assert_eq!(losses_off, losses_on, "telemetry changed the loss trajectory");
    for (i, (a, b)) in params_off.iter().zip(&params_on).enumerate() {
        assert_eq!(a.data, b.data, "telemetry changed param {i} bitwise");
    }
}
