//! Coordinator-level integration: checkpoint round-trips through the
//! trainer, deterministic replay, layer-sharded vs serial equivalence at
//! the trainer level, and (when artifacts exist) the PJRT-optimizer path
//! agreeing with the native optimizer path step-for-step.

use soap_lab::coordinator::{Checkpoint, Trainer, TrainerConfig};
use soap_lab::model::NplmConfig;
use soap_lab::optim::{Hyper, OptKind, Schedule};

fn native(opt: OptKind, steps: u64, seed: u64, workers: usize) -> Trainer {
    let cfg = TrainerConfig {
        opt,
        hyper: Hyper { precond_freq: 4, ..Hyper::default() },
        schedule: Schedule::Constant { lr: 0.02 },
        steps,
        seed,
        workers,
        log_every: 0,
        vocab: 64,
        zipf_alpha: 1.3,
        ..TrainerConfig::default()
    };
    Trainer::new_native(NplmConfig { vocab: 64, context: 3, dim: 12, hidden: 24, conv: false }, cfg, 24, 8)
}

#[test]
fn worker_count_does_not_change_results() {
    // Layer sharding is a pure execution strategy: 1 worker vs 6 workers
    // must produce bitwise-identical parameters.
    let mut a = native(OptKind::Soap, 20, 5, 1);
    let mut b = native(OptKind::Soap, 20, 5, 6);
    a.run().unwrap();
    b.run().unwrap();
    for (x, y) in a.params.iter().zip(&b.params) {
        assert_eq!(x.data, y.data, "sharding changed the trajectory");
    }
}

#[test]
fn checkpoint_resume_continues_exactly() {
    // Train 30 steps straight vs 15 + checkpoint + restore + 15: identical
    // (the data stream is a pure function of (seed, step), so the resumed
    // trainer replays batches 16..30 by fast-forwarding).
    let mut full = native(OptKind::Soap, 30, 11, 2);
    full.run().unwrap();

    let mut first = native(OptKind::Soap, 15, 11, 2);
    first.run().unwrap();
    let ck = Checkpoint::new(
        first.step,
        first.params.clone(),
        first.native_optimizer().unwrap().export_state(),
    );
    let path = std::env::temp_dir().join(format!("soap_resume_{}.ckpt", std::process::id()));
    ck.save(&path).unwrap();

    // Fresh trainer (different worker count, too): restore state, skip the
    // 15 batches the first segment consumed, run the remaining 15 steps.
    let mut second = native(OptKind::Soap, 15, 11, 4);
    let restored = Checkpoint::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    second.params = restored.params;
    second.step = restored.step;
    second
        .native_optimizer_mut()
        .unwrap()
        .import_state(restored.opt_state)
        .unwrap();
    second.skip_batches(15);
    second.run().unwrap();
    assert_eq!(second.step, 30);

    // Bitwise-identical to the uninterrupted run.
    for (x, y) in full.params.iter().zip(&second.params) {
        assert_eq!(x.data, y.data, "resumed trajectory diverged");
    }
}

#[test]
fn deterministic_full_replay() {
    let mut a = native(OptKind::Shampoo, 25, 3, 2);
    let mut b = native(OptKind::Shampoo, 25, 3, 2);
    let la = a.run().unwrap();
    let lb = b.run().unwrap();
    assert_eq!(la.losses, lb.losses);
    for (x, y) in a.params.iter().zip(&b.params) {
        assert_eq!(x.data, y.data);
    }
}

#[test]
fn pjrt_optimizer_path_matches_native_path() {
    // The paper's hot path (SOAP through the Pallas-built artifacts) must
    // produce the same trajectory as the native sharded optimizer.
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let mk = |pjrt: bool| -> Trainer {
        let cfg = TrainerConfig {
            opt: OptKind::Soap,
            hyper: Hyper { precond_freq: 3, ..Hyper::default() },
            schedule: Schedule::Constant { lr: 0.01 },
            steps: 8,
            seed: 2,
            log_every: 0,
            ..TrainerConfig::default()
        };
        if pjrt {
            Trainer::new_pjrt_full("nano", cfg, "artifacts").unwrap()
        } else {
            Trainer::new_pjrt("nano", cfg, "artifacts").unwrap()
        }
    };
    let mut native_t = mk(false);
    let mut pjrt_t = mk(true);
    let log_n = native_t.run().unwrap();
    let log_p = pjrt_t.run().unwrap();
    // Same grads (identical params/batches), same update math ⇒ same losses
    // up to fp noise from kernel vs native op ordering.
    for ((sa, la), (sb, lb)) in log_n.losses.iter().zip(&log_p.losses) {
        assert_eq!(sa, sb);
        assert!(
            (la - lb).abs() < 5e-2 * (1.0 + la.abs()),
            "step {sa}: native {la} vs pjrt {lb}"
        );
    }
    let max_diff = native_t
        .params
        .iter()
        .zip(&pjrt_t.params)
        .map(|(a, b)| a.max_abs_diff(b))
        .fold(0.0f32, f32::max);
    // fp noise in the QR refresh (native Householder vs jnp fori_loop) gets
    // amplified by Adam's 1/(√v+ε) early in training; losses above already
    // agree to 5%, so bound the raw weight gap loosely.
    assert!(max_diff < 0.15, "param divergence {max_diff}");
}

#[test]
fn pjrt_trainer_rejects_unknown_model() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        return;
    }
    let cfg = TrainerConfig::default();
    let err = match Trainer::new_pjrt("no_such_model", cfg, "artifacts") {
        Err(e) => e,
        Ok(_) => panic!("unknown model accepted"),
    };
    assert!(err.to_string().contains("make artifacts"), "{err}");
}
