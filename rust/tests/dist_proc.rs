//! Multi-process distributed tests through the real CLI binary
//! (`CARGO_BIN_EXE_soap-lab`): the TCP transport, the coordinator's
//! self-spawn launcher, manual `--rank/--coordinator-addr` launch, and the
//! dead-peer failure path. The in-process mem-transport pins live in
//! `dist_golden`; this file is about processes and sockets.

use std::path::{Path, PathBuf};
use std::process::{Child, Command, Output, Stdio};
use std::time::{Duration, Instant};

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_soap-lab")
}

fn run(args: &[&str]) -> Output {
    Command::new(bin()).args(args).output().expect("spawning soap-lab")
}

fn assert_success(out: &Output, label: &str) {
    assert!(
        out.status.success(),
        "{label} failed (status {:?})\n--- stdout ---\n{}\n--- stderr ---\n{}",
        out.status.code(),
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr),
    );
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("soap_dist_proc_{}_{name}", std::process::id()))
}

/// Shared training flags — everything except backend/launch wiring, so the
/// serial reference and the distributed runs are configured identically.
fn train_flags(ckpt: &Path, steps: &str) -> Vec<String> {
    [
        "train",
        "--model",
        "nplm-tiny",
        "--optimizer",
        "soap",
        "--lr",
        "0.02",
        "--steps",
        steps,
        "--seed",
        "3",
        "--precond-freq",
        "4",
        "--grad-accum",
        "3",
        "--workers",
        "2",
        "--log-every",
        "0",
        "--save",
    ]
    .iter()
    .map(|s| s.to_string())
    .chain([ckpt.display().to_string()])
    .collect()
}

/// The headline end-to-end path: `--backend distributed --ranks 3` makes the
/// coordinator spawn two worker processes, rendezvous over localhost TCP,
/// train, and write a rank-0 checkpoint that is BYTE-identical to the serial
/// backend's — then a serial run resumes it.
#[test]
fn self_spawned_three_rank_train_checkpoint_resume() {
    let dist_ckpt = tmp("self_spawn.ckpt");
    let serial_ckpt = tmp("serial_ref.ckpt");

    let mut args = train_flags(&dist_ckpt, "8");
    args.extend(["--backend", "distributed", "--ranks", "3"].map(String::from));
    let out = run(&args.iter().map(String::as_str).collect::<Vec<_>>());
    assert_success(&out, "3-rank self-spawned train");
    assert!(dist_ckpt.exists(), "coordinator wrote no checkpoint");

    let mut args = train_flags(&serial_ckpt, "8");
    args.extend(["--backend", "serial"].map(String::from));
    let out = run(&args.iter().map(String::as_str).collect::<Vec<_>>());
    assert_success(&out, "serial reference train");

    // Uniform checkpoint semantics, the strong form: not just resumable,
    // but the same bytes — same params, same optimizer state, same cursor.
    let a = std::fs::read(&dist_ckpt).unwrap();
    let b = std::fs::read(&serial_ckpt).unwrap();
    assert_eq!(a, b, "distributed rank-0 checkpoint differs from the serial checkpoint");
    std::fs::remove_file(&serial_ckpt).ok();

    // Any backend resumes any backend's checkpoint: serial picks it up.
    let resume_ckpt = tmp("resumed.ckpt");
    let mut args = train_flags(&resume_ckpt, "12");
    args.extend(["--backend", "serial", "--resume"].map(String::from));
    args.push(dist_ckpt.display().to_string());
    let out = run(&args.iter().map(String::as_str).collect::<Vec<_>>());
    assert_success(&out, "serial resume of distributed checkpoint");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        stdout.contains("resumed from") && stdout.contains("at step 8"),
        "resume banner missing: {stdout}"
    );
    std::fs::remove_file(&dist_ckpt).ok();
    std::fs::remove_file(&resume_ckpt).ok();
}

fn wait_with_deadline(mut child: Child, secs: u64, label: &str) -> Output {
    let deadline = Instant::now() + Duration::from_secs(secs);
    loop {
        if child.try_wait().expect("try_wait").is_some() {
            return child.wait_with_output().unwrap();
        }
        if Instant::now() > deadline {
            child.kill().ok();
            child.wait().ok();
            panic!("{label}: still running after {secs}s — dead-peer detection failed");
        }
        std::thread::sleep(Duration::from_millis(100));
    }
}

/// Kill a worker mid-run (manual two-rank launch): the coordinator must fail
/// FAST with the typed distributed error — not hang, not write a checkpoint.
#[test]
fn killing_a_rank_fails_the_run_cleanly() {
    // Reserve a port for the rendezvous address, then release it for rank 0.
    let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = probe.local_addr().unwrap().to_string();
    drop(probe);

    let ckpt = tmp("killed.ckpt");
    // A step budget far beyond what can finish before the kill lands.
    let base = train_flags(&ckpt, "500000");
    let spawn = |rank: &str| -> Child {
        let mut args = base.clone();
        args.extend(
            ["--backend", "distributed", "--ranks", "2", "--dist-timeout", "8000", "--rank"]
                .map(String::from),
        );
        args.push(rank.to_string());
        args.extend(["--coordinator-addr".to_string(), addr.clone()]);
        Command::new(bin())
            .args(&args)
            .stdout(Stdio::null())
            .stderr(Stdio::piped())
            .spawn()
            .expect("spawning rank")
    };

    let coordinator = spawn("0");
    let mut worker = spawn("1");
    // Let rendezvous complete and training get going, then kill the worker.
    std::thread::sleep(Duration::from_millis(1500));
    worker.kill().expect("killing worker");
    worker.wait().ok();

    let out = wait_with_deadline(coordinator, 60, "coordinator");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        !out.status.success(),
        "coordinator exited cleanly despite a dead worker\nstderr: {stderr}"
    );
    assert!(
        stderr.contains("distributed error on rank 0"),
        "expected the typed DistError surface, got: {stderr}"
    );
    assert!(!ckpt.exists(), "a failed run must not leave a checkpoint behind");
}

/// A worker whose coordinator never shows up times out with a rendezvous
/// error instead of wedging.
#[test]
fn worker_without_coordinator_times_out() {
    let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = probe.local_addr().unwrap().to_string();
    drop(probe); // nobody ever listens here

    let ckpt = tmp("orphan.ckpt");
    let mut args = train_flags(&ckpt, "8");
    args.extend(
        [
            "--backend",
            "distributed",
            "--ranks",
            "2",
            "--dist-timeout",
            "2000",
            "--rank",
            "1",
        ]
        .map(String::from),
    );
    args.extend(["--coordinator-addr".to_string(), addr]);
    let child = Command::new(bin())
        .args(&args)
        .stdout(Stdio::null())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawning orphan worker");
    let out = wait_with_deadline(child, 30, "orphan worker");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(!out.status.success(), "orphan worker should fail\nstderr: {stderr}");
    assert!(
        stderr.contains("rendezvous") || stderr.contains("distributed error"),
        "expected a rendezvous-phase error, got: {stderr}"
    );
    assert!(!ckpt.exists());
}
