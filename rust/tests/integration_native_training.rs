//! Artifact-free end-to-end training: the native NPLM model + every
//! optimizer through the full coordinator (data pipeline → grads → sharded
//! update), checking that each optimizer actually learns the synthetic
//! language and that the paper's headline ordering holds on this substrate.

use soap_lab::coordinator::{Trainer, TrainerConfig};
use soap_lab::model::NplmConfig;
use soap_lab::optim::{Hyper, OptKind, Schedule};

fn trainer(opt: OptKind, hyper: Hyper, steps: u64, lr: f32, seed: u64) -> Trainer {
    let cfg = TrainerConfig {
        opt,
        hyper,
        schedule: Schedule::paper(lr, steps / 5, steps),
        steps,
        seed,
        grad_accum: 1,
        workers: 3,
        log_every: 0,
        vocab: 64,
        zipf_alpha: 1.3,
        ..TrainerConfig::default()
    };
    Trainer::new_native(NplmConfig { vocab: 64, context: 4, dim: 16, hidden: 32, conv: false }, cfg, 32, 16)
}

#[test]
fn every_optimizer_learns_the_language() {
    for (opt, lr) in [
        (OptKind::AdamW, 0.01),
        (OptKind::Adafactor, 0.01),
        (OptKind::Shampoo, 0.02),
        (OptKind::Soap, 0.02),
        (OptKind::Galore, 0.01),
    ] {
        let hyper = Hyper { precond_freq: 5, ..Hyper::default() };
        let mut t = trainer(opt, hyper, 250, lr, 1);
        let floor = t.entropy_floor() as f32;
        let log = t.run().unwrap();
        let first = log.losses[0].1;
        let last = log.tail_loss(25);
        // ln(64) ≈ 4.16; the floor ≈ 2.7. Demand real progress toward it
        // (GaLore learns slowest — the paper's Appendix-B negative result).
        let bar = if opt == OptKind::Galore { 0.35 } else { 0.5 };
        assert!(
            last < first - bar,
            "{} did not learn: {first:.3} → {last:.3} (floor {floor:.3})",
            opt.name()
        );
        assert!(last > floor - 0.05, "{}: loss below entropy floor?!", opt.name());
    }
}

#[test]
fn soap_beats_adamw_at_equal_steps() {
    // The paper's headline, on the artifact-free substrate, averaged over
    // seeds to suppress single-run noise.
    let mut soap_total = 0.0f32;
    let mut adamw_total = 0.0f32;
    for seed in [1u64, 2, 3] {
        let hyper = Hyper { precond_freq: 10, ..Hyper::default() };
        soap_total += trainer(OptKind::Soap, hyper.clone(), 220, 0.02, seed)
            .run()
            .unwrap()
            .tail_loss(20);
        adamw_total += trainer(OptKind::AdamW, hyper, 220, 0.01, seed)
            .run()
            .unwrap()
            .tail_loss(20);
    }
    assert!(
        soap_total < adamw_total + 0.03,
        "SOAP ({:.4}) should be ≤ AdamW ({:.4}) at equal steps",
        soap_total / 3.0,
        adamw_total / 3.0
    );
}

#[test]
fn frequency_robustness_soap_vs_shampoo() {
    // Fig 1 (right) on the native substrate: going f=1 → f=50 should hurt
    // Shampoo at least as much as SOAP.
    let run = |opt: OptKind, f: u64| -> f32 {
        let hyper = Hyper { precond_freq: f, ..Hyper::default() };
        trainer(opt, hyper, 200, 0.02, 7).run().unwrap().tail_loss(20)
    };
    let soap_degradation = run(OptKind::Soap, 50) - run(OptKind::Soap, 1);
    let shampoo_degradation = run(OptKind::Shampoo, 50) - run(OptKind::Shampoo, 1);
    assert!(
        soap_degradation <= shampoo_degradation + 0.05,
        "SOAP degraded more than Shampoo: {soap_degradation:.4} vs {shampoo_degradation:.4}"
    );
}

#[test]
fn grad_accum_consistency() {
    // 2 microbatches of 8 == 1 batch of 16 in data content; losses finite
    // and comparable.
    let cfg = TrainerConfig {
        opt: OptKind::AdamW,
        schedule: Schedule::Constant { lr: 0.01 },
        steps: 30,
        grad_accum: 2,
        log_every: 0,
        vocab: 64,
        zipf_alpha: 1.3,
        ..TrainerConfig::default()
    };
    let mut t = Trainer::new_native(NplmConfig { vocab: 64, context: 4, dim: 16, hidden: 32, conv: false }, cfg, 32, 8);
    assert_eq!(t.tokens_per_step(), 16 * 32);
    let log = t.run().unwrap();
    assert!(log.final_loss().is_finite());
    assert!(log.tail_loss(5) < log.losses[0].1);
}

#[test]
fn eval_loss_close_to_train_loss() {
    let hyper = Hyper { precond_freq: 10, ..Hyper::default() };
    let mut t = trainer(OptKind::Soap, hyper, 150, 0.02, 9);
    let log = t.run().unwrap();
    let eval = t.eval_loss(8).unwrap();
    // Same distribution (synthetic corpus) → eval ≈ train tail.
    assert!(
        (eval - log.tail_loss(15)).abs() < 0.5,
        "train {:.3} vs eval {eval:.3}",
        log.tail_loss(15)
    );
}
