//! Property tests over the optimizer family — the paper's structural claims
//! as invariants: Claim 1 equivalence, SOAP(Q=I) ≡ AdamW, grafting norm
//! equality, refresh staleness semantics, descent on random quadratics —
//! plus the sharding balancer's invariants (determinism, exact partition,
//! the LPT balance bound, and degenerate inputs).

use soap_lab::coordinator::sharded::{
    assign_shards, assign_shards_tensors, layer_update_flops, tensor_update_flops,
};
use soap_lab::coordinator::ShardedOptimizer;
use soap_lab::linalg::{Matrix, TensorShape};
use soap_lab::optim::idealized::{claim1_row_identity, idealized_adafactor_dir, idealized_shampoo_dir};
use soap_lab::optim::{AdamW, Hyper, LayerOptimizer, OptKind, Soap};
use soap_lab::util::prop::{self, ensure};
use soap_lab::util::rng::Rng;

#[test]
fn prop_claim1_equivalence() {
    prop::check("Claim 1: Alg1 ≡ Alg2 on random datasets", 20, |rng| {
        let m = 2 + rng.below(8) as usize;
        let n = 2 + rng.below(8) as usize;
        let k = (m.max(n)) * 2 + rng.below(8) as usize;
        let grads: Vec<Matrix> = (0..k).map(|_| Matrix::randn(rng, m, n, 1.0)).collect();
        let g = grads[rng.below(k as u64) as usize].clone();
        let d1 = idealized_shampoo_dir(&grads, &g);
        let d2 = idealized_adafactor_dir(&grads, &g, 0.0);
        let rel = d1.max_abs_diff(&d2) / d1.max_abs().max(1e-9);
        ensure(rel < 0.05, format!("{m}x{n} k={k}: rel {rel}"))
    });
}

#[test]
fn prop_claim1_row_identity() {
    prop::check("Claim 1 proof step: A_i = λ_i", 20, |rng| {
        let m = 2 + rng.below(8) as usize;
        let n = 2 + rng.below(8) as usize;
        let grads: Vec<Matrix> = (0..(2 * m + 4)).map(|_| Matrix::randn(rng, m, n, 1.0)).collect();
        let (a, lambda) = claim1_row_identity(&grads);
        for (x, y) in a.iter().zip(&lambda) {
            ensure(
                (x - y).abs() < 3e-2 * (1.0 + y.abs()),
                format!("A {x} vs λ {y}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_soap_identity_basis_is_adamw() {
    prop::check("SOAP with Q=I ≡ AdamW exactly", 15, |rng| {
        let m = 2 + rng.below(8) as usize;
        let n = 2 + rng.below(8) as usize;
        let h = Hyper { max_precond_dim: 0, weight_decay: 0.0, ..Hyper::default() };
        let mut soap = Soap::new(m, n, h.clone());
        let mut adam = AdamW::new(m, n, h);
        let mut ws = Matrix::randn(rng, m, n, 1.0);
        let mut wa = ws.clone();
        for t in 1..=12 {
            let g = Matrix::randn(rng, m, n, 1.0);
            soap.update(&mut ws, &g, t, 0.01);
            adam.update(&mut wa, &g, t, 0.01);
        }
        ensure(
            ws.max_abs_diff(&wa) < 5e-5,
            format!("diverged by {}", ws.max_abs_diff(&wa)),
        )
    });
}

/// The preset kinds plus two composition-grammar kinds (one canonical, one
/// novel), so the property suite covers the composed core's full surface.
fn all_kinds() -> Vec<OptKind> {
    vec![
        OptKind::AdamW,
        OptKind::Adafactor,
        OptKind::Shampoo,
        OptKind::Soap,
        OptKind::Galore,
        OptKind::parse("basis=eigen:one-sided,inner=adafactor").unwrap(),
        OptKind::parse("basis=svd,inner=adafactor").unwrap(),
    ]
}

#[test]
fn prop_all_optimizers_descend_on_quadratic() {
    prop::check("every optimizer reduces a random quadratic", 10, |rng| {
        let m = 2 + rng.below(6) as usize;
        let n = 2 + rng.below(6) as usize;
        let target = Matrix::randn(rng, m, n, 1.0);
        for kind in [
            OptKind::AdamW,
            OptKind::Adafactor,
            OptKind::Shampoo,
            OptKind::Soap,
            OptKind::Galore,
        ] {
            let h = Hyper { weight_decay: 0.0, precond_freq: 3, ..Hyper::default() };
            let mut opt = kind.build(m, n, &h);
            let mut w = Matrix::zeros(m, n);
            let loss = |w: &Matrix| {
                let d = w.sub(&target);
                (d.frob_norm() as f64).powi(2)
            };
            let l0 = loss(&w);
            for t in 1..=300 {
                let g = w.sub(&target).scale(2.0);
                opt.update(&mut w, &g, t, 0.02);
            }
            let l1 = loss(&w);
            ensure(
                l1 < 0.5 * l0,
                format!("{} failed to descend: {l0} → {l1} on {m}x{n}", kind.name()),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_all_optimizers_finite_under_extreme_gradients() {
    prop::check("no NaN/Inf under huge/tiny/zero gradients", 10, |rng| {
        let m = 2 + rng.below(5) as usize;
        let n = 2 + rng.below(5) as usize;
        let scales = [0.0f32, 1e-20, 1e20];
        for kind in all_kinds() {
            let h = Hyper { precond_freq: 2, ..Hyper::default() };
            let mut opt = kind.build(m, n, &h);
            let mut w = Matrix::randn(rng, m, n, 1.0);
            for (t, &s) in scales.iter().enumerate() {
                let g = Matrix::randn(rng, m, n, 1.0).scale(s);
                opt.update(&mut w, &g, t as u64 + 1, 0.01);
                ensure(
                    w.data.iter().all(|x| x.is_finite()),
                    format!("{} produced non-finite weights at |g|~{s}", kind.name()),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_state_roundtrip_all_optimizers() {
    prop::check("export/import state preserves the trajectory", 8, |rng| {
        let m = 2 + rng.below(6) as usize;
        let n = 2 + rng.below(6) as usize;
        for kind in all_kinds() {
            let h = Hyper { precond_freq: 2, ..Hyper::default() };
            let mut a = kind.build(m, n, &h);
            let mut wa = Matrix::randn(rng, m, n, 1.0);
            let pre: Vec<Matrix> = (0..3).map(|_| Matrix::randn(rng, m, n, 1.0)).collect();
            let post: Vec<Matrix> = (0..3).map(|_| Matrix::randn(rng, m, n, 1.0)).collect();
            for (t, g) in pre.iter().enumerate() {
                a.update(&mut wa, g, t as u64 + 1, 0.01);
            }
            // Clone through the checkpoint surface.
            let mut b = kind.build(m, n, &h);
            b.import_state(a.export_state())
                .map_err(|e| format!("{}: {e}", kind.name()))?;
            let mut wb = wa.clone();
            for (t, g) in post.iter().enumerate() {
                a.update(&mut wa, g, t as u64 + 4, 0.01);
                b.update(&mut wb, g, t as u64 + 4, 0.01);
            }
            ensure(
                wa.max_abs_diff(&wb) < 1e-5,
                format!("{} drifted {}", kind.name(), wa.max_abs_diff(&wb)),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_grafting_matches_adamw_norm() {
    prop::check("Shampoo grafting: step norm equals AdamW's", 15, |rng| {
        let m = 2 + rng.below(8) as usize;
        let n = 2 + rng.below(8) as usize;
        let h = Hyper { weight_decay: 0.0, precond_freq: 1, ..Hyper::default() };
        let mut sh = OptKind::Shampoo.build(m, n, &h);
        let mut ad = OptKind::AdamW.build(m, n, &h);
        let g = Matrix::randn(rng, m, n, 1.0);
        let mut ws = Matrix::zeros(m, n);
        let mut wa = Matrix::zeros(m, n);
        sh.update(&mut ws, &g, 1, 1.0);
        ad.update(&mut wa, &g, 1, 1.0);
        let (ns, na) = (ws.frob_norm(), wa.frob_norm());
        ensure(
            (ns - na).abs() / na.max(1e-9) < 0.05,
            format!("norms {ns} vs {na}"),
        )
    });
}

/// Random mixed-rank shape lists for the sharding properties.
fn random_shapes(rng: &mut Rng, n: usize) -> Vec<TensorShape> {
    (0..n)
        .map(|_| {
            let rank = 1 + rng.below(3) as usize; // 1..=3
            let dims: Vec<usize> = (0..rank).map(|_| 1 + rng.below(24) as usize).collect();
            TensorShape::new(dims)
        })
        .collect()
}

#[test]
fn prop_assign_shards_partitions_every_layer_exactly_once() {
    prop::check("assign_shards: exact partition, valid shard ids", 25, |rng| {
        let n = rng.below(14) as usize;
        let k = 1 + rng.below(6) as usize;
        let shapes = random_shapes(rng, n);
        let assign = assign_shards_tensors(&shapes, k);
        // Every layer appears exactly once (the output IS the partition
        // function), and every shard id is in range.
        ensure(assign.len() == n, format!("{} assignments for {n} layers", assign.len()))?;
        ensure(assign.iter().all(|&s| s < k), format!("shard id out of range: {assign:?}"))
    });
}

#[test]
fn prop_assign_shards_deterministic_across_runs() {
    prop::check("assign_shards: same input ⇒ same assignment", 20, |rng| {
        let n = rng.below(12) as usize;
        let k = 1 + rng.below(5) as usize;
        let shapes = random_shapes(rng, n);
        let a = assign_shards_tensors(&shapes, k);
        let b = assign_shards_tensors(&shapes, k);
        ensure(a == b, format!("nondeterministic assignment: {a:?} vs {b:?}"))?;
        // The rank-2 entry point agrees with the tensor one on matrices.
        let mats: Vec<(usize, usize)> = shapes.iter().map(|s| s.carrier()).collect();
        let rank2: Vec<TensorShape> =
            mats.iter().map(|&(m, n)| TensorShape::matrix(m, n)).collect();
        ensure(
            assign_shards(&mats, k) == assign_shards_tensors(&rank2, k),
            "matrix and tensor entry points disagree on rank-2 input".to_string(),
        )
    });
}

#[test]
fn prop_assign_shards_lpt_balance_bound() {
    prop::check("assign_shards: max shard cost ≤ 4/3 · OPT proxy", 30, |rng| {
        let n = 1 + rng.below(16) as usize;
        let k = 1 + rng.below(5) as usize;
        let shapes = random_shapes(rng, n);
        let costs: Vec<f64> = shapes.iter().map(|s| tensor_update_flops(s.dims())).collect();
        let assign = assign_shards_tensors(&shapes, k);
        let mut load = vec![0.0f64; k];
        for (i, &s) in assign.iter().enumerate() {
            load[s] += costs[i];
        }
        let max_load = load.iter().cloned().fold(0.0f64, f64::max);
        // OPT lower-bound proxy: mean load, the biggest single job, and —
        // when there are more jobs than shards — the two smallest of the
        // k+1 largest jobs (some shard must take two of them). Graham's
        // LPT guarantee (≤ 4/3·OPT − 1/(3k)) holds against any OPT ≥ proxy.
        let total: f64 = costs.iter().sum();
        let mut sorted = costs.clone();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let mut proxy = (total / k as f64).max(sorted.first().copied().unwrap_or(0.0));
        if n > k {
            proxy = proxy.max(sorted[k - 1] + sorted[k]);
        }
        ensure(
            max_load <= 4.0 / 3.0 * proxy + 1e-6,
            format!("LPT bound violated: max {max_load} vs proxy {proxy} (k={k}, n={n})"),
        )
    });
}

#[test]
fn assign_shards_degenerate_inputs() {
    // Empty shape list: an empty assignment, from both entry points.
    assert!(assign_shards(&[], 3).is_empty());
    assert!(assign_shards_tensors(&[], 3).is_empty());
    // More shards than layers: everything assigned, ids in range, and the
    // sharded optimizer still constructs and steps.
    let shapes = vec![(4usize, 4usize), (1, 8)];
    let assign = assign_shards(&shapes, 7);
    assert_eq!(assign.len(), 2);
    assert!(assign.iter().all(|&s| s < 7));
    let hyper = Hyper { weight_decay: 0.0, ..Hyper::default() };
    let mut opt = ShardedOptimizer::new(OptKind::Soap, &hyper, &shapes, 7);
    let mut rng = Rng::new(5);
    let mut params: Vec<Matrix> =
        shapes.iter().map(|&(m, n)| Matrix::randn(&mut rng, m, n, 1.0)).collect();
    let grads: Vec<Matrix> =
        shapes.iter().map(|&(m, n)| Matrix::randn(&mut rng, m, n, 1.0)).collect();
    opt.step(&mut params, &grads, 1, 0.01);
    assert!(params.iter().all(|p| p.data.iter().all(|x| x.is_finite())));
    // An empty model is a no-op, not a panic.
    let mut empty = ShardedOptimizer::new(OptKind::Soap, &hyper, &[], 3);
    empty.step(&mut [], &[], 1, 0.01);
    assert_eq!(empty.state_bytes(), 0);
}

#[test]
fn tensor_cost_model_reduces_to_paper_matrix_model() {
    // Σ dₖ³ + 2·numel·Σ dₖ on [m, n] IS m³ + n³ + 2m²n + 2mn² (§7.3), and
    // the per-mode model values a cube of small factors far below its
    // carrier fold — the point of threading true shapes to the balancer.
    for &(m, n) in &[(8usize, 4usize), (64, 64), (1, 128)] {
        let got = layer_update_flops(m, n);
        let (mf, nf) = (m as f64, n as f64);
        let want = mf * mf * mf + nf * nf * nf + 2.0 * mf * mf * nf + 2.0 * mf * nf * nf;
        assert!((got - want).abs() <= 1e-9 * want.abs(), "{m}×{n}: {got} vs {want}");
    }
    let cube = tensor_update_flops(&[8, 8, 8]);
    let folded = tensor_update_flops(&[64, 8]);
    assert!(
        cube < folded,
        "per-mode cost of [8,8,8] ({cube}) should be far below its 64×8 fold ({folded})"
    );
}

#[test]
fn prop_schedule_bounded_and_floored() {
    prop::check("warmup-cosine stays within [floor, peak]", 30, |rng| {
        let lr = 10f32.powf(-(1.0 + rng.uniform() as f32 * 3.0));
        let total = 50 + rng.below(5000);
        let warmup = rng.below(total / 2 + 1);
        let s = soap_lab::optim::Schedule::paper(lr, warmup, total);
        for _ in 0..50 {
            let t = rng.below(total * 2);
            let v = s.lr_at(t);
            ensure(
                v >= 0.1 * lr - 1e-9 && v <= lr + 1e-9,
                format!("lr_at({t}) = {v} outside [0.1·{lr}, {lr}]"),
            )?;
        }
        Ok(())
    });
}
