//! Checkpoint format v4 hardening: corruption fuzzing + legacy fixtures.
//!
//! The container format must never panic or silently accept a damaged
//! file — every corruption class here must surface as an `Err` whose
//! message NAMES the field where parsing stopped:
//!
//! - random truncations at every depth (header, shape section, tensor
//!   payloads) — seeded sweep over a real v4 file with a rank-3 state row;
//! - targeted header corruptions (future version, malformed seed flag,
//!   unknown state-dtype tag, implausible counts/ranks, oversized dims);
//! - trailing bytes after a valid payload;
//! - bit-flipped optimizer-state *flags rows* — the container parses (flags
//!   are ordinary f32 rows) but `import_state` must reject the
//!   now-inconsistent record instead of training on corrupted state.
//!
//! Checked-in `rust/tests/fixtures/{v1,v2,v3,v4}.ckpt` prove the legacy
//! formats keep loading (with the state-dtype tag defaulting to f32 for
//! v1–v3) and round-trip through the current writer.

use soap_lab::coordinator::Checkpoint;
use soap_lab::linalg::{Matrix, TensorShape};
use soap_lab::optim::compose::presets;
use soap_lab::optim::{Hyper, LayerOptimizer, StateDtype};
use soap_lab::util::rng::Rng;

fn tmpfile(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("soap_fuzz_{name}_{}", std::process::id()))
}

fn fixture(name: &str) -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/fixtures")
        .join(name)
}

/// A realistic current-format checkpoint: a rank-3 parameter with a genuine
/// per-mode (`TensorModes`) optimizer state row next to a rank-2 one.
fn rank3_checkpoint() -> Checkpoint {
    let mut rng = Rng::new(91);
    let shape3 = TensorShape::new(vec![3, 4, 5]);
    let (r3, c3) = shape3.carrier();
    let h = Hyper { weight_decay: 0.0, precond_freq: 3, ..Hyper::default() };

    let mut opt3 = presets::soap_nd((r3, c3), &shape3, h.clone());
    let mut w3 = Matrix::randn(&mut rng, r3, c3, 1.0);
    let mut opt2 = presets::soap(6, 4, h);
    let mut w2 = Matrix::randn(&mut rng, 6, 4, 1.0);
    for t in 1..=5 {
        let g3 = Matrix::randn(&mut rng, r3, c3, 1.0);
        let g2 = Matrix::randn(&mut rng, 6, 4, 1.0);
        opt3.update(&mut w3, &g3, t, 0.01);
        opt2.update(&mut w2, &g2, t, 0.01);
    }
    Checkpoint {
        step: 5,
        params: vec![w3, w2],
        opt_state: vec![(0, opt3.export_state()), (1, opt2.export_state())],
        data_batches: 5,
        seed: Some(3),
        stream_batch: 8,
        stream_seq: 16,
        param_dims: vec![vec![3, 4, 5], vec![6, 4]],
        state_dtype: StateDtype::F32,
    }
}

fn current_bytes(tag: &str) -> Vec<u8> {
    // Per-caller temp name: the tests sharing this run on parallel harness
    // threads within one process, so the pid alone does not disambiguate.
    let path = tmpfile(&format!("v4base_{tag}"));
    rank3_checkpoint().save(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    std::fs::remove_file(&path).ok();
    bytes
}

fn load_bytes(bytes: &[u8]) -> anyhow::Result<Checkpoint> {
    let tag = bytes.len() ^ ((bytes.first().copied().unwrap_or(0) as usize) << 13);
    let path = tmpfile(&format!("case_{tag:x}"));
    std::fs::write(&path, bytes).unwrap();
    let out = Checkpoint::load(&path);
    std::fs::remove_file(&path).ok();
    out
}

#[test]
fn random_truncations_always_error_with_field_context() {
    let bytes = current_bytes("trunc");
    let mut rng = Rng::new(0xFADE);
    // Boundary cuts plus a seeded random sweep across every depth.
    let mut cuts: Vec<usize> = vec![0, 1, 7, 8, 11, 12, 44, 45, bytes.len() - 1];
    for _ in 0..150 {
        cuts.push(rng.below(bytes.len() as u64) as usize);
    }
    for cut in cuts {
        let err = match load_bytes(&bytes[..cut]) {
            Err(e) => format!("{e:#}"),
            Ok(_) => panic!("truncation at {cut}/{} silently accepted", bytes.len()),
        };
        // Every truncation error names the field (or dies on the magic).
        assert!(
            err.contains("truncated") || err.contains("not a soap-lab checkpoint"),
            "cut at {cut}: unexpected error shape: {err}"
        );
    }
}

#[test]
fn trailing_bytes_rejected() {
    let mut bytes = current_bytes("trail");
    bytes.extend_from_slice(&[0xAB, 0xCD, 0xEF]);
    let err = format!("{:#}", load_bytes(&bytes).unwrap_err());
    assert!(err.contains("trailing"), "{err}");
}

#[test]
fn targeted_header_corruptions_name_their_field() {
    let base = current_bytes("hdr");
    // Fixed v4 prefix offsets: magic[0..8] version[8..12] step[12..20]
    // cursor[20..28] seed-flag[28] seed[29..37] batch[37..41] seq[41..45]
    // state-dtype[45] n_shapes[46..50] shape0-rank[50..54] …
    let mutate = |at: usize, val: &[u8]| {
        let mut b = base.clone();
        b[at..at + val.len()].copy_from_slice(val);
        b
    };

    // Future version: refused, never misparsed.
    let err = format!("{:#}", load_bytes(&mutate(8, &99u32.to_le_bytes())).unwrap_err());
    assert!(err.contains("version 99") && err.contains("newer"), "{err}");

    // Non-boolean seed flag.
    let err = format!("{:#}", load_bytes(&mutate(28, &[7])).unwrap_err());
    assert!(err.contains("seed flag"), "{err}");

    // Unknown state-dtype tag: named error, not a silent f32 fallback.
    let err = format!("{:#}", load_bytes(&mutate(45, &[9])).unwrap_err());
    assert!(err.contains("state dtype tag 9"), "{err}");

    // Implausible shape count: bound-checked before any allocation.
    let err =
        format!("{:#}", load_bytes(&mutate(46, &(u32::MAX).to_le_bytes())).unwrap_err());
    assert!(err.contains("shape count"), "{err}");

    // Implausible rank on shape 0.
    let err = format!("{:#}", load_bytes(&mutate(50, &4096u32.to_le_bytes())).unwrap_err());
    assert!(err.contains("shape 0") && err.contains("rank"), "{err}");

    // Zero dim on shape 0 (first dim sits right after its rank).
    let err = format!("{:#}", load_bytes(&mutate(54, &0u32.to_le_bytes())).unwrap_err());
    assert!(err.contains("shape 0") && err.contains("dim 0"), "{err}");

    // A bit-flipped dim value survives the shape section but must then be
    // caught by the shape/param element-count cross-check, naming the param.
    let err = format!("{:#}", load_bytes(&mutate(54, &7u32.to_le_bytes())).unwrap_err());
    assert!(err.contains("param 0") && err.contains("tensor shape"), "{err}");
}

#[test]
fn bit_flipped_state_flags_rows_are_rejected_on_import() {
    // The container cannot distinguish a flipped flag from data (flags are
    // ordinary f32 rows) — the OPTIMIZER import must catch the
    // inconsistency. Never a panic, never silent acceptance.
    let h = Hyper { weight_decay: 0.0, precond_freq: 3, ..Hyper::default() };

    // Rank-2 SOAP row: flipping the full-V flag claims a factored second
    // moment the engine does not have.
    let mut opt = presets::soap(6, 4, h.clone());
    let mut w = Matrix::randn(&mut Rng::new(92), 6, 4, 1.0);
    for t in 1..=4 {
        let g = Matrix::randn(&mut Rng::new(92 + t), 6, 4, 1.0);
        opt.update(&mut w, &g, t, 0.01);
    }
    let mut state = opt.export_state();
    state[0].data[3] = 0.0; // has_full_v: 1 → 0
    let mut fresh = presets::soap(6, 4, h.clone());
    let err = fresh.import_state(state).unwrap_err().to_string();
    assert!(err.contains("full V") || err.contains("factored"), "{err}");

    // Flipping has_l desynchronizes the tensor count: strict arity must
    // notice the leftover tensor rather than shifting every later field.
    let mut state = opt.export_state();
    state[0].data[1] = 0.0; // has_l: 1 → 0
    let mut fresh = presets::soap(6, 4, h.clone());
    assert!(fresh.import_state(state).is_err(), "has_l flip silently accepted");

    // Rank-3 (TensorModes) row: a flipped rank field must be a named error.
    let shape = TensorShape::new(vec![3, 4, 5]);
    let mut opt3 = presets::soap_nd(shape.carrier(), &shape, h.clone());
    let mut w3 = Matrix::randn(&mut Rng::new(93), 12, 5, 1.0);
    for t in 1..=4 {
        let g = Matrix::randn(&mut Rng::new(93 + t), 12, 5, 1.0);
        opt3.update(&mut w3, &g, t, 0.01);
    }
    let mut state = opt3.export_state();
    state[0].data[1] = 2.0; // rank: 3 → 2
    let mut fresh = presets::soap_nd(shape.carrier(), &shape, h.clone());
    let err = fresh.import_state(state).unwrap_err().to_string();
    assert!(err.contains("rank"), "{err}");

    // …and a flipped per-mode has-factor flag must not shift the records.
    let mut state = opt3.export_state();
    state[0].data[2] = 0.0; // mode-0 has_factor: 1 → 0
    let mut fresh = presets::soap_nd(shape.carrier(), &shape, h);
    assert!(fresh.import_state(state).is_err(), "mode-flag flip silently accepted");
}

#[test]
fn v1_fixture_loads_and_roundtrips() {
    let back = Checkpoint::load(fixture("v1.ckpt")).unwrap();
    assert_eq!(back.step, 5);
    assert_eq!(back.data_batches, 5, "v1 cursor defaults to step");
    assert_eq!(back.seed, None);
    assert_eq!((back.stream_batch, back.stream_seq), (0, 0));
    assert!(back.param_dims.is_empty(), "v1 records no tensor shapes");
    assert_eq!(back.state_dtype, StateDtype::F32, "v1 state dtype defaults to f32");
    assert_eq!((back.params[0].rows, back.params[0].cols), (2, 3));
    assert_eq!(back.params[0].data, vec![0.5, -1.25, 2.0, 3.5, -0.75, 1.5]);
    assert_eq!(back.params[1].data, vec![10.0, 20.0, 30.0, 40.0]);
    assert_eq!(back.opt_state.len(), 2);
    assert_eq!(back.opt_state[1].1[1].data, Matrix::eye(4).data);

    // Round-trip through the CURRENT writer: data is preserved and the
    // rewrite upgrades to v4 with carrier-fold shapes.
    let path = tmpfile("v1rt");
    back.save(&path).unwrap();
    let again = Checkpoint::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(again.step, back.step);
    assert_eq!(again.params[0].data, back.params[0].data);
    assert_eq!(again.opt_state[1].1[0].data, back.opt_state[1].1[0].data);
    assert_eq!(again.param_dims, vec![vec![2, 3], vec![1, 4]]);
}

#[test]
fn v2_fixture_loads_and_roundtrips() {
    let back = Checkpoint::load(fixture("v2.ckpt")).unwrap();
    assert_eq!(back.step, 9);
    assert_eq!(back.data_batches, 9);
    assert_eq!(back.seed, Some(77));
    assert_eq!((back.stream_batch, back.stream_seq), (8, 16));
    assert!(back.param_dims.is_empty(), "v2 records no tensor shapes");
    assert_eq!(back.state_dtype, StateDtype::F32, "v2 state dtype defaults to f32");
    assert_eq!(back.params[0].data, vec![0.5, -1.25, 2.0, 3.5, -0.75, 1.5]);

    let path = tmpfile("v2rt");
    back.save(&path).unwrap();
    let again = Checkpoint::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(again.seed, Some(77));
    assert_eq!((again.stream_batch, again.stream_seq), (8, 16));
    assert_eq!(again.params[1].data, back.params[1].data);
    assert_eq!(again.opt_state[0].1[0].data, back.opt_state[0].1[0].data);
}

#[test]
fn v3_fixture_loads_with_f32_default_and_upgrades() {
    let back = Checkpoint::load(fixture("v3.ckpt")).unwrap();
    assert_eq!(back.step, 9);
    assert_eq!(back.seed, Some(77));
    assert_eq!((back.stream_batch, back.stream_seq), (8, 16));
    assert_eq!(back.param_dims, vec![vec![2, 3], vec![1, 4]]);
    assert_eq!(back.state_dtype, StateDtype::F32, "v3 state dtype defaults to f32");
    assert_eq!(back.params[0].data, vec![0.5, -1.25, 2.0, 3.5, -0.75, 1.5]);
    assert_eq!(back.opt_state[1].1[1].data, Matrix::eye(4).data);

    // Round-trip through the current writer keeps the f32 tag.
    let path = tmpfile("v3ckrt");
    back.save(&path).unwrap();
    let again = Checkpoint::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(again.state_dtype, StateDtype::F32);
    assert_eq!(again.param_dims, back.param_dims);
    assert_eq!(again.params[1].data, back.params[1].data);
}

#[test]
fn v4_fixture_loads_with_bf16_tag() {
    let back = Checkpoint::load(fixture("v4.ckpt")).unwrap();
    assert_eq!(back.step, 9);
    assert_eq!(back.seed, Some(77));
    assert_eq!(back.param_dims, vec![vec![2, 3], vec![1, 4]]);
    assert_eq!(back.state_dtype, StateDtype::Bf16, "v4 fixture carries the bf16 tag");
    // State tensors stay f32 on the wire regardless of the tag.
    assert_eq!(back.params[0].data, vec![0.5, -1.25, 2.0, 3.5, -0.75, 1.5]);
    assert_eq!(back.opt_state[1].1[1].data, Matrix::eye(4).data);

    let path = tmpfile("v4ckrt");
    back.save(&path).unwrap();
    let again = Checkpoint::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(again.state_dtype, StateDtype::Bf16, "bf16 tag survives the round-trip");
}

#[test]
fn current_roundtrip_preserves_rank3_shapes_and_state() {
    let ck = rank3_checkpoint();
    let path = tmpfile("v4rt");
    ck.save(&path).unwrap();
    let back = Checkpoint::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(back.param_dims, vec![vec![3, 4, 5], vec![6, 4]]);
    assert_eq!(back.opt_state.len(), 2);
    for ((ia, ta), (ib, tb)) in ck.opt_state.iter().zip(&back.opt_state) {
        assert_eq!(ia, ib);
        assert_eq!(ta.len(), tb.len());
        for (x, y) in ta.iter().zip(tb) {
            assert_eq!(x.data, y.data, "state tensor drifted through save/load");
        }
    }
}
