//! Golden tests for the N-dimensional tensor-parameter path.
//!
//! The contract of the tensor generalization is: **rank ≤ 2 changes
//! nothing**. Every preset built through `OptKind::build_tensor` /
//! `ModelOptimizer::new_tensors` / the tensor-shaped executors on a rank-2
//! shape must be BITWISE identical to the pre-existing matrix path — inline
//! and drained-async — because they route onto exactly that path. Rank-3+
//! must train end-to-end through the serial and sharded backends with
//! checkpoint/resume bitwise-equal to an uninterrupted run (the acceptance
//! bar), and the merge/squeeze collapses must rejoin the matrix path.

use soap_lab::coordinator::ShardedOptimizer;
use soap_lab::linalg::{Matrix, TensorShape};
use soap_lab::optim::{Hyper, ModelOptimizer, OptKind, Schedule};
use soap_lab::session::{Backend, ExecutorBackend, ModelSpec, SerialExecutor, TrainSession};
use soap_lab::util::rng::Rng;

fn seeded_grads(seed: u64, steps: usize, m: usize, n: usize) -> Vec<Matrix> {
    let mut rng = Rng::new(seed);
    (0..steps).map(|_| Matrix::randn(&mut rng, m, n, 1.0)).collect()
}

fn tmpfile(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("soap_golden_tensor_{name}_{}", std::process::id()))
}

/// Every preset (and the SOAP variants), rank-2 shapes: `build_tensor` must
/// reproduce `build` bitwise, step for step — wide, tall, and 1-D carriers.
#[test]
fn rank2_tensor_build_bitwise_matches_matrix_build() {
    let base = Hyper { weight_decay: 1e-4, precond_freq: 5, ..Hyper::default() };
    let variants: Vec<(&str, OptKind, Hyper)> = vec![
        ("adamw", OptKind::AdamW, base.clone()),
        ("adafactor", OptKind::Adafactor, base.clone()),
        ("shampoo", OptKind::Shampoo, base.clone()),
        ("soap", OptKind::Soap, base.clone()),
        ("soap-one-sided", OptKind::Soap, Hyper { one_sided: true, ..base.clone() }),
        ("soap-factorized", OptKind::Soap, Hyper { factorized: true, ..base.clone() }),
        ("soap-dim-capped", OptKind::Soap, Hyper { max_precond_dim: 9, ..base.clone() }),
        ("galore", OptKind::Galore, base.clone()),
    ];
    for &(m, n) in &[(12usize, 8usize), (8, 12), (1, 16)] {
        for (label, kind, h) in &variants {
            let mut a = kind.build(m, n, h);
            let mut b = kind.build_tensor(&TensorShape::matrix(m, n), h);
            assert_eq!(a.name(), b.name(), "{label} {m}×{n}: label changed");
            let mut rng = Rng::new(7);
            let mut wa = Matrix::randn(&mut rng, m, n, 1.0);
            let mut wb = wa.clone();
            for (t, g) in seeded_grads(100, 26, m, n).iter().enumerate() {
                a.update(&mut wa, g, t as u64 + 1, 0.01);
                b.update(&mut wb, g, t as u64 + 1, 0.01);
                assert_eq!(
                    wa.data,
                    wb.data,
                    "{label} {m}×{n}: tensor path diverged from matrix path at step {}",
                    t + 1
                );
            }
        }
    }
}

/// The serial executor over tensor shapes ≡ over (m, n) shapes, bitwise —
/// inline AND drained-async (the service is drained after every step so
/// adoption timing is a pure function of the step count).
#[test]
fn rank2_executors_bitwise_inline_and_drained_async() {
    let shapes: Vec<(usize, usize)> = vec![(12, 12), (1, 24), (8, 16), (16, 8)];
    let tshapes: Vec<TensorShape> =
        shapes.iter().map(|&(m, n)| TensorShape::matrix(m, n)).collect();
    for kind in [OptKind::Soap, OptKind::Shampoo, OptKind::Galore] {
        for asynchronous in [false, true] {
            let mut h = Hyper { weight_decay: 0.0, precond_freq: 3, ..Hyper::default() };
            if asynchronous {
                h = h.async_refresh();
            }
            let mut a = SerialExecutor::new(kind, &h, &shapes);
            let mut b = SerialExecutor::new_tensors(kind, &h, &tshapes);
            let mut rng = Rng::new(11);
            let init: Vec<Matrix> =
                shapes.iter().map(|&(m, n)| Matrix::randn(&mut rng, m, n, 1.0)).collect();
            let mut pa = init.clone();
            let mut pb = init;
            for t in 1..=10u64 {
                let grads: Vec<Matrix> = shapes
                    .iter()
                    .map(|&(m, n)| Matrix::randn(&mut rng, m, n, 1.0))
                    .collect();
                a.step(None, &mut pa, &grads, t, 0.01).unwrap();
                b.step(None, &mut pb, &grads, t, 0.01).unwrap();
                if asynchronous {
                    // Drain both so each adopts the same publications at the
                    // same steps — the deterministic-async contract.
                    a.wait_refresh_idle();
                    b.wait_refresh_idle();
                }
            }
            for (x, y) in pa.iter().zip(&pb) {
                assert_eq!(
                    x.data, y.data,
                    "{} (async={asynchronous}): tensor-shaped executor diverged",
                    kind.name()
                );
            }
        }
    }
}

/// `ModelOptimizer::new_tensors` on rank-2 shapes ≡ `ModelOptimizer::new`.
#[test]
fn model_optimizer_tensor_ctor_bitwise() {
    let shapes = [(6usize, 10usize), (1, 12), (10, 6)];
    let tshapes: Vec<TensorShape> =
        shapes.iter().map(|&(m, n)| TensorShape::matrix(m, n)).collect();
    let h = Hyper { weight_decay: 0.0, precond_freq: 4, ..Hyper::default() };
    let sched = Schedule::Constant { lr: 0.01 };
    let mut a = ModelOptimizer::new(OptKind::Soap, h.clone(), sched.clone(), &shapes);
    let mut b = ModelOptimizer::new_tensors(OptKind::Soap, h, sched, &tshapes);
    let mut rng = Rng::new(13);
    let init: Vec<Matrix> =
        shapes.iter().map(|&(m, n)| Matrix::randn(&mut rng, m, n, 1.0)).collect();
    let mut pa = init.clone();
    let mut pb = init;
    for _ in 0..9 {
        let grads: Vec<Matrix> =
            shapes.iter().map(|&(m, n)| Matrix::randn(&mut rng, m, n, 1.0)).collect();
        a.step(&mut pa, &grads);
        b.step(&mut pb, &grads);
    }
    for (x, y) in pa.iter().zip(&pb) {
        assert_eq!(x.data, y.data, "new_tensors diverged from new");
    }
}

/// A rank-3 shape whose modes merge into its own carrier fold rejoins the
/// matrix path — bitwise, not approximately.
#[test]
fn merged_rank3_collapse_routes_to_matrix_path_bitwise() {
    // [3, 4, 6] with merge cap 12 → [12, 6] == the (12, 6) carrier.
    let h = Hyper { weight_decay: 0.0, precond_freq: 4, merge_dims: 12, ..Hyper::default() };
    let shape = TensorShape::new(vec![3, 4, 6]);
    assert_eq!(shape.carrier(), (12, 6));
    let mut a = OptKind::Soap.build(12, 6, &h);
    let mut b = OptKind::Soap.build_tensor(&shape, &h);
    let mut rng = Rng::new(17);
    let mut wa = Matrix::randn(&mut rng, 12, 6, 1.0);
    let mut wb = wa.clone();
    for (t, g) in seeded_grads(200, 14, 12, 6).iter().enumerate() {
        a.update(&mut wa, g, t as u64 + 1, 0.01);
        b.update(&mut wb, g, t as u64 + 1, 0.01);
    }
    assert_eq!(wa.data, wb.data, "merged collapse must rejoin the matrix path");
    // Without merging the same shape takes the per-mode path (different
    // math — three factors, not two) yet still descends and stays finite.
    let h_nd = Hyper { merge_dims: 0, ..h };
    let mut c = OptKind::Soap.build_tensor(&shape, &h_nd);
    let mut wc = Matrix::randn(&mut rng, 12, 6, 1.0);
    for (t, g) in seeded_grads(201, 14, 12, 6).iter().enumerate() {
        c.update(&mut wc, g, t as u64 + 1, 0.01);
    }
    assert!(wc.data.iter().all(|x| x.is_finite()));
}

/// Degenerate collapses must route, not panic: an over-aggressive
/// `merge_dims` that folds everything into one mode, and size-1 padding
/// that squeezes to a vector, both land on the carrier matrix path.
#[test]
fn degenerate_rank_collapses_route_to_carrier_path() {
    // [3, 12, 24] with merge cap ≥ numel → effective [864] (rank 1, carrier
    // changed): must behave exactly like 2-D SOAP on the (36, 24) carrier.
    let h = Hyper { weight_decay: 0.0, precond_freq: 4, merge_dims: 900, ..Hyper::default() };
    let shape = TensorShape::new(vec![3, 12, 24]);
    let mut a = OptKind::Soap.build(36, 24, &h);
    let mut b = OptKind::Soap.build_tensor(&shape, &h);
    assert_eq!(b.name(), "soap");
    let mut rng = Rng::new(23);
    let mut wa = Matrix::randn(&mut rng, 36, 24, 1.0);
    let mut wb = wa.clone();
    for (t, g) in seeded_grads(300, 6, 36, 24).iter().enumerate() {
        a.update(&mut wa, g, t as u64 + 1, 0.01);
        b.update(&mut wb, g, t as u64 + 1, 0.01);
    }
    assert_eq!(wa.data, wb.data, "over-merged collapse must rejoin the carrier path");
    // [1, n, 1] squeezes to a vector (carrier (n, 1)): the 1-D Adam
    // fallback applies, for the preset and the spec grammar alike.
    let padded = TensorShape::new(vec![1, 16, 1]);
    assert_eq!(OptKind::Soap.build_tensor(&padded, &Hyper::default()).name(), "adamw");
    let spec = OptKind::parse("basis=eigen,inner=adafactor").unwrap();
    assert_eq!(spec.build_tensor(&padded, &Hyper::default()).name(), "adamw");
    // Shampoo still preconditions the degenerate vector's carrier.
    assert_eq!(OptKind::Shampoo.build_tensor(&padded, &Hyper::default()).name(), "shampoo");
}

/// Rank-3+ state rows survive executor-to-executor transfer (serial exports,
/// sharded imports) and continue bitwise — the per-mode factor records are
/// complete.
#[test]
fn rank3_state_moves_between_executors_bitwise() {
    let tshapes = vec![
        TensorShape::new(vec![3, 4, 5]),
        TensorShape::matrix(6, 8),
        TensorShape::new(vec![2, 3, 4, 2]),
        TensorShape::matrix(1, 10),
    ];
    let shapes: Vec<(usize, usize)> = tshapes.iter().map(|s| s.carrier()).collect();
    let h = Hyper { weight_decay: 0.0, precond_freq: 3, ..Hyper::default() };
    for kind in [OptKind::Soap, OptKind::Shampoo] {
        let mut a = SerialExecutor::new_tensors(kind, &h, &tshapes);
        let mut rng = Rng::new(19);
        let mut params: Vec<Matrix> =
            shapes.iter().map(|&(m, n)| Matrix::randn(&mut rng, m, n, 1.0)).collect();
        for t in 1..=5u64 {
            let grads: Vec<Matrix> =
                shapes.iter().map(|&(m, n)| Matrix::randn(&mut rng, m, n, 1.0)).collect();
            a.step(None, &mut params, &grads, t, 0.01).unwrap();
        }
        let state = a.export_state().unwrap();
        let mut b = ShardedOptimizer::new_tensors(kind, &h, &tshapes, 3);
        b.import_state(state).unwrap();
        let mut pa = params.clone();
        let mut pb = params;
        for t in 6..=9u64 {
            let grads: Vec<Matrix> =
                shapes.iter().map(|&(m, n)| Matrix::randn(&mut rng, m, n, 1.0)).collect();
            ExecutorBackend::step(&mut a, None, &mut pa, &grads, t, 0.01).unwrap();
            b.step(&mut pb, &grads, t, 0.01);
        }
        for (x, y) in pa.iter().zip(&pb) {
            assert_eq!(x.data, y.data, "{}: rank-3 state transfer drifted", kind.name());
        }
    }
}

fn conv_session(backend: Backend, opt: &str, steps: u64) -> TrainSession {
    TrainSession::builder()
        .model(ModelSpec::parse("nplm-conv").unwrap())
        .optimizer(OptKind::parse(opt).unwrap())
        .hyper(Hyper { weight_decay: 0.0, precond_freq: 4, ..Hyper::default() })
        .schedule(Schedule::Constant { lr: 0.01 })
        .steps(steps)
        .workers(3)
        .backend(backend)
        .build()
        .unwrap()
}

/// The acceptance bar, part 1: a rank-3 parameter trains end-to-end through
/// the serial AND sharded backends, bitwise-identically.
#[test]
fn rank3_conv_model_serial_matches_sharded_bitwise() {
    let mut serial = conv_session(Backend::Serial, "soap", 8);
    let mut sharded = conv_session(Backend::Sharded, "soap", 8);
    // The conv model really does declare a rank-3 W1.
    assert_eq!(serial.tensor_shapes[1].dims(), &[3, 12, 24]);
    let log_a = serial.run().unwrap();
    let log_b = sharded.run().unwrap();
    assert!(log_a.final_loss().is_finite());
    for (i, (a, b)) in serial.params.iter().zip(&sharded.params).enumerate() {
        assert_eq!(a.data, b.data, "param {i}: sharded diverged from serial on rank-3");
    }
    // Identical data + identical layers ⇒ identical losses too.
    for ((sa, la), (sb, lb)) in log_a.losses.iter().zip(&log_b.losses) {
        assert_eq!((sa, la), (sb, lb));
    }
    // SOAP actually preconditions the rank-3 layer (per-mode factors carry
    // state an AdamW layer would not have).
    let mut adam = conv_session(Backend::Serial, "adamw", 1);
    adam.run().unwrap();
    assert!(
        serial.state_bytes() > adam.state_bytes(),
        "rank-3 SOAP should hold per-mode factor state beyond AdamW's moments"
    );
}

/// The acceptance bar, part 2: checkpoint/resume on the rank-3 model is
/// bitwise-identical to the uninterrupted run — inline and drained-async.
#[test]
fn rank3_conv_checkpoint_resume_bitwise() {
    for asynchronous in [false, true] {
        let build = |steps: u64| {
            let mut h = Hyper { weight_decay: 0.0, precond_freq: 4, ..Hyper::default() };
            if asynchronous {
                h = h.async_refresh();
            }
            TrainSession::builder()
                .model(ModelSpec::parse("nplm-conv").unwrap())
                .optimizer(OptKind::Soap)
                .hyper(h)
                .schedule(Schedule::Constant { lr: 0.01 })
                .steps(steps)
                .backend(Backend::Serial)
                .drain_refresh_each_step(asynchronous)
                .build()
                .unwrap()
        };
        // Uninterrupted: 12 straight steps.
        let mut full = build(12);
        full.run().unwrap();
        // Interrupted: 6 steps, checkpoint to disk, resume, 6 more.
        let path = tmpfile(&format!("resume_{asynchronous}"));
        let mut first = build(12);
        while first.current_step() < 6 {
            first.step().unwrap();
        }
        first.save_checkpoint(&path).unwrap();
        drop(first);
        let mut h = Hyper { weight_decay: 0.0, precond_freq: 4, ..Hyper::default() };
        if asynchronous {
            h = h.async_refresh();
        }
        let mut resumed = TrainSession::builder()
            .model(ModelSpec::parse("nplm-conv").unwrap())
            .optimizer(OptKind::Soap)
            .hyper(h)
            .schedule(Schedule::Constant { lr: 0.01 })
            .steps(12)
            .backend(Backend::Serial)
            .drain_refresh_each_step(asynchronous)
            .resume_from(&path)
            .build()
            .unwrap();
        assert_eq!(resumed.current_step(), 6);
        resumed.run().unwrap();
        std::fs::remove_file(&path).ok();
        for (i, (a, b)) in full.params.iter().zip(&resumed.params).enumerate() {
            assert_eq!(
                a.data, b.data,
                "param {i} (async={asynchronous}): resume diverged from uninterrupted"
            );
        }
    }
}

/// Resuming the rank-3 checkpoint into a model that declares W1 as a matrix
/// must be rejected (the v3 shape record disagrees) — not silently
/// re-preconditioned.
#[test]
fn rank3_checkpoint_rejected_by_matrix_model() {
    let path = tmpfile("shape_mismatch");
    let mut conv = conv_session(Backend::Serial, "soap", 6);
    while conv.current_step() < 3 {
        conv.step().unwrap();
    }
    conv.save_checkpoint(&path).unwrap();
    let err = TrainSession::builder()
        .model(ModelSpec::parse("nplm-tiny").unwrap()) // same carriers, rank-2 W1
        .optimizer(OptKind::Soap)
        .hyper(Hyper { weight_decay: 0.0, precond_freq: 4, ..Hyper::default() })
        .schedule(Schedule::Constant { lr: 0.01 })
        .steps(6)
        .backend(Backend::Serial)
        .resume_from(&path)
        .build()
        .map(|_| ())
        .unwrap_err()
        .to_string();
    std::fs::remove_file(&path).ok();
    assert!(err.contains("tensor shape"), "{err}");
}

/// Other presets and the composition grammar also run the rank-3 model
/// end-to-end: tensor Shampoo (per-mode inverse roots + grafting) and the
/// factorized eigen×adafactor spec.
#[test]
fn rank3_conv_other_optimizers_train() {
    for opt in ["shampoo", "basis=eigen,inner=adafactor", "adamw", "adafactor"] {
        let mut s = conv_session(Backend::Sharded, opt, 6);
        let log = s.run().unwrap();
        assert!(
            log.final_loss().is_finite(),
            "{opt}: non-finite loss on the rank-3 model"
        );
        // And the state is checkpoint-complete: a fresh session resumes it.
        let ck = s.checkpoint().unwrap();
        let mut t = TrainSession::builder()
            .model(ModelSpec::parse("nplm-conv").unwrap())
            .optimizer(OptKind::parse(opt).unwrap())
            .hyper(Hyper { weight_decay: 0.0, precond_freq: 4, ..Hyper::default() })
            .schedule(Schedule::Constant { lr: 0.01 })
            .steps(8)
            .workers(3)
            .backend(Backend::Sharded)
            .resume_checkpoint(ck)
            .build()
            .unwrap();
        assert_eq!(t.current_step(), 6);
        t.run().unwrap();
    }
}
