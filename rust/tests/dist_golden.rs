//! Distributed-backend acceptance pins (the PR-7 tentpole):
//!
//! 1. an N-rank distributed run (mem transport, one thread per rank) is
//!    BITWISE-identical — params and loss trajectory — to the serial
//!    backend for adamw/soap/shampoo, at 2 and 4 ranks, with the batch's
//!    microbatches genuinely split across ranks;
//! 2. the same holds in drained-async refresh mode (the service runs, the
//!    step drains it, ownership broadcast happens post-step);
//! 3. checkpoints cross backends: distributed rank 0's checkpoint resumes
//!    on serial, a serial checkpoint resumes on distributed, and both
//!    match the uninterrupted serial run bitwise;
//! 4. eigenbasis refreshes are genuinely DISTRIBUTED: the per-rank health
//!    rows gathered on the metrics cadence show every rank owning layers
//!    and a non-zero rank publishing refreshes.
//!
//! Everything here uses the in-process mem transport; the separate
//! `dist_proc` test exercises the TCP + multi-process path through the CLI.

use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use soap_lab::dist::{MemCluster, Transport};
use soap_lab::model::NplmConfig;
use soap_lab::optim::{Hyper, OptKind, RefreshMode, Schedule};
use soap_lab::session::{
    Backend, DistEndpoint, DistOptions, HealthSnapshot, MetricsSink, ModelSpec, SessionBuilder,
    StepRecord, TrainSession,
};

const SEQ: usize = 24;
const BATCH: usize = 8;
const ACCUM: usize = 4;

fn nplm() -> NplmConfig {
    NplmConfig { vocab: 64, context: 3, dim: 12, hidden: 24, conv: false }
}

fn hyper(mode: RefreshMode) -> Hyper {
    Hyper { precond_freq: 4, ..Hyper::default() }.with_refresh_mode(mode)
}

fn builder(opt: OptKind, steps: u64, seed: u64, mode: RefreshMode) -> SessionBuilder {
    TrainSession::builder()
        .model(ModelSpec::nplm(nplm(), SEQ, BATCH))
        .optimizer(opt)
        .hyper(hyper(mode))
        .schedule(Schedule::Constant { lr: 0.02 })
        .steps(steps)
        .seed(seed)
        .grad_accum(ACCUM)
        .workers(2)
        .drain_refresh_each_step(mode == RefreshMode::Async)
}

/// What one rank's thread hands back for comparison.
struct RankRun {
    rank: usize,
    params: Vec<Vec<f32>>,
    losses: Vec<(u64, f32)>,
}

/// Run an N-rank distributed session over the mem transport, one thread per
/// rank. `save` makes rank 0 write a checkpoint after its run; `resume`
/// makes every rank restore from it first. `customize` runs on each rank's
/// builder (telemetry, sinks, …) right before `build()`.
fn dist_run<F>(
    opt: OptKind,
    steps: u64,
    seed: u64,
    mode: RefreshMode,
    ranks: usize,
    save: Option<PathBuf>,
    resume: Option<PathBuf>,
    customize: F,
) -> Vec<RankRun>
where
    F: Fn(usize, SessionBuilder) -> SessionBuilder + Send + Sync + 'static,
{
    let customize = Arc::new(customize);
    let endpoints = MemCluster::new(ranks);
    let mut handles = Vec::new();
    for (rank, ep) in endpoints.into_iter().enumerate() {
        let customize = Arc::clone(&customize);
        let save = save.clone();
        let resume = resume.clone();
        handles.push(std::thread::spawn(move || -> RankRun {
            let mut b = builder(opt, steps, seed, mode)
                .backend(Backend::Distributed { ranks, transport: Transport::Mem })
                .dist(DistOptions {
                    rank,
                    ranks,
                    timeout: Duration::from_secs(30),
                    endpoint: DistEndpoint::Mem(ep),
                });
            if let Some(path) = &resume {
                b = b.resume_from(path);
            }
            b = customize(rank, b);
            let mut session = b.build().unwrap_or_else(|e| panic!("rank {rank}: build: {e}"));
            let log = session.run().unwrap_or_else(|e| panic!("rank {rank}: run: {e}"));
            if rank == 0 {
                if let Some(path) = &save {
                    session.save_checkpoint(path).unwrap();
                }
            }
            RankRun {
                rank,
                params: session.params.iter().map(|m| m.data.clone()).collect(),
                losses: log.losses,
            }
        }));
    }
    let mut runs: Vec<RankRun> =
        handles.into_iter().map(|h| h.join().expect("rank thread panicked")).collect();
    runs.sort_by_key(|r| r.rank);
    runs
}

/// Every rank ends with identical replicated state; rank 0 speaks for all.
fn assert_ranks_agree(runs: &[RankRun], label: &str) {
    for r in &runs[1..] {
        assert_eq!(
            r.losses, runs[0].losses,
            "{label}: rank {} loss trajectory diverged from rank 0",
            r.rank
        );
        for (i, (a, b)) in r.params.iter().zip(&runs[0].params).enumerate() {
            assert_eq!(a, b, "{label}: rank {} param {i} diverged from rank 0", r.rank);
        }
    }
}

fn assert_matches_serial(
    runs: &[RankRun],
    serial: &TrainSession,
    losses: &[(u64, f32)],
    label: &str,
) {
    assert_eq!(runs[0].losses, losses, "{label}: distributed loss trajectory != serial");
    for (i, (a, b)) in runs[0].params.iter().zip(&serial.params).enumerate() {
        assert_eq!(a, &b.data, "{label}: distributed param {i} != serial");
    }
}

#[test]
fn distributed_matches_serial_bitwise_inline() {
    for opt in [OptKind::AdamW, OptKind::Soap, OptKind::Shampoo] {
        let mut serial =
            builder(opt, 12, 3, RefreshMode::Inline).backend(Backend::Serial).build().unwrap();
        let serial_log = serial.run().unwrap();
        for ranks in [2usize, 4] {
            let label = format!("{} x{ranks}", opt.name());
            let runs =
                dist_run(opt, 12, 3, RefreshMode::Inline, ranks, None, None, |_, b| b);
            assert_ranks_agree(&runs, &label);
            assert_matches_serial(&runs, &serial, &serial_log.losses, &label);
        }
    }
}

#[test]
fn distributed_matches_serial_bitwise_drained_async() {
    let mut serial =
        builder(OptKind::Soap, 12, 7, RefreshMode::Async).backend(Backend::Serial).build().unwrap();
    let serial_log = serial.run().unwrap();
    let runs = dist_run(OptKind::Soap, 12, 7, RefreshMode::Async, 2, None, None, |_, b| b);
    assert_ranks_agree(&runs, "soap async x2");
    assert_matches_serial(&runs, &serial, &serial_log.losses, "soap async x2");
}

#[test]
fn checkpoints_cross_backends_both_directions() {
    let n = 8u64;
    let seed = 11u64;
    // Uninterrupted serial reference.
    let mut full =
        builder(OptKind::Soap, 2 * n, seed, RefreshMode::Inline).backend(Backend::Serial).build().unwrap();
    full.run().unwrap();
    let pid = std::process::id();

    // distributed → serial.
    let d2s = std::env::temp_dir().join(format!("soap_dist_golden_d2s_{pid}.ckpt"));
    dist_run(OptKind::Soap, n, seed, RefreshMode::Inline, 2, Some(d2s.clone()), None, |_, b| b);
    let mut resumed = builder(OptKind::Soap, 2 * n, seed, RefreshMode::Inline)
        .backend(Backend::Serial)
        .resume_from(&d2s)
        .build()
        .unwrap();
    std::fs::remove_file(&d2s).ok();
    assert_eq!(resumed.current_step(), n, "distributed checkpoint lost the step counter");
    resumed.run().unwrap();
    for (i, (a, b)) in resumed.params.iter().zip(&full.params).enumerate() {
        assert_eq!(a.data, b.data, "dist→serial resume: param {i} != uninterrupted serial");
    }

    // serial → distributed.
    let s2d = std::env::temp_dir().join(format!("soap_dist_golden_s2d_{pid}.ckpt"));
    let mut first =
        builder(OptKind::Soap, n, seed, RefreshMode::Inline).backend(Backend::Serial).build().unwrap();
    first.run().unwrap();
    first.save_checkpoint(&s2d).unwrap();
    let runs = dist_run(
        OptKind::Soap,
        2 * n,
        seed,
        RefreshMode::Inline,
        2,
        None,
        Some(s2d.clone()),
        |_, b| b,
    );
    std::fs::remove_file(&s2d).ok();
    assert_ranks_agree(&runs, "serial→dist resume");
    for (i, (a, b)) in runs[0].params.iter().zip(&full.params).enumerate() {
        assert_eq!(a, &b.data, "serial→dist resume: param {i} != uninterrupted serial");
    }
}

/// Forwards health snapshots out of the boxed-sink seam (sinks are owned by
/// the session; the Arc lets the test read them after the threads join).
struct ShareSink {
    health: Arc<Mutex<Vec<HealthSnapshot>>>,
}

impl MetricsSink for ShareSink {
    fn on_step(&mut self, _rec: &StepRecord<'_>) {}

    fn on_health(&mut self, h: &HealthSnapshot) {
        self.health.lock().unwrap().push(h.clone());
    }
}

#[test]
fn refresh_ownership_is_distributed_across_ranks() {
    let _g = soap_lab::telemetry::trace::test_lock();
    soap_lab::telemetry::trace::drain();
    let health = Arc::new(Mutex::new(Vec::new()));
    let shared = Arc::clone(&health);
    let runs = dist_run(
        OptKind::Soap,
        12,
        5,
        RefreshMode::Inline,
        2,
        None,
        None,
        move |rank, b| {
            // Telemetry is process-global, so every rank-thread enables it;
            // only rank 0 gets the sink (it is the gather root).
            let b = b.telemetry(true).metrics_every(6);
            if rank == 0 {
                b.sink(Box::new(ShareSink { health: Arc::clone(&shared) }))
            } else {
                b
            }
        },
    );
    soap_lab::telemetry::set_enabled(false);
    soap_lab::telemetry::trace::drain();
    assert_ranks_agree(&runs, "soap telemetry x2");

    let snaps = health.lock().unwrap();
    assert!(!snaps.is_empty(), "rank 0 sink saw no health snapshots");
    let last = snaps.last().unwrap();
    assert_eq!(last.ranks.len(), 2, "health gather missed a rank row");
    for row in &last.ranks {
        assert!(row.owned_layers > 0, "rank {} owns no layers", row.rank);
        assert!(row.frames_sent > 0, "rank {} sent no frames", row.rank);
        assert!(row.bytes_recv > 0, "rank {} received no bytes", row.rank);
    }
    // The point of ownership: refreshes actually execute off rank 0. With
    // f=4 and 12 steps every owned layer published at least twice.
    let nonzero = last.ranks.iter().find(|r| r.rank != 0).unwrap();
    assert!(
        nonzero.owned_refreshes > 0,
        "rank {} owns {} layers but published no refreshes",
        nonzero.rank,
        nonzero.owned_layers
    );
    // Grad norms survive the distributed path (no fake zeros).
    assert!(last.layers.iter().all(|l| l.grad_norm.unwrap_or(0.0) > 0.0));
}
