//! Session-API acceptance pins (the PR-4 tentpole):
//!
//! 1. a `TrainSession` run is BITWISE-identical (params + loss trajectory)
//!    to the equivalent pre-redesign `Trainer` run, for adamw/soap/shampoo
//!    on both native backends (serial and sharded);
//! 2. checkpoint→resume through the session API matches the uninterrupted
//!    run bitwise — N steps + checkpoint + resume to 2N ≡ 2N straight —
//!    for one preset per family, in inline AND drained-async refresh modes,
//!    through the v2 checkpoint file format;
//! 3. resume is strict: wrong seed, wrong shapes, and an exhausted step
//!    budget are errors, not silent divergence.

use soap_lab::coordinator::{Trainer, TrainerConfig};
use soap_lab::model::NplmConfig;
use soap_lab::optim::{Hyper, OptKind, RefreshMode, Schedule};
use soap_lab::session::{Backend, ModelSpec, SessionBuilder, TrainSession};

const SEQ: usize = 24;
const BATCH: usize = 8;

fn nplm() -> NplmConfig {
    NplmConfig { vocab: 64, context: 3, dim: 12, hidden: 24, conv: false }
}

fn hyper(mode: RefreshMode) -> Hyper {
    Hyper { precond_freq: 4, ..Hyper::default() }.with_refresh_mode(mode)
}

fn builder(opt: OptKind, steps: u64, seed: u64, mode: RefreshMode) -> SessionBuilder {
    TrainSession::builder()
        .model(ModelSpec::nplm(nplm(), SEQ, BATCH))
        .optimizer(opt)
        .hyper(hyper(mode))
        .schedule(Schedule::Constant { lr: 0.02 })
        .steps(steps)
        .seed(seed)
        .workers(2)
        .drain_refresh_each_step(mode == RefreshMode::Async)
}

fn legacy_trainer(opt: OptKind, steps: u64, seed: u64) -> Trainer {
    let cfg = TrainerConfig {
        opt,
        hyper: hyper(RefreshMode::Inline),
        schedule: Schedule::Constant { lr: 0.02 },
        steps,
        seed,
        grad_accum: 1,
        workers: 2,
        log_every: 0,
        vocab: 64,
        zipf_alpha: 1.2,
    };
    Trainer::new_native(nplm(), cfg, SEQ, BATCH)
}

#[test]
fn session_matches_legacy_trainer_bitwise() {
    // Acceptance: the redesign changed the API, not one bit of the math.
    for opt in [OptKind::AdamW, OptKind::Soap, OptKind::Shampoo] {
        let mut trainer = legacy_trainer(opt, 20, 3);
        let trainer_log = trainer.run().unwrap();

        for backend in [Backend::Serial, Backend::Sharded] {
            let mut session = builder(opt, 20, 3, RefreshMode::Inline)
                .backend(backend)
                .build()
                .unwrap();
            let log = session.run().unwrap();
            assert_eq!(
                log.losses, trainer_log.losses,
                "{} on {:?}: session loss trajectory diverged from Trainer",
                opt.name(),
                backend
            );
            for (i, (a, b)) in session.params.iter().zip(&trainer.params).enumerate() {
                assert_eq!(
                    a.data,
                    b.data,
                    "{} on {:?}: session param {i} diverged from Trainer",
                    opt.name(),
                    backend
                );
            }
            assert_eq!(session.state_bytes(), trainer.state_bytes());
        }
    }
}

fn resume_roundtrip(opt: OptKind, mode: RefreshMode, backend: Backend, seed: u64) {
    let n = 12u64;
    let label = format!("{} {:?} {:?}", opt.name(), mode, backend);

    // Uninterrupted 2N-step reference.
    let mut full = builder(opt, 2 * n, seed, mode).backend(backend).build().unwrap();
    let full_log = full.run().unwrap();

    // N steps → checkpoint through the v2 file format → resume → N more.
    let mut first = builder(opt, n, seed, mode).backend(backend).build().unwrap();
    first.run().unwrap();
    let path = std::env::temp_dir().join(format!(
        "soap_session_resume_{}_{}_{}.ckpt",
        opt.name(),
        seed,
        std::process::id()
    ));
    first.save_checkpoint(&path).unwrap();

    let mut resumed = builder(opt, 2 * n, seed, mode)
        .backend(backend)
        .resume_from(&path)
        .build()
        .unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(resumed.current_step(), n, "{label}: resume did not restore the step");
    let resumed_log = resumed.run().unwrap();
    assert_eq!(resumed.current_step(), 2 * n);

    // Bitwise: parameters and the post-resume loss trajectory.
    for (i, (a, b)) in resumed.params.iter().zip(&full.params).enumerate() {
        assert_eq!(a.data, b.data, "{label}: resumed param {i} diverged from uninterrupted");
    }
    assert_eq!(
        resumed_log.losses,
        full_log.losses[n as usize..].to_vec(),
        "{label}: resumed losses diverged (schedule step or data cursor drift)"
    );

    // The optimizer state itself must also agree (moments, bases, caches).
    let full_state = full.checkpoint().unwrap();
    let resumed_state = resumed.checkpoint().unwrap();
    assert_eq!(full_state.opt_state.len(), resumed_state.opt_state.len());
    for ((ia, ta), (ib, tb)) in full_state.opt_state.iter().zip(&resumed_state.opt_state) {
        assert_eq!(ia, ib);
        assert_eq!(ta.len(), tb.len(), "{label}: state row {ia} arity changed");
        for (j, (x, y)) in ta.iter().zip(tb).enumerate() {
            assert_eq!(x.data, y.data, "{label}: state row {ia} tensor {j} diverged");
        }
    }
}

#[test]
fn resume_bitwise_inline_adamw() {
    resume_roundtrip(OptKind::AdamW, RefreshMode::Inline, Backend::Serial, 11);
}

#[test]
fn resume_bitwise_inline_soap() {
    resume_roundtrip(OptKind::Soap, RefreshMode::Inline, Backend::Sharded, 12);
}

#[test]
fn resume_bitwise_inline_shampoo() {
    // Pins the warm-start eigenvector caches riding the checkpoint: without
    // them the first post-resume refresh cold-starts its eigh and drifts.
    resume_roundtrip(OptKind::Shampoo, RefreshMode::Inline, Backend::Sharded, 13);
}

#[test]
fn resume_bitwise_drained_async_adamw() {
    // AdamW has nothing to refresh — drained-async degenerates to inline,
    // and the checkpoint path must not trip over the absent service.
    resume_roundtrip(OptKind::AdamW, RefreshMode::Async, Backend::Sharded, 14);
}

#[test]
fn resume_bitwise_drained_async_soap() {
    resume_roundtrip(OptKind::Soap, RefreshMode::Async, Backend::Sharded, 15);
}

#[test]
fn resume_bitwise_drained_async_shampoo() {
    resume_roundtrip(OptKind::Shampoo, RefreshMode::Async, Backend::Serial, 16);
}

#[test]
fn resume_rejects_wrong_seed() {
    let mut first = builder(OptKind::AdamW, 4, 21, RefreshMode::Inline).build().unwrap();
    first.run().unwrap();
    let ck = first.checkpoint().unwrap();
    let err = builder(OptKind::AdamW, 8, 22, RefreshMode::Inline)
        .resume_checkpoint(ck)
        .build()
        .err()
        .expect("seed mismatch must be rejected")
        .to_string();
    assert!(err.contains("seed"), "{err}");
}

#[test]
fn resume_rejects_exhausted_budget_and_wrong_shapes() {
    let mut first = builder(OptKind::AdamW, 6, 23, RefreshMode::Inline).build().unwrap();
    first.run().unwrap();
    let ck = first.checkpoint().unwrap();
    // Budget already spent: steps(4) < checkpoint step 6.
    let err = builder(OptKind::AdamW, 4, 23, RefreshMode::Inline)
        .resume_checkpoint(ck)
        .build()
        .err()
        .expect("exhausted budget must be rejected")
        .to_string();
    assert!(err.contains("budget") || err.contains("steps"), "{err}");

    // Different model geometry: shape mismatch is an error, not garbage.
    let mut first = builder(OptKind::AdamW, 3, 24, RefreshMode::Inline).build().unwrap();
    first.run().unwrap();
    let ck = first.checkpoint().unwrap();
    let other = NplmConfig { vocab: 64, context: 3, dim: 16, hidden: 24, conv: false };
    let err = TrainSession::builder()
        .model(ModelSpec::nplm(other, SEQ, BATCH))
        .optimizer(OptKind::AdamW)
        .steps(6)
        .seed(24)
        .resume_checkpoint(ck)
        .build()
        .err()
        .expect("shape mismatch must be rejected")
        .to_string();
    assert!(err.contains("×") || err.contains("param"), "{err}");
}

#[test]
fn resume_rejects_changed_data_geometry() {
    // The cursor counts stream batches of (batch × grad-accum) rows; a
    // different grad-accum on resume would fast-forward to the wrong
    // tokens. Strict: rejected, not silently divergent.
    let mut first = builder(OptKind::AdamW, 4, 25, RefreshMode::Inline)
        .grad_accum(2)
        .build()
        .unwrap();
    first.run().unwrap();
    let ck = first.checkpoint().unwrap();
    assert_eq!(ck.stream_batch as usize, BATCH * 2);
    let err = builder(OptKind::AdamW, 8, 25, RefreshMode::Inline)
        .resume_checkpoint(ck)
        .build()
        .err()
        .expect("geometry mismatch must be rejected")
        .to_string();
    assert!(err.contains("geometry") || err.contains("grad-accum"), "{err}");
}

#[test]
fn composed_spec_session_trains_and_resumes() {
    // The builder is spec-transparent: a novel basis×engine combo trains
    // and checkpoints through the same path as the presets.
    let spec = OptKind::parse("basis=eigen:one-sided,inner=adafactor").unwrap();
    resume_roundtrip(spec, RefreshMode::Inline, Backend::Sharded, 31);
}

#[test]
fn session_learns_on_soap() {
    let mut session = builder(OptKind::Soap, 150, 1, RefreshMode::Inline).build().unwrap();
    let log = session.run().unwrap();
    assert!(
        log.tail_loss(10) < log.losses[0].1 - 0.4,
        "SOAP did not learn through the session API: {} → {}",
        log.losses[0].1,
        log.tail_loss(10)
    );
}
