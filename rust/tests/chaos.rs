//! Chaos suite: seeded fault injection end-to-end (the PR-8 tentpole).
//!
//! 1. **Frame faults are transparent**: a 2-rank mem-transport run under
//!    injected drops/dups/delays completes and is BITWISE-identical to the
//!    faults-off run — the retry/dedup machinery delivers every frame
//!    exactly once — while `soap_transport_retries_total` and
//!    `soap_fault_injected_total` prove the faults actually fired.
//! 2. **One bad batch costs one step**: a NaN gradient injected at the last
//!    step under the default skip-step guard leaves params + optimizer
//!    state bitwise equal to a clean run that stopped one step earlier.
//! 3. **Stale-basis grace**: a poisoned eigh refresh is rejected, the
//!    previous basis stays active (paper §1/Fig. 1), and the run completes
//!    with finite loss.
//! 4. **Abort policy**: an injected Inf gradient under `guard=abort`
//!    surfaces a typed error instead of corrupting state.
//! 5. **Backoff property**: `backoff_delay` is bounded by its cap and
//!    monotone nondecreasing in the attempt number for any seed.
//!
//! Fault installation is process-global, so every test that arms a plan
//! holds `CHAOS_LOCK` and clears the plan before releasing it.

use std::sync::Mutex;
use std::time::Duration;

use soap_lab::coordinator::Checkpoint;
use soap_lab::dist::{MemCluster, Transport};
use soap_lab::model::NplmConfig;
use soap_lab::optim::{GuardPolicy, Hyper, OptKind, Schedule};
use soap_lab::session::{
    Backend, DistEndpoint, DistOptions, ModelSpec, SessionBuilder, TrainSession,
};

static CHAOS_LOCK: Mutex<()> = Mutex::new(());

const SEQ: usize = 24;
const BATCH: usize = 8;

fn builder(steps: u64) -> SessionBuilder {
    let nplm = NplmConfig { vocab: 64, context: 3, dim: 12, hidden: 24, conv: false };
    TrainSession::builder()
        .model(ModelSpec::nplm(nplm, SEQ, BATCH))
        .optimizer(OptKind::Soap)
        .hyper(Hyper { precond_freq: 4, ..Hyper::default() })
        .schedule(Schedule::Constant { lr: 0.02 })
        .steps(steps)
        .seed(9)
        .grad_accum(2)
        .workers(2)
        .backend(Backend::Serial)
}

/// Run a 2-rank mem-transport session (one thread per rank), optionally
/// under a fault plan; returns rank 0's `(params, losses)`.
fn dist_pair(steps: u64, plan: Option<&'static str>) -> (Vec<Vec<f32>>, Vec<(u64, f32)>) {
    let ranks = 2;
    let endpoints = MemCluster::new(ranks);
    let mut handles = Vec::new();
    for (rank, ep) in endpoints.into_iter().enumerate() {
        handles.push(std::thread::spawn(move || {
            let mut b = builder(steps)
                .backend(Backend::Distributed { ranks, transport: Transport::Mem })
                .dist(DistOptions {
                    rank,
                    ranks,
                    timeout: Duration::from_secs(30),
                    endpoint: DistEndpoint::Mem(ep),
                });
            if let Some(plan) = plan {
                b = b.fault_plan(plan, 0);
            }
            let mut session = b.build().unwrap_or_else(|e| panic!("rank {rank}: build: {e}"));
            let log = session.run().unwrap_or_else(|e| panic!("rank {rank}: run: {e}"));
            let params: Vec<Vec<f32>> = session.params.iter().map(|m| m.data.clone()).collect();
            (rank, params, log.losses)
        }));
    }
    let mut runs: Vec<_> =
        handles.into_iter().map(|h| h.join().expect("rank thread panicked")).collect();
    runs.sort_by_key(|r| r.0);
    let (_, params, losses) = runs.swap_remove(0);
    (params, losses)
}

#[test]
fn frame_faults_are_recoverable_and_bitwise_transparent() {
    let _g = CHAOS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let clean = dist_pair(10, None);
    let injected_before = soap_lab::telemetry::metrics::fault_injected_total().get();
    let retries_before = soap_lab::telemetry::metrics::transport_retries_total().get();
    let faulted =
        dist_pair(10, Some("seed=7;drop-frame=0.25;dup-frame=0.25;delay-frame=0.1:1"));
    let injected = soap_lab::telemetry::metrics::fault_injected_total().get() - injected_before;
    let retries = soap_lab::telemetry::metrics::transport_retries_total().get() - retries_before;
    soap_lab::fault::clear();
    assert!(injected > 0, "fault plan armed but nothing fired");
    assert!(retries > 0, "injected drops must show up as transport retries");
    assert_eq!(faulted.1, clean.1, "loss trajectory changed under recoverable frame faults");
    for (i, (a, b)) in faulted.0.iter().zip(&clean.0).enumerate() {
        assert_eq!(a, b, "param {i} diverged under recoverable frame faults");
    }
}

#[test]
fn nan_grad_skip_step_costs_exactly_one_step() {
    let _g = CHAOS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let pid = std::process::id();
    let k = 8u64;

    // Clean run stopping one step short of the fault.
    let short = std::env::temp_dir().join(format!("soap_chaos_short_{pid}.ckpt"));
    let mut session = builder(k - 1).build().unwrap();
    session.run().unwrap();
    session.save_checkpoint(&short).unwrap();
    drop(session);

    // Faulted run: NaN injected into layer 0's gradient at step k; the
    // default skip-step guard must bypass the optimizer entirely.
    let skipped_before = soap_lab::telemetry::metrics::step_skipped_total().get();
    let full = std::env::temp_dir().join(format!("soap_chaos_full_{pid}.ckpt"));
    let mut session = builder(k).fault_plan(&format!("nan-grad=0:{k}"), 0).build().unwrap();
    session.run().unwrap();
    session.save_checkpoint(&full).unwrap();
    drop(session);
    let skipped = soap_lab::telemetry::metrics::step_skipped_total().get() - skipped_before;
    soap_lab::fault::clear();
    assert_eq!(skipped, 1, "exactly one step should have been skipped");

    let a = Checkpoint::load(&short).unwrap();
    let b = Checkpoint::load(&full).unwrap();
    std::fs::remove_file(&short).ok();
    std::fs::remove_file(&full).ok();
    // Step counter and data cursor differ (batch k was drawn but never
    // applied); params and optimizer state must match bitwise.
    assert_eq!(a.step, k - 1);
    assert_eq!(b.step, k);
    assert_eq!(a.params.len(), b.params.len());
    for (i, (pa, pb)) in a.params.iter().zip(&b.params).enumerate() {
        assert_eq!(pa.data, pb.data, "param {i}: skipped step leaked into the weights");
    }
    assert_eq!(a.opt_state.len(), b.opt_state.len());
    for ((la, ta), (lb, tb)) in a.opt_state.iter().zip(&b.opt_state) {
        assert_eq!(la, lb);
        assert_eq!(ta.len(), tb.len(), "layer {la}: optimizer state tensor count changed");
        for (j, (ma, mb)) in ta.iter().zip(tb).enumerate() {
            assert_eq!(ma.data, mb.data, "layer {la} state tensor {j} touched by skipped step");
        }
    }
}

#[test]
fn poisoned_eigh_is_rejected_and_stale_basis_carries_the_run() {
    let _g = CHAOS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let rejected_before = soap_lab::telemetry::metrics::basis_rejected_total().get();
    let injected_before = soap_lab::telemetry::metrics::fault_injected_total().get();
    let mut session = builder(12).fault_plan("eigh-fail=0:8", 0).build().unwrap();
    let log = session.run().unwrap();
    let rejected = soap_lab::telemetry::metrics::basis_rejected_total().get() - rejected_before;
    let injected = soap_lab::telemetry::metrics::fault_injected_total().get() - injected_before;
    soap_lab::fault::clear();
    assert!(injected >= 1, "eigh-fail clause never fired");
    assert!(rejected >= 1, "poisoned refresh was not rejected");
    let (_, last) = *log.losses.last().unwrap();
    assert!(last.is_finite(), "run diverged despite basis rejection: loss {last}");
}

#[test]
fn abort_guard_surfaces_typed_error() {
    let _g = CHAOS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let mut session = builder(6)
        .hyper(Hyper { precond_freq: 4, ..Hyper::default() }.with_guard(GuardPolicy::Abort))
        .fault_plan("inf-grad=0:3", 0)
        .build()
        .unwrap();
    let err = session.run().unwrap_err();
    soap_lab::fault::clear();
    let msg = format!("{err:#}");
    assert!(msg.contains("guard=abort") && msg.contains("step 3"), "{msg}");
}

#[test]
fn backoff_delay_is_bounded_and_monotone() {
    let base = Duration::from_micros(50);
    let cap = Duration::from_millis(5);
    for seed in 0..32u64 {
        let mut prev = Duration::ZERO;
        for attempt in 0..64u32 {
            let d = soap_lab::fault::backoff_delay(attempt, base, cap, seed);
            assert!(d <= cap, "seed {seed} attempt {attempt}: {d:?} exceeds cap {cap:?}");
            assert!(d >= base.min(cap), "seed {seed} attempt {attempt}: {d:?} under base");
            assert!(
                d >= prev,
                "seed {seed} attempt {attempt}: backoff not monotone ({prev:?} -> {d:?})"
            );
            prev = d;
        }
        assert_eq!(prev, cap, "seed {seed}: backoff never saturated at the cap");
    }
}
