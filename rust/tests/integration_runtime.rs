//! Cross-language integration: the HLO artifacts (jax/Pallas-lowered,
//! PJRT-executed) must agree with the native Rust implementations.
//!
//! Requires `make artifacts` (skips gracefully when artifacts/ is absent so
//! `cargo test` works in a fresh checkout).

use soap_lab::linalg::{power_iter_refresh, Matrix};
use soap_lab::optim::{Hyper, LayerOptimizer};
use soap_lab::runtime::{
    literal_from_matrix, literal_from_tokens, literal_scalar, matrix_from_literal,
    scalar_from_literal, Engine,
};
use soap_lab::util::rng::Rng;

fn engine() -> Option<Engine> {
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping runtime integration tests: run `make artifacts`");
        return None;
    }
    Some(Engine::load(dir).expect("engine"))
}

fn randm(rng: &mut Rng, m: usize, n: usize) -> Matrix {
    Matrix::randn(rng, m, n, 1.0)
}

#[test]
fn adamw_artifact_matches_native() {
    let Some(eng) = engine() else { return };
    let mut rng = Rng::new(100);
    let (m, n) = (64, 64);
    let w0 = randm(&mut rng, m, n);
    let g = randm(&mut rng, m, n);

    // Native step from zero state at t = 1.
    let h = Hyper::default();
    let mut native = soap_lab::optim::AdamW::new(m, n, h);
    let mut w_native = w0.clone();
    native.update(&mut w_native, &g, 1, 0.01);

    // Artifact step.
    let out = eng
        .run(
            "adamw_update_64x64",
            &[
                literal_from_matrix(&w0).unwrap(),
                literal_from_matrix(&Matrix::zeros(m, n)).unwrap(),
                literal_from_matrix(&Matrix::zeros(m, n)).unwrap(),
                literal_from_matrix(&g).unwrap(),
                literal_scalar(1.0),
                literal_scalar(0.01),
            ],
        )
        .unwrap();
    let w_art = matrix_from_literal(&out[0], m, n).unwrap();
    let diff = w_art.max_abs_diff(&w_native);
    assert!(diff < 1e-5, "adamw artifact vs native: {diff}");
}

#[test]
fn soap_artifact_matches_native_math() {
    let Some(eng) = engine() else { return };
    let mut rng = Rng::new(101);
    let (m, n) = (64, 64);
    let w0 = randm(&mut rng, m, n);
    let g = randm(&mut rng, m, n);
    let m0 = randm(&mut rng, m, n).scale(0.1);
    let v0 = randm(&mut rng, m, n).map(|x| x.abs());
    let l0 = Matrix::rand_psd(&mut rng, m);
    let r0 = Matrix::rand_psd(&mut rng, n);
    let (ql, _) = soap_lab::linalg::qr_positive(&randm(&mut rng, m, m));
    let (qr, _) = soap_lab::linalg::qr_positive(&randm(&mut rng, n, n));
    let t = 4.0f32;
    let lr = 0.02f32;
    let h = Hyper::default();

    // Native mirror of Algorithm 3 (same math as optim::Soap::update).
    let m_new = {
        let mut mm = m0.clone();
        mm.ema_inplace(&g, h.beta1);
        mm
    };
    let g_rot = ql.matmul_tn(&g).matmul(&qr);
    let m_rot = ql.matmul_tn(&m_new).matmul(&qr);
    let bc1 = 1.0 - h.beta1.powi(t as i32);
    let bc2 = 1.0 - h.beta2.powi(t as i32);
    let mut v_new = v0.clone();
    v_new.ema_inplace(&g_rot.hadamard(&g_rot), h.beta2);
    let n_rot = m_rot
        .scale(1.0 / bc1)
        .zip(&v_new, |mi, vi| mi / ((vi / bc2).max(0.0).sqrt() + h.eps));
    let n_dir = ql.matmul(&n_rot).matmul_nt(&qr);
    let mut w_native = w0.clone();
    w_native.axpy_inplace(-lr, &n_dir);
    w_native.scale_inplace(1.0 - lr * h.weight_decay);
    let mut l_new = l0.clone();
    l_new.ema_inplace(&g.matmul_nt(&g), h.shampoo_beta);

    let out = eng
        .run(
            "soap_update_64x64",
            &[
                literal_from_matrix(&w0).unwrap(),
                literal_from_matrix(&m0).unwrap(),
                literal_from_matrix(&v0).unwrap(),
                literal_from_matrix(&l0).unwrap(),
                literal_from_matrix(&r0).unwrap(),
                literal_from_matrix(&ql).unwrap(),
                literal_from_matrix(&qr).unwrap(),
                literal_from_matrix(&g).unwrap(),
                literal_scalar(t),
                literal_scalar(lr),
            ],
        )
        .unwrap();
    assert_eq!(out.len(), 5);
    let w_art = matrix_from_literal(&out[0], m, n).unwrap();
    let m_art = matrix_from_literal(&out[1], m, n).unwrap();
    let v_art = matrix_from_literal(&out[2], m, n).unwrap();
    let l_art = matrix_from_literal(&out[3], m, m).unwrap();
    assert!(w_art.max_abs_diff(&w_native) < 1e-4, "w: {}", w_art.max_abs_diff(&w_native));
    assert!(m_art.max_abs_diff(&m_new) < 1e-5);
    assert!(v_art.max_abs_diff(&v_new) < 1e-4);
    assert!(l_art.max_abs_diff(&l_new) < 1e-3);
}

#[test]
fn soap_refresh_artifact_matches_native_qr() {
    let Some(eng) = engine() else { return };
    let mut rng = Rng::new(102);
    let p = Matrix::rand_psd(&mut rng, 64);
    let (q0, _) = soap_lab::linalg::qr_positive(&randm(&mut rng, 64, 64));

    let native = power_iter_refresh(&p, &q0);
    let out = eng
        .run(
            "soap_refresh_64",
            &[literal_from_matrix(&p).unwrap(), literal_from_matrix(&q0).unwrap()],
        )
        .unwrap();
    let q_art = matrix_from_literal(&out[0], 64, 64).unwrap();

    // Both must be orthogonal and equal up to fp noise (same sign fix).
    let qtq = q_art.matmul_tn(&q_art);
    assert!(qtq.max_abs_diff(&Matrix::eye(64)) < 1e-3);
    assert!(
        q_art.max_abs_diff(&native) < 5e-2,
        "refresh mismatch: {}",
        q_art.max_abs_diff(&native)
    );
}

fn init_inputs(eng: &Engine, cfg_name: &str, seed: u64) -> (Vec<xla::Literal>, usize) {
    let cfg = eng.manifest.config(cfg_name).expect("config").clone();
    let mut rng = Rng::new(seed);
    let mut inputs = Vec::new();
    for (name, r, c) in &cfg.params {
        let m = if name.contains("ln") {
            Matrix::from_fn(*r, *c, |_, _| 1.0)
        } else {
            Matrix::randn(&mut rng, *r, *c, 1.0 / (*r as f32).sqrt())
        };
        inputs.push(literal_from_matrix(&m).unwrap());
    }
    let ntok = cfg.batch * cfg.seq;
    let tokens: Vec<u32> = (0..ntok).map(|_| rng.below(cfg.vocab as u64) as u32).collect();
    let targets: Vec<u32> = (0..ntok).map(|_| rng.below(cfg.vocab as u64) as u32).collect();
    inputs.push(literal_from_tokens(&tokens, cfg.batch, cfg.seq).unwrap());
    inputs.push(literal_from_tokens(&targets, cfg.batch, cfg.seq).unwrap());
    (inputs, cfg.params.len())
}

#[test]
fn lm_grads_artifact_runs_and_losses_sane() {
    let Some(eng) = engine() else { return };
    let cfg = eng.manifest.config("nano").expect("nano").clone();
    let (inputs, nparams) = init_inputs(&eng, "nano", 103);

    let out = eng.run("lm_grads_nano", &inputs).unwrap();
    assert_eq!(out.len(), 1 + nparams);
    let loss = scalar_from_literal(&out[0]).unwrap();
    let expect = (cfg.vocab as f32).ln();
    assert!(
        (loss - expect).abs() < 1.0,
        "init loss {loss} should be near ln V = {expect}"
    );
    // Gradients: finite, right shapes, not all zero.
    let mut total = 0.0f32;
    for (i, (_, r, c)) in cfg.params.iter().enumerate() {
        let gm = matrix_from_literal(&out[1 + i], *r, *c).unwrap();
        assert!(gm.data.iter().all(|x| x.is_finite()));
        total += gm.frob_norm();
    }
    assert!(total > 0.0);
}

#[test]
fn lm_loss_matches_lm_grads_loss() {
    let Some(eng) = engine() else { return };
    let (inputs, _) = init_inputs(&eng, "nano", 104);
    let l1 = scalar_from_literal(&eng.run("lm_loss_nano", &inputs).unwrap()[0]).unwrap();
    let l2 = scalar_from_literal(&eng.run("lm_grads_nano", &inputs).unwrap()[0]).unwrap();
    assert!((l1 - l2).abs() < 1e-5, "{l1} vs {l2}");
}
