//! Property tests over the linear-algebra substrate (proptest stand-in:
//! `soap_lab::util::prop`). These are the invariants the optimizer stack
//! leans on; shapes and contents are randomized per case.

use soap_lab::linalg::{
    eigh, eigh_warm, inv_root_eigh, power_iter_refresh, qr, qr_positive, roots::root_eigh, Matrix,
};
use soap_lab::util::prop::{self, ensure};

#[test]
fn prop_qr_orthogonal_and_reconstructs() {
    prop::check("qr: QᵀQ=I and QR=A", 40, |rng| {
        let n = 1 + rng.below(24) as usize;
        let a = Matrix::randn(rng, n, n, 1.0);
        let (q, r) = qr(&a);
        let qtq = q.matmul_tn(&q);
        ensure(
            qtq.max_abs_diff(&Matrix::eye(n)) < 2e-3,
            format!("QᵀQ err {}", qtq.max_abs_diff(&Matrix::eye(n))),
        )?;
        let rec = q.matmul(&r);
        ensure(
            rec.max_abs_diff(&a) < 2e-3 * (1.0 + a.max_abs()),
            format!("QR err {}", rec.max_abs_diff(&a)),
        )
    });
}

#[test]
fn prop_qr_positive_unique_diag() {
    prop::check("qr_positive: diag(R) ≥ 0", 40, |rng| {
        let n = 1 + rng.below(16) as usize;
        let a = Matrix::randn(rng, n, n, 1.0);
        let (_, r) = qr_positive(&a);
        for j in 0..n {
            ensure(r.at(j, j) >= -1e-5, format!("R[{j}][{j}] = {}", r.at(j, j)))?;
        }
        Ok(())
    });
}

#[test]
fn prop_eigh_reconstructs_psd() {
    prop::check("eigh: V diag(w) Vᵀ = A, w sorted desc", 30, |rng| {
        let n = 2 + rng.below(48) as usize;
        let a = Matrix::rand_psd(rng, n);
        let (w, v) = eigh(&a);
        for k in 1..n {
            ensure(w[k - 1] >= w[k] - 1e-4, "eigvals not descending")?;
        }
        let rec = soap_lab::linalg::eigh::reconstruct(&w, &v);
        ensure(
            rec.max_abs_diff(&a) < 5e-3 * (1.0 + a.max_abs()),
            format!("reconstruction err {}", rec.max_abs_diff(&a)),
        )?;
        let vtv = v.matmul_tn(&v);
        ensure(
            vtv.max_abs_diff(&Matrix::eye(n)) < 2e-3,
            format!("VᵀV err {}", vtv.max_abs_diff(&Matrix::eye(n))),
        )
    });
}

#[test]
fn prop_inv_root_inverts() {
    prop::check("inv_root: (A^{-1/p})^p · A ≈ I (well-conditioned)", 25, |rng| {
        let n = 2 + rng.below(12) as usize;
        // Well-conditioned PSD: eigenvalues in [0.5, ~2.5].
        let mut a = Matrix::rand_psd(rng, n);
        let tr = (a.trace() / n as f32).max(1e-6);
        a.scale_inplace(1.0 / tr);
        for i in 0..n {
            let v = a.at(i, i) + 0.5;
            a.set(i, i, v);
        }
        let p = [2.0f32, 4.0][rng.below(2) as usize];
        let r = inv_root_eigh(&a, p, 0.0);
        let mut acc = Matrix::eye(n);
        for _ in 0..p as usize {
            acc = acc.matmul(&r);
        }
        let check = acc.matmul(&a);
        ensure(
            check.max_abs_diff(&Matrix::eye(n)) < 0.05,
            format!("p={p} err {}", check.max_abs_diff(&Matrix::eye(n))),
        )
    });
}

#[test]
fn prop_root_and_inv_root_cancel() {
    prop::check("A^{1/p} · A^{-1/p} ≈ I", 25, |rng| {
        let n = 2 + rng.below(10) as usize;
        let mut a = Matrix::rand_psd(rng, n);
        for i in 0..n {
            let v = a.at(i, i) + 0.3;
            a.set(i, i, v);
        }
        let up = root_eigh(&a, 2.0, 0.0);
        let dn = inv_root_eigh(&a, 2.0, 0.0);
        let check = up.matmul(&dn);
        ensure(
            check.max_abs_diff(&Matrix::eye(n)) < 0.05,
            format!("err {}", check.max_abs_diff(&Matrix::eye(n))),
        )
    });
}

#[test]
fn prop_power_iter_refresh_orthonormal_on_spd() {
    // The async refresh service publishes exactly this product; the basis
    // the optimizer adopts must be orthonormal to ‖QᵀQ − I‖∞ < 1e-4 (the
    // precond invariant) for ANY SPD factor snapshot and warm-start basis.
    prop::check("refresh: ‖QᵀQ − I‖∞ < 1e-4 on random SPD", 40, |rng| {
        let n = 2 + rng.below(24) as usize;
        let p = Matrix::rand_psd(rng, n);
        let (q0, _) = qr_positive(&Matrix::randn(rng, n, n, 1.0));
        let q = power_iter_refresh(&p, &q0);
        let qtq = q.matmul_tn(&q);
        ensure(
            qtq.max_abs_diff(&Matrix::eye(n)) < 1e-4,
            format!("n={n}: ‖QᵀQ−I‖∞ = {}", qtq.max_abs_diff(&Matrix::eye(n))),
        )
    });
}

#[test]
fn prop_eigh_warm_orthonormal_on_spd() {
    // Warm-started eigh (the RefreshMethod::Eigh arm and Shampoo's root
    // recompute) must return an orthonormal eigenvector matrix even when the
    // warm-start basis comes from a perturbed earlier factor — the
    // refresh-over-EMA'd-factors situation.
    prop::check("eigh_warm: ‖VᵀV − I‖∞ < 1e-4 on random SPD", 30, |rng| {
        let n = 2 + rng.below(24) as usize;
        let p = Matrix::rand_psd(rng, n);
        let (_, v_prev) = eigh(&p);
        // Drift the factor the way the EMA does between refreshes.
        let p2 = p.add(&Matrix::rand_psd(rng, n).scale(0.05));
        let (_, v) = eigh_warm(&p2, &v_prev);
        let vtv = v.matmul_tn(&v);
        ensure(
            vtv.max_abs_diff(&Matrix::eye(n)) < 1e-4,
            format!("n={n}: ‖VᵀV−I‖∞ = {}", vtv.max_abs_diff(&Matrix::eye(n))),
        )
    });
}

#[test]
fn prop_power_iter_preserves_orthogonality() {
    prop::check("Alg 4 refresh: Q stays orthogonal under iteration", 25, |rng| {
        let n = 2 + rng.below(24) as usize;
        let p = Matrix::rand_psd(rng, n);
        let (mut q, _) = qr_positive(&Matrix::randn(rng, n, n, 1.0));
        for _ in 0..5 {
            q = power_iter_refresh(&p, &q);
        }
        let qtq = q.matmul_tn(&q);
        ensure(
            qtq.max_abs_diff(&Matrix::eye(n)) < 5e-3,
            format!("QᵀQ err {}", qtq.max_abs_diff(&Matrix::eye(n))),
        )
    });
}

#[test]
fn prop_power_iter_monotone_diagonalization() {
    prop::check("Alg 4 refresh reduces off-diagonal energy of QᵀPQ", 20, |rng| {
        let n = 3 + rng.below(12) as usize;
        // Distinct spectrum so convergence is strict.
        let (v, _) = qr_positive(&Matrix::randn(rng, n, n, 1.0));
        let d = Matrix::from_fn(n, n, |i, j| if i == j { (n - i) as f32 + 0.1 } else { 0.0 });
        let p = v.matmul(&d).matmul_nt(&v);
        let (q0, _) = qr_positive(&Matrix::randn(rng, n, n, 1.0));

        let off = |q: &Matrix| {
            let a = q.matmul_tn(&p.matmul(q));
            let mut s = 0.0f64;
            for i in 0..n {
                for j in 0..n {
                    if i != j {
                        s += (a.at(i, j) as f64).powi(2);
                    }
                }
            }
            s
        };
        let mut q = q0.clone();
        for _ in 0..10 {
            q = power_iter_refresh(&p, &q);
        }
        ensure(
            off(&q) <= off(&q0) + 1e-9,
            format!("off-diag grew: {} → {}", off(&q0), off(&q)),
        )
    });
}

#[test]
fn prop_gemm_matches_naive() {
    prop::check("gemm == naive f64 reference", 30, |rng| {
        let m = 1 + rng.below(40) as usize;
        let k = 1 + rng.below(40) as usize;
        let n = 1 + rng.below(40) as usize;
        let a = Matrix::randn(rng, m, k, 1.0);
        let b = Matrix::randn(rng, k, n, 1.0);
        let c = a.matmul(&b);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for p in 0..k {
                    acc += a.at(i, p) as f64 * b.at(p, j) as f64;
                }
                let got = c.at(i, j) as f64;
                if (got - acc).abs() > 1e-3 * (1.0 + acc.abs()) {
                    return Err(format!("({i},{j}): {got} vs {acc}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_transpose_contractions_consistent() {
    prop::check("matmul_tn/nt agree with explicit transpose", 30, |rng| {
        let m = 1 + rng.below(20) as usize;
        let k = 1 + rng.below(20) as usize;
        let n = 1 + rng.below(20) as usize;
        let a = Matrix::randn(rng, k, m, 1.0);
        let b = Matrix::randn(rng, k, n, 1.0);
        let tn = a.matmul_tn(&b);
        let want = a.t().matmul(&b);
        ensure(tn.max_abs_diff(&want) < 1e-3, "tn mismatch")?;
        let c = Matrix::randn(rng, m, k, 1.0);
        let d = Matrix::randn(rng, n, k, 1.0);
        let nt = c.matmul_nt(&d);
        let want = c.matmul(&d.t());
        ensure(nt.max_abs_diff(&want) < 1e-3, "nt mismatch")
    });
}

#[test]
fn prop_gemm_into_family_matches_f64_naive() {
    // f64-accumulated reference for `op(A)·op(B)`.
    fn naive(m: usize, k: usize, n: usize, a: &Matrix, b: &Matrix, ta: bool, tb: bool) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for p in 0..k {
                    let av = if ta { a.at(p, i) } else { a.at(i, p) };
                    let bv = if tb { b.at(j, p) } else { b.at(p, j) };
                    acc += av as f64 * bv as f64;
                }
                c[i * n + j] = acc as f32;
            }
        }
        c
    }
    prop::check("gemm `*_into` family matches the f64 naive reference", 60, |rng| {
        // Bias toward degenerate dims so 1×1, 1×n, tall and wide shapes all
        // appear alongside generic rectangles.
        fn dim(rng: &mut soap_lab::util::rng::Rng) -> usize {
            if rng.below(5) == 0 {
                1
            } else {
                1 + rng.below(28) as usize
            }
        }
        let (m, k, n) = (dim(rng), dim(rng), dim(rng));
        let a = Matrix::randn(rng, m, k, 1.0);
        let b = Matrix::randn(rng, k, n, 1.0);
        let at = Matrix::randn(rng, k, m, 1.0);
        let bt = Matrix::randn(rng, n, k, 1.0);
        // Dirty, wrongly-shaped out/pack buffers: the `*_into` kernels must
        // overwrite (never blend with) previous contents.
        let (dr, dc) = (1 + rng.below(4) as usize, 1 + rng.below(4) as usize);
        let mut out = Matrix::randn(rng, dr, dc, 1.0);
        let mut pack = vec![3.0f32; rng.below(9) as usize];

        a.matmul_into(&b, &mut out);
        prop::close_slices(&out.data, &naive(m, k, n, &a, &b, false, false), 2e-4)?;
        at.matmul_tn_into(&b, &mut out);
        prop::close_slices(&out.data, &naive(m, k, n, &at, &b, true, false), 2e-4)?;
        a.matmul_nt_into(&bt, &mut out, &mut pack);
        prop::close_slices(&out.data, &naive(m, k, n, &a, &bt, false, true), 2e-4)?;
        ensure(
            (out.rows, out.cols) == (m, n),
            format!("out shape {}×{} after reuse", out.rows, out.cols),
        )
    });
}

#[test]
fn prop_allocating_matmuls_match_into_kernels_bitwise() {
    // The allocating entries dispatch to the parallel drivers; row
    // partitioning preserves accumulation order, so they must agree
    // BITWISE with the serial `*_into` kernels. Shapes are drawn ABOVE the
    // parallel gate (2·m·k·n ≥ 2²², ≥ 2 chunks of 16 rows) so the parallel
    // code actually runs — smaller products would silently compare the
    // serial fallback against itself.
    //
    // Mirror `linalg_pool`'s sizing: with one thread the pool is disabled
    // and this comparison would be vacuous — skip loudly instead.
    let threads = std::env::var("SOAP_GEMM_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1));
    if threads <= 1 {
        eprintln!("SKIP prop_allocating_matmuls_match_into_kernels_bitwise: GEMM pool disabled (1 thread)");
        return;
    }
    prop::check("parallel matmul/matmul_tn/matmul_nt ≡ serial `*_into` bitwise", 6, |rng| {
        // Minimum draw: 2·96·160·160 = 4.9M flops > 2²² and 96/16 = 6
        // chunks, so every case clears the gate in `par_chunk_rows`.
        let m = 96 + rng.below(64) as usize;
        let k = 160 + rng.below(64) as usize;
        let n = 160 + rng.below(64) as usize;
        let a = Matrix::randn(rng, m, k, 1.0);
        let b = Matrix::randn(rng, k, n, 1.0);
        let at = Matrix::randn(rng, k, m, 1.0);
        let bt = Matrix::randn(rng, n, k, 1.0);
        let mut out = Matrix::zeros(0, 0);
        let mut pack = Vec::new();
        a.matmul_into(&b, &mut out);
        ensure(a.matmul(&b).data == out.data, "NN parallel/serial drift")?;
        at.matmul_tn_into(&b, &mut out);
        ensure(at.matmul_tn(&b).data == out.data, "TN parallel/serial drift")?;
        a.matmul_nt_into(&bt, &mut out, &mut pack);
        ensure(a.matmul_nt(&bt).data == out.data, "NT parallel/serial drift")
    });
}
