//! Golden equivalence tests for the composable optimizer core.
//!
//! `mod legacy` freezes the pre-refactor monolithic implementations of
//! SOAP / Shampoo / GaLore / AdamW / Adafactor (verbatim step math from the
//! commit that preceded `optim/compose/`). The tests then assert, seeded and
//! step-for-step:
//!
//! - composed presets reproduce the legacy trajectories **bitwise** in
//!   inline mode (all variants: one-sided, factorized, eigh refresh,
//!   dim-capped) and in drained async mode;
//! - undrained async keeps loss parity with inline;
//! - legacy (pre-refactor) checkpoint state rows load into composed
//!   optimizers and continue bitwise — including rows from before the
//!   `basis_step` flag existed;
//! - Claim 1: `basis=eigen,inner=adafactor` with `shampoo_exponent = 2`
//!   tracks `idealized_adafactor_dir` (and composed power-1/2 Shampoo) on a
//!   fixed gradient set;
//! - the paper's §7.2 memory ordering holds on a 64×48 layer:
//!   AdamW < factorized SOAP < SOAP < Shampoo+grafting;
//! - a novel composition spec runs end-to-end through the trainer and its
//!   checkpoints round-trip.

use std::sync::Arc;

use soap_lab::coordinator::{Checkpoint, Trainer, TrainerConfig};
use soap_lab::linalg::Matrix;
use soap_lab::model::NplmConfig;
use soap_lab::optim::{Hyper, LayerOptimizer, OptKind, RefreshMethod, Schedule};
use soap_lab::precond::RefreshService;
use soap_lab::util::rng::Rng;

/// Frozen pre-refactor implementations. Deliberately kept as close to the
/// original sources as possible — these are the golden reference, not code
/// to be improved.
mod legacy {
    use std::sync::Arc;

    use soap_lab::linalg::{
        eigh, eigh_warm, power_iter_refresh, roots::inv_root_from_eig, Matrix,
    };
    use soap_lab::optim::{Hyper, RefreshMethod};
    use soap_lab::precond::{BasisHandle, BasisPayload, RefreshService};

    /// Frozen copy of the pre-refactor `adafactor::factored_normalize` —
    /// deliberately NOT imported from the crate, so a regression in the live
    /// kernel cannot shift both sides of the bitwise comparison.
    fn factored_normalize(num: &Matrix, a: &[f32], c: &[f32], eps: f32) -> Matrix {
        let sum_a: f32 = a.iter().map(|&x| x as f64).sum::<f64>() as f32;
        let inv_sum = if sum_a > 0.0 { 1.0 / sum_a } else { 0.0 };
        Matrix::from_fn(num.rows, num.cols, |i, j| {
            let vhat = (a[i] * c[j] * inv_sum).max(0.0);
            num.at(i, j) / (vhat + eps).sqrt()
        })
    }

    /// Frozen copy of the pre-refactor `AdamW::direction` (same rationale).
    fn adam_direction(
        m: &Matrix,
        v: &Matrix,
        t: u64,
        beta1: f32,
        beta2: f32,
        eps: f32,
    ) -> Matrix {
        let bc1 = 1.0 - beta1.powi(t as i32);
        let bc2 = 1.0 - beta2.powi(t as i32);
        m.zip(v, |mi, vi| (mi / bc1) / ((vi / bc2).max(0.0).sqrt() + eps))
    }

    pub struct LegacySoap {
        h: Hyper,
        m: Matrix,
        pub l: Option<Matrix>,
        pub r: Option<Matrix>,
        pub ql: Option<Matrix>,
        pub qr: Option<Matrix>,
        v: Option<Matrix>,
        va: Vec<f32>,
        vc: Vec<f32>,
        initialized: bool,
        service: Option<Arc<RefreshService>>,
        handle: Option<Arc<BasisHandle>>,
        adopted_version: u64,
        basis_step: u64,
    }

    impl LegacySoap {
        pub fn new(rows: usize, cols: usize, h: Hyper) -> Self {
            let mut left = rows <= h.max_precond_dim;
            let mut right = cols <= h.max_precond_dim;
            if h.one_sided {
                if rows <= cols {
                    right = false;
                } else {
                    left = false;
                }
            }
            let factorized = h.factorized;
            Self {
                m: Matrix::zeros(rows, cols),
                l: left.then(|| Matrix::zeros(rows, rows)),
                r: right.then(|| Matrix::zeros(cols, cols)),
                ql: None,
                qr: None,
                v: (!factorized).then(|| Matrix::zeros(rows, cols)),
                va: if factorized { vec![0.0; rows] } else { Vec::new() },
                vc: if factorized { vec![0.0; cols] } else { Vec::new() },
                initialized: false,
                service: None,
                handle: None,
                adopted_version: 0,
                basis_step: 0,
                h,
            }
        }

        pub fn attach_async(&mut self, service: &Arc<RefreshService>) -> bool {
            if self.l.is_none() && self.r.is_none() {
                return false;
            }
            self.service = Some(Arc::clone(service));
            self.handle = Some(Arc::new(BasisHandle::new()));
            self.adopted_version = 0;
            true
        }

        fn project(&self, x: &Matrix) -> Matrix {
            let mut y = match &self.ql {
                Some(ql) => ql.matmul_tn(x),
                None => x.clone(),
            };
            if let Some(qr) = &self.qr {
                y = y.matmul(qr);
            }
            y
        }

        fn project_back(&self, x: &Matrix) -> Matrix {
            let mut y = match &self.ql {
                Some(ql) => ql.matmul(x),
                None => x.clone(),
            };
            if let Some(qr) = &self.qr {
                y = y.matmul_nt(qr);
            }
            y
        }

        fn init_basis(&mut self, g: &Matrix) {
            if let Some(l) = &mut self.l {
                *l = g.matmul_nt(g);
                let (_, v) = eigh(l);
                self.ql = Some(v);
            }
            if let Some(r) = &mut self.r {
                *r = g.matmul_tn(g);
                let (_, v) = eigh(r);
                self.qr = Some(v);
            }
            self.initialized = true;
        }

        fn compute_refresh(
            method: RefreshMethod,
            l: Option<&Matrix>,
            r: Option<&Matrix>,
            ql: Option<&Matrix>,
            qr: Option<&Matrix>,
        ) -> (Option<Matrix>, Option<Matrix>) {
            let one_side = |p: Option<&Matrix>, q: Option<&Matrix>| -> Option<Matrix> {
                match method {
                    RefreshMethod::QrPowerIteration => match (p, q) {
                        (Some(p), Some(q)) => Some(power_iter_refresh(p, q)),
                        _ => None,
                    },
                    RefreshMethod::Eigh => p.map(|p| match q {
                        Some(prev) => eigh_warm(p, prev).1,
                        None => eigh(p).1,
                    }),
                }
            };
            (one_side(l, ql), one_side(r, qr))
        }

        fn refresh_basis(&mut self, t: u64) {
            let (new_ql, new_qr) = Self::compute_refresh(
                self.h.refresh,
                self.l.as_ref(),
                self.r.as_ref(),
                self.ql.as_ref(),
                self.qr.as_ref(),
            );
            if let Some(q) = new_ql {
                self.ql = Some(q);
            }
            if let Some(q) = new_qr {
                self.qr = Some(q);
            }
            self.basis_step = t;
        }

        fn adopt_published(&mut self) {
            let Some(handle) = &self.handle else { return };
            if handle.version() <= self.adopted_version {
                return;
            }
            if let Some(published) = handle.latest() {
                if published.version > self.adopted_version {
                    if let Some(q) = &published.payload.left {
                        self.ql = Some(q.clone());
                    }
                    if let Some(q) = &published.payload.right {
                        self.qr = Some(q.clone());
                    }
                    self.adopted_version = published.version;
                    self.basis_step = published.snapshot_step;
                }
            }
        }

        fn enqueue_refresh(
            &self,
            service: &Arc<RefreshService>,
            handle: &Arc<BasisHandle>,
            t: u64,
        ) {
            if !handle.try_begin_refresh() {
                return;
            }
            let method = self.h.refresh;
            let l = self.l.clone();
            let r = self.r.clone();
            let ql = self.ql.clone();
            let qr = self.qr.clone();
            service.enqueue(
                Arc::clone(handle),
                t,
                Box::new(move || {
                    let (left, right) = Self::compute_refresh(
                        method,
                        l.as_ref(),
                        r.as_ref(),
                        ql.as_ref(),
                        qr.as_ref(),
                    );
                    BasisPayload { left, right, left_aux: None, right_aux: None }
                }),
            );
        }

        pub fn update(&mut self, w: &mut Matrix, g: &Matrix, t: u64, lr: f32) {
            let h = self.h.clone();
            if !self.initialized {
                self.init_basis(g);
                self.basis_step = t;
            }
            self.adopt_published();

            self.m.ema_inplace(g, h.beta1);
            let g_rot = self.project(g);
            let m_rot = self.project(&self.m);

            let bc1 = 1.0 - h.beta1.powi(t as i32);
            let bc2 = 1.0 - h.beta2.powi(t as i32);
            let m_hat = m_rot.scale(1.0 / bc1);

            let n_rot = if let Some(v) = &mut self.v {
                let g2 = g_rot.hadamard(&g_rot);
                v.ema_inplace(&g2, h.beta2);
                m_hat.zip(v, |mi, vi| mi / ((vi / bc2).max(0.0).sqrt() + h.eps))
            } else {
                let g2 = g_rot.hadamard(&g_rot);
                let rows = g2.row_sums();
                let cols = g2.col_sums();
                for (ai, ri) in self.va.iter_mut().zip(&rows) {
                    *ai = h.beta2 * *ai + (1.0 - h.beta2) * ri;
                }
                for (ci, cj) in self.vc.iter_mut().zip(&cols) {
                    *ci = h.beta2 * *ci + (1.0 - h.beta2) * cj;
                }
                let a_hat: Vec<f32> = self.va.iter().map(|&x| x / bc2).collect();
                let c_hat: Vec<f32> = self.vc.iter().map(|&x| x / bc2).collect();
                factored_normalize(&m_hat, &a_hat, &c_hat, h.eps)
            };

            let n = self.project_back(&n_rot);
            w.axpy_inplace(-lr, &n);
            if h.weight_decay != 0.0 {
                w.scale_inplace(1.0 - lr * h.weight_decay);
            }

            if let Some(l) = &mut self.l {
                let ggt = g.matmul_nt(g);
                l.ema_inplace(&ggt, h.shampoo_beta);
            }
            if let Some(r) = &mut self.r {
                let gtg = g.matmul_tn(g);
                r.ema_inplace(&gtg, h.shampoo_beta);
            }
            if h.is_refresh_step(t) {
                match (self.service.clone(), self.handle.clone()) {
                    (Some(service), Some(handle)) => self.enqueue_refresh(&service, &handle, t),
                    _ => self.refresh_basis(t),
                }
            }
        }

        /// The pre-refactor checkpoint layout:
        /// `[flags(1×5), M, L?, R?, QL?, QR?, V?, va?, vc?]`.
        pub fn export_state(&self) -> Vec<Matrix> {
            let flags = Matrix::from_vec(
                1,
                5,
                vec![
                    self.initialized as u8 as f32,
                    self.l.is_some() as u8 as f32,
                    self.r.is_some() as u8 as f32,
                    self.v.is_some() as u8 as f32,
                    self.basis_step as f32,
                ],
            );
            let mut out = vec![flags, self.m.clone()];
            for opt in [&self.l, &self.r, &self.ql, &self.qr, &self.v] {
                if let Some(x) = opt {
                    out.push(x.clone());
                }
            }
            if !self.va.is_empty() {
                out.push(Matrix::from_vec(1, self.va.len(), self.va.clone()));
                out.push(Matrix::from_vec(1, self.vc.len(), self.vc.clone()));
            }
            out
        }
    }

    pub struct LegacyShampoo {
        h: Hyper,
        m: Matrix,
        l: Matrix,
        r: Matrix,
        pub l_inv: Matrix,
        pub r_inv: Matrix,
        v_graft: Matrix,
        l_vecs: Option<Matrix>,
        r_vecs: Option<Matrix>,
        initialized: bool,
        service: Option<Arc<RefreshService>>,
        handle: Option<Arc<BasisHandle>>,
        adopted_version: u64,
        basis_step: u64,
    }

    impl LegacyShampoo {
        pub fn new(rows: usize, cols: usize, h: Hyper) -> Self {
            Self {
                h,
                m: Matrix::zeros(rows, cols),
                l: Matrix::zeros(rows, rows),
                r: Matrix::zeros(cols, cols),
                l_inv: Matrix::eye(rows),
                r_inv: Matrix::eye(cols),
                v_graft: Matrix::zeros(rows, cols),
                l_vecs: None,
                r_vecs: None,
                initialized: false,
                service: None,
                handle: None,
                adopted_version: 0,
                basis_step: 0,
            }
        }

        pub fn attach_async(&mut self, service: &Arc<RefreshService>) -> bool {
            self.service = Some(Arc::clone(service));
            self.handle = Some(Arc::new(BasisHandle::new()));
            self.adopted_version = 0;
            true
        }

        fn compute_roots(
            lh: &Matrix,
            rh: &Matrix,
            prev_l: Option<&Matrix>,
            prev_r: Option<&Matrix>,
            e: f32,
            eps: f32,
        ) -> (Matrix, Matrix, Matrix, Matrix) {
            let (wl, vl) = match prev_l {
                Some(prev) => eigh_warm(lh, prev),
                None => eigh(lh),
            };
            let (wr, vr) = match prev_r {
                Some(prev) => eigh_warm(rh, prev),
                None => eigh(rh),
            };
            let l_inv = inv_root_from_eig(&wl, &vl, e, eps);
            let r_inv = inv_root_from_eig(&wr, &vr, e, eps);
            (l_inv, r_inv, vl, vr)
        }

        fn corrected_factors(&self, t: u64) -> (Matrix, Matrix) {
            let bc = 1.0 - self.h.shampoo_beta.powi(t as i32);
            (self.l.scale(1.0 / bc), self.r.scale(1.0 / bc))
        }

        fn refresh_roots(&mut self, t: u64) {
            let (lh, rh) = self.corrected_factors(t);
            let (l_inv, r_inv, vl, vr) = Self::compute_roots(
                &lh,
                &rh,
                self.l_vecs.as_ref(),
                self.r_vecs.as_ref(),
                self.h.shampoo_exponent,
                self.h.shampoo_eps,
            );
            self.l_inv = l_inv;
            self.r_inv = r_inv;
            self.l_vecs = Some(vl);
            self.r_vecs = Some(vr);
            self.basis_step = t;
        }

        fn adopt_published(&mut self) {
            let Some(handle) = &self.handle else { return };
            if handle.version() <= self.adopted_version {
                return;
            }
            if let Some(published) = handle.latest() {
                if published.version > self.adopted_version {
                    let p = &published.payload;
                    if let (Some(li), Some(ri)) = (&p.left, &p.right) {
                        self.l_inv = li.clone();
                        self.r_inv = ri.clone();
                    }
                    self.l_vecs = p.left_aux.clone().or_else(|| self.l_vecs.take());
                    self.r_vecs = p.right_aux.clone().or_else(|| self.r_vecs.take());
                    self.adopted_version = published.version;
                    self.basis_step = published.snapshot_step;
                }
            }
        }

        fn enqueue_refresh(
            &self,
            service: &Arc<RefreshService>,
            handle: &Arc<BasisHandle>,
            t: u64,
        ) {
            if !handle.try_begin_refresh() {
                return;
            }
            let (lh, rh) = self.corrected_factors(t);
            let prev_l = self.l_vecs.clone();
            let prev_r = self.r_vecs.clone();
            let e = self.h.shampoo_exponent;
            let eps = self.h.shampoo_eps;
            service.enqueue(
                Arc::clone(handle),
                t,
                Box::new(move || {
                    let (l_inv, r_inv, vl, vr) =
                        Self::compute_roots(&lh, &rh, prev_l.as_ref(), prev_r.as_ref(), e, eps);
                    BasisPayload {
                        left: Some(l_inv),
                        right: Some(r_inv),
                        left_aux: Some(vl),
                        right_aux: Some(vr),
                    }
                }),
            );
        }

        pub fn update(&mut self, w: &mut Matrix, g: &Matrix, t: u64, lr: f32) {
            let h = self.h.clone();

            let ggt = g.matmul_nt(g);
            let gtg = g.matmul_tn(g);
            self.l.ema_inplace(&ggt, h.shampoo_beta);
            self.r.ema_inplace(&gtg, h.shampoo_beta);

            self.adopt_published();
            if !self.initialized {
                self.refresh_roots(t);
                self.initialized = true;
            } else if h.is_refresh_step(t) {
                match (self.service.clone(), self.handle.clone()) {
                    (Some(service), Some(handle)) => self.enqueue_refresh(&service, &handle, t),
                    _ => self.refresh_roots(t),
                }
            }

            self.m.ema_inplace(g, h.beta1);
            let bc1 = 1.0 - h.beta1.powi(t as i32);
            let m_hat = self.m.scale(1.0 / bc1);
            let mut dir = self.l_inv.matmul(&m_hat).matmul(&self.r_inv);

            if h.grafting {
                let g2 = g.hadamard(g);
                self.v_graft.ema_inplace(&g2, h.beta2);
                let adam_dir =
                    adam_direction(&self.m, &self.v_graft, t, h.beta1, h.beta2, h.eps);
                let target = adam_dir.frob_norm();
                let actual = dir.frob_norm();
                if actual > 1e-30 {
                    dir.scale_inplace(target / actual);
                }
            }

            w.axpy_inplace(-lr, &dir);
            if h.weight_decay != 0.0 {
                w.scale_inplace(1.0 - lr * h.weight_decay);
            }
        }

        /// Pre-refactor layout: `[flags(1×2), M, L, R, L_inv, R_inv, V_graft]`.
        pub fn export_state(&self) -> Vec<Matrix> {
            let flags = Matrix::from_vec(
                1,
                2,
                vec![self.initialized as u8 as f32, self.basis_step as f32],
            );
            vec![
                flags,
                self.m.clone(),
                self.l.clone(),
                self.r.clone(),
                self.l_inv.clone(),
                self.r_inv.clone(),
                self.v_graft.clone(),
            ]
        }
    }

    pub struct LegacyGalore {
        h: Hyper,
        p: Option<Matrix>,
        left: bool,
        m: Matrix,
        v: Matrix,
    }

    impl LegacyGalore {
        pub fn new(rows: usize, cols: usize, h: Hyper) -> Self {
            Self {
                left: rows <= cols,
                p: None,
                m: Matrix::zeros(rows, cols),
                v: Matrix::zeros(rows, cols),
                h,
            }
        }

        fn project(&self, g: &Matrix) -> Matrix {
            match (&self.p, self.left) {
                (Some(p), true) => p.matmul_tn(g),
                (Some(p), false) => g.matmul(p),
                (None, _) => g.clone(),
            }
        }

        fn project_back(&self, x: &Matrix) -> Matrix {
            match (&self.p, self.left) {
                (Some(p), true) => p.matmul(x),
                (Some(p), false) => x.matmul_nt(p),
                (None, _) => x.clone(),
            }
        }

        pub fn update(&mut self, w: &mut Matrix, g: &Matrix, t: u64, lr: f32) {
            let h = self.h.clone();

            if self.p.is_none() || h.is_refresh_step(t) {
                let factor = if self.left { g.matmul_nt(g) } else { g.matmul_tn(g) };
                let (_, vecs) = eigh(&factor);
                self.p = Some(vecs);
            }

            let g_proj = self.project(g);
            self.m.ema_inplace(&g_proj, h.beta1);
            let g2 = g_proj.hadamard(&g_proj);
            self.v.ema_inplace(&g2, h.beta2);

            let bc1 = 1.0 - h.beta1.powi(t as i32);
            let bc2 = 1.0 - h.beta2.powi(t as i32);
            let dir_proj = self
                .m
                .zip(&self.v, |mi, vi| (mi / bc1) / ((vi / bc2).max(0.0).sqrt() + h.eps));
            let dir = self.project_back(&dir_proj).scale(h.galore_scale);

            w.axpy_inplace(-lr, &dir);
            if h.weight_decay != 0.0 {
                w.scale_inplace(1.0 - lr * h.weight_decay);
            }
        }

        /// Pre-refactor layout: `[has_p(1×1), M, V, P?]`.
        pub fn export_state(&self) -> Vec<Matrix> {
            let has_p = Matrix::from_vec(1, 1, vec![self.p.is_some() as u8 as f32]);
            let mut out = vec![has_p, self.m.clone(), self.v.clone()];
            if let Some(p) = &self.p {
                out.push(p.clone());
            }
            out
        }
    }

    pub struct LegacyAdamW {
        h: Hyper,
        m: Matrix,
        v: Matrix,
    }

    impl LegacyAdamW {
        pub fn new(rows: usize, cols: usize, h: Hyper) -> Self {
            Self { h, m: Matrix::zeros(rows, cols), v: Matrix::zeros(rows, cols) }
        }

        pub fn update(&mut self, w: &mut Matrix, g: &Matrix, t: u64, lr: f32) {
            self.m.ema_inplace(g, self.h.beta1);
            let g2 = g.hadamard(g);
            self.v.ema_inplace(&g2, self.h.beta2);
            let dir =
                adam_direction(&self.m, &self.v, t, self.h.beta1, self.h.beta2, self.h.eps);
            w.axpy_inplace(-lr, &dir);
            if self.h.weight_decay != 0.0 {
                w.scale_inplace(1.0 - lr * self.h.weight_decay);
            }
        }

        pub fn export_state(&self) -> Vec<Matrix> {
            vec![self.m.clone(), self.v.clone()]
        }
    }

    pub struct LegacyAdafactor {
        h: Hyper,
        m: Matrix,
        a: Vec<f32>,
        c: Vec<f32>,
        v_1d: Option<Matrix>,
    }

    impl LegacyAdafactor {
        pub fn new(rows: usize, cols: usize, h: Hyper) -> Self {
            let is_1d = rows == 1 || cols == 1;
            Self {
                h,
                m: Matrix::zeros(rows, cols),
                a: vec![0.0; rows],
                c: vec![0.0; cols],
                v_1d: if is_1d { Some(Matrix::zeros(rows, cols)) } else { None },
            }
        }

        pub fn update(&mut self, w: &mut Matrix, g: &Matrix, t: u64, lr: f32) {
            let h = &self.h;
            self.m.ema_inplace(g, h.beta1);
            let bc1 = 1.0 - h.beta1.powi(t as i32);
            let bc2 = 1.0 - h.beta2.powi(t as i32);

            let dir = if let Some(v) = &mut self.v_1d {
                let g2 = g.hadamard(g);
                v.ema_inplace(&g2, h.beta2);
                self.m
                    .zip(v, |mi, vi| (mi / bc1) / ((vi / bc2).max(0.0).sqrt() + h.eps))
            } else {
                let g2 = g.hadamard(g);
                let rows = g2.row_sums();
                let cols = g2.col_sums();
                for (ai, ri) in self.a.iter_mut().zip(&rows) {
                    *ai = h.beta2 * *ai + (1.0 - h.beta2) * ri;
                }
                for (ci, cj) in self.c.iter_mut().zip(&cols) {
                    *ci = h.beta2 * *ci + (1.0 - h.beta2) * cj;
                }
                let a_hat: Vec<f32> = self.a.iter().map(|&x| x / bc2).collect();
                let c_hat: Vec<f32> = self.c.iter().map(|&x| x / bc2).collect();
                let m_hat = self.m.scale(1.0 / bc1);
                factored_normalize(&m_hat, &a_hat, &c_hat, h.eps)
            };

            w.axpy_inplace(-lr, &dir);
            if h.weight_decay != 0.0 {
                w.scale_inplace(1.0 - lr * h.weight_decay);
            }
        }

        pub fn export_state(&self) -> Vec<Matrix> {
            let mut out = vec![
                self.m.clone(),
                Matrix::from_vec(1, self.a.len(), self.a.clone()),
                Matrix::from_vec(1, self.c.len(), self.c.clone()),
            ];
            if let Some(v) = &self.v_1d {
                out.push(v.clone());
            }
            out
        }
    }
}

fn seeded_grads(seed: u64, steps: usize, m: usize, n: usize) -> Vec<Matrix> {
    let mut rng = Rng::new(seed);
    (0..steps).map(|_| Matrix::randn(&mut rng, m, n, 1.0)).collect()
}

/// Drive `legacy_step` and `composed` over the same gradient stream and
/// assert the weights agree bitwise after every step.
fn assert_bitwise_trajectory(
    label: &str,
    grads: &[Matrix],
    mut legacy_step: impl FnMut(&mut Matrix, &Matrix, u64),
    composed: &mut dyn LayerOptimizer,
    lr: f32,
) {
    let (m, n) = (grads[0].rows, grads[0].cols);
    let mut w_legacy = Matrix::zeros(m, n);
    let mut w_composed = Matrix::zeros(m, n);
    for (i, g) in grads.iter().enumerate() {
        let t = i as u64 + 1;
        legacy_step(&mut w_legacy, g, t);
        composed.update(&mut w_composed, g, t, lr);
        assert_eq!(
            w_legacy.data, w_composed.data,
            "{label}: composed diverged from legacy at step {t}"
        );
    }
}

#[test]
fn golden_soap_inline_bitwise_all_variants() {
    let base = Hyper { precond_freq: 5, ..Hyper::default() };
    let variants: Vec<(&str, Hyper)> = vec![
        ("default", base.clone()),
        ("one-sided", Hyper { one_sided: true, ..base.clone() }),
        ("factorized", Hyper { factorized: true, ..base.clone() }),
        ("eigh-refresh", Hyper { refresh: RefreshMethod::Eigh, ..base.clone() }),
        ("dim-capped", Hyper { max_precond_dim: 7, ..base.clone() }),
        ("phase-2", base.clone().with_refresh_phase(2)),
        (
            "one-sided+factorized",
            Hyper { one_sided: true, factorized: true, ..base },
        ),
    ];
    for (label, h) in variants {
        // ≥ 3·f steps so at least three refreshes land.
        let grads = seeded_grads(900, 17, 6, 8);
        let mut legacy = legacy::LegacySoap::new(6, 8, h.clone());
        let mut composed = OptKind::Soap.build(6, 8, &h);
        assert_bitwise_trajectory(
            &format!("soap/{label}"),
            &grads,
            |w, g, t| legacy.update(w, g, t, 0.01),
            composed.as_mut(),
            0.01,
        );
    }
}

#[test]
fn golden_soap_spec_grammar_bitwise() {
    // The grammar route (`basis=eigen,inner=…`) must build the SAME
    // optimizer as the preset — and therefore match legacy bitwise too.
    let h = Hyper { precond_freq: 5, ..Hyper::default() };
    let grads = seeded_grads(901, 17, 6, 8);
    let mut legacy = legacy::LegacySoap::new(
        6,
        8,
        Hyper { one_sided: true, factorized: true, ..h.clone() },
    );
    let spec = OptKind::parse("basis=eigen:one-sided,inner=adafactor").unwrap();
    let mut composed = spec.build(6, 8, &h);
    assert_bitwise_trajectory(
        "soap/spec-grammar",
        &grads,
        |w, g, t| legacy.update(w, g, t, 0.01),
        composed.as_mut(),
        0.01,
    );
}

#[test]
fn golden_shampoo_inline_bitwise() {
    let base = Hyper { precond_freq: 5, ..Hyper::default() };
    let variants: Vec<(&str, Hyper)> = vec![
        ("grafted", base.clone()),
        ("no-graft", Hyper { grafting: false, ..base.clone() }),
        ("power-half", Hyper { shampoo_exponent: 2.0, ..base }),
    ];
    for (label, h) in variants {
        let grads = seeded_grads(902, 17, 6, 4);
        let mut legacy = legacy::LegacyShampoo::new(6, 4, h.clone());
        let mut composed = OptKind::Shampoo.build(6, 4, &h);
        assert_bitwise_trajectory(
            &format!("shampoo/{label}"),
            &grads,
            |w, g, t| legacy.update(w, g, t, 0.01),
            composed.as_mut(),
            0.01,
        );
    }
}

#[test]
fn golden_galore_adamw_adafactor_inline_bitwise() {
    let h = Hyper { precond_freq: 5, ..Hyper::default() };

    let grads = seeded_grads(903, 17, 4, 9);
    let mut lg = legacy::LegacyGalore::new(4, 9, h.clone());
    let mut cg = OptKind::Galore.build(4, 9, &h);
    assert_bitwise_trajectory(
        "galore",
        &grads,
        |w, g, t| lg.update(w, g, t, 0.01),
        cg.as_mut(),
        0.01,
    );

    let grads = seeded_grads(904, 17, 5, 7);
    let mut la = legacy::LegacyAdamW::new(5, 7, h.clone());
    let mut ca = OptKind::AdamW.build(5, 7, &h);
    assert_bitwise_trajectory(
        "adamw",
        &grads,
        |w, g, t| la.update(w, g, t, 0.01),
        ca.as_mut(),
        0.01,
    );

    for (m, n) in [(5usize, 7usize), (1, 12)] {
        let grads = seeded_grads(905, 17, m, n);
        let mut lf = legacy::LegacyAdafactor::new(m, n, h.clone());
        let mut cf = OptKind::Adafactor.build(m, n, &h);
        assert_bitwise_trajectory(
            &format!("adafactor/{m}x{n}"),
            &grads,
            |w, g, t| lf.update(w, g, t, 0.01),
            cf.as_mut(),
            0.01,
        );
    }
}

#[test]
fn golden_async_drained_bitwise() {
    // Drain both services after every step: publication timing becomes
    // deterministic, so even async trajectories must agree bitwise.
    let h = Hyper { precond_freq: 5, ..Hyper::default() };

    let svc_l = Arc::new(RefreshService::new(1));
    let svc_c = Arc::new(RefreshService::new(1));
    let grads = seeded_grads(906, 17, 6, 6);
    let mut legacy = legacy::LegacySoap::new(6, 6, h.clone());
    assert!(legacy.attach_async(&svc_l));
    let mut composed = OptKind::Soap.build(6, 6, &h);
    assert!(composed.attach_async(&svc_c));
    let mut w_l = Matrix::zeros(6, 6);
    let mut w_c = Matrix::zeros(6, 6);
    for (i, g) in grads.iter().enumerate() {
        let t = i as u64 + 1;
        legacy.update(&mut w_l, g, t, 0.01);
        svc_l.wait_idle();
        composed.update(&mut w_c, g, t, 0.01);
        svc_c.wait_idle();
        assert_eq!(w_l.data, w_c.data, "async soap diverged at step {t}");
    }

    let svc_l = Arc::new(RefreshService::new(1));
    let svc_c = Arc::new(RefreshService::new(1));
    let grads = seeded_grads(907, 17, 6, 4);
    let mut legacy = legacy::LegacyShampoo::new(6, 4, h.clone());
    assert!(legacy.attach_async(&svc_l));
    let mut composed = OptKind::Shampoo.build(6, 4, &h);
    assert!(composed.attach_async(&svc_c));
    let mut w_l = Matrix::zeros(6, 4);
    let mut w_c = Matrix::zeros(6, 4);
    for (i, g) in grads.iter().enumerate() {
        let t = i as u64 + 1;
        legacy.update(&mut w_l, g, t, 0.01);
        svc_l.wait_idle();
        composed.update(&mut w_c, g, t, 0.01);
        svc_c.wait_idle();
        assert_eq!(w_l.data, w_c.data, "async shampoo diverged at step {t}");
    }
}

#[test]
fn async_undrained_keeps_loss_parity() {
    // Without draining, adoption timing is nondeterministic — the acceptance
    // bar is loss parity, not bitwise equality.
    let h = Hyper { weight_decay: 0.0, precond_freq: 5, ..Hyper::default() };
    let mut rng = Rng::new(908);
    let target = Matrix::randn(&mut rng, 6, 4, 1.0);
    let run = |mut opt: Box<dyn LayerOptimizer>| -> f32 {
        let mut w = Matrix::zeros(6, 4);
        for t in 1..=1200 {
            let g = w.sub(&target).scale(2.0);
            opt.update(&mut w, &g, t, 0.02);
        }
        w.max_abs_diff(&target)
    };
    let inline_err = run(OptKind::Soap.build(6, 4, &h));
    let svc = Arc::new(RefreshService::new(2));
    let mut async_opt = OptKind::Soap.build(6, 4, &h);
    assert!(async_opt.attach_async(&svc));
    let async_err = run(async_opt);
    svc.wait_idle();
    assert!(inline_err < 0.1, "inline SOAP failed: {inline_err}");
    assert!(async_err < 0.15, "async SOAP lost parity: {async_err}");
}

#[test]
fn legacy_checkpoint_rows_load_into_composed() {
    let h = Hyper { precond_freq: 4, ..Hyper::default() };
    let grads = seeded_grads(909, 9, 6, 5);
    let post = seeded_grads(910, 5, 6, 5);

    // For each optimizer: run the frozen legacy impl, export its
    // pre-refactor state rows, import into a FRESH composed optimizer, then
    // continue both and require bitwise agreement.
    {
        for factorized in [false, true] {
            let hh = Hyper { factorized, ..h.clone() };
            let mut legacy = legacy::LegacySoap::new(6, 5, hh.clone());
            let mut w = Matrix::zeros(6, 5);
            for (i, g) in grads.iter().enumerate() {
                legacy.update(&mut w, g, i as u64 + 1, 0.01);
            }
            let mut composed = OptKind::Soap.build(6, 5, &hh);
            composed.import_state(legacy.export_state()).unwrap();
            let mut w_l = w.clone();
            let mut w_c = w.clone();
            for (i, g) in post.iter().enumerate() {
                let t = grads.len() as u64 + i as u64 + 1;
                legacy.update(&mut w_l, g, t, 0.01);
                composed.update(&mut w_c, g, t, 0.01);
            }
            assert_eq!(w_l.data, w_c.data, "soap(factorized={factorized}) restore drifted");
        }
    }
    {
        let mut legacy = legacy::LegacyShampoo::new(6, 5, h.clone());
        let mut w = Matrix::zeros(6, 5);
        for (i, g) in grads.iter().enumerate() {
            legacy.update(&mut w, g, i as u64 + 1, 0.01);
        }
        let mut composed = OptKind::Shampoo.build(6, 5, &h);
        composed.import_state(legacy.export_state()).unwrap();
        let mut w_l = w.clone();
        let mut w_c = w.clone();
        for (i, g) in post.iter().enumerate() {
            let t = grads.len() as u64 + i as u64 + 1;
            legacy.update(&mut w_l, g, t, 0.01);
            composed.update(&mut w_c, g, t, 0.01);
        }
        // The restored composed Shampoo cold-starts its warm-start eigh
        // caches (they are not serialized — same as pre-refactor), so the
        // first post-restore refresh may differ by an eigh-convergence
        // whisker; everything before it is exact.
        assert!(
            w_l.max_abs_diff(&w_c) < 1e-5,
            "shampoo restore drifted: {}",
            w_l.max_abs_diff(&w_c)
        );
    }
    {
        let mut legacy = legacy::LegacyGalore::new(6, 5, h.clone());
        let mut w = Matrix::zeros(6, 5);
        for (i, g) in grads.iter().enumerate() {
            legacy.update(&mut w, g, i as u64 + 1, 0.01);
        }
        let mut composed = OptKind::Galore.build(6, 5, &h);
        composed.import_state(legacy.export_state()).unwrap();
        let mut w_l = w.clone();
        let mut w_c = w.clone();
        for (i, g) in post.iter().enumerate() {
            let t = grads.len() as u64 + i as u64 + 1;
            legacy.update(&mut w_l, g, t, 0.01);
            composed.update(&mut w_c, g, t, 0.01);
        }
        assert_eq!(w_l.data, w_c.data, "galore restore drifted");
    }
    {
        let mut legacy = legacy::LegacyAdamW::new(6, 5, h.clone());
        let mut w = Matrix::zeros(6, 5);
        for (i, g) in grads.iter().enumerate() {
            legacy.update(&mut w, g, i as u64 + 1, 0.01);
        }
        let mut composed = OptKind::AdamW.build(6, 5, &h);
        composed.import_state(legacy.export_state()).unwrap();
        let mut w_l = w.clone();
        let mut w_c = w.clone();
        for (i, g) in post.iter().enumerate() {
            let t = grads.len() as u64 + i as u64 + 1;
            legacy.update(&mut w_l, g, t, 0.01);
            composed.update(&mut w_c, g, t, 0.01);
        }
        assert_eq!(w_l.data, w_c.data, "adamw restore drifted");
    }
    {
        let mut legacy = legacy::LegacyAdafactor::new(6, 5, h.clone());
        let mut w = Matrix::zeros(6, 5);
        for (i, g) in grads.iter().enumerate() {
            legacy.update(&mut w, g, i as u64 + 1, 0.01);
        }
        let mut composed = OptKind::Adafactor.build(6, 5, &h);
        composed.import_state(legacy.export_state()).unwrap();
        let mut w_l = w.clone();
        let mut w_c = w.clone();
        for (i, g) in post.iter().enumerate() {
            let t = grads.len() as u64 + i as u64 + 1;
            legacy.update(&mut w_l, g, t, 0.01);
            composed.update(&mut w_c, g, t, 0.01);
        }
        assert_eq!(w_l.data, w_c.data, "adafactor restore drifted");
    }
}

#[test]
fn pre_basis_step_flag_rows_still_load() {
    // Checkpoints written before the basis_step flag existed carry 4-col
    // (SOAP) / 1-col (Shampoo) flag rows; they must still import.
    let h = Hyper { precond_freq: 4, ..Hyper::default() };
    let grads = seeded_grads(911, 6, 5, 4);

    let mut legacy = legacy::LegacySoap::new(5, 4, h.clone());
    let mut w = Matrix::zeros(5, 4);
    for (i, g) in grads.iter().enumerate() {
        legacy.update(&mut w, g, i as u64 + 1, 0.01);
    }
    let mut state = legacy.export_state();
    let old_flags = state[0].data[..4].to_vec();
    state[0] = Matrix::from_vec(1, 4, old_flags);
    let mut composed = OptKind::Soap.build(5, 4, &h);
    composed.import_state(state).unwrap();
    assert_eq!(composed.basis_snapshot_step(), Some(0), "staleness restarts from 0");

    let mut legacy = legacy::LegacyShampoo::new(5, 4, h.clone());
    let mut w = Matrix::zeros(5, 4);
    for (i, g) in grads.iter().enumerate() {
        legacy.update(&mut w, g, i as u64 + 1, 0.01);
    }
    let mut state = legacy.export_state();
    let old_flags = state[0].data[..1].to_vec();
    state[0] = Matrix::from_vec(1, 1, old_flags);
    let mut composed = OptKind::Shampoo.build(5, 4, &h);
    composed.import_state(state).unwrap();
    assert_eq!(composed.basis_snapshot_step(), Some(0));
}

/// Cosine similarity over the flattened matrices.
fn cosine(a: &Matrix, b: &Matrix) -> f64 {
    let mut dot = 0.0f64;
    let mut na = 0.0f64;
    let mut nb = 0.0f64;
    for (x, y) in a.data.iter().zip(&b.data) {
        dot += *x as f64 * *y as f64;
        na += *x as f64 * *x as f64;
        nb += *y as f64 * *y as f64;
    }
    dot / (na.sqrt() * nb.sqrt()).max(1e-30)
}

#[test]
fn claim1_eigen_adafactor_tracks_idealized_adafactor() {
    // Claim 1 (§4.1): running Adafactor in Shampoo's eigenbasis equals
    // idealized power-1/2 Shampoo. Feed a FIXED gradient set cycled long
    // enough that the EMA factors ≈ dataset averages, then compare the
    // composed `basis=eigen,inner=adafactor` direction (and the composed
    // power-1/2 Shampoo direction) against the idealized algorithms.
    let (m, n, k) = (6usize, 5usize, 16usize);
    let grads = seeded_grads(912, k, m, n);
    let probe = grads[0].clone();

    let h = Hyper {
        beta1: 0.0,              // momentum = current gradient, as idealized
        beta2: 0.995,            // second-moment EMA ≈ dataset mean
        shampoo_beta: 0.995,     // factor EMA ≈ dataset mean
        shampoo_exponent: 2.0,   // power 1/2 — the Claim 1 configuration
        grafting: false,
        weight_decay: 0.0,
        precond_freq: 1,
        refresh: RefreshMethod::Eigh,
        eps: 1e-10,
        ..Hyper::default()
    };
    let warmup = 1200usize;

    // Direction probe: with w = 0 and lr = 1, the post-update weights are
    // exactly -direction.
    let probe_dir = |opt: &mut dyn LayerOptimizer| -> Matrix {
        for t in 0..warmup {
            let g = &grads[t % k];
            let mut w = Matrix::zeros(m, n);
            opt.update(&mut w, g, t as u64 + 1, 0.0);
        }
        let mut w = Matrix::zeros(m, n);
        opt.update(&mut w, &probe, warmup as u64 + 1, 1.0);
        w.scale(-1.0)
    };

    let mut factored = OptKind::parse("basis=eigen,inner=adafactor").unwrap().build(m, n, &h);
    let dir_factored = probe_dir(factored.as_mut());

    let mut shampoo = OptKind::parse("basis=eigen,inner=shampoo,graft=none")
        .unwrap()
        .build(m, n, &h);
    let dir_shampoo = probe_dir(shampoo.as_mut());

    let ideal_af = soap_lab::optim::idealized::idealized_adafactor_dir(&grads, &probe, 1e-10);
    let ideal_sh = soap_lab::optim::idealized::idealized_shampoo_dir(&grads, &probe);

    let c_af = cosine(&dir_factored, &ideal_af);
    let c_sh = cosine(&dir_shampoo, &ideal_sh);
    let c_claim1 = cosine(&dir_factored, &dir_shampoo);
    assert!(c_af > 0.95, "eigen×adafactor vs idealized Adafactor: cos {c_af}");
    assert!(c_sh > 0.95, "power-1/2 Shampoo vs idealized Shampoo: cos {c_sh}");
    assert!(c_claim1 > 0.93, "Claim 1: eigen×adafactor vs Shampoo^1/2: cos {c_claim1}");
}

#[test]
fn memory_ordering_section_7_2() {
    // Paper §7.2 on a 64×48 layer, after one step so every lazily-allocated
    // tensor exists: AdamW < factorized SOAP < SOAP < Shampoo+grafting.
    let (m, n) = (64usize, 48usize);
    let h = Hyper::default();
    let mut rng = Rng::new(913);
    let g = Matrix::randn(&mut rng, m, n, 1.0);

    let bytes = |kind: OptKind, h: &Hyper| -> usize {
        let mut opt = kind.build(m, n, h);
        let mut w = Matrix::zeros(m, n);
        opt.update(&mut w, &g, 1, 0.01);
        opt.state_bytes()
    };

    let adamw = bytes(OptKind::AdamW, &h);
    let soap_fact = bytes(OptKind::Soap, &Hyper { factorized: true, ..h.clone() });
    let soap = bytes(OptKind::Soap, &h);
    let shampoo = bytes(OptKind::Shampoo, &h);

    assert_eq!(adamw, 2 * m * n * 4);
    assert_eq!(soap_fact, (2 * m * m + 2 * n * n + m * n + m + n) * 4);
    assert_eq!(soap, (2 * m * m + 2 * n * n + 2 * m * n) * 4);
    // Shampoo honestly counts its warm-start eigenvector caches now:
    // 3m² + 3n² + 2mn.
    assert_eq!(shampoo, (3 * m * m + 3 * n * n + 2 * m * n) * 4);
    assert!(
        adamw < soap_fact && soap_fact < soap && soap < shampoo,
        "§7.2 ordering violated: {adamw} {soap_fact} {soap} {shampoo}"
    );
}

#[test]
fn composed_spec_trains_end_to_end_and_checkpoints_roundtrip() {
    // Acceptance: `--optimizer basis=eigen:one-sided,inner=adafactor` runs
    // through the trainer, and checkpoints round-trip exactly.
    let spec = OptKind::parse("basis=eigen:one-sided,inner=adafactor").unwrap();
    let mk = |steps: u64| -> Trainer {
        let cfg = TrainerConfig {
            opt: spec,
            hyper: Hyper { precond_freq: 4, ..Hyper::default() },
            schedule: Schedule::Constant { lr: 0.02 },
            steps,
            seed: 13,
            workers: 2,
            log_every: 0,
            vocab: 64,
            zipf_alpha: 1.3,
            ..TrainerConfig::default()
        };
        Trainer::new_native(NplmConfig { vocab: 64, context: 3, dim: 12, hidden: 24, conv: false }, cfg, 24, 8)
    };

    let mut full = mk(30);
    let log = full.run().unwrap();
    assert!(log.final_loss().is_finite());
    assert!(
        log.tail_loss(5) < log.losses[0].1,
        "composed spec did not learn: {} → {}",
        log.losses[0].1,
        log.tail_loss(5)
    );
    assert!(full.state_bytes() > 0);

    // 15 steps + checkpoint + restore + 15 steps ≡ 30 straight.
    let mut first = mk(15);
    first.run().unwrap();
    let ck = Checkpoint::new(
        first.step,
        first.params.clone(),
        first.native_optimizer().unwrap().export_state(),
    );
    let path = std::env::temp_dir().join(format!("golden_compose_{}.ckpt", std::process::id()));
    ck.save(&path).unwrap();

    let mut second = mk(15);
    let restored = Checkpoint::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    second.params = restored.params;
    second.step = restored.step;
    second
        .native_optimizer_mut()
        .unwrap()
        .import_state(restored.opt_state)
        .unwrap();
    second.skip_batches(15);
    second.run().unwrap();
    assert_eq!(second.step, 30);
    for (x, y) in full.params.iter().zip(&second.params) {
        assert_eq!(x.data, y.data, "composed-spec resume diverged");
    }
}

#[test]
fn workspace_path_matches_allocating_path_bitwise_all_presets() {
    // PR-3 tentpole pin: `Composed::update` (the fused, zero-allocation
    // workspace path) against `Composed::update_legacy_alloc` (the frozen
    // allocating clone/map/zip reference) — bitwise, for every preset plus
    // the factorized-SOAP engine path, over ≥ 3·f steps so basis inits and
    // refreshes land inside the window.
    use soap_lab::optim::compose::presets;
    use soap_lab::optim::DynComposed;
    let h = Hyper { precond_freq: 5, ..Hyper::default() };
    type Build = fn(usize, usize, Hyper) -> DynComposed;
    let builds: [(&str, Build); 6] = [
        ("soap", presets::soap),
        ("soap-factorized", |r, c, h| presets::soap(r, c, Hyper { factorized: true, ..h })),
        ("shampoo", presets::shampoo),
        ("galore", presets::galore),
        ("adamw", presets::adamw),
        ("adafactor", presets::adafactor),
    ];
    for (label, build) in builds {
        let grads = seeded_grads(950, 17, 6, 8);
        let mut fused = build(6, 8, h.clone());
        let mut reference = build(6, 8, h.clone());
        let mut w_f = Matrix::zeros(6, 8);
        let mut w_r = Matrix::zeros(6, 8);
        for (i, g) in grads.iter().enumerate() {
            let t = i as u64 + 1;
            fused.update(&mut w_f, g, t, 0.01);
            reference.update_legacy_alloc(&mut w_r, g, t, 0.01);
            assert_eq!(
                w_f.data, w_r.data,
                "{label}: workspace path diverged from allocating path at step {t}"
            );
        }
        assert!(fused.scratch_bytes() > 0, "{label}: workspace never grew");
    }
}

#[test]
fn workspace_path_matches_allocating_path_async_drained() {
    // Same pin in drained-async mode: publication timing is deterministic,
    // so the two paths must stay bitwise equal under background refreshes
    // too. Presets without async bases degrade to the inline comparison.
    use soap_lab::optim::compose::presets;
    use soap_lab::optim::DynComposed;
    let h = Hyper { precond_freq: 5, ..Hyper::default() };
    type Build = fn(usize, usize, Hyper) -> DynComposed;
    let builds: [(&str, Build); 5] = [
        ("soap", presets::soap),
        ("shampoo", presets::shampoo),
        ("galore", presets::galore),
        ("adamw", presets::adamw),
        ("adafactor", presets::adafactor),
    ];
    for (label, build) in builds {
        let svc_f = Arc::new(RefreshService::new(1));
        let svc_r = Arc::new(RefreshService::new(1));
        let grads = seeded_grads(951, 17, 6, 6);
        let mut fused = build(6, 6, h.clone());
        let mut reference = build(6, 6, h.clone());
        assert_eq!(fused.attach_async(&svc_f), reference.attach_async(&svc_r));
        let mut w_f = Matrix::zeros(6, 6);
        let mut w_r = Matrix::zeros(6, 6);
        for (i, g) in grads.iter().enumerate() {
            let t = i as u64 + 1;
            fused.update(&mut w_f, g, t, 0.01);
            svc_f.wait_idle();
            reference.update_legacy_alloc(&mut w_r, g, t, 0.01);
            svc_r.wait_idle();
            assert_eq!(
                w_f.data, w_r.data,
                "{label} (async drained): workspace path diverged at step {t}"
            );
        }
    }
}
