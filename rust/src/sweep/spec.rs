//! Declarative sweep specification: a JSON base configuration plus a `grid`
//! object whose axes expand into the Cartesian product of jobs. Benches and
//! the `sweep-lr` preset skip the JSON and build [`JobSpec`]s directly.

use anyhow::Result;

use crate::experiments::harness::{paper_schedule, tuned_lr};
use crate::optim::{FreqSchedule, Hyper, OptKind, Schedule};
use crate::session::{Backend, ModelSpec, SessionBuilder, TrainSession};
use crate::util::json::Json;

/// One planned training job: everything needed to build its
/// [`TrainSession`], plus the parameter assignment that tags its lines in
/// the multiplexed JSONL stream.
#[derive(Clone, Debug)]
pub struct JobSpec {
    /// Stable id (`j000`, `j001`, …) — grid-product index for spec-derived
    /// sweeps; also the key in the journal, results table, and JSONL tags.
    pub id: String,
    /// `(axis, value)` pairs this job was assigned from the grid, in axis
    /// order. Rides along as the `assign` tag on every JSONL line.
    pub assign: Vec<(String, String)>,
    pub model: String,
    pub opt: OptKind,
    pub hyper: Hyper,
    /// `None` picks the per-optimizer tuned LR
    /// ([`crate::experiments::harness::tuned_lr`]).
    pub lr: Option<f32>,
    /// Constant LR instead of the paper's warmup-cosine schedule.
    pub constant_lr: bool,
    pub steps: u64,
    pub seed: u64,
    pub grad_accum: usize,
    /// Override the session backend (`None` = the builder default,
    /// sharded). `sweep-lr --backend serial|pjrt` rides this.
    pub backend: Option<Backend>,
    /// Optional seeded fault-injection plan for this job
    /// ([`crate::fault::FaultPlan`] grammar). The fault seam is
    /// process-global, so chaos sweeps should run with concurrency 1.
    pub fault_plan: Option<String>,
}

impl JobSpec {
    /// A job with the sweep defaults (SOAP on `nplm-tiny`, tuned LR, paper
    /// schedule, seed 0).
    pub fn new(id: impl Into<String>, model: &str, opt: OptKind, steps: u64) -> Self {
        Self {
            id: id.into(),
            assign: Vec::new(),
            model: model.to_string(),
            opt,
            hyper: Hyper::default(),
            lr: None,
            constant_lr: false,
            steps,
            seed: 0,
            grad_accum: 1,
            backend: None,
            fault_plan: None,
        }
    }

    pub fn with_assign(mut self, axis: &str, value: impl Into<String>) -> Self {
        self.assign.push((axis.to_string(), value.into()));
        self
    }

    pub fn with_hyper(mut self, h: Hyper) -> Self {
        self.hyper = h;
        self
    }

    pub fn with_lr(mut self, lr: f32) -> Self {
        self.lr = Some(lr);
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn constant_lr(mut self, on: bool) -> Self {
        self.constant_lr = on;
        self
    }

    /// The job's `assign` pairs as a JSON object (the JSONL line tag).
    pub fn assign_json(&self) -> Json {
        Json::obj(self.assign.iter().map(|(k, v)| (k.as_str(), Json::str(v.clone()))).collect())
    }

    /// Map onto the session builder — the same construction path `main.rs`
    /// and the figure benches use, so a sweep job and a CLI run of the same
    /// configuration are identical.
    pub fn session(&self, workers: usize, artifacts_dir: &str) -> Result<SessionBuilder> {
        let lr = self.lr.unwrap_or_else(|| tuned_lr(self.opt));
        let mut b = TrainSession::builder()
            .model(ModelSpec::parse(&self.model)?)
            .artifacts_dir(artifacts_dir)
            .optimizer(self.opt)
            .hyper(self.hyper.clone())
            .schedule(if self.constant_lr {
                Schedule::Constant { lr }
            } else {
                paper_schedule(lr, self.steps)
            })
            .steps(self.steps)
            .seed(self.seed)
            .grad_accum(self.grad_accum)
            .workers(workers);
        if let Some(backend) = self.backend {
            b = b.backend(backend);
        }
        if let Some(plan) = &self.fault_plan {
            b = b.fault_plan(plan, 0);
        }
        Ok(b)
    }
}

/// A parsed sweep: name, per-job worker threads, and the expanded job list.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    pub name: String,
    /// Optimizer worker threads per job (jobs run concurrently, so this
    /// stays small; default 2).
    pub workers: usize,
    pub artifacts_dir: String,
    pub jobs: Vec<JobSpec>,
    /// The source document, recorded verbatim in the sweep manifest.
    pub source: Json,
}

/// Base keys accepted at the top level of a sweep spec (also valid as grid
/// axes, except `name`, `workers`, `artifacts`, and `grid` itself).
pub const SPEC_KEYS: &str = "name, model, optimizer, lr, constant-lr, steps, seed, \
grad-accum, precond-freq, precondition-1d, one-sided, factorized, fault-plan, \
workers, artifacts, grid";

/// Grid axis keys (each maps to an array of values in the `grid` object).
pub const AXIS_KEYS: &str =
    "model, optimizer, lr, constant-lr, steps, seed, grad-accum, precond-freq, \
precondition-1d, one-sided, factorized, fault-plan";

fn bad_value(key: &str, v: &Json) -> anyhow::Error {
    anyhow::anyhow!("sweep spec key '{key}': unsupported value {}", v.dump())
}

/// Apply one key to a job template. `value` is JSON (so grid axes can mix
/// numbers and strings naturally).
fn apply_key(job: &mut JobSpec, key: &str, value: &Json) -> Result<()> {
    match key {
        "model" => job.model = value.as_str().ok_or_else(|| bad_value(key, value))?.to_string(),
        "optimizer" => {
            job.opt = OptKind::parse(value.as_str().ok_or_else(|| bad_value(key, value))?)?;
        }
        "lr" => job.lr = Some(value.as_f64().ok_or_else(|| bad_value(key, value))? as f32),
        "constant-lr" => job.constant_lr = value.as_bool().ok_or_else(|| bad_value(key, value))?,
        "steps" => {
            let n = value.as_f64().ok_or_else(|| bad_value(key, value))?;
            anyhow::ensure!(n >= 1.0, "sweep spec: steps must be ≥ 1");
            job.steps = n as u64;
        }
        "seed" => job.seed = value.as_f64().ok_or_else(|| bad_value(key, value))? as u64,
        "grad-accum" => {
            let n = value.as_f64().ok_or_else(|| bad_value(key, value))? as usize;
            anyhow::ensure!(n >= 1, "sweep spec: grad-accum must be ≥ 1");
            job.grad_accum = n;
        }
        // Number = constant frequency; string = `f@start` schedule (same
        // normalization as the config file: a schedule skipping step 0
        // inherits the job's current base frequency).
        "precond-freq" => match value {
            Json::Num(f) => {
                anyhow::ensure!(*f >= 1.0, "sweep spec: precond-freq must be ≥ 1");
                job.hyper.precond_freq = *f as u64;
                job.hyper.precond_freq_schedule = None;
            }
            Json::Str(s) => {
                let parsed = FreqSchedule::parse(s)?;
                let sched = if parsed.freq_at(0).is_some() {
                    parsed
                } else {
                    let mut pieces = vec![(0, job.hyper.precond_freq)];
                    pieces.extend_from_slice(parsed.pieces());
                    FreqSchedule::new(&pieces)?
                };
                job.hyper.precond_freq =
                    sched.freq_at(0).expect("schedule covers step 0");
                job.hyper.precond_freq_schedule = Some(sched);
            }
            other => return Err(bad_value(key, other)),
        },
        "precondition-1d" => {
            job.hyper.precondition_1d = value.as_bool().ok_or_else(|| bad_value(key, value))?;
        }
        "one-sided" => {
            job.hyper.one_sided = value.as_bool().ok_or_else(|| bad_value(key, value))?;
        }
        "factorized" => {
            job.hyper.factorized = value.as_bool().ok_or_else(|| bad_value(key, value))?;
        }
        "fault-plan" => {
            job.fault_plan =
                Some(value.as_str().ok_or_else(|| bad_value(key, value))?.to_string());
        }
        other => anyhow::bail!("unknown sweep spec key '{other}': expected one of {SPEC_KEYS}"),
    }
    Ok(())
}

/// Display form of a grid value for the `assign` tag.
fn tag_value(v: &Json) -> String {
    match v {
        Json::Str(s) => s.clone(),
        other => other.dump(),
    }
}

impl SweepSpec {
    /// Wrap an explicit job list (benches, the `sweep-lr` preset).
    pub fn from_jobs(name: &str, jobs: Vec<JobSpec>) -> Self {
        let source = Json::obj(vec![
            ("name", Json::str(name)),
            ("jobs", Json::num(jobs.len() as f64)),
            ("origin", Json::str("api")),
        ]);
        Self {
            name: name.to_string(),
            workers: 2,
            artifacts_dir: "artifacts".to_string(),
            jobs,
            source,
        }
    }

    /// Parse a sweep spec document. Grid axes expand in lexicographic axis
    /// order, values in listed order; job ids are the product indices
    /// (`j000`, `j001`, …), so the expansion is fully deterministic.
    pub fn parse(text: &str) -> Result<Self> {
        let doc = Json::parse(text).map_err(|e| anyhow::anyhow!("sweep spec: {e}"))?;
        let obj = doc
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("sweep spec must be a JSON object"))?;

        let name = doc.get("name").as_str().unwrap_or("sweep").to_string();
        let workers = doc.get("workers").as_usize().unwrap_or(2).max(1);
        let artifacts_dir =
            doc.get("artifacts").as_str().unwrap_or("artifacts").to_string();

        // Base template from the scalar keys.
        let mut base = JobSpec::new("j000", "nplm-tiny", OptKind::Soap, 50);
        for (key, value) in obj {
            match key.as_str() {
                "name" | "workers" | "artifacts" | "grid" => {}
                other => apply_key(&mut base, other, value)?,
            }
        }

        // Grid axes: BTreeMap iteration gives lexicographic axis order.
        let mut axes: Vec<(String, Vec<Json>)> = Vec::new();
        if let Some(grid) = doc.get("grid").as_obj() {
            for (axis, values) in grid {
                let values = values.as_arr().ok_or_else(|| {
                    anyhow::anyhow!("sweep grid axis '{axis}' must be an array of values")
                })?;
                anyhow::ensure!(
                    !values.is_empty(),
                    "sweep grid axis '{axis}' has no values"
                );
                anyhow::ensure!(
                    !matches!(axis.as_str(), "name" | "workers" | "artifacts" | "grid"),
                    "'{axis}' cannot be a grid axis (expected one of {AXIS_KEYS})"
                );
                axes.push((axis.clone(), values.to_vec()));
            }
        }

        let total: usize = axes.iter().map(|(_, v)| v.len()).product();
        anyhow::ensure!(total >= 1, "sweep grid expands to zero jobs");
        let mut jobs = Vec::with_capacity(total);
        for idx in 0..total {
            let mut job = base.clone();
            job.id = format!("j{idx:03}");
            job.assign.clear();
            // Mixed-radix decomposition: the LAST axis varies fastest.
            let mut rem = idx;
            let mut coords = vec![0usize; axes.len()];
            for (a, (_, values)) in axes.iter().enumerate().rev() {
                coords[a] = rem % values.len();
                rem /= values.len();
            }
            for ((axis, values), &c) in axes.iter().zip(&coords) {
                let v = &values[c];
                apply_key(&mut job, axis, v)?;
                job.assign.push((axis.clone(), tag_value(v)));
            }
            jobs.push(job);
        }

        Ok(Self { name, workers, artifacts_dir, jobs, source: doc })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_expands_deterministically() {
        let spec = SweepSpec::parse(
            r#"{
                "name": "demo",
                "model": "nplm-tiny",
                "steps": 10,
                "constant-lr": true,
                "grid": {
                    "lr": [0.01, 0.00316],
                    "optimizer": ["soap", "adamw"]
                }
            }"#,
        )
        .unwrap();
        assert_eq!(spec.name, "demo");
        assert_eq!(spec.jobs.len(), 4);
        // Axes in lexicographic order (lr before optimizer); last axis
        // varies fastest.
        let ids: Vec<&str> = spec.jobs.iter().map(|j| j.id.as_str()).collect();
        assert_eq!(ids, ["j000", "j001", "j002", "j003"]);
        assert_eq!(spec.jobs[0].lr, Some(0.01));
        assert_eq!(spec.jobs[0].opt, OptKind::Soap);
        assert_eq!(spec.jobs[1].opt, OptKind::AdamW);
        assert_eq!(spec.jobs[2].lr, Some(0.00316));
        assert!(spec.jobs.iter().all(|j| j.constant_lr && j.steps == 10));
        assert_eq!(
            spec.jobs[3].assign,
            vec![("lr".to_string(), "0.00316".to_string()),
                 ("optimizer".to_string(), "adamw".to_string())]
        );
        // Each job maps onto a valid builder without touching the fs.
        for j in &spec.jobs {
            j.session(2, "artifacts").unwrap().validate().unwrap();
        }
    }

    #[test]
    fn base_keys_cover_hyper_knobs() {
        let spec = SweepSpec::parse(
            r#"{
                "model": "nplm-tiny",
                "steps": 5,
                "precond-freq": "4@0,10@20",
                "precondition-1d": true,
                "one-sided": true
            }"#,
        )
        .unwrap();
        assert_eq!(spec.jobs.len(), 1);
        let h = &spec.jobs[0].hyper;
        assert_eq!(h.precond_freq, 4);
        assert_eq!(
            h.precond_freq_schedule.unwrap().pieces(),
            &[(0, 4), (20, 10)]
        );
        assert!(h.precondition_1d && h.one_sided);
    }

    #[test]
    fn rejects_unknown_keys_and_bad_values() {
        let e = SweepSpec::parse(r#"{"bogus": 1}"#).unwrap_err().to_string();
        assert!(e.contains("bogus") && e.contains("model"), "{e}");
        let e = SweepSpec::parse(r#"{"grid": {"lr": []}}"#).unwrap_err().to_string();
        assert!(e.contains("no values"), "{e}");
        let e = SweepSpec::parse(r#"{"steps": 0}"#).unwrap_err().to_string();
        assert!(e.contains("steps"), "{e}");
        assert!(SweepSpec::parse("not json").is_err());
        let e = SweepSpec::parse(r#"{"grid": {"workers": [1]}}"#).unwrap_err().to_string();
        assert!(e.contains("axis"), "{e}");
    }

    #[test]
    fn from_jobs_wraps_explicit_lists() {
        let jobs = vec![
            JobSpec::new("lr-0", "nplm-tiny", OptKind::Soap, 5).with_lr(0.01),
            JobSpec::new("lr-1", "nplm-tiny", OptKind::Soap, 5).with_lr(0.001),
        ];
        let spec = SweepSpec::from_jobs("lr-grid", jobs);
        assert_eq!(spec.jobs.len(), 2);
        assert_eq!(spec.source.get("origin").as_str(), Some("api"));
    }
}
