//! Sweep orchestrator: concurrent budget-aware hyperparameter sweeps with
//! streaming results and crash-safe resume (`soap-lab sweep`).
//!
//! The pipeline, one module per stage:
//!
//! 1. [`spec`] — parse a declarative sweep spec (base config + `grid`
//!    axes) into a deterministic [`JobSpec`] list, or wrap an explicit job
//!    list built in code (benches, `sweep-lr`).
//! 2. [`planner`] — estimate each job's resident bytes and total FLOPs
//!    from its model's tensor shapes via the coordinator's cost model, and
//!    order jobs longest-first.
//! 3. [`scheduler`] — [`Admission`]: the global memory budget and
//!    concurrency cap that gate job starts.
//! 4. [`runner`] — worker threads execute jobs as builder-validated
//!    [`crate::session::TrainSession`]s, multiplex their metrics into one
//!    tagged JSONL stream, journal terminal events for crash-safe resume,
//!    and emit `SWEEP_results.json`.
//! 5. [`manifest`] — the on-disk formats (manifest, journal, results) and
//!    atomic/append-safe IO helpers.
//!
//! ```no_run
//! use soap_lab::sweep::{run_sweep, SweepOptions, SweepSpec};
//!
//! # fn main() -> anyhow::Result<()> {
//! let spec = SweepSpec::parse(
//!     r#"{"name": "lr-grid", "model": "nplm-tiny", "steps": 50,
//!         "grid": {"lr": [0.01, 0.00316], "optimizer": ["soap", "adamw"]}}"#,
//! )?;
//! let outcome = run_sweep(&spec, &SweepOptions {
//!     out_dir: "sweep-out".into(),
//!     max_mem_bytes: 256 << 20,
//!     max_concurrency: 2,
//!     ..SweepOptions::default()
//! })?;
//! for row in &outcome.rows {
//!     println!("{} {}", row.get("job_id").as_str().unwrap_or("?"), row.dump());
//! }
//! # Ok(())
//! # }
//! ```

pub mod manifest;
pub mod planner;
pub mod runner;
pub mod scheduler;
pub mod spec;

pub use manifest::{JobCkpt, Journal};
pub use planner::{plan, JobPlan};
pub use runner::{run_sweep, SweepOptions, SweepOutcome};
pub use scheduler::{Admission, Admit};
pub use spec::{JobSpec, SweepSpec};
