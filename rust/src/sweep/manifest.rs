//! Sweep persistence: the manifest, the append-only state journal, and the
//! final results summary.
//!
//! Three files live in the sweep output directory:
//!
//! - `SWEEP_manifest.json` — the plan: spec source, budget, concurrency,
//!   and per-job estimates. Written once (atomically) at sweep start and
//!   validated on resume so a resumed sweep can't silently run a different
//!   job set.
//! - `SWEEP_state.jsonl` — append-only journal of `done` / `failed` /
//!   `ckpt` events, one JSON object per line. Crash-safe: a torn final
//!   line is skipped on load.
//! - `SWEEP_results.json` — the summary, written atomically only when
//!   every job has a row. Contains deterministic fields only (no
//!   wall-clock), so an interrupted-and-resumed sweep produces a
//!   bitwise-identical file.

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

use super::planner::JobPlan;

/// Write `text` to `path` atomically: temp file in the same directory,
/// fsync, rename.
pub fn write_atomic(path: &Path, text: &str) -> Result<()> {
    let tmp = path.with_extension("tmp");
    {
        let mut f = File::create(&tmp)
            .with_context(|| format!("create {}", tmp.display()))?;
        f.write_all(text.as_bytes())?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("rename into {}", path.display()))?;
    Ok(())
}

/// Append one JSON line to the journal and sync it — each event is durable
/// before the sweep moves on.
pub fn append_event(path: &Path, event: &Json) -> Result<()> {
    let mut f = OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .with_context(|| format!("open journal {}", path.display()))?;
    writeln!(f, "{}", event.dump())?;
    f.sync_all()?;
    Ok(())
}

/// A journaled mid-flight checkpoint reference for one job.
#[derive(Clone, Debug)]
pub struct JobCkpt {
    pub step: u64,
    /// The loss trajectory up to (and including) `step`, replayed into the
    /// resumed job's row so the final trajectory matches an uninterrupted
    /// run exactly.
    pub losses: Vec<(u64, f32)>,
}

/// The journal replayed into memory: terminal rows plus the latest
/// checkpoint event per job.
#[derive(Debug, Default)]
pub struct Journal {
    /// Terminal (`done` / `failed`) result rows by job id.
    pub rows: BTreeMap<String, Json>,
    /// Latest `ckpt` event per job (later events supersede earlier ones).
    pub ckpts: BTreeMap<String, JobCkpt>,
}

impl Journal {
    /// Load a journal, tolerating a torn trailing line (the crash case the
    /// journal exists for). A missing file is an empty journal.
    pub fn load(path: &Path) -> Result<Self> {
        let mut journal = Journal::default();
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(journal),
            Err(e) => return Err(e).with_context(|| format!("read {}", path.display())),
        };
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let Ok(event) = Json::parse(line) else { continue };
            let Some(job) = event.get("job").as_str() else { continue };
            match event.get("event").as_str() {
                Some("done") | Some("failed") => {
                    journal.rows.insert(job.to_string(), event.get("row").clone());
                }
                Some("ckpt") => {
                    let step = event.get("step").as_f64().unwrap_or(0.0) as u64;
                    let losses = event
                        .get("losses")
                        .as_arr()
                        .map(|arr| {
                            arr.iter()
                                .filter_map(|pair| {
                                    let pair = pair.as_arr()?;
                                    let s = pair.first()?.as_f64()? as u64;
                                    let l = pair.get(1)?.as_f64()? as f32;
                                    Some((s, l))
                                })
                                .collect()
                        })
                        .unwrap_or_default();
                    journal.ckpts.insert(job.to_string(), JobCkpt { step, losses });
                }
                _ => {}
            }
        }
        Ok(journal)
    }
}

/// `losses` as the JSON `[[step, loss], …]` array.
pub fn losses_json(losses: &[(u64, f32)]) -> Json {
    Json::arr(
        losses
            .iter()
            .map(|&(s, l)| Json::arr(vec![Json::num(s as f64), Json::num(l as f64)])),
    )
}

/// A journal `ckpt` event.
pub fn ckpt_event(job_id: &str, step: u64, losses: &[(u64, f32)]) -> Json {
    Json::obj(vec![
        ("event", Json::str("ckpt")),
        ("job", Json::str(job_id)),
        ("step", Json::num(step as f64)),
        ("losses", losses_json(losses)),
    ])
}

/// A journal terminal event wrapping a result row.
pub fn row_event(job_id: &str, status: &str, row: &Json) -> Json {
    Json::obj(vec![
        ("event", Json::str(status)),
        ("job", Json::str(job_id)),
        ("row", row.clone()),
    ])
}

/// Render the sweep manifest document.
pub fn manifest_json(
    name: &str,
    source: &Json,
    budget_bytes: u64,
    concurrency: usize,
    plans: &[JobPlan],
) -> Json {
    let mut jobs: Vec<&JobPlan> = plans.iter().collect();
    jobs.sort_by(|a, b| a.job.id.cmp(&b.job.id));
    Json::obj(vec![
        ("name", Json::str(name)),
        ("spec", source.clone()),
        ("budget_bytes", Json::num(budget_bytes as f64)),
        ("concurrency", Json::num(concurrency as f64)),
        (
            "jobs",
            Json::arr(
                jobs.iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("id", Json::str(p.job.id.clone())),
                            ("assign", p.job.assign_json()),
                            ("model", Json::str(p.job.model.clone())),
                            ("optimizer", Json::str(p.job.opt.name())),
                            ("steps", Json::num(p.job.steps as f64)),
                            ("est_bytes", Json::num(p.est_bytes as f64)),
                            ("est_flops", Json::num(p.est_flops)),
                        ])
                    }),
            ),
        ),
    ])
}

/// The final summary: rows in job-id order. Deterministic fields only —
/// budget and concurrency stay in the manifest so runs that only differ in
/// scheduling produce identical results files.
pub fn results_json(name: &str, rows: &BTreeMap<String, Json>) -> Json {
    Json::obj(vec![
        ("name", Json::str(name)),
        ("jobs", Json::arr(rows.values().cloned())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn journal_roundtrips_and_last_ckpt_wins() {
        let dir = std::env::temp_dir().join("soap-sweep-manifest-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("SWEEP_state.jsonl");
        let _ = std::fs::remove_file(&path);

        let row = Json::obj(vec![
            ("job_id", Json::str("j000")),
            ("status", Json::str("done")),
        ]);
        append_event(&path, &row_event("j000", "done", &row)).unwrap();
        append_event(&path, &ckpt_event("j001", 5, &[(1, 2.0), (5, 1.5)])).unwrap();
        append_event(&path, &ckpt_event("j001", 10, &[(1, 2.0), (10, 1.0)])).unwrap();
        // Torn trailing line: must be skipped, not fatal.
        {
            let mut f = OpenOptions::new().append(true).open(&path).unwrap();
            write!(f, "{{\"event\":\"ckpt\",\"job\":\"j0").unwrap();
        }

        let journal = Journal::load(&path).unwrap();
        assert_eq!(journal.rows.len(), 1);
        assert_eq!(journal.rows["j000"].get("status").as_str(), Some("done"));
        let ck = &journal.ckpts["j001"];
        assert_eq!(ck.step, 10);
        assert_eq!(ck.losses, vec![(1, 2.0), (10, 1.0)]);

        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn missing_journal_is_empty() {
        let journal =
            Journal::load(Path::new("/definitely/not/here.jsonl")).unwrap();
        assert!(journal.rows.is_empty() && journal.ckpts.is_empty());
    }

    #[test]
    fn write_atomic_replaces_content() {
        let dir = std::env::temp_dir().join("soap-sweep-atomic-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("SWEEP_results.json");
        write_atomic(&path, "first").unwrap();
        write_atomic(&path, "second").unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "second");
        assert!(!path.with_extension("tmp").exists());
        let _ = std::fs::remove_file(&path);
    }
}
