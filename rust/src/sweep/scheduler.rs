//! Budget-aware admission control for concurrent sweep jobs.
//!
//! [`Admission`] is deliberately passive bookkeeping — no threads of its
//! own. The runner holds it under a mutex, asks [`Admission::admit`] before
//! starting a job, and calls [`Admission::release`] when the job finishes.
//! Invariant (pinned by a property test in `rust/tests/sweep.rs`): the sum
//! of admitted footprints never exceeds the budget.

/// Outcome of an admission query.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admit {
    /// Fits right now — the job may start.
    Start,
    /// Doesn't fit alongside the currently running jobs; retry after a
    /// release.
    Wait,
    /// Larger than the whole budget — can never run under it.
    TooBig,
}

/// Tracks running jobs against a global memory budget and a concurrency
/// cap.
#[derive(Debug)]
pub struct Admission {
    budget: u64,
    max_concurrency: usize,
    used: u64,
    running: Vec<(String, u64)>,
}

impl Admission {
    /// `budget` of 0 means unlimited memory; `max_concurrency` is clamped
    /// to at least 1.
    pub fn new(budget: u64, max_concurrency: usize) -> Self {
        Self {
            budget: if budget == 0 { u64::MAX } else { budget },
            max_concurrency: max_concurrency.max(1),
            used: 0,
            running: Vec::new(),
        }
    }

    pub fn budget(&self) -> u64 {
        self.budget
    }

    /// Would a job of `bytes` be admitted right now? (Pure query.)
    pub fn decide(&self, bytes: u64) -> Admit {
        if bytes > self.budget {
            return Admit::TooBig;
        }
        if self.running.len() >= self.max_concurrency {
            return Admit::Wait;
        }
        if self.used.saturating_add(bytes) > self.budget {
            return Admit::Wait;
        }
        Admit::Start
    }

    /// Query and, on [`Admit::Start`], record the job as running.
    pub fn admit(&mut self, id: &str, bytes: u64) -> Admit {
        let verdict = self.decide(bytes);
        if verdict == Admit::Start {
            self.used = self.used.saturating_add(bytes);
            self.running.push((id.to_string(), bytes));
        }
        verdict
    }

    /// Release a finished job's footprint. Unknown ids are ignored (a job
    /// rejected as [`Admit::TooBig`] never held a reservation).
    pub fn release(&mut self, id: &str) {
        if let Some(pos) = self.running.iter().position(|(j, _)| j == id) {
            let (_, bytes) = self.running.remove(pos);
            self.used = self.used.saturating_sub(bytes);
        }
    }

    pub fn running(&self) -> usize {
        self.running.len()
    }

    pub fn used_bytes(&self) -> u64 {
        self.used
    }

    /// The invariant the property test pins: reserved bytes within budget,
    /// concurrency within cap, and `used` consistent with the running set.
    pub fn check_invariant(&self) -> bool {
        self.used <= self.budget
            && self.running.len() <= self.max_concurrency
            && self.used == self.running.iter().map(|(_, b)| b).sum::<u64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admits_until_budget_then_waits() {
        let mut a = Admission::new(100, 8);
        assert_eq!(a.admit("j0", 60), Admit::Start);
        assert_eq!(a.admit("j1", 60), Admit::Wait);
        assert_eq!(a.admit("j2", 40), Admit::Start);
        assert_eq!(a.used_bytes(), 100);
        a.release("j0");
        assert_eq!(a.admit("j1", 60), Admit::Start);
        assert!(a.check_invariant());
    }

    #[test]
    fn concurrency_cap_blocks_even_with_budget_room() {
        let mut a = Admission::new(0, 2);
        assert_eq!(a.admit("j0", 10), Admit::Start);
        assert_eq!(a.admit("j1", 10), Admit::Start);
        assert_eq!(a.decide(10), Admit::Wait);
        a.release("j1");
        assert_eq!(a.admit("j2", 10), Admit::Start);
    }

    #[test]
    fn oversized_job_is_too_big_not_wait() {
        let mut a = Admission::new(100, 4);
        assert_eq!(a.admit("j0", 101), Admit::TooBig);
        assert_eq!(a.running(), 0);
        // TooBig never reserves; releasing it is a no-op.
        a.release("j0");
        assert!(a.check_invariant());
    }

    #[test]
    fn zero_budget_means_unlimited() {
        let mut a = Admission::new(0, 1);
        assert_eq!(a.budget(), u64::MAX);
        assert_eq!(a.admit("j0", u64::MAX / 2), Admit::Start);
        assert_eq!(a.decide(u64::MAX), Admit::Wait); // concurrency, not memory
    }
}
