//! Per-job footprint estimation and LPT ordering.
//!
//! The planner reuses the coordinator's per-tensor cost model
//! ([`crate::coordinator::sharded::tensor_update_flops`]) to estimate each job's
//! optimizer-state bytes and per-step FLOPs from its model's tensor shapes,
//! then orders jobs longest-processing-time-first so the scheduler starts
//! the heavyweights while small jobs backfill the remaining budget.

use crate::coordinator::sharded::tensor_update_flops;
use crate::linalg::TensorShape;
use crate::optim::OptKind;
use crate::runtime::Manifest;
use crate::session::ModelSpec;

use super::spec::JobSpec;

/// A job plus its estimated resources. `est_bytes` gates admission;
/// `est_flops` (per-run total) drives the LPT ordering.
#[derive(Clone, Debug)]
pub struct JobPlan {
    pub job: JobSpec,
    /// Estimated resident bytes while the job runs: params, grads,
    /// accumulators, Adam moments, and (for preconditioned optimizers)
    /// rotated moments plus per-mode factor/basis matrices, with scratch
    /// headroom for the largest tensor.
    pub est_bytes: u64,
    /// Estimated total FLOPs for the run (per-step cost × steps).
    pub est_flops: f64,
}

/// Tensor shapes for a job's model, or `None` when they can't be resolved
/// (e.g. an artifact model whose manifest isn't on disk). Unknown models
/// get a zero estimate — admitted immediately, failing fast at session
/// build into an isolated failed row rather than blocking the sweep.
pub fn job_shapes(job: &JobSpec, artifacts_dir: &str) -> Option<Vec<TensorShape>> {
    match ModelSpec::parse(&job.model).ok()? {
        ModelSpec::Nplm { cfg, .. } => Some(cfg.tensor_shapes()),
        ModelSpec::Artifact { name } => {
            let manifest = Manifest::load(std::path::Path::new(artifacts_dir)).ok()?;
            let info = manifest.config(&name).ok()?;
            Some(
                info.params
                    .iter()
                    .map(|(_, r, c)| TensorShape::matrix(*r, *c))
                    .collect(),
            )
        }
    }
}

/// Whether the optimizer keeps preconditioner state (factors + eigenbases
/// + rotated moments) in addition to the Adam-style moments.
fn preconditioned(opt: OptKind) -> bool {
    !matches!(opt.canonical(), OptKind::AdamW | OptKind::Adafactor)
}

/// Estimate `(bytes, flops_per_step)` for one job from its tensor shapes.
pub fn estimate(job: &JobSpec, shapes: &[TensorShape]) -> (u64, f64) {
    const F32: u64 = 4;
    let precond = preconditioned(job.opt);
    let mut bytes: u64 = 0;
    let mut flops: f64 = 0.0;
    let mut max_numel: u64 = 0;
    for shape in shapes {
        let numel = shape.numel() as u64;
        max_numel = max_numel.max(numel);
        // Params + grads + grad-accum buffer, then the two Adam moments.
        bytes += 3 * numel * F32;
        bytes += 2 * numel * F32;
        if precond {
            // Rotated moment plus per-mode factor and eigenbasis matrices.
            bytes += numel * F32;
            for &d in shape.dims() {
                bytes += 2 * (d as u64) * (d as u64) * F32;
            }
            flops += tensor_update_flops(shape.dims());
        } else {
            flops += 2.0 * numel as f64;
        }
    }
    // Scratch headroom: rotation workspaces for the largest tensor.
    bytes += 2 * max_numel * F32;
    (bytes, flops * job.steps as f64)
}

/// Plan a job list: estimate each job and sort longest-first (stable, so
/// equal-cost jobs keep id order and the plan is deterministic).
pub fn plan(jobs: &[JobSpec], artifacts_dir: &str) -> Vec<JobPlan> {
    let mut plans: Vec<JobPlan> = jobs
        .iter()
        .map(|job| {
            let (est_bytes, est_flops) = match job_shapes(job, artifacts_dir) {
                Some(shapes) => estimate(job, &shapes),
                None => (0, 0.0),
            };
            JobPlan { job: job.clone(), est_bytes, est_flops }
        })
        .collect();
    plans.sort_by(|a, b| {
        b.est_flops
            .partial_cmp(&a.est_flops)
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    plans
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::OptKind;
    use crate::sweep::spec::JobSpec;

    #[test]
    fn estimates_scale_with_model_and_optimizer() {
        let soap = JobSpec::new("a", "nplm-tiny", OptKind::Soap, 10);
        let adamw = JobSpec::new("b", "nplm-tiny", OptKind::AdamW, 10);
        let shapes = job_shapes(&soap, "artifacts").unwrap();
        assert!(!shapes.is_empty());
        let (soap_bytes, soap_flops) = estimate(&soap, &shapes);
        let (adamw_bytes, adamw_flops) = estimate(&adamw, &shapes);
        // Preconditioned state strictly dominates Adam-only state.
        assert!(soap_bytes > adamw_bytes);
        assert!(soap_flops > adamw_flops);

        let big = JobSpec::new("c", "nplm", OptKind::Soap, 10);
        let big_shapes = job_shapes(&big, "artifacts").unwrap();
        let (big_bytes, _) = estimate(&big, &big_shapes);
        assert!(big_bytes > soap_bytes, "nplm should out-weigh nplm-tiny");
    }

    #[test]
    fn estimates_scale_with_steps() {
        let short = JobSpec::new("a", "nplm-tiny", OptKind::Soap, 10);
        let long = JobSpec::new("b", "nplm-tiny", OptKind::Soap, 100);
        let shapes = job_shapes(&short, "artifacts").unwrap();
        let (_, f_short) = estimate(&short, &shapes);
        let (_, f_long) = estimate(&long, &shapes);
        assert!((f_long / f_short - 10.0).abs() < 1e-9);
    }

    #[test]
    fn plan_orders_longest_first_and_is_stable() {
        let jobs = vec![
            JobSpec::new("j000", "nplm-tiny", OptKind::AdamW, 10),
            JobSpec::new("j001", "nplm", OptKind::Soap, 100),
            JobSpec::new("j002", "nplm-tiny", OptKind::AdamW, 10),
        ];
        let plans = plan(&jobs, "artifacts");
        assert_eq!(plans[0].job.id, "j001");
        // Equal-cost jobs keep their id order (stable sort).
        assert_eq!(plans[1].job.id, "j000");
        assert_eq!(plans[2].job.id, "j002");
    }

    #[test]
    fn unknown_artifact_model_gets_zero_estimate() {
        let job = JobSpec::new("a", "no-such-model", OptKind::Soap, 10);
        assert!(job_shapes(&job, "definitely-missing-dir").is_none());
        let plans = plan(&[job], "definitely-missing-dir");
        assert_eq!(plans[0].est_bytes, 0);
    }
}
