//! The sweep executor: worker threads pull jobs off the LPT-ordered plan
//! under [`Admission`] control, run each as a [`TrainSession`] streaming
//! tagged JSONL through one [`SharedLineWriter`], journal every terminal
//! event, and write the results summary when the last row lands.
//!
//! ## Concurrency model
//!
//! `max_concurrency` OS threads share a mutex-protected scheduler state
//! (admission bookkeeping + claimed set + result rows) and a condvar.
//! Each worker scans the plan longest-first and claims the first job the
//! budget admits (first-fit backfill: a small job may start while a big
//! one waits). Sessions are built under a dedicated build lock because
//! [`SessionBuilder::build`] flips process-global seams (telemetry enable,
//! fault-plan install) — every job therefore runs with the sweep-level
//! telemetry flag, and per-job fault plans are only meaningful at
//! `max_concurrency = 1`.
//!
//! ## Halt and resume
//!
//! `halt_after_steps` stops the sweep after N training steps summed across
//! all jobs (the deterministic interruption the resume test pins; it also
//! models a crash at an arbitrary point). Each in-flight job saves a
//! checkpoint and journals it; completed rows are already journaled. A
//! `--resume-sweep` run skips journaled rows, resumes checkpointed jobs
//! via [`SessionBuilder::resume_from`] (bitwise-identical continuation),
//! and rewrites the metrics JSONL to drop lines past each resumed job's
//! checkpoint — so the final files match an uninterrupted run exactly at
//! `max_concurrency = 1` (with more workers, JSONL interleaving is
//! scheduler-dependent; rows and summary still match).

use std::collections::{BTreeMap, BTreeSet};
use std::fs::OpenOptions;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard};

use anyhow::{Context, Result};

use crate::session::{JsonlSink, SessionBuilder, SharedLineWriter, TrainSession};
use crate::telemetry::metrics;
use crate::util::json::Json;

use super::manifest::{
    append_event, ckpt_event, losses_json, manifest_json, results_json, row_event,
    write_atomic, JobCkpt, Journal,
};
use super::planner::{plan, JobPlan};
use super::scheduler::{Admission, Admit};
use super::spec::{JobSpec, SweepSpec};

/// Knobs for one `run_sweep` invocation (the CLI flags, basically).
#[derive(Clone, Debug)]
pub struct SweepOptions {
    /// Directory for the manifest, journal, metrics JSONL, results, and
    /// per-job checkpoints.
    pub out_dir: PathBuf,
    /// Global memory budget over concurrently-running jobs' estimated
    /// footprints; 0 = unlimited.
    pub max_mem_bytes: u64,
    /// Maximum concurrently-running jobs (also the worker thread count).
    pub max_concurrency: usize,
    /// Resume an interrupted sweep in `out_dir` instead of starting fresh.
    pub resume: bool,
    /// Checkpoint each running job every N of its own steps (0 = only when
    /// halting). Halt-time checkpoints are always written.
    pub ckpt_every: u64,
    /// Stop the whole sweep after this many training steps summed across
    /// jobs (`None` = run to completion). Deterministic at concurrency 1.
    pub halt_after_steps: Option<u64>,
    /// Optimizer worker threads inside each job's sharded executor.
    pub workers_per_job: usize,
    /// Telemetry flag applied to EVERY job (the seam is process-global).
    pub telemetry: bool,
}

impl Default for SweepOptions {
    fn default() -> Self {
        Self {
            out_dir: PathBuf::from("sweep-out"),
            max_mem_bytes: 0,
            max_concurrency: 2,
            resume: false,
            ckpt_every: 0,
            halt_after_steps: None,
            workers_per_job: 2,
            telemetry: false,
        }
    }
}

/// What `run_sweep` hands back to the CLI / benches.
#[derive(Debug)]
pub struct SweepOutcome {
    /// Result rows in job-id order (only the jobs that reached a terminal
    /// state — a halted sweep returns a partial list).
    pub rows: Vec<Json>,
    /// True when `halt_after_steps` tripped; no results file is written.
    pub halted: bool,
    /// `SWEEP_results.json`, present only for a completed sweep.
    pub results_path: Option<PathBuf>,
    pub metrics_path: PathBuf,
    pub manifest_path: PathBuf,
    pub journal_path: PathBuf,
}

impl SweepOutcome {
    /// The row for `job_id`, if it reached a terminal state.
    pub fn row(&self, job_id: &str) -> Option<&Json> {
        self.rows.iter().find(|r| r.get("job_id").as_str() == Some(job_id))
    }
}

/// Mean of the last `min(20, len)` losses — the figure the paper's sweep
/// tables report. One pure function used by every path that renders a row,
/// so interrupted and uninterrupted runs agree bitwise.
fn tail_loss(losses: &[(u64, f32)]) -> Option<f32> {
    if losses.is_empty() {
        return None;
    }
    let k = losses.len().min(20);
    let sum: f64 = losses[losses.len() - k..].iter().map(|&(_, l)| l as f64).sum();
    Some((sum / k as f64) as f32)
}

fn done_row(job: &JobSpec, losses: &[(u64, f32)], state_bytes: usize) -> Json {
    Json::obj(vec![
        ("job_id", Json::str(job.id.clone())),
        ("assign", job.assign_json()),
        ("status", Json::str("done")),
        ("steps", Json::num(job.steps as f64)),
        (
            "final_loss",
            losses.last().map_or(Json::Null, |&(_, l)| Json::num(l as f64)),
        ),
        (
            "tail_loss",
            tail_loss(losses).map_or(Json::Null, |l| Json::num(l as f64)),
        ),
        ("state_bytes", Json::num(state_bytes as f64)),
        ("losses", losses_json(losses)),
    ])
}

fn failed_row(job: &JobSpec, error: &str, losses: &[(u64, f32)]) -> Json {
    Json::obj(vec![
        ("job_id", Json::str(job.id.clone())),
        ("assign", job.assign_json()),
        ("status", Json::str("failed")),
        ("error", Json::str(error)),
        ("steps", Json::num(job.steps as f64)),
        ("final_loss", Json::Null),
        ("tail_loss", Json::Null),
        ("state_bytes", Json::num(0.0)),
        ("losses", losses_json(losses)),
    ])
}

/// Scheduler state shared by the worker threads.
struct Shared {
    admission: Admission,
    /// Parallel to the plan: claimed jobs are running, finished, or
    /// rejected — never scanned again.
    claimed: Vec<bool>,
    /// Terminal rows by job id (pre-seeded from the journal on resume).
    results: BTreeMap<String, Json>,
}

/// Everything a worker thread needs, borrowed from `run_sweep`'s frame.
struct RunCtx<'a> {
    opts: &'a SweepOptions,
    spec: &'a SweepSpec,
    journal_path: &'a Path,
    writer: &'a SharedLineWriter,
    shared: &'a Mutex<Shared>,
    cv: &'a Condvar,
    halt: &'a AtomicBool,
    global_steps: &'a AtomicU64,
    /// Serializes [`SessionBuilder::build`]: it flips process-global
    /// telemetry / fault seams.
    build_lock: &'a Mutex<()>,
    resume_ckpts: &'a BTreeMap<String, JobCkpt>,
}

impl<'a> RunCtx<'a> {
    fn lock(&self) -> MutexGuard<'a, Shared> {
        self.shared.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn journal(&self, event: &Json) {
        if let Err(e) = append_event(self.journal_path, event) {
            eprintln!("sweep: journal write failed: {e:#}");
        }
    }
}

enum JobOutcome {
    Done(Json),
    Failed(Json),
    /// The job checkpointed and stopped because the sweep is halting; no
    /// terminal row.
    Halted,
}

fn panic_msg(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "unknown panic payload".to_string()
    }
}

/// Run one job to completion, failure, or halt-checkpoint. Panics are
/// caught and isolated into a failed row like any other job error.
fn run_job(ctx: &RunCtx<'_>, plan: &JobPlan) -> JobOutcome {
    let job = &plan.job;
    let ckpt_path = ctx.opts.out_dir.join(format!("job_{}.ckpt", job.id));
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        run_job_inner(ctx, job, &ckpt_path)
    }));
    match caught {
        Ok(Ok(outcome)) => outcome,
        Ok(Err(e)) => JobOutcome::Failed(failed_row(job, &format!("{e:#}"), &[])),
        Err(payload) => JobOutcome::Failed(failed_row(
            job,
            &format!("panicked: {}", panic_msg(payload)),
            &[],
        )),
    }
}

fn run_job_inner(ctx: &RunCtx<'_>, job: &JobSpec, ckpt_path: &Path) -> Result<JobOutcome> {
    let mut losses: Vec<(u64, f32)> = Vec::new();
    let mut builder: SessionBuilder = job
        .session(ctx.opts.workers_per_job, &ctx.spec.artifacts_dir)?
        .telemetry(ctx.opts.telemetry);
    if let Some(ck) = ctx.resume_ckpts.get(&job.id) {
        builder = builder.resume_from(ckpt_path);
        losses = ck.losses.clone();
    }
    let sink = JsonlSink::new(ctx.writer.handle())
        .with_tag("job_id", Json::str(job.id.clone()))
        .with_tag("assign", job.assign_json());
    let mut session: TrainSession = {
        let _build = ctx.build_lock.lock().unwrap_or_else(|e| e.into_inner());
        builder.sink(Box::new(sink)).build()?
    };

    while session.current_step() < session.total_steps() {
        match session.step() {
            Ok((loss, _)) => losses.push((session.current_step(), loss)),
            // Guard aborts and injected faults surface here; the job
            // becomes a failed row and the sweep keeps going.
            Err(e) => {
                return Ok(JobOutcome::Failed(failed_row(job, &format!("{e:#}"), &losses)))
            }
        }
        let sweep_steps = ctx.global_steps.fetch_add(1, Ordering::SeqCst) + 1;
        let at_end = session.current_step() >= session.total_steps();
        let halting = ctx.halt.load(Ordering::SeqCst)
            || ctx.opts.halt_after_steps.is_some_and(|h| sweep_steps >= h);
        if halting {
            ctx.halt.store(true, Ordering::SeqCst);
            if !at_end {
                session.save_checkpoint(ckpt_path)?;
                ctx.journal(&ckpt_event(&job.id, session.current_step(), &losses));
                ctx.cv.notify_all();
                return Ok(JobOutcome::Halted);
            }
            // On the final step: finish normally; the flag still stops the
            // rest of the sweep.
            ctx.cv.notify_all();
        } else if ctx.opts.ckpt_every > 0
            && !at_end
            && session.current_step() % ctx.opts.ckpt_every == 0
        {
            session.save_checkpoint(ckpt_path)?;
            ctx.journal(&ckpt_event(&job.id, session.current_step(), &losses));
        }
    }
    let state_bytes = session.state_bytes();
    Ok(JobOutcome::Done(done_row(job, &losses, state_bytes)))
}

/// Worker loop: claim the next admissible job (longest-first with
/// first-fit backfill), run it, publish its row, repeat until the plan is
/// drained or the sweep halts.
fn worker(ctx: &RunCtx<'_>, plans: &[JobPlan]) {
    loop {
        if ctx.halt.load(Ordering::SeqCst) {
            return;
        }
        let picked = {
            let mut s = ctx.lock();
            loop {
                if ctx.halt.load(Ordering::SeqCst) {
                    return;
                }
                let mut pick = None;
                let mut unclaimed = 0usize;
                for (i, p) in plans.iter().enumerate() {
                    if s.claimed[i] {
                        continue;
                    }
                    match s.admission.decide(p.est_bytes) {
                        Admit::TooBig => {
                            // Can never run under this budget: reject it
                            // now as an isolated failed row.
                            s.claimed[i] = true;
                            let row = failed_row(
                                &p.job,
                                &format!(
                                    "estimated footprint {} bytes exceeds memory budget {} bytes",
                                    p.est_bytes,
                                    s.admission.budget()
                                ),
                                &[],
                            );
                            ctx.journal(&row_event(&p.job.id, "failed", &row));
                            metrics::sweep_jobs_failed().inc();
                            s.results.insert(p.job.id.clone(), row);
                        }
                        Admit::Start => {
                            s.admission.admit(&p.job.id, p.est_bytes);
                            s.claimed[i] = true;
                            metrics::sweep_jobs_running().set(s.admission.running() as f64);
                            pick = Some(i);
                            break;
                        }
                        Admit::Wait => unclaimed += 1,
                    }
                }
                if let Some(i) = pick {
                    break Some(i);
                }
                if unclaimed == 0 {
                    break None; // plan drained (running jobs belong to other workers)
                }
                s = ctx.cv.wait(s).unwrap_or_else(|e| e.into_inner());
            }
        };
        let Some(i) = picked else { return };
        let p = &plans[i];
        let outcome = run_job(ctx, p);
        let mut s = ctx.lock();
        s.admission.release(&p.job.id);
        metrics::sweep_jobs_running().set(s.admission.running() as f64);
        match outcome {
            JobOutcome::Done(row) => {
                ctx.journal(&row_event(&p.job.id, "done", &row));
                metrics::sweep_jobs_done().inc();
                s.results.insert(p.job.id.clone(), row);
            }
            JobOutcome::Failed(row) => {
                ctx.journal(&row_event(&p.job.id, "failed", &row));
                metrics::sweep_jobs_failed().inc();
                s.results.insert(p.job.id.clone(), row);
            }
            JobOutcome::Halted => {}
        }
        drop(s);
        ctx.cv.notify_all();
    }
}

/// On resume, rewrite the metrics JSONL keeping only lines that belong to
/// the replayed history: all lines of jobs with terminal rows, and lines
/// at or before the checkpoint step for jobs about to resume. Everything
/// else (post-checkpoint lines, torn lines, unclaimed jobs) is dropped and
/// will be re-emitted by the resumed run.
fn rewrite_metrics(path: &Path, journal: &Journal) -> Result<()> {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(()),
        Err(e) => return Err(e).with_context(|| format!("read {}", path.display())),
    };
    let mut kept = String::new();
    for line in text.lines() {
        let Ok(v) = Json::parse(line) else { continue };
        let Some(job) = v.get("job_id").as_str() else { continue };
        let keep = if journal.rows.contains_key(job) {
            true
        } else if let Some(ck) = journal.ckpts.get(job) {
            v.get("step").as_f64().is_some_and(|s| (s as u64) <= ck.step)
        } else {
            false
        };
        if keep {
            kept.push_str(line);
            kept.push('\n');
        }
    }
    write_atomic(path, &kept)
}

/// Run a sweep. See the module docs for the concurrency / halt / resume
/// semantics.
pub fn run_sweep(spec: &SweepSpec, opts: &SweepOptions) -> Result<SweepOutcome> {
    anyhow::ensure!(!spec.jobs.is_empty(), "sweep has no jobs");
    let mut ids = BTreeSet::new();
    for j in &spec.jobs {
        anyhow::ensure!(ids.insert(j.id.as_str()), "duplicate job id '{}'", j.id);
    }

    std::fs::create_dir_all(&opts.out_dir)
        .with_context(|| format!("create {}", opts.out_dir.display()))?;
    let manifest_path = opts.out_dir.join("SWEEP_manifest.json");
    let journal_path = opts.out_dir.join("SWEEP_state.jsonl");
    let metrics_path = opts.out_dir.join("SWEEP_metrics.jsonl");
    let results_path = opts.out_dir.join("SWEEP_results.json");

    let plans = plan(&spec.jobs, &spec.artifacts_dir);

    let mut results: BTreeMap<String, Json> = BTreeMap::new();
    let mut resume_ckpts: BTreeMap<String, JobCkpt> = BTreeMap::new();
    if opts.resume {
        let prior_text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!("--resume-sweep: no sweep manifest at {}", manifest_path.display())
        })?;
        let prior = Json::parse(&prior_text)
            .map_err(|e| anyhow::anyhow!("--resume-sweep: bad manifest: {e}"))?;
        let prior_ids: BTreeSet<&str> = prior
            .get("jobs")
            .as_arr()
            .map(|a| a.iter().filter_map(|j| j.get("id").as_str()).collect())
            .unwrap_or_default();
        let now_ids: BTreeSet<&str> = spec.jobs.iter().map(|j| j.id.as_str()).collect();
        anyhow::ensure!(
            prior_ids == now_ids,
            "--resume-sweep: the spec expands to a different job set than the \
             manifest in {} ({} jobs vs {}); resume with the original spec or \
             start a fresh --out-dir",
            opts.out_dir.display(),
            now_ids.len(),
            prior_ids.len(),
        );
        let journal = Journal::load(&journal_path)?;
        rewrite_metrics(&metrics_path, &journal)?;
        for (id, row) in &journal.rows {
            if row.get("status").as_str() == Some("done") {
                metrics::sweep_jobs_done().inc(); // skipped-on-resume counts as done
            } else {
                metrics::sweep_jobs_failed().inc();
            }
            results.insert(id.clone(), row.clone());
        }
        for (id, ck) in journal.ckpts {
            if results.contains_key(&id) {
                continue; // terminal row supersedes any checkpoint
            }
            if opts.out_dir.join(format!("job_{id}.ckpt")).exists() {
                resume_ckpts.insert(id, ck);
            }
        }
    } else {
        // Fresh start: clear any prior sweep state in this directory so
        // stale rows can't leak into the new run.
        let _ = std::fs::remove_file(&journal_path);
        let _ = std::fs::remove_file(&metrics_path);
        let _ = std::fs::remove_file(&results_path);
        let doc = manifest_json(
            &spec.name,
            &spec.source,
            opts.max_mem_bytes,
            opts.max_concurrency,
            &plans,
        );
        write_atomic(&manifest_path, &(doc.pretty() + "\n"))?;
    }

    metrics::sweep_mem_budget_bytes().set(opts.max_mem_bytes as f64);

    let metrics_file = OpenOptions::new()
        .create(true)
        .append(true)
        .open(&metrics_path)
        .with_context(|| format!("open {}", metrics_path.display()))?;
    let writer = SharedLineWriter::new(metrics_file);

    let pending = spec.jobs.len() - results.len();
    let claimed: Vec<bool> =
        plans.iter().map(|p| results.contains_key(&p.job.id)).collect();
    let shared = Mutex::new(Shared {
        admission: Admission::new(opts.max_mem_bytes, opts.max_concurrency),
        claimed,
        results,
    });
    let cv = Condvar::new();
    let halt = AtomicBool::new(false);
    let global_steps = AtomicU64::new(0);
    let build_lock = Mutex::new(());
    let ctx = RunCtx {
        opts,
        spec,
        journal_path: &journal_path,
        writer: &writer,
        shared: &shared,
        cv: &cv,
        halt: &halt,
        global_steps: &global_steps,
        build_lock: &build_lock,
        resume_ckpts: &resume_ckpts,
    };

    let n_workers = opts.max_concurrency.max(1).min(pending);
    std::thread::scope(|scope| {
        for _ in 0..n_workers {
            scope.spawn(|| worker(&ctx, &plans));
        }
    });

    let halted = halt.load(Ordering::SeqCst);
    let shared = shared.into_inner().unwrap_or_else(|e| e.into_inner());
    metrics::sweep_jobs_running().set(0.0);
    let rows: Vec<Json> = shared.results.values().cloned().collect();
    let results_path = if !halted && shared.results.len() == spec.jobs.len() {
        let doc = results_json(&spec.name, &shared.results);
        write_atomic(&results_path, &(doc.pretty() + "\n"))?;
        Some(results_path)
    } else {
        None
    };
    Ok(SweepOutcome {
        rows,
        halted,
        results_path,
        metrics_path,
        manifest_path,
        journal_path,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tail_loss_is_mean_of_last_20() {
        assert_eq!(tail_loss(&[]), None);
        assert_eq!(tail_loss(&[(1, 2.0)]), Some(2.0));
        let losses: Vec<(u64, f32)> = (1..=30).map(|i| (i, i as f32)).collect();
        // Last 20 of 1..=30 are 11..=30, mean 20.5.
        assert_eq!(tail_loss(&losses), Some(20.5));
    }

    #[test]
    fn rows_carry_assign_and_status() {
        use crate::optim::OptKind;
        let job = JobSpec::new("j007", "nplm-tiny", OptKind::Soap, 5)
            .with_assign("lr", "0.01");
        let done = done_row(&job, &[(1, 3.0), (2, 2.0)], 1234);
        assert_eq!(done.get("job_id").as_str(), Some("j007"));
        assert_eq!(done.get("status").as_str(), Some("done"));
        assert_eq!(done.get("assign").get("lr").as_str(), Some("0.01"));
        assert_eq!(done.get("final_loss").as_f64(), Some(2.0));
        let failed = failed_row(&job, "boom", &[]);
        assert_eq!(failed.get("status").as_str(), Some("failed"));
        assert_eq!(failed.get("error").as_str(), Some("boom"));
        assert_eq!(failed.get("final_loss"), &Json::Null);
    }

    #[test]
    fn rewrite_metrics_keeps_done_jobs_and_ckpt_prefix() {
        let dir = std::env::temp_dir().join("soap-sweep-rewrite-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("SWEEP_metrics.jsonl");
        let lines = [
            r#"{"job_id":"j000","step":1,"loss":2.0}"#,
            r#"{"job_id":"j000","step":2,"loss":1.9}"#,
            r#"{"job_id":"j001","step":1,"loss":2.1}"#,
            r#"{"job_id":"j001","step":2,"loss":2.0}"#,
            r#"{"job_id":"j001","step":3,"loss":1.8}"#,
            r#"{"job_id":"j002","step":1,"loss":2.2}"#,
            r#"{"job_id":"j0"#, // torn tail
        ];
        std::fs::write(&path, lines.join("\n")).unwrap();

        let mut journal = Journal::default();
        journal.rows.insert(
            "j000".into(),
            Json::obj(vec![("status", Json::str("done"))]),
        );
        journal
            .ckpts
            .insert("j001".into(), JobCkpt { step: 2, losses: vec![] });
        // j002 has neither a row nor a checkpoint: dropped entirely.
        rewrite_metrics(&path, &journal).unwrap();

        let kept = std::fs::read_to_string(&path).unwrap();
        let kept: Vec<&str> = kept.lines().collect();
        assert_eq!(kept.len(), 4);
        assert!(kept.iter().all(|l| !l.contains("j002")));
        assert!(kept.iter().filter(|l| l.contains("j001")).count() == 2);
        let _ = std::fs::remove_file(&path);
    }
}
