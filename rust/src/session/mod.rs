//! The unified training API: one typed entry point over the serial /
//! sharded / PJRT executor backends, with first-class checkpoint/resume.
//!
//! [`TrainSession`] (alias [`Session`]) replaces the three bespoke
//! `Trainer::new_*` constructors and the per-bench hand-rolled harness
//! code: a [`SessionBuilder`] takes a model spec, an optimizer
//! preset/composition, a schedule, data knobs, and a [`Backend`], validates
//! the whole configuration up front (including the PJRT artifact
//! preflight), and yields a session with a uniform lifecycle.
//!
//! ```no_run
//! use soap_lab::optim::{OptKind, Schedule};
//! use soap_lab::session::{Backend, ModelSpec, TrainSession};
//!
//! # fn main() -> anyhow::Result<()> {
//! let mut session = TrainSession::builder()
//!     .model(ModelSpec::parse("nplm")?)      // or .model(ModelSpec::artifact("nano"))
//!     .optimizer(OptKind::parse("soap")?)    // presets or basis=…,inner=… specs
//!     .schedule(Schedule::Constant { lr: 0.01 })
//!     .steps(200)
//!     .backend(Backend::Sharded)             // Serial | Sharded | Pjrt
//!     .log_every(10)
//!     .build()?;                             // all validation happens here
//!
//! let log = session.run()?;                  // or session.step() in a loop
//! session.save_checkpoint("run.ckpt")?;
//! println!("tail loss {:.4}, state {} bytes", log.tail_loss(20), session.state_bytes());
//!
//! // Later (even in a new process): resume and run to a larger budget.
//! let mut resumed = TrainSession::builder()
//!     .model(ModelSpec::parse("nplm")?)
//!     .optimizer(OptKind::parse("soap")?)
//!     .schedule(Schedule::Constant { lr: 0.01 })
//!     .steps(400)                            // TOTAL budget; runs the remainder
//!     .resume_from("run.ckpt")               // params + moments + step + data cursor
//!     .build()?;
//! resumed.run()?;
//! # Ok(())
//! # }
//! ```
//!
//! ## Backend matrix
//!
//! | backend       | gradients        | optimizer updates            | checkpoint |
//! |---------------|------------------|------------------------------|------------|
//! | `Serial`      | native or PJRT   | this thread, layer order     | yes        |
//! | `Sharded`     | native or PJRT   | cost-balanced worker pool    | yes        |
//! | `Pjrt`        | PJRT artifacts   | compiled Pallas kernels      | no         |
//! | `Distributed` | native, SPMD     | replicated; refreshes owned  | yes (rank 0) |
//!
//! `Serial` and `Sharded` are bitwise-interchangeable; both are
//! bitwise-identical to the pre-redesign `Trainer` paths
//! (`rust/tests/session.rs` pins this for adamw/soap/shampoo).
//! `Distributed` splits each batch's microbatches across ranks, averages
//! gradients with an order-preserving fold-reduce, and partitions eigenbasis
//! refreshes by layer ownership — also bitwise-identical to `Serial` in
//! inline / drained-async refresh modes, and rank 0's checkpoint is
//! format-identical to a serial checkpoint (any backend resumes it).
//!
//! ## Resume semantics
//!
//! [`TrainSession::checkpoint`] drains the async refresh service, folds in
//! any published-but-unadopted eigenbasis, and records params, optimizer
//! state, the step counter, the data cursor, and the seed. A session built
//! with `resume_from` restores ALL of them together, so a resumed run is
//! bitwise-identical to an uninterrupted one in `Inline` refresh mode — and
//! in `Async` mode when each step drains the service
//! ([`SessionBuilder::drain_refresh_each_step`]); undrained async adoption
//! timing is inherently racy, so there the bar is loss parity, not bit
//! equality. `steps` is a TOTAL budget: resuming at step `k` runs `steps −
//! k` more, with the LR schedule continuing from `k` (the pre-redesign
//! `--resume` restored the schedule but replayed data from batch 0 and ran
//! `steps` EXTRA steps; both drifts are gone).

pub mod backend;
pub mod builder;
pub mod sink;
mod train;

pub use backend::{Backend, ExecutorBackend, PjrtExecutor, SerialExecutor, ShardedExecutor};
pub use builder::{DistEndpoint, DistOptions, ModelSpec, SessionBuilder};
pub use sink::{
    CollectSink, HealthSnapshot, JsonlSink, LayerHealth, MetricsSink, RankHealth,
    SharedLineWriter, StdoutSink, StepRecord,
};
pub use train::TrainSession;

/// Short alias: `Session::builder()` reads naturally at call sites.
pub type Session = TrainSession;
