//! [`SessionBuilder`] — the single typed entry point for constructing a
//! training run. Takes a model spec, an optimizer composition/preset, a
//! schedule, data knobs, and a [`Backend`]; validates the WHOLE
//! configuration up front (the checks that used to live in
//! `RunConfig::validate` plus the PJRT artifact preflight); and yields a
//! [`TrainSession`] with a uniform lifecycle.

use std::net::TcpListener;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use super::backend::{Backend, ExecutorBackend, PjrtExecutor, SerialExecutor, ShardedExecutor};
use super::sink::{MetricsSink, StdoutSink};
use super::TrainSession;
use crate::coordinator::pjrt_optim::preflight;
use crate::coordinator::{init_lm_params, Checkpoint, GradBackend};
use crate::data::{BatchStream, CorpusSpec};
use crate::dist::{DistComm, DistExecutor, MemEndpoint};
use crate::linalg::TensorShape;
use crate::model::{self, NplmConfig};
use crate::optim::{Hyper, OptKind, RefreshMode, Schedule};
use crate::runtime::Engine;
use crate::util::rng::Rng;

/// What produces gradients: a native NPLM (artifact-free, runs on any
/// checkout) or a transformer LM from the compiled artifact manifest.
#[derive(Clone, Debug)]
pub enum ModelSpec {
    /// Native hand-backpropped NPLM with its data geometry.
    Nplm { cfg: NplmConfig, seq: usize, batch: usize },
    /// Artifact manifest config name (`nano`, `small`, …); gradients come
    /// from the `lm_grads_<name>` PJRT executable.
    Artifact { name: String },
}

/// The native model names accepted by [`ModelSpec::parse`].
pub const NPLM_NAMES: &str = "nplm (128-vocab probe config), nplm-tiny (test-scale), \
nplm-conv (test-scale with a rank-3 conv kernel)";

impl ModelSpec {
    pub fn artifact(name: &str) -> Self {
        ModelSpec::Artifact { name: name.to_string() }
    }

    pub fn nplm(cfg: NplmConfig, seq: usize, batch: usize) -> Self {
        ModelSpec::Nplm { cfg, seq, batch }
    }

    /// Map a CLI/config model name onto a spec: the `nplm*` names select the
    /// built-in native presets (so artifact-free runs work from the CLI);
    /// anything else is an artifact manifest config name, checked when the
    /// manifest loads.
    pub fn parse(name: &str) -> Result<Self> {
        Ok(match name.to_ascii_lowercase().as_str() {
            // The perf-probe / async-refresh bench geometry: layer shapes
            // up to 192×192 so preconditioning actually costs something.
            "nplm" => ModelSpec::nplm(
                NplmConfig { vocab: 128, context: 4, dim: 48, hidden: 96, conv: false },
                32,
                16,
            ),
            // The integration-test geometry: small enough for smoke jobs.
            "nplm-tiny" => ModelSpec::nplm(
                NplmConfig { vocab: 64, context: 3, dim: 12, hidden: 24, conv: false },
                24,
                8,
            ),
            // nplm-tiny with W1 declared as the rank-3 [context, dim,
            // hidden] conv kernel it is — exercises per-mode tensor
            // preconditioning end-to-end (same gradients and carrier
            // matrices as nplm-tiny; only the optimizer's view changes).
            "nplm-conv" => ModelSpec::nplm(
                NplmConfig { vocab: 64, context: 3, dim: 12, hidden: 24, conv: true },
                24,
                8,
            ),
            other if other.starts_with("nplm") => anyhow::bail!(
                "unknown native model '{name}': expected one of {NPLM_NAMES}"
            ),
            _ => ModelSpec::artifact(name),
        })
    }

    pub fn label(&self) -> String {
        match self {
            ModelSpec::Artifact { name } => name.clone(),
            ModelSpec::Nplm { cfg, .. } => {
                format!("nplm-v{}d{}h{}", cfg.vocab, cfg.dim, cfg.hidden)
            }
        }
    }
}

enum ResumeSource {
    Path(PathBuf),
    Loaded(Checkpoint),
}

/// How one rank of a [`Backend::Distributed`] session reaches its peers.
pub enum DistEndpoint {
    /// Rendezvous over localhost TCP. Rank 0 should pass its pre-bound
    /// listener (bind BEFORE spawning workers so no child races the
    /// coordinator socket); workers pass `None` and dial `coordinator`.
    Tcp { coordinator: String, listener: Option<TcpListener> },
    /// A pre-built in-process channel endpoint from
    /// [`crate::dist::MemCluster`] (tests, single-process experiments).
    Mem(MemEndpoint),
}

/// Per-rank wiring for the distributed backend, attached with
/// [`SessionBuilder::dist`]. The CLI assembles this from
/// `--ranks/--rank/--coordinator-addr/--dist-timeout`.
pub struct DistOptions {
    /// This process's rank in `0..ranks`.
    pub rank: usize,
    /// World size; must equal the backend's `ranks`.
    pub ranks: usize,
    /// How long any collective waits on a peer before raising a typed
    /// [`crate::dist::DistError`] (dead/hung worker detection).
    pub timeout: Duration,
    pub endpoint: DistEndpoint,
}

/// Builder for [`TrainSession`] — see the [`crate::session`] module docs for
/// a worked example. Every knob has the paper-default value; only `model`
/// is required.
pub struct SessionBuilder {
    model: Option<ModelSpec>,
    artifacts_dir: String,
    opt: OptKind,
    hyper: Hyper,
    schedule: Schedule,
    steps: u64,
    seed: u64,
    grad_accum: usize,
    workers: usize,
    backend: Backend,
    zipf_alpha: f64,
    log_every: u64,
    drain_refresh: bool,
    resume: Option<ResumeSource>,
    sinks: Vec<Box<dyn MetricsSink>>,
    telemetry: bool,
    metrics_every: u64,
    trace_out: Option<PathBuf>,
    dist: Option<DistOptions>,
    fault_plan: Option<(String, u32)>,
}

impl Default for SessionBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl SessionBuilder {
    pub fn new() -> Self {
        Self {
            model: None,
            artifacts_dir: "artifacts".into(),
            opt: OptKind::Soap,
            hyper: Hyper::default(),
            schedule: Schedule::Constant { lr: 3e-3 },
            steps: 100,
            seed: 0,
            grad_accum: 1,
            workers: 4,
            backend: Backend::Sharded,
            zipf_alpha: 1.2,
            log_every: 0,
            drain_refresh: false,
            resume: None,
            sinks: Vec::new(),
            telemetry: false,
            metrics_every: 10,
            trace_out: None,
            dist: None,
            fault_plan: None,
        }
    }

    /// REQUIRED: what to train.
    pub fn model(mut self, spec: ModelSpec) -> Self {
        self.model = Some(spec);
        self
    }

    /// Artifact directory for [`ModelSpec::Artifact`] models (default
    /// `artifacts`).
    pub fn artifacts_dir(mut self, dir: &str) -> Self {
        self.artifacts_dir = dir.to_string();
        self
    }

    /// Optimizer preset or composition spec (default SOAP).
    pub fn optimizer(mut self, opt: OptKind) -> Self {
        self.opt = opt;
        self
    }

    pub fn hyper(mut self, h: Hyper) -> Self {
        self.hyper = h;
        self
    }

    pub fn schedule(mut self, s: Schedule) -> Self {
        self.schedule = s;
        self
    }

    /// TOTAL step budget; a resumed session runs the remainder.
    pub fn steps(mut self, n: u64) -> Self {
        self.steps = n;
        self
    }

    /// Data/init seed.
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    /// Gradient-accumulation microbatches per step (≥ 1).
    pub fn grad_accum(mut self, k: usize) -> Self {
        self.grad_accum = k;
        self
    }

    /// Worker threads for [`Backend::Sharded`].
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = n;
        self
    }

    /// Optimizer executor (default [`Backend::Sharded`]).
    pub fn backend(mut self, b: Backend) -> Self {
        self.backend = b;
        self
    }

    /// Zipf exponent of the synthetic corpus (default 1.2).
    pub fn zipf_alpha(mut self, a: f64) -> Self {
        self.zipf_alpha = a;
        self
    }

    /// Attach a stdout progress sink printing every `k`-th step (0 = none).
    pub fn log_every(mut self, k: u64) -> Self {
        self.log_every = k;
        self
    }

    /// Deterministic async mode: drain the refresh service after every step
    /// so basis adoption timing is a pure function of the step count and
    /// runs (and checkpoint/resume) are replayable bitwise. Costs the
    /// overlap benefit; meant for tests and reproducibility studies.
    pub fn drain_refresh_each_step(mut self, on: bool) -> Self {
        self.drain_refresh = on;
        self
    }

    /// Resume from a checkpoint file at build time (params, optimizer
    /// state, step counter, and data cursor are all restored together).
    pub fn resume_from(mut self, path: impl Into<PathBuf>) -> Self {
        self.resume = Some(ResumeSource::Path(path.into()));
        self
    }

    /// Resume from an in-memory [`Checkpoint`].
    pub fn resume_checkpoint(mut self, ck: Checkpoint) -> Self {
        self.resume = Some(ResumeSource::Loaded(ck));
        self
    }

    /// Attach a typed metrics sink.
    pub fn sink(mut self, sink: Box<dyn MetricsSink>) -> Self {
        self.sinks.push(sink);
        self
    }

    /// Master telemetry switch (default off). When on, `build()` enables
    /// the process-wide [`crate::telemetry`] recorder: span tracing, the
    /// metrics registry, and per-layer [`super::HealthSnapshot`] emission
    /// every [`Self::metrics_every`] steps. When off (the default), the
    /// instrumentation compiles to one relaxed atomic load per span site and
    /// the trained trajectory is bitwise identical to a build without it.
    pub fn telemetry(mut self, on: bool) -> Self {
        self.telemetry = on;
        self
    }

    /// Health-snapshot cadence in steps (default 10; 0 = never). Only
    /// meaningful with [`Self::telemetry`] on.
    pub fn metrics_every(mut self, k: u64) -> Self {
        self.metrics_every = k;
        self
    }

    /// Write a Chrome trace-event JSON (`chrome://tracing` / Perfetto) of
    /// every recorded span when `run()` completes.
    pub fn trace_out(mut self, path: impl Into<PathBuf>) -> Self {
        self.trace_out = Some(path.into());
        self
    }

    /// REQUIRED with [`Backend::Distributed`]: this rank's wiring (rank id,
    /// world size, peer timeout, transport endpoint).
    pub fn dist(mut self, opts: DistOptions) -> Self {
        self.dist = Some(opts);
        self
    }

    /// Arm a seeded fault-injection plan ([`crate::fault::FaultPlan`]
    /// grammar) for this session's process. `attempt` is the auto-resume
    /// relaunch counter: attempts > 0 disarm the plan's one-shot clauses
    /// (crash, eigh-fail, grad poison) so a recovered run doesn't re-fire
    /// the fault it just survived. Chaos testing only.
    pub fn fault_plan(mut self, plan: &str, attempt: u32) -> Self {
        self.fault_plan = Some((plan.to_string(), attempt));
        self
    }

    /// The hyperparameters as the optimizer will actually see them — with a
    /// composition spec's structural overrides folded in.
    fn resolved_hyper(&self) -> Hyper {
        let mut h = self.hyper.clone();
        if let OptKind::Composed(spec) = &self.opt {
            spec.apply(&mut h);
        }
        h
    }

    /// Validate the whole configuration, without touching the filesystem.
    /// `build()` runs this first; `RunConfig::validate` delegates here so
    /// the CLI and the API reject the same configurations with the same
    /// messages.
    pub fn validate(&self) -> Result<()> {
        let model = self
            .model
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("SessionBuilder requires a model spec"))?;
        anyhow::ensure!(self.steps > 0, "steps must be > 0");
        anyhow::ensure!(self.grad_accum >= 1, "grad-accum must be ≥ 1");
        anyhow::ensure!(self.workers >= 1, "workers must be ≥ 1");
        anyhow::ensure!(self.hyper.precond_freq > 0, "precond-freq must be > 0");
        anyhow::ensure!(self.hyper.refresh_workers >= 1, "refresh-workers must be ≥ 1");
        if let OptKind::Composed(spec) = &self.opt {
            spec.check_flag_consistency(self.hyper.one_sided, self.hyper.factorized)?;
        }
        let resolved = self.resolved_hyper();
        if self.backend == Backend::Pjrt {
            anyhow::ensure!(
                matches!(model, ModelSpec::Artifact { .. }),
                "the pjrt backend runs on artifact models (native nplm models have no \
                 compiled optimizer kernels)"
            );
            anyhow::ensure!(
                resolved.refresh_mode != RefreshMode::Async,
                "async refresh applies to the native backends (serial/sharded)"
            );
            anyhow::ensure!(
                matches!(self.opt.canonical(), OptKind::Soap | OptKind::AdamW),
                "the pjrt backend supports soap|adamw (or composition specs canonical to them)"
            );
            anyhow::ensure!(
                !resolved.factorized,
                "the pjrt backend runs the full-V SOAP artifacts; the factorized \
                 (adafactor-engine) variant is native-only"
            );
            anyhow::ensure!(
                self.resume.is_none(),
                "checkpoint resume requires a native backend (serial/sharded)"
            );
        }
        if let Backend::Distributed { ranks, .. } = self.backend {
            anyhow::ensure!(ranks >= 2, "the distributed backend needs ranks ≥ 2");
            anyhow::ensure!(
                matches!(model, ModelSpec::Nplm { .. }),
                "the distributed backend runs native models (each PJRT engine is \
                 process-local; artifact models are not supported across ranks)"
            );
            let opts = self.dist.as_ref().ok_or_else(|| {
                anyhow::anyhow!(
                    "the distributed backend needs per-rank wiring: call \
                     SessionBuilder::dist (the CLI assembles it from \
                     --ranks/--rank/--coordinator-addr)"
                )
            })?;
            anyhow::ensure!(
                opts.ranks == ranks,
                "DistOptions declares {} ranks but the backend says {ranks}",
                opts.ranks
            );
            anyhow::ensure!(
                opts.rank < ranks,
                "rank {} is out of range for a {ranks}-rank run",
                opts.rank
            );
        } else {
            anyhow::ensure!(
                self.dist.is_none(),
                "DistOptions are set but the backend is {} — pass Backend::Distributed",
                self.backend.name()
            );
        }
        Ok(())
    }

    /// FNV-1a over the canonical run-configuration string: every rank's
    /// rendezvous hello carries this, so a worker launched with a different
    /// optimizer/model/schedule is rejected up front instead of silently
    /// diverging mid-run.
    fn config_fingerprint(opt: &OptKind, label: &str, parts: &[u64]) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut eat = |bytes: &[u8]| {
            for &b in bytes {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
        };
        eat(format!("{opt:?}").as_bytes());
        eat(label.as_bytes());
        for p in parts {
            eat(&p.to_le_bytes());
        }
        h
    }

    /// Validate, load what the configuration needs (artifact engine +
    /// preflight for PJRT paths), build the executor, and — when a resume
    /// source is set — restore the checkpoint into the fresh session.
    pub fn build(self) -> Result<TrainSession> {
        self.validate()?;
        let SessionBuilder {
            model,
            artifacts_dir,
            opt,
            hyper,
            schedule,
            steps,
            seed,
            grad_accum,
            workers,
            backend,
            zipf_alpha,
            log_every,
            drain_refresh,
            resume,
            mut sinks,
            telemetry,
            metrics_every,
            trace_out,
            mut dist,
            fault_plan,
        } = self;
        let model = model.expect("validated");
        // The span recorder and instrument gates are process-global; the
        // builder is the one place sessions flip them — and likewise the
        // fault-injection seam: armed here (with this process's rank, so
        // rank-targeted clauses resolve) or explicitly cleared, so one
        // session's plan never leaks into the next build in this process.
        crate::telemetry::set_enabled(telemetry);
        match &fault_plan {
            Some((plan, attempt)) => {
                let mut plan = crate::fault::FaultPlan::parse(plan)?;
                if *attempt > 0 {
                    plan.disarm_one_shot();
                }
                crate::fault::install(plan, dist.as_ref().map(|o| o.rank).unwrap_or(0));
            }
            None => crate::fault::clear(),
        }

        let mut rng = Rng::new(seed);
        let (grad, params, vocab, seq, batch) = match &model {
            ModelSpec::Artifact { name } => {
                let engine = Engine::load(&artifacts_dir)?;
                let info = engine.manifest.config(name)?.clone();
                let params = init_lm_params(&info.params, &mut rng);
                let grad = GradBackend::Pjrt { engine, config: name.clone() };
                (grad, params, info.vocab, info.seq, info.batch)
            }
            ModelSpec::Nplm { cfg, seq, batch } => {
                let params = model::init_params(cfg, &mut rng);
                (GradBackend::Native { cfg: *cfg }, params, cfg.vocab, *seq, *batch)
            }
        };
        let shapes: Vec<(usize, usize)> = params.iter().map(|p| (p.rows, p.cols)).collect();
        // True N-D shapes for the optimizer: artifact params are matrices,
        // native models declare theirs (the nplm-conv preset's rank-3 W1).
        let tensor_shapes: Vec<TensorShape> = match &model {
            ModelSpec::Artifact { .. } => {
                shapes.iter().map(|&(m, n)| TensorShape::matrix(m, n)).collect()
            }
            ModelSpec::Nplm { cfg, .. } => cfg.tensor_shapes(),
        };
        for (i, (ts, &(m, n))) in tensor_shapes.iter().zip(&shapes).enumerate() {
            anyhow::ensure!(
                ts.carrier() == (m, n),
                "model bug: param {i} tensor shape {ts} does not fold to its {m}×{n} carrier"
            );
        }
        let stream = BatchStream::new(
            CorpusSpec { vocab_size: vocab, zipf_alpha, seed, stream: 0 },
            batch * grad_accum,
            seq,
            0,
            1,
        );

        let mut dist_comm: Option<Arc<DistComm>> = None;
        let exec: Box<dyn ExecutorBackend> = match backend {
            Backend::Serial => Box::new(SerialExecutor::new_tensors(opt, &hyper, &tensor_shapes)),
            Backend::Sharded => {
                Box::new(ShardedExecutor::new_tensors(opt, &hyper, &tensor_shapes, workers))
            }
            Backend::Pjrt => {
                let GradBackend::Pjrt { engine, .. } = &grad else {
                    unreachable!("validate() pinned pjrt to artifact models");
                };
                preflight(engine, opt, &hyper, &shapes)?;
                Box::new(PjrtExecutor::new(opt, hyper.clone(), &shapes)?)
            }
            Backend::Distributed { ranks, .. } => {
                let opts = dist.take().expect("validated: dist options present");
                let fp = Self::config_fingerprint(
                    &opt,
                    &model.label(),
                    &[
                        steps,
                        seed,
                        batch as u64,
                        grad_accum as u64,
                        seq as u64,
                        ranks as u64,
                        hyper.precond_freq as u64,
                        (hyper.refresh_mode == RefreshMode::Async) as u64,
                        drain_refresh as u64,
                        hyper.state_dtype.bytes() as u64,
                    ],
                );
                let comm = match opts.endpoint {
                    DistEndpoint::Tcp { coordinator, listener } => DistComm::connect_tcp(
                        opts.rank,
                        ranks,
                        &coordinator,
                        listener,
                        opts.timeout,
                        fp,
                    )?,
                    DistEndpoint::Mem(ep) => DistComm::connect_mem(ep, opts.timeout)?,
                };
                let comm = Arc::new(comm);
                // Liveness beacon (TCP only): peers detect a dead rank
                // within the collective timeout even between steps.
                DistComm::start_heartbeat(&comm);
                dist_comm = Some(Arc::clone(&comm));
                Box::new(DistExecutor::new_tensors(opt, &hyper, &tensor_shapes, comm, drain_refresh))
            }
        };

        if log_every > 0 {
            sinks.push(Box::new(StdoutSink::every(log_every)));
        }

        let mut session = TrainSession {
            opt,
            hyper,
            schedule,
            total_steps: steps,
            seed,
            grad_accum,
            vocab,
            zipf_alpha,
            grad,
            model_label: model.label(),
            exec,
            params,
            shapes,
            tensor_shapes,
            stream,
            steps_done: 0,
            drain_refresh,
            sinks,
            telemetry,
            metrics_every,
            trace_out,
            dist: dist_comm,
        };
        if let Some(src) = resume {
            let ck = match src {
                ResumeSource::Path(p) => Checkpoint::load(&p)?,
                ResumeSource::Loaded(ck) => ck,
            };
            session.apply_resume(ck)?;
        }
        Ok(session)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn native_builder() -> SessionBuilder {
        TrainSession::builder()
            .model(ModelSpec::parse("nplm-tiny").unwrap())
            .optimizer(OptKind::AdamW)
            .steps(3)
            .workers(2)
    }

    #[test]
    fn model_spec_parse() {
        assert!(matches!(ModelSpec::parse("nplm").unwrap(), ModelSpec::Nplm { .. }));
        assert!(matches!(ModelSpec::parse("NPLM-TINY").unwrap(), ModelSpec::Nplm { .. }));
        assert!(matches!(
            ModelSpec::parse("nplm-conv").unwrap(),
            ModelSpec::Nplm { cfg, .. } if cfg.conv
        ));
        assert!(matches!(
            ModelSpec::parse("nano").unwrap(),
            ModelSpec::Artifact { name } if name == "nano"
        ));
        let e = ModelSpec::parse("nplm-huge").unwrap_err().to_string();
        assert!(e.contains("nplm-tiny"), "{e}");
    }

    #[test]
    fn missing_model_rejected_up_front() {
        let e = TrainSession::builder().validate().unwrap_err().to_string();
        assert!(e.contains("model"), "{e}");
    }

    #[test]
    fn bad_configs_rejected_up_front() {
        assert!(native_builder().steps(0).validate().is_err());
        assert!(native_builder().grad_accum(0).validate().is_err());
        assert!(native_builder()
            .hyper(Hyper { precond_freq: 0, ..Hyper::default() })
            .validate()
            .is_err());
        // PJRT gates: native model, async refresh, non-artifact optimizer.
        assert!(native_builder().backend(Backend::Pjrt).validate().is_err());
        let artifact = || {
            TrainSession::builder()
                .model(ModelSpec::artifact("nano"))
                .backend(Backend::Pjrt)
        };
        assert!(artifact().optimizer(OptKind::Shampoo).validate().is_err());
        assert!(artifact()
            .hyper(Hyper::default().async_refresh())
            .validate()
            .is_err());
        assert!(artifact()
            .hyper(Hyper::default().factorized())
            .validate()
            .is_err());
        assert!(artifact().resume_from("/tmp/x.ckpt").validate().is_err());
        assert!(artifact().validate().is_ok());
    }

    #[test]
    fn builds_native_session_and_trains() {
        let mut s = native_builder().build().unwrap();
        assert_eq!(s.current_step(), 0);
        let log = s.run().unwrap();
        assert_eq!(s.current_step(), 3);
        assert_eq!(log.losses.len(), 3);
        assert!(log.final_loss().is_finite());
        assert!(s.state_bytes() > 0);
        // run() is budget-based: a second call is a no-op at the budget.
        let log2 = s.run().unwrap();
        assert!(log2.losses.is_empty());
    }

    #[test]
    fn distributed_wiring_validated_up_front() {
        use crate::dist::MemCluster;
        let dist_backend = Backend::Distributed { ranks: 2, transport: crate::dist::Transport::Mem };
        // Missing DistOptions.
        let e = native_builder().backend(dist_backend).validate().unwrap_err().to_string();
        assert!(e.contains("--rank"), "{e}");
        // World-size mismatch between backend and options.
        let ep = MemCluster::new(3).pop().unwrap();
        let e = native_builder()
            .backend(dist_backend)
            .dist(DistOptions {
                rank: 2,
                ranks: 3,
                timeout: Duration::from_secs(1),
                endpoint: DistEndpoint::Mem(ep),
            })
            .validate()
            .unwrap_err()
            .to_string();
        assert!(e.contains("3 ranks"), "{e}");
        // Options on a non-distributed backend.
        let ep = MemCluster::new(2).pop().unwrap();
        let e = native_builder()
            .backend(Backend::Serial)
            .dist(DistOptions {
                rank: 1,
                ranks: 2,
                timeout: Duration::from_secs(1),
                endpoint: DistEndpoint::Mem(ep),
            })
            .validate()
            .unwrap_err()
            .to_string();
        assert!(e.contains("serial"), "{e}");
        // Artifact models cannot run distributed.
        let e = TrainSession::builder()
            .model(ModelSpec::artifact("nano"))
            .backend(dist_backend)
            .validate()
            .unwrap_err()
            .to_string();
        assert!(e.contains("native"), "{e}");
    }

    #[test]
    fn composed_spec_flag_contradiction_rejected() {
        let spec = OptKind::parse("basis=eigen:two-sided,inner=adam").unwrap();
        let b = native_builder()
            .optimizer(spec)
            .hyper(Hyper::default().one_sided());
        assert!(b.validate().is_err());
    }
}
