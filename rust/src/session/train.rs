//! [`TrainSession`] — the uniform training lifecycle every entry point
//! (CLI, benches, examples) drives: `step()` / `run()` over a validated
//! configuration, typed metrics streaming, state accounting, and
//! first-class `checkpoint()`/resume.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::backend::ExecutorBackend;
use super::sink::{HealthSnapshot, MetricsSink, StepRecord};
use crate::coordinator::{Checkpoint, GradBackend, StepTiming, TrainLog};
use crate::data::{Batch, BatchStream, CorpusSpec};
use crate::dist::{microbatch_slice, DistComm};
use crate::linalg::{Matrix, TensorShape};
use crate::model;
use crate::optim::hyper::GuardPolicy;
use crate::optim::{Hyper, OptKind, RefreshMode, Schedule};
use crate::runtime::{
    literal_from_matrix, literal_from_tokens, matrix_from_literal, scalar_from_literal,
};

/// A built training session: model + data + executor behind one lifecycle.
///
/// Construct through [`crate::session::SessionBuilder`] (via
/// `TrainSession::builder()`), which validates the whole configuration up
/// front. `steps` is the TOTAL step budget: a session resumed from a
/// checkpoint at step `k` runs `steps − k` more steps, with the LR schedule
/// and the data cursor both restored — unlike the pre-redesign `--resume`
/// path, which restored the schedule step but replayed data from batch 0.
pub struct TrainSession {
    pub(super) opt: OptKind,
    pub(super) hyper: Hyper,
    pub(super) schedule: Schedule,
    pub(super) total_steps: u64,
    pub(super) seed: u64,
    pub(super) grad_accum: usize,
    pub(super) vocab: usize,
    pub(super) zipf_alpha: f64,
    pub(super) grad: GradBackend,
    /// Display label from the [`super::ModelSpec`] (one source of truth for
    /// log aggregation keys).
    pub(super) model_label: String,
    pub(super) exec: Box<dyn ExecutorBackend>,
    pub params: Vec<Matrix>,
    pub shapes: Vec<(usize, usize)>,
    /// True N-dimensional shapes of the parameters (each folds to the
    /// matching `shapes` carrier); recorded in checkpoints (format v3) and
    /// validated on resume.
    pub tensor_shapes: Vec<TensorShape>,
    pub(super) stream: BatchStream,
    pub(super) steps_done: u64,
    pub(super) drain_refresh: bool,
    pub(super) sinks: Vec<Box<dyn MetricsSink>>,
    /// Telemetry master switch for THIS session (mirrors the global
    /// [`crate::telemetry::enabled`] flag the builder set).
    pub(super) telemetry: bool,
    /// Emit a [`HealthSnapshot`] every k-th step when telemetry is on
    /// (0 = never).
    pub(super) metrics_every: u64,
    /// Where `run()` writes the Chrome trace-event JSON, if anywhere.
    pub(super) trace_out: Option<PathBuf>,
    /// The communicator when this session is one rank of a distributed run
    /// (`Backend::Distributed`); `None` on single-process backends. Drives
    /// the microbatch split + gradient fold-reduce in [`Self::step`] and the
    /// health gather in `emit_health`.
    pub(super) dist: Option<Arc<DistComm>>,
}

impl TrainSession {
    /// Entry point: a builder with the paper-default configuration.
    pub fn builder() -> super::SessionBuilder {
        super::SessionBuilder::new()
    }

    /// 1-based step counter (0 before the first step; equals the checkpoint
    /// step right after a resume).
    pub fn current_step(&self) -> u64 {
        self.steps_done
    }

    /// The session's total step budget (`run()` stops here).
    pub fn total_steps(&self) -> u64 {
        self.total_steps
    }

    /// Tokens consumed per optimizer step.
    pub fn tokens_per_step(&self) -> usize {
        self.stream.batch * self.stream.seq
    }

    pub fn entropy_floor(&self) -> f64 {
        self.stream.entropy_floor()
    }

    /// Discard `k` batches from the data stream (resume fast-forward; the
    /// stream is a pure function of (seed, position)).
    pub(super) fn skip_batches(&mut self, k: u64) {
        for _ in 0..k {
            let _ = self.stream.next_batch();
        }
    }

    fn grads_for(&self, batch: &Batch) -> Result<(f32, Vec<Matrix>)> {
        match &self.grad {
            GradBackend::Pjrt { engine, config } => {
                let info = engine.manifest.config(config)?;
                anyhow::ensure!(batch.batch == info.batch, "microbatch must equal artifact batch");
                let mut inputs = Vec::with_capacity(self.params.len() + 2);
                for p in &self.params {
                    inputs.push(literal_from_matrix(p)?);
                }
                inputs.push(literal_from_tokens(&batch.tokens, batch.batch, batch.seq)?);
                inputs.push(literal_from_tokens(&batch.targets, batch.batch, batch.seq)?);
                let out = engine.run(&format!("lm_grads_{config}"), &inputs)?;
                let loss = scalar_from_literal(&out[0])?;
                let mut grads = Vec::with_capacity(self.params.len());
                for (i, &(r, c)) in self.shapes.iter().enumerate() {
                    grads.push(matrix_from_literal(&out[1 + i], r, c)?);
                }
                Ok((loss, grads))
            }
            GradBackend::Native { cfg } => {
                let (loss, grads) = model::loss_and_grads(cfg, &self.params, batch);
                Ok((loss, grads))
            }
        }
    }

    /// Run one training step; returns (loss, timing). Metrics sinks fire
    /// after the step completes.
    pub fn step(&mut self) -> Result<(f32, StepTiming)> {
        let mut timing = StepTiming::default();

        let span_data = crate::telemetry::span("step.data", "step");
        let t0 = Instant::now();
        let batch = self.stream.next_batch();
        let micro = batch.microbatches(self.grad_accum);
        timing.data_s = t0.elapsed().as_secs_f64();
        drop(span_data);

        // Gradient accumulation: mean over microbatches. Distributed runs
        // split the microbatch list into contiguous per-rank slices and
        // reproduce the serial fold-left bracketing through the
        // order-preserving fold-reduce chain — the sum every rank gets back
        // is BITWISE the sum this loop would have produced serially.
        let span_grad = crate::telemetry::span("step.grad", "step");
        let t0 = Instant::now();
        let (loss_acc, mut grads) = if let Some(comm) = self.dist.clone() {
            let (start, count) = microbatch_slice(comm.rank(), comm.nranks(), micro.len());
            let mut local = Vec::with_capacity(count);
            for mb in &micro[start..start + count] {
                let (loss, g) = self.grads_for(mb)?;
                local.push((loss as f64, g));
            }
            comm.fold_all_reduce(local, self.params.len())?
        } else {
            let mut loss_acc = 0.0f64;
            let mut grads: Option<Vec<Matrix>> = None;
            for mb in &micro {
                let (loss, g) = self.grads_for(mb)?;
                loss_acc += loss as f64;
                grads = Some(match grads.take() {
                    None => g,
                    Some(mut acc) => {
                        for (a, b) in acc.iter_mut().zip(&g) {
                            a.axpy_inplace(1.0, b);
                        }
                        acc
                    }
                });
            }
            (loss_acc, grads.ok_or_else(|| anyhow!("no microbatches"))?)
        };
        if micro.len() > 1 {
            let s = 1.0 / micro.len() as f32;
            for g in &mut grads {
                g.scale_inplace(s);
            }
        }
        let loss = (loss_acc / micro.len() as f64) as f32;
        timing.grad_s = t0.elapsed().as_secs_f64();
        drop(span_grad);

        // Seeded fault injection (post-allreduce, so every rank of a
        // distributed run poisons the same replicated gradient and the guard
        // decisions below stay in lockstep).
        if let Some(f) = crate::fault::active() {
            let t_next = self.steps_done + 1;
            if f.should_crash(t_next) {
                crate::telemetry::metrics::fault_injected_total().inc();
                eprintln!("fault-plan: injected crash at step {t_next}");
                std::process::exit(101);
            }
            for (layer, g) in grads.iter_mut().enumerate() {
                if let Some(v) = f.grad_poison(layer, t_next) {
                    crate::telemetry::metrics::fault_injected_total().inc();
                    g.data[0] = v;
                }
            }
        }

        // Gradient-level numerical-health guard: catch a poisoned batch
        // BEFORE the optimizer consumes it, so a skipped step leaves moments
        // and factor statistics exactly as they were — one bad batch costs
        // one step, not the run.
        let mut skip_update = false;
        if self.hyper.guard != GuardPolicy::Off {
            let finite = grads
                .iter()
                .all(|g| g.data.iter().map(|&x| (x as f64).abs()).sum::<f64>().is_finite());
            if !finite {
                match self.hyper.guard {
                    GuardPolicy::Off => {}
                    GuardPolicy::SkipStep => {
                        crate::telemetry::metrics::step_skipped_total().inc();
                        skip_update = true;
                    }
                    GuardPolicy::Clip(max) => {
                        for g in &mut grads {
                            for x in &mut g.data {
                                *x = if x.is_finite() { x.clamp(-max, max) } else { 0.0 };
                            }
                        }
                    }
                    GuardPolicy::Abort => anyhow::bail!(
                        "non-finite gradient at step {} (guard=abort)",
                        self.steps_done + 1
                    ),
                }
            }
        }

        // Optimizer step (+ refresh accounting): hot-path refresh seconds
        // from the executor's inline account, background seconds reported
        // separately (they overlap the step instead of extending it).
        self.steps_done += 1;
        let t = self.steps_done;
        let lr = self.schedule.lr_at(t - 1);
        let t0 = Instant::now();
        let refresh_before = self.exec.refresh_seconds();
        let bg_before = self.exec.async_refresh_seconds();
        let engine = match &self.grad {
            GradBackend::Pjrt { engine, .. } => Some(engine),
            GradBackend::Native { .. } => None,
        };
        if !skip_update {
            let _span = crate::telemetry::span("step.update", "step");
            self.exec.step(engine, &mut self.params, &grads, t, lr)?;
        }
        if crate::fault::take_guard_abort() {
            anyhow::bail!("non-finite update direction at step {t} (guard=abort)");
        }
        if self.drain_refresh {
            // Deterministic-async mode: adoption timing becomes a pure
            // function of the step count, so runs are replayable bitwise.
            // The drain wait is real critical-path time — captured below in
            // update_total so reported throughput stays honest.
            let _span = crate::telemetry::span("step.refresh", "step");
            self.exec.wait_refresh_idle();
        }
        let update_total = t0.elapsed().as_secs_f64();
        timing.refresh_s = self.exec.refresh_seconds() - refresh_before;
        timing.update_s = (update_total - timing.refresh_s).max(0.0);
        timing.bg_refresh_s = (self.exec.async_refresh_seconds() - bg_before).max(0.0);
        timing.staleness_steps = self.exec.mean_basis_staleness(t);

        let rec = StepRecord {
            step: t,
            loss,
            lr,
            tokens_per_step: self.stream.batch * self.stream.seq,
            timing: &timing,
        };
        for sink in &mut self.sinks {
            sink.on_step(&rec);
        }
        if self.telemetry && self.metrics_every > 0 && t % self.metrics_every == 0 {
            self.emit_health(t, &grads);
        }
        Ok((loss, timing))
    }

    /// Assemble a [`HealthSnapshot`] — per-layer optimizer health plus
    /// refresh-service and pool introspection — and publish it through every
    /// sink, mirroring the queue depth into the metrics-registry gauge.
    /// Telemetry-gated by the caller; runs on the metrics cadence only, so
    /// its allocations never touch the steady-state step path.
    fn emit_health(&mut self, t: u64, grads: &[Matrix]) {
        let mut layers = self.exec.collect_layer_health(t);
        for lh in layers.iter_mut() {
            if let Some(g) = grads.get(lh.layer) {
                lh.grad_norm =
                    Some(g.data.iter().map(|&x| x as f64 * x as f64).sum::<f64>().sqrt());
            }
        }
        // Distributed: gather every rank's ownership/traffic row. This is a
        // COLLECTIVE — all ranks reach it at the same metrics step (same
        // config ⇒ same cadence), sinks or no sinks. A gather failure here
        // must not kill the step (health is advisory); the next all-reduce
        // surfaces the typed error if a peer is really gone.
        let ranks = match &self.dist {
            Some(comm) => {
                let local = self.exec.dist_rank_health().unwrap_or_default();
                match comm.gather_health(&local) {
                    Ok(Some(rows)) => rows,
                    Ok(None) => Vec::new(),
                    Err(e) => {
                        eprintln!("warning: distributed health gather failed: {e}");
                        Vec::new()
                    }
                }
            }
            None => Vec::new(),
        };
        let queue_depth = self.exec.refresh_queue_depth();
        crate::telemetry::metrics::refresh_queue_depth().set(queue_depth as f64);
        let (pool_jobs, pool_busy_s) = match self.exec.refresh_pool_stats() {
            Some((jobs, busy)) => (Some(jobs), Some(busy)),
            None => (None, None),
        };
        let lat = crate::telemetry::metrics::refresh_latency_seconds();
        let faults = super::sink::FaultHealth {
            injected_total: crate::telemetry::metrics::fault_injected_total().get(),
            steps_skipped_total: crate::telemetry::metrics::step_skipped_total().get(),
            bases_rejected_total: crate::telemetry::metrics::basis_rejected_total().get(),
            transport_retries_total: crate::telemetry::metrics::transport_retries_total().get(),
            heartbeats_sent_total: crate::telemetry::metrics::heartbeats_sent_total().get(),
            heartbeat_silence_s: crate::telemetry::metrics::heartbeat_silence_seconds().get(),
        };
        let health = HealthSnapshot {
            step: t,
            queue_depth,
            shed_total: crate::telemetry::metrics::refresh_shed_total().get(),
            refresh_p50_s: lat.quantile(0.5),
            refresh_p99_s: lat.quantile(0.99),
            refresh_count: lat.count(),
            pool_jobs,
            pool_busy_s,
            layers,
            ranks,
            faults,
        };
        for sink in &mut self.sinks {
            sink.on_health(&health);
        }
    }

    /// Train up to the session's total step budget, returning the full log.
    pub fn run(&mut self) -> Result<TrainLog> {
        let mut log = TrainLog {
            optimizer: self.opt_label(),
            model: self.model_label(),
            tokens_per_batch: self.tokens_per_step(),
            ..Default::default()
        };
        while self.steps_done < self.total_steps {
            let (loss, timing) = self.step()?;
            log.losses.push((self.steps_done, loss));
            log.timings.push(timing);
        }
        for sink in &mut self.sinks {
            sink.on_complete(&log);
        }
        // Trace requested with telemetry never enabled still writes a
        // valid (empty) trace — the file's existence is part of the CLI
        // contract, its contents are whatever the recorder captured.
        if let Some(path) = self.trace_out.clone() {
            crate::telemetry::trace::write_chrome_trace(&path)
                .with_context(|| format!("writing chrome trace to {}", path.display()))?;
        }
        Ok(log)
    }

    /// Evaluate mean loss over `batches` held-out batches (same language,
    /// fresh sample stream).
    pub fn eval_loss(&mut self, batches: usize) -> Result<f32> {
        let mut eval_stream = BatchStream::new(
            CorpusSpec {
                vocab_size: self.vocab,
                zipf_alpha: self.zipf_alpha,
                seed: self.seed,      // SAME language…
                stream: 0xE7A1,       // …fresh held-out sample stream
            },
            self.stream.batch / self.grad_accum.max(1),
            self.stream.seq,
            0,
            1,
        );
        let mut total = 0.0f64;
        for _ in 0..batches {
            let b = eval_stream.next_batch();
            let (loss, _) = self.grads_for(&b)?;
            total += loss as f64;
        }
        Ok((total / batches as f64) as f32)
    }

    /// Snapshot the full resumable state: parameters, optimizer state
    /// (drained and adoption-complete in async mode), step counter, data
    /// cursor, and seed. A session resumed from this checkpoint continues
    /// bitwise-identically to an uninterrupted run (inline and drained-async
    /// refresh modes; undrained async is nondeterministic by nature).
    pub fn checkpoint(&mut self) -> Result<Checkpoint> {
        self.exec.prepare_export();
        Ok(Checkpoint {
            step: self.steps_done,
            params: self.params.clone(),
            opt_state: self.exec.export_state()?,
            data_batches: self.stream.batches_produced(),
            seed: Some(self.seed),
            stream_batch: self.stream.batch as u32,
            stream_seq: self.stream.seq as u32,
            param_dims: self.tensor_shapes.iter().map(|s| s.dims().to_vec()).collect(),
            state_dtype: self.hyper.state_dtype,
        })
    }

    /// [`Self::checkpoint`] straight to a file.
    pub fn save_checkpoint(&mut self, path: impl AsRef<Path>) -> Result<()> {
        self.checkpoint()?.save(path)
    }

    /// Restore a checkpoint into this (freshly built) session — the builder
    /// calls this for `resume_from`; strict about shape/seed/step mismatches.
    pub(super) fn apply_resume(&mut self, ck: Checkpoint) -> Result<()> {
        anyhow::ensure!(
            ck.params.len() == self.params.len(),
            "checkpoint has {} parameter tensors but the model has {}",
            ck.params.len(),
            self.params.len()
        );
        for (i, (p, q)) in ck.params.iter().zip(&self.params).enumerate() {
            anyhow::ensure!(
                p.rows == q.rows && p.cols == q.cols,
                "checkpoint param {i} is {}×{} but the model expects {}×{}",
                p.rows,
                p.cols,
                q.rows,
                q.cols
            );
        }
        // v3 checkpoints record each param's true N-D shape. A mismatch
        // means the optimizer state rows were built over a DIFFERENT
        // per-mode decomposition (e.g. a rank-3 kernel resumed as a
        // matrix) — reject instead of misinterpreting the factor records.
        // Empty = legacy v1/v2 file, shapes unrecorded.
        if !ck.param_dims.is_empty() {
            for (i, (dims, ts)) in ck.param_dims.iter().zip(&self.tensor_shapes).enumerate() {
                anyhow::ensure!(
                    dims == ts.dims(),
                    "checkpoint param {i} has tensor shape {dims:?} but the session's model \
                     declares {:?} — resume with the model the checkpoint was written from",
                    ts.dims()
                );
            }
        }
        if let Some(s) = ck.seed {
            anyhow::ensure!(
                s == self.seed,
                "checkpoint was written with seed {s} but the session uses seed {} — a \
                 resumed run would train on a different data stream (pass the original seed)",
                self.seed
            );
        }
        // The data cursor counts stream batches of the ORIGINAL geometry; a
        // changed batch size / grad-accum / sequence length would silently
        // fast-forward to the wrong tokens. (0 = legacy v1, unrecorded.)
        if ck.stream_batch != 0 {
            anyhow::ensure!(
                ck.stream_batch as usize == self.stream.batch
                    && ck.stream_seq as usize == self.stream.seq,
                "checkpoint was written with stream geometry {}×{} (batch·grad-accum × seq) \
                 but the session uses {}×{} — resume with the original batch/grad-accum/seq",
                ck.stream_batch,
                ck.stream_seq,
                self.stream.batch,
                self.stream.seq
            );
        }
        // A changed --state-dtype would re-round every subsequent EMA update
        // differently from the writing run (v1–v3 files default to f32, the
        // only dtype those writers had).
        anyhow::ensure!(
            ck.state_dtype == self.hyper.state_dtype,
            "checkpoint state dtype is {} but the session uses {} — resume with \
             --state-dtype {} (the precision the state was written in)",
            ck.state_dtype.name(),
            self.hyper.state_dtype.name(),
            ck.state_dtype.name()
        );
        anyhow::ensure!(
            ck.step <= self.total_steps,
            "checkpoint is already at step {} but the session's total budget is {} — \
             raise steps to continue the run",
            ck.step,
            self.total_steps
        );
        self.exec.import_state(ck.opt_state)?;
        self.params = ck.params;
        self.steps_done = ck.step;
        self.skip_batches(ck.data_batches);
        Ok(())
    }

    // ---- accounting passthroughs -------------------------------------

    /// Persistent optimizer state bytes (paper §7.2 accounting).
    pub fn state_bytes(&self) -> usize {
        self.exec.state_bytes()
    }

    /// Workspace-arena bytes held by the step path (0 for PJRT).
    pub fn scratch_bytes(&self) -> usize {
        self.exec.scratch_bytes()
    }

    /// Cumulative hot-path refresh seconds.
    pub fn refresh_seconds(&self) -> f64 {
        self.exec.refresh_seconds()
    }

    /// Cumulative background (async-service) refresh seconds.
    pub fn async_refresh_seconds(&self) -> f64 {
        self.exec.async_refresh_seconds()
    }

    /// Mean basis staleness (steps) right now.
    pub fn mean_basis_staleness(&self) -> f64 {
        self.exec.mean_basis_staleness(self.steps_done)
    }

    /// Drain in-flight background refreshes (no-op inline/PJRT). Call
    /// before reading final `async_refresh_seconds` totals.
    pub fn wait_refresh_idle(&self) {
        self.exec.wait_refresh_idle();
    }

    /// Attach another metrics sink mid-run.
    pub fn add_sink(&mut self, sink: Box<dyn MetricsSink>) {
        self.sinks.push(sink);
    }

    /// Canonicalized optimizer label — preset/spec spellings of the same
    /// configuration share one aggregation key, variant suffixes come from
    /// the spec-resolved hyperparameters, and the backend is tagged.
    pub fn opt_label(&self) -> String {
        let mut h = self.hyper.clone();
        if let OptKind::Composed(spec) = &self.opt {
            spec.apply(&mut h);
        }
        let mut s = self.opt.canonical().name().to_string();
        if h.one_sided {
            s.push_str("-onesided");
        }
        if h.factorized {
            s.push_str("-factorized");
        }
        if self.hyper.refresh_mode == RefreshMode::Async {
            s.push_str("-async");
        }
        if self.exec.name() == "pjrt" {
            s.push_str("(pjrt)");
        }
        s
    }

    pub fn model_label(&self) -> String {
        self.model_label.clone()
    }
}
