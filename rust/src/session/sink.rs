//! Typed metrics streaming for [`super::TrainSession`]: every training step
//! emits a [`StepRecord`] to each attached [`MetricsSink`], and the final
//! [`TrainLog`] is offered once at the end of `run()`. Sinks replace the
//! ad-hoc `println!` blocks the pre-redesign entry points each hand-rolled.

use std::io::Write;

use crate::coordinator::{StepTiming, TrainLog};
use crate::util::json::Json;

/// One step's worth of metrics, as handed to sinks.
#[derive(Clone, Copy, Debug)]
pub struct StepRecord<'a> {
    /// 1-based global step.
    pub step: u64,
    pub loss: f32,
    /// Learning rate applied on this step.
    pub lr: f32,
    /// Tokens consumed per optimizer step.
    pub tokens_per_step: usize,
    pub timing: &'a StepTiming,
}

/// Streaming consumer of training metrics.
pub trait MetricsSink {
    /// Called after every training step.
    fn on_step(&mut self, rec: &StepRecord<'_>);

    /// Called once when `run()` finishes, with the full log.
    fn on_complete(&mut self, _log: &TrainLog) {}
}

/// Human-readable progress lines on stdout, every `k`-th step — the format
/// the pre-redesign `Trainer::run` printed.
pub struct StdoutSink {
    every: u64,
}

impl StdoutSink {
    pub fn every(k: u64) -> Self {
        Self { every: k }
    }
}

impl MetricsSink for StdoutSink {
    fn on_step(&mut self, rec: &StepRecord<'_>) {
        if self.every > 0 && rec.step % self.every == 0 {
            println!(
                "step {:>6}  loss {:.4}  lr {:.2e}  {:.0} tok/s",
                rec.step,
                rec.loss,
                rec.lr,
                rec.tokens_per_step as f64 / rec.timing.total().max(1e-9),
            );
        }
    }
}

/// One JSON object per step on any writer — machine-readable streaming for
/// dashboards and log scrapers.
pub struct JsonlSink<W: Write> {
    out: W,
}

impl<W: Write> JsonlSink<W> {
    pub fn new(out: W) -> Self {
        Self { out }
    }
}

impl<W: Write> MetricsSink for JsonlSink<W> {
    fn on_step(&mut self, rec: &StepRecord<'_>) {
        let line = Json::obj(vec![
            ("step", Json::num(rec.step as f64)),
            ("loss", Json::num(rec.loss as f64)),
            ("lr", Json::num(rec.lr as f64)),
            ("step_s", Json::num(rec.timing.total())),
            ("refresh_s", Json::num(rec.timing.refresh_s)),
            ("staleness_steps", Json::num(rec.timing.staleness_steps)),
        ]);
        let _ = writeln!(self.out, "{}", line.dump());
    }

    fn on_complete(&mut self, _log: &TrainLog) {
        let _ = self.out.flush();
    }
}

/// In-memory sink: collects `(step, loss)` pairs. Mostly for tests and
/// programmatic consumers that want live losses without parsing the log.
#[derive(Default)]
pub struct CollectSink {
    pub losses: Vec<(u64, f32)>,
    pub completed: bool,
}

impl MetricsSink for CollectSink {
    fn on_step(&mut self, rec: &StepRecord<'_>) {
        self.losses.push((rec.step, rec.loss));
    }

    fn on_complete(&mut self, _log: &TrainLog) {
        self.completed = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(timing: &StepTiming) -> StepRecord<'_> {
        StepRecord { step: 3, loss: 1.5, lr: 0.01, tokens_per_step: 256, timing }
    }

    #[test]
    fn jsonl_sink_emits_parseable_lines() {
        let mut buf = Vec::new();
        {
            let mut sink = JsonlSink::new(&mut buf);
            let t = StepTiming { grad_s: 0.5, update_s: 0.25, ..Default::default() };
            sink.on_step(&rec(&t));
        }
        let line = String::from_utf8(buf).unwrap();
        let v = Json::parse(line.trim()).unwrap();
        assert_eq!(v.get("step").as_f64(), Some(3.0));
        assert_eq!(v.get("loss").as_f64(), Some(1.5));
        assert_eq!(v.get("step_s").as_f64(), Some(0.75));
    }

    #[test]
    fn collect_sink_accumulates() {
        let mut sink = CollectSink::default();
        let t = StepTiming::default();
        sink.on_step(&rec(&t));
        sink.on_complete(&TrainLog::default());
        assert_eq!(sink.losses, vec![(3, 1.5)]);
        assert!(sink.completed);
    }
}
