//! Typed metrics streaming for [`super::TrainSession`]: every training step
//! emits a [`StepRecord`] to each attached [`MetricsSink`], and the final
//! [`TrainLog`] is offered once at the end of `run()`. Sinks replace the
//! ad-hoc `println!` blocks the pre-redesign entry points each hand-rolled.

use std::io::{self, Write};
use std::sync::{Arc, Mutex};

use crate::coordinator::{StepTiming, TrainLog};
use crate::util::json::Json;

/// One step's worth of metrics, as handed to sinks.
#[derive(Clone, Copy, Debug)]
pub struct StepRecord<'a> {
    /// 1-based global step.
    pub step: u64,
    pub loss: f32,
    /// Learning rate applied on this step.
    pub lr: f32,
    /// Tokens consumed per optimizer step.
    pub tokens_per_step: usize,
    pub timing: &'a StepTiming,
}

/// Per-layer optimizer health, one entry per parameter tensor.
#[derive(Clone, Debug, Default)]
pub struct LayerHealth {
    /// Layer index in executor order (matches checkpoint layer order).
    pub layer: usize,
    /// Frobenius norm of this step's (accumulated) gradient. `None` when the
    /// backend cannot measure it (PJRT holds gradients device-side) — an
    /// explicit "unsupported" marker, never a fake `0.0`.
    pub grad_norm: Option<f64>,
    /// Frobenius norm of the last preconditioned update direction, when the
    /// optimizer exposes one (composed optimizers do; PJRT does not).
    pub update_norm: Option<f64>,
    /// Basis staleness in steps (`t − basis_step`); `None` for optimizers
    /// without a refreshed basis (AdamW, Adafactor, identity basis).
    pub staleness: Option<u64>,
    /// Whitening quality: off-diagonal mass ratio of the rotated second
    /// moment `QᵀLQ` (0 = perfectly diagonal), sampled at the most recent
    /// refresh. `None` until first sampled or for basis-free optimizers.
    pub whitening_offdiag: Option<f64>,
}

/// One rank's row in a distributed health snapshot: refresh-ownership
/// distribution plus communicator traffic. Gathered from every worker on the
/// metrics cadence; empty (`HealthSnapshot::ranks`) outside the distributed
/// backend.
#[derive(Clone, Debug, Default)]
pub struct RankHealth {
    pub rank: usize,
    /// Layers whose eigenbasis refreshes this rank owns.
    pub owned_layers: usize,
    /// Basis publications this rank has broadcast so far — the observable
    /// proof that refreshes actually execute on non-zero ranks.
    pub owned_refreshes: u64,
    pub frames_sent: u64,
    pub frames_recv: u64,
    pub bytes_sent: u64,
    pub bytes_recv: u64,
    /// Cumulative wall-clock seconds this rank spent inside the gradient
    /// fold-reduce (send + wait + local adds).
    pub allreduce_s: f64,
}

impl RankHealth {
    pub fn new(rank: usize) -> Self {
        Self { rank, ..Self::default() }
    }
}

/// Cumulative fault-tolerance counters, sampled from the metrics registry on
/// the health cadence. Always present — all zeros on a clean run — so
/// dashboards can alert on the first nonzero value. Field names in the JSONL
/// `faults` object are the full `soap_*` series names.
#[derive(Clone, Copy, Debug, Default)]
pub struct FaultHealth {
    /// Faults fired by the seeded injection plan (`--fault-plan`).
    pub injected_total: u64,
    /// Optimizer updates skipped by the numerical-health guard.
    pub steps_skipped_total: u64,
    /// Refreshed bases rejected for non-finite factors (stale-basis grace).
    pub bases_rejected_total: u64,
    /// Transport retries (injected-drop re-sends + connect backoff rounds).
    pub transport_retries_total: u64,
    /// Heartbeat frames written by this process.
    pub heartbeats_sent_total: u64,
    /// Longest current peer silence, seconds (0 outside TCP transport).
    pub heartbeat_silence_s: f64,
}

/// A periodic optimizer-health sample (every `metrics_every` steps when
/// telemetry is enabled), combining per-layer state with refresh-service
/// and thread-pool introspection.
#[derive(Clone, Debug, Default)]
pub struct HealthSnapshot {
    /// 1-based global step this snapshot was taken after.
    pub step: u64,
    /// Background refreshes currently pending in the refresh service.
    pub queue_depth: usize,
    /// Cumulative refresh snapshots shed (skipped because the previous
    /// refresh of the same basis was still in flight).
    pub shed_total: u64,
    /// Background refresh-task latency quantiles, seconds (`NaN` until the
    /// first background refresh completes).
    pub refresh_p50_s: f64,
    pub refresh_p99_s: f64,
    /// Background refresh tasks completed so far.
    pub refresh_count: u64,
    /// Refresh `ThreadPool` utilization: jobs executed and cumulative busy
    /// seconds across workers (`None` when no async refresh service runs).
    pub pool_jobs: Option<u64>,
    pub pool_busy_s: Option<f64>,
    pub layers: Vec<LayerHealth>,
    /// Per-rank rows (distributed backend only; empty elsewhere). Rank 0
    /// gathers one row from every worker on the metrics cadence.
    pub ranks: Vec<RankHealth>,
    /// Fault-tolerance counters at this sample.
    pub faults: FaultHealth,
}

/// Streaming consumer of training metrics.
pub trait MetricsSink {
    /// Called after every training step.
    fn on_step(&mut self, rec: &StepRecord<'_>);

    /// Called on health-sample steps (telemetry enabled, every
    /// `metrics_every`-th step) with per-layer optimizer health.
    fn on_health(&mut self, _health: &HealthSnapshot) {}

    /// Called once when `run()` finishes, with the full log.
    fn on_complete(&mut self, _log: &TrainLog) {}
}

/// Human-readable progress lines on stdout, every `k`-th step — the format
/// the pre-redesign `Trainer::run` printed.
pub struct StdoutSink {
    every: u64,
}

impl StdoutSink {
    pub fn every(k: u64) -> Self {
        Self { every: k }
    }
}

impl MetricsSink for StdoutSink {
    fn on_step(&mut self, rec: &StepRecord<'_>) {
        if self.every > 0 && rec.step % self.every == 0 {
            println!(
                "step {:>6}  loss {:.4}  lr {:.2e}  {:.0} tok/s",
                rec.step,
                rec.loss,
                rec.lr,
                rec.tokens_per_step as f64 / rec.timing.total().max(1e-9),
            );
        }
    }
}

/// One JSON object per step on any writer — machine-readable streaming for
/// dashboards and log scrapers.
pub struct JsonlSink<W: Write> {
    out: W,
    tags: Vec<(String, Json)>,
}

impl<W: Write> JsonlSink<W> {
    pub fn new(out: W) -> Self {
        Self { out, tags: Vec::new() }
    }

    /// Stamp every emitted line with an extra top-level field — how the sweep
    /// orchestrator tags a multiplexed stream with `job_id` and the job's
    /// parameter assignment.
    pub fn with_tag(mut self, key: impl Into<String>, value: Json) -> Self {
        self.tags.push((key.into(), value));
        self
    }
}

/// `NaN`/infinite floats have no JSON representation; emit `null` so every
/// line stays parseable.
fn num_or_null(x: f64) -> Json {
    if x.is_finite() { Json::num(x) } else { Json::Null }
}

fn opt_num(x: Option<f64>) -> Json {
    match x {
        Some(v) => num_or_null(v),
        None => Json::Null,
    }
}

impl<W: Write> MetricsSink for JsonlSink<W> {
    fn on_step(&mut self, rec: &StepRecord<'_>) {
        let mut fields = vec![
            ("step", Json::num(rec.step as f64)),
            ("loss", Json::num(rec.loss as f64)),
            ("lr", Json::num(rec.lr as f64)),
            ("step_s", Json::num(rec.timing.total())),
            ("data_s", Json::num(rec.timing.data_s)),
            ("grad_s", Json::num(rec.timing.grad_s)),
            ("update_s", Json::num(rec.timing.update_s)),
            ("refresh_s", Json::num(rec.timing.refresh_s)),
            ("bg_refresh_s", Json::num(rec.timing.bg_refresh_s)),
            ("staleness_steps", Json::num(rec.timing.staleness_steps)),
        ];
        for (k, v) in &self.tags {
            fields.push((k.as_str(), v.clone()));
        }
        let line = Json::obj(fields);
        let _ = writeln!(self.out, "{}", line.dump());
    }

    fn on_health(&mut self, health: &HealthSnapshot) {
        let layers = health
            .layers
            .iter()
            .map(|l| {
                Json::obj(vec![
                    ("layer", Json::num(l.layer as f64)),
                    ("grad_norm", opt_num(l.grad_norm)),
                    ("update_norm", opt_num(l.update_norm)),
                    ("staleness", opt_num(l.staleness.map(|s| s as f64))),
                    ("whitening_offdiag", opt_num(l.whitening_offdiag)),
                ])
            })
            .collect::<Vec<_>>();
        let mut fields = vec![
            ("kind", Json::str("health")),
            ("step", Json::num(health.step as f64)),
            ("queue_depth", Json::num(health.queue_depth as f64)),
            ("shed_total", Json::num(health.shed_total as f64)),
            ("refresh_p50_s", num_or_null(health.refresh_p50_s)),
            ("refresh_p99_s", num_or_null(health.refresh_p99_s)),
            ("refresh_count", Json::num(health.refresh_count as f64)),
            ("pool_jobs", opt_num(health.pool_jobs.map(|j| j as f64))),
            ("pool_busy_s", opt_num(health.pool_busy_s)),
            (
                "faults",
                Json::obj(vec![
                    (
                        "soap_fault_injected_total",
                        Json::num(health.faults.injected_total as f64),
                    ),
                    (
                        "soap_step_skipped_total",
                        Json::num(health.faults.steps_skipped_total as f64),
                    ),
                    (
                        "soap_basis_rejected_total",
                        Json::num(health.faults.bases_rejected_total as f64),
                    ),
                    (
                        "soap_transport_retries_total",
                        Json::num(health.faults.transport_retries_total as f64),
                    ),
                    (
                        "soap_heartbeats_sent_total",
                        Json::num(health.faults.heartbeats_sent_total as f64),
                    ),
                    (
                        "soap_heartbeat_silence_seconds",
                        num_or_null(health.faults.heartbeat_silence_s),
                    ),
                ]),
            ),
            ("layers", Json::Arr(layers)),
        ];
        if !health.ranks.is_empty() {
            let ranks = health
                .ranks
                .iter()
                .map(|r| {
                    Json::obj(vec![
                        ("rank", Json::num(r.rank as f64)),
                        ("owned_layers", Json::num(r.owned_layers as f64)),
                        ("owned_refreshes", Json::num(r.owned_refreshes as f64)),
                        ("frames_sent", Json::num(r.frames_sent as f64)),
                        ("frames_recv", Json::num(r.frames_recv as f64)),
                        ("bytes_sent", Json::num(r.bytes_sent as f64)),
                        ("bytes_recv", Json::num(r.bytes_recv as f64)),
                        ("allreduce_s", num_or_null(r.allreduce_s)),
                    ])
                })
                .collect::<Vec<_>>();
            fields.push(("ranks", Json::Arr(ranks)));
        }
        for (k, v) in &self.tags {
            fields.push((k.as_str(), v.clone()));
        }
        let line = Json::obj(fields);
        let _ = writeln!(self.out, "{}", line.dump());
    }

    fn on_complete(&mut self, _log: &TrainLog) {
        let _ = self.out.flush();
    }
}

/// Line-atomic fan-in for multiplexed streams: each [`handle`] buffers bytes
/// privately and forwards only complete `\n`-terminated lines to the shared
/// underlying writer under one lock, so concurrently-running jobs' JSONL
/// lines interleave whole, never torn mid-line.
///
/// [`handle`]: SharedLineWriter::handle
pub struct SharedLineWriter {
    inner: Arc<Mutex<Box<dyn Write + Send>>>,
    buf: Vec<u8>,
}

impl SharedLineWriter {
    pub fn new(out: impl Write + Send + 'static) -> Self {
        Self { inner: Arc::new(Mutex::new(Box::new(out))), buf: Vec::new() }
    }

    /// A new handle on the same underlying writer, with its own line buffer.
    /// Give one to each concurrent producer.
    pub fn handle(&self) -> Self {
        Self { inner: Arc::clone(&self.inner), buf: Vec::new() }
    }

    fn locked(&self) -> std::sync::MutexGuard<'_, Box<dyn Write + Send>> {
        // A producer that panicked mid-job (sweep jobs are unwound and
        // recorded as failed rows) must not wedge every other job's stream.
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }
}

impl Write for SharedLineWriter {
    fn write(&mut self, data: &[u8]) -> io::Result<usize> {
        self.buf.extend_from_slice(data);
        if let Some(pos) = self.buf.iter().rposition(|&b| b == b'\n') {
            let complete: Vec<u8> = self.buf.drain(..=pos).collect();
            self.locked().write_all(&complete)?;
        }
        Ok(data.len())
    }

    fn flush(&mut self) -> io::Result<()> {
        // An incomplete tail line stays buffered — flushing it would tear the
        // line; it goes out when its newline arrives.
        self.locked().flush()
    }
}

/// In-memory sink: collects `(step, loss)` pairs and health snapshots.
/// Mostly for tests and programmatic consumers that want live metrics
/// without parsing the log.
#[derive(Default)]
pub struct CollectSink {
    pub losses: Vec<(u64, f32)>,
    pub health: Vec<HealthSnapshot>,
    pub completed: bool,
}

impl MetricsSink for CollectSink {
    fn on_step(&mut self, rec: &StepRecord<'_>) {
        self.losses.push((rec.step, rec.loss));
    }

    fn on_health(&mut self, health: &HealthSnapshot) {
        self.health.push(health.clone());
    }

    fn on_complete(&mut self, _log: &TrainLog) {
        self.completed = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(timing: &StepTiming) -> StepRecord<'_> {
        StepRecord { step: 3, loss: 1.5, lr: 0.01, tokens_per_step: 256, timing }
    }

    #[test]
    fn jsonl_sink_emits_parseable_lines() {
        let mut buf = Vec::new();
        {
            let mut sink = JsonlSink::new(&mut buf);
            let t = StepTiming {
                data_s: 0.125,
                grad_s: 0.5,
                update_s: 0.25,
                bg_refresh_s: 0.0625,
                ..Default::default()
            };
            sink.on_step(&rec(&t));
        }
        let line = String::from_utf8(buf).unwrap();
        let v = Json::parse(line.trim()).unwrap();
        assert_eq!(v.get("step").as_f64(), Some(3.0));
        assert_eq!(v.get("loss").as_f64(), Some(1.5));
        assert_eq!(v.get("step_s").as_f64(), Some(0.875));
        // The full timing breakdown rides along (bg_refresh_s overlaps the
        // step, so it is reported but excluded from step_s).
        assert_eq!(v.get("data_s").as_f64(), Some(0.125));
        assert_eq!(v.get("grad_s").as_f64(), Some(0.5));
        assert_eq!(v.get("update_s").as_f64(), Some(0.25));
        assert_eq!(v.get("bg_refresh_s").as_f64(), Some(0.0625));
    }

    #[test]
    fn jsonl_sink_emits_parseable_health_lines() {
        let mut buf = Vec::new();
        {
            let mut sink = JsonlSink::new(&mut buf);
            let h = HealthSnapshot {
                step: 10,
                queue_depth: 2,
                shed_total: 1,
                refresh_p50_s: f64::NAN, // no background refresh yet
                refresh_p99_s: f64::NAN,
                refresh_count: 0,
                pool_jobs: Some(4),
                pool_busy_s: Some(0.5),
                layers: vec![
                    LayerHealth {
                        layer: 0,
                        grad_norm: Some(2.0),
                        update_norm: Some(0.25),
                        staleness: Some(3),
                        whitening_offdiag: Some(0.125),
                    },
                    LayerHealth { layer: 1, ..Default::default() },
                ],
                ranks: vec![RankHealth {
                    rank: 1,
                    owned_layers: 4,
                    owned_refreshes: 9,
                    frames_sent: 100,
                    frames_recv: 90,
                    bytes_sent: 4096,
                    bytes_recv: 2048,
                    allreduce_s: 0.25,
                }],
                faults: FaultHealth {
                    injected_total: 2,
                    steps_skipped_total: 1,
                    ..Default::default()
                },
            };
            sink.on_health(&h);
        }
        let line = String::from_utf8(buf).unwrap();
        let v = Json::parse(line.trim()).unwrap();
        assert_eq!(v.get("kind").as_str(), Some("health"));
        assert_eq!(v.get("queue_depth").as_f64(), Some(2.0));
        // NaN quantiles must serialize as null, keeping the line valid JSON.
        assert_eq!(v.get("refresh_p50_s"), &Json::Null);
        let layers = v.get("layers").as_arr().unwrap();
        assert_eq!(layers.len(), 2);
        assert_eq!(layers[0].get("staleness").as_f64(), Some(3.0));
        assert_eq!(layers[0].get("whitening_offdiag").as_f64(), Some(0.125));
        assert_eq!(layers[1].get("update_norm"), &Json::Null);
        // Backend-unsupported grad_norm is an explicit null, not a fake 0.0.
        assert_eq!(layers[1].get("grad_norm"), &Json::Null);
        let ranks = v.get("ranks").as_arr().unwrap();
        assert_eq!(ranks.len(), 1);
        assert_eq!(ranks[0].get("rank").as_f64(), Some(1.0));
        assert_eq!(ranks[0].get("owned_refreshes").as_f64(), Some(9.0));
        assert_eq!(ranks[0].get("allreduce_s").as_f64(), Some(0.25));
        // Fault counters ride along under their full series names.
        let faults = v.get("faults");
        assert_eq!(faults.get("soap_fault_injected_total").as_f64(), Some(2.0));
        assert_eq!(faults.get("soap_step_skipped_total").as_f64(), Some(1.0));
        assert_eq!(faults.get("soap_basis_rejected_total").as_f64(), Some(0.0));
    }

    #[test]
    fn jsonl_health_omits_ranks_outside_distributed() {
        let mut buf = Vec::new();
        {
            let mut sink = JsonlSink::new(&mut buf);
            sink.on_health(&HealthSnapshot { step: 1, ..Default::default() });
        }
        let v = Json::parse(String::from_utf8(buf).unwrap().trim()).unwrap();
        assert_eq!(v.get("ranks"), &Json::Null, "single-process runs must not emit a ranks array");
    }

    #[test]
    fn jsonl_sink_tags_every_line() {
        let mut buf = Vec::new();
        {
            let mut sink = JsonlSink::new(&mut buf)
                .with_tag("job_id", Json::str("j003"))
                .with_tag("assign", Json::obj(vec![("lr", Json::num(0.01))]));
            let t = StepTiming::default();
            sink.on_step(&rec(&t));
            sink.on_health(&HealthSnapshot { step: 3, ..Default::default() });
        }
        let text = String::from_utf8(buf).unwrap();
        let mut lines = text.lines();
        let step = Json::parse(lines.next().unwrap()).unwrap();
        assert_eq!(step.get("job_id").as_str(), Some("j003"));
        assert_eq!(step.get("assign").get("lr").as_f64(), Some(0.01));
        assert_eq!(step.get("loss").as_f64(), Some(1.5));
        let health = Json::parse(lines.next().unwrap()).unwrap();
        assert_eq!(health.get("kind").as_str(), Some("health"));
        assert_eq!(health.get("job_id").as_str(), Some("j003"));
    }

    #[test]
    fn shared_line_writer_keeps_lines_whole() {
        use std::sync::{Arc, Mutex};

        #[derive(Clone, Default)]
        struct Capture(Arc<Mutex<Vec<u8>>>);
        impl Write for Capture {
            fn write(&mut self, data: &[u8]) -> io::Result<usize> {
                self.0.lock().unwrap().extend_from_slice(data);
                Ok(data.len())
            }
            fn flush(&mut self) -> io::Result<()> {
                Ok(())
            }
        }

        let cap = Capture::default();
        let root = SharedLineWriter::new(cap.clone());
        let mut a = root.handle();
        let mut b = root.handle();
        // Interleave partial writes from two handles; nothing may reach the
        // underlying writer until a newline completes the line.
        a.write_all(b"{\"job\":").unwrap();
        b.write_all(b"{\"job\":\"b\"}\n").unwrap();
        assert_eq!(&*cap.0.lock().unwrap(), b"{\"job\":\"b\"}\n");
        a.write_all(b"\"a\"}\n").unwrap();
        let text = String::from_utf8(cap.0.lock().unwrap().clone()).unwrap();
        assert_eq!(text, "{\"job\":\"b\"}\n{\"job\":\"a\"}\n");
        for line in text.lines() {
            Json::parse(line).unwrap();
        }

        // Multi-line bursts pass through in one locked write.
        let mut c = root.handle();
        c.write_all(b"x\ny\n").unwrap();
        assert!(String::from_utf8(cap.0.lock().unwrap().clone()).unwrap().ends_with("x\ny\n"));
    }

    #[test]
    fn collect_sink_accumulates() {
        let mut sink = CollectSink::default();
        let t = StepTiming::default();
        sink.on_step(&rec(&t));
        sink.on_complete(&TrainLog::default());
        assert_eq!(sink.losses, vec![(3, 1.5)]);
        assert!(sink.completed);
    }
}
