//! Executor backends — the one seam behind which the serial / layer-sharded
//! / PJRT optimizer branching lives. [`super::TrainSession`] drives a
//! `Box<dyn ExecutorBackend>` and never matches on the execution strategy
//! again (the pre-redesign code repeated that match across `Trainer`,
//! `main.rs`, and every bench harness).

use std::sync::Arc;

use anyhow::{anyhow, Result};

use super::sink::{LayerHealth, RankHealth};
use crate::coordinator::{PjrtOptimizer, ShardedOptimizer};
use crate::dist::Transport;
use crate::linalg::{Matrix, TensorShape};
use crate::optim::{Hyper, LayerOptimizer, OptKind, RefreshMode};
use crate::precond::RefreshService;
use crate::runtime::Engine;

/// Which optimizer executor a session runs updates on.
///
/// Serial and Sharded are bitwise-interchangeable (sharding is a pure
/// execution strategy); Pjrt routes updates through the compiled
/// Pallas/PJRT artifacts and requires an artifact model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Single-threaded native executor: every layer updated in order on the
    /// caller's thread. Simplest, fully deterministic, no thread spawns.
    Serial,
    /// Layer-sharded native worker threads (cost-balanced static
    /// assignment) — the default. Bitwise-identical to [`Backend::Serial`].
    Sharded,
    /// Per-layer PJRT artifacts (SOAP/AdamW through the L1 Pallas kernels).
    Pjrt,
    /// Multi-process SPMD executor: `ranks` workers average gradients via an
    /// order-preserving fold-reduce and partition eigenbasis refreshes by
    /// layer ownership. Bitwise-identical to [`Backend::Serial`]
    /// (inline / drained-async refresh modes).
    Distributed {
        /// World size (≥ 2).
        ranks: usize,
        /// Wire between ranks: localhost TCP processes or in-process
        /// channel threads.
        transport: Transport,
    },
}

/// The backend names accepted by [`Backend::parse`], embedded in errors.
pub const BACKEND_NAMES: &str = "serial, sharded, pjrt, distributed";

impl Backend {
    /// Parse a CLI/config token. Errors enumerate the valid values.
    /// `distributed` defaults to 2 TCP ranks; `--ranks`/`--dist-transport`
    /// (or the config keys) override.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "serial" => Backend::Serial,
            "sharded" | "native" => Backend::Sharded,
            "pjrt" => Backend::Pjrt,
            "distributed" | "dist" => {
                Backend::Distributed { ranks: 2, transport: Transport::Tcp }
            }
            other => anyhow::bail!("unknown backend '{other}': expected one of {BACKEND_NAMES}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Serial => "serial",
            Backend::Sharded => "sharded",
            Backend::Pjrt => "pjrt",
            Backend::Distributed { .. } => "distributed",
        }
    }
}

/// Uniform surface over the optimizer executors: one `step` entry point plus
/// the accounting and checkpoint hooks the session lifecycle needs. The
/// `engine` argument carries the PJRT runtime when the model is
/// artifact-backed (`None` on native models); only [`PjrtExecutor`] uses it.
pub trait ExecutorBackend {
    /// Backend name for labels ("serial" / "sharded" / "pjrt").
    fn name(&self) -> &'static str;

    /// Apply one optimizer step in place. `t` is the 1-based global step.
    fn step(
        &mut self,
        engine: Option<&Engine>,
        params: &mut [Matrix],
        grads: &[Matrix],
        t: u64,
        lr: f32,
    ) -> Result<()>;

    /// Persistent optimizer-state bytes (paper §7.2 accounting).
    fn state_bytes(&self) -> usize;

    /// Workspace-arena bytes (the zero-allocation step path's grow-only
    /// scratch; 0 for PJRT, whose scratch lives in the compiled artifact).
    fn scratch_bytes(&self) -> usize {
        0
    }

    /// Cumulative hot-path refresh seconds.
    fn refresh_seconds(&self) -> f64;

    /// Cumulative background (async-service) refresh seconds.
    fn async_refresh_seconds(&self) -> f64 {
        0.0
    }

    /// Mean basis staleness at step `t`, averaged over preconditioned layers.
    fn mean_basis_staleness(&self, _t: u64) -> f64 {
        0.0
    }

    /// Per-layer optimizer health at step `t`, layer-ordered. `grad_norm`
    /// is left `None` — the session fills it in from the gradients it owns.
    /// Fields a backend cannot observe stay `None` (never a fake 0.0);
    /// empty when there is no per-layer introspection at all.
    fn collect_layer_health(&self, _t: u64) -> Vec<LayerHealth> {
        Vec::new()
    }

    /// This rank's distributed-health row (ownership + traffic counters).
    /// `None` on single-process backends.
    fn dist_rank_health(&self) -> Option<RankHealth> {
        None
    }

    /// Background refresh-service queue depth (0 without a service).
    fn refresh_queue_depth(&self) -> usize {
        0
    }

    /// Refresh-pool utilization `(jobs, busy seconds)`, when a service runs.
    fn refresh_pool_stats(&self) -> Option<(u64, f64)> {
        None
    }

    /// Barrier: wait for in-flight background refreshes (no-op inline/PJRT).
    fn wait_refresh_idle(&self) {}

    /// Make the in-memory state checkpoint-complete: drain the refresh
    /// service and adopt anything published-but-unadopted, so
    /// [`Self::export_state`] captures exactly the state an uninterrupted
    /// run would use on its next step. Default no-op.
    fn prepare_export(&mut self) {}

    /// Serialize per-layer optimizer state, layer-ordered. Errors on
    /// backends that do not support checkpointing (PJRT).
    fn export_state(&self) -> Result<Vec<(usize, Vec<Matrix>)>>;

    /// Restore state produced by [`Self::export_state`].
    fn import_state(&mut self, state: Vec<(usize, Vec<Matrix>)>) -> Result<()>;
}

/// Single-threaded native executor: the layers in order, on this thread.
pub struct SerialExecutor {
    slots: Vec<Box<dyn LayerOptimizer>>,
    refresh_service: Option<Arc<RefreshService>>,
}

impl SerialExecutor {
    pub fn new(kind: OptKind, hyper: &Hyper, shapes: &[(usize, usize)]) -> Self {
        let tshapes: Vec<TensorShape> =
            shapes.iter().map(|&(m, n)| TensorShape::matrix(m, n)).collect();
        Self::new_tensors(kind, hyper, &tshapes)
    }

    /// [`Self::new`] over arbitrary-rank parameter shapes; rank-2 shapes
    /// build the identical matrix-path layers.
    pub fn new_tensors(kind: OptKind, hyper: &Hyper, shapes: &[TensorShape]) -> Self {
        let mut slots: Vec<Box<dyn LayerOptimizer>> = shapes
            .iter()
            .enumerate()
            .map(|(idx, shape)| kind.build_staggered_tensor(idx, shape, hyper))
            .collect();
        // Same service policy as ShardedOptimizer: spin one up only in
        // Async mode and only if at least one layer has work to offload.
        let refresh_service = (hyper.refresh_mode == RefreshMode::Async)
            .then(|| Arc::new(RefreshService::new(hyper.refresh_workers)))
            .filter(|svc| {
                let mut any = false;
                for slot in slots.iter_mut() {
                    any |= slot.attach_async(svc);
                }
                any
            });
        Self { slots, refresh_service }
    }
}

impl ExecutorBackend for SerialExecutor {
    fn name(&self) -> &'static str {
        "serial"
    }

    fn step(
        &mut self,
        _engine: Option<&Engine>,
        params: &mut [Matrix],
        grads: &[Matrix],
        t: u64,
        lr: f32,
    ) -> Result<()> {
        anyhow::ensure!(params.len() == self.slots.len(), "layer count mismatch");
        for ((slot, w), g) in self.slots.iter_mut().zip(params.iter_mut()).zip(grads) {
            slot.update(w, g, t, lr);
        }
        Ok(())
    }

    fn state_bytes(&self) -> usize {
        self.slots.iter().map(|s| s.state_bytes()).sum()
    }

    fn scratch_bytes(&self) -> usize {
        self.slots.iter().map(|s| s.scratch_bytes()).sum()
    }

    fn refresh_seconds(&self) -> f64 {
        self.slots.iter().map(|s| s.refresh_seconds()).sum()
    }

    fn async_refresh_seconds(&self) -> f64 {
        self.refresh_service.as_ref().map(|s| s.refresh_seconds()).unwrap_or(0.0)
    }

    fn mean_basis_staleness(&self, t: u64) -> f64 {
        let (mut sum, mut n) = (0.0f64, 0u32);
        for slot in &self.slots {
            if let Some(snap) = slot.basis_snapshot_step() {
                sum += t.saturating_sub(snap) as f64;
                n += 1;
            }
        }
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }

    fn collect_layer_health(&self, t: u64) -> Vec<LayerHealth> {
        self.slots
            .iter()
            .enumerate()
            .map(|(layer, slot)| LayerHealth {
                layer,
                grad_norm: None,
                update_norm: slot.update_norm(),
                staleness: slot.basis_snapshot_step().map(|snap| t.saturating_sub(snap)),
                whitening_offdiag: slot.whitening_offdiag(),
            })
            .collect()
    }

    fn refresh_queue_depth(&self) -> usize {
        self.refresh_service.as_ref().map(|s| s.pending()).unwrap_or(0)
    }

    fn refresh_pool_stats(&self) -> Option<(u64, f64)> {
        self.refresh_service.as_ref().map(|s| s.pool_stats())
    }

    fn wait_refresh_idle(&self) {
        if let Some(svc) = &self.refresh_service {
            svc.wait_idle();
        }
    }

    fn prepare_export(&mut self) {
        self.wait_refresh_idle();
        for slot in self.slots.iter_mut() {
            slot.finish_pending();
        }
    }

    fn export_state(&self) -> Result<Vec<(usize, Vec<Matrix>)>> {
        Ok(self.slots.iter().enumerate().map(|(i, s)| (i, s.export_state())).collect())
    }

    fn import_state(&mut self, mut state: Vec<(usize, Vec<Matrix>)>) -> Result<()> {
        state.sort_by_key(|&(i, _)| i);
        for (idx, slot) in self.slots.iter_mut().enumerate() {
            let pos = state
                .binary_search_by_key(&idx, |&(i, _)| i)
                .map_err(|_| anyhow!("missing state for layer {idx}"))?;
            slot.import_state(std::mem::take(&mut state[pos].1))?;
        }
        Ok(())
    }
}

/// Layer-sharded native executor (worker threads) — wraps the coordinator's
/// [`ShardedOptimizer`] behind the backend seam.
pub struct ShardedExecutor {
    inner: ShardedOptimizer,
}

impl ShardedExecutor {
    pub fn new(kind: OptKind, hyper: &Hyper, shapes: &[(usize, usize)], workers: usize) -> Self {
        Self { inner: ShardedOptimizer::new(kind, hyper, shapes, workers) }
    }

    /// [`Self::new`] over arbitrary-rank parameter shapes (cost-balanced by
    /// the per-mode decomposition model).
    pub fn new_tensors(
        kind: OptKind,
        hyper: &Hyper,
        shapes: &[TensorShape],
        workers: usize,
    ) -> Self {
        Self { inner: ShardedOptimizer::new_tensors(kind, hyper, shapes, workers) }
    }

    /// The wrapped optimizer (coordinator-level tooling).
    pub fn inner(&self) -> &ShardedOptimizer {
        &self.inner
    }
}

impl ExecutorBackend for ShardedExecutor {
    fn name(&self) -> &'static str {
        "sharded"
    }

    fn step(
        &mut self,
        _engine: Option<&Engine>,
        params: &mut [Matrix],
        grads: &[Matrix],
        t: u64,
        lr: f32,
    ) -> Result<()> {
        self.inner.step(params, grads, t, lr);
        Ok(())
    }

    fn state_bytes(&self) -> usize {
        self.inner.state_bytes()
    }

    fn scratch_bytes(&self) -> usize {
        self.inner.scratch_bytes()
    }

    fn refresh_seconds(&self) -> f64 {
        self.inner.refresh_seconds()
    }

    fn async_refresh_seconds(&self) -> f64 {
        self.inner.async_refresh_seconds()
    }

    fn mean_basis_staleness(&self, t: u64) -> f64 {
        self.inner.mean_basis_staleness(t)
    }

    fn collect_layer_health(&self, t: u64) -> Vec<LayerHealth> {
        self.inner.layer_health(t)
    }

    fn refresh_queue_depth(&self) -> usize {
        self.inner.refresh_queue_depth()
    }

    fn refresh_pool_stats(&self) -> Option<(u64, f64)> {
        self.inner.refresh_pool_stats()
    }

    fn wait_refresh_idle(&self) {
        self.inner.wait_refresh_idle();
    }

    fn prepare_export(&mut self) {
        self.inner.finish_pending();
    }

    fn export_state(&self) -> Result<Vec<(usize, Vec<Matrix>)>> {
        Ok(self.inner.export_state())
    }

    fn import_state(&mut self, state: Vec<(usize, Vec<Matrix>)>) -> Result<()> {
        self.inner.import_state(state)
    }
}

/// PJRT executor — optimizer updates through the compiled artifacts. Needs
/// the engine handed in at step time (the session owns it alongside the
/// gradient artifacts).
pub struct PjrtExecutor {
    inner: PjrtOptimizer,
    n_layers: usize,
}

impl PjrtExecutor {
    pub fn new(kind: OptKind, hyper: Hyper, shapes: &[(usize, usize)]) -> Result<Self> {
        Ok(Self { inner: PjrtOptimizer::new(kind, hyper, shapes)?, n_layers: shapes.len() })
    }
}

impl ExecutorBackend for PjrtExecutor {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn step(
        &mut self,
        engine: Option<&Engine>,
        params: &mut [Matrix],
        grads: &[Matrix],
        t: u64,
        lr: f32,
    ) -> Result<()> {
        let engine =
            engine.ok_or_else(|| anyhow!("pjrt executor requires an artifact-backed model"))?;
        self.inner.step(engine, params, grads, t, lr)
    }

    fn state_bytes(&self) -> usize {
        self.inner.state_bytes()
    }

    fn refresh_seconds(&self) -> f64 {
        self.inner.refresh_secs
    }

    fn collect_layer_health(&self, _t: u64) -> Vec<LayerHealth> {
        // The compiled artifacts expose no per-layer introspection: emit one
        // row per layer with every observable `None` so downstream consumers
        // see an explicit "unsupported" rather than fabricated zeros.
        (0..self.n_layers)
            .map(|layer| LayerHealth { layer, ..LayerHealth::default() })
            .collect()
    }

    fn export_state(&self) -> Result<Vec<(usize, Vec<Matrix>)>> {
        Err(anyhow!(
            "checkpointing is not supported on the pjrt backend — use a native backend \
             (serial/sharded) for runs that save or resume"
        ))
    }

    fn import_state(&mut self, _state: Vec<(usize, Vec<Matrix>)>) -> Result<()> {
        Err(anyhow!(
            "checkpoint resume is not supported on the pjrt backend — use a native backend"
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn shapes() -> Vec<(usize, usize)> {
        vec![(12, 12), (1, 24), (8, 16)]
    }

    #[test]
    fn backend_parse_and_names() {
        assert_eq!(Backend::parse("serial").unwrap(), Backend::Serial);
        assert_eq!(Backend::parse("SHARDED").unwrap(), Backend::Sharded);
        assert_eq!(Backend::parse("pjrt").unwrap(), Backend::Pjrt);
        assert_eq!(
            Backend::parse("distributed").unwrap(),
            Backend::Distributed { ranks: 2, transport: Transport::Tcp }
        );
        assert_eq!(Backend::parse("dist").unwrap().name(), "distributed");
        let e = Backend::parse("gpu").unwrap_err().to_string();
        for name in ["serial", "sharded", "pjrt", "distributed"] {
            assert!(e.contains(name), "{e}");
        }
    }

    #[test]
    fn serial_matches_sharded_bitwise() {
        let shapes = shapes();
        let hyper = Hyper { precond_freq: 3, ..Hyper::default() };
        let mut rng = Rng::new(77);
        let init: Vec<Matrix> =
            shapes.iter().map(|&(m, n)| Matrix::randn(&mut rng, m, n, 1.0)).collect();
        let mut serial = SerialExecutor::new(OptKind::Soap, &hyper, &shapes);
        let mut sharded = ShardedExecutor::new(OptKind::Soap, &hyper, &shapes, 3);
        let mut ps = init.clone();
        let mut pt = init;
        for t in 1..=8 {
            let grads: Vec<Matrix> =
                shapes.iter().map(|&(m, n)| Matrix::randn(&mut rng, m, n, 1.0)).collect();
            serial.step(None, &mut ps, &grads, t, 0.01).unwrap();
            sharded.step(None, &mut pt, &grads, t, 0.01).unwrap();
        }
        for (a, b) in ps.iter().zip(&pt) {
            assert_eq!(a.data, b.data, "serial executor diverged from sharded");
        }
        assert_eq!(serial.state_bytes(), sharded.state_bytes());
    }

    #[test]
    fn serial_state_roundtrips_through_sharded() {
        let shapes = shapes();
        let hyper = Hyper::default();
        let mut rng = Rng::new(78);
        let mut a = SerialExecutor::new(OptKind::Soap, &hyper, &shapes);
        let mut params: Vec<Matrix> =
            shapes.iter().map(|&(m, n)| Matrix::randn(&mut rng, m, n, 1.0)).collect();
        for t in 1..=3 {
            let grads: Vec<Matrix> =
                shapes.iter().map(|&(m, n)| Matrix::randn(&mut rng, m, n, 1.0)).collect();
            a.step(None, &mut params, &grads, t, 0.01).unwrap();
        }
        let state = a.export_state().unwrap();
        let mut b = ShardedExecutor::new(OptKind::Soap, &hyper, &shapes, 2);
        b.import_state(state).unwrap();
        let mut pa = params.clone();
        let mut pb = params;
        for t in 4..=6 {
            let grads: Vec<Matrix> =
                shapes.iter().map(|&(m, n)| Matrix::randn(&mut rng, m, n, 1.0)).collect();
            a.step(None, &mut pa, &grads, t, 0.01).unwrap();
            b.step(None, &mut pb, &grads, t, 0.01).unwrap();
        }
        for (x, y) in pa.iter().zip(&pb) {
            assert_eq!(x.data, y.data, "state moved between executors diverged");
        }
    }

    #[test]
    fn pjrt_executor_rejects_checkpointing() {
        let exec = PjrtExecutor::new(OptKind::AdamW, Hyper::default(), &[(4, 4)]).unwrap();
        assert!(exec.export_state().is_err());
    }

    #[test]
    fn serial_async_drives_service() {
        let shapes = shapes();
        let hyper = Hyper { precond_freq: 3, ..Hyper::default() }.async_refresh();
        let mut exec = SerialExecutor::new(OptKind::Soap, &hyper, &shapes);
        let mut rng = Rng::new(79);
        let mut params: Vec<Matrix> =
            shapes.iter().map(|&(m, n)| Matrix::randn(&mut rng, m, n, 1.0)).collect();
        for t in 1..=12 {
            let grads: Vec<Matrix> =
                shapes.iter().map(|&(m, n)| Matrix::randn(&mut rng, m, n, 1.0)).collect();
            exec.step(None, &mut params, &grads, t, 0.01).unwrap();
        }
        exec.wait_refresh_idle();
        assert!(exec.async_refresh_seconds() > 0.0, "no background refresh ran");
        exec.prepare_export();
        assert!(exec.export_state().is_ok());
    }
}
