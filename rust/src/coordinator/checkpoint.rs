//! Checkpointing: binary save/restore of parameters + optimizer state +
//! step counter, so long runs (Fig 5) survive interruption and runs can be
//! forked (e.g. the shorter-LR-schedule runs of Fig 2 resume from a common
//! prefix).
//!
//! Format (little-endian):
//!   magic "SOAPCKPT" | version u32 | step u64
//!   | n_params u32 | per param: rows u32, cols u32, f32 data
//!   | n_state u32  | per layer: layer_idx u32, n_tensors u32,
//!                    per tensor: rows u32, cols u32, f32 data

use std::io::Read;
use std::path::Path;

use anyhow::{anyhow, Result};

use crate::linalg::Matrix;

const MAGIC: &[u8; 8] = b"SOAPCKPT";
const VERSION: u32 = 1;

pub struct Checkpoint {
    pub step: u64,
    pub params: Vec<Matrix>,
    pub opt_state: Vec<(usize, Vec<Matrix>)>,
}

fn write_matrix(out: &mut Vec<u8>, m: &Matrix) {
    out.extend_from_slice(&(m.rows as u32).to_le_bytes());
    out.extend_from_slice(&(m.cols as u32).to_le_bytes());
    for &x in &m.data {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_matrix(r: &mut impl Read) -> Result<Matrix> {
    let rows = read_u32(r)? as usize;
    let cols = read_u32(r)? as usize;
    anyhow::ensure!(rows.saturating_mul(cols) < (1 << 31), "matrix too large");
    let mut data = vec![0f32; rows * cols];
    let mut buf = vec![0u8; rows * cols * 4];
    r.read_exact(&mut buf)?;
    for (i, c) in buf.chunks_exact(4).enumerate() {
        data[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
    }
    Ok(Matrix::from_vec(rows, cols, data))
}

impl Checkpoint {
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&self.step.to_le_bytes());
        out.extend_from_slice(&(self.params.len() as u32).to_le_bytes());
        for p in &self.params {
            write_matrix(&mut out, p);
        }
        out.extend_from_slice(&(self.opt_state.len() as u32).to_le_bytes());
        for (idx, tensors) in &self.opt_state {
            out.extend_from_slice(&(*idx as u32).to_le_bytes());
            out.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
            for t in tensors {
                write_matrix(&mut out, t);
            }
        }
        // Write-then-rename for atomicity.
        let tmp = path.as_ref().with_extension("tmp");
        std::fs::write(&tmp, &out)?;
        std::fs::rename(&tmp, path.as_ref())?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let data = std::fs::read(path.as_ref())
            .map_err(|e| anyhow!("checkpoint {:?}: {e}", path.as_ref()))?;
        let mut r = data.as_slice();
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == MAGIC, "not a soap-lab checkpoint");
        let version = read_u32(&mut r)?;
        anyhow::ensure!(version == VERSION, "unsupported checkpoint version {version}");
        let step = read_u64(&mut r)?;
        let n_params = read_u32(&mut r)? as usize;
        let mut params = Vec::with_capacity(n_params);
        for _ in 0..n_params {
            params.push(read_matrix(&mut r)?);
        }
        let n_state = read_u32(&mut r)? as usize;
        let mut opt_state = Vec::with_capacity(n_state);
        for _ in 0..n_state {
            let idx = read_u32(&mut r)? as usize;
            let n_tensors = read_u32(&mut r)? as usize;
            let mut tensors = Vec::with_capacity(n_tensors);
            for _ in 0..n_tensors {
                tensors.push(read_matrix(&mut r)?);
            }
            opt_state.push((idx, tensors));
        }
        Ok(Self { step, params, opt_state })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("soap_ckpt_test_{name}_{}", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(1);
        let ck = Checkpoint {
            step: 42,
            params: vec![Matrix::randn(&mut rng, 3, 4, 1.0), Matrix::randn(&mut rng, 1, 7, 1.0)],
            opt_state: vec![
                (0, vec![Matrix::randn(&mut rng, 3, 4, 1.0)]),
                (1, vec![Matrix::randn(&mut rng, 1, 7, 1.0), Matrix::eye(7)]),
            ],
        };
        let path = tmpfile("roundtrip");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back.step, 42);
        assert_eq!(back.params.len(), 2);
        assert_eq!(back.params[0].data, ck.params[0].data);
        assert_eq!(back.opt_state[1].1[1].data, Matrix::eye(7).data);
    }

    #[test]
    fn rejects_garbage() {
        let path = tmpfile("garbage");
        std::fs::write(&path, b"definitely not a checkpoint").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_file_errors() {
        assert!(Checkpoint::load("/nonexistent/soap.ckpt").is_err());
    }
}
