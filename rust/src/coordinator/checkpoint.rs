//! Checkpointing: binary save/restore of parameters + optimizer state +
//! step counter + data cursor, so long runs (Fig 5) survive interruption and
//! runs can be forked (e.g. the shorter-LR-schedule runs of Fig 2 resume
//! from a common prefix).
//!
//! Format v4 (little-endian):
//!   magic "SOAPCKPT" | version u32 | step u64
//!   | data_batches u64 | has_seed u8 | seed u64
//!   | stream_batch u32 | stream_seq u32 | state_dtype u8
//!   | n_shapes u32 | per param: rank u32, dims (rank × u32)
//!   | n_params u32 | per param: rows u32, cols u32, f32 data
//!   | n_state u32  | per layer: layer_idx u32, n_tensors u32,
//!                    per tensor: rows u32, cols u32, f32 data
//!   | end of file (strict — trailing bytes are rejected)
//!
//! v4 adds the **state-dtype tag** (0 = f32, 1 = bf16): the storage
//! precision of the second-moment optimizer state (`Hyper::state_dtype`)
//! when the checkpoint was taken. State tensors on the wire stay f32 either
//! way — bf16 state decodes to values on the bf16 grid, which re-encode
//! bit-identically on import — the tag only lets resume paths reject a
//! run whose `--state-dtype` disagrees with the file instead of silently
//! changing the rounding of every subsequent EMA update. v3 and earlier
//! files load with the tag defaulting to f32 (the only dtype they could
//! have been written with).
//!
//! v3 adds the **tensor-shape section**: the true N-dimensional dims of
//! every parameter (a rank-3 conv kernel is carried as its 2-D fold in the
//! param section, so without the dims a resumed run could silently rebuild
//! it as a matrix and precondition it differently). `n_shapes` must equal
//! `n_params` and each shape's element count must match its param's — both
//! are validated with field-naming errors. Optimizer state rows for rank-3+
//! layers carry per-mode factor records (see
//! `optim::compose::StateLayout::TensorModes`).
//!
//! v2 (before the shape section) and v1 (before the data cursor — such
//! files load with `data_batches` defaulting to `step`, one batch per step,
//! true for every writer this repo ever shipped, `seed` unknown, and the
//! geometry unrecorded) both still load, with `param_dims` left empty
//! (= unrecorded; rank-2 assumed). Files with a version newer than
//! [`VERSION`] are rejected with a clear error instead of being misparsed
//! into garbage state, and truncated files name the field at which the data
//! ran out.

use std::io::Read;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::linalg::Matrix;
use crate::optim::hyper::StateDtype;

const MAGIC: &[u8; 8] = b"SOAPCKPT";
/// Newest checkpoint format this build reads and the one it writes.
pub const VERSION: u32 = 4;

/// Upper bounds used for strict field validation: a corrupt or foreign file
/// should fail on a bound check, not attempt a multi-gigabyte allocation.
const MAX_PARAMS: usize = 1 << 20;
const MAX_TENSORS_PER_LAYER: usize = 1 << 12;
/// No realistic parameter exceeds this rank; a bigger value is corruption.
const MAX_RANK: usize = 16;

pub struct Checkpoint {
    pub step: u64,
    pub params: Vec<Matrix>,
    pub opt_state: Vec<(usize, Vec<Matrix>)>,
    /// Batches drawn from the training stream when the checkpoint was taken
    /// — the data cursor a resumed run fast-forwards to. Equals `step` for
    /// every current trainer (one batch per optimizer step) and for legacy
    /// v1 files.
    pub data_batches: u64,
    /// Data/init seed of the run that wrote the checkpoint (`None` for
    /// legacy v1 files). Resume paths use it to reject a mismatched seed
    /// instead of silently training on a different data stream.
    pub seed: Option<u64>,
    /// Rows per stream batch (batch × grad-accum) when the checkpoint was
    /// taken; 0 = unrecorded (legacy v1). The cursor counts batches of THIS
    /// size, so resume paths reject a mismatched geometry (e.g. a changed
    /// `--grad-accum`) instead of fast-forwarding to the wrong tokens.
    pub stream_batch: u32,
    /// Sequence length of the stream; 0 = unrecorded (legacy v1).
    pub stream_seq: u32,
    /// True N-dimensional dims of each parameter (aligned with `params`,
    /// which carry the 2-D fold). Empty = unrecorded (legacy v1/v2 files;
    /// rank-2 assumed). When present, resume paths reject a session whose
    /// tensor shapes disagree instead of silently re-preconditioning a
    /// rank-3 kernel as a matrix.
    pub param_dims: Vec<Vec<usize>>,
    /// Storage dtype of the second-moment optimizer state when the
    /// checkpoint was taken (`Hyper::state_dtype`). Legacy v1–v3 files
    /// default to [`StateDtype::F32`], the only dtype those writers had.
    /// Resume paths reject a mismatch with a named-field error.
    pub state_dtype: StateDtype,
}

impl Checkpoint {
    /// Convenience constructor for the common "cursor follows the step
    /// counter" case (v1 semantics; the session layer fills the cursor,
    /// seed, and stream geometry explicitly).
    pub fn new(step: u64, params: Vec<Matrix>, opt_state: Vec<(usize, Vec<Matrix>)>) -> Self {
        // Dims default to each param's carrier fold (rank 2) — callers with
        // genuine tensor parameters (the session layer) fill `param_dims`
        // explicitly.
        let param_dims = params.iter().map(|p| vec![p.rows, p.cols]).collect();
        Self {
            step,
            params,
            opt_state,
            data_batches: step,
            seed: None,
            stream_batch: 0,
            stream_seq: 0,
            param_dims,
            state_dtype: StateDtype::F32,
        }
    }
}

fn write_matrix(out: &mut Vec<u8>, m: &Matrix) {
    out.extend_from_slice(&(m.rows as u32).to_le_bytes());
    out.extend_from_slice(&(m.cols as u32).to_le_bytes());
    for &x in &m.data {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn read_u8(r: &mut impl Read, what: &str) -> Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b).with_context(|| format!("checkpoint truncated at {what}"))?;
    Ok(b[0])
}

fn read_u32(r: &mut impl Read, what: &str) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b).with_context(|| format!("checkpoint truncated at {what}"))?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read, what: &str) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b).with_context(|| format!("checkpoint truncated at {what}"))?;
    Ok(u64::from_le_bytes(b))
}

fn read_matrix(r: &mut &[u8], what: &str) -> Result<Matrix> {
    let rows = read_u32(r, what)? as usize;
    let cols = read_u32(r, what)? as usize;
    anyhow::ensure!(
        rows.saturating_mul(cols) < (1 << 31),
        "checkpoint {what}: matrix {rows}×{cols} too large"
    );
    // Bound-check against the REMAINING bytes before allocating, so a
    // corrupt dimension header fails cleanly instead of attempting a
    // multi-gigabyte allocation and only then discovering the truncation.
    let nbytes = rows * cols * 4;
    anyhow::ensure!(
        r.len() >= nbytes,
        "checkpoint truncated inside {what} ({rows}×{cols}: need {nbytes} bytes, {} left)",
        r.len()
    );
    let (payload, rest) = r.split_at(nbytes);
    *r = rest;
    let mut data = Vec::with_capacity(rows * cols);
    for c in payload.chunks_exact(4) {
        data.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
    }
    Ok(Matrix::from_vec(rows, cols, data))
}

impl Checkpoint {
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&self.step.to_le_bytes());
        out.extend_from_slice(&self.data_batches.to_le_bytes());
        out.push(self.seed.is_some() as u8);
        out.extend_from_slice(&self.seed.unwrap_or(0).to_le_bytes());
        out.extend_from_slice(&self.stream_batch.to_le_bytes());
        out.extend_from_slice(&self.stream_seq.to_le_bytes());
        // v4 state-dtype tag.
        out.push(match self.state_dtype {
            StateDtype::F32 => 0u8,
            StateDtype::Bf16 => 1u8,
        });
        // v3 tensor-shape section: one dims record per param, falling back
        // to the carrier fold for callers that never set `param_dims`.
        out.extend_from_slice(&(self.params.len() as u32).to_le_bytes());
        for (i, p) in self.params.iter().enumerate() {
            let fallback = [p.rows, p.cols];
            let dims: &[usize] = match self.param_dims.get(i) {
                Some(d) if !d.is_empty() => d,
                _ => &fallback,
            };
            out.extend_from_slice(&(dims.len() as u32).to_le_bytes());
            for &d in dims {
                out.extend_from_slice(&(d as u32).to_le_bytes());
            }
        }
        out.extend_from_slice(&(self.params.len() as u32).to_le_bytes());
        for p in &self.params {
            write_matrix(&mut out, p);
        }
        out.extend_from_slice(&(self.opt_state.len() as u32).to_le_bytes());
        for (idx, tensors) in &self.opt_state {
            out.extend_from_slice(&(*idx as u32).to_le_bytes());
            out.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
            for t in tensors {
                write_matrix(&mut out, t);
            }
        }
        // Write-then-rename for atomicity: a writer killed mid-write leaves
        // only a `.tmp` sibling behind — the destination is either the old
        // complete file or the new complete file, never a torn prefix. The
        // tmp name APPENDS the suffix (rather than replacing the extension)
        // so two checkpoints differing only in extension cannot share a tmp
        // slot, and the bytes are fsynced before the rename so a crash right
        // after `save` returns cannot surface a renamed-but-empty file.
        let path = path.as_ref();
        let tmp = {
            let mut s = path.as_os_str().to_os_string();
            s.push(".tmp");
            std::path::PathBuf::from(s)
        };
        {
            use std::io::Write;
            let mut f =
                std::fs::File::create(&tmp).with_context(|| format!("creating {tmp:?}"))?;
            f.write_all(&out).with_context(|| format!("writing {tmp:?}"))?;
            f.sync_all().with_context(|| format!("syncing {tmp:?}"))?;
        }
        std::fs::rename(&tmp, path).with_context(|| format!("renaming {tmp:?} into place"))?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let data = std::fs::read(path.as_ref())
            .map_err(|e| anyhow!("checkpoint {:?}: {e}", path.as_ref()))?;
        let mut r = data.as_slice();
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic).context("checkpoint truncated at magic")?;
        anyhow::ensure!(&magic == MAGIC, "not a soap-lab checkpoint");
        let version = read_u32(&mut r, "version")?;
        anyhow::ensure!(
            (1..=VERSION).contains(&version),
            "checkpoint version {version} is newer than this build supports (≤ {VERSION}); \
             refusing to misparse it"
        );
        let step = read_u64(&mut r, "step")?;
        let (data_batches, seed, stream_batch, stream_seq) = if version >= 2 {
            let cursor = read_u64(&mut r, "data cursor")?;
            let has_seed = read_u8(&mut r, "seed flag")?;
            anyhow::ensure!(has_seed <= 1, "checkpoint seed flag malformed ({has_seed})");
            let seed = read_u64(&mut r, "seed")?;
            let stream_batch = read_u32(&mut r, "stream batch")?;
            let stream_seq = read_u32(&mut r, "stream seq")?;
            (cursor, (has_seed == 1).then_some(seed), stream_batch, stream_seq)
        } else {
            // Legacy v1: one batch per step, seed + geometry unrecorded.
            (step, None, 0, 0)
        };
        let state_dtype = if version >= 4 {
            match read_u8(&mut r, "state dtype")? {
                0 => StateDtype::F32,
                1 => StateDtype::Bf16,
                other => anyhow::bail!(
                    "checkpoint state dtype tag {other} unknown (expected 0 = f32 or 1 = bf16)"
                ),
            }
        } else {
            StateDtype::F32 // the only dtype v1–v3 writers had
        };
        let param_dims: Vec<Vec<usize>> = if version >= 3 {
            let n_shapes = read_u32(&mut r, "shape count")? as usize;
            anyhow::ensure!(
                n_shapes <= MAX_PARAMS,
                "checkpoint shape count {n_shapes} implausible"
            );
            let mut dims = Vec::with_capacity(n_shapes);
            for i in 0..n_shapes {
                let rank = read_u32(&mut r, &format!("shape {i} rank"))? as usize;
                anyhow::ensure!(
                    (1..=MAX_RANK).contains(&rank),
                    "checkpoint shape {i}: rank {rank} implausible (expected 1..={MAX_RANK})"
                );
                let mut d = Vec::with_capacity(rank);
                for m in 0..rank {
                    let v = read_u32(&mut r, &format!("shape {i} dim {m}"))? as usize;
                    anyhow::ensure!(v > 0, "checkpoint shape {i}: dim {m} is zero");
                    d.push(v);
                }
                let numel = d.iter().try_fold(1usize, |a, &x| a.checked_mul(x));
                anyhow::ensure!(
                    matches!(numel, Some(n) if n < (1 << 31)),
                    "checkpoint shape {i}: element count overflows"
                );
                dims.push(d);
            }
            dims
        } else {
            Vec::new() // legacy v1/v2: shapes unrecorded, rank-2 assumed
        };
        let n_params = read_u32(&mut r, "param count")? as usize;
        anyhow::ensure!(n_params <= MAX_PARAMS, "checkpoint param count {n_params} implausible");
        anyhow::ensure!(
            version < 3 || param_dims.len() == n_params,
            "checkpoint shape section lists {} shapes but there are {n_params} params",
            param_dims.len()
        );
        let mut params = Vec::with_capacity(n_params);
        for i in 0..n_params {
            let p = read_matrix(&mut r, &format!("param {i}"))?;
            if let Some(dims) = param_dims.get(i) {
                let numel: usize = dims.iter().product();
                anyhow::ensure!(
                    numel == p.numel(),
                    "checkpoint param {i}: tensor shape {dims:?} has {numel} elements but \
                     the stored matrix is {}×{}",
                    p.rows,
                    p.cols
                );
            }
            params.push(p);
        }
        let n_state = read_u32(&mut r, "state row count")? as usize;
        anyhow::ensure!(n_state <= MAX_PARAMS, "checkpoint state count {n_state} implausible");
        let mut opt_state = Vec::with_capacity(n_state);
        for row in 0..n_state {
            let idx = read_u32(&mut r, &format!("state row {row} layer index"))? as usize;
            let n_tensors = read_u32(&mut r, &format!("state row {row} tensor count"))? as usize;
            anyhow::ensure!(
                n_tensors <= MAX_TENSORS_PER_LAYER,
                "checkpoint state row {row}: tensor count {n_tensors} implausible"
            );
            let mut tensors = Vec::with_capacity(n_tensors);
            for t in 0..n_tensors {
                tensors.push(read_matrix(&mut r, &format!("state row {row} tensor {t}"))?);
            }
            opt_state.push((idx, tensors));
        }
        anyhow::ensure!(
            r.is_empty(),
            "checkpoint carries {} unexpected trailing bytes (truncated rewrite or foreign data)",
            r.len()
        );
        Ok(Self {
            step,
            params,
            opt_state,
            data_batches,
            seed,
            stream_batch,
            stream_seq,
            param_dims,
            state_dtype,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("soap_ckpt_test_{name}_{}", std::process::id()))
    }

    fn sample() -> Checkpoint {
        let mut rng = Rng::new(1);
        Checkpoint {
            step: 42,
            params: vec![Matrix::randn(&mut rng, 3, 4, 1.0), Matrix::randn(&mut rng, 1, 7, 1.0)],
            opt_state: vec![
                (0, vec![Matrix::randn(&mut rng, 3, 4, 1.0)]),
                (1, vec![Matrix::randn(&mut rng, 1, 7, 1.0), Matrix::eye(7)]),
            ],
            data_batches: 42,
            seed: Some(7),
            stream_batch: 16,
            stream_seq: 32,
            param_dims: vec![vec![3, 4], vec![1, 7]],
            state_dtype: StateDtype::Bf16,
        }
    }

    #[test]
    fn roundtrip() {
        let ck = sample();
        let path = tmpfile("roundtrip");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back.step, 42);
        assert_eq!(back.data_batches, 42);
        assert_eq!(back.seed, Some(7));
        assert_eq!((back.stream_batch, back.stream_seq), (16, 32));
        assert_eq!(back.params.len(), 2);
        assert_eq!(back.params[0].data, ck.params[0].data);
        assert_eq!(back.opt_state[1].1[1].data, Matrix::eye(7).data);
        assert_eq!(back.param_dims, ck.param_dims, "v3 shape section must round-trip");
        assert_eq!(back.state_dtype, StateDtype::Bf16, "v4 state-dtype tag must round-trip");
    }

    #[test]
    fn rank3_dims_roundtrip_and_mismatch_named() {
        let mut rng = Rng::new(2);
        let mut ck = sample();
        // Declare param 0 (3×4 carrier) as a rank-3 [3, 2, 2] tensor.
        ck.param_dims[0] = vec![3, 2, 2];
        ck.params[0] = Matrix::randn(&mut rng, 3, 4, 1.0);
        let path = tmpfile("rank3dims");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back.param_dims[0], vec![3, 2, 2]);
        // A dims/param element-count mismatch must error naming the param.
        let mut ck = sample();
        ck.param_dims[0] = vec![5, 5];
        let path = tmpfile("baddims");
        ck.save(&path).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        std::fs::remove_file(&path).ok();
        assert!(format!("{err:#}").contains("param 0"), "{err:#}");
    }

    #[test]
    fn rejects_garbage() {
        let path = tmpfile("garbage");
        std::fs::write(&path, b"definitely not a checkpoint").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_file_errors() {
        assert!(Checkpoint::load("/nonexistent/soap.ckpt").is_err());
    }

    #[test]
    fn rejects_truncated_with_field_context() {
        let ck = sample();
        let path = tmpfile("full");
        ck.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::remove_file(&path).ok();
        // Chop at several depths; every prefix must error (never garbage
        // state), and mid-tensor cuts must say so.
        for cut in [4usize, 11, 20, 40, bytes.len() - 3] {
            let path = tmpfile("trunc");
            std::fs::write(&path, &bytes[..cut]).unwrap();
            let err = Checkpoint::load(&path).unwrap_err();
            std::fs::remove_file(&path).ok();
            assert!(
                format!("{err:#}").contains("truncated"),
                "cut at {cut}: error should mention truncation: {err:#}"
            );
        }
    }

    #[test]
    fn corrupt_dims_fail_before_allocating() {
        // A foreign/corrupt matrix header must hit the remaining-bytes
        // bound check, not attempt a multi-gigabyte allocation.
        let ck = sample();
        let path = tmpfile("hugedims");
        ck.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // Param 0 header sits right after the fixed v4 prefix:
        // magic(8)+version(4)+step(8)+cursor(8)+flag(1)+seed(8)+geom(8)
        // + dtype(1) + shape section (n(4) + two rank-2 records of 4+8
        // bytes) + n(4).
        let hdr = 8 + 4 + 8 + 8 + 1 + 8 + 8 + 1 + (4 + 2 * 12) + 4;
        bytes[hdr..hdr + 4].copy_from_slice(&46_000u32.to_le_bytes());
        bytes[hdr + 4..hdr + 8].copy_from_slice(&46_000u32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        std::fs::remove_file(&path).ok();
        assert!(format!("{err:#}").contains("truncated inside param 0"), "{err:#}");
    }

    #[test]
    fn rejects_trailing_bytes() {
        let ck = sample();
        let path = tmpfile("trailing");
        ck.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(b"junk");
        std::fs::write(&path, &bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        std::fs::remove_file(&path).ok();
        assert!(format!("{err:#}").contains("trailing"), "{err:#}");
    }

    #[test]
    fn rejects_future_version_with_clear_error() {
        let ck = sample();
        let path = tmpfile("future");
        ck.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[8..12].copy_from_slice(&99u32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        std::fs::remove_file(&path).ok();
        let msg = format!("{err:#}");
        assert!(msg.contains("version 99") && msg.contains("newer"), "{msg}");
    }

    #[test]
    fn legacy_v1_files_still_load() {
        // Hand-write a v1 file: no data cursor / seed fields.
        let ck = sample();
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(&ck.step.to_le_bytes());
        out.extend_from_slice(&(ck.params.len() as u32).to_le_bytes());
        for p in &ck.params {
            write_matrix(&mut out, p);
        }
        out.extend_from_slice(&(ck.opt_state.len() as u32).to_le_bytes());
        for (idx, tensors) in &ck.opt_state {
            out.extend_from_slice(&(*idx as u32).to_le_bytes());
            out.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
            for t in tensors {
                write_matrix(&mut out, t);
            }
        }
        let path = tmpfile("v1");
        std::fs::write(&path, &out).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back.step, 42);
        assert_eq!(back.data_batches, 42, "v1 cursor defaults to step");
        assert_eq!(back.seed, None);
        assert_eq!((back.stream_batch, back.stream_seq), (0, 0), "v1 geometry unrecorded");
        assert_eq!(back.params[0].data, ck.params[0].data);
        assert!(back.param_dims.is_empty(), "v1 shapes unrecorded");
        assert_eq!(back.state_dtype, StateDtype::F32, "v1 state dtype defaults to f32");
    }

    #[test]
    fn legacy_v2_files_still_load() {
        // Hand-write a v2 file: cursor/seed/geometry but no shape section.
        let ck = sample();
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&2u32.to_le_bytes());
        out.extend_from_slice(&ck.step.to_le_bytes());
        out.extend_from_slice(&ck.data_batches.to_le_bytes());
        out.push(1u8);
        out.extend_from_slice(&ck.seed.unwrap().to_le_bytes());
        out.extend_from_slice(&ck.stream_batch.to_le_bytes());
        out.extend_from_slice(&ck.stream_seq.to_le_bytes());
        out.extend_from_slice(&(ck.params.len() as u32).to_le_bytes());
        for p in &ck.params {
            write_matrix(&mut out, p);
        }
        out.extend_from_slice(&(ck.opt_state.len() as u32).to_le_bytes());
        for (idx, tensors) in &ck.opt_state {
            out.extend_from_slice(&(*idx as u32).to_le_bytes());
            out.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
            for t in tensors {
                write_matrix(&mut out, t);
            }
        }
        let path = tmpfile("v2");
        std::fs::write(&path, &out).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back.step, 42);
        assert_eq!(back.seed, Some(7));
        assert_eq!((back.stream_batch, back.stream_seq), (16, 32));
        assert_eq!(back.params[0].data, ck.params[0].data);
        assert!(back.param_dims.is_empty(), "v2 shapes unrecorded");
        assert_eq!(back.state_dtype, StateDtype::F32, "v2 state dtype defaults to f32");
    }

    #[test]
    fn legacy_v3_files_still_load() {
        // Hand-write a v3 file: everything v4 has except the state-dtype
        // tag between the stream geometry and the shape section.
        let ck = sample();
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&3u32.to_le_bytes());
        out.extend_from_slice(&ck.step.to_le_bytes());
        out.extend_from_slice(&ck.data_batches.to_le_bytes());
        out.push(1u8);
        out.extend_from_slice(&ck.seed.unwrap().to_le_bytes());
        out.extend_from_slice(&ck.stream_batch.to_le_bytes());
        out.extend_from_slice(&ck.stream_seq.to_le_bytes());
        out.extend_from_slice(&(ck.params.len() as u32).to_le_bytes());
        for dims in &ck.param_dims {
            out.extend_from_slice(&(dims.len() as u32).to_le_bytes());
            for &d in dims {
                out.extend_from_slice(&(d as u32).to_le_bytes());
            }
        }
        out.extend_from_slice(&(ck.params.len() as u32).to_le_bytes());
        for p in &ck.params {
            write_matrix(&mut out, p);
        }
        out.extend_from_slice(&(ck.opt_state.len() as u32).to_le_bytes());
        for (idx, tensors) in &ck.opt_state {
            out.extend_from_slice(&(*idx as u32).to_le_bytes());
            out.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
            for t in tensors {
                write_matrix(&mut out, t);
            }
        }
        let path = tmpfile("v3");
        std::fs::write(&path, &out).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back.step, 42);
        assert_eq!(back.seed, Some(7));
        assert_eq!(back.param_dims, ck.param_dims, "v3 shape section loads");
        assert_eq!(back.state_dtype, StateDtype::F32, "v3 state dtype defaults to f32");
    }

    #[test]
    fn unknown_state_dtype_tag_rejected() {
        let ck = sample();
        let path = tmpfile("baddtype");
        ck.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // The dtype tag sits right after the fixed prefix:
        // magic(8)+version(4)+step(8)+cursor(8)+flag(1)+seed(8)+geom(8).
        bytes[45] = 7;
        std::fs::write(&path, &bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        std::fs::remove_file(&path).ok();
        let msg = format!("{err:#}");
        assert!(msg.contains("state dtype tag 7"), "{msg}");
    }

    #[test]
    fn interrupted_save_leaves_previous_checkpoint_intact() {
        // Simulated mid-write kill: a torn prefix sitting in the `.tmp`
        // slot must never affect the destination — the previous complete
        // checkpoint stays loadable, the torn bytes are rejected if read
        // directly, and the next save consumes the leftover tmp file.
        let ck = sample();
        let path = tmpfile("atomic");
        ck.save(&path).unwrap();
        let good = std::fs::read(&path).unwrap();
        let tmp = {
            let mut s = path.as_os_str().to_os_string();
            s.push(".tmp");
            std::path::PathBuf::from(s)
        };
        std::fs::write(&tmp, &good[..good.len() / 3]).unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), good, "destination untouched by torn tmp");
        Checkpoint::load(&path).unwrap();
        assert!(Checkpoint::load(&tmp).is_err(), "torn prefix must be rejected, not misparsed");
        ck.save(&path).unwrap();
        assert!(!tmp.exists(), "successful save must consume the tmp file");
        assert_eq!(std::fs::read(&path).unwrap(), good, "rewrite is byte-identical");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn new_defaults_cursor_to_step() {
        let ck = Checkpoint::new(9, Vec::new(), Vec::new());
        assert_eq!(ck.data_batches, 9);
        assert_eq!(ck.seed, None);
    }
}
