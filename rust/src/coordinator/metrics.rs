//! Training metrics: per-step wall-clock breakdown (the Fig 7 overhead
//! accounting), loss curve, and throughput.

use crate::util::json::Json;

/// Wall-clock breakdown of one training step.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepTiming {
    /// Data pipeline (batch generation).
    pub data_s: f64,
    /// Forward+backward (grad computation).
    pub grad_s: f64,
    /// Optimizer update excluding eigenbasis/inverse-root refreshes.
    pub update_s: f64,
    /// Eigenbasis / inverse-root refresh work ON THE HOT PATH in this step
    /// (Inline mode; ~0 in Async mode, where only the first-step init runs
    /// inline).
    pub refresh_s: f64,
    /// Background refresh compute attributed to this step (Async mode).
    /// OVERLAPPED with the step, not part of its critical path — excluded
    /// from [`Self::total`] by design.
    pub bg_refresh_s: f64,
    /// Mean basis staleness after this step: steps since the factors backing
    /// each layer's active preconditioner were snapshotted, averaged over
    /// preconditioned layers. Nonzero in Inline mode too (bases age between
    /// periodic refreshes).
    pub staleness_steps: f64,
}

impl StepTiming {
    /// Critical-path seconds of this step (background refresh excluded —
    /// it overlaps with the step on the service pool).
    pub fn total(&self) -> f64 {
        self.data_s + self.grad_s + self.update_s + self.refresh_s
    }
}

/// Full log of a training run — everything the figure benches need.
#[derive(Clone, Debug, Default)]
pub struct TrainLog {
    pub optimizer: String,
    pub model: String,
    /// (step, train loss) per step.
    pub losses: Vec<(u64, f32)>,
    pub timings: Vec<StepTiming>,
    pub tokens_per_batch: usize,
}

impl TrainLog {
    pub fn final_loss(&self) -> f32 {
        self.losses.last().map(|&(_, l)| l).unwrap_or(f32::NAN)
    }

    /// Mean of the last `k` losses — the robust "final loss" used when
    /// comparing optimizers (single-batch noise is large at small scale).
    pub fn tail_loss(&self, k: usize) -> f32 {
        if self.losses.is_empty() {
            return f32::NAN;
        }
        let k = k.min(self.losses.len()).max(1);
        let s: f32 = self.losses[self.losses.len() - k..].iter().map(|&(_, l)| l).sum();
        s / k as f32
    }

    pub fn total_seconds(&self) -> f64 {
        self.timings.iter().map(|t| t.total()).sum()
    }

    pub fn tokens_per_second(&self) -> f64 {
        let total = self.total_seconds();
        if total <= 0.0 {
            return 0.0;
        }
        (self.tokens_per_batch as f64 * self.timings.len() as f64) / total
    }

    /// Optimizer overhead fraction: (update+refresh) / total — Fig 7 left.
    pub fn optimizer_overhead_frac(&self) -> f64 {
        let total = self.total_seconds();
        if total <= 0.0 {
            return 0.0;
        }
        let opt: f64 = self.timings.iter().map(|t| t.update_s + t.refresh_s).sum();
        opt / total
    }

    /// Hot-path refresh seconds across the run — what the Fig 7 benches and
    /// `perf_probe` report, without reaching into optimizer internals.
    pub fn refresh_seconds_total(&self) -> f64 {
        self.timings.iter().map(|t| t.refresh_s).sum()
    }

    /// Background (overlapped) refresh seconds across the run (Async mode).
    pub fn bg_refresh_seconds_total(&self) -> f64 {
        self.timings.iter().map(|t| t.bg_refresh_s).sum()
    }

    /// Hot-path refresh share of total step time — the Fig 7 companion
    /// metric.
    pub fn refresh_frac(&self) -> f64 {
        let total = self.total_seconds();
        if total <= 0.0 {
            return 0.0;
        }
        self.refresh_seconds_total() / total
    }

    /// Mean basis staleness (steps) across the run.
    pub fn mean_staleness(&self) -> f64 {
        if self.timings.is_empty() {
            return 0.0;
        }
        self.timings.iter().map(|t| t.staleness_steps).sum::<f64>() / self.timings.len() as f64
    }

    /// Quantile of per-step critical-path time, q ∈ [0, 1] (p50/p99 step
    /// latency for the async-refresh bench).
    pub fn step_time_quantile(&self, q: f64) -> f64 {
        let mut samples = crate::util::stats::Samples::new();
        for t in &self.timings {
            samples.push(t.total());
        }
        if samples.is_empty() {
            return 0.0;
        }
        samples.quantile(q)
    }

    /// First step (1-based) whose loss reaches `target`, if any — the
    /// steps-to-target metric of Fig 4. Uses a trailing mean of width `k`
    /// to suppress single-batch noise.
    ///
    /// The window is a `VecDeque` with a running `f64` sum — O(1) per step
    /// instead of the O(k) `Vec::remove(0)` shuffle this used to do, which
    /// matters when the figure benches sweep many (target, k) pairs over
    /// long loss curves.
    pub fn steps_to_loss(&self, target: f32, k: usize) -> Option<u64> {
        let k = k.max(1);
        let mut window: std::collections::VecDeque<f32> = std::collections::VecDeque::new();
        let mut sum = 0.0f64;
        for &(step, l) in &self.losses {
            window.push_back(l);
            sum += l as f64;
            if window.len() > k {
                sum -= window.pop_front().expect("window non-empty") as f64;
            }
            if window.len() == k && sum / k as f64 <= target as f64 {
                return Some(step);
            }
        }
        None
    }

    pub fn loss_series(&self) -> Vec<(f64, f64)> {
        self.losses.iter().map(|&(s, l)| (s as f64, l as f64)).collect()
    }

    /// Loss vs cumulative wall-clock seconds (the paper's right-hand plots).
    pub fn loss_vs_time(&self) -> Vec<(f64, f64)> {
        let mut acc = 0.0;
        self.losses
            .iter()
            .zip(&self.timings)
            .map(|(&(_, l), t)| {
                acc += t.total();
                (acc, l as f64)
            })
            .collect()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("optimizer", Json::str(self.optimizer.clone())),
            ("model", Json::str(self.model.clone())),
            ("final_loss", Json::num(self.final_loss() as f64)),
            ("tail_loss", Json::num(self.tail_loss(20) as f64)),
            ("tokens_per_second", Json::num(self.tokens_per_second())),
            ("overhead_frac", Json::num(self.optimizer_overhead_frac())),
            ("refresh_seconds", Json::num(self.refresh_seconds_total())),
            ("bg_refresh_seconds", Json::num(self.bg_refresh_seconds_total())),
            ("refresh_frac", Json::num(self.refresh_frac())),
            ("mean_staleness_steps", Json::num(self.mean_staleness())),
            ("p50_step_s", Json::num(self.step_time_quantile(0.50))),
            ("p99_step_s", Json::num(self.step_time_quantile(0.99))),
            (
                "losses",
                Json::arr(
                    self.losses
                        .iter()
                        .map(|&(s, l)| Json::arr([Json::num(s as f64), Json::num(l as f64)])),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn log_with(losses: &[f32]) -> TrainLog {
        TrainLog {
            optimizer: "x".into(),
            model: "m".into(),
            losses: losses.iter().enumerate().map(|(i, &l)| (i as u64 + 1, l)).collect(),
            timings: losses
                .iter()
                .map(|_| StepTiming {
                    grad_s: 0.5,
                    update_s: 0.25,
                    refresh_s: 0.25,
                    staleness_steps: 2.0,
                    ..Default::default()
                })
                .collect(),
            tokens_per_batch: 100,
        }
    }

    #[test]
    fn steps_to_loss_trailing_mean() {
        let log = log_with(&[5.0, 4.0, 3.0, 2.0, 1.0]);
        assert_eq!(log.steps_to_loss(3.0, 1), Some(3));
        // width-2 mean reaches ≤3.0 at step 4 ((3+2)/2 = 2.5).
        assert_eq!(log.steps_to_loss(3.0, 2), Some(4));
        assert_eq!(log.steps_to_loss(0.5, 1), None);
    }

    /// Reference implementation: recompute the trailing-window sum from
    /// scratch at every step (the behavior the `Vec::remove(0)` version
    /// had, minus its O(k) shuffle).
    fn steps_to_loss_naive(losses: &[(u64, f32)], target: f32, k: usize) -> Option<u64> {
        let k = k.max(1);
        for (i, &(step, _)) in losses.iter().enumerate() {
            if i + 1 < k {
                continue;
            }
            let sum: f64 = losses[i + 1 - k..=i].iter().map(|&(_, l)| l as f64).sum();
            if sum / k as f64 <= target as f64 {
                return Some(step);
            }
        }
        None
    }

    #[test]
    fn steps_to_loss_running_sum_matches_naive() {
        // Deterministic LCG; losses are multiples of 2⁻⁷ in [0, 8), so both
        // the running f64 add/subtract and the fresh window sums are exact —
        // the two implementations must agree on every (target, k) pair.
        let mut state = 0x9E37_79B9_7F4A_7C15u64;
        let mut next = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            state
        };
        for trial in 0..50 {
            let n = 1 + (next() % 120) as usize;
            let losses: Vec<(u64, f32)> = (0..n)
                .map(|i| (i as u64 + 1, ((next() >> 20) & 0x3FF) as f32 / 128.0))
                .collect();
            let log = TrainLog { losses: losses.clone(), ..Default::default() };
            for k in [1usize, 2, 3, 7, n, n + 3] {
                let target = ((next() >> 20) & 0x3FF) as f32 / 128.0;
                assert_eq!(
                    log.steps_to_loss(target, k),
                    steps_to_loss_naive(&losses, target, k),
                    "trial {trial}: divergence at n={n} k={k} target={target}"
                );
            }
        }
    }

    #[test]
    fn overhead_fraction() {
        let log = log_with(&[1.0, 1.0]);
        assert!((log.optimizer_overhead_frac() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn tokens_per_second() {
        let log = log_with(&[1.0, 1.0]);
        // 2 steps × 100 tokens / 2.0 s.
        assert!((log.tokens_per_second() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn tail_loss_averages() {
        let log = log_with(&[9.0, 2.0, 4.0]);
        assert!((log.tail_loss(2) - 3.0).abs() < 1e-6);
        assert_eq!(log_with(&[]).tail_loss(5).is_nan(), true);
    }

    #[test]
    fn json_roundtrip_parses() {
        let j = log_with(&[3.0]).to_json().dump();
        let v = crate::util::json::Json::parse(&j).unwrap();
        assert_eq!(v.get("optimizer").as_str(), Some("x"));
        assert_eq!(v.get("refresh_seconds").as_f64(), Some(0.25));
        assert_eq!(v.get("mean_staleness_steps").as_f64(), Some(2.0));
    }

    #[test]
    fn refresh_and_staleness_helpers() {
        let log = log_with(&[1.0, 1.0, 1.0, 1.0]);
        assert!((log.refresh_seconds_total() - 1.0).abs() < 1e-9);
        assert_eq!(log.bg_refresh_seconds_total(), 0.0);
        assert!((log.refresh_frac() - 0.25).abs() < 1e-9);
        assert!((log.mean_staleness() - 2.0).abs() < 1e-9);
        // All steps take 1.0s ⇒ every quantile is 1.0; background time is
        // excluded from the critical path.
        assert!((log.step_time_quantile(0.5) - 1.0).abs() < 1e-9);
        assert!((log.step_time_quantile(0.99) - 1.0).abs() < 1e-9);
    }
}
