//! Training metrics: per-step wall-clock breakdown (the Fig 7 overhead
//! accounting), loss curve, and throughput.

use crate::util::json::Json;

/// Wall-clock breakdown of one training step.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepTiming {
    /// Data pipeline (batch generation).
    pub data_s: f64,
    /// Forward+backward (grad computation).
    pub grad_s: f64,
    /// Optimizer update excluding eigenbasis/inverse-root refreshes.
    pub update_s: f64,
    /// Eigenbasis / inverse-root refresh work in this step.
    pub refresh_s: f64,
}

impl StepTiming {
    pub fn total(&self) -> f64 {
        self.data_s + self.grad_s + self.update_s + self.refresh_s
    }
}

/// Full log of a training run — everything the figure benches need.
#[derive(Clone, Debug, Default)]
pub struct TrainLog {
    pub optimizer: String,
    pub model: String,
    /// (step, train loss) per step.
    pub losses: Vec<(u64, f32)>,
    pub timings: Vec<StepTiming>,
    pub tokens_per_batch: usize,
}

impl TrainLog {
    pub fn final_loss(&self) -> f32 {
        self.losses.last().map(|&(_, l)| l).unwrap_or(f32::NAN)
    }

    /// Mean of the last `k` losses — the robust "final loss" used when
    /// comparing optimizers (single-batch noise is large at small scale).
    pub fn tail_loss(&self, k: usize) -> f32 {
        if self.losses.is_empty() {
            return f32::NAN;
        }
        let k = k.min(self.losses.len()).max(1);
        let s: f32 = self.losses[self.losses.len() - k..].iter().map(|&(_, l)| l).sum();
        s / k as f32
    }

    pub fn total_seconds(&self) -> f64 {
        self.timings.iter().map(|t| t.total()).sum()
    }

    pub fn tokens_per_second(&self) -> f64 {
        let total = self.total_seconds();
        if total <= 0.0 {
            return 0.0;
        }
        (self.tokens_per_batch as f64 * self.timings.len() as f64) / total
    }

    /// Optimizer overhead fraction: (update+refresh) / total — Fig 7 left.
    pub fn optimizer_overhead_frac(&self) -> f64 {
        let total = self.total_seconds();
        if total <= 0.0 {
            return 0.0;
        }
        let opt: f64 = self.timings.iter().map(|t| t.update_s + t.refresh_s).sum();
        opt / total
    }

    /// First step (1-based) whose loss reaches `target`, if any — the
    /// steps-to-target metric of Fig 4. Uses a trailing mean of width `k`
    /// to suppress single-batch noise.
    pub fn steps_to_loss(&self, target: f32, k: usize) -> Option<u64> {
        let k = k.max(1);
        let mut window: Vec<f32> = Vec::new();
        for &(step, l) in &self.losses {
            window.push(l);
            if window.len() > k {
                window.remove(0);
            }
            if window.len() == k {
                let mean = window.iter().sum::<f32>() / k as f32;
                if mean <= target {
                    return Some(step);
                }
            }
        }
        None
    }

    pub fn loss_series(&self) -> Vec<(f64, f64)> {
        self.losses.iter().map(|&(s, l)| (s as f64, l as f64)).collect()
    }

    /// Loss vs cumulative wall-clock seconds (the paper's right-hand plots).
    pub fn loss_vs_time(&self) -> Vec<(f64, f64)> {
        let mut acc = 0.0;
        self.losses
            .iter()
            .zip(&self.timings)
            .map(|(&(_, l), t)| {
                acc += t.total();
                (acc, l as f64)
            })
            .collect()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("optimizer", Json::str(self.optimizer.clone())),
            ("model", Json::str(self.model.clone())),
            ("final_loss", Json::num(self.final_loss() as f64)),
            ("tail_loss", Json::num(self.tail_loss(20) as f64)),
            ("tokens_per_second", Json::num(self.tokens_per_second())),
            ("overhead_frac", Json::num(self.optimizer_overhead_frac())),
            (
                "losses",
                Json::arr(
                    self.losses
                        .iter()
                        .map(|&(s, l)| Json::arr([Json::num(s as f64), Json::num(l as f64)])),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn log_with(losses: &[f32]) -> TrainLog {
        TrainLog {
            optimizer: "x".into(),
            model: "m".into(),
            losses: losses.iter().enumerate().map(|(i, &l)| (i as u64 + 1, l)).collect(),
            timings: losses.iter().map(|_| StepTiming { grad_s: 0.5, update_s: 0.25, refresh_s: 0.25, data_s: 0.0 }).collect(),
            tokens_per_batch: 100,
        }
    }

    #[test]
    fn steps_to_loss_trailing_mean() {
        let log = log_with(&[5.0, 4.0, 3.0, 2.0, 1.0]);
        assert_eq!(log.steps_to_loss(3.0, 1), Some(3));
        // width-2 mean reaches ≤3.0 at step 4 ((3+2)/2 = 2.5).
        assert_eq!(log.steps_to_loss(3.0, 2), Some(4));
        assert_eq!(log.steps_to_loss(0.5, 1), None);
    }

    #[test]
    fn overhead_fraction() {
        let log = log_with(&[1.0, 1.0]);
        assert!((log.optimizer_overhead_frac() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn tokens_per_second() {
        let log = log_with(&[1.0, 1.0]);
        // 2 steps × 100 tokens / 2.0 s.
        assert!((log.tokens_per_second() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn tail_loss_averages() {
        let log = log_with(&[9.0, 2.0, 4.0]);
        assert!((log.tail_loss(2) - 3.0).abs() < 1e-6);
        assert_eq!(log_with(&[]).tail_loss(5).is_nan(), true);
    }

    #[test]
    fn json_roundtrip_parses() {
        let j = log_with(&[3.0]).to_json().dump();
        let v = crate::util::json::Json::parse(&j).unwrap();
        assert_eq!(v.get("optimizer").as_str(), Some("x"));
    }
}
