//! Layer-sharded optimizer execution — the DistributedShampoo coordination
//! pattern (paper §5: "the overhead of Shampoo/SOAP can be amortized across
//! layers by distributing the updates across multiple GPUs"), realized here
//! as worker threads that each own a disjoint set of layers' optimizer state
//! and parameters.
//!
//! Sharding is static and cost-balanced: layers are assigned greedily by
//! estimated per-step optimizer FLOPs (m³+n³+2m²n+2mn² for rotating
//! optimizers — the paper §7.3 cost model) so no worker becomes the straggler
//! that serializes the step.

use std::sync::Arc;

use crate::linalg::{Matrix, TensorShape};
use crate::optim::{Hyper, LayerOptimizer, OptKind, RefreshMode};
use crate::precond::{RefreshService, RefreshStats};

/// Per-step FLOP estimate of a rotating optimizer on an arbitrary-rank
/// layer: the per-mode decomposition cost `Σₖ dₖ³` (eigh / power-iteration
/// per Kronecker factor) plus the per-mode projection cost
/// `2·numel·Σₖ dₖ` (each mode-k product touches every element `dₖ` times,
/// twice per step for rotate + rotate-back). On rank-2 this reduces to
/// exactly the paper's §7.3 matrix model `m³ + n³ + 2m²n + 2mn²`.
pub fn tensor_update_flops(dims: &[usize]) -> f64 {
    let numel: f64 = dims.iter().map(|&d| d as f64).product();
    let mut cost = 0.0;
    for &d in dims {
        let d = d as f64;
        cost += d * d * d;
    }
    for &d in dims {
        cost += 2.0 * numel * d as f64;
    }
    cost
}

/// Per-step FLOP estimate of a rotating optimizer on an m×n layer (§7.3) —
/// the rank-2 specialization of [`tensor_update_flops`].
pub fn layer_update_flops(m: usize, n: usize) -> f64 {
    tensor_update_flops(&[m, n])
}

/// Greedy longest-processing-time assignment of `costs` to `k` shards —
/// the core both shape-typed entry points share. Deterministic: ties in
/// cost break on the lower layer index, ties in load on the lower shard
/// index. Empty inputs yield an empty assignment; `k` larger than the
/// layer count simply leaves shards empty.
fn assign_by_cost(costs: &[f64], k: usize) -> Vec<usize> {
    assert!(k > 0);
    let mut order: Vec<usize> = (0..costs.len()).collect();
    order.sort_by(|&a, &b| costs[b].partial_cmp(&costs[a]).unwrap().then(a.cmp(&b)));
    let mut load = vec![0.0f64; k];
    let mut assign = vec![0usize; costs.len()];
    for idx in order {
        let best = (0..k)
            .min_by(|&a, &b| load[a].partial_cmp(&load[b]).unwrap())
            .unwrap();
        assign[idx] = best;
        load[best] += costs[idx];
    }
    assign
}

/// Greedy longest-processing-time assignment of layers to `k` shards.
/// Returns shard index per layer. Deterministic.
pub fn assign_shards(shapes: &[(usize, usize)], k: usize) -> Vec<usize> {
    let costs: Vec<f64> = shapes.iter().map(|&(m, n)| layer_update_flops(m, n)).collect();
    assign_by_cost(&costs, k)
}

/// [`assign_shards`] over arbitrary-rank shapes: the cost model is the
/// per-mode decomposition cost ([`tensor_update_flops`]), not the carrier
/// `m·n` fold — a `[8, 8, 8]` kernel costs three cheap 8³ factors, not one
/// 64³ one, and the balancer must know that.
pub fn assign_shards_tensors(shapes: &[TensorShape], k: usize) -> Vec<usize> {
    let costs: Vec<f64> = shapes.iter().map(|s| tensor_update_flops(s.dims())).collect();
    assign_by_cost(&costs, k)
}

struct ShardSlot {
    layer_idx: usize,
    opt: Box<dyn LayerOptimizer>,
}

/// Optimizer states sharded across worker threads. Parameters stay with the
/// caller (they are also needed by the gradient engine); each step the
/// grads+params are partitioned by shard, updated in parallel under
/// `std::thread::scope`, and reassembled in layer order.
pub struct ShardedOptimizer {
    shards: Vec<Vec<ShardSlot>>,
    pub num_workers: usize,
    kind: OptKind,
    /// Background eigenbasis/inverse-root refresh service — `Some` only in
    /// `RefreshMode::Async` when at least one layer has work to offload. It
    /// owns a DEDICATED pool: shard workers block inside `step` joins, so
    /// sharing their pool with refresh jobs could deadlock (a step waiting
    /// on a worker that is waiting behind a refresh that needs the step's
    /// snapshot). Separate pools make the two queues independent.
    refresh_service: Option<Arc<RefreshService>>,
}

impl ShardedOptimizer {
    pub fn new(kind: OptKind, hyper: &Hyper, shapes: &[(usize, usize)], workers: usize) -> Self {
        let tshapes: Vec<TensorShape> =
            shapes.iter().map(|&(m, n)| TensorShape::matrix(m, n)).collect();
        Self::new_tensors(kind, hyper, &tshapes, workers)
    }

    /// [`Self::new`] over arbitrary-rank parameter shapes: layers are
    /// cost-balanced by the per-mode decomposition model
    /// ([`tensor_update_flops`]) and rank-3+ layers build per-mode bases.
    /// Rank-2 shapes build the identical matrix-path layers [`Self::new`]
    /// builds.
    pub fn new_tensors(
        kind: OptKind,
        hyper: &Hyper,
        shapes: &[TensorShape],
        workers: usize,
    ) -> Self {
        let workers = workers.max(1);
        let assign = assign_shards_tensors(shapes, workers);
        let mut shards: Vec<Vec<ShardSlot>> = (0..workers).map(|_| Vec::new()).collect();
        for (idx, (shape, &s)) in shapes.iter().zip(&assign).enumerate() {
            // Staggered refresh phase (layer_idx % f): spreads the periodic
            // decomposition cost across steps in Inline mode and spreads the
            // enqueue burst in Async mode. Serial ModelOptimizer staggers
            // identically, keeping the two executors bitwise equal.
            shards[s].push(ShardSlot {
                layer_idx: idx,
                opt: kind.build_staggered_tensor(idx, shape, hyper),
            });
        }
        let refresh_service = (hyper.refresh_mode == RefreshMode::Async).then(|| {
            Arc::new(RefreshService::new(hyper.refresh_workers))
        });
        let refresh_service = refresh_service.filter(|svc| {
            let mut any = false;
            for slot in shards.iter_mut().flat_map(|s| s.iter_mut()) {
                any |= slot.opt.attach_async(svc);
            }
            any // all-identity / element-wise models stay service-free
        });
        Self { shards, num_workers: workers, kind, refresh_service }
    }

    pub fn kind(&self) -> OptKind {
        self.kind
    }

    /// The background refresh service, when running in `Async` mode.
    pub fn refresh_service(&self) -> Option<&Arc<RefreshService>> {
        self.refresh_service.as_ref()
    }

    /// Seconds of background (off-hot-path) refresh compute so far.
    pub fn async_refresh_seconds(&self) -> f64 {
        self.refresh_service.as_ref().map(|s| s.refresh_seconds()).unwrap_or(0.0)
    }

    /// Aggregate background refresh counters (zeroes in Inline mode).
    pub fn async_refresh_stats(&self) -> RefreshStats {
        self.refresh_service.as_ref().map(|s| s.stats()).unwrap_or_default()
    }

    /// Mean basis staleness at step `t` (steps since the factors backing
    /// each layer's active preconditioner were snapshotted), averaged over
    /// layers that have one. Meaningful in both modes: Inline bases also age
    /// between refreshes.
    pub fn mean_basis_staleness(&self, t: u64) -> f64 {
        let (mut sum, mut n) = (0.0f64, 0u32);
        for slot in self.shards.iter().flat_map(|s| s.iter()) {
            if let Some(snap) = slot.opt.basis_snapshot_step() {
                sum += t.saturating_sub(snap) as f64;
                n += 1;
            }
        }
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }

    /// Per-layer optimizer health at step `t`, layer-ordered: update norm,
    /// basis staleness, whitening quality. `grad_norm` is left `None` — the
    /// session fills it in from the gradients it owns.
    pub fn layer_health(&self, t: u64) -> Vec<crate::session::LayerHealth> {
        let mut out: Vec<crate::session::LayerHealth> = self
            .shards
            .iter()
            .flat_map(|s| s.iter())
            .map(|s| crate::session::LayerHealth {
                layer: s.layer_idx,
                grad_norm: None,
                update_norm: s.opt.update_norm(),
                staleness: s.opt.basis_snapshot_step().map(|snap| t.saturating_sub(snap)),
                whitening_offdiag: s.opt.whitening_offdiag(),
            })
            .collect();
        out.sort_by_key(|h| h.layer);
        out
    }

    /// Refresh-service queue depth (0 in Inline mode).
    pub fn refresh_queue_depth(&self) -> usize {
        self.refresh_service.as_ref().map(|s| s.pending()).unwrap_or(0)
    }

    /// Refresh-pool utilization `(jobs, busy seconds)` in Async mode.
    pub fn refresh_pool_stats(&self) -> Option<(u64, f64)> {
        self.refresh_service.as_ref().map(|s| s.pool_stats())
    }

    /// Barrier: wait for every in-flight background refresh (tests and
    /// orderly shutdown; a no-op in Inline mode).
    pub fn wait_refresh_idle(&self) {
        if let Some(svc) = &self.refresh_service {
            svc.wait_idle();
        }
    }

    /// Drain the refresh service and fold every published-but-unadopted
    /// basis into its layer's state, so [`Self::export_state`] captures what
    /// an uninterrupted run would use on its next step. Checkpointing calls
    /// this; a no-op in Inline mode.
    pub fn finish_pending(&mut self) {
        self.wait_refresh_idle();
        for slot in self.shards.iter_mut().flat_map(|s| s.iter_mut()) {
            slot.opt.finish_pending();
        }
    }

    /// One sharded optimizer step: updates `params` in place given `grads`.
    pub fn step(&mut self, params: &mut [Matrix], grads: &[Matrix], t: u64, lr: f32) {
        assert_eq!(params.len(), grads.len());
        // Move each shard's parameters out (cheap Vec swaps), update in
        // parallel, then move back.
        let mut shard_params: Vec<Vec<(usize, Matrix)>> = self
            .shards
            .iter()
            .map(|slots| {
                slots
                    .iter()
                    .map(|s| {
                        (s.layer_idx, std::mem::replace(&mut params[s.layer_idx], Matrix::zeros(0, 0)))
                    })
                    .collect()
            })
            .collect();

        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for (slots, sp) in self.shards.iter_mut().zip(shard_params.iter_mut()) {
                handles.push(scope.spawn(move || {
                    for (slot, (idx, w)) in slots.iter_mut().zip(sp.iter_mut()) {
                        debug_assert_eq!(slot.layer_idx, *idx);
                        slot.opt.update(w, &grads[*idx], t, lr);
                    }
                }));
            }
            for h in handles {
                h.join().expect("shard worker");
            }
        });

        for sp in shard_params {
            for (idx, w) in sp {
                params[idx] = w;
            }
        }
    }

    /// Total optimizer state bytes (paper §7.2 accounting).
    pub fn state_bytes(&self) -> usize {
        self.shards
            .iter()
            .flat_map(|s| s.iter())
            .map(|s| s.opt.state_bytes())
            .sum()
    }

    /// Total workspace-arena bytes across layers (the zero-allocation step
    /// path's grow-only scratch; 0 before the first step). Each layer's
    /// workspace is owned by its shard slot, so it is only ever touched by
    /// that shard's worker thread.
    pub fn scratch_bytes(&self) -> usize {
        self.shards
            .iter()
            .flat_map(|s| s.iter())
            .map(|s| s.opt.scratch_bytes())
            .sum()
    }

    /// Cumulative eigen/inverse-root refresh seconds across all layers.
    pub fn refresh_seconds(&self) -> f64 {
        self.shards
            .iter()
            .flat_map(|s| s.iter())
            .map(|s| s.opt.refresh_seconds())
            .sum()
    }

    /// Export (layer_idx, state tensors) for checkpointing, layer-ordered.
    pub fn export_state(&self) -> Vec<(usize, Vec<Matrix>)> {
        let mut out: Vec<(usize, Vec<Matrix>)> = self
            .shards
            .iter()
            .flat_map(|s| s.iter())
            .map(|s| (s.layer_idx, s.opt.export_state()))
            .collect();
        out.sort_by_key(|&(i, _)| i);
        out
    }

    pub fn import_state(&mut self, mut state: Vec<(usize, Vec<Matrix>)>) -> anyhow::Result<()> {
        state.sort_by_key(|&(i, _)| i);
        for shard in &mut self.shards {
            for slot in shard.iter_mut() {
                let pos = state
                    .binary_search_by_key(&slot.layer_idx, |&(i, _)| i)
                    .map_err(|_| anyhow::anyhow!("missing state for layer {}", slot.layer_idx))?;
                slot.opt.import_state(std::mem::take(&mut state[pos].1))?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{ModelOptimizer, Schedule};
    use crate::util::rng::Rng;

    fn shapes() -> Vec<(usize, usize)> {
        vec![(16, 16), (1, 32), (8, 24), (24, 8), (32, 32)]
    }

    #[test]
    fn assignment_is_partition() {
        let s = shapes();
        let a = assign_shards(&s, 3);
        assert_eq!(a.len(), s.len());
        assert!(a.iter().all(|&x| x < 3));
        // Each shard used if enough layers.
        let mut used = [false; 3];
        for &x in &a {
            used[x] = true;
        }
        assert!(used.iter().all(|&u| u));
    }

    #[test]
    fn balanced_by_cost_not_count() {
        // One huge layer + many small ones: the huge layer must sit alone.
        let s = vec![(256, 256), (4, 4), (4, 4), (4, 4), (4, 4), (4, 4)];
        let a = assign_shards(&s, 2);
        let huge_shard = a[0];
        for (i, &x) in a.iter().enumerate().skip(1) {
            assert_ne!(x, huge_shard, "small layer {i} shares the hot shard");
        }
    }

    #[test]
    fn sharded_step_matches_serial_model_optimizer() {
        // The sharded executor must produce EXACTLY the same parameters as
        // the serial ModelOptimizer — bitwise, since the math per layer is
        // identical and independent.
        let shapes = shapes();
        let hyper = Hyper { weight_decay: 0.0, precond_freq: 2, ..Hyper::default() };
        let mut rng = Rng::new(200);
        let init: Vec<Matrix> = shapes.iter().map(|&(m, n)| Matrix::randn(&mut rng, m, n, 1.0)).collect();

        let mut serial = ModelOptimizer::new(
            OptKind::Soap,
            hyper.clone(),
            Schedule::Constant { lr: 0.01 },
            &shapes,
        );
        let mut sharded = ShardedOptimizer::new(OptKind::Soap, &hyper, &shapes, 3);

        let mut p_serial = init.clone();
        let mut p_sharded = init;
        for t in 1..=7 {
            let grads: Vec<Matrix> = shapes
                .iter()
                .map(|&(m, n)| Matrix::randn(&mut rng, m, n, 1.0))
                .collect();
            serial.step(&mut p_serial, &grads);
            sharded.step(&mut p_sharded, &grads, t, 0.01);
        }
        for (a, b) in p_serial.iter().zip(&p_sharded) {
            assert_eq!(a.data, b.data, "sharded diverged from serial");
        }
    }

    #[test]
    fn state_export_import_roundtrip() {
        let shapes = shapes();
        let hyper = Hyper::default();
        let mut rng = Rng::new(201);
        let mut a = ShardedOptimizer::new(OptKind::Soap, &hyper, &shapes, 2);
        let mut params: Vec<Matrix> =
            shapes.iter().map(|&(m, n)| Matrix::randn(&mut rng, m, n, 1.0)).collect();
        for t in 1..=3 {
            let grads: Vec<Matrix> =
                shapes.iter().map(|&(m, n)| Matrix::randn(&mut rng, m, n, 1.0)).collect();
            a.step(&mut params, &grads, t, 0.01);
        }
        let state = a.export_state();

        let mut b = ShardedOptimizer::new(OptKind::Soap, &hyper, &shapes, 4);
        b.import_state(state).unwrap();

        // Continue both for 2 steps — identical trajectories.
        let mut pa = params.clone();
        let mut pb = params;
        for t in 4..=5 {
            let grads: Vec<Matrix> =
                shapes.iter().map(|&(m, n)| Matrix::randn(&mut rng, m, n, 1.0)).collect();
            // Same grads for both (clone the RNG state by regenerating).
            a.step(&mut pa, &grads, t, 0.01);
            b.step(&mut pb, &grads, t, 0.01);
        }
        for (x, y) in pa.iter().zip(&pb) {
            assert!(x.max_abs_diff(y) < 1e-6, "restore drifted: {}", x.max_abs_diff(y));
        }
    }

    #[test]
    fn flops_model_symmetric() {
        assert_eq!(layer_update_flops(8, 4), layer_update_flops(4, 8));
        assert!(layer_update_flops(64, 64) > layer_update_flops(8, 8));
    }

    #[test]
    fn async_mode_spins_up_service_and_tracks_loss() {
        let shapes = shapes();
        let hyper = Hyper { weight_decay: 0.0, precond_freq: 3, ..Hyper::default() };
        let mut inline = ShardedOptimizer::new(OptKind::Soap, &hyper, &shapes, 2);
        assert!(inline.refresh_service().is_none());

        let hyper_async = hyper.clone().async_refresh();
        let mut asynced = ShardedOptimizer::new(OptKind::Soap, &hyper_async, &shapes, 2);
        assert!(asynced.refresh_service().is_some(), "SOAP layers must attach");

        let mut rng = Rng::new(202);
        let init: Vec<Matrix> =
            shapes.iter().map(|&(m, n)| Matrix::randn(&mut rng, m, n, 1.0)).collect();
        let mut p_inline = init.clone();
        let mut p_async = init;
        for t in 1..=30 {
            let grads: Vec<Matrix> =
                shapes.iter().map(|&(m, n)| Matrix::randn(&mut rng, m, n, 1.0)).collect();
            inline.step(&mut p_inline, &grads, t, 0.01);
            asynced.step(&mut p_async, &grads, t, 0.01);
        }
        asynced.wait_refresh_idle();
        let stats = asynced.async_refresh_stats();
        assert!(stats.completed > 0, "no background refresh ran");
        assert_eq!(stats.failed, 0);
        assert!(asynced.async_refresh_seconds() > 0.0);
        assert_eq!(inline.async_refresh_seconds(), 0.0);
        // Same gradients, stale-but-adapting basis: parameters stay close
        // (not bitwise — async adopts each basis a step or two late).
        for (a, b) in p_inline.iter().zip(&p_async) {
            let diff = a.max_abs_diff(b);
            assert!(diff.is_finite() && diff < 1.0, "async diverged: {diff}");
        }
    }

    #[test]
    fn adamw_async_mode_needs_no_service() {
        let hyper = Hyper::default().async_refresh();
        let opt = ShardedOptimizer::new(OptKind::AdamW, &hyper, &shapes(), 2);
        assert!(opt.refresh_service().is_none(), "nothing to refresh for AdamW");
        assert_eq!(opt.async_refresh_stats().completed, 0);
    }

    #[test]
    fn staleness_reflects_staggered_refreshes() {
        // f = 4 over 5 layers, phases 0..3: after a few steps every SOAP
        // layer has refreshed within the last f steps, so mean staleness
        // must sit in [0, f].
        let shapes = shapes();
        let hyper = Hyper { weight_decay: 0.0, precond_freq: 4, ..Hyper::default() };
        let mut opt = ShardedOptimizer::new(OptKind::Soap, &hyper, &shapes, 3);
        let mut rng = Rng::new(203);
        let mut params: Vec<Matrix> =
            shapes.iter().map(|&(m, n)| Matrix::randn(&mut rng, m, n, 1.0)).collect();
        let mut t = 0;
        for _ in 0..10 {
            t += 1;
            let grads: Vec<Matrix> =
                shapes.iter().map(|&(m, n)| Matrix::randn(&mut rng, m, n, 1.0)).collect();
            opt.step(&mut params, &grads, t, 0.01);
        }
        let stale = opt.mean_basis_staleness(t);
        assert!(
            stale >= 0.0 && stale <= hyper.precond_freq as f64,
            "staggered inline staleness out of range: {stale}"
        );
    }
}
