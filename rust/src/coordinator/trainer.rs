//! The training coordinator: leader loop tying together the data pipeline,
//! the gradient engine (PJRT transformer artifacts or the native MLP), and
//! the optimizer executor (layer-sharded native workers or the PJRT/Pallas
//! artifact path).
//!
//! Layout of one step (DESIGN.md §6):
//!   data → microbatched fwd/bwd (grad accumulation) → sharded optimizer
//!   update (+ scheduled eigenbasis refresh) → metrics.

use std::time::Instant;

use anyhow::{anyhow, Result};

use super::metrics::{StepTiming, TrainLog};
use super::pjrt_optim::{preflight, PjrtOptimizer};
use super::sharded::ShardedOptimizer;
use crate::data::{Batch, BatchStream, CorpusSpec};
use crate::linalg::Matrix;
use crate::model::{self, NplmConfig};
use crate::optim::{Hyper, OptKind, Schedule};
use crate::runtime::{
    literal_from_matrix, literal_from_tokens, matrix_from_literal, scalar_from_literal, Engine,
};
use crate::util::rng::Rng;

/// Where gradients come from.
pub enum GradBackend {
    /// PJRT transformer artifact (`lm_grads_<cfg>`): the paper's workload.
    Pjrt { engine: Engine, config: String },
    /// Native hand-backpropped MLP LM — artifact-free runs and tests.
    Native { cfg: NplmConfig },
}

/// How optimizer updates are applied.
pub enum UpdateBackend {
    /// Layer-sharded native optimizers on worker threads (default).
    Native(ShardedOptimizer),
    /// Per-layer PJRT artifacts (SOAP through the L1 Pallas kernels).
    Pjrt(PjrtOptimizer),
}

#[derive(Clone)]
pub struct TrainerConfig {
    pub opt: OptKind,
    pub hyper: Hyper,
    pub schedule: Schedule,
    pub steps: u64,
    pub seed: u64,
    /// Gradient-accumulation microbatches per step (≥1).
    pub grad_accum: usize,
    /// Native optimizer worker threads.
    pub workers: usize,
    /// Log every k-th step to stdout (0 = silent).
    pub log_every: u64,
    pub vocab: usize,
    pub zipf_alpha: f64,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        Self {
            opt: OptKind::Soap,
            hyper: Hyper::default(),
            schedule: Schedule::Constant { lr: 3e-3 },
            steps: 100,
            seed: 0,
            grad_accum: 1,
            workers: 4,
            log_every: 0,
            vocab: 256,
            zipf_alpha: 1.2,
        }
    }
}

pub struct Trainer {
    pub cfg: TrainerConfig,
    grad: GradBackend,
    update: UpdateBackend,
    pub params: Vec<Matrix>,
    pub shapes: Vec<(usize, usize)>,
    stream: BatchStream,
    pub step: u64,
}

impl Trainer {
    /// Build a trainer with PJRT gradients (`lm_grads_<model>`) and native
    /// sharded optimizer updates — the default configuration.
    ///
    /// **Deprecated** in favor of the session builder:
    /// `TrainSession::builder().model(ModelSpec::artifact(name))…` — see
    /// [`crate::session`]. Kept for the integration tests that pin the
    /// session API bitwise to this path.
    pub fn new_pjrt(model_name: &str, cfg: TrainerConfig, artifacts_dir: &str) -> Result<Self> {
        let engine = Engine::load(artifacts_dir)?;
        let info = engine.manifest.config(model_name)?.clone();
        let shapes = info.shapes();
        let mut rng = Rng::new(cfg.seed);
        let params = init_lm_params(&info.params, &mut rng);
        let stream = BatchStream::new(
            CorpusSpec { vocab_size: info.vocab, zipf_alpha: cfg.zipf_alpha, seed: cfg.seed, stream: 0 },
            info.batch * cfg.grad_accum,
            info.seq,
            0,
            1,
        );
        let update = UpdateBackend::Native(ShardedOptimizer::new(
            cfg.opt, &cfg.hyper, &shapes, cfg.workers,
        ));
        Ok(Self {
            grad: GradBackend::Pjrt { engine, config: model_name.to_string() },
            update,
            params,
            shapes,
            stream,
            step: 0,
            cfg,
        })
    }

    /// PJRT gradients AND PJRT optimizer updates (the full artifact hot
    /// path, SOAP through the Pallas kernels).
    ///
    /// **Deprecated** in favor of the session builder with
    /// [`crate::session::Backend::Pjrt`] — see [`crate::session`].
    pub fn new_pjrt_full(model_name: &str, cfg: TrainerConfig, artifacts_dir: &str) -> Result<Self> {
        let mut t = Self::new_pjrt(model_name, cfg, artifacts_dir)?;
        let GradBackend::Pjrt { engine, .. } = &t.grad else { unreachable!() };
        preflight(engine, t.cfg.opt, &t.cfg.hyper, &t.shapes)?;
        t.update = UpdateBackend::Pjrt(PjrtOptimizer::new(
            t.cfg.opt,
            t.cfg.hyper.clone(),
            &t.shapes,
        )?);
        Ok(t)
    }

    /// Native MLP gradients + native sharded optimizer — no artifacts needed.
    ///
    /// **Deprecated** in favor of the session builder:
    /// `TrainSession::builder().model(ModelSpec::nplm(cfg, seq, batch))…` —
    /// see [`crate::session`].
    pub fn new_native(nplm: NplmConfig, mut cfg: TrainerConfig, seq: usize, batch: usize) -> Self {
        cfg.vocab = nplm.vocab;
        let mut rng = Rng::new(cfg.seed);
        let params = model::init_params(&nplm, &mut rng);
        let shapes: Vec<(usize, usize)> = params.iter().map(|p| (p.rows, p.cols)).collect();
        let stream = BatchStream::new(
            CorpusSpec { vocab_size: nplm.vocab, zipf_alpha: cfg.zipf_alpha, seed: cfg.seed, stream: 0 },
            batch * cfg.grad_accum,
            seq,
            0,
            1,
        );
        let update = UpdateBackend::Native(ShardedOptimizer::new(
            cfg.opt, &cfg.hyper, &shapes, cfg.workers,
        ));
        Self {
            grad: GradBackend::Native { cfg: nplm },
            update,
            params,
            shapes,
            stream,
            step: 0,
            cfg,
        }
    }

    /// Discard `k` batches from the data stream — used when resuming from a
    /// checkpoint so the restored run sees exactly the batches the original
    /// would have (the stream is a pure function of (seed, position)).
    pub fn skip_batches(&mut self, k: u64) {
        for _ in 0..k {
            let _ = self.stream.next_batch();
        }
    }

    /// Tokens consumed per optimizer step.
    pub fn tokens_per_step(&self) -> usize {
        self.stream.batch * self.stream.seq
    }

    pub fn entropy_floor(&self) -> f64 {
        self.stream.entropy_floor()
    }

    fn grads_for(&self, batch: &Batch) -> Result<(f32, Vec<Matrix>)> {
        match &self.grad {
            GradBackend::Pjrt { engine, config } => {
                let info = engine.manifest.config(config)?;
                anyhow::ensure!(batch.batch == info.batch, "microbatch must equal artifact batch");
                let mut inputs = Vec::with_capacity(self.params.len() + 2);
                for p in &self.params {
                    inputs.push(literal_from_matrix(p)?);
                }
                inputs.push(literal_from_tokens(&batch.tokens, batch.batch, batch.seq)?);
                inputs.push(literal_from_tokens(&batch.targets, batch.batch, batch.seq)?);
                let out = engine.run(&format!("lm_grads_{config}"), &inputs)?;
                let loss = scalar_from_literal(&out[0])?;
                let mut grads = Vec::with_capacity(self.params.len());
                for (i, &(r, c)) in self.shapes.iter().enumerate() {
                    grads.push(matrix_from_literal(&out[1 + i], r, c)?);
                }
                Ok((loss, grads))
            }
            GradBackend::Native { cfg } => {
                let (loss, grads) = model::loss_and_grads(cfg, &self.params, batch);
                Ok((loss, grads))
            }
        }
    }

    /// Run one training step; returns (loss, timing).
    pub fn train_step(&mut self) -> Result<(f32, StepTiming)> {
        let mut timing = StepTiming::default();

        let t0 = Instant::now();
        let batch = self.stream.next_batch();
        let micro = batch.microbatches(self.cfg.grad_accum);
        timing.data_s = t0.elapsed().as_secs_f64();

        // Gradient accumulation: mean over microbatches.
        let t0 = Instant::now();
        let mut loss_acc = 0.0f64;
        let mut grads: Option<Vec<Matrix>> = None;
        for mb in &micro {
            let (loss, g) = self.grads_for(mb)?;
            loss_acc += loss as f64;
            grads = Some(match grads.take() {
                None => g,
                Some(mut acc) => {
                    for (a, b) in acc.iter_mut().zip(&g) {
                        a.axpy_inplace(1.0, b);
                    }
                    acc
                }
            });
        }
        let mut grads = grads.ok_or_else(|| anyhow!("no microbatches"))?;
        if micro.len() > 1 {
            let s = 1.0 / micro.len() as f32;
            for g in &mut grads {
                g.scale_inplace(s);
            }
        }
        let loss = (loss_acc / micro.len() as f64) as f32;
        timing.grad_s = t0.elapsed().as_secs_f64();

        // Optimizer step (+ refresh accounting). Hot-path refresh seconds
        // come from the optimizer's inline account; background (async)
        // refresh seconds are drawn from the service and reported separately
        // — they overlap the step instead of extending it.
        self.step += 1;
        let lr = self.cfg.schedule.lr_at(self.step - 1);
        let t0 = Instant::now();
        let refresh_before = self.refresh_seconds();
        let bg_before = self.async_refresh_seconds();
        match &mut self.update {
            UpdateBackend::Native(sharded) => {
                sharded.step(&mut self.params, &grads, self.step, lr)
            }
            UpdateBackend::Pjrt(pjrt) => {
                let GradBackend::Pjrt { engine, .. } = &self.grad else {
                    return Err(anyhow!("PJRT update backend requires PJRT grads"));
                };
                pjrt.step(engine, &mut self.params, &grads, self.step, lr)?;
            }
        }
        let update_total = t0.elapsed().as_secs_f64();
        timing.refresh_s = self.refresh_seconds() - refresh_before;
        timing.update_s = (update_total - timing.refresh_s).max(0.0);
        timing.bg_refresh_s = (self.async_refresh_seconds() - bg_before).max(0.0);
        timing.staleness_steps = self.mean_basis_staleness();

        Ok((loss, timing))
    }

    /// Train for `cfg.steps` steps, returning the full log.
    pub fn run(&mut self) -> Result<TrainLog> {
        let mut log = TrainLog {
            optimizer: self.opt_label(),
            model: self.model_label(),
            tokens_per_batch: self.tokens_per_step(),
            ..Default::default()
        };
        for _ in 0..self.cfg.steps {
            let (loss, timing) = self.train_step()?;
            log.losses.push((self.step, loss));
            log.timings.push(timing);
            if self.cfg.log_every > 0 && self.step % self.cfg.log_every == 0 {
                println!(
                    "step {:>6}  loss {:.4}  lr {:.2e}  {:.0} tok/s",
                    self.step,
                    loss,
                    self.cfg.schedule.lr_at(self.step - 1),
                    self.tokens_per_step() as f64 / timing.total().max(1e-9),
                );
            }
        }
        Ok(log)
    }

    /// Evaluate mean loss over `batches` held-out batches (separate shard).
    pub fn eval_loss(&mut self, batches: usize) -> Result<f32> {
        let mut eval_stream = BatchStream::new(
            CorpusSpec {
                vocab_size: self.cfg.vocab,
                zipf_alpha: self.cfg.zipf_alpha,
                seed: self.cfg.seed,      // SAME language…
                stream: 0xE7A1,           // …fresh held-out sample stream
            },
            self.stream.batch / self.cfg.grad_accum.max(1),
            self.stream.seq,
            0,
            1,
        );
        let mut total = 0.0f64;
        for _ in 0..batches {
            let b = eval_stream.next_batch();
            let (loss, _) = self.grads_for(&b)?;
            total += loss as f64;
        }
        Ok((total / batches as f64) as f32)
    }

    pub fn refresh_seconds(&self) -> f64 {
        match &self.update {
            UpdateBackend::Native(s) => s.refresh_seconds(),
            UpdateBackend::Pjrt(p) => p.refresh_secs,
        }
    }

    /// Cumulative background (async-service) refresh seconds — 0 in Inline
    /// mode and on the PJRT path.
    pub fn async_refresh_seconds(&self) -> f64 {
        match &self.update {
            UpdateBackend::Native(s) => s.async_refresh_seconds(),
            UpdateBackend::Pjrt(_) => 0.0,
        }
    }

    /// Mean basis staleness (steps) across preconditioned layers right now.
    pub fn mean_basis_staleness(&self) -> f64 {
        match &self.update {
            UpdateBackend::Native(s) => s.mean_basis_staleness(self.step),
            UpdateBackend::Pjrt(_) => 0.0,
        }
    }

    /// Drain in-flight background refreshes (no-op in Inline/PJRT modes).
    /// Call before reading final `async_refresh_seconds` totals, so work
    /// still in flight at the last step isn't silently dropped from the
    /// accounting.
    pub fn wait_refresh_idle(&self) {
        if let UpdateBackend::Native(s) = &self.update {
            s.wait_refresh_idle();
        }
    }

    pub fn state_bytes(&self) -> usize {
        match &self.update {
            UpdateBackend::Native(s) => s.state_bytes(),
            UpdateBackend::Pjrt(p) => p.state_bytes(),
        }
    }

    /// Workspace-arena bytes held by the native step path (0 for PJRT,
    /// whose scratch lives device-side in the compiled artifact).
    pub fn scratch_bytes(&self) -> usize {
        match &self.update {
            UpdateBackend::Native(s) => s.scratch_bytes(),
            UpdateBackend::Pjrt(_) => 0,
        }
    }

    pub fn opt_label(&self) -> String {
        // Canonicalize so the preset and composition-spec spellings of the
        // same configuration share one label (one aggregation key in
        // TrainLog / bench JSON): base name from the canonical kind, variant
        // suffixes from the spec-resolved hyperparameters.
        let mut h = self.cfg.hyper.clone();
        if let crate::optim::OptKind::Composed(spec) = &self.cfg.opt {
            spec.apply(&mut h);
        }
        let mut s = self.cfg.opt.canonical().name().to_string();
        if h.one_sided {
            s.push_str("-onesided");
        }
        if h.factorized {
            s.push_str("-factorized");
        }
        if self.cfg.hyper.refresh_mode == crate::optim::RefreshMode::Async {
            s.push_str("-async");
        }
        if matches!(self.update, UpdateBackend::Pjrt(_)) {
            s.push_str("(pjrt)");
        }
        s
    }

    pub fn model_label(&self) -> String {
        match &self.grad {
            GradBackend::Pjrt { config, .. } => config.clone(),
            GradBackend::Native { cfg } => {
                format!("nplm-v{}d{}h{}", cfg.vocab, cfg.dim, cfg.hidden)
            }
        }
    }

    /// Access the sharded native optimizer (checkpointing).
    pub fn native_optimizer(&self) -> Option<&ShardedOptimizer> {
        match &self.update {
            UpdateBackend::Native(s) => Some(s),
            _ => None,
        }
    }

    pub fn native_optimizer_mut(&mut self) -> Option<&mut ShardedOptimizer> {
        match &mut self.update {
            UpdateBackend::Native(s) => Some(s),
            _ => None,
        }
    }
}

/// Initialize LM parameters the same way `model.init_params` does in jax
/// (1/√fan_in; RMS scales at 1) but with the native RNG, so native runs are
/// self-contained.
pub fn init_lm_params(specs: &[(String, usize, usize)], rng: &mut Rng) -> Vec<Matrix> {
    specs
        .iter()
        .map(|(name, r, c)| {
            if name.contains("ln") {
                Matrix::from_fn(*r, *c, |_, _| 1.0)
            } else {
                Matrix::randn(rng, *r, *c, 1.0 / (*r as f32).sqrt())
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn native_trainer(opt: OptKind, steps: u64, seed: u64) -> Trainer {
        let cfg = TrainerConfig {
            opt,
            hyper: Hyper { precond_freq: 4, ..Hyper::default() },
            schedule: Schedule::Constant { lr: 0.02 },
            steps,
            seed,
            grad_accum: 1,
            workers: 2,
            log_every: 0,
            vocab: 64,
            zipf_alpha: 1.3,
        };
        Trainer::new_native(
            NplmConfig { vocab: 64, context: 3, dim: 12, hidden: 24, conv: false },
            cfg,
            24,
            8,
        )
    }

    #[test]
    fn native_training_reduces_loss_soap() {
        let mut t = native_trainer(OptKind::Soap, 150, 1);
        let log = t.run().unwrap();
        let first = log.losses[0].1;
        let last = log.tail_loss(10);
        assert!(
            last < first - 0.4,
            "SOAP did not learn: {first} → {last}"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = native_trainer(OptKind::AdamW, 10, 7);
        let mut b = native_trainer(OptKind::AdamW, 10, 7);
        let la = a.run().unwrap();
        let lb = b.run().unwrap();
        assert_eq!(la.losses, lb.losses);
    }

    #[test]
    fn grad_accum_equals_bigger_batch() {
        // accum=2 with microbatch 8 must see the same data as batch 16 and
        // produce identical parameters (mean of microbatch grads == full
        // batch grad for a mean loss… per-example sets differ though, so we
        // check the weaker but exact invariant: identical data stream).
        let base = native_trainer(OptKind::AdamW, 1, 3);
        let mut accum = {
            let mut t = native_trainer(OptKind::AdamW, 1, 3);
            t.cfg.grad_accum = 2;
            // rebuild stream with doubled batch
            Trainer::new_native(
                NplmConfig { vocab: 64, context: 3, dim: 12, hidden: 24, conv: false },
                TrainerConfig { grad_accum: 2, ..t.cfg },
                24,
                8,
            )
        };
        assert_eq!(accum.tokens_per_step(), 2 * base.tokens_per_step());
        let (loss, _) = accum.train_step().unwrap();
        assert!(loss.is_finite());
    }

    #[test]
    fn timing_breakdown_populated() {
        let mut t = native_trainer(OptKind::Soap, 5, 5);
        let log = t.run().unwrap();
        let total: f64 = log.timings.iter().map(|x| x.total()).sum();
        assert!(total > 0.0);
        // SOAP with f=4 must have refresh time in steps 4 (plus init at 1).
        let refreshes: f64 = log.timings.iter().map(|x| x.refresh_s).sum();
        assert!(refreshes > 0.0);
    }

    #[test]
    fn state_bytes_positive_and_ordered() {
        let t_soap = native_trainer(OptKind::Soap, 1, 1);
        let t_adam = native_trainer(OptKind::AdamW, 1, 1);
        assert!(t_soap.state_bytes() > t_adam.state_bytes());
    }

    #[test]
    fn async_refresh_trains_off_the_hot_path() {
        let mut t = native_trainer(OptKind::Soap, 60, 2);
        t.cfg.hyper = Hyper { precond_freq: 4, ..Hyper::default() }.async_refresh();
        // Rebuild with the async hyper (native_trainer built an inline one).
        let mut t = Trainer::new_native(
            NplmConfig { vocab: 64, context: 3, dim: 12, hidden: 24, conv: false },
            t.cfg.clone(),
            24,
            8,
        );
        let log = t.run().unwrap();
        t.native_optimizer().unwrap().wait_refresh_idle();
        assert!(log.final_loss().is_finite());
        assert!(log.tail_loss(10) < log.losses[0].1, "async SOAP did not learn");
        // Background service did the refreshes; the hot path only paid the
        // one-time first-step eigh init.
        assert!(t.async_refresh_seconds() > 0.0, "no background refresh ran");
        let stats = t.native_optimizer().unwrap().async_refresh_stats();
        assert!(stats.completed > 0);
        assert_eq!(stats.failed, 0);
        // Staleness is reported and bounded (≈ f + adoption delay; the wide
        // margin keeps slow CI machines from flaking).
        assert!(log.mean_staleness() > 0.0);
        let last = log.timings.last().unwrap().staleness_steps;
        assert!(last <= 12.0, "staleness runaway: {last}");
    }
}
