//! L3 coordinator — the training orchestration layer (DESIGN.md §1):
//! leader loop, microbatch gradient accumulation, layer-sharded optimizer
//! workers, the PJRT/Pallas optimizer hot path, preconditioning-frequency
//! scheduling, checkpoints, and per-step wall-clock accounting.

pub mod checkpoint;
pub mod metrics;
pub mod pjrt_optim;
pub mod sharded;
pub mod trainer;

pub use checkpoint::Checkpoint;
pub use metrics::{StepTiming, TrainLog};
pub use pjrt_optim::PjrtOptimizer;
pub use sharded::ShardedOptimizer;
pub use trainer::{init_lm_params, GradBackend, Trainer, TrainerConfig, UpdateBackend};
