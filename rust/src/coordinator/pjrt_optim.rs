//! PJRT-backed optimizer execution — the paper's hot path through the L1
//! Pallas kernels: per-layer `soap_update_*` / `adamw_update_*` artifacts for
//! the step, `soap_refresh_*` for the Algorithm-4 eigenbasis refresh.
//!
//! Semantics match `optim::Soap`/`optim::AdamW` exactly (the integration
//! tests assert trajectory equality), so the coordinator can switch between
//! native and PJRT update engines per config.

use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::linalg::{eigh, Matrix};
use crate::optim::{Hyper, OptKind};
use crate::runtime::{literal_from_matrix, literal_scalar, matrix_from_literal, Engine};

enum LayerState {
    /// Elementwise AdamW artifact (1-D params, or 2-D with both sides
    /// identity).
    Adamw { m: Matrix, v: Matrix },
    /// SOAP artifact; `ql`/`qr` are `None` for identity sides.
    Soap {
        m: Matrix,
        v: Matrix,
        l: Option<Matrix>,
        r: Option<Matrix>,
        ql: Option<Matrix>,
        qr: Option<Matrix>,
        initialized: bool,
    },
}

pub struct PjrtLayer {
    rows: usize,
    cols: usize,
    state: LayerState,
}

/// Model-wide PJRT optimizer (SOAP with AdamW on 1-D params, or pure AdamW).
pub struct PjrtOptimizer {
    pub kind: OptKind,
    hyper: Hyper,
    layers: Vec<PjrtLayer>,
    pub refresh_secs: f64,
}

impl PjrtOptimizer {
    pub fn new(kind: OptKind, hyper: Hyper, shapes: &[(usize, usize)]) -> Result<Self> {
        // Composition specs canonical to a preset (e.g. basis=eigen,inner=
        // adam ≡ soap) ride the same artifacts.
        let kind = kind.canonical();
        anyhow::ensure!(
            matches!(kind, OptKind::Soap | OptKind::AdamW),
            "PJRT optimizer path supports soap|adamw (got {})",
            kind.name()
        );
        anyhow::ensure!(
            !(kind == OptKind::Soap && hyper.factorized),
            "PJRT SOAP artifacts implement the full-V Adam engine; factorized SOAP is native-only"
        );
        let layers = shapes
            .iter()
            .map(|&(rows, cols)| {
                let is_1d = rows == 1 || cols == 1;
                let state = if kind == OptKind::AdamW || is_1d {
                    LayerState::Adamw { m: Matrix::zeros(rows, cols), v: Matrix::zeros(rows, cols) }
                } else {
                    let mut left = rows <= hyper.max_precond_dim;
                    let mut right = cols <= hyper.max_precond_dim;
                    if hyper.one_sided {
                        if rows <= cols {
                            right = false;
                        } else {
                            left = false;
                        }
                    }
                    if !left && !right {
                        LayerState::Adamw {
                            m: Matrix::zeros(rows, cols),
                            v: Matrix::zeros(rows, cols),
                        }
                    } else {
                        LayerState::Soap {
                            m: Matrix::zeros(rows, cols),
                            v: Matrix::zeros(rows, cols),
                            l: left.then(|| Matrix::zeros(rows, rows)),
                            r: right.then(|| Matrix::zeros(cols, cols)),
                            ql: None,
                            qr: None,
                            initialized: false,
                        }
                    }
                };
                PjrtLayer { rows, cols, state }
            })
            .collect();
        Ok(Self { kind, hyper, layers, refresh_secs: 0.0 })
    }

    /// One optimizer step over all layers through the artifacts.
    pub fn step(
        &mut self,
        engine: &Engine,
        params: &mut [Matrix],
        grads: &[Matrix],
        t: u64,
        lr: f32,
    ) -> Result<()> {
        anyhow::ensure!(params.len() == self.layers.len());
        let freq = self.hyper.precond_freq.max(1);
        for (idx, ((layer, w), g)) in
            self.layers.iter_mut().zip(params.iter_mut()).zip(grads).enumerate()
        {
            // Staggered per-layer refresh phase (layer_idx % f) — must match
            // the native executors' `OptKind::build_staggered` schedule so
            // the PJRT and native trajectories stay comparable; a pinned
            // phase (stagger_refresh = false) is honored verbatim, same as
            // there.
            let refresh_phase = if self.hyper.stagger_refresh {
                idx as u64 % freq
            } else {
                self.hyper.refresh_phase % freq
            };
            let (rows, cols) = (layer.rows, layer.cols);
            match &mut layer.state {
                LayerState::Adamw { m, v } => {
                    let key = format!("adamw_update_{rows}x{cols}");
                    let out = engine.run(
                        &key,
                        &[
                            literal_from_matrix(w)?,
                            literal_from_matrix(m)?,
                            literal_from_matrix(v)?,
                            literal_from_matrix(g)?,
                            literal_scalar(t as f32),
                            literal_scalar(lr),
                        ],
                    )?;
                    *w = matrix_from_literal(&out[0], rows, cols)?;
                    *m = matrix_from_literal(&out[1], rows, cols)?;
                    *v = matrix_from_literal(&out[2], rows, cols)?;
                }
                LayerState::Soap { m, v, l, r, ql, qr, initialized } => {
                    // First step: initialize factors + eigenbasis natively
                    // (matches optim::Soap::init_basis).
                    if !*initialized {
                        let t0 = Instant::now();
                        if let Some(lm) = l {
                            *lm = g.matmul_nt(g);
                            let (_, vecs) = eigh(lm);
                            *ql = Some(vecs);
                        }
                        if let Some(rm) = r {
                            *rm = g.matmul_tn(g);
                            let (_, vecs) = eigh(rm);
                            *qr = Some(vecs);
                        }
                        *initialized = true;
                        self.refresh_secs += t0.elapsed().as_secs_f64();
                    }

                    match (l.as_mut(), r.as_mut()) {
                        (Some(lm), Some(rm)) => {
                            let key = format!("soap_update_{rows}x{cols}");
                            let out = engine.run(
                                &key,
                                &[
                                    literal_from_matrix(w)?,
                                    literal_from_matrix(m)?,
                                    literal_from_matrix(v)?,
                                    literal_from_matrix(lm)?,
                                    literal_from_matrix(rm)?,
                                    literal_from_matrix(ql.as_ref().unwrap())?,
                                    literal_from_matrix(qr.as_ref().unwrap())?,
                                    literal_from_matrix(g)?,
                                    literal_scalar(t as f32),
                                    literal_scalar(lr),
                                ],
                            )?;
                            *w = matrix_from_literal(&out[0], rows, cols)?;
                            *m = matrix_from_literal(&out[1], rows, cols)?;
                            *v = matrix_from_literal(&out[2], rows, cols)?;
                            *lm = matrix_from_literal(&out[3], rows, rows)?;
                            *rm = matrix_from_literal(&out[4], cols, cols)?;
                        }
                        (Some(lm), None) => {
                            let key = format!("soap_left_{rows}x{cols}");
                            let out = engine.run(
                                &key,
                                &[
                                    literal_from_matrix(w)?,
                                    literal_from_matrix(m)?,
                                    literal_from_matrix(v)?,
                                    literal_from_matrix(lm)?,
                                    literal_from_matrix(ql.as_ref().unwrap())?,
                                    literal_from_matrix(g)?,
                                    literal_scalar(t as f32),
                                    literal_scalar(lr),
                                ],
                            )?;
                            *w = matrix_from_literal(&out[0], rows, cols)?;
                            *m = matrix_from_literal(&out[1], rows, cols)?;
                            *v = matrix_from_literal(&out[2], rows, cols)?;
                            *lm = matrix_from_literal(&out[3], rows, rows)?;
                        }
                        (None, Some(rm)) => {
                            let key = format!("soap_right_{rows}x{cols}");
                            let out = engine.run(
                                &key,
                                &[
                                    literal_from_matrix(w)?,
                                    literal_from_matrix(m)?,
                                    literal_from_matrix(v)?,
                                    literal_from_matrix(rm)?,
                                    literal_from_matrix(qr.as_ref().unwrap())?,
                                    literal_from_matrix(g)?,
                                    literal_scalar(t as f32),
                                    literal_scalar(lr),
                                ],
                            )?;
                            *w = matrix_from_literal(&out[0], rows, cols)?;
                            *m = matrix_from_literal(&out[1], rows, cols)?;
                            *v = matrix_from_literal(&out[2], rows, cols)?;
                            *rm = matrix_from_literal(&out[3], cols, cols)?;
                        }
                        (None, None) => unreachable!("handled as Adamw"),
                    }

                    // Eigenbasis refresh (Algorithm 4) at frequency f.
                    if t % freq == refresh_phase {
                        let t0 = Instant::now();
                        if let (Some(lm), Some(q)) = (l.as_ref(), ql.as_mut()) {
                            let out = engine.run(
                                &format!("soap_refresh_{rows}"),
                                &[literal_from_matrix(lm)?, literal_from_matrix(q)?],
                            )?;
                            *q = matrix_from_literal(&out[0], rows, rows)?;
                        }
                        if let (Some(rm), Some(q)) = (r.as_ref(), qr.as_mut()) {
                            let out = engine.run(
                                &format!("soap_refresh_{cols}"),
                                &[literal_from_matrix(rm)?, literal_from_matrix(q)?],
                            )?;
                            *q = matrix_from_literal(&out[0], cols, cols)?;
                        }
                        self.refresh_secs += t0.elapsed().as_secs_f64();
                    }
                }
            }
        }
        Ok(())
    }

    /// Optimizer state bytes (§7.2 accounting — same formula as native).
    pub fn state_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|layer| {
                match &layer.state {
                    LayerState::Adamw { m, v } => (m.numel() + v.numel()) * 4,
                    LayerState::Soap { m, v, l, r, ql, qr, .. } => {
                        let opt = |x: &Option<Matrix>| x.as_ref().map(|m| m.numel()).unwrap_or(0);
                        (m.numel() + v.numel() + opt(l) + opt(r) + opt(ql) + opt(qr)) * 4
                    }
                }
            })
            .sum()
    }
}

/// Resolve which artifact a SOAP layer of a given shape needs — used by
/// preflight checks so a missing artifact fails fast with a clear message.
pub fn required_artifacts(kind: OptKind, hyper: &Hyper, shapes: &[(usize, usize)]) -> Vec<String> {
    let kind = kind.canonical();
    let mut keys = Vec::new();
    for &(rows, cols) in shapes {
        let is_1d = rows == 1 || cols == 1;
        if kind == OptKind::AdamW || is_1d {
            keys.push(format!("adamw_update_{rows}x{cols}"));
            continue;
        }
        let mut left = rows <= hyper.max_precond_dim;
        let mut right = cols <= hyper.max_precond_dim;
        if hyper.one_sided {
            if rows <= cols {
                right = false;
            } else {
                left = false;
            }
        }
        match (left, right) {
            (true, true) => {
                keys.push(format!("soap_update_{rows}x{cols}"));
                keys.push(format!("soap_refresh_{rows}"));
                keys.push(format!("soap_refresh_{cols}"));
            }
            (true, false) => {
                keys.push(format!("soap_left_{rows}x{cols}"));
                keys.push(format!("soap_refresh_{rows}"));
            }
            (false, true) => {
                keys.push(format!("soap_right_{rows}x{cols}"));
                keys.push(format!("soap_refresh_{cols}"));
            }
            (false, false) => keys.push(format!("adamw_update_{rows}x{cols}")),
        }
    }
    keys.sort();
    keys.dedup();
    keys
}

/// Preflight: verify the manifest carries everything the run needs.
pub fn preflight(engine: &Engine, kind: OptKind, hyper: &Hyper, shapes: &[(usize, usize)]) -> Result<()> {
    let missing: Vec<String> = required_artifacts(kind, hyper, shapes)
        .into_iter()
        .filter(|k| !engine.manifest.has_artifact(k))
        .collect();
    if missing.is_empty() {
        Ok(())
    } else {
        Err(anyhow!(
            "missing artifacts {missing:?} — re-run `make artifacts` with the right --configs"
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn required_artifacts_1d_uses_adamw() {
        let keys = required_artifacts(OptKind::Soap, &Hyper::default(), &[(1, 64)]);
        assert_eq!(keys, vec!["adamw_update_1x64".to_string()]);
    }

    #[test]
    fn required_artifacts_2d_full() {
        let keys = required_artifacts(OptKind::Soap, &Hyper::default(), &[(64, 256)]);
        assert!(keys.contains(&"soap_update_64x256".to_string()));
        assert!(keys.contains(&"soap_refresh_64".to_string()));
        assert!(keys.contains(&"soap_refresh_256".to_string()));
    }

    #[test]
    fn required_artifacts_one_sided() {
        let h = Hyper::default().one_sided();
        let keys = required_artifacts(OptKind::Soap, &h, &[(64, 256)]);
        assert!(keys.contains(&"soap_left_64x256".to_string()));
        assert!(!keys.iter().any(|k| k.contains("soap_update")));
    }

    #[test]
    fn required_artifacts_dim_cap_forces_one_sided() {
        let h = Hyper { max_precond_dim: 128, ..Hyper::default() };
        let keys = required_artifacts(OptKind::Soap, &h, &[(8192, 64)]);
        assert!(keys.contains(&"soap_right_8192x64".to_string()));
    }

    #[test]
    fn builds_without_engine() {
        let o = PjrtOptimizer::new(OptKind::Soap, Hyper::default(), &[(8, 8), (1, 8)]).unwrap();
        assert_eq!(o.layers.len(), 2);
        assert!(PjrtOptimizer::new(OptKind::Galore, Hyper::default(), &[(8, 8)]).is_err());
    }

    #[test]
    fn canonical_composition_specs_ride_the_artifact_path() {
        let soap_spec = OptKind::parse("basis=eigen,inner=adam").unwrap();
        let o = PjrtOptimizer::new(soap_spec, Hyper::default(), &[(8, 8)]).unwrap();
        assert_eq!(o.kind, OptKind::Soap);
        assert_eq!(
            required_artifacts(soap_spec, &Hyper::default(), &[(64, 256)]),
            required_artifacts(OptKind::Soap, &Hyper::default(), &[(64, 256)]),
        );
        let novel = OptKind::parse("basis=svd,inner=adafactor").unwrap();
        assert!(PjrtOptimizer::new(novel, Hyper::default(), &[(8, 8)]).is_err());
    }
}
