//! Deterministic fault injection and recovery primitives.
//!
//! SOAP's stale-basis tolerance (paper §1, Fig. 1) is a license to degrade
//! gracefully instead of aborting: keep stepping on the last-good eigenbasis
//! when a refresh fails, keep the run alive when a frame drops. This module
//! supplies the two halves of that story:
//!
//! - **[`FaultPlan`]** — a seeded, reproducible chaos schedule parsed from
//!   `--fault-plan` (see the grammar below). Installed process-wide via
//!   [`install`]; every injection seam asks [`active`] first, which is a
//!   single atomic pointer load — runs without a plan take no RNG draws, no
//!   locks, and no allocations, so faults-off trajectories are bitwise
//!   identical to a build without the seams.
//! - **[`backoff_delay`]** — the shared exponential-backoff-with-jitter
//!   schedule used by transport connect/rendezvous/send retries. Delays are
//!   deterministic in `(seed, attempt)`, bounded by the cap, and monotone
//!   nondecreasing per attempt (jitter is `[0, 0.5]` multiplicative, and
//!   `2^(n+1) ≥ 1.5·2^n`), which `rust/tests/chaos.rs` property-tests.
//!
//! ## Fault-plan grammar
//!
//! `;`-separated clauses, each `key=value`:
//!
//! | clause                   | effect                                              |
//! |--------------------------|-----------------------------------------------------|
//! | `seed=<u64>`             | RNG seed (mixed with the rank; default 0)           |
//! | `drop-frame=<p>`         | drop a steady-state frame send with probability `p` (retried transparently) |
//! | `delay-frame=<p>:<ms>`   | sleep `ms` before a frame send with probability `p` |
//! | `dup-frame=<p>`          | retransmit a frame (same sequence number) with probability `p` |
//! | `crash-rank=<r>:<step>`  | rank `r` exits abruptly at step `step` (once)       |
//! | `eigh-fail=<basis>:<step>` | poison basis `basis`'s decomposition at step `step` (once) |
//! | `nan-grad=<layer>:<step>`  | inject NaN into layer `layer`'s gradient at step `step` (once) |
//! | `inf-grad=<layer>:<step>`  | inject Inf into layer `layer`'s gradient at step `step` (once) |
//!
//! Probabilities are capped at 0.9 so injected-drop retry loops terminate
//! almost surely. One-shot clauses (`crash-rank`, `eigh-fail`, `nan-grad`,
//! `inf-grad`) are disarmed on an `--auto-resume` relaunch
//! (`fault-attempt > 0`) — otherwise a crash plan would re-kill every
//! attempt; the probabilistic frame clauses persist across attempts.

use std::sync::atomic::{AtomicBool, AtomicPtr, AtomicU64, Ordering};
use std::time::Duration;

use anyhow::Result;

/// A parsed `--fault-plan`: the full seeded chaos schedule for one run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// RNG seed for the probabilistic clauses (mixed with the rank so every
    /// rank draws an independent deterministic stream).
    pub seed: u64,
    /// Probability a steady-state frame send is dropped (and retried).
    pub drop_frame: f64,
    /// `(probability, millis)` a frame send is delayed.
    pub delay_frame: Option<(f64, u64)>,
    /// Probability a frame is sent twice with the same sequence number.
    pub dup_frame: f64,
    /// `(rank, step)`: that rank exits abruptly at that step. One-shot.
    pub crash_rank: Option<(usize, u64)>,
    /// `(basis id, step)`: poison that basis's decomposition result with
    /// NaN at that step, exercising the reject-and-keep-previous guard.
    /// One-shot. The basis id is the per-process creation index
    /// (`EigenBasis` trace id) — the layer index for matrix models.
    pub eigh_fail: Option<(u64, u64)>,
    /// `(layer, step)`: overwrite that layer's gradient with NaN at that
    /// step (post-allreduce, so every rank sees it). One-shot.
    pub nan_grad: Option<(usize, u64)>,
    /// `(layer, step)`: same with +Inf. One-shot.
    pub inf_grad: Option<(usize, u64)>,
}

impl FaultPlan {
    /// Parse the `--fault-plan` grammar (see the module docs).
    pub fn parse(s: &str) -> Result<Self> {
        fn prob(key: &str, v: &str) -> Result<f64> {
            let p: f64 = v.parse().map_err(|e| anyhow::anyhow!("{key}={v}: {e}"))?;
            anyhow::ensure!(
                (0.0..=0.9).contains(&p),
                "{key}={v}: probability must be in [0, 0.9] so retries terminate"
            );
            Ok(p)
        }
        fn pair<A, B>(key: &str, v: &str) -> Result<(A, B)>
        where
            A: std::str::FromStr,
            B: std::str::FromStr,
            A::Err: std::fmt::Display,
            B::Err: std::fmt::Display,
        {
            let (a, b) = v
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("{key}={v}: expected <a>:<b>"))?;
            Ok((
                a.parse().map_err(|e| anyhow::anyhow!("{key}={v}: {e}"))?,
                b.parse().map_err(|e| anyhow::anyhow!("{key}={v}: {e}"))?,
            ))
        }
        let mut plan = FaultPlan::default();
        for clause in s.split(';') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            let (key, value) = clause
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("fault-plan clause '{clause}' is not key=value"))?;
            match key.trim() {
                "seed" => {
                    plan.seed =
                        value.parse().map_err(|e| anyhow::anyhow!("seed={value}: {e}"))?;
                }
                "drop-frame" => plan.drop_frame = prob("drop-frame", value)?,
                "dup-frame" => plan.dup_frame = prob("dup-frame", value)?,
                "delay-frame" => {
                    let (p, ms) = value.split_once(':').ok_or_else(|| {
                        anyhow::anyhow!("delay-frame={value}: expected <p>:<millis>")
                    })?;
                    let ms: u64 =
                        ms.parse().map_err(|e| anyhow::anyhow!("delay-frame={value}: {e}"))?;
                    plan.delay_frame = Some((prob("delay-frame", p)?, ms));
                }
                "crash-rank" => plan.crash_rank = Some(pair("crash-rank", value)?),
                "eigh-fail" => plan.eigh_fail = Some(pair("eigh-fail", value)?),
                "nan-grad" => plan.nan_grad = Some(pair("nan-grad", value)?),
                "inf-grad" => plan.inf_grad = Some(pair("inf-grad", value)?),
                other => anyhow::bail!(
                    "unknown fault-plan clause '{other}': expected seed, drop-frame, \
                     delay-frame, dup-frame, crash-rank, eigh-fail, nan-grad, inf-grad"
                ),
            }
        }
        Ok(plan)
    }

    /// Disarm the one-shot clauses (crash/eigh/NaN/Inf) — called when a run
    /// is an `--auto-resume` relaunch so the same fault doesn't re-fire on
    /// every attempt. Probabilistic frame faults stay armed.
    pub fn disarm_one_shot(&mut self) {
        self.crash_rank = None;
        self.eigh_fail = None;
        self.nan_grad = None;
        self.inf_grad = None;
    }

    /// Any probabilistic frame clause present?
    pub fn has_frame_faults(&self) -> bool {
        self.drop_frame > 0.0 || self.dup_frame > 0.0 || self.delay_frame.is_some()
    }
}

/// The armed, per-process form of a [`FaultPlan`]: the plan plus this
/// process's rank, a lock-free RNG, and once-only latches for the one-shot
/// clauses.
pub struct FaultState {
    plan: FaultPlan,
    rank: usize,
    rng: AtomicU64,
    crash_fired: AtomicBool,
    eigh_fired: AtomicBool,
    grad_fired: AtomicBool,
}

/// SplitMix64 output mix — full-period, passes through zero seeds.
fn splitmix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

fn unit(z: u64) -> f64 {
    (z >> 11) as f64 / (1u64 << 53) as f64
}

impl FaultState {
    pub fn new(plan: FaultPlan, rank: usize) -> Self {
        let seed = splitmix(plan.seed ^ (rank as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15));
        Self {
            plan,
            rank,
            rng: AtomicU64::new(seed | 1),
            crash_fired: AtomicBool::new(false),
            eigh_fired: AtomicBool::new(false),
            grad_fired: AtomicBool::new(false),
        }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// One uniform draw in `[0, 1)` (xorshift64*, advanced with a CAS so
    /// concurrent seams share one deterministic-per-interleaving stream).
    fn draw(&self) -> f64 {
        let mut x = self.rng.load(Ordering::Relaxed);
        loop {
            let mut y = x;
            y ^= y >> 12;
            y ^= y << 25;
            y ^= y >> 27;
            match self.rng.compare_exchange_weak(x, y, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => return unit(y.wrapping_mul(0x2545_f491_4f6c_dd1d)),
                Err(cur) => x = cur,
            }
        }
    }

    /// Should this frame-send attempt be dropped (injected transient loss)?
    pub fn drop_frame(&self) -> bool {
        self.plan.drop_frame > 0.0 && self.draw() < self.plan.drop_frame
    }

    /// Should this frame be retransmitted after the real send?
    pub fn dup_frame(&self) -> bool {
        self.plan.dup_frame > 0.0 && self.draw() < self.plan.dup_frame
    }

    /// Delay to apply before this frame send, if the delay clause fires.
    pub fn delay_frame(&self) -> Option<Duration> {
        let (p, ms) = self.plan.delay_frame?;
        (self.draw() < p).then(|| Duration::from_millis(ms))
    }

    /// Should this rank crash at step `t`? Fires at most once per process.
    pub fn should_crash(&self, t: u64) -> bool {
        match self.plan.crash_rank {
            Some((r, step)) if r == self.rank && step == t => {
                !self.crash_fired.swap(true, Ordering::Relaxed)
            }
            _ => false,
        }
    }

    /// Poison value for layer `layer`'s gradient at step `t`, if the NaN/Inf
    /// clause targets it. Fires at most once per process.
    pub fn grad_poison(&self, layer: usize, t: u64) -> Option<f32> {
        let (value, hit) = match (self.plan.nan_grad, self.plan.inf_grad) {
            (Some((l, s)), _) if l == layer && s == t => (f32::NAN, true),
            (_, Some((l, s))) if l == layer && s == t => (f32::INFINITY, true),
            _ => (0.0, false),
        };
        (hit && !self.grad_fired.swap(true, Ordering::Relaxed)).then_some(value)
    }

    /// Should basis `basis_id`'s decomposition at step `t` be poisoned?
    /// Fires at most once per process.
    pub fn eigh_poison(&self, basis_id: u64, t: u64) -> bool {
        match self.plan.eigh_fail {
            Some((b, step)) if b == basis_id && step == t => {
                !self.eigh_fired.swap(true, Ordering::Relaxed)
            }
            _ => false,
        }
    }
}

// ---- process-wide installation -------------------------------------------

/// The armed fault state, or null when no plan is active. An `AtomicPtr`
/// (not a `OnceLock`) because `--auto-resume` re-installs per attempt in the
/// same coordinator process; replaced states are leaked, like telemetry
/// instruments — they are tiny and installs are per-run.
static ACTIVE: AtomicPtr<FaultState> = AtomicPtr::new(std::ptr::null_mut());

/// The active fault state, if a plan is installed. One atomic load — this is
/// the zero-cost seam every injection site gates on.
#[inline]
pub fn active() -> Option<&'static FaultState> {
    let p = ACTIVE.load(Ordering::Acquire);
    if p.is_null() {
        None
    } else {
        // Installed states are intentionally leaked, so the reference is
        // 'static for the life of the process.
        Some(unsafe { &*p })
    }
}

/// Arm a fault plan process-wide for this rank (replacing any previous one).
pub fn install(plan: FaultPlan, rank: usize) {
    let state = Box::into_raw(Box::new(FaultState::new(plan, rank)));
    ACTIVE.store(state, Ordering::Release);
}

/// Disarm fault injection (runs without `--fault-plan` call this so a prior
/// in-process session's plan cannot leak into a fresh run).
pub fn clear() {
    ACTIVE.store(std::ptr::null_mut(), Ordering::Release);
}

// ---- guard-abort latch ---------------------------------------------------

/// Set by a `GuardPolicy::Abort` trip inside the per-layer update path
/// (which cannot return an error itself); the session checks and clears it
/// after each step and surfaces a typed error.
static GUARD_ABORT: AtomicBool = AtomicBool::new(false);

pub fn flag_guard_abort() {
    GUARD_ABORT.store(true, Ordering::Relaxed);
}

pub fn take_guard_abort() -> bool {
    GUARD_ABORT.swap(false, Ordering::Relaxed)
}

// ---- backoff -------------------------------------------------------------

/// Exponential backoff with deterministic multiplicative jitter:
/// `min(cap, base · 2^attempt · (1 + j))` with `j ∈ [0, 0.5]` drawn from
/// `(seed, attempt)`. Bounded by `cap` and monotone nondecreasing in
/// `attempt` (`2^(n+1) · 1 ≥ 2^n · 1.5`), property-tested in
/// `rust/tests/chaos.rs`.
pub fn backoff_delay(attempt: u32, base: Duration, cap: Duration, seed: u64) -> Duration {
    let jitter = 0.5 * unit(splitmix(seed ^ u64::from(attempt)));
    // 2^attempt saturates long before the cap stops mattering.
    let exp = base.as_secs_f64() * 2f64.powi(attempt.min(62) as i32) * (1.0 + jitter);
    if !exp.is_finite() || exp >= cap.as_secs_f64() {
        cap
    } else {
        Duration::from_secs_f64(exp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_parse_full_grammar() {
        let p = FaultPlan::parse(
            "seed=7; drop-frame=0.2; delay-frame=0.1:25; dup-frame=0.05; \
             crash-rank=1:6; eigh-fail=0:10; nan-grad=2:5; inf-grad=3:9",
        )
        .unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.drop_frame, 0.2);
        assert_eq!(p.delay_frame, Some((0.1, 25)));
        assert_eq!(p.dup_frame, 0.05);
        assert_eq!(p.crash_rank, Some((1, 6)));
        assert_eq!(p.eigh_fail, Some((0, 10)));
        assert_eq!(p.nan_grad, Some((2, 5)));
        assert_eq!(p.inf_grad, Some((3, 9)));
        assert!(p.has_frame_faults());
    }

    #[test]
    fn plan_parse_rejects_bad_input() {
        assert!(FaultPlan::parse("drop-frame=0.95").is_err(), "p > 0.9 must be rejected");
        assert!(FaultPlan::parse("drop-frame=-0.1").is_err());
        assert!(FaultPlan::parse("bogus=1").is_err());
        assert!(FaultPlan::parse("crash-rank=1").is_err(), "missing :step");
        assert!(FaultPlan::parse("no-equals").is_err());
        assert_eq!(FaultPlan::parse("").unwrap(), FaultPlan::default());
        assert_eq!(FaultPlan::parse(" ; ").unwrap(), FaultPlan::default());
    }

    #[test]
    fn disarm_clears_one_shot_only() {
        let mut p = FaultPlan::parse("drop-frame=0.2;crash-rank=1:6;nan-grad=0:3").unwrap();
        p.disarm_one_shot();
        assert_eq!(p.crash_rank, None);
        assert_eq!(p.nan_grad, None);
        assert_eq!(p.drop_frame, 0.2, "probabilistic clauses persist across attempts");
    }

    #[test]
    fn one_shot_latches_fire_once() {
        let s = FaultState::new(
            FaultPlan::parse("crash-rank=0:6;nan-grad=1:4;eigh-fail=2:10").unwrap(),
            0,
        );
        assert!(!s.should_crash(5));
        assert!(s.should_crash(6));
        assert!(!s.should_crash(6), "crash clause must fire once");
        assert!(s.grad_poison(0, 4).is_none(), "wrong layer");
        let v = s.grad_poison(1, 4).unwrap();
        assert!(v.is_nan());
        assert!(s.grad_poison(1, 4).is_none(), "grad clause must fire once");
        assert!(!s.eigh_poison(2, 9));
        assert!(s.eigh_poison(2, 10));
        assert!(!s.eigh_poison(2, 10));
    }

    #[test]
    fn wrong_rank_never_crashes() {
        let s = FaultState::new(FaultPlan::parse("crash-rank=1:6").unwrap(), 0);
        assert!(!s.should_crash(6));
    }

    #[test]
    fn draws_are_deterministic_per_seed_and_rank() {
        let plan = FaultPlan::parse("seed=3;drop-frame=0.5").unwrap();
        let a = FaultState::new(plan.clone(), 0);
        let b = FaultState::new(plan.clone(), 0);
        let seq_a: Vec<bool> = (0..64).map(|_| a.drop_frame()).collect();
        let seq_b: Vec<bool> = (0..64).map(|_| b.drop_frame()).collect();
        assert_eq!(seq_a, seq_b, "same seed+rank must draw the same stream");
        let c = FaultState::new(plan, 1);
        let seq_c: Vec<bool> = (0..64).map(|_| c.drop_frame()).collect();
        assert_ne!(seq_a, seq_c, "ranks must draw independent streams");
    }

    #[test]
    fn install_clear_roundtrip() {
        clear();
        assert!(active().is_none());
        install(FaultPlan::parse("drop-frame=0.1").unwrap(), 0);
        assert_eq!(active().unwrap().plan().drop_frame, 0.1);
        clear();
        assert!(active().is_none());
    }

    #[test]
    fn guard_abort_latch() {
        assert!(!take_guard_abort());
        flag_guard_abort();
        assert!(take_guard_abort());
        assert!(!take_guard_abort(), "take must clear the latch");
    }
}
