//! Experiment analysis — the paper's evaluation methodology as code:
//! scaling-law fits (§5), efficiency benefits (Fig 2), and the
//! critical-batch-size analysis (Fig 4).

pub mod efficiency;
pub mod harness;
pub mod scaling;

pub use efficiency::{
    batch_scaling_analysis, efficiency_benefit, Baseline, BatchScalingPoint, EfficiencyBenefit,
};
pub use scaling::{fit_scaling_law, ScalingLaw};
