//! Efficiency-benefit computation (paper Fig 2): given a fitted SOAP
//! scaling law and a baseline's (steps, final loss, seconds/step), report
//! the % reduction in iterations and in wall-clock time for SOAP to reach
//! the baseline's loss.

use super::scaling::ScalingLaw;

#[derive(Clone, Debug)]
pub struct Baseline {
    pub name: String,
    pub steps: f64,
    pub final_loss: f64,
    /// Mean seconds per training step (fwd+bwd+optimizer).
    pub secs_per_step: f64,
}

#[derive(Clone, Debug)]
pub struct EfficiencyBenefit {
    pub baseline: String,
    /// Steps SOAP needs to match the baseline loss (from the scaling law).
    pub soap_steps: f64,
    /// 1 − soap_steps/baseline_steps (paper's "% reduction in iterations").
    pub iter_reduction: f64,
    /// 1 − soap_time/baseline_time.
    pub wallclock_reduction: f64,
}

/// Compute the Fig 2 numbers for one baseline.
pub fn efficiency_benefit(
    soap_law: &ScalingLaw,
    soap_secs_per_step: f64,
    baseline: &Baseline,
) -> Option<EfficiencyBenefit> {
    let soap_steps = soap_law.steps_to(baseline.final_loss)?;
    let iter_reduction = 1.0 - soap_steps / baseline.steps;
    let soap_time = soap_steps * soap_secs_per_step;
    let baseline_time = baseline.steps * baseline.secs_per_step;
    let wallclock_reduction = 1.0 - soap_time / baseline_time;
    Some(EfficiencyBenefit {
        baseline: baseline.name.clone(),
        soap_steps,
        iter_reduction,
        wallclock_reduction,
    })
}

/// Critical-batch-size analysis (paper Fig 4 left): per batch size, the
/// measured steps-to-target and the deviation from perfect linear scaling
/// anchored at the smallest batch.
#[derive(Clone, Debug)]
pub struct BatchScalingPoint {
    pub batch: f64,
    pub steps_to_target: f64,
    /// steps-to-target under ideal linear scaling from the smallest batch.
    pub ideal_steps: f64,
    /// measured / ideal  (1.0 = perfect scaling; larger = past the critical
    /// batch size).
    pub scaling_inefficiency: f64,
}

pub fn batch_scaling_analysis(points: &[(f64, f64)]) -> Vec<BatchScalingPoint> {
    if points.is_empty() {
        return Vec::new();
    }
    let mut pts = points.to_vec();
    pts.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let (b0, s0) = pts[0];
    pts.iter()
        .map(|&(b, s)| {
            let ideal = s0 * b0 / b;
            BatchScalingPoint {
                batch: b,
                steps_to_target: s,
                ideal_steps: ideal,
                scaling_inefficiency: s / ideal,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::scaling::fit_scaling_law;

    #[test]
    fn forty_percent_reduction_example() {
        // SOAP law reaching the baseline loss at 600 steps vs AdamW's 1000.
        let pts: Vec<(f64, f64)> = [300.0, 450.0, 600.0, 900.0]
            .iter()
            .map(|&n: &f64| (n, 2.0 + 30.0 * n.powf(-0.7)))
            .collect();
        let law = fit_scaling_law(&pts).unwrap();
        let adamw = Baseline {
            name: "adamw".into(),
            steps: 1000.0,
            final_loss: 2.0 + 30.0 * 600f64.powf(-0.7),
            secs_per_step: 1.0,
        };
        let e = efficiency_benefit(&law, 1.1, &adamw).unwrap();
        assert!((e.soap_steps - 600.0).abs() < 20.0, "{}", e.soap_steps);
        assert!((e.iter_reduction - 0.4).abs() < 0.03);
        // With 10% slower steps: time reduction = 1 − 600·1.1/1000 = 0.34.
        assert!((e.wallclock_reduction - 0.34).abs() < 0.03);
    }

    #[test]
    fn unreachable_baseline_none() {
        let pts: Vec<(f64, f64)> = [300.0, 600.0, 1200.0]
            .iter()
            .map(|&n: &f64| (n, 2.0 + 30.0 * n.powf(-0.7)))
            .collect();
        let law = fit_scaling_law(&pts).unwrap();
        let b = Baseline { name: "x".into(), steps: 100.0, final_loss: 1.0, secs_per_step: 1.0 };
        assert!(efficiency_benefit(&law, 1.0, &b).is_none());
    }

    #[test]
    fn batch_scaling_detects_critical_batch() {
        // Perfect scaling up to batch 4, then saturation.
        let pts = [(1.0, 1000.0), (2.0, 500.0), (4.0, 250.0), (8.0, 200.0)];
        let out = batch_scaling_analysis(&pts);
        assert!((out[0].scaling_inefficiency - 1.0).abs() < 1e-9);
        assert!((out[2].scaling_inefficiency - 1.0).abs() < 1e-9);
        assert!(out[3].scaling_inefficiency > 1.5);
    }
}
