//! Shared harness for the figure-regeneration benches: maps the paper's
//! per-optimizer tuned defaults onto the [`crate::session`] builder, runs
//! the session, and returns [`TrainLog`]s. Keeps each `benches/fig*.rs`
//! thin and consistent — every figure bench rides the same construction
//! path as `main.rs`.

use crate::coordinator::TrainLog;
use crate::optim::{Hyper, OptKind, Schedule};
use crate::session::{ModelSpec, SessionBuilder, TrainSession};

/// Tuned peak LRs on the scaled testbed (selected by an Appendix-A-style
/// sweep over {.1, .0316, …, 3.16e-4} on the nano config; see
/// EXPERIMENTS.md §Tuning). Second-order methods tolerate ~1 grid step
/// larger LR than AdamW, matching the paper's observation.
pub fn tuned_lr(opt: OptKind) -> f32 {
    match opt {
        OptKind::AdamW => 3.16e-3,
        OptKind::Adafactor => 3.16e-3,
        OptKind::Shampoo => 1e-2,
        OptKind::Soap => 1e-2,
        OptKind::Galore => 3.16e-3,
        // Composition specs inherit their canonical preset's tuning; novel
        // combos start from the conservative AdamW grid point.
        OptKind::Composed(spec) => match spec.canonical() {
            Some(kind) => tuned_lr(kind),
            None => 3.16e-3,
        },
    }
}

/// Benchmark scale knobs (env-overridable so CI can shrink them):
/// `SOAP_BENCH_STEPS`, `SOAP_BENCH_MODEL`.
pub fn bench_steps(default: u64) -> u64 {
    std::env::var("SOAP_BENCH_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

pub fn bench_model() -> String {
    std::env::var("SOAP_BENCH_MODEL").unwrap_or_else(|_| "nano".to_string())
}

/// Paper-shaped schedule: 20% warmup, cosine to 0.1×.
pub fn paper_schedule(lr: f32, steps: u64) -> Schedule {
    Schedule::paper(lr, (steps / 5).max(1), steps)
}

#[derive(Clone)]
pub struct RunSpec {
    pub model: String,
    pub opt: OptKind,
    pub steps: u64,
    pub lr: Option<f32>,
    pub hyper: Hyper,
    pub seed: u64,
    pub grad_accum: usize,
    pub constant_lr: bool,
}

impl RunSpec {
    pub fn new(model: &str, opt: OptKind, steps: u64) -> Self {
        Self {
            model: model.to_string(),
            opt,
            steps,
            lr: None,
            hyper: Hyper::default(),
            seed: 0,
            grad_accum: 1,
            constant_lr: false,
        }
    }

    pub fn with_freq(mut self, f: u64) -> Self {
        self.hyper.precond_freq = f;
        self
    }

    pub fn with_hyper(mut self, h: Hyper) -> Self {
        self.hyper = h;
        self
    }

    pub fn with_lr(mut self, lr: f32) -> Self {
        self.lr = Some(lr);
        self
    }

    pub fn with_accum(mut self, k: usize) -> Self {
        self.grad_accum = k;
        self
    }

    /// Map onto the session builder — the same construction path `main.rs`
    /// uses, so a bench run and a CLI run of the same spec are identical.
    /// Model names resolve like the CLI's `--model`: `nplm*` picks the
    /// native presets (`SOAP_BENCH_MODEL=nplm` runs figure benches
    /// artifact-free), anything else is an artifact manifest config.
    /// Errors on `nplm`-prefixed typos, same as the CLI.
    pub fn session(&self) -> anyhow::Result<SessionBuilder> {
        let lr = self.lr.unwrap_or_else(|| tuned_lr(self.opt));
        Ok(TrainSession::builder()
            .model(ModelSpec::parse(&self.model)?)
            .optimizer(self.opt)
            .hyper(self.hyper.clone())
            .schedule(if self.constant_lr {
                Schedule::Constant { lr }
            } else {
                paper_schedule(lr, self.steps)
            })
            .steps(self.steps)
            .seed(self.seed)
            .grad_accum(self.grad_accum)
            .workers(4))
    }

    /// Build the session without running it — state/scratch accounting
    /// probes (e.g. the Fig 6 memory table) use this.
    pub fn build_session(&self) -> anyhow::Result<TrainSession> {
        self.session()?.build()
    }

    /// Build and run the session. Returns the training log plus mean
    /// seconds/step.
    pub fn run(&self) -> anyhow::Result<(TrainLog, f64)> {
        let mut session = self.build_session()?;
        let log = session.run()?;
        let secs = log.total_seconds() / log.timings.len().max(1) as f64;
        Ok((log, secs))
    }
}

/// Skip helper: figure benches need artifacts; print a pointer instead of
/// failing when they are missing (e.g. fresh checkout).
pub fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tuned_lrs_cover_all_kinds() {
        for k in [OptKind::AdamW, OptKind::Adafactor, OptKind::Shampoo, OptKind::Soap, OptKind::Galore] {
            assert!(tuned_lr(k) > 0.0);
        }
        let canonical = OptKind::parse("basis=eigen,inner=adam").unwrap();
        assert_eq!(tuned_lr(canonical), tuned_lr(OptKind::Soap));
        let novel = OptKind::parse("basis=svd,inner=adafactor").unwrap();
        assert!(tuned_lr(novel) > 0.0);
    }

    #[test]
    fn spec_builders() {
        let s = RunSpec::new("nano", OptKind::Soap, 100).with_freq(32).with_lr(0.01);
        assert_eq!(s.hyper.precond_freq, 32);
        assert_eq!(s.steps, 100);
        // The builder mapping is structurally valid without artifacts on
        // disk (engine load happens at build()).
        s.session().unwrap().validate().unwrap();
        // nplm-prefixed typos surface parse's clear error, as on the CLI.
        let bad = RunSpec::new("nplm-huge", OptKind::Soap, 10);
        assert!(bad.session().is_err());
    }

    #[test]
    fn env_step_override() {
        std::env::remove_var("SOAP_BENCH_STEPS");
        assert_eq!(bench_steps(123), 123);
    }
}
