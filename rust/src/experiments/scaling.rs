//! Scaling-law fitting — the paper's §5 "Efficiency Benefits" methodology:
//! run SOAP on {.5, .625, .75, .875, 1.0} fractions of the budget, fit
//! `loss(N) = a + b·N^(−β)` through the final losses, then read off the
//! step count at which SOAP matches a baseline's final loss.
//!
//! Fit: for fixed β the model is linear in (a, b) — closed-form least
//! squares; β is found by golden-section search on the SSE profile.

/// Fitted scaling law `a + b·N^(−β)`.
#[derive(Clone, Copy, Debug)]
pub struct ScalingLaw {
    pub a: f64,
    pub b: f64,
    pub beta: f64,
    pub sse: f64,
}

impl ScalingLaw {
    pub fn predict(&self, n: f64) -> f64 {
        self.a + self.b * n.powf(-self.beta)
    }

    /// Steps needed to reach `target` loss (None if unreachable: target ≤ a).
    pub fn steps_to(&self, target: f64) -> Option<f64> {
        if target <= self.a || self.b <= 0.0 {
            return None;
        }
        Some(((target - self.a) / self.b).powf(-1.0 / self.beta))
    }
}

/// Closed-form (a, b) and SSE for fixed β.
fn solve_ab(ns: &[f64], ls: &[f64], beta: f64) -> (f64, f64, f64) {
    let k = ns.len() as f64;
    let xs: Vec<f64> = ns.iter().map(|&n| n.powf(-beta)).collect();
    let mx = xs.iter().sum::<f64>() / k;
    let my = ls.iter().sum::<f64>() / k;
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    for (&x, &y) in xs.iter().zip(ls) {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
    }
    if sxx < 1e-300 {
        return (my, 0.0, f64::INFINITY);
    }
    let b = sxy / sxx;
    let a = my - b * mx;
    let sse: f64 = xs
        .iter()
        .zip(ls)
        .map(|(&x, &y)| {
            let e = y - (a + b * x);
            e * e
        })
        .sum();
    (a, b, sse)
}

/// Fit `a + b·N^(−β)` to (steps, final-loss) points.
///
/// Requires ≥3 points. β is restricted to (0.01, 3.0) — outside that range
/// the law degenerates at our scales.
pub fn fit_scaling_law(points: &[(f64, f64)]) -> anyhow::Result<ScalingLaw> {
    anyhow::ensure!(points.len() >= 3, "need ≥3 points for a 3-parameter fit");
    let ns: Vec<f64> = points.iter().map(|&(n, _)| n).collect();
    let ls: Vec<f64> = points.iter().map(|&(_, l)| l).collect();
    anyhow::ensure!(ns.iter().all(|&n| n > 0.0), "step counts must be positive");

    // Coarse grid, then golden-section refinement around the best cell.
    let mut best = (0.5, f64::INFINITY);
    let grid: Vec<f64> = (1..=300).map(|i| i as f64 * 0.01).collect();
    for &beta in &grid {
        let (_, b, sse) = solve_ab(&ns, &ls, beta);
        // Reject fits with b ≤ 0 (loss increasing with steps — unphysical).
        if b > 0.0 && sse < best.1 {
            best = (beta, sse);
        }
    }
    anyhow::ensure!(best.1.is_finite(), "no physical fit found");

    let (mut lo, mut hi) = ((best.0 - 0.02).max(1e-3), best.0 + 0.02);
    let phi = 0.618_033_988_75;
    for _ in 0..60 {
        let m1 = hi - phi * (hi - lo);
        let m2 = lo + phi * (hi - lo);
        let s1 = solve_ab(&ns, &ls, m1).2;
        let s2 = solve_ab(&ns, &ls, m2).2;
        if s1 < s2 {
            hi = m2;
        } else {
            lo = m1;
        }
    }
    let beta = 0.5 * (lo + hi);
    let (a, b, sse) = solve_ab(&ns, &ls, beta);
    Ok(ScalingLaw { a, b, beta, sse })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovers_exact_law() {
        let truth = ScalingLaw { a: 2.5, b: 30.0, beta: 0.6, sse: 0.0 };
        let pts: Vec<(f64, f64)> = [200.0, 400.0, 800.0, 1600.0, 3200.0]
            .iter()
            .map(|&n| (n, truth.predict(n)))
            .collect();
        let fit = fit_scaling_law(&pts).unwrap();
        assert!((fit.a - 2.5).abs() < 1e-3, "a = {}", fit.a);
        assert!((fit.beta - 0.6).abs() < 1e-2, "beta = {}", fit.beta);
        assert!(fit.sse < 1e-6);
    }

    #[test]
    fn steps_to_inverts_predict() {
        let law = ScalingLaw { a: 2.0, b: 20.0, beta: 0.5, sse: 0.0 };
        let n = 700.0;
        let target = law.predict(n);
        let back = law.steps_to(target).unwrap();
        assert!((back - n).abs() / n < 1e-9);
    }

    #[test]
    fn unreachable_target_is_none() {
        let law = ScalingLaw { a: 2.0, b: 20.0, beta: 0.5, sse: 0.0 };
        assert!(law.steps_to(1.9).is_none());
        assert!(law.steps_to(2.0).is_none());
    }

    #[test]
    fn tolerates_noise() {
        let truth = ScalingLaw { a: 3.0, b: 15.0, beta: 0.45, sse: 0.0 };
        let noise = [0.004, -0.006, 0.002, -0.003, 0.005];
        let pts: Vec<(f64, f64)> = [500.0, 750.0, 1000.0, 1500.0, 2000.0]
            .iter()
            .zip(&noise)
            .map(|(&n, &e)| (n, truth.predict(n) + e))
            .collect();
        let fit = fit_scaling_law(&pts).unwrap();
        assert!((fit.a - 3.0).abs() < 0.15, "a = {}", fit.a);
        // Interpolation quality matters more than parameter identity.
        for &(n, l) in &pts {
            assert!((fit.predict(n) - l).abs() < 0.02);
        }
    }

    #[test]
    fn too_few_points_errors() {
        assert!(fit_scaling_law(&[(1.0, 1.0), (2.0, 0.9)]).is_err());
    }
}
