//! Versioned, double-buffered publication point for a layer's refreshed
//! preconditioner artifacts.
//!
//! One [`BasisHandle`] pairs one optimizer layer (the consumer, on a shard
//! worker thread) with the refresh service (the producer, on the background
//! pool). The producer publishes a complete [`BasisPayload`] behind a single
//! `Arc` swap, so a consumer can never observe a torn (half-updated) basis:
//! it either sees the previous complete pair or the new complete pair. A
//! monotonic version counter lets the consumer's hot path detect "nothing
//! new" with one atomic load — no lock, no allocation — on the overwhelming
//! majority of steps.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::linalg::Matrix;

/// The product of one background refresh. Field meaning is owner-defined:
/// SOAP publishes `left`/`right` = `Q_L`/`Q_R`; Shampoo publishes
/// `left`/`right` = `L^{-1/e}`/`R^{-1/e}` with the warm-start eigenvector
/// caches in the `*_aux` slots. `None` slots mean "that side is identity /
/// not preconditioned" and must be left untouched by the consumer.
#[derive(Clone, Debug, Default)]
pub struct BasisPayload {
    pub left: Option<Matrix>,
    pub right: Option<Matrix>,
    pub left_aux: Option<Matrix>,
    pub right_aux: Option<Matrix>,
}

impl BasisPayload {
    /// Every present factor fully finite? A NaN/Inf decomposition result
    /// must never publish — consumers keep stepping on the previous basis
    /// (stale-basis grace) and the rejection is counted instead.
    pub fn is_finite(&self) -> bool {
        [&self.left, &self.right, &self.left_aux, &self.right_aux]
            .into_iter()
            .flatten()
            .all(|m| m.data.iter().all(|x| x.is_finite()))
    }
}

/// A published payload plus its provenance.
#[derive(Clone, Debug)]
pub struct PublishedBasis {
    pub payload: BasisPayload,
    /// Step whose factor EMAs were snapshotted to compute this payload — the
    /// consumer's staleness metric is `current_step - snapshot_step`.
    pub snapshot_step: u64,
    /// Monotonic publication counter (first publish = 1).
    pub version: u64,
}

/// Producer/consumer mailbox for one layer's refreshed basis.
#[derive(Debug, Default)]
pub struct BasisHandle {
    /// Latest complete publication. The `Arc` is the double buffer: a reader
    /// that cloned it keeps the old payload alive while the writer installs
    /// the new one.
    slot: Mutex<Option<Arc<PublishedBasis>>>,
    /// Version of the newest publication (0 = none yet). Written with
    /// `Release` after the slot, read with `Acquire`, so `version() >
    /// adopted` guarantees `latest()` sees at least that publication.
    version: AtomicU64,
    /// Refresh-in-flight gate: the consumer only enqueues a new snapshot once
    /// the previous one has published (or aborted), bounding the service
    /// queue at one job per layer.
    in_flight: AtomicBool,
    /// Latched when a background refresh for this handle panicked; the
    /// consumer takes it at its next refresh step and falls back to an
    /// inline refresh instead of re-enqueueing onto a pool that just blew
    /// up under this layer's data.
    worker_panicked: AtomicBool,
}

/// A distributed executor's grip on one refreshable basis (one per active
/// mode): the publication mailbox plus the adoption cap the executor raises
/// once a publication has been broadcast to (or received from) every peer.
/// Ports are handed out by `attach_dist` in a deterministic per-layer order,
/// which is what makes `(layer_idx, port_idx)` a valid wire address.
#[derive(Clone, Debug)]
pub struct DistBasisPort {
    pub handle: Arc<BasisHandle>,
    pub adopt_cap: Arc<AtomicU64>,
}

impl DistBasisPort {
    /// Allow adoption of every publication up to and including `version`.
    pub fn raise_cap(&self, version: u64) {
        self.adopt_cap.fetch_max(version, Ordering::AcqRel);
    }
}

impl BasisHandle {
    pub fn new() -> Self {
        Self::default()
    }

    /// Newest published version (0 when nothing has been published).
    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    /// Latest complete publication, if any.
    pub fn latest(&self) -> Option<Arc<PublishedBasis>> {
        self.slot.lock().unwrap().clone()
    }

    /// Producer side: install a complete payload and bump the version.
    /// Returns the new version. Also clears the in-flight gate.
    pub fn publish(&self, payload: BasisPayload, snapshot_step: u64) -> u64 {
        let mut slot = self.slot.lock().unwrap();
        let version = self.version.load(Ordering::Relaxed) + 1;
        *slot = Some(Arc::new(PublishedBasis { payload, snapshot_step, version }));
        drop(slot);
        self.version.store(version, Ordering::Release);
        self.in_flight.store(false, Ordering::Release);
        version
    }

    /// Consumer side: claim the right to enqueue a refresh. Returns `false`
    /// while a previous refresh is still in flight.
    pub fn try_begin_refresh(&self) -> bool {
        !self.in_flight.swap(true, Ordering::AcqRel)
    }

    /// Producer side: release the gate without publishing (compute panicked
    /// or was skipped), so the consumer can retry at its next refresh step.
    pub fn abort_refresh(&self) {
        self.in_flight.store(false, Ordering::Release);
    }

    pub fn refresh_in_flight(&self) -> bool {
        self.in_flight.load(Ordering::Acquire)
    }

    /// Producer side: record that the background compute panicked (called
    /// alongside [`Self::abort_refresh`]).
    pub fn note_worker_panic(&self) {
        self.worker_panicked.store(true, Ordering::Release);
    }

    /// Consumer side: did the last background refresh panic? Clears the
    /// latch — the caller is expected to run its fallback (inline refresh)
    /// exactly once per failure.
    pub fn take_worker_panic(&self) -> bool {
        self.worker_panicked.swap(false, Ordering::AcqRel)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn payload(k: f32, n: usize) -> BasisPayload {
        BasisPayload {
            left: Some(Matrix::eye(n).scale(k)),
            right: Some(Matrix::eye(2 * n).scale(k)),
            left_aux: None,
            right_aux: None,
        }
    }

    #[test]
    fn versions_are_monotonic_and_latest_wins() {
        let h = BasisHandle::new();
        assert_eq!(h.version(), 0);
        assert!(h.latest().is_none());
        assert_eq!(h.publish(payload(1.0, 3), 10), 1);
        assert_eq!(h.publish(payload(2.0, 3), 20), 2);
        let latest = h.latest().unwrap();
        assert_eq!(latest.version, 2);
        assert_eq!(latest.snapshot_step, 20);
        assert_eq!(latest.payload.left.as_ref().unwrap().at(0, 0), 2.0);
    }

    #[test]
    fn in_flight_gate_is_exclusive_until_publish() {
        let h = BasisHandle::new();
        assert!(h.try_begin_refresh());
        assert!(!h.try_begin_refresh(), "second enqueue while in flight");
        h.publish(payload(1.0, 2), 1);
        assert!(h.try_begin_refresh(), "publish must release the gate");
        h.abort_refresh();
        assert!(h.try_begin_refresh(), "abort must release the gate");
    }

    #[test]
    fn concurrent_publish_never_tears_the_pair() {
        // Writer publishes matched (left, right) pairs scaled by the same k;
        // a reader hammering `latest()` must only ever observe matched pairs
        // — the Arc swap makes a half-updated basis unrepresentable.
        let h = Arc::new(BasisHandle::new());
        let writer = {
            let h = Arc::clone(&h);
            std::thread::spawn(move || {
                for k in 1..=200 {
                    h.publish(payload(k as f32, 4), k as u64);
                }
            })
        };
        let reader = {
            let h = Arc::clone(&h);
            std::thread::spawn(move || {
                let mut seen = 0u64;
                while seen < 200 {
                    if let Some(p) = h.latest() {
                        let l = p.payload.left.as_ref().unwrap().at(0, 0);
                        let r = p.payload.right.as_ref().unwrap().at(0, 0);
                        assert_eq!(l, r, "torn basis observed at version {}", p.version);
                        assert_eq!(l as u64, p.snapshot_step, "payload/step mismatch");
                        seen = seen.max(p.version);
                    }
                }
            })
        };
        writer.join().unwrap();
        reader.join().unwrap();
    }
}
