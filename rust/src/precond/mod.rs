//! Asynchronous preconditioner refresh — SOAP's periodic eigenbasis updates
//! (and Shampoo's inverse-root recomputes) taken off the training hot path.
//!
//! SOAP's entire wall-clock overhead over Adam is the periodic refresh
//! (paper §7.3, Fig 7): every step `t ≡ φ (mod f)` the inline implementation
//! stalls on a power-iteration + QR (or warm `eigh`). But SOAP is *designed*
//! to tolerate a stale basis — the Adam second moment keeps adapting every
//! step in the slowly rotating eigenbasis (§1), and "Purifying Shampoo"
//! (Eschenhagen et al., 2025) shows the basis tolerates substantial delay
//! when the second moment stays fresh. Distributed Shampoo deployments
//! (Gupta et al., 2018) exploit exactly this by computing decompositions on
//! dedicated workers. This module is that architecture for soap-lab:
//!
//! - [`BasisHandle`] — a versioned, double-buffered publication slot. The
//!   producer swaps in a complete [`BasisPayload`] behind one `Arc`; the
//!   consumer detects news with a single atomic load and can never observe
//!   a torn (half-updated) basis.
//! - [`RefreshService`] — a dedicated [`crate::util::pool::ThreadPool`] that
//!   runs snapshot → decompose → publish, with latency/panic accounting.
//!
//! Mode selection lives in [`crate::optim::Hyper::refresh_mode`]
//! ([`RefreshMode::Inline`] runs the same synchronous math as before and is
//! fully deterministic — same seed ⇒ same trajectory at any worker count;
//! [`RefreshMode::Async`] enqueues to the service), and the coordinator
//! staggers per-layer refresh phases (`layer_idx % f`, both modes) so layers
//! don't all refresh or enqueue on the same step — note this *does* shift
//! refresh steps relative to the pre-stagger all-at-once schedule. Staleness
//! (steps since the active basis' factors were snapshotted) is reported
//! through `StepTiming::staleness_steps`.

pub mod handle;
pub mod service;

pub use handle::{BasisHandle, BasisPayload, DistBasisPort, PublishedBasis};
pub use service::{RefreshService, RefreshStats};

/// How a layer's periodic preconditioner recompute is executed.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RefreshMode {
    /// Recompute synchronously inside `LayerOptimizer::update` — fully
    /// deterministic trajectories (same seed ⇒ same weights, bitwise, at any
    /// worker count), at each layer's staggered refresh phase.
    #[default]
    Inline,
    /// Snapshot the factors and hand the recompute to the background
    /// [`RefreshService`]; adopt the published result at a later step. The
    /// hot path never blocks on linear algebra.
    Async,
}

impl RefreshMode {
    pub fn name(&self) -> &'static str {
        match self {
            RefreshMode::Inline => "inline",
            RefreshMode::Async => "async",
        }
    }

    /// Parse a CLI/config token. Errors enumerate the valid values.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "inline" | "sync" => RefreshMode::Inline,
            "async" | "background" => RefreshMode::Async,
            other => anyhow::bail!(
                "unknown refresh mode '{other}': expected inline (alias sync) or async \
                 (alias background)"
            ),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_defaults_inline() {
        assert_eq!(RefreshMode::default(), RefreshMode::Inline);
        assert_eq!(RefreshMode::Async.name(), "async");
    }

    #[test]
    fn mode_parse_enumerates_choices() {
        assert_eq!(RefreshMode::parse("ASYNC").unwrap(), RefreshMode::Async);
        assert_eq!(RefreshMode::parse("inline").unwrap(), RefreshMode::Inline);
        let e = RefreshMode::parse("eager").unwrap_err().to_string();
        assert!(e.contains("inline") && e.contains("async"), "{e}");
    }
}
