//! Background eigenbasis refresh service.
//!
//! Owns a **dedicated** [`ThreadPool`] (never the shard workers' pool: shard
//! workers block inside `ShardedOptimizer::step` joins, so sharing one pool
//! would let a step's layer updates queue behind refresh jobs they are
//! themselves waiting on — the classic self-deadlock this service exists to
//! avoid). Consumers snapshot their factor EMAs, enqueue a compute closure,
//! and keep stepping on the stale basis; the service runs the closure, times
//! it, and publishes the result through the layer's [`BasisHandle`].
//!
//! The per-layer in-flight gate lives on the handle (`try_begin_refresh`), so
//! a slow refresh sheds subsequent snapshots instead of building a queue.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use super::handle::{BasisHandle, BasisPayload};
use crate::util::pool::ThreadPool;

/// Aggregate counters across all completed refreshes.
#[derive(Clone, Copy, Debug, Default)]
pub struct RefreshStats {
    /// Refreshes that ran to completion and published.
    pub completed: u64,
    /// Refresh computations that panicked (payload discarded, gate released).
    pub failed: u64,
    /// Total seconds of background linear algebra.
    pub total_secs: f64,
    /// Slowest single refresh.
    pub max_secs: f64,
}

#[derive(Default)]
struct Shared {
    pending: Mutex<usize>,
    idle: Condvar,
    stats: Mutex<RefreshStats>,
}

/// The background refresh executor; cheap to share via `Arc`.
pub struct RefreshService {
    pool: ThreadPool,
    shared: Arc<Shared>,
}

impl RefreshService {
    /// Spawn a service with `workers` dedicated threads (≥ 1 enforced).
    pub fn new(workers: usize) -> Self {
        Self {
            pool: ThreadPool::new(workers.max(1)),
            shared: Arc::new(Shared::default()),
        }
    }

    pub fn workers(&self) -> usize {
        self.pool.size()
    }

    /// Enqueue one refresh: run `compute` on the pool and publish its payload
    /// to `handle`, stamped with `snapshot_step`. The caller is expected to
    /// have claimed `handle.try_begin_refresh()` first; on panic inside
    /// `compute` the gate is released and nothing is published.
    pub fn enqueue(
        &self,
        handle: Arc<BasisHandle>,
        snapshot_step: u64,
        compute: Box<dyn FnOnce() -> BasisPayload + Send + 'static>,
    ) {
        *self.shared.pending.lock().unwrap() += 1;
        if crate::telemetry::enabled() {
            crate::telemetry::metrics::refresh_enqueued_total().inc();
        }
        let shared = Arc::clone(&self.shared);
        self.pool.submit(move || {
            let t0 = Instant::now();
            let result = {
                // Generic task span; the compute closure itself opens the
                // per-layer `refresh.bg` span with its basis id.
                let _span = crate::telemetry::span("refresh.task", "refresh");
                catch_unwind(AssertUnwindSafe(compute))
            };
            let dt = t0.elapsed().as_secs_f64();
            if crate::telemetry::enabled() {
                crate::telemetry::metrics::refresh_latency_seconds().observe(dt);
            }
            {
                let mut stats = shared.stats.lock().unwrap();
                match result {
                    // Central numerical-health gate for every async refresh,
                    // whatever basis kind produced the payload: a non-finite
                    // decomposition is rejected here so consumers keep the
                    // previous versioned publication (stale-basis grace).
                    Ok(payload) if !payload.is_finite() => {
                        handle.abort_refresh();
                        stats.failed += 1;
                        crate::telemetry::metrics::basis_rejected_total().inc();
                    }
                    Ok(payload) => {
                        handle.publish(payload, snapshot_step);
                        stats.completed += 1;
                        stats.total_secs += dt;
                        stats.max_secs = stats.max_secs.max(dt);
                    }
                    Err(_) => {
                        handle.note_worker_panic();
                        handle.abort_refresh();
                        stats.failed += 1;
                    }
                }
            }
            let mut pending = shared.pending.lock().unwrap();
            *pending -= 1;
            if *pending == 0 {
                shared.idle.notify_all();
            }
        });
    }

    /// Jobs enqueued but not yet finished.
    pub fn pending(&self) -> usize {
        *self.shared.pending.lock().unwrap()
    }

    /// Block until every enqueued refresh has finished (tests, shutdown
    /// barriers). Safe to call from any thread except a pool worker.
    pub fn wait_idle(&self) {
        let mut pending = self.shared.pending.lock().unwrap();
        while *pending > 0 {
            pending = self.shared.idle.wait(pending).unwrap();
        }
    }

    pub fn stats(&self) -> RefreshStats {
        *self.shared.stats.lock().unwrap()
    }

    /// Cumulative background refresh seconds — the async analogue of
    /// `LayerOptimizer::refresh_seconds`, surfaced per step by the trainer
    /// as `StepTiming::bg_refresh_s`.
    pub fn refresh_seconds(&self) -> f64 {
        self.stats().total_secs
    }

    /// Refresh-pool utilization: `(jobs executed, cumulative busy seconds)`.
    /// Advances only while telemetry is enabled.
    pub fn pool_stats(&self) -> (u64, f64) {
        self.pool.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{power_iter_refresh, qr_positive, Matrix};
    use crate::util::rng::Rng;

    #[test]
    fn publishes_and_counts() {
        let svc = RefreshService::new(2);
        let handle = Arc::new(BasisHandle::new());
        for step in 1..=4u64 {
            // wait_idle below guarantees the previous publish released the
            // gate, so each claim must succeed — the optimizer's cadence.
            assert!(handle.try_begin_refresh());
            svc.enqueue(
                Arc::clone(&handle),
                step,
                Box::new(move || BasisPayload {
                    left: Some(Matrix::eye(3).scale(step as f32)),
                    ..Default::default()
                }),
            );
            svc.wait_idle();
        }
        let stats = svc.stats();
        assert_eq!(stats.completed, 4);
        assert_eq!(stats.failed, 0);
        assert!(stats.total_secs >= 0.0 && stats.max_secs <= stats.total_secs + 1e-12);
        let latest = handle.latest().unwrap();
        assert_eq!(latest.version, 4);
        assert_eq!(latest.snapshot_step, 4);
    }

    #[test]
    fn delayed_swap_is_never_torn_and_stays_orthonormal() {
        // The satellite invariant: a slow background refresh must never
        // expose a non-orthonormal or half-updated basis. The compute closure
        // sleeps to force the consumer to observe the stale version first.
        let mut rng = Rng::new(7);
        let n = 16;
        let p = Matrix::rand_psd(&mut rng, n);
        let (q0, _) = qr_positive(&Matrix::randn(&mut rng, n, n, 1.0));

        let svc = RefreshService::new(1);
        let handle = Arc::new(BasisHandle::new());
        assert!(handle.try_begin_refresh());
        let (pj, qj) = (p.clone(), q0.clone());
        svc.enqueue(
            Arc::clone(&handle),
            42,
            Box::new(move || {
                std::thread::sleep(std::time::Duration::from_millis(25));
                let q = power_iter_refresh(&pj, &qj);
                BasisPayload { left: Some(q.clone()), right: Some(q), ..Default::default() }
            }),
        );
        // While the refresh is in flight the handle must still serve the old
        // state (here: nothing yet) — never a partial result.
        assert!(handle.latest().is_none() || handle.version() == 1);
        svc.wait_idle();
        let published = handle.latest().expect("refresh published");
        assert_eq!(published.version, 1);
        assert_eq!(published.snapshot_step, 42);
        let ql = published.payload.left.as_ref().unwrap();
        let qr = published.payload.right.as_ref().unwrap();
        assert_eq!(ql.data, qr.data, "pair published atomically");
        let qtq = ql.matmul_tn(ql);
        assert!(
            qtq.max_abs_diff(&Matrix::eye(n)) < 1e-4,
            "async-refreshed basis lost orthonormality: {}",
            qtq.max_abs_diff(&Matrix::eye(n))
        );
        assert!(!handle.refresh_in_flight());
    }

    #[test]
    fn panicking_compute_releases_gate_without_publishing() {
        let svc = RefreshService::new(1);
        let handle = Arc::new(BasisHandle::new());
        assert!(handle.try_begin_refresh());
        svc.enqueue(Arc::clone(&handle), 1, Box::new(|| panic!("synthetic refresh failure")));
        svc.wait_idle();
        assert_eq!(svc.stats().failed, 1);
        assert_eq!(handle.version(), 0, "failed refresh must not publish");
        assert!(handle.take_worker_panic(), "panic must latch for the inline fallback");
        assert!(!handle.take_worker_panic(), "latch must clear on take");
        assert!(handle.try_begin_refresh(), "gate released after failure");
    }

    #[test]
    fn non_finite_payload_is_rejected_not_published() {
        let svc = RefreshService::new(1);
        let handle = Arc::new(BasisHandle::new());
        // Seed a good publication, then push a poisoned one: consumers must
        // keep seeing version 1.
        assert!(handle.try_begin_refresh());
        svc.enqueue(
            Arc::clone(&handle),
            1,
            Box::new(|| BasisPayload { left: Some(Matrix::eye(3)), ..Default::default() }),
        );
        svc.wait_idle();
        assert!(handle.try_begin_refresh());
        svc.enqueue(
            Arc::clone(&handle),
            2,
            Box::new(|| BasisPayload {
                left: Some(Matrix::from_vec(1, 2, vec![f32::NAN, 1.0])),
                ..Default::default()
            }),
        );
        svc.wait_idle();
        let stats = svc.stats();
        assert_eq!((stats.completed, stats.failed), (1, 1));
        let latest = handle.latest().unwrap();
        assert_eq!(latest.version, 1, "poisoned refresh must not publish");
        assert!(latest.payload.is_finite());
        assert!(!handle.take_worker_panic(), "rejection is not a panic");
        assert!(handle.try_begin_refresh(), "gate released after rejection");
    }

    #[test]
    fn many_layers_share_the_service() {
        let svc = Arc::new(RefreshService::new(3));
        let handles: Vec<Arc<BasisHandle>> =
            (0..8).map(|_| Arc::new(BasisHandle::new())).collect();
        for (i, h) in handles.iter().enumerate() {
            assert!(h.try_begin_refresh());
            let k = i as f32;
            svc.enqueue(
                Arc::clone(h),
                i as u64,
                Box::new(move || BasisPayload {
                    left: Some(Matrix::eye(2).scale(k)),
                    ..Default::default()
                }),
            );
        }
        svc.wait_idle();
        for (i, h) in handles.iter().enumerate() {
            let p = h.latest().unwrap();
            assert_eq!(p.snapshot_step, i as u64);
            assert_eq!(p.payload.left.as_ref().unwrap().at(0, 0), i as f32);
        }
        assert_eq!(svc.stats().completed, 8);
    }
}
