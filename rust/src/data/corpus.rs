//! Synthetic corpus generator — the C4 stand-in (DESIGN.md §2).
//!
//! Tokens are drawn from an order-1 Markov chain whose rows are Zipfian
//! distributions over per-state permutations of the vocabulary. This gives
//! the two statistics that matter for comparing optimizers on language
//! modeling: heavy-tailed unigram frequencies and learnable local structure
//! with a known, non-trivial entropy floor.
//!
//! The conditional entropy H(next | prev) is computed analytically from the
//! transition table, so training-loss curves have an absolute reference:
//! a perfect model reaches exactly `entropy_floor()` nats.

use crate::util::rng::{Rng, Zipf};

#[derive(Clone, Debug)]
pub struct CorpusSpec {
    pub vocab_size: usize,
    /// Zipf exponent for each transition row (1.0–1.5 is natural-ish text).
    pub zipf_alpha: f64,
    /// Language seed: determines the transition table. Two streams with the
    /// same `seed` sample the SAME language.
    pub seed: u64,
    /// Stream seed: determines which sample path through the language is
    /// drawn. Shards and eval sets vary this, never `seed` — so held-out
    /// data is fresh text from the same distribution.
    pub stream: u64,
}

impl Default for CorpusSpec {
    fn default() -> Self {
        Self { vocab_size: 512, zipf_alpha: 1.2, seed: 0, stream: 0 }
    }
}

/// A deterministic infinite token stream with known entropy.
pub struct SyntheticCorpus {
    spec: CorpusSpec,
    /// Per-state permutation of the vocabulary: row s of the transition
    /// matrix is `zipf(rank of permuted symbol)`.
    perms: Vec<Vec<u32>>,
    zipf: Zipf,
    state: u32,
    rng: Rng,
}

impl SyntheticCorpus {
    pub fn new(spec: CorpusSpec) -> Self {
        assert!(spec.vocab_size >= 2);
        let mut seeder = Rng::new(spec.seed);
        let mut perms = Vec::with_capacity(spec.vocab_size);
        for _ in 0..spec.vocab_size {
            let mut p: Vec<u32> = (0..spec.vocab_size as u32).collect();
            seeder.shuffle(&mut p);
            perms.push(p);
        }
        let zipf = Zipf::new(spec.vocab_size, spec.zipf_alpha);
        // Sampling stream is independent of the language structure.
        let rng = Rng::new(
            spec.seed
                .wrapping_mul(0x9E3779B97F4A7C15)
                ^ spec.stream.wrapping_mul(0xD1B54A32D192ED03)
                ^ 0x5EED,
        );
        Self { spec, perms, zipf, state: 0, rng }
    }

    pub fn vocab_size(&self) -> usize {
        self.spec.vocab_size
    }

    /// Next token of the stream.
    pub fn next_token(&mut self) -> u32 {
        let rank = self.zipf.sample(&mut self.rng);
        let tok = self.perms[self.state as usize][rank];
        self.state = tok;
        tok
    }

    /// Fill a buffer with the next `buf.len()` tokens.
    pub fn fill(&mut self, buf: &mut [u32]) {
        for t in buf.iter_mut() {
            *t = self.next_token();
        }
    }

    /// Exact conditional entropy H(next|prev) in nats — identical for every
    /// state because each row is the same Zipf distribution permuted.
    pub fn entropy_floor(&self) -> f64 {
        let n = self.spec.vocab_size;
        let alpha = self.spec.zipf_alpha;
        let z: f64 = (1..=n).map(|k| (k as f64).powf(-alpha)).sum();
        -(1..=n)
            .map(|k| {
                let p = (k as f64).powf(-alpha) / z;
                p * p.ln()
            })
            .sum::<f64>()
    }

    /// Unigram entropy upper bound (loss of a context-free model): entropy
    /// of the stationary distribution. For permuted-Zipf rows the stationary
    /// distribution is near-uniform, so this ≈ ln(V) — the gap to
    /// `entropy_floor()` is what a context-using model can learn.
    pub fn unigram_entropy_bound(&self) -> f64 {
        (self.spec.vocab_size as f64).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = SyntheticCorpus::new(CorpusSpec { seed: 9, ..Default::default() });
        let mut b = SyntheticCorpus::new(CorpusSpec { seed: 9, ..Default::default() });
        let mut xa = vec![0u32; 256];
        let mut xb = vec![0u32; 256];
        a.fill(&mut xa);
        b.fill(&mut xb);
        assert_eq!(xa, xb);
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = SyntheticCorpus::new(CorpusSpec { seed: 1, ..Default::default() });
        let mut b = SyntheticCorpus::new(CorpusSpec { seed: 2, ..Default::default() });
        let mut xa = vec![0u32; 64];
        let mut xb = vec![0u32; 64];
        a.fill(&mut xa);
        b.fill(&mut xb);
        assert_ne!(xa, xb);
    }

    #[test]
    fn tokens_in_vocab() {
        let spec = CorpusSpec { vocab_size: 100, ..Default::default() };
        let mut c = SyntheticCorpus::new(spec);
        for _ in 0..10_000 {
            assert!(c.next_token() < 100);
        }
    }

    #[test]
    fn entropy_floor_below_unigram_bound() {
        let c = SyntheticCorpus::new(CorpusSpec::default());
        let floor = c.entropy_floor();
        let bound = c.unigram_entropy_bound();
        assert!(floor > 0.0);
        assert!(
            floor < bound - 0.5,
            "structure must be learnable: floor {floor} vs bound {bound}"
        );
    }

    #[test]
    fn empirical_bigram_entropy_near_floor() {
        // Estimate H(next|prev) from a long sample on a tiny vocab and
        // compare to the analytic floor.
        let spec = CorpusSpec { vocab_size: 16, zipf_alpha: 1.3, seed: 4, stream: 0 };
        let mut c = SyntheticCorpus::new(spec);
        let floor = c.entropy_floor();
        let n = 400_000usize;
        let mut counts = vec![vec![0f64; 16]; 16];
        let mut prev = c.next_token() as usize;
        for _ in 0..n {
            let t = c.next_token() as usize;
            counts[prev][t] += 1.0;
            prev = t;
        }
        let mut h = 0.0;
        let total: f64 = n as f64;
        for row in &counts {
            let rs: f64 = row.iter().sum();
            if rs == 0.0 {
                continue;
            }
            for &c in row {
                if c > 0.0 {
                    let p = c / rs;
                    h += (rs / total) * (-p * p.ln());
                }
            }
        }
        assert!((h - floor).abs() < 0.05, "empirical {h} vs floor {floor}");
    }

    #[test]
    fn zipf_head_dominates_each_row() {
        // The most likely successor of any state should be sampled far more
        // often than uniform.
        let spec = CorpusSpec { vocab_size: 64, zipf_alpha: 1.2, seed: 7, stream: 0 };
        let mut c = SyntheticCorpus::new(spec);
        let mut counts = vec![0usize; 64];
        // Condition on state 0 by resetting state each draw.
        for _ in 0..20_000 {
            c.state = 0;
            counts[c.next_token() as usize] += 1;
        }
        let max = *counts.iter().max().unwrap();
        assert!(max > 20_000 / 64 * 4, "max count {max}");
    }
}
