//! Data pipeline — the C4/T5 stand-in (DESIGN.md §2): a synthetic Markov
//! corpus with a known entropy floor and a deterministic, shardable batch
//! stream with microbatching for gradient accumulation.
//!
//! The corpus emits token ids directly (the T5 tokenizer is bypassed: token
//! statistics, not byte-pair merges, are what optimizer comparisons see).

pub mod batcher;
pub mod corpus;

pub use batcher::{Batch, BatchStream};
pub use corpus::{CorpusSpec, SyntheticCorpus};
