//! Batch pipeline: token stream → fixed `(batch, seq)` training batches with
//! next-token targets, deterministic sharding, and gradient-accumulation
//! microbatching (the paper trains 2m-token batches via accumulation on a
//! single device — §5 Throughput Measurement).

use super::corpus::{CorpusSpec, SyntheticCorpus};

/// One training batch: `tokens[b][s]` inputs with `targets[b][s]` the next
/// token. Stored flat, row-major `[batch, seq]`.
#[derive(Clone, Debug, PartialEq)]
pub struct Batch {
    pub batch: usize,
    pub seq: usize,
    pub tokens: Vec<u32>,
    pub targets: Vec<u32>,
}

impl Batch {
    pub fn num_tokens(&self) -> usize {
        self.batch * self.seq
    }

    /// Split into `k` microbatches along the batch dimension for gradient
    /// accumulation. `batch` must be divisible by `k`.
    pub fn microbatches(&self, k: usize) -> Vec<Batch> {
        assert!(k >= 1 && self.batch % k == 0, "batch {} not divisible by {k}", self.batch);
        let mb = self.batch / k;
        (0..k)
            .map(|i| {
                let lo = i * mb * self.seq;
                let hi = (i + 1) * mb * self.seq;
                Batch {
                    batch: mb,
                    seq: self.seq,
                    tokens: self.tokens[lo..hi].to_vec(),
                    targets: self.targets[lo..hi].to_vec(),
                }
            })
            .collect()
    }
}

/// Deterministic batch stream over the synthetic corpus.
///
/// Shard `(shard_id, num_shards)` partitions *sequences*: each shard draws
/// from an independently seeded corpus stream, so multi-worker data loading
/// never overlaps (the rebalancing guarantee DistributedShampoo-style data
/// parallel training needs).
pub struct BatchStream {
    corpus: SyntheticCorpus,
    pub batch: usize,
    pub seq: usize,
    produced: u64,
}

impl BatchStream {
    pub fn new(spec: CorpusSpec, batch: usize, seq: usize, shard_id: u64, num_shards: u64) -> Self {
        assert!(shard_id < num_shards);
        let mut spec = spec;
        // Shards draw disjoint sample streams from the SAME language (same
        // spec.seed → same transition table; different stream → fresh text).
        spec.stream = spec
            .stream
            .wrapping_mul(num_shards.max(1))
            .wrapping_add(shard_id + 1);
        Self { corpus: SyntheticCorpus::new(spec), batch, seq, produced: 0 }
    }

    pub fn vocab_size(&self) -> usize {
        self.corpus.vocab_size()
    }

    pub fn entropy_floor(&self) -> f64 {
        self.corpus.entropy_floor()
    }

    pub fn batches_produced(&self) -> u64 {
        self.produced
    }

    /// Produce the next batch: each row is a contiguous (seq+1)-token window
    /// of the stream, split into inputs (first `seq`) and targets (last `seq`).
    pub fn next_batch(&mut self) -> Batch {
        let (b, s) = (self.batch, self.seq);
        let mut tokens = Vec::with_capacity(b * s);
        let mut targets = Vec::with_capacity(b * s);
        let mut window = vec![0u32; s + 1];
        for _ in 0..b {
            self.corpus.fill(&mut window);
            tokens.extend_from_slice(&window[..s]);
            targets.extend_from_slice(&window[1..]);
        }
        self.produced += 1;
        Batch { batch: b, seq: s, tokens, targets }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> CorpusSpec {
        CorpusSpec { vocab_size: 64, zipf_alpha: 1.2, seed: 3, stream: 0 }
    }

    #[test]
    fn targets_are_shifted_inputs() {
        let mut bs = BatchStream::new(spec(), 2, 8, 0, 1);
        let b = bs.next_batch();
        for row in 0..2 {
            for i in 0..7 {
                assert_eq!(b.tokens[row * 8 + i + 1], b.targets[row * 8 + i]);
            }
        }
    }

    #[test]
    fn deterministic_stream() {
        let mut a = BatchStream::new(spec(), 4, 16, 0, 1);
        let mut b = BatchStream::new(spec(), 4, 16, 0, 1);
        assert_eq!(a.next_batch(), b.next_batch());
        assert_eq!(a.next_batch(), b.next_batch());
    }

    #[test]
    fn shards_disjoint_streams() {
        let mut s0 = BatchStream::new(spec(), 2, 16, 0, 2);
        let mut s1 = BatchStream::new(spec(), 2, 16, 1, 2);
        assert_ne!(s0.next_batch(), s1.next_batch());
    }

    #[test]
    fn microbatches_partition_batch() {
        let mut bs = BatchStream::new(spec(), 8, 4, 0, 1);
        let b = bs.next_batch();
        let mbs = b.microbatches(4);
        assert_eq!(mbs.len(), 4);
        let recon: Vec<u32> = mbs.iter().flat_map(|m| m.tokens.clone()).collect();
        assert_eq!(recon, b.tokens);
        for m in &mbs {
            assert_eq!(m.batch, 2);
            assert_eq!(m.seq, 4);
        }
    }

    #[test]
    #[should_panic]
    fn microbatch_indivisible_panics() {
        let mut bs = BatchStream::new(spec(), 6, 4, 0, 1);
        let b = bs.next_batch();
        let _ = b.microbatches(4);
    }

    #[test]
    fn tokens_in_range() {
        let mut bs = BatchStream::new(spec(), 4, 32, 0, 1);
        let b = bs.next_batch();
        assert!(b.tokens.iter().all(|&t| t < 64));
        assert!(b.targets.iter().all(|&t| t < 64));
    }
}
