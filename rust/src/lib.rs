//! # soap-lab
//!
//! A production-shaped reproduction of **“SOAP: Improving and Stabilizing
//! Shampoo using Adam”** (Vyas et al., 2024) as a three-layer
//! Rust + JAX + Pallas training framework:
//!
//! - **L3 (this crate)** — training coordinator: data pipeline, microbatch
//!   gradient accumulation, layer-sharded optimizer workers, preconditioning
//!   scheduler, checkpoints, metrics, and the benchmark harness that
//!   regenerates every figure of the paper's evaluation.
//! - **L2 (`python/compile/model.py`)** — the JAX transformer LM fwd/bwd and
//!   per-optimizer update graphs, AOT-lowered to HLO text.
//! - **L1 (`python/compile/kernels/`)** — Pallas kernels for the SOAP hot
//!   path (rotate → Adam → rotate-back), lowered inside the L2 graphs.
//!
//! Python never runs on the training path: artifacts are compiled once by
//! `make artifacts` and executed from Rust via the PJRT CPU client
//! ([`runtime`]).
//!
//! ## Training sessions
//!
//! Every entry point (CLI, benches, examples) constructs runs through the
//! [`session`] layer: `TrainSession::builder()` takes a model spec, an
//! optimizer preset/composition, a schedule, and a
//! [`session::Backend`] (serial / sharded / PJRT), validates the whole
//! configuration up front, and yields a session with a uniform lifecycle —
//! `step()`/`run()`, typed [`session::MetricsSink`] streaming,
//! `state_bytes`/`scratch_bytes`, and first-class checkpoint/resume that
//! round-trips the step counter, data cursor, and drained async-refresh
//! state (a resumed run is bitwise-identical to an uninterrupted one). See
//! the [`session`] module docs for the builder example, the backend
//! matrix, and the resume semantics.
//!
//! ## Refresh modes
//!
//! SOAP/Shampoo periodically recompute their preconditioner decompositions
//! (frequency `f`, the paper's only overhead over Adam). Two execution modes
//! are supported, selected by [`optim::Hyper::refresh_mode`]:
//!
//! - **Inline** (default): the decomposition runs synchronously inside the
//!   optimizer step — the paper's Algorithm 3 math, fully deterministic
//!   (same seed ⇒ bitwise-identical weights at any worker count). Per-layer
//!   refresh phases are staggered (`layer_idx % f`) so the step-time spike
//!   is spread across steps rather than landing on every `t ≡ 0 (mod f)`.
//! - **Async**: the step snapshots the factor EMAs and enqueues the
//!   decomposition on the background [`precond::RefreshService`]; the new
//!   basis is adopted atomically at a later step ([`precond::BasisHandle`]).
//!   The hot path never blocks on linear algebra; the price is bounded
//!   basis *staleness* (steps between snapshot and adoption), which SOAP
//!   tolerates by design — its Adam second moment keeps adapting every step.
//!   Prefer Async when step time matters (throughput/p99); prefer Inline
//!   for exact reproducibility of the paper's trajectories.
//!
//! ## Observability
//!
//! The [`telemetry`] module provides opt-in span tracing (Chrome
//! trace-event export via `--trace-out`), a counters/gauges/histograms
//! registry with Prometheus text exposition (`--metrics-out`), and
//! per-layer optimizer health snapshots (gradient/update norms, basis
//! staleness, refresh-queue depth, whitening quality) streamed through
//! [`session::MetricsSink::on_health`]. Telemetry is free when disabled:
//! the steady-state step stays zero-alloc and trajectories are bitwise
//! unchanged.
//!
//! ## Sweeps
//!
//! The [`sweep`] orchestrator (`soap-lab sweep`) runs grids of training
//! jobs concurrently under a global memory budget: jobs are planned with
//! the coordinator's per-layer cost model, admitted longest-first as the
//! budget allows, streamed into one `job_id`-tagged JSONL, journaled for
//! crash-safe resume (a resumed sweep is bitwise-identical to an
//! uninterrupted one), and summarized in `SWEEP_results.json`.
//!
//! See `DESIGN.md` for the full system inventory and experiment index, and
//! `EXPERIMENTS.md` for measured reproductions.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod dist;
pub mod experiments;
pub mod fault;
pub mod linalg;
pub mod model;
pub mod optim;
pub mod precond;
pub mod runtime;
pub mod session;
pub mod sweep;
pub mod telemetry;
pub mod util;
