//! # soap-lab
//!
//! A production-shaped reproduction of **“SOAP: Improving and Stabilizing
//! Shampoo using Adam”** (Vyas et al., 2024) as a three-layer
//! Rust + JAX + Pallas training framework:
//!
//! - **L3 (this crate)** — training coordinator: data pipeline, microbatch
//!   gradient accumulation, layer-sharded optimizer workers, preconditioning
//!   scheduler, checkpoints, metrics, and the benchmark harness that
//!   regenerates every figure of the paper's evaluation.
//! - **L2 (`python/compile/model.py`)** — the JAX transformer LM fwd/bwd and
//!   per-optimizer update graphs, AOT-lowered to HLO text.
//! - **L1 (`python/compile/kernels/`)** — Pallas kernels for the SOAP hot
//!   path (rotate → Adam → rotate-back), lowered inside the L2 graphs.
//!
//! Python never runs on the training path: artifacts are compiled once by
//! `make artifacts` and executed from Rust via the PJRT CPU client
//! ([`runtime`]).
//!
//! See `DESIGN.md` for the full system inventory and experiment index, and
//! `EXPERIMENTS.md` for measured reproductions.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod linalg;
pub mod model;
pub mod optim;
pub mod runtime;
pub mod util;
