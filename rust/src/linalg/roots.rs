//! Matrix inverse p-th roots of PSD matrices — the Shampoo preconditioner
//! transform `L^{-1/(2p)}`.
//!
//! Two engines:
//! - [`inv_root_eigh`]: exact via Jacobi eigendecomposition (the default,
//!   matching DistributedShampoo's `eigh` root computation);
//! - [`inv_root_newton`]: coupled Newton iteration (pure matmuls — the form
//!   that ports to HLO), provided for the ablation benches.
//!
//! Both regularize with `ε·I` the way DistributedShampoo does.

use super::eigh::eigh;
use super::matrix::Matrix;

/// `(a + eps·I)^(-1/p)` via eigendecomposition.
pub fn inv_root_eigh(a: &Matrix, p: f32, eps: f32) -> Matrix {
    assert!(p > 0.0);
    let (w, v) = eigh(a);
    inv_root_from_eig(&w, &v, p, eps)
}

/// Build `(a + eps·I)^(-1/p)` from a precomputed eigendecomposition — used
/// by the warm-started Shampoo refresh (§Perf) which reuses the previous
/// basis via [`super::eigh::eigh_warm`].
pub fn inv_root_from_eig(w: &[f32], v: &Matrix, p: f32, eps: f32) -> Matrix {
    assert!(p > 0.0);
    let n = v.rows;
    let mut d = Matrix::zeros(n, n);
    for i in 0..n {
        let lam = (w[i].max(0.0) + eps).max(1e-30);
        d.set(i, i, lam.powf(-1.0 / p));
    }
    v.matmul(&d).matmul_nt(v)
}

/// `(a + eps·I)^(+1/p)` via eigendecomposition (used in tests/oracles).
pub fn root_eigh(a: &Matrix, p: f32, eps: f32) -> Matrix {
    assert!(p > 0.0);
    let n = a.rows;
    let (w, v) = eigh(a);
    let mut d = Matrix::zeros(n, n);
    for i in 0..n {
        let lam = (w[i].max(0.0) + eps).max(1e-30);
        d.set(i, i, lam.powf(1.0 / p));
    }
    v.matmul(&d).matmul_nt(&v)
}

/// Coupled Newton iteration for `a^{-1/p}` (integer p ≥ 1), after Guo &
/// Higham. Pure matmul/elementwise — mirrors what an HLO-side implementation
/// does. `a` must be PSD; `eps·I` is added first.
pub fn inv_root_newton(a: &Matrix, p: u32, eps: f32, iters: usize) -> Matrix {
    assert!(p >= 1);
    let n = a.rows;
    let mut a_reg = a.clone();
    for i in 0..n {
        let v = a_reg.at(i, i) + eps;
        a_reg.set(i, i, v);
    }
    // Scale so the spectrum is inside the Newton convergence region:
    // z = 1 / ||A||_F; X0 = I * z^{1/p}? The standard coupled iteration:
    //   X_{k+1} = X_k ((p+1)I − M_k)/p,  M_{k+1} = ((p+1)I − M_k)^p / p^p · M_k
    // with X0 = (1/c) I, M0 = A / c^p where c = (||A||_2)^{1/p} estimate.
    let norm = a_reg.frob_norm().max(1e-30);
    let c = norm.powf(1.0 / p as f32);
    let mut x = Matrix::eye(n).scale(1.0 / c);
    let mut m_k = a_reg.scale(1.0 / norm);

    let pf = p as f32;
    for _ in 0..iters {
        // T = ((p+1) I − M_k) / p
        let mut t = m_k.scale(-1.0 / pf);
        for i in 0..n {
            let v = t.at(i, i) + (pf + 1.0) / pf;
            t.set(i, i, v);
        }
        x = x.matmul(&t);
        // M ← T^p · M
        let mut tp = Matrix::eye(n);
        for _ in 0..p {
            tp = tp.matmul(&t);
        }
        m_k = tp.matmul(&m_k);
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn well_conditioned_psd(rng: &mut Rng, n: usize) -> Matrix {
        // PSD with spectrum in roughly [0.5, 2.5] — Newton's comfort zone.
        let mut a = Matrix::rand_psd(rng, n);
        let tr = a.trace() / n as f32;
        a.scale_inplace(1.0 / tr.max(1e-6));
        for i in 0..n {
            let v = a.at(i, i) + 0.5;
            a.set(i, i, v);
        }
        a
    }

    #[test]
    fn inv_root_eigh_squares_to_inverse() {
        let mut rng = Rng::new(30);
        let a = well_conditioned_psd(&mut rng, 8);
        // (a^(-1/2))² · a ≈ I
        let r = inv_root_eigh(&a, 2.0, 0.0);
        let check = r.matmul(&r).matmul(&a);
        assert!(check.max_abs_diff(&Matrix::eye(8)) < 2e-2, "{}", check.max_abs_diff(&Matrix::eye(8)));
    }

    #[test]
    fn inv_root_p4() {
        let mut rng = Rng::new(31);
        let a = well_conditioned_psd(&mut rng, 6);
        let r = inv_root_eigh(&a, 4.0, 0.0);
        let r4 = r.matmul(&r).matmul(&r).matmul(&r);
        let check = r4.matmul(&a);
        assert!(check.max_abs_diff(&Matrix::eye(6)) < 3e-2);
    }

    #[test]
    fn root_inverse_consistency() {
        let mut rng = Rng::new(32);
        let a = well_conditioned_psd(&mut rng, 7);
        let up = root_eigh(&a, 2.0, 0.0);
        let dn = inv_root_eigh(&a, 2.0, 0.0);
        let check = up.matmul(&dn);
        assert!(check.max_abs_diff(&Matrix::eye(7)) < 2e-2);
    }

    #[test]
    fn newton_matches_eigh_p2() {
        let mut rng = Rng::new(33);
        let a = well_conditioned_psd(&mut rng, 8);
        let want = inv_root_eigh(&a, 2.0, 1e-6);
        let got = inv_root_newton(&a, 2, 1e-6, 40);
        assert!(
            got.max_abs_diff(&want) < 5e-2 * (1.0 + want.max_abs()),
            "err={}",
            got.max_abs_diff(&want)
        );
    }

    #[test]
    fn eps_regularizes_singular() {
        // Zero matrix: (0 + eps I)^(-1/2) = eps^(-1/2) I — finite.
        let a = Matrix::zeros(5, 5);
        let r = inv_root_eigh(&a, 2.0, 1e-4);
        for i in 0..5 {
            assert!((r.at(i, i) - 100.0).abs() < 1.0);
            assert!(r.at(i, i).is_finite());
        }
    }

    #[test]
    fn identity_fixed_point() {
        let r = inv_root_eigh(&Matrix::eye(4), 2.0, 0.0);
        assert!(r.max_abs_diff(&Matrix::eye(4)) < 1e-4);
    }
}
