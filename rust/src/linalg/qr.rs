//! Householder QR decomposition.
//!
//! The SOAP eigenbasis refresh (paper Algorithm 4) is one power-iteration
//! step `S = P·Q` followed by `Q ← QR(S).Q`. The HLO artifact path carries
//! the same algorithm (hand-rolled in jnp, see `python/compile/kernels/`);
//! this native version is the oracle for it and the engine for the
//! CPU-offloaded refresh mode.

use super::matrix::Matrix;

/// Full QR via Householder reflections: `a = Q·R`, Q orthogonal (m×m),
/// R upper-triangular (m×n). For our use m == n always, but the code is
/// general for m ≥ n.
pub fn qr(a: &Matrix) -> (Matrix, Matrix) {
    let (m, n) = (a.rows, a.cols);
    assert!(m >= n, "qr expects m >= n");
    let mut r = a.clone();
    let mut q = Matrix::eye(m);
    let mut v = vec![0.0f32; m];

    for k in 0..n.min(m - 1) {
        // Build the Householder vector for column k, rows k..m.
        let mut norm2 = 0.0f64;
        for i in k..m {
            let x = r.at(i, k) as f64;
            norm2 += x * x;
        }
        let norm = norm2.sqrt() as f32;
        if norm < 1e-30 {
            continue; // column already zero below the diagonal
        }
        let x0 = r.at(k, k);
        let alpha = if x0 >= 0.0 { -norm } else { norm };
        let mut vnorm2 = 0.0f64;
        for i in k..m {
            let vi = if i == k { r.at(i, k) - alpha } else { r.at(i, k) };
            v[i] = vi;
            vnorm2 += vi as f64 * vi as f64;
        }
        if vnorm2 < 1e-60 {
            continue;
        }
        let inv = (1.0 / vnorm2.sqrt()) as f32;
        for i in k..m {
            v[i] *= inv;
        }

        // R ← (I − 2vvᵀ) R, applied to columns k..n
        for j in k..n {
            let mut dot = 0.0f32;
            for i in k..m {
                dot += v[i] * r.at(i, j);
            }
            let two_dot = 2.0 * dot;
            for i in k..m {
                let val = r.at(i, j) - two_dot * v[i];
                r.set(i, j, val);
            }
        }
        // Q ← Q (I − 2vvᵀ)
        for i in 0..m {
            let mut dot = 0.0f32;
            for j in k..m {
                dot += q.at(i, j) * v[j];
            }
            let two_dot = 2.0 * dot;
            for j in k..m {
                let val = q.at(i, j) - two_dot * v[j];
                q.set(i, j, val);
            }
        }
    }

    // Zero the strictly-lower part of R (numerical dust).
    for i in 1..m {
        for j in 0..i.min(n) {
            r.set(i, j, 0.0);
        }
    }
    (q, r)
}

/// Sign-fix Q (and correspondingly R) so diagonal of R is non-negative —
/// makes QR unique and keeps the power-iteration eigenbasis stable across
/// steps (no column sign flips between refreshes).
pub fn qr_positive(a: &Matrix) -> (Matrix, Matrix) {
    let (mut q, mut r) = qr(a);
    let n = r.cols.min(r.rows);
    for j in 0..n {
        if r.at(j, j) < 0.0 {
            for i in 0..r.cols {
                if i >= j {
                    let v = -r.at(j, i);
                    r.set(j, i, v);
                }
            }
            for i in 0..q.rows {
                let v = -q.at(i, j);
                q.set(i, j, v);
            }
        }
    }
    (q, r)
}

/// One step of orthogonal (power) iteration: `Q ← QR(P·Q).Q` — paper Alg 4.
pub fn power_iter_refresh(p: &Matrix, q_prev: &Matrix) -> Matrix {
    let s = p.matmul(q_prev);
    let (q, _) = qr_positive(&s);
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn reconstructs_a() {
        let mut rng = Rng::new(10);
        for n in [1usize, 2, 3, 8, 17] {
            let a = Matrix::randn(&mut rng, n, n, 1.0);
            let (q, r) = qr(&a);
            let qa = q.matmul(&r);
            assert!(qa.max_abs_diff(&a) < 1e-3, "n={n}");
        }
    }

    #[test]
    fn q_is_orthogonal() {
        let mut rng = Rng::new(11);
        let a = Matrix::randn(&mut rng, 24, 24, 1.0);
        let (q, _) = qr(&a);
        let qtq = q.matmul_tn(&q);
        assert!(qtq.max_abs_diff(&Matrix::eye(24)) < 1e-4);
    }

    #[test]
    fn r_is_upper_triangular() {
        let mut rng = Rng::new(12);
        let a = Matrix::randn(&mut rng, 9, 9, 1.0);
        let (_, r) = qr(&a);
        for i in 1..9 {
            for j in 0..i {
                assert_eq!(r.at(i, j), 0.0);
            }
        }
    }

    #[test]
    fn positive_diag_unique() {
        let mut rng = Rng::new(13);
        let a = Matrix::randn(&mut rng, 6, 6, 1.0);
        let (q, r) = qr_positive(&a);
        for j in 0..6 {
            assert!(r.at(j, j) >= 0.0);
        }
        assert!(q.matmul(&r).max_abs_diff(&a) < 1e-3);
    }

    #[test]
    fn power_iteration_converges_to_eigenvectors() {
        // Diagonal P with distinct eigenvalues: iterating from a random
        // orthogonal start must converge to (signed) identity basis.
        let n = 6;
        let p = Matrix::from_fn(n, n, |i, j| if i == j { (n - i) as f32 } else { 0.0 });
        let mut rng = Rng::new(14);
        let (mut q, _) = qr_positive(&Matrix::randn(&mut rng, n, n, 1.0));
        for _ in 0..200 {
            q = power_iter_refresh(&p, &q);
        }
        // Columns of q should be ± canonical basis vectors (col_into: one
        // buffer reused across the column loop).
        let mut col = Vec::new();
        for j in 0..n {
            q.col_into(j, &mut col);
            let max = col.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            assert!(max > 0.999, "col {j} max {max}");
        }
    }

    #[test]
    fn identity_is_fixed_point() {
        let p = Matrix::eye(5);
        let q = Matrix::eye(5);
        let q2 = power_iter_refresh(&p, &q);
        assert!(q2.max_abs_diff(&Matrix::eye(5)) < 1e-5);
    }
}
