//! N-dimensional tensor shapes and mode-k (unfolding) operations over the
//! crate's contiguous row-major `f32` storage.
//!
//! Shampoo (Gupta et al., 2018) is defined for arbitrary-rank parameters via
//! one Kronecker factor **per mode**; the SOAP recipe inherits that
//! decomposition for its eigenbasis. This module supplies the substrate:
//!
//! - [`TensorShape`] — the dimension vector of a parameter, with the
//!   canonical 2-D **carrier** fold `(numel/d_last, d_last)` under which the
//!   rest of the system (model gradients, [`Matrix`] storage, checkpoints)
//!   moves the data. A rank-2 shape's carrier is itself, so every existing
//!   matrix parameter is a tensor parameter already.
//! - mode-k **gram products** ([`mode_gram_into`]) — `G₍ₖ₎·G₍ₖ₎ᵀ`, the
//!   per-mode factor statistic, computed without materializing the unfolding
//!   for the first and last modes (they are reshapes of row-major storage)
//!   and through a caller-provided unfold buffer for interior modes.
//! - mode-k **products** ([`mode_apply_into`]) — `T ×ₖ Q` (or `×ₖ Qᵀ`),
//!   the per-mode basis rotation, executed as contiguous-slice GEMMs over
//!   the existing blocked [`crate::linalg::gemm`] kernel family.
//!
//! Everything here is **allocation-free in steady state**: the `*_into`
//! entry points write through caller-provided grow-only buffers (the
//! optimizer threads its per-layer `Workspace` arena), so the zero-alloc
//! step path extends to rank-3+ parameters unchanged.

use super::gemm::{gemm_into, gemm_nt_into, gemm_tn_into};
use super::Matrix;

/// The dimension vector of an N-dimensional parameter.
///
/// Rank 1 covers bias/gain vectors, rank 2 the classic weight matrices,
/// rank 3+ convolution-style kernels. Data is always carried row-major and
/// contiguous in a [`Matrix`] of the [`TensorShape::carrier`] shape.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorShape {
    dims: Vec<usize>,
}

impl TensorShape {
    /// A shape from explicit dims. Zero-sized dims are rejected (a zero-size
    /// parameter has no optimizer state to shape).
    pub fn new(dims: Vec<usize>) -> Self {
        assert!(!dims.is_empty(), "TensorShape needs at least one dim");
        assert!(dims.iter().all(|&d| d > 0), "TensorShape dims must be > 0: {dims:?}");
        Self { dims }
    }

    /// The rank-2 shape of an `m×n` matrix parameter.
    pub fn matrix(rows: usize, cols: usize) -> Self {
        Self::new(vec![rows, cols])
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }

    /// The canonical 2-D fold the data is carried under:
    /// `(numel / d_last, d_last)`. For rank ≤ 2 this is the shape itself
    /// (`(1, n)` for vectors), and for a conv-style `[k, in, out]` kernel it
    /// is the `(k·in, out)` matrix its forward GEMM uses — i.e. exactly the
    /// [`Matrix`] the model already materializes.
    pub fn carrier(&self) -> (usize, usize) {
        let last = *self.dims.last().expect("non-empty");
        (self.numel() / last, last)
    }

    /// Greedy adjacent-mode merging (`merge_small_dims` in
    /// DistributedShampoo): walk the dims left to right, folding a dim into
    /// its left neighbour while the merged size stays ≤ `cap`. `cap == 0`
    /// disables merging. Never changes `numel`.
    pub fn merge_adjacent(&self, cap: usize) -> TensorShape {
        if cap == 0 || self.rank() <= 1 {
            return self.clone();
        }
        let mut out = vec![self.dims[0]];
        for &d in &self.dims[1..] {
            let last = out.last_mut().expect("non-empty");
            if last.saturating_mul(d) <= cap {
                *last *= d;
            } else {
                out.push(d);
            }
        }
        TensorShape::new(out)
    }

    /// The shape the optimizer actually preconditions: rank ≤ 2 passes
    /// through untouched (the matrix path is the golden reference), rank ≥ 3
    /// drops size-1 modes and applies [`TensorShape::merge_adjacent`] with
    /// `merge_cap`. A rank-3+ shape that collapses to rank ≤ 2 with its
    /// carrier fold preserved re-joins the bitwise-pinned matrix path (see
    /// `OptKind::build_tensor`).
    pub fn effective(&self, merge_cap: usize) -> TensorShape {
        if self.rank() <= 2 {
            return self.clone();
        }
        let squeezed: Vec<usize> = self.dims.iter().copied().filter(|&d| d > 1).collect();
        let mut s = TensorShape::new(if squeezed.is_empty() { vec![1] } else { squeezed });
        if s.rank() > 2 {
            s = s.merge_adjacent(merge_cap);
        }
        s
    }
}

impl std::fmt::Display for TensorShape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut first = true;
        for d in &self.dims {
            if !first {
                write!(f, "×")?;
            }
            write!(f, "{d}")?;
            first = false;
        }
        Ok(())
    }
}

#[inline]
fn split_at_mode(dims: &[usize], k: usize) -> (usize, usize, usize) {
    let outer: usize = dims[..k].iter().product();
    let dk = dims[k];
    let inner: usize = dims[k + 1..].iter().product();
    (outer, dk, inner)
}

/// Copy the mode-`k` unfolding `G₍ₖ₎` (shape `dk × numel/dk`) of `data`
/// into `out` (grow-only reuse). Only interior modes need this copy — the
/// first and last modes of a row-major tensor are reshapes.
pub fn unfold_into(data: &[f32], dims: &[usize], k: usize, out: &mut Matrix) {
    let (outer, dk, inner) = split_at_mode(dims, k);
    debug_assert_eq!(data.len(), outer * dk * inner, "data/shape mismatch");
    let cols = outer * inner;
    out.reuse_shape(dk, cols);
    for o in 0..outer {
        for i in 0..dk {
            let src = &data[(o * dk + i) * inner..(o * dk + i + 1) * inner];
            out.data[i * cols + o * inner..i * cols + o * inner + inner].copy_from_slice(src);
        }
    }
}

/// `out ← G₍ₖ₎·G₍ₖ₎ᵀ` (`dk × dk`), the mode-`k` gram of `data` with shape
/// `dims`. Allocation-free given grow-only `out`/`unfold`/`pack` buffers:
/// mode 0 runs `A·Aᵀ` on the `(d₀ × rest)` reshape, the last mode runs
/// `MᵀM` on the carrier reshape, interior modes unfold into `unfold` first.
pub fn mode_gram_into(
    data: &[f32],
    dims: &[usize],
    k: usize,
    out: &mut Matrix,
    unfold: &mut Matrix,
    pack: &mut Vec<f32>,
) {
    let (outer, dk, inner) = split_at_mode(dims, k);
    debug_assert_eq!(data.len(), outer * dk * inner, "data/shape mismatch");
    let rest = outer * inner;
    out.reuse_shape(dk, dk);
    if outer == 1 {
        // First (or only) mode: data IS the (dk × inner) unfolding.
        gemm_nt_into(dk, rest, dk, data, data, &mut out.data, pack);
    } else if inner == 1 {
        // Last mode: data reshapes to M (rest × dk); G₍ₖ₎G₍ₖ₎ᵀ = MᵀM.
        gemm_tn_into(dk, rest, dk, data, data, &mut out.data);
    } else {
        unfold_into(data, dims, k, unfold);
        gemm_nt_into(dk, rest, dk, &unfold.data, &unfold.data, &mut out.data, pack);
    }
}

/// Allocating convenience wrapper over [`mode_gram_into`] (init/refresh-time
/// and test callers).
pub fn mode_gram(data: &[f32], dims: &[usize], k: usize) -> Matrix {
    let mut out = Matrix::zeros(0, 0);
    let mut unfold = Matrix::zeros(0, 0);
    let mut pack = Vec::new();
    mode_gram_into(data, dims, k, &mut out, &mut unfold, &mut pack);
    out
}

/// Mode-`k` product: every mode-`k` fiber `f` of `src` is replaced by
/// `Qᵀ·f` (`transpose_q == true`, the into-basis rotation) or `Q·f`
/// (`false`, the back-rotation / symmetric-factor application). `src` and
/// `dst` must be distinct buffers of `numel` elements; `q` is `dk × dk`.
///
/// Executes as contiguous-slice GEMMs: the last mode is one `(rest × dk)`
/// row-wise product, earlier modes run one `(dk × inner)` GEMM per outer
/// slice. No allocation beyond grow-only `pack`.
pub fn mode_apply_into(
    src: &[f32],
    dst: &mut [f32],
    dims: &[usize],
    k: usize,
    q: &Matrix,
    transpose_q: bool,
    pack: &mut Vec<f32>,
) {
    let (outer, dk, inner) = split_at_mode(dims, k);
    debug_assert_eq!(src.len(), outer * dk * inner, "src/shape mismatch");
    debug_assert_eq!(dst.len(), src.len(), "dst/shape mismatch");
    assert_eq!((q.rows, q.cols), (dk, dk), "mode-{k} factor must be {dk}×{dk}");
    if inner == 1 {
        // Fibers are the rows of the (outer × dk) reshape: Qᵀf ≡ row·Q,
        // Q·f ≡ row·Qᵀ.
        if transpose_q {
            gemm_into(outer, dk, dk, src, &q.data, dst);
        } else {
            gemm_nt_into(outer, dk, dk, src, &q.data, dst, pack);
        }
    } else {
        for o in 0..outer {
            let s = &src[o * dk * inner..(o + 1) * dk * inner];
            let d = &mut dst[o * dk * inner..(o + 1) * dk * inner];
            if transpose_q {
                gemm_tn_into(dk, dk, inner, &q.data, s, d);
            } else {
                gemm_into(dk, dk, inner, &q.data, s, d);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tensor3(rng: &mut Rng, d: [usize; 3]) -> (Vec<f32>, Vec<usize>) {
        let n: usize = d.iter().product();
        let mut data = vec![0.0f32; n];
        rng.fill_normal(&mut data, 1.0);
        (data, d.to_vec())
    }

    /// Reference mode-k unfolding via explicit index arithmetic.
    fn unfold_ref(data: &[f32], dims: &[usize], k: usize) -> Matrix {
        let (outer, dk, inner) = split_at_mode(dims, k);
        Matrix::from_fn(dk, outer * inner, |i, col| {
            let (o, j) = (col / inner, col % inner);
            data[(o * dk + i) * inner + j]
        })
    }

    #[test]
    fn shape_basics_and_carrier() {
        let s = TensorShape::new(vec![3, 4, 5]);
        assert_eq!((s.rank(), s.numel()), (3, 60));
        assert_eq!(s.carrier(), (12, 5));
        assert_eq!(TensorShape::matrix(7, 2).carrier(), (7, 2));
        assert_eq!(TensorShape::new(vec![9]).carrier(), (1, 9));
        assert_eq!(format!("{s}"), "3×4×5");
    }

    #[test]
    #[should_panic]
    fn zero_dim_rejected() {
        let _ = TensorShape::new(vec![3, 0]);
    }

    #[test]
    fn merge_adjacent_greedy() {
        let s = TensorShape::new(vec![2, 3, 4, 5]);
        assert_eq!(s.merge_adjacent(6).dims(), &[6, 4, 5]);
        assert_eq!(s.merge_adjacent(24).dims(), &[24, 5]);
        assert_eq!(s.merge_adjacent(1000).dims(), &[120]);
        assert_eq!(s.merge_adjacent(0).dims(), s.dims(), "0 disables merging");
        assert_eq!(s.merge_adjacent(6).numel(), s.numel());
    }

    #[test]
    fn effective_squeezes_and_merges_only_rank3_plus() {
        // Rank ≤ 2 is untouched — the matrix path stays the reference.
        let m = TensorShape::matrix(1, 8);
        assert_eq!(m.effective(1000), m);
        // Size-1 modes drop; [2,1,3] is really a 2×3 matrix.
        assert_eq!(TensorShape::new(vec![2, 1, 3]).effective(0).dims(), &[2, 3]);
        // Merging applies after the squeeze.
        assert_eq!(TensorShape::new(vec![2, 3, 4]).effective(6).dims(), &[6, 4]);
        assert_eq!(TensorShape::new(vec![2, 3, 4]).effective(0).dims(), &[2, 3, 4]);
        assert_eq!(TensorShape::new(vec![1, 1, 1]).effective(0).dims(), &[1]);
    }

    #[test]
    fn unfold_matches_reference_all_modes() {
        let mut rng = Rng::new(11);
        let (data, dims) = tensor3(&mut rng, [3, 4, 5]);
        for k in 0..3 {
            let mut out = Matrix::zeros(0, 0);
            unfold_into(&data, &dims, k, &mut out);
            let want = unfold_ref(&data, &dims, k);
            assert_eq!(out, want, "mode {k}");
        }
    }

    #[test]
    fn mode_gram_matches_unfold_product() {
        let mut rng = Rng::new(12);
        let (data, dims) = tensor3(&mut rng, [3, 4, 5]);
        for k in 0..3 {
            let got = mode_gram(&data, &dims, k);
            let unf = unfold_ref(&data, &dims, k);
            let want = unf.matmul_nt(&unf);
            assert!(
                got.max_abs_diff(&want) < 1e-4,
                "mode {k}: {}",
                got.max_abs_diff(&want)
            );
            assert_eq!((got.rows, got.cols), (dims[k], dims[k]));
        }
    }

    #[test]
    fn mode_apply_matches_unfolded_gemm() {
        let mut rng = Rng::new(13);
        let (data, dims) = tensor3(&mut rng, [3, 4, 5]);
        for k in 0..3 {
            let q = Matrix::randn(&mut rng, dims[k], dims[k], 1.0);
            for &transpose in &[true, false] {
                let mut dst = vec![0.0f32; data.len()];
                let mut pack = Vec::new();
                mode_apply_into(&data, &mut dst, &dims, k, &q, transpose, &mut pack);
                // Reference: unfold, multiply, compare unfolded results.
                let unf = unfold_ref(&data, &dims, k);
                let want = if transpose { q.matmul_tn(&unf) } else { q.matmul(&unf) };
                let got = unfold_ref(&dst, &dims, k);
                assert!(
                    got.max_abs_diff(&want) < 1e-4,
                    "mode {k} transpose={transpose}: {}",
                    got.max_abs_diff(&want)
                );
            }
        }
    }

    #[test]
    fn mode_apply_round_trips_with_orthonormal_q() {
        use crate::linalg::qr_positive;
        let mut rng = Rng::new(14);
        let (data, dims) = tensor3(&mut rng, [4, 3, 6]);
        for k in 0..3 {
            let (q, _) = qr_positive(&Matrix::randn(&mut rng, dims[k], dims[k], 1.0));
            let mut mid = vec![0.0f32; data.len()];
            let mut back = vec![0.0f32; data.len()];
            let mut pack = Vec::new();
            mode_apply_into(&data, &mut mid, &dims, k, &q, true, &mut pack);
            mode_apply_into(&mid, &mut back, &dims, k, &q, false, &mut pack);
            for (a, b) in data.iter().zip(&back) {
                assert!((a - b).abs() < 1e-4, "mode {k}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn rank2_mode_ops_match_matrix_products() {
        // The rank-2 special case must agree with the plain matrix algebra
        // the 2-D eigenbasis uses: mode-0 gram = G·Gᵀ, mode-1 gram = Gᵀ·G.
        let mut rng = Rng::new(15);
        let g = Matrix::randn(&mut rng, 5, 7, 1.0);
        let dims = vec![5, 7];
        assert!(mode_gram(&g.data, &dims, 0).max_abs_diff(&g.matmul_nt(&g)) < 1e-4);
        assert!(mode_gram(&g.data, &dims, 1).max_abs_diff(&g.matmul_tn(&g)) < 1e-4);
        let q = Matrix::randn(&mut rng, 5, 5, 1.0);
        let mut dst = vec![0.0f32; g.data.len()];
        let mut pack = Vec::new();
        mode_apply_into(&g.data, &mut dst, &dims, 0, &q, true, &mut pack);
        let want = q.matmul_tn(&g);
        assert!(
            Matrix::from_vec(5, 7, dst).max_abs_diff(&want) < 1e-4,
            "mode-0 rotation disagrees with QᵀG"
        );
    }
}
