//! Blocked f32 GEMM kernel for the native hot paths (preconditioner updates
//! `GGᵀ`, projections `QᵀGQ` in the oracle/refresh code).
//!
//! Strategy: ikj loop order (unit-stride on both B-row and C-row) with k-tiled
//! blocking for L1/L2 locality and a 4-wide manually unrolled inner update
//! that the compiler auto-vectorizes. This is the §Perf-tuned version; see
//! EXPERIMENTS.md §Perf for the before/after on the baseline naive kernel.

/// `c[m×n] += 0; c = a[m×k] · b[k×n]` — all row-major, `c` assumed zeroed.
pub fn gemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    const KB: usize = 256; // k-block: keeps a KB×n panel of B in cache
    for k0 in (0..k).step_by(KB) {
        let k1 = (k0 + KB).min(k);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            for p in k0..k1 {
                let av = arow[p];
                if av == 0.0 {
                    continue;
                }
                let brow = &b[p * n..(p + 1) * n];
                axpy(av, brow, crow);
            }
        }
    }
}

/// crow += av * brow. Iterator zip elides all bounds checks, so LLVM emits
/// packed mul/add over the whole row (§Perf iteration 1: the previous
/// index-based 4-unroll kept bounds checks alive and ran ~6× slower).
#[inline]
fn axpy(av: f32, brow: &[f32], crow: &mut [f32]) {
    for (c, &b) in crow.iter_mut().zip(brow) {
        *c += av * b;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for p in 0..k {
                    acc += a[i * k + p] as f64 * b[p * n + j] as f64;
                }
                c[i * n + j] = acc as f32;
            }
        }
        c
    }

    #[test]
    fn matches_naive_various_shapes() {
        let mut rng = Rng::new(77);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 2), (17, 33, 9), (64, 300, 48)] {
            let mut a = vec![0.0f32; m * k];
            let mut b = vec![0.0f32; k * n];
            rng.fill_normal(&mut a, 1.0);
            rng.fill_normal(&mut b, 1.0);
            let mut c = vec![0.0f32; m * n];
            gemm(m, k, n, &a, &b, &mut c);
            let want = naive(m, k, n, &a, &b);
            for (x, y) in c.iter().zip(&want) {
                assert!((x - y).abs() < 1e-3 * (1.0 + y.abs()), "{x} vs {y}");
            }
        }
    }

    #[test]
    fn zero_inputs() {
        let mut c = vec![0.0f32; 4];
        gemm(2, 3, 2, &[0.0; 6], &[0.0; 6], &mut c);
        assert_eq!(c, vec![0.0; 4]);
    }
}
