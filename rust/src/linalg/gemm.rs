//! Blocked f32 GEMM kernel family for the native hot paths (preconditioner
//! products `GGᵀ`/`GᵀG`, projections `QᵀGQ`, refresh-time power iterations).
//!
//! Three transpose variants share one inner loop shape — k-blocked, axpy-form
//! (`crow += av · brow`), unit-stride on both the B-row and the C-row — so
//! the compiler emits packed mul/add over whole rows for all of them:
//!
//! - [`gemm_into`]    — `C = A·B`       (`A: m×k`, `B: k×n`)
//! - [`gemm_tn_into`] — `C = Aᵀ·B`      (`A: k×m`, `B: k×n`)
//! - [`gemm_nt_into`] — `C = A·Bᵀ`      (`A: m×k`, `B: n×k`), via **B-panel
//!   packing**: `Bᵀ` is transposed once into a caller-provided grow-only
//!   buffer and the product runs as the plain `NN` kernel over the packed
//!   panel. The previous bespoke NT loop was a per-element dot product whose
//!   serial accumulation chain cannot vectorize; packing converts it to the
//!   axpy form.
//!
//! The `*_into` kernels are **serial and allocation-free** (given a
//! pre-grown pack buffer) — they are the steady-state optimizer step path.
//! The `par_*` drivers row-partition `C` across a process-wide
//! [`ThreadPool`] (`soap-worker-*` threads, size from
//! `SOAP_GEMM_THREADS` or `available_parallelism`) for the large
//! refresh-time products; row partitioning preserves each element's
//! accumulation order, so serial and parallel results are **bitwise
//! identical** at any worker count.
//!
//! Accumulation order is ascending-`p` for every element in every variant —
//! the same order as the pre-blocked reference loops — so golden trajectory
//! tests stay bitwise across this kernel family. There is deliberately *no*
//! skip of zero `A` elements: the old `av == 0.0` `continue` silently
//! dropped NaN/Inf propagation from `B` (a poisoned gradient could be
//! masked to 0 by a zero momentum row); see `nan_propagates_through_zero_a`.
//!
//! The inner cores are **runtime-dispatched** between this portable scalar
//! kernel and the register-tiled AVX2/NEON microkernels in
//! [`simd`](super::simd) — `SOAP_GEMM_KERNEL=scalar|simd|auto` (default
//! `auto`: SIMD whenever the ISA is present). The SIMD kernels preserve the
//! per-element ascending-`p` mul-then-add sequence, so **scalar ≡ SIMD ≡
//! parallel bitwise** and the kernel choice can never change a trajectory.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

use super::simd;
use crate::util::pool::ThreadPool;

/// k-block: keeps a KB×n panel of B in cache.
const KB: usize = 256;
/// i-block for the TN kernel: bounds the C working set per B sweep.
const IB: usize = 64;
/// Don't parallelize below this many flops (2·m·k·n) — fan-out overhead
/// dominates small products, and the step path must stay allocation-free.
const PAR_MIN_FLOPS: usize = 1 << 22;
/// Minimum C rows per parallel chunk.
const PAR_MIN_ROWS: usize = 16;

/// Which inner kernel the GEMM family runs. Selected once per process from
/// `SOAP_GEMM_KERNEL` (`scalar` | `simd` | `auto`, default `auto` = SIMD
/// when the CPU has AVX2/NEON), overridable in-process via
/// [`force_gemm_kernel`] for A/B tests. Both kernels are **bitwise
/// identical** (see `simd.rs` module docs), so the choice affects latency
/// only, never trajectories.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GemmKernel {
    /// Portable axpy core — LLVM auto-vectorizes it, but without register
    /// tiling.
    Scalar,
    /// Explicit register-tiled AVX2/NEON microkernel.
    Simd,
}

impl GemmKernel {
    pub fn name(&self) -> &'static str {
        match self {
            GemmKernel::Scalar => "scalar",
            GemmKernel::Simd => "simd",
        }
    }
}

/// In-process kernel override: 0 = unset (env / auto), 1 = scalar,
/// 2 = simd. Lets tests and benches flip kernels without re-spawning the
/// process (the env choice is latched in a `OnceLock`).
static KERNEL_OVERRIDE: AtomicU8 = AtomicU8::new(0);

/// Force the GEMM kernel for this process, or `None` to return to the
/// `SOAP_GEMM_KERNEL`/auto choice. Forcing `Simd` on a CPU without
/// AVX2/NEON falls back to scalar (with a one-time warning path through
/// [`parse_kernel`] semantics: the caller asked for something unavailable).
pub fn force_gemm_kernel(kernel: Option<GemmKernel>) {
    let v = match kernel {
        None => 0,
        Some(GemmKernel::Scalar) => 1,
        Some(GemmKernel::Simd) if simd::available() => 2,
        Some(GemmKernel::Simd) => 1,
    };
    KERNEL_OVERRIDE.store(v, Ordering::Relaxed);
}

/// Parse `SOAP_GEMM_KERNEL`. Pure so the unit tests can cover every arm;
/// returns the resolved kernel plus an optional warning line (invalid
/// token, or `simd` requested without an ISA).
fn parse_kernel(raw: Option<&str>, simd_ok: bool) -> (GemmKernel, Option<String>) {
    let auto = if simd_ok { GemmKernel::Simd } else { GemmKernel::Scalar };
    match raw {
        None => (auto, None),
        Some(s) => match s.to_ascii_lowercase().as_str() {
            "auto" => (auto, None),
            "scalar" => (GemmKernel::Scalar, None),
            "simd" if simd_ok => (GemmKernel::Simd, None),
            "simd" => (
                GemmKernel::Scalar,
                Some(
                    "SOAP_GEMM_KERNEL=simd requested but this CPU has no AVX2/NEON; \
                     using the scalar kernel"
                        .to_string(),
                ),
            ),
            _ => (
                auto,
                Some(format!(
                    "invalid SOAP_GEMM_KERNEL '{s}': expected scalar, simd, or auto; \
                     using auto ({})",
                    auto.name()
                )),
            ),
        },
    }
}

/// Parse `SOAP_GEMM_THREADS`. Pure for unit testing; invalid values (empty,
/// non-numeric, `0`) produce a warning naming the bad value and the
/// fallback instead of a silent default.
fn parse_threads(raw: Option<&str>, default: usize) -> (usize, Option<String>) {
    match raw {
        None => (default, None),
        Some(s) => match s.trim().parse::<usize>() {
            Ok(n) if n >= 1 => (n, None),
            _ => (
                default,
                Some(format!(
                    "invalid SOAP_GEMM_THREADS '{s}': expected a positive integer; \
                     using {default} (available parallelism)"
                )),
            ),
        },
    }
}

/// The env-selected kernel, parsed once (with its one-time stderr warning).
fn env_kernel() -> GemmKernel {
    static KERNEL: OnceLock<GemmKernel> = OnceLock::new();
    *KERNEL.get_or_init(|| {
        let raw = std::env::var("SOAP_GEMM_KERNEL").ok();
        let (kernel, warn) = parse_kernel(raw.as_deref(), simd::available());
        if let Some(w) = warn {
            eprintln!("[soap-gemm] {w}");
        }
        kernel
    })
}

/// Kernel in force right now: the [`force_gemm_kernel`] override when set,
/// else the latched env choice.
fn active_kernel() -> GemmKernel {
    match KERNEL_OVERRIDE.load(Ordering::Relaxed) {
        1 => GemmKernel::Scalar,
        2 => GemmKernel::Simd,
        _ => env_kernel(),
    }
}

/// Name of the kernel currently in force (`"scalar"` / `"simd"`) — surfaced
/// by the step-latency bench so baselines record which path they measured.
pub fn active_gemm_kernel_name() -> &'static str {
    active_kernel().name()
}

/// The process-wide pool backing the `par_*` drivers. `None` when
/// single-threaded (1 CPU or `SOAP_GEMM_THREADS=1`). Never dropped — the
/// workers are idle daemons between fan-outs.
fn linalg_pool() -> Option<&'static ThreadPool> {
    static POOL: OnceLock<Option<ThreadPool>> = OnceLock::new();
    POOL.get_or_init(|| {
        let default = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let raw = std::env::var("SOAP_GEMM_THREADS").ok();
        let (threads, warn) = parse_threads(raw.as_deref(), default);
        if let Some(w) = warn {
            eprintln!("[soap-gemm] {w}");
        }
        (threads > 1).then(|| ThreadPool::new(threads))
    })
    .as_ref()
}

/// crow += av * brow. Iterator zip elides all bounds checks, so LLVM emits
/// packed mul/add over the whole row (§Perf iteration 1: the previous
/// index-based 4-unroll kept bounds checks alive and ran ~6× slower).
#[inline]
fn axpy(av: f32, brow: &[f32], crow: &mut [f32]) {
    for (c, &b) in crow.iter_mut().zip(brow) {
        *c += av * b;
    }
}

/// `c[rows×n] += a[rows×k] · b[k×n]` — the portable NN accumulation core.
fn nn_acc_scalar(rows: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    for k0 in (0..k).step_by(KB) {
        let k1 = (k0 + KB).min(k);
        for i in 0..rows {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            for p in k0..k1 {
                axpy(arow[p], &b[p * n..(p + 1) * n], crow);
            }
        }
    }
}

/// NN core, dispatched on the active kernel. Every `gemm_into` /
/// `gemm_nt_into` call — serial or a `par_*` chunk — funnels through here,
/// so all drivers inherit the SIMD path from one switch.
fn nn_acc(rows: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    match active_kernel() {
        GemmKernel::Scalar => nn_acc_scalar(rows, k, n, a, b, c),
        GemmKernel::Simd => simd::nn_acc(rows, k, n, a, b, c),
    }
}

/// `c[rows×n] = (Aᵀ·B)[i0..i0+rows, :]` with `A: k×m`, `B: k×n` — the
/// portable TN core. `c` is the chunk's rows only; `i0` is its absolute
/// offset into Aᵀ's rows (= A's columns).
#[allow(clippy::too_many_arguments)]
fn tn_rows_scalar(i0: usize, rows: usize, m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    c.fill(0.0);
    for ib in (0..rows).step_by(IB) {
        let ie = (ib + IB).min(rows);
        for p in 0..k {
            let arow = &a[p * m..(p + 1) * m];
            let brow = &b[p * n..(p + 1) * n];
            for i in ib..ie {
                axpy(arow[i0 + i], brow, &mut c[i * n..(i + 1) * n]);
            }
        }
    }
}

/// TN core, dispatched on the active kernel (serial `gemm_tn_into` and
/// every `par_gemm_tn_into` chunk).
#[allow(clippy::too_many_arguments)]
fn tn_rows(i0: usize, rows: usize, m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    match active_kernel() {
        GemmKernel::Scalar => tn_rows_scalar(i0, rows, m, k, n, a, b, c),
        GemmKernel::Simd => simd::tn_rows(i0, rows, m, k, n, a, b, c),
    }
}

/// Pack `Bᵀ` (`B: n×k`, row-major) into `pack` as a `k×n` row-major panel.
/// Grow-only: the buffer reallocates at most up to the largest `B` ever
/// packed through it.
fn pack_bt(k: usize, n: usize, b: &[f32], pack: &mut Vec<f32>) {
    pack.resize(k * n, 0.0);
    for j in 0..n {
        let brow = &b[j * k..(j + 1) * k];
        for (p, &x) in brow.iter().enumerate() {
            pack[p * n + j] = x;
        }
    }
}

/// `c[m×n] = a[m×k] · b[k×n]` (overwrites `c`). Serial, allocation-free.
pub fn gemm_into(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    c.fill(0.0);
    nn_acc(m, k, n, a, b, c);
}

/// `c[m×n] = aᵀ · b` with `a: k×m`, `b: k×n` (overwrites `c`). Serial,
/// allocation-free; the transpose is never materialized.
pub fn gemm_tn_into(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    tn_rows(0, m, m, k, n, a, b, c);
}

/// `c[m×n] = a · bᵀ` with `a: m×k`, `b: n×k` (overwrites `c`). `Bᵀ` is
/// packed into `pack` (grow-only; zero allocations once grown), then the
/// product runs as the vectorizable NN kernel.
pub fn gemm_nt_into(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32], pack: &mut Vec<f32>) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    pack_bt(k, n, b, pack);
    c.fill(0.0);
    nn_acc(m, k, n, a, pack, c);
}

/// Rows per parallel chunk, or `None` when the product should stay serial
/// (small, single CPU, or not enough rows to split).
fn par_chunk_rows(m: usize, k: usize, n: usize) -> Option<(usize, &'static ThreadPool)> {
    // Size gates BEFORE touching the pool: the first large product — not the
    // first product of any size — is what spawns the worker threads.
    if 2usize.saturating_mul(m).saturating_mul(k).saturating_mul(n) < PAR_MIN_FLOPS {
        return None;
    }
    let max_chunks = m / PAR_MIN_ROWS;
    if max_chunks < 2 {
        return None;
    }
    let pool = linalg_pool()?;
    let chunks = pool.size().min(max_chunks);
    if chunks < 2 {
        return None;
    }
    Some((m.div_ceil(chunks), pool))
}

/// [`gemm_into`], row-partitioned across the process pool when large.
/// Bitwise identical to the serial kernel at any worker count.
pub fn par_gemm_into(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    if m == 0 || n == 0 {
        return;
    }
    match par_chunk_rows(m, k, n) {
        Some((chunk, pool)) => {
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            for (ci, c_chunk) in c.chunks_mut(chunk * n).enumerate() {
                let rows = c_chunk.len() / n;
                let i0 = ci * chunk;
                let a_chunk = &a[i0 * k..(i0 + rows) * k];
                jobs.push(Box::new(move || {
                    c_chunk.fill(0.0);
                    nn_acc(rows, k, n, a_chunk, b, c_chunk);
                }));
            }
            pool.scope_borrowed(jobs);
        }
        None => gemm_into(m, k, n, a, b, c),
    }
}

/// [`gemm_tn_into`], row-partitioned across the process pool when large.
pub fn par_gemm_tn_into(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    if m == 0 || n == 0 {
        return;
    }
    match par_chunk_rows(m, k, n) {
        Some((chunk, pool)) => {
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            for (ci, c_chunk) in c.chunks_mut(chunk * n).enumerate() {
                let rows = c_chunk.len() / n;
                let i0 = ci * chunk;
                jobs.push(Box::new(move || {
                    tn_rows(i0, rows, m, k, n, a, b, c_chunk);
                }));
            }
            pool.scope_borrowed(jobs);
        }
        None => gemm_tn_into(m, k, n, a, b, c),
    }
}

/// [`gemm_nt_into`], row-partitioned across the process pool when large.
/// The packed `Bᵀ` panel is built once and shared read-only by all chunks.
pub fn par_gemm_nt_into(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32], pack: &mut Vec<f32>) {
    if m == 0 || n == 0 {
        return;
    }
    match par_chunk_rows(m, k, n) {
        Some((chunk, pool)) => {
            pack_bt(k, n, b, pack);
            let packed: &[f32] = pack;
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            for (ci, c_chunk) in c.chunks_mut(chunk * n).enumerate() {
                let rows = c_chunk.len() / n;
                let i0 = ci * chunk;
                let a_chunk = &a[i0 * k..(i0 + rows) * k];
                jobs.push(Box::new(move || {
                    c_chunk.fill(0.0);
                    nn_acc(rows, k, n, a_chunk, packed, c_chunk);
                }));
            }
            pool.scope_borrowed(jobs);
        }
        None => gemm_nt_into(m, k, n, a, b, c, pack),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// f64 reference: `op(A)·op(B)` with per-element f64 accumulation.
    fn naive(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], ta: bool, tb: bool) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for p in 0..k {
                    let av = if ta { a[p * m + i] } else { a[i * k + p] };
                    let bv = if tb { b[j * k + p] } else { b[p * n + j] };
                    acc += av as f64 * bv as f64;
                }
                c[i * n + j] = acc as f32;
            }
        }
        c
    }

    fn close(got: &[f32], want: &[f32]) {
        for (x, y) in got.iter().zip(want) {
            assert!((x - y).abs() < 1e-3 * (1.0 + y.abs()), "{x} vs {y}");
        }
    }

    const SHAPES: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (1, 7, 1),
        (1, 1, 9),
        (5, 1, 3),
        (3, 5, 2),
        (17, 33, 9),
        (64, 300, 48),
    ];

    #[test]
    fn matches_naive_various_shapes() {
        let mut rng = Rng::new(77);
        for &(m, k, n) in SHAPES {
            let mut a = vec![0.0f32; m * k];
            let mut b = vec![0.0f32; k * n];
            rng.fill_normal(&mut a, 1.0);
            rng.fill_normal(&mut b, 1.0);
            let mut c = vec![0.0f32; m * n];
            gemm_into(m, k, n, &a, &b, &mut c);
            close(&c, &naive(m, k, n, &a, &b, false, false));
        }
    }

    #[test]
    fn into_family_matches_naive() {
        let mut rng = Rng::new(78);
        for &(m, k, n) in SHAPES {
            let mut a = vec![0.0f32; m * k];
            let mut at = vec![0.0f32; k * m];
            let mut bt = vec![0.0f32; n * k];
            let mut b = vec![0.0f32; k * n];
            rng.fill_normal(&mut a, 1.0);
            rng.fill_normal(&mut at, 1.0);
            rng.fill_normal(&mut bt, 1.0);
            rng.fill_normal(&mut b, 1.0);
            // Overwrite semantics: poison c first.
            let mut c = vec![f32::NAN; m * n];
            gemm_into(m, k, n, &a, &b, &mut c);
            close(&c, &naive(m, k, n, &a, &b, false, false));
            c.fill(f32::NAN);
            gemm_tn_into(m, k, n, &at, &b, &mut c);
            close(&c, &naive(m, k, n, &at, &b, true, false));
            c.fill(f32::NAN);
            let mut pack = Vec::new();
            gemm_nt_into(m, k, n, &a, &bt, &mut c, &mut pack);
            close(&c, &naive(m, k, n, &a, &bt, false, true));
        }
    }

    #[test]
    fn parallel_matches_serial_bitwise() {
        let mut rng = Rng::new(79);
        // Big enough to cross PAR_MIN_FLOPS with rows to split.
        let (m, k, n) = (160, 130, 120);
        let mut a = vec![0.0f32; m * k];
        let mut b = vec![0.0f32; k * n];
        let mut bt = vec![0.0f32; n * k];
        rng.fill_normal(&mut a, 1.0);
        rng.fill_normal(&mut b, 1.0);
        rng.fill_normal(&mut bt, 1.0);
        let (mut s, mut p) = (vec![0.0f32; m * n], vec![0.0f32; m * n]);

        gemm_into(m, k, n, &a, &b, &mut s);
        par_gemm_into(m, k, n, &a, &b, &mut p);
        assert_eq!(s, p, "NN parallel drifted from serial");

        // TN: treat `a` as a m×k matrix whose transpose is k×m; result k×n.
        let mut b2 = vec![0.0f32; m * n];
        rng.fill_normal(&mut b2, 1.0);
        let mut s2 = vec![0.0f32; k * n];
        let mut p2 = vec![0.0f32; k * n];
        gemm_tn_into(k, m, n, &a, &b2, &mut s2);
        par_gemm_tn_into(k, m, n, &a, &b2, &mut p2);
        assert_eq!(s2, p2, "TN parallel drifted from serial");

        let mut s3 = vec![0.0f32; m * n];
        let mut p3 = vec![0.0f32; m * n];
        let (mut pk1, mut pk2) = (Vec::new(), Vec::new());
        gemm_nt_into(m, k, n, &a, &bt, &mut s3, &mut pk1);
        par_gemm_nt_into(m, k, n, &a, &bt, &mut p3, &mut pk2);
        assert_eq!(s3, p3, "NT parallel drifted from serial");
    }

    #[test]
    fn zero_inputs() {
        let mut c = vec![0.0f32; 4];
        gemm_into(2, 3, 2, &[0.0; 6], &[0.0; 6], &mut c);
        assert_eq!(c, vec![0.0; 4]);
    }

    #[test]
    fn nan_propagates_through_zero_a() {
        // Regression: the old kernel skipped `av == 0.0` rows of B entirely,
        // so a NaN-poisoned B could be silently masked to 0. IEEE semantics
        // demand 0·NaN = NaN.
        let a = [0.0f32, 1.0, 2.0, 3.0];
        let b = [f32::NAN, f32::NAN, 1.0, 1.0];
        let mut c = vec![0.0f32; 4];
        gemm_into(2, 2, 2, &a, &b, &mut c);
        assert!(c[0].is_nan() && c[1].is_nan(), "NaN from B masked by zero A: {c:?}");
        // Row 2 of A has no zeros — NaN still reaches it through column sums.
        assert!(c[2].is_nan() && c[3].is_nan());

        // TN variant: zero column of A against a NaN row of B.
        let at = [0.0f32, 5.0, 0.0, 7.0]; // A: 2×2, first column zero
        let mut c = vec![0.0f32; 4];
        gemm_tn_into(2, 2, 2, &at, &b, &mut c);
        assert!(c[0].is_nan() && c[1].is_nan(), "TN kernel masked NaN: {c:?}");

        // NT variant: Inf must survive too.
        let bt = [f32::INFINITY, 0.0, 0.0, 1.0];
        let mut c = vec![0.0f32; 4];
        let mut pack = Vec::new();
        gemm_nt_into(2, 2, 2, &a, &bt, &mut c, &mut pack);
        assert!(c[0].is_nan(), "0·Inf must be NaN, got {}", c[0]); // 0·Inf + 1·0
    }

    /// Bit-level comparison that treats NaN as equal to the *same* NaN bits
    /// (plain `==` would fail on any NaN).
    fn assert_bits_eq(got: &[f32], want: &[f32], what: &str) {
        assert_eq!(got.len(), want.len(), "{what}: length mismatch");
        for (idx, (x, y)) in got.iter().zip(want).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{what}: element {idx} drifted ({x} vs {y})"
            );
        }
    }

    #[test]
    fn simd_matches_scalar_bitwise_odd_shapes() {
        if !simd::available() {
            eprintln!("skipping: no SIMD ISA on this CPU");
            return;
        }
        // Odd shapes exercise every tail path: partial row tiles (rows %
        // MR), partial vectors (n % W), and k crossing the KB block edge is
        // covered by the 63..=65 band against the property that blocking
        // never changes per-element order.
        let dims: Vec<usize> = (1..=17).chain([63, 64, 65]).collect();
        let mut rng = Rng::new(4242);
        for &m in &dims {
            for &k in &dims {
                for &n in &dims {
                    let mut a = vec![0.0f32; m * k];
                    let mut b = vec![0.0f32; k * n];
                    rng.fill_normal(&mut a, 1.0);
                    rng.fill_normal(&mut b, 1.0);
                    let mut cs = vec![0.0f32; m * n];
                    let mut cv = vec![0.0f32; m * n];
                    nn_acc_scalar(m, k, n, &a, &b, &mut cs);
                    simd::nn_acc(m, k, n, &a, &b, &mut cv);
                    assert_bits_eq(&cv, &cs, &format!("NN {m}x{k}x{n}"));

                    // TN: a k×m operand produces the same m×n output shape.
                    let mut cs = vec![f32::NAN; m * n];
                    let mut cv = vec![f32::NAN; m * n];
                    let mut at = vec![0.0f32; k * m];
                    rng.fill_normal(&mut at, 1.0);
                    tn_rows_scalar(0, m, m, k, n, &at, &b, &mut cs);
                    simd::tn_rows(0, m, m, k, n, &at, &b, &mut cv);
                    assert_bits_eq(&cv, &cs, &format!("TN {m}x{k}x{n}"));
                }
            }
        }
    }

    #[test]
    fn simd_matches_scalar_on_tn_chunk_offsets() {
        if !simd::available() {
            return;
        }
        // Nonzero i0 is what the parallel TN driver feeds the core.
        let (m, k, n) = (13, 9, 11);
        let mut rng = Rng::new(4243);
        let mut a = vec![0.0f32; k * m];
        let mut b = vec![0.0f32; k * n];
        rng.fill_normal(&mut a, 1.0);
        rng.fill_normal(&mut b, 1.0);
        for i0 in [0usize, 1, 5, 12] {
            let rows = m - i0;
            let mut cs = vec![f32::NAN; rows * n];
            let mut cv = vec![f32::NAN; rows * n];
            tn_rows_scalar(i0, rows, m, k, n, &a, &b, &mut cs);
            simd::tn_rows(i0, rows, m, k, n, &a, &b, &mut cv);
            assert_bits_eq(&cv, &cs, &format!("TN i0={i0}"));
        }
    }

    #[test]
    fn simd_propagates_nan_inf_through_zero_a_like_scalar() {
        if !simd::available() {
            return;
        }
        // Zero A rows against NaN/Inf B: 0·NaN = NaN and 0·∞ = NaN must
        // survive the SIMD path too, with the exact scalar bit patterns.
        for (m, k, n) in [(4, 4, 8), (5, 3, 9), (1, 1, 1), (8, 16, 17)] {
            let mut a = vec![0.0f32; m * k]; // all-zero A
            a[m * k - 1] = 2.0;
            let mut b = vec![1.0f32; k * n];
            b[0] = f32::NAN;
            b[k * n - 1] = f32::INFINITY;
            if k * n > 1 {
                b[1] = f32::NEG_INFINITY;
            }
            let mut cs = vec![0.0f32; m * n];
            let mut cv = vec![0.0f32; m * n];
            nn_acc_scalar(m, k, n, &a, &b, &mut cs);
            simd::nn_acc(m, k, n, &a, &b, &mut cv);
            assert!(cs.iter().any(|x| x.is_nan()), "poison lost in scalar reference");
            assert_bits_eq(&cv, &cs, &format!("NN poison {m}x{k}x{n}"));

            let mut cs = vec![0.0f32; m * n];
            let mut cv = vec![0.0f32; m * n];
            let at = vec![0.0f32; k * m];
            tn_rows_scalar(0, m, m, k, n, &at, &b, &mut cs);
            simd::tn_rows(0, m, m, k, n, &at, &b, &mut cv);
            assert!(cs.iter().any(|x| x.is_nan()), "poison lost in scalar TN reference");
            assert_bits_eq(&cv, &cs, &format!("TN poison {m}x{k}x{n}"));
        }
    }

    #[test]
    fn kernel_env_parse_covers_all_arms() {
        // SOAP_GEMM_KERNEL.
        assert_eq!(parse_kernel(None, true), (GemmKernel::Simd, None));
        assert_eq!(parse_kernel(None, false), (GemmKernel::Scalar, None));
        assert_eq!(parse_kernel(Some("auto"), true), (GemmKernel::Simd, None));
        assert_eq!(parse_kernel(Some("AUTO"), false), (GemmKernel::Scalar, None));
        assert_eq!(parse_kernel(Some("scalar"), true), (GemmKernel::Scalar, None));
        assert_eq!(parse_kernel(Some("simd"), true), (GemmKernel::Simd, None));
        let (k, warn) = parse_kernel(Some("simd"), false);
        assert_eq!(k, GemmKernel::Scalar);
        assert!(warn.unwrap().contains("no AVX2/NEON"));
        let (k, warn) = parse_kernel(Some("avx512"), true);
        assert_eq!(k, GemmKernel::Simd);
        let w = warn.unwrap();
        assert!(w.contains("'avx512'") && w.contains("scalar, simd, or auto"), "{w}");

        // SOAP_GEMM_THREADS: empty, non-numeric, and zero all warn by name.
        assert_eq!(parse_threads(None, 8), (8, None));
        assert_eq!(parse_threads(Some("4"), 8), (4, None));
        assert_eq!(parse_threads(Some(" 2 "), 8), (2, None));
        for bad in ["abc", "", "0", "-3", "1.5"] {
            let (n, warn) = parse_threads(Some(bad), 8);
            assert_eq!(n, 8, "bad value {bad:?} must fall back");
            let w = warn.expect("invalid value must warn");
            assert!(w.contains(&format!("'{bad}'")) && w.contains("using 8"), "{w}");
        }
    }

    #[test]
    fn forced_kernel_overrides_and_restores() {
        // Single test owns the global override so parallel tests never see a
        // half-flipped state (results would still match — both kernels are
        // bitwise identical — but the name assertions below would race).
        force_gemm_kernel(Some(GemmKernel::Scalar));
        assert_eq!(active_gemm_kernel_name(), "scalar");
        force_gemm_kernel(Some(GemmKernel::Simd));
        if simd::available() {
            assert_eq!(active_gemm_kernel_name(), "simd");
        } else {
            assert_eq!(active_gemm_kernel_name(), "scalar", "no-ISA force must clamp");
        }
        // Forced kernels drive the public entry points end to end.
        let (m, k, n) = (6, 5, 7);
        let a: Vec<f32> = (0..m * k).map(|i| (i as f32).sin()).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i as f32).cos()).collect();
        let mut c_simd = vec![0.0f32; m * n];
        gemm_into(m, k, n, &a, &b, &mut c_simd);
        force_gemm_kernel(Some(GemmKernel::Scalar));
        let mut c_scalar = vec![0.0f32; m * n];
        gemm_into(m, k, n, &a, &b, &mut c_scalar);
        assert_bits_eq(&c_simd, &c_scalar, "forced kernels");
        force_gemm_kernel(None);
    }

    #[test]
    fn pack_buffer_grows_only() {
        let mut pack = Vec::new();
        let a = vec![1.0f32; 8 * 6];
        let b = vec![1.0f32; 4 * 6];
        let mut c = vec![0.0f32; 8 * 4];
        gemm_nt_into(8, 6, 4, &a, &b, &mut c, &mut pack);
        let cap = pack.capacity();
        assert!(cap >= 24);
        // Smaller product: no shrink, no realloc.
        let mut c2 = vec![0.0f32; 2 * 2];
        gemm_nt_into(2, 3, 2, &a[..6], &b[..6], &mut c2, &mut pack);
        assert_eq!(pack.capacity(), cap);
    }
}
