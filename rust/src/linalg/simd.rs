//! Explicit SIMD microkernels for the GEMM family (AVX2 on x86_64, NEON on
//! aarch64), runtime-dispatched by `gemm.rs` via `SOAP_GEMM_KERNEL`.
//!
//! # Why the SIMD path is bitwise identical to the scalar path
//!
//! Every element of `C` is produced by the exact same sequence of IEEE-754
//! f32 operations as the scalar kernel: accumulation runs in ascending `p`,
//! and each step is a separate multiply followed by a separate add — never a
//! fused multiply-add, whose single rounding would differ from the scalar
//! mul/add pair. Vector lanes compute the same elementwise f32 ops as scalar
//! instructions, so tiling rows into registers and columns into vectors
//! reorders *which elements* are computed when, but never the op sequence
//! *within* an element. The loop-nest order over `i`/`j` is therefore free
//! to change; only the per-element `p` order and the op shapes are pinned.
//!
//! Like the scalar kernel there is deliberately no skip of zero `A`
//! elements: `0 · NaN = NaN` and `0 · ∞ = NaN` must propagate (see
//! `nan_propagates_through_zero_a` in `gemm.rs`).

/// k-block: matches the scalar kernel's panel height. Blocking advances in
/// ascending `p`, so it affects cache behavior only — never the per-element
/// accumulation order.
const KB: usize = 256;

/// Rows of `C` held in registers per tile.
const MR: usize = 4;

/// Is a SIMD kernel available on this CPU? x86_64 requires AVX2 (checked at
/// runtime, cached); NEON is baseline on aarch64; other arches have no
/// kernel and always run scalar.
pub fn available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2")
    }
    #[cfg(target_arch = "aarch64")]
    {
        true
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        false
    }
}

/// `c[rows×n] += a[rows×k] · b[k×n]` — SIMD twin of the scalar `nn_acc`.
/// Bitwise identical to it (see module docs). Panics when no SIMD ISA is
/// available; the dispatcher in `gemm.rs` only routes here after checking
/// [`available`].
#[allow(unused_variables)]
pub fn nn_acc(rows: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    assert!(available(), "SIMD GEMM kernel dispatched on a CPU without AVX2/NEON");
    debug_assert!(a.len() >= rows * k);
    debug_assert!(b.len() >= k * n);
    debug_assert!(c.len() >= rows * n);
    #[cfg(target_arch = "x86_64")]
    // SAFETY: bounds checked above; AVX2 presence checked by `available`.
    unsafe {
        avx2::nn_acc(rows, k, n, a, b, c)
    }
    #[cfg(target_arch = "aarch64")]
    // SAFETY: bounds checked above; NEON is baseline on aarch64.
    unsafe {
        neon::nn_acc(rows, k, n, a, b, c)
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    unreachable!()
}

/// `c[rows×n] = (Aᵀ·B)[i0..i0+rows, :]` with `A: k×m`, `B: k×n` — SIMD twin
/// of the scalar `tn_rows` (zero-init accumulators, ascending `p` over the
/// full `0..k`, mul then add). Bitwise identical to it.
#[allow(unused_variables, clippy::too_many_arguments)]
pub fn tn_rows(i0: usize, rows: usize, m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    assert!(available(), "SIMD GEMM kernel dispatched on a CPU without AVX2/NEON");
    debug_assert!(a.len() >= k * m);
    debug_assert!(b.len() >= k * n);
    debug_assert!(c.len() >= rows * n);
    debug_assert!(i0 + rows <= m);
    #[cfg(target_arch = "x86_64")]
    // SAFETY: bounds checked above; AVX2 presence checked by `available`.
    unsafe {
        avx2::tn_rows(i0, rows, m, k, n, a, b, c)
    }
    #[cfg(target_arch = "aarch64")]
    // SAFETY: bounds checked above; NEON is baseline on aarch64.
    unsafe {
        neon::tn_rows(i0, rows, m, k, n, a, b, c)
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    unreachable!()
}

/// Scalar tail columns `j0..n` of the NN kernel for one row over one
/// k-block: the same mul-then-add ascending-`p` sequence as the vector
/// lanes, so tail elements match the scalar kernel too.
///
/// # Safety
/// `arow` must be valid for `k1` reads, `b` for `k1 * n`, `crow` for `n`.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
#[inline(always)]
unsafe fn nn_tail(j0: usize, n: usize, k0: usize, k1: usize, arow: *const f32, b: *const f32, crow: *mut f32) {
    for j in j0..n {
        let mut acc = *crow.add(j);
        for p in k0..k1 {
            acc += *arow.add(p) * *b.add(p * n + j);
        }
        *crow.add(j) = acc;
    }
}

/// Scalar tail columns `j0..n` of the TN kernel for one output row
/// (`A`-column `acol`): zero-init, ascending `p` over `0..k`, mul then add.
///
/// # Safety
/// `a` must be valid for `k * m` reads, `b` for `k * n`, `crow` for `n`.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
#[inline(always)]
#[allow(clippy::too_many_arguments)]
unsafe fn tn_tail(j0: usize, n: usize, m: usize, k: usize, acol: usize, a: *const f32, b: *const f32, crow: *mut f32) {
    for j in j0..n {
        let mut acc = 0.0f32;
        for p in 0..k {
            acc += *a.add(p * m + acol) * *b.add(p * n + j);
        }
        *crow.add(j) = acc;
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{nn_tail, tn_tail, KB, MR};
    use core::arch::x86_64::*;

    /// f32 lanes per vector.
    const W: usize = 8;

    /// # Safety
    /// Caller must ensure AVX2 is available and slices cover
    /// `a: rows×k`, `b: k×n`, `c: rows×n`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn nn_acc(rows: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
        let (a, b, cp) = (a.as_ptr(), b.as_ptr(), c.as_mut_ptr());
        let nv = n / W * W;
        for k0 in (0..k).step_by(KB) {
            let k1 = (k0 + KB).min(k);
            let mut i = 0;
            while i + MR <= rows {
                let (ar0, ar1, ar2, ar3) =
                    (a.add(i * k), a.add((i + 1) * k), a.add((i + 2) * k), a.add((i + 3) * k));
                let (cr0, cr1, cr2, cr3) =
                    (cp.add(i * n), cp.add((i + 1) * n), cp.add((i + 2) * n), cp.add((i + 3) * n));
                let mut j = 0;
                while j < nv {
                    let mut acc0 = _mm256_loadu_ps(cr0.add(j));
                    let mut acc1 = _mm256_loadu_ps(cr1.add(j));
                    let mut acc2 = _mm256_loadu_ps(cr2.add(j));
                    let mut acc3 = _mm256_loadu_ps(cr3.add(j));
                    for p in k0..k1 {
                        let bv = _mm256_loadu_ps(b.add(p * n + j));
                        // Separate mul/add — FMA's single rounding would
                        // drift from the scalar kernel.
                        acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(_mm256_set1_ps(*ar0.add(p)), bv));
                        acc1 = _mm256_add_ps(acc1, _mm256_mul_ps(_mm256_set1_ps(*ar1.add(p)), bv));
                        acc2 = _mm256_add_ps(acc2, _mm256_mul_ps(_mm256_set1_ps(*ar2.add(p)), bv));
                        acc3 = _mm256_add_ps(acc3, _mm256_mul_ps(_mm256_set1_ps(*ar3.add(p)), bv));
                    }
                    _mm256_storeu_ps(cr0.add(j), acc0);
                    _mm256_storeu_ps(cr1.add(j), acc1);
                    _mm256_storeu_ps(cr2.add(j), acc2);
                    _mm256_storeu_ps(cr3.add(j), acc3);
                    j += W;
                }
                nn_tail(nv, n, k0, k1, ar0, b, cr0);
                nn_tail(nv, n, k0, k1, ar1, b, cr1);
                nn_tail(nv, n, k0, k1, ar2, b, cr2);
                nn_tail(nv, n, k0, k1, ar3, b, cr3);
                i += MR;
            }
            while i < rows {
                let (ar, cr) = (a.add(i * k), cp.add(i * n));
                let mut j = 0;
                while j < nv {
                    let mut acc = _mm256_loadu_ps(cr.add(j));
                    for p in k0..k1 {
                        let bv = _mm256_loadu_ps(b.add(p * n + j));
                        acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_set1_ps(*ar.add(p)), bv));
                    }
                    _mm256_storeu_ps(cr.add(j), acc);
                    j += W;
                }
                nn_tail(nv, n, k0, k1, ar, b, cr);
                i += 1;
            }
        }
    }

    /// # Safety
    /// Caller must ensure AVX2 is available, `i0 + rows <= m`, and slices
    /// cover `a: k×m`, `b: k×n`, `c: rows×n`.
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn tn_rows(i0: usize, rows: usize, m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
        let (a, b, cp) = (a.as_ptr(), b.as_ptr(), c.as_mut_ptr());
        let nv = n / W * W;
        let mut i = 0;
        while i + MR <= rows {
            let mut j = 0;
            while j < nv {
                let mut acc0 = _mm256_setzero_ps();
                let mut acc1 = _mm256_setzero_ps();
                let mut acc2 = _mm256_setzero_ps();
                let mut acc3 = _mm256_setzero_ps();
                for p in 0..k {
                    let bv = _mm256_loadu_ps(b.add(p * n + j));
                    let ap = a.add(p * m + i0 + i);
                    acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(_mm256_set1_ps(*ap), bv));
                    acc1 = _mm256_add_ps(acc1, _mm256_mul_ps(_mm256_set1_ps(*ap.add(1)), bv));
                    acc2 = _mm256_add_ps(acc2, _mm256_mul_ps(_mm256_set1_ps(*ap.add(2)), bv));
                    acc3 = _mm256_add_ps(acc3, _mm256_mul_ps(_mm256_set1_ps(*ap.add(3)), bv));
                }
                _mm256_storeu_ps(cp.add(i * n + j), acc0);
                _mm256_storeu_ps(cp.add((i + 1) * n + j), acc1);
                _mm256_storeu_ps(cp.add((i + 2) * n + j), acc2);
                _mm256_storeu_ps(cp.add((i + 3) * n + j), acc3);
                j += W;
            }
            for r in 0..MR {
                tn_tail(nv, n, m, k, i0 + i + r, a, b, cp.add((i + r) * n));
            }
            i += MR;
        }
        while i < rows {
            let mut j = 0;
            while j < nv {
                let mut acc = _mm256_setzero_ps();
                for p in 0..k {
                    let bv = _mm256_loadu_ps(b.add(p * n + j));
                    acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_set1_ps(*a.add(p * m + i0 + i)), bv));
                }
                _mm256_storeu_ps(cp.add(i * n + j), acc);
                j += W;
            }
            tn_tail(nv, n, m, k, i0 + i, a, b, cp.add(i * n));
            i += 1;
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::{nn_tail, tn_tail, KB, MR};
    use core::arch::aarch64::*;

    /// f32 lanes per vector.
    const W: usize = 4;

    /// # Safety
    /// Caller must ensure slices cover `a: rows×k`, `b: k×n`, `c: rows×n`.
    #[target_feature(enable = "neon")]
    pub unsafe fn nn_acc(rows: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
        let (a, b, cp) = (a.as_ptr(), b.as_ptr(), c.as_mut_ptr());
        let nv = n / W * W;
        for k0 in (0..k).step_by(KB) {
            let k1 = (k0 + KB).min(k);
            let mut i = 0;
            while i + MR <= rows {
                let (ar0, ar1, ar2, ar3) =
                    (a.add(i * k), a.add((i + 1) * k), a.add((i + 2) * k), a.add((i + 3) * k));
                let (cr0, cr1, cr2, cr3) =
                    (cp.add(i * n), cp.add((i + 1) * n), cp.add((i + 2) * n), cp.add((i + 3) * n));
                let mut j = 0;
                while j < nv {
                    let mut acc0 = vld1q_f32(cr0.add(j));
                    let mut acc1 = vld1q_f32(cr1.add(j));
                    let mut acc2 = vld1q_f32(cr2.add(j));
                    let mut acc3 = vld1q_f32(cr3.add(j));
                    for p in k0..k1 {
                        let bv = vld1q_f32(b.add(p * n + j));
                        // Separate mul/add — vfmaq would fuse the rounding.
                        acc0 = vaddq_f32(acc0, vmulq_f32(vdupq_n_f32(*ar0.add(p)), bv));
                        acc1 = vaddq_f32(acc1, vmulq_f32(vdupq_n_f32(*ar1.add(p)), bv));
                        acc2 = vaddq_f32(acc2, vmulq_f32(vdupq_n_f32(*ar2.add(p)), bv));
                        acc3 = vaddq_f32(acc3, vmulq_f32(vdupq_n_f32(*ar3.add(p)), bv));
                    }
                    vst1q_f32(cr0.add(j), acc0);
                    vst1q_f32(cr1.add(j), acc1);
                    vst1q_f32(cr2.add(j), acc2);
                    vst1q_f32(cr3.add(j), acc3);
                    j += W;
                }
                nn_tail(nv, n, k0, k1, ar0, b, cr0);
                nn_tail(nv, n, k0, k1, ar1, b, cr1);
                nn_tail(nv, n, k0, k1, ar2, b, cr2);
                nn_tail(nv, n, k0, k1, ar3, b, cr3);
                i += MR;
            }
            while i < rows {
                let (ar, cr) = (a.add(i * k), cp.add(i * n));
                let mut j = 0;
                while j < nv {
                    let mut acc = vld1q_f32(cr.add(j));
                    for p in k0..k1 {
                        let bv = vld1q_f32(b.add(p * n + j));
                        acc = vaddq_f32(acc, vmulq_f32(vdupq_n_f32(*ar.add(p)), bv));
                    }
                    vst1q_f32(cr.add(j), acc);
                    j += W;
                }
                nn_tail(nv, n, k0, k1, ar, b, cr);
                i += 1;
            }
        }
    }

    /// # Safety
    /// Caller must ensure `i0 + rows <= m` and slices cover `a: k×m`,
    /// `b: k×n`, `c: rows×n`.
    #[target_feature(enable = "neon")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn tn_rows(i0: usize, rows: usize, m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
        let (a, b, cp) = (a.as_ptr(), b.as_ptr(), c.as_mut_ptr());
        let nv = n / W * W;
        let mut i = 0;
        while i + MR <= rows {
            let mut j = 0;
            while j < nv {
                let mut acc0 = vdupq_n_f32(0.0);
                let mut acc1 = vdupq_n_f32(0.0);
                let mut acc2 = vdupq_n_f32(0.0);
                let mut acc3 = vdupq_n_f32(0.0);
                for p in 0..k {
                    let bv = vld1q_f32(b.add(p * n + j));
                    let ap = a.add(p * m + i0 + i);
                    acc0 = vaddq_f32(acc0, vmulq_f32(vdupq_n_f32(*ap), bv));
                    acc1 = vaddq_f32(acc1, vmulq_f32(vdupq_n_f32(*ap.add(1)), bv));
                    acc2 = vaddq_f32(acc2, vmulq_f32(vdupq_n_f32(*ap.add(2)), bv));
                    acc3 = vaddq_f32(acc3, vmulq_f32(vdupq_n_f32(*ap.add(3)), bv));
                }
                vst1q_f32(cp.add(i * n + j), acc0);
                vst1q_f32(cp.add((i + 1) * n + j), acc1);
                vst1q_f32(cp.add((i + 2) * n + j), acc2);
                vst1q_f32(cp.add((i + 3) * n + j), acc3);
                j += W;
            }
            for r in 0..MR {
                tn_tail(nv, n, m, k, i0 + i + r, a, b, cp.add((i + r) * n));
            }
            i += MR;
        }
        while i < rows {
            let mut j = 0;
            while j < nv {
                let mut acc = vdupq_n_f32(0.0);
                for p in 0..k {
                    let bv = vld1q_f32(b.add(p * n + j));
                    acc = vaddq_f32(acc, vmulq_f32(vdupq_n_f32(*a.add(p * m + i0 + i)), bv));
                }
                vst1q_f32(cp.add(i * n + j), acc);
                j += W;
            }
            tn_tail(nv, n, m, k, i0 + i, a, b, cp.add(i * n));
            i += 1;
        }
    }
}
