//! Symmetric eigendecomposition via the cyclic Jacobi method.
//!
//! Replaces `torch.linalg.eigh` from the paper's implementation (the image's
//! XLA runtime cannot execute jax's LAPACK FFI custom-calls, DESIGN.md §2).
//! Used for: SOAP eigenbasis *initialization* (first preconditioning step),
//! the `eigh` arm of the Fig 7 (right) comparison, Shampoo inverse-root
//! computation, and the idealized-algorithm oracle for Claim 1.
//!
//! Performance (§Perf iteration 2): rotations touch only contiguous rows —
//! the column half of each two-sided rotation is reconstructed from symmetry
//! with a strided *copy* instead of strided compute — and the eigenvector
//! accumulator is kept transposed so its rotations are row operations too.
//! [`eigh_warm`] adds warm-starting from a previous basis (3 GEMMs + ~1
//! Jacobi sweep), which is what the periodic Shampoo/SOAP refreshes use.
//! Internally f64; inputs/outputs are the f32 `Matrix`.

use super::matrix::Matrix;

/// Eigendecomposition of a symmetric matrix: returns `(eigvals, eigvecs)`
/// with eigenvalues **descending** and eigenvectors as *columns* of the
/// returned matrix, so `a ≈ V · diag(w) · Vᵀ`.
///
/// Engine (§Perf iteration 3): Householder tridiagonalization (`tred2`) +
/// QL with implicit shifts (`tql2`) — ~4n³ flops vs cyclic Jacobi's
/// ~90n³; Jacobi remains for tiny matrices where its constant wins.
pub fn eigh(a: &Matrix) -> (Vec<f32>, Matrix) {
    let n = a.rows;
    assert_eq!(a.rows, a.cols, "eigh expects square");
    let mut m: Vec<f64> = a.data.iter().map(|&x| x as f64).collect();
    symmetrize(&mut m, n);
    if n <= 8 {
        let mut vt = vec![0.0f64; n * n];
        for i in 0..n {
            vt[i * n + i] = 1.0;
        }
        jacobi(&mut m, &mut vt, n);
        return finish(&m, &vt, n);
    }
    let mut d = vec![0.0f64; n];
    let mut e = vec![0.0f64; n];
    tred2(&mut m, &mut d, &mut e, n);
    // Transpose the accumulated transform so tql2's plane rotations act on
    // contiguous rows.
    let mut zt = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            zt[j * n + i] = m[i * n + j];
        }
    }
    tql2(&mut d, &mut e, &mut zt, n);
    // `finish` expects a diagonal-carrying matrix; reuse m's diagonal slots.
    for i in 0..n {
        m[i * n + i] = d[i];
    }
    finish(&m, &zt, n)
}

/// Householder reduction of a real symmetric matrix to tridiagonal form
/// (EISPACK `tred2`): on return `a` holds the accumulated orthogonal
/// transform (columns), `d` the diagonal, `e` the subdiagonal (e[0] = 0).
fn tred2(a: &mut [f64], d: &mut [f64], e: &mut [f64], n: usize) {
    for i in (1..n).rev() {
        let l = i - 1;
        let mut h = 0.0f64;
        if l > 0 {
            let mut scale = 0.0f64;
            for k in 0..=l {
                scale += a[i * n + k].abs();
            }
            if scale == 0.0 {
                e[i] = a[i * n + l];
            } else {
                for k in 0..=l {
                    a[i * n + k] /= scale;
                    h += a[i * n + k] * a[i * n + k];
                }
                let f = a[i * n + l];
                let g = if f >= 0.0 { -h.sqrt() } else { h.sqrt() };
                e[i] = scale * g;
                h -= f * g;
                a[i * n + l] = f - g;
                let mut f_acc = 0.0f64;
                for j in 0..=l {
                    a[j * n + i] = a[i * n + j] / h;
                    let mut g = 0.0f64;
                    for k in 0..=j {
                        g += a[j * n + k] * a[i * n + k];
                    }
                    for k in (j + 1)..=l {
                        g += a[k * n + j] * a[i * n + k];
                    }
                    e[j] = g / h;
                    f_acc += e[j] * a[i * n + j];
                }
                let hh = f_acc / (h + h);
                for j in 0..=l {
                    let f = a[i * n + j];
                    let g = e[j] - hh * f;
                    e[j] = g;
                    for k in 0..=j {
                        a[j * n + k] -= f * e[k] + g * a[i * n + k];
                    }
                }
            }
        } else {
            e[i] = a[i * n + l];
        }
        d[i] = h;
    }
    d[0] = 0.0;
    e[0] = 0.0;
    for i in 0..n {
        if d[i] != 0.0 {
            for j in 0..i {
                let mut g = 0.0f64;
                for k in 0..i {
                    g += a[i * n + k] * a[k * n + j];
                }
                for k in 0..i {
                    a[k * n + j] -= g * a[k * n + i];
                }
            }
        }
        d[i] = a[i * n + i];
        a[i * n + i] = 1.0;
        for j in 0..i {
            a[j * n + i] = 0.0;
            a[i * n + j] = 0.0;
        }
    }
}

/// QL with implicit shifts on a tridiagonal matrix (EISPACK `tql2`),
/// rotating the TRANSPOSED eigenvector accumulator `zt` (rows are
/// eigenvectors, so the plane rotations run over contiguous memory).
fn tql2(d: &mut [f64], e: &mut [f64], zt: &mut [f64], n: usize) {
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;
    for l in 0..n {
        let mut iter = 0;
        loop {
            let mut m = l;
            while m + 1 < n {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            if iter > 50 {
                break; // fail-safe; residual checked by callers/tests
            }
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = g.hypot(1.0);
            g = d[m] - d[l] + e[l] / (g + if g >= 0.0 { r.abs() } else { -r.abs() });
            let mut s = 1.0f64;
            let mut c = 1.0f64;
            let mut p = 0.0f64;
            let mut i = m as isize - 1;
            while i >= l as isize {
                let iu = i as usize;
                let f = s * e[iu];
                let b = c * e[iu];
                r = f.hypot(g);
                e[iu + 1] = r;
                if r == 0.0 {
                    d[iu + 1] -= p;
                    e[m] = 0.0;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[iu + 1] - p;
                r = (d[iu] - g) * s + 2.0 * c * b;
                p = s * r;
                d[iu + 1] = g + p;
                g = c * r - b;
                // Rotate eigenvector rows iu and iu+1 (contiguous).
                let (head, tail) = zt.split_at_mut((iu + 1) * n);
                let ri = &mut head[iu * n..iu * n + n];
                let ri1 = &mut tail[..n];
                for (a_, b_) in ri.iter_mut().zip(ri1.iter_mut()) {
                    let zf = *b_;
                    let zk = *a_;
                    *b_ = s * zk + c * zf;
                    *a_ = c * zk - s * zf;
                }
                i -= 1;
            }
            if r == 0.0 && i >= l as isize {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }
}

/// Warm-started eigendecomposition. With the tred2/tql2 engine (§Perf
/// iteration 3) a cold solve is already cheaper than the rotate-into-basis
/// + Jacobi warm path (§Perf iteration 2, kept in git history), so this is
/// now an alias kept for API stability of the refresh call sites; `v_prev`
/// only participates in debug shape checks.
pub fn eigh_warm(a: &Matrix, v_prev: &Matrix) -> (Vec<f32>, Matrix) {
    debug_assert_eq!((a.rows, a.rows), (v_prev.rows, v_prev.cols));
    eigh(a)
}

fn symmetrize(m: &mut [f64], n: usize) {
    for i in 0..n {
        for j in (i + 1)..n {
            let s = 0.5 * (m[i * n + j] + m[j * n + i]);
            m[i * n + j] = s;
            m[j * n + i] = s;
        }
    }
}

/// Cyclic Jacobi on a symmetric matrix stored row-major; accumulates the
/// transposed eigenvector matrix in `vt`.
fn jacobi(m: &mut [f64], vt: &mut [f64], n: usize) {
    if n <= 1 {
        return;
    }
    let max_sweeps = 16;
    for _sweep in 0..max_sweeps {
        // Off-diagonal norm for convergence + per-rotation threshold.
        let mut off = 0.0f64;
        let mut diag = 0.0f64;
        for i in 0..n {
            diag += m[i * n + i] * m[i * n + i];
            for j in (i + 1)..n {
                off += m[i * n + j] * m[i * n + j];
            }
        }
        let scale = (diag + 2.0 * off).sqrt().max(1e-300);
        if off.sqrt() < 1e-9 * scale {
            break;
        }
        // Skip rotations below this; they cannot affect fp32 output.
        let thresh = 1e-14 * scale / n as f64;

        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[p * n + q];
                if apq.abs() <= thresh {
                    continue;
                }
                let app = m[p * n + p];
                let aqq = m[q * n + q];
                let theta = 0.5 * (aqq - app) / apq;
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;

                // Rows p and q (contiguous; vectorizes).
                rotate_rows(m, n, p, q, c, s);
                // Special entries from the closed forms.
                let new_pp = c * c * app - 2.0 * s * c * apq + s * s * aqq;
                let new_qq = s * s * app + 2.0 * s * c * apq + c * c * aqq;
                m[p * n + p] = new_pp;
                m[q * n + q] = new_qq;
                m[p * n + q] = 0.0;
                m[q * n + p] = 0.0;
                // Mirror rows back to columns (strided copies only).
                for k in 0..n {
                    if k != p && k != q {
                        m[k * n + p] = m[p * n + k];
                        m[k * n + q] = m[q * n + k];
                    }
                }
                // Eigenvectors: vt rows p,q (contiguous).
                rotate_rows(vt, n, p, q, c, s);
            }
        }
    }
}

/// rows[p], rows[q] ← (c·rows[p] − s·rows[q], s·rows[p] + c·rows[q]).
#[inline]
fn rotate_rows(m: &mut [f64], n: usize, p: usize, q: usize, c: f64, s: f64) {
    debug_assert!(p < q);
    let (head, tail) = m.split_at_mut(q * n);
    let rp = &mut head[p * n..p * n + n];
    let rq = &mut tail[..n];
    for (a, b) in rp.iter_mut().zip(rq.iter_mut()) {
        let x = *a;
        let y = *b;
        *a = c * x - s * y;
        *b = s * x + c * y;
    }
}

/// Sort descending, un-transpose the eigenvectors, fix signs.
fn finish(m: &[f64], vt: &[f64], n: usize) -> (Vec<f32>, Matrix) {
    let mut pairs: Vec<(f64, usize)> = (0..n).map(|i| (m[i * n + i], i)).collect();
    pairs.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let mut w = Vec::with_capacity(n);
    let mut vecs = Matrix::zeros(n, n);
    for (col_out, &(val, row_in)) in pairs.iter().enumerate() {
        w.push(val as f32);
        // vt row `row_in` is the eigenvector.
        for i in 0..n {
            vecs.set(i, col_out, vt[row_in * n + i] as f32);
        }
    }
    // Sign convention: largest-|entry| component positive.
    for j in 0..n {
        let (mut bi, mut bv) = (0usize, 0.0f32);
        for i in 0..n {
            let x = vecs.at(i, j).abs();
            if x > bv {
                bv = x;
                bi = i;
            }
        }
        if vecs.at(bi, j) < 0.0 {
            for i in 0..n {
                let x = -vecs.at(i, j);
                vecs.set(i, j, x);
            }
        }
    }
    (w, vecs)
}

/// Reconstruct `V diag(w) Vᵀ` — testing helper.
pub fn reconstruct(w: &[f32], v: &Matrix) -> Matrix {
    let n = v.rows;
    let mut wd = Matrix::zeros(n, n);
    for i in 0..n {
        wd.set(i, i, w[i]);
    }
    v.matmul(&wd).matmul_nt(v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn diagonal_matrix_exact() {
        let a = Matrix::from_fn(4, 4, |i, j| if i == j { (i + 1) as f32 } else { 0.0 });
        let (w, v) = eigh(&a);
        assert_eq!(w, vec![4.0, 3.0, 2.0, 1.0]);
        let mut col = Vec::new();
        for j in 0..4 {
            v.col_into(j, &mut col);
            assert!((col[3 - j] - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn reconstruction_random_psd() {
        let mut rng = Rng::new(20);
        for n in [2usize, 5, 16, 40, 100] {
            let a = Matrix::rand_psd(&mut rng, n);
            let (w, v) = eigh(&a);
            let rec = reconstruct(&w, &v);
            assert!(
                rec.max_abs_diff(&a) < 1e-3 * (1.0 + a.max_abs()),
                "n={n} err={}",
                rec.max_abs_diff(&a)
            );
        }
    }

    #[test]
    fn eigvecs_orthonormal() {
        let mut rng = Rng::new(21);
        let a = Matrix::rand_psd(&mut rng, 12);
        let (_, v) = eigh(&a);
        let vtv = v.matmul_tn(&v);
        assert!(vtv.max_abs_diff(&Matrix::eye(12)) < 1e-4);
    }

    #[test]
    fn eigvals_descending_nonneg_for_psd() {
        let mut rng = Rng::new(22);
        let a = Matrix::rand_psd(&mut rng, 10);
        let (w, _) = eigh(&a);
        for k in 1..w.len() {
            assert!(w[k - 1] >= w[k] - 1e-5);
        }
        for &x in &w {
            assert!(x > -1e-4);
        }
    }

    #[test]
    fn trace_preserved() {
        let mut rng = Rng::new(23);
        let a = Matrix::rand_psd(&mut rng, 15);
        let (w, _) = eigh(&a);
        let tw: f32 = w.iter().sum();
        assert!((tw - a.trace()).abs() < 1e-2 * (1.0 + a.trace().abs()));
    }

    #[test]
    fn one_by_one() {
        let a = Matrix::from_vec(1, 1, vec![7.0]);
        let (w, v) = eigh(&a);
        assert_eq!(w, vec![7.0]);
        assert_eq!(v.data, vec![1.0]);
    }

    #[test]
    fn warm_start_matches_cold() {
        let mut rng = Rng::new(24);
        let a = Matrix::rand_psd(&mut rng, 24);
        let (w_cold, v_cold) = eigh(&a);
        // Perturb the matrix slightly and warm-start from the old basis.
        let mut a2 = a.clone();
        let d = Matrix::rand_psd(&mut rng, 24).scale(0.01);
        a2 = a2.add(&d);
        let (w_warm, v_warm) = eigh_warm(&a2, &v_cold);
        let (w_cold2, _) = eigh(&a2);
        for (x, y) in w_warm.iter().zip(&w_cold2) {
            assert!((x - y).abs() < 1e-3 * (1.0 + y.abs()), "{x} vs {y}");
        }
        // Reconstruction through the warm basis.
        let rec = reconstruct(&w_warm, &v_warm);
        assert!(rec.max_abs_diff(&a2) < 1e-3 * (1.0 + a2.max_abs()));
        let _ = w_cold;
    }

    #[test]
    fn warm_start_identity_guess_equals_cold() {
        let mut rng = Rng::new(25);
        let a = Matrix::rand_psd(&mut rng, 10);
        let (w1, v1) = eigh(&a);
        let (w2, v2) = eigh_warm(&a, &Matrix::eye(10));
        for (x, y) in w1.iter().zip(&w2) {
            assert!((x - y).abs() < 1e-4 * (1.0 + y.abs()));
        }
        assert!(v1.max_abs_diff(&v2) < 1e-2);
    }
}
