//! Dense row-major `f32` matrix — the native-Rust numeric substrate.
//!
//! Used by the native optimizer implementations (oracle + CPU-offloaded
//! preconditioner refresh), the experiment fits, and the tests. The PJRT
//! artifacts carry the training-path compute; this type exists so the
//! coordinator can be validated and benchmarked without artifacts, mirroring
//! DistributedShampoo's CPU-side eigendecomposition path.

use crate::util::rng::Rng;

#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    // ---- constructors ----------------------------------------------------
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Self { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Self { rows, cols, data }
    }

    /// N(0, std²) entries.
    pub fn randn(rng: &mut Rng, rows: usize, cols: usize, std: f32) -> Self {
        let mut m = Self::zeros(rows, cols);
        rng.fill_normal(&mut m.data, std);
        m
    }

    /// Random symmetric positive semi-definite matrix AᵀA / n.
    pub fn rand_psd(rng: &mut Rng, n: usize) -> Self {
        let a = Self::randn(rng, n, n, 1.0);
        let mut p = a.matmul_tn(&a);
        p.scale_inplace(1.0 / n as f32);
        p
    }

    // ---- element access ---------------------------------------------------
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.cols;
        &mut self.data[i * c..(i + 1) * c]
    }

    pub fn col(&self, j: usize) -> Vec<f32> {
        let mut out = Vec::new();
        self.col_into(j, &mut out);
        out
    }

    /// No-alloc companion of [`Matrix::col`] for callers that loop over
    /// columns: reuses `out`'s allocation (grow-only).
    pub fn col_into(&self, j: usize, out: &mut Vec<f32>) {
        out.clear();
        out.extend((0..self.rows).map(|i| self.at(i, j)));
    }

    pub fn numel(&self) -> usize {
        self.rows * self.cols
    }

    /// Reshape `self` for use as an output buffer, reusing its allocation
    /// (grow-only). Contents are unspecified afterwards — every caller
    /// overwrites before reading.
    pub fn reuse_shape(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.resize(rows * cols, 0.0);
    }

    /// `self ← src` without allocating (beyond grow-only buffer growth).
    pub fn copy_from(&mut self, src: &Matrix) {
        self.reuse_shape(src.rows, src.cols);
        self.data.copy_from_slice(&src.data);
    }

    // ---- elementwise ops ---------------------------------------------------
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Self {
        Self {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn zip(&self, other: &Self, f: impl Fn(f32, f32) -> f32) -> Self {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Self {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(&a, &b)| f(a, b))
                .collect(),
        }
    }

    pub fn add(&self, o: &Self) -> Self {
        self.zip(o, |a, b| a + b)
    }
    pub fn sub(&self, o: &Self) -> Self {
        self.zip(o, |a, b| a - b)
    }
    pub fn hadamard(&self, o: &Self) -> Self {
        self.zip(o, |a, b| a * b)
    }
    pub fn scale(&self, s: f32) -> Self {
        self.map(|x| x * s)
    }

    pub fn scale_inplace(&mut self, s: f32) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    /// `self ← β·self + (1−β)·other` — the EMA update used by every optimizer.
    pub fn ema_inplace(&mut self, other: &Self, beta: f32) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let ob = 1.0 - beta;
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a = beta * *a + ob * b;
        }
    }

    /// `self ← self + s·other` (axpy).
    pub fn axpy_inplace(&mut self, s: f32, other: &Self) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a += s * b;
        }
    }

    // ---- reductions ---------------------------------------------------------
    pub fn trace(&self) -> f32 {
        assert_eq!(self.rows, self.cols);
        (0..self.rows).map(|i| self.at(i, i)).sum()
    }

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|&x| x as f64 * x as f64).sum::<f64>().sqrt() as f32
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    pub fn sum(&self) -> f32 {
        self.data.iter().map(|&x| x as f64).sum::<f64>() as f32
    }

    /// Row sums (length `rows`).
    pub fn row_sums(&self) -> Vec<f32> {
        (0..self.rows)
            .map(|i| self.row(i).iter().map(|&x| x as f64).sum::<f64>() as f32)
            .collect()
    }

    /// Column sums (length `cols`).
    pub fn col_sums(&self) -> Vec<f32> {
        let mut out = vec![0.0f64; self.cols];
        for i in 0..self.rows {
            for (j, &x) in self.row(i).iter().enumerate() {
                out[j] += x as f64;
            }
        }
        out.into_iter().map(|x| x as f32).collect()
    }

    // ---- structural ----------------------------------------------------------
    pub fn t(&self) -> Self {
        let mut out = Self::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    /// Maximum |aᵢⱼ − bᵢⱼ|.
    pub fn max_abs_diff(&self, other: &Self) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f32, |m, (&a, &b)| m.max((a - b).abs()))
    }

    // ---- matmul family ---------------------------------------------------------
    //
    // All three transpose variants route through the blocked kernel family
    // in `gemm.rs`. The allocating entry points (`matmul*`) dispatch to the
    // row-partitioned parallel drivers — large refresh-time products
    // (`GGᵀ`, power-iteration `P·Q`, warm-eigh rotations) fan out across
    // the process pool, bitwise identically to the serial kernels. The
    // `*_into` methods are the serial, allocation-free forms the optimizer
    // step path uses with per-layer `Workspace` buffers.

    /// C = A·B (allocating; parallel when large).
    pub fn matmul(&self, b: &Self) -> Self {
        assert_eq!(self.cols, b.rows, "matmul shape mismatch");
        let mut c = Self::zeros(self.rows, b.cols);
        super::gemm::par_gemm_into(
            self.rows, self.cols, b.cols, &self.data, &b.data, &mut c.data,
        );
        c
    }

    /// `out = A·B` without allocating (grow-only `out` reuse). Serial —
    /// the zero-allocation step path.
    pub fn matmul_into(&self, b: &Self, out: &mut Self) {
        assert_eq!(self.cols, b.rows, "matmul shape mismatch");
        out.reuse_shape(self.rows, b.cols);
        super::gemm::gemm_into(self.rows, self.cols, b.cols, &self.data, &b.data, &mut out.data);
    }

    /// C = Aᵀ·B without materializing the transpose (allocating; parallel
    /// when large).
    pub fn matmul_tn(&self, b: &Self) -> Self {
        assert_eq!(self.rows, b.rows, "matmul_tn shape mismatch");
        let (k, m, n) = (self.rows, self.cols, b.cols);
        let mut c = Self::zeros(m, n);
        super::gemm::par_gemm_tn_into(m, k, n, &self.data, &b.data, &mut c.data);
        c
    }

    /// `out = Aᵀ·B` without allocating. Serial.
    pub fn matmul_tn_into(&self, b: &Self, out: &mut Self) {
        assert_eq!(self.rows, b.rows, "matmul_tn shape mismatch");
        let (k, m, n) = (self.rows, self.cols, b.cols);
        out.reuse_shape(m, n);
        super::gemm::gemm_tn_into(m, k, n, &self.data, &b.data, &mut out.data);
    }

    /// C = A·Bᵀ without materializing the transpose (allocating; parallel
    /// when large; `Bᵀ` packed internally).
    pub fn matmul_nt(&self, b: &Self) -> Self {
        assert_eq!(self.cols, b.cols, "matmul_nt shape mismatch");
        let (m, k, n) = (self.rows, self.cols, b.rows);
        let mut c = Self::zeros(m, n);
        let mut pack = Vec::new();
        super::gemm::par_gemm_nt_into(m, k, n, &self.data, &b.data, &mut c.data, &mut pack);
        c
    }

    /// `out = A·Bᵀ` without allocating once `pack` has grown to `B`'s size
    /// (the `Workspace` owns that buffer on the step path). Serial.
    pub fn matmul_nt_into(&self, b: &Self, out: &mut Self, pack: &mut Vec<f32>) {
        assert_eq!(self.cols, b.cols, "matmul_nt shape mismatch");
        let (m, k, n) = (self.rows, self.cols, b.rows);
        out.reuse_shape(m, n);
        super::gemm::gemm_nt_into(m, k, n, &self.data, &b.data, &mut out.data, pack);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> (Matrix, Matrix) {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Matrix::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        (a, b)
    }

    #[test]
    fn matmul_known() {
        let (a, b) = small();
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_tn_matches_explicit_transpose() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(&mut rng, 7, 5, 1.0);
        let b = Matrix::randn(&mut rng, 7, 4, 1.0);
        let got = a.matmul_tn(&b);
        let want = a.t().matmul(&b);
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn matmul_nt_matches_explicit_transpose() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(&mut rng, 6, 5, 1.0);
        let b = Matrix::randn(&mut rng, 3, 5, 1.0);
        let got = a.matmul_nt(&b);
        let want = a.matmul(&b.t());
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn transpose_involution() {
        let (a, _) = small();
        assert_eq!(a.t().t(), a);
    }

    #[test]
    fn trace_and_eye() {
        assert_eq!(Matrix::eye(5).trace(), 5.0);
        assert_eq!(Matrix::eye(3).matmul(&Matrix::eye(3)), Matrix::eye(3));
    }

    #[test]
    fn ema_inplace_correct() {
        let mut a = Matrix::from_vec(1, 2, vec![1.0, 2.0]);
        let b = Matrix::from_vec(1, 2, vec![3.0, 4.0]);
        a.ema_inplace(&b, 0.9);
        assert!((a.data[0] - (0.9 + 0.3)).abs() < 1e-6);
        assert!((a.data[1] - (1.8 + 0.4)).abs() < 1e-6);
    }

    #[test]
    fn psd_is_symmetric_nonneg_diag() {
        let mut rng = Rng::new(3);
        let p = Matrix::rand_psd(&mut rng, 8);
        assert!(p.max_abs_diff(&p.t()) < 1e-5);
        for i in 0..8 {
            assert!(p.at(i, i) >= 0.0);
        }
    }

    #[test]
    fn row_col_sums() {
        let (a, _) = small();
        assert_eq!(a.row_sums(), vec![6.0, 15.0]);
        assert_eq!(a.col_sums(), vec![5.0, 7.0, 9.0]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let (a, _) = small();
        let _ = a.matmul(&a);
    }

    #[test]
    fn into_variants_match_allocating_bitwise() {
        let mut rng = Rng::new(4);
        let a = Matrix::randn(&mut rng, 7, 5, 1.0);
        let b = Matrix::randn(&mut rng, 5, 6, 1.0);
        let bt = Matrix::randn(&mut rng, 6, 5, 1.0);
        let at = Matrix::randn(&mut rng, 7, 4, 1.0);
        // Pre-dirty buffers with wrong shapes: reuse must still be exact.
        let mut out = Matrix::randn(&mut rng, 2, 9, 1.0);
        let mut pack = vec![7.0f32; 3];
        a.matmul_into(&b, &mut out);
        assert_eq!(out, a.matmul(&b));
        a.matmul_tn_into(&at, &mut out);
        assert_eq!(out, a.matmul_tn(&at));
        a.matmul_nt_into(&bt, &mut out, &mut pack);
        assert_eq!(out, a.matmul_nt(&bt));
    }

    #[test]
    fn col_into_reuses_buffer() {
        let (a, _) = small();
        let mut buf = Vec::new();
        a.col_into(1, &mut buf);
        assert_eq!(buf, vec![2.0, 5.0]);
        let cap = buf.capacity();
        a.col_into(0, &mut buf);
        assert_eq!(buf, vec![1.0, 4.0]);
        assert_eq!(buf.capacity(), cap);
        assert_eq!(a.col(2), vec![3.0, 6.0]);
    }

    #[test]
    fn copy_from_and_reuse_shape() {
        let (a, _) = small();
        let mut dst = Matrix::zeros(9, 9);
        dst.copy_from(&a);
        assert_eq!(dst, a);
        dst.reuse_shape(1, 4);
        assert_eq!((dst.rows, dst.cols, dst.data.len()), (1, 4, 4));
    }
}
