//! Dense linear-algebra substrate (native Rust).
//!
//! The paper's implementation leans on `torch.linalg.{eigh,qr}` and cuBLAS;
//! the image's XLA runtime cannot run jax's LAPACK FFI custom-calls, so this
//! module provides the native engines: blocked GEMM, Householder QR, cyclic
//! Jacobi `eigh`, and PSD inverse p-th roots (eigh- and Newton-based). See
//! DESIGN.md §2/§4.

pub mod eigh;
pub mod gemm;
pub mod matrix;
pub mod qr;
pub mod roots;
pub mod simd;
pub mod tensor;

pub use eigh::{eigh, eigh_warm};
pub use gemm::{
    active_gemm_kernel_name, force_gemm_kernel, gemm_into, gemm_nt_into, gemm_tn_into,
    par_gemm_into, par_gemm_nt_into, par_gemm_tn_into, GemmKernel,
};
pub use matrix::Matrix;
pub use qr::{power_iter_refresh, qr, qr_positive};
pub use roots::{inv_root_eigh, inv_root_newton, root_eigh};
pub use tensor::TensorShape;
