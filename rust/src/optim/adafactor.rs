//! Adafactor (Shazeer & Stern 2018), in the simplified form used by the
//! paper (Zhai et al. 2022 / Zhao et al. 2024c), as a named preset over the
//! composable core:
//!
//! ```text
//!   Adafactor = IdentityBasis × Adafactor(rank-1 V)
//! ```
//!
//!   A ← β₂A + (1−β₂)·rowsum(G⊙G),  C ← β₂C + (1−β₂)·colsum(G⊙G)
//!   V̂ᵢⱼ = AᵢCⱼ / ΣA,   W ← W − η · M̂/√(V̂+ε)
//!
//! The same [`crate::optim::compose::AdafactorEngine`] run inside the eigenbasis is the
//! paper's factorized SOAP (§7.2.1) — and, by Claim 1, idealized Shampoo
//! with power 1/2. Momentum is kept, the LR schedule is external, and only
//! the second moment is factored.

use super::compose::{presets, DynComposed};
use super::hyper::Hyper;

// The factored denominator is shared by every space the engine runs in;
// re-exported here under its historical name.
pub use super::compose::factored_normalize;

/// Named preset: [`Adafactor::new`] builds the identity × rank-1-Adafactor
/// composition. 1-D parameters degenerate the factorization and fall back to
/// a full Adam `V` (matches practical Adafactor implementations).
pub struct Adafactor;

impl Adafactor {
    // Historical constructor name, kept across the compose refactor; it
    // intentionally returns the composed type, not Self.
    #[allow(clippy::new_ret_no_self)]
    pub fn new(rows: usize, cols: usize, h: Hyper) -> DynComposed {
        presets::adafactor(rows, cols, h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::optim::LayerOptimizer;
    use crate::util::rng::Rng;

    fn h_nowd() -> Hyper {
        Hyper { weight_decay: 0.0, ..Hyper::default() }
    }

    #[test]
    fn rank1_gradient_recovers_adam_scale() {
        // For a rank-1 |G| = u·vᵀ the factored V̂ is exact, so the step
        // magnitude matches AdamW's.
        let u = [1.0f32, 2.0];
        let v = [0.5f32, 1.0, 1.5];
        let g = Matrix::from_fn(2, 3, |i, j| u[i] * v[j]);
        let mut opt = Adafactor::new(2, 3, h_nowd());
        let mut w = Matrix::zeros(2, 3);
        for t in 1..=300 {
            let mut wc = w.clone();
            opt.update(&mut wc, &g, t, 0.01);
            if t == 300 {
                let step = w.sub(&wc).scale(1.0 / 0.01);
                // Every coordinate should step with unit magnitude.
                for &s in &step.data {
                    assert!((s.abs() - 1.0).abs() < 0.05, "step {s}");
                }
            }
            w = wc;
        }
    }

    #[test]
    fn state_is_sublinear_for_2d() {
        let opt = Adafactor::new(64, 128, Hyper::default());
        // m·n (momentum) + m + n (factored), ×4 bytes.
        assert_eq!(opt.state_bytes(), (64 * 128 + 64 + 128) * 4);
    }

    #[test]
    fn vector_param_uses_full_v() {
        let opt = Adafactor::new(1, 32, Hyper::default());
        assert_eq!(opt.state_bytes(), (32 + 1 + 32 + 32) * 4);
    }

    #[test]
    fn minimizes_quadratic() {
        let mut rng = Rng::new(6);
        let target = Matrix::randn(&mut rng, 5, 3, 1.0);
        let mut w = Matrix::zeros(5, 3);
        let mut opt = Adafactor::new(5, 3, h_nowd());
        for t in 1..=3000 {
            let g = w.sub(&target).scale(2.0);
            opt.update(&mut w, &g, t, 0.02);
        }
        assert!(w.max_abs_diff(&target) < 0.1, "{}", w.max_abs_diff(&target));
    }

    #[test]
    fn factored_normalize_row_col_structure() {
        let num = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let a = [4.0f32, 1.0];
        let c = [2.0f32, 8.0];
        let out = factored_normalize(&num, &a, &c, 0.0);
        // vhat[0][0] = 4*2/5, vhat[1][1] = 1*8/5 — check one ratio.
        let want00 = 1.0 / (8.0f32 / 5.0).sqrt();
        assert!((out.at(0, 0) - want00).abs() < 1e-5);
    }
}
