//! Adafactor (Shazeer & Stern 2018), in the simplified form used by the
//! paper (Zhai et al. 2022 / Zhao et al. 2024c): momentum is kept, the LR
//! schedule is external, and only the second moment is factored:
//!
//!   A ← β₂A + (1−β₂)·rowsum(G⊙G),  C ← β₂C + (1−β₂)·colsum(G⊙G)
//!   V̂ᵢⱼ = AᵢCⱼ / ΣA,   W ← W − η · M̂/√(V̂+ε)
//!
//! This is the algorithm Claim 1 equates with Shampoo when run in Shampoo's
//! eigenbasis; SOAP's `factorized` variant reuses the same factored second
//! moment (see `soap.rs`).

use super::hyper::Hyper;
use super::LayerOptimizer;
use crate::linalg::Matrix;

pub struct Adafactor {
    h: Hyper,
    m: Matrix,
    /// Row second-moment EMA (m×1) — `A` in the paper's Algorithm 2.
    a: Vec<f32>,
    /// Column second-moment EMA (1×n) — `C`.
    c: Vec<f32>,
    /// For 1-D parameters the factorization is degenerate; fall back to a
    /// full Adam `V` (matches practical Adafactor implementations).
    v_1d: Option<Matrix>,
}

/// Compute the factored second-moment denominator √(AᵢCⱼ/ΣA + ε) and return
/// the elementwise-normalized `num / denom`. Shared with SOAP-factorized.
pub fn factored_normalize(num: &Matrix, a: &[f32], c: &[f32], eps: f32) -> Matrix {
    let sum_a: f32 = a.iter().map(|&x| x as f64).sum::<f64>() as f32;
    let inv_sum = if sum_a > 0.0 { 1.0 / sum_a } else { 0.0 };
    Matrix::from_fn(num.rows, num.cols, |i, j| {
        let vhat = (a[i] * c[j] * inv_sum).max(0.0);
        num.at(i, j) / (vhat + eps).sqrt()
    })
}

impl Adafactor {
    pub fn new(rows: usize, cols: usize, h: Hyper) -> Self {
        let is_1d = rows == 1 || cols == 1;
        Self {
            h,
            m: Matrix::zeros(rows, cols),
            a: vec![0.0; rows],
            c: vec![0.0; cols],
            v_1d: if is_1d { Some(Matrix::zeros(rows, cols)) } else { None },
        }
    }
}

impl LayerOptimizer for Adafactor {
    fn update(&mut self, w: &mut Matrix, g: &Matrix, t: u64, lr: f32) {
        let h = &self.h;
        self.m.ema_inplace(g, h.beta1);
        let bc1 = 1.0 - h.beta1.powi(t as i32);
        let bc2 = 1.0 - h.beta2.powi(t as i32);

        let dir = if let Some(v) = &mut self.v_1d {
            // Degenerate (vector) case: plain Adam second moment.
            let g2 = g.hadamard(g);
            v.ema_inplace(&g2, h.beta2);
            self.m
                .zip(v, |mi, vi| (mi / bc1) / ((vi / bc2).max(0.0).sqrt() + h.eps))
        } else {
            let g2 = g.hadamard(g);
            let rows = g2.row_sums();
            let cols = g2.col_sums();
            for (ai, ri) in self.a.iter_mut().zip(&rows) {
                *ai = h.beta2 * *ai + (1.0 - h.beta2) * ri;
            }
            for (ci, cj) in self.c.iter_mut().zip(&cols) {
                *ci = h.beta2 * *ci + (1.0 - h.beta2) * cj;
            }
            // Bias-correct A, C and M; the ΣA normalization makes the A/C
            // corrections cancel except through ε, but we keep them for
            // parity with the Adam code path.
            let a_hat: Vec<f32> = self.a.iter().map(|&x| x / bc2).collect();
            let c_hat: Vec<f32> = self.c.iter().map(|&x| x / bc2).collect();
            let m_hat = self.m.scale(1.0 / bc1);
            factored_normalize(&m_hat, &a_hat, &c_hat, h.eps)
        };

        w.axpy_inplace(-lr, &dir);
        if h.weight_decay != 0.0 {
            w.scale_inplace(1.0 - lr * h.weight_decay);
        }
    }

    fn state_bytes(&self) -> usize {
        let factored = (self.a.len() + self.c.len()) * 4;
        let v1d = self.v_1d.as_ref().map(|v| v.numel() * 4).unwrap_or(0);
        self.m.numel() * 4 + factored + v1d
    }

    fn name(&self) -> &'static str {
        "adafactor"
    }

    fn export_state(&self) -> Vec<Matrix> {
        let mut out = vec![
            self.m.clone(),
            Matrix::from_vec(1, self.a.len(), self.a.clone()),
            Matrix::from_vec(1, self.c.len(), self.c.clone()),
        ];
        if let Some(v) = &self.v_1d {
            out.push(v.clone());
        }
        out
    }

    fn import_state(&mut self, state: Vec<Matrix>) -> anyhow::Result<()> {
        anyhow::ensure!(state.len() >= 3, "adafactor expects ≥3 state tensors");
        let mut it = state.into_iter();
        self.m = it.next().unwrap();
        self.a = it.next().unwrap().data;
        self.c = it.next().unwrap().data;
        if self.v_1d.is_some() {
            self.v_1d = Some(it.next().ok_or_else(|| anyhow::anyhow!("missing v_1d"))?);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn h_nowd() -> Hyper {
        Hyper { weight_decay: 0.0, ..Hyper::default() }
    }

    #[test]
    fn rank1_gradient_recovers_adam_scale() {
        // For a rank-1 |G| = u·vᵀ the factored V̂ is exact, so the step
        // magnitude matches AdamW's.
        let u = [1.0f32, 2.0];
        let v = [0.5f32, 1.0, 1.5];
        let g = Matrix::from_fn(2, 3, |i, j| u[i] * v[j]);
        let mut opt = Adafactor::new(2, 3, h_nowd());
        let mut w = Matrix::zeros(2, 3);
        for t in 1..=300 {
            let mut wc = w.clone();
            opt.update(&mut wc, &g, t, 0.01);
            if t == 300 {
                let step = w.sub(&wc).scale(1.0 / 0.01);
                // Every coordinate should step with unit magnitude.
                for &s in &step.data {
                    assert!((s.abs() - 1.0).abs() < 0.05, "step {s}");
                }
            }
            w = wc;
        }
    }

    #[test]
    fn state_is_sublinear_for_2d() {
        let opt = Adafactor::new(64, 128, Hyper::default());
        // m·n (momentum) + m + n (factored), ×4 bytes.
        assert_eq!(opt.state_bytes(), (64 * 128 + 64 + 128) * 4);
    }

    #[test]
    fn vector_param_uses_full_v() {
        let opt = Adafactor::new(1, 32, Hyper::default());
        assert_eq!(opt.state_bytes(), (32 + 1 + 32 + 32) * 4);
    }

    #[test]
    fn minimizes_quadratic() {
        let mut rng = Rng::new(6);
        let target = Matrix::randn(&mut rng, 5, 3, 1.0);
        let mut w = Matrix::zeros(5, 3);
        let mut opt = Adafactor::new(5, 3, h_nowd());
        for t in 1..=3000 {
            let g = w.sub(&target).scale(2.0);
            opt.update(&mut w, &g, t, 0.02);
        }
        assert!(w.max_abs_diff(&target) < 0.1, "{}", w.max_abs_diff(&target));
    }

    #[test]
    fn factored_normalize_row_col_structure() {
        let num = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let a = [4.0f32, 1.0];
        let c = [2.0f32, 8.0];
        let out = factored_normalize(&num, &a, &c, 0.0);
        // vhat[0][0] = 4*2/5, vhat[1][1] = 1*8/5 — check one ratio.
        let want00 = 1.0 / (8.0f32 / 5.0).sqrt();
        assert!((out.at(0, 0) - want00).abs() < 1e-5);
    }
}
