//! The idealized algorithms of the paper's §4.1 (Claim 1):
//!
//! - **Algorithm 1** — idealized Shampoo with power 1/2: dataset averages
//!   `L = E[GGᵀ]`, `R = E[GᵀG]`, update `Tr(L)^{1/2} · L^{-1/2} G R^{-1/2}`.
//! - **Algorithm 2** — idealized Adafactor run in Shampoo's eigenbasis:
//!   rotate by the eigenvectors of L and R, apply the factored second-moment
//!   normalization, rotate back.
//!
//! Claim 1 states these are identical; `rust/tests/prop_optim.rs` property-
//! tests that equivalence and `benches/claim1_equiv.rs` reports the residual
//! over random gradient datasets (the paper's Table-free theoretical check).

use crate::linalg::{eigh, inv_root_eigh, Matrix};

/// Dataset averages L = E[GGᵀ], R = E[GᵀG].
pub fn dataset_factors(grads: &[Matrix]) -> (Matrix, Matrix) {
    assert!(!grads.is_empty());
    let (m, n) = (grads[0].rows, grads[0].cols);
    let mut l = Matrix::zeros(m, m);
    let mut r = Matrix::zeros(n, n);
    for g in grads {
        l = l.add(&g.matmul_nt(g));
        r = r.add(&g.matmul_tn(g));
    }
    let k = grads.len() as f32;
    (l.scale(1.0 / k), r.scale(1.0 / k))
}

/// Algorithm 1: one idealized-Shampoo step direction for gradient `g`.
pub fn idealized_shampoo_dir(grads: &[Matrix], g: &Matrix) -> Matrix {
    let (l, r) = dataset_factors(grads);
    let tr = l.trace();
    let l_inv = inv_root_eigh(&l, 2.0, 0.0);
    let r_inv = inv_root_eigh(&r, 2.0, 0.0);
    // Ĥ = L⊗R/Tr(L) ⇒ Ĥ^{-1/2} G = Tr(L)^{1/2} L^{-1/2} G R^{-1/2}.
    l_inv.matmul(g).matmul(&r_inv).scale(tr.sqrt())
}

/// Algorithm 2: one idealized Adafactor-in-eigenbasis step direction.
pub fn idealized_adafactor_dir(grads: &[Matrix], g: &Matrix, eps: f32) -> Matrix {
    let (l, r) = dataset_factors(grads);
    let (_, ql) = eigh(&l);
    let (_, qr) = eigh(&r);

    // Rotated dataset second moments.
    let (m, n) = (g.rows, g.cols);
    let mut e_g2 = Matrix::zeros(m, n);
    for gb in grads {
        let gp = ql.matmul_tn(gb).matmul(&qr);
        e_g2 = e_g2.add(&gp.hadamard(&gp));
    }
    e_g2.scale_inplace(1.0 / grads.len() as f32);

    // A = row sums, C = col sums, V̂ = A·Cᵀ / ΣA.
    let a = e_g2.row_sums();
    let c = e_g2.col_sums();
    let sum_a: f32 = a.iter().sum();

    let g_rot = ql.matmul_tn(g).matmul(&qr);
    let g_norm = Matrix::from_fn(m, n, |i, j| {
        let vhat = (a[i] * c[j] / sum_a).max(0.0);
        g_rot.at(i, j) / (vhat + eps).sqrt()
    });
    ql.matmul(&g_norm).matmul_nt(&qr)
}

/// The A/λ identity proved inside Claim 1: row sums of the rotated dataset
/// second moment equal the eigenvalues of L. Returns (A, λ) for inspection.
pub fn claim1_row_identity(grads: &[Matrix]) -> (Vec<f32>, Vec<f32>) {
    let (l, _) = dataset_factors(grads);
    let (lambda, ql) = eigh(&l);
    let (m, n) = (grads[0].rows, grads[0].cols);
    let mut e_g2 = Matrix::zeros(m, n);
    for gb in grads {
        let gp = ql.matmul_tn(gb);
        e_g2 = e_g2.add(&gp.hadamard(&gp));
    }
    e_g2.scale_inplace(1.0 / grads.len() as f32);
    (e_g2.row_sums(), lambda)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_dataset(rng: &mut Rng, k: usize, m: usize, n: usize) -> Vec<Matrix> {
        (0..k).map(|_| Matrix::randn(rng, m, n, 1.0)).collect()
    }

    #[test]
    fn claim1_equivalence_small() {
        let mut rng = Rng::new(60);
        let grads = random_dataset(&mut rng, 12, 4, 3);
        let g = grads[0].clone();
        let d1 = idealized_shampoo_dir(&grads, &g);
        let d2 = idealized_adafactor_dir(&grads, &g, 0.0);
        let rel = d1.max_abs_diff(&d2) / d1.max_abs().max(1e-12);
        assert!(rel < 5e-2, "claim 1 violated: rel err {rel}");
    }

    #[test]
    fn row_identity_a_equals_lambda() {
        let mut rng = Rng::new(61);
        let grads = random_dataset(&mut rng, 10, 5, 4);
        let (a, lambda) = claim1_row_identity(&grads);
        for (x, y) in a.iter().zip(&lambda) {
            assert!((x - y).abs() < 2e-2 * (1.0 + y.abs()), "{x} vs {y}");
        }
    }

    #[test]
    fn shampoo_dir_whitens_spectrum() {
        // For G drawn i.i.d., preconditioning with the dataset factors should
        // roughly normalize the scale of the direction.
        let mut rng = Rng::new(62);
        let grads = random_dataset(&mut rng, 32, 6, 6);
        let g = grads[1].clone();
        let d = idealized_shampoo_dir(&grads, &g);
        assert!(d.frob_norm().is_finite());
        assert!(d.frob_norm() > 0.0);
    }
}
