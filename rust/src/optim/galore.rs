//! GaLore (Zhao et al. 2024a), full-rank version — the Appendix B baseline,
//! as a named preset over the composable core:
//!
//! ```text
//!   GaLore = GradSvdBasis × Adam (moments in the projected space)
//! ```
//!
//! The differences from SOAP that the paper calls out (§3) and that
//! Appendix B shows matter empirically are exactly the composition's two
//! swapped components:
//!
//!  1. the basis ([`crate::optim::compose::GradSvdBasis`]) comes from the SVD of the
//!     **current gradient** (not an EMA of GGᵀ/GᵀG), one side only;
//!  2. the engine ([`crate::optim::compose::AdamEngine`] with `MomentumSpace::InBasis`)
//!     keeps Adam's moments in the **projected space** and does *not*
//!     re-rotate them when the basis changes.
//!
//! The composition is bitwise-identical to the pre-refactor monolithic
//! implementation (`rust/tests/golden_compose.rs`).

use super::compose::{presets, DynComposed};
use super::hyper::Hyper;

/// Named preset: [`Galore::new`] builds the gradient-SVD × projected-Adam
/// composition.
pub struct Galore;

impl Galore {
    // Historical constructor name, kept across the compose refactor; it
    // intentionally returns the composed type, not Self.
    #[allow(clippy::new_ret_no_self)]
    pub fn new(rows: usize, cols: usize, h: Hyper) -> DynComposed {
        presets::galore(rows, cols, h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::optim::compose::GradSvdBasis;
    use crate::optim::LayerOptimizer;
    use crate::util::rng::Rng;

    fn h_base() -> Hyper {
        Hyper { weight_decay: 0.0, precond_freq: 5, ..Hyper::default() }
    }

    fn svd(opt: &DynComposed) -> &GradSvdBasis {
        opt.basis.as_grad_svd().expect("galore preset uses the grad-svd basis")
    }

    #[test]
    fn minimizes_quadratic() {
        let mut rng = Rng::new(50);
        let target = Matrix::randn(&mut rng, 4, 6, 1.0);
        let mut w = Matrix::zeros(4, 6);
        let mut opt = Galore::new(4, 6, h_base());
        for t in 1..=2000 {
            let g = w.sub(&target).scale(2.0);
            opt.update(&mut w, &g, t, 0.02);
        }
        assert!(w.max_abs_diff(&target) < 0.1, "{}", w.max_abs_diff(&target));
    }

    #[test]
    fn projects_smaller_side() {
        assert!(svd(&Galore::new(4, 16, h_base())).left);
        assert!(!svd(&Galore::new(16, 4, h_base())).left);
    }

    #[test]
    fn projector_is_orthogonal() {
        let mut rng = Rng::new(51);
        let mut opt = Galore::new(5, 9, h_base());
        let mut w = Matrix::zeros(5, 9);
        let g = Matrix::randn(&mut rng, 5, 9, 1.0);
        opt.update(&mut w, &g, 1, 0.01);
        let p = svd(&opt).p.as_ref().unwrap();
        assert_eq!(p.rows, 5);
        assert!(p.matmul_tn(p).max_abs_diff(&Matrix::eye(5)) < 1e-3);
    }

    #[test]
    fn basis_refreshes_at_frequency_only() {
        let mut rng = Rng::new(52);
        let mut opt = Galore::new(4, 4, h_base()); // f = 5
        let mut w = Matrix::zeros(4, 4);
        opt.update(&mut w, &Matrix::randn(&mut rng, 4, 4, 1.0), 1, 0.01);
        let p1 = svd(&opt).p.clone().unwrap();
        for t in 2..=4 {
            opt.update(&mut w, &Matrix::randn(&mut rng, 4, 4, 1.0), t, 0.01);
        }
        assert_eq!(svd(&opt).p.as_ref().unwrap(), &p1, "P changed off-schedule");
        opt.update(&mut w, &Matrix::randn(&mut rng, 4, 4, 1.0), 5, 0.01);
        assert!(svd(&opt).p.as_ref().unwrap().max_abs_diff(&p1) > 0.0);
    }

    #[test]
    fn state_excludes_large_side_projector() {
        let mut rng = Rng::new(53);
        let mut opt = Galore::new(4, 32, h_base());
        let mut w = Matrix::zeros(4, 32);
        opt.update(&mut w, &Matrix::randn(&mut rng, 4, 32, 1.0), 1, 0.01);
        // P is 4×4 (small side), not 32×32.
        assert_eq!(opt.state_bytes(), (16 + 2 * 4 * 32) * 4);
    }
}
