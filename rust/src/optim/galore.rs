//! GaLore (Zhao et al. 2024a), full-rank version — the Appendix B baseline.
//!
//! Differences from SOAP that the paper calls out (§3) and that Appendix B
//! shows matter empirically:
//!  1. the projection basis comes from the SVD of the **current gradient**
//!     (not an EMA of GGᵀ/GᵀG);
//!  2. Adam's momentum lives in the **projected space** and is *not*
//!     re-rotated when the basis changes;
//!  3. only ONE side is projected (the smaller one), identity on the other.
//!
//! For the full-rank square projector the left singular vectors of `G` are
//! the eigenvectors of `GGᵀ`, so we compute the basis with the Jacobi `eigh`
//! of the square factor (avoids needing a general SVD).

use super::hyper::Hyper;
use super::LayerOptimizer;
use crate::linalg::{eigh, Matrix};

pub struct Galore {
    h: Hyper,
    /// Projection matrix P (k×k on the smaller side); identity until the
    /// first refresh step.
    p: Option<Matrix>,
    /// Project the left side (true) or the right side (false).
    left: bool,
    /// Adam moments in the PROJECTED space.
    m: Matrix,
    v: Matrix,
    refresh_secs: f64,
}

impl Galore {
    pub fn new(rows: usize, cols: usize, h: Hyper) -> Self {
        Self {
            left: rows <= cols,
            p: None,
            m: Matrix::zeros(rows, cols),
            v: Matrix::zeros(rows, cols),
            refresh_secs: 0.0,
            h,
        }
    }

    fn project(&self, g: &Matrix) -> Matrix {
        match (&self.p, self.left) {
            (Some(p), true) => p.matmul_tn(g),
            (Some(p), false) => g.matmul(p),
            (None, _) => g.clone(),
        }
    }

    fn project_back(&self, x: &Matrix) -> Matrix {
        match (&self.p, self.left) {
            (Some(p), true) => p.matmul(x),
            (Some(p), false) => x.matmul_nt(p),
            (None, _) => x.clone(),
        }
    }
}

impl LayerOptimizer for Galore {
    fn update(&mut self, w: &mut Matrix, g: &Matrix, t: u64, lr: f32) {
        let h = self.h.clone();

        // Basis refresh from the CURRENT gradient (difference #1), at this
        // layer's staggered phase (`build_staggered` sets layer_idx % f).
        if self.p.is_none() || h.is_refresh_step(t) {
            let t0 = std::time::Instant::now();
            let factor = if self.left { g.matmul_nt(g) } else { g.matmul_tn(g) };
            let (_, vecs) = eigh(&factor);
            self.p = Some(vecs);
            // NOTE: momentum is deliberately NOT re-rotated (difference #2).
            self.refresh_secs += t0.elapsed().as_secs_f64();
        }

        let g_proj = self.project(g);
        self.m.ema_inplace(&g_proj, h.beta1);
        let g2 = g_proj.hadamard(&g_proj);
        self.v.ema_inplace(&g2, h.beta2);

        let bc1 = 1.0 - h.beta1.powi(t as i32);
        let bc2 = 1.0 - h.beta2.powi(t as i32);
        let dir_proj = self
            .m
            .zip(&self.v, |mi, vi| (mi / bc1) / ((vi / bc2).max(0.0).sqrt() + h.eps));
        let dir = self.project_back(&dir_proj).scale(h.galore_scale);

        w.axpy_inplace(-lr, &dir);
        if h.weight_decay != 0.0 {
            w.scale_inplace(1.0 - lr * h.weight_decay);
        }
    }

    fn state_bytes(&self) -> usize {
        let p = self.p.as_ref().map(|p| p.numel()).unwrap_or(0);
        (p + self.m.numel() + self.v.numel()) * 4
    }

    fn name(&self) -> &'static str {
        "galore"
    }

    fn refresh_seconds(&self) -> f64 {
        self.refresh_secs
    }

    fn export_state(&self) -> Vec<Matrix> {
        let has_p = Matrix::from_vec(1, 1, vec![self.p.is_some() as u8 as f32]);
        let mut out = vec![has_p, self.m.clone(), self.v.clone()];
        if let Some(p) = &self.p {
            out.push(p.clone());
        }
        out
    }

    fn import_state(&mut self, state: Vec<Matrix>) -> anyhow::Result<()> {
        anyhow::ensure!(state.len() >= 3, "galore expects ≥3 state tensors");
        let mut it = state.into_iter();
        let has_p = it.next().unwrap().data[0] != 0.0;
        self.m = it.next().unwrap();
        self.v = it.next().unwrap();
        self.p = if has_p {
            Some(it.next().ok_or_else(|| anyhow::anyhow!("missing p"))?)
        } else {
            None
        };
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn h_base() -> Hyper {
        Hyper { weight_decay: 0.0, precond_freq: 5, ..Hyper::default() }
    }

    #[test]
    fn minimizes_quadratic() {
        let mut rng = Rng::new(50);
        let target = Matrix::randn(&mut rng, 4, 6, 1.0);
        let mut w = Matrix::zeros(4, 6);
        let mut opt = Galore::new(4, 6, h_base());
        for t in 1..=2000 {
            let g = w.sub(&target).scale(2.0);
            opt.update(&mut w, &g, t, 0.02);
        }
        assert!(w.max_abs_diff(&target) < 0.1, "{}", w.max_abs_diff(&target));
    }

    #[test]
    fn projects_smaller_side() {
        assert!(Galore::new(4, 16, h_base()).left);
        assert!(!Galore::new(16, 4, h_base()).left);
    }

    #[test]
    fn projector_is_orthogonal() {
        let mut rng = Rng::new(51);
        let mut opt = Galore::new(5, 9, h_base());
        let mut w = Matrix::zeros(5, 9);
        let g = Matrix::randn(&mut rng, 5, 9, 1.0);
        opt.update(&mut w, &g, 1, 0.01);
        let p = opt.p.as_ref().unwrap();
        assert_eq!(p.rows, 5);
        assert!(p.matmul_tn(p).max_abs_diff(&Matrix::eye(5)) < 1e-3);
    }

    #[test]
    fn basis_refreshes_at_frequency_only() {
        let mut rng = Rng::new(52);
        let mut opt = Galore::new(4, 4, h_base()); // f = 5
        let mut w = Matrix::zeros(4, 4);
        opt.update(&mut w, &Matrix::randn(&mut rng, 4, 4, 1.0), 1, 0.01);
        let p1 = opt.p.clone().unwrap();
        for t in 2..=4 {
            opt.update(&mut w, &Matrix::randn(&mut rng, 4, 4, 1.0), t, 0.01);
        }
        assert_eq!(opt.p.as_ref().unwrap(), &p1, "P changed off-schedule");
        opt.update(&mut w, &Matrix::randn(&mut rng, 4, 4, 1.0), 5, 0.01);
        assert!(opt.p.as_ref().unwrap().max_abs_diff(&p1) > 0.0);
    }

    #[test]
    fn state_excludes_large_side_projector() {
        let mut rng = Rng::new(53);
        let mut opt = Galore::new(4, 32, h_base());
        let mut w = Matrix::zeros(4, 32);
        opt.update(&mut w, &Matrix::randn(&mut rng, 4, 32, 1.0), 1, 0.01);
        // P is 4×4 (small side), not 32×32.
        assert_eq!(opt.state_bytes(), (16 + 2 * 4 * 32) * 4);
    }
}
