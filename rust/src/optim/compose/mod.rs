//! Composable optimizer core — the paper's factorization as an API.
//!
//! The central claim of "SOAP: Improving and Stabilizing Shampoo using Adam"
//! (Vyas et al., 2024) is structural: SOAP is **Adam run in Shampoo's
//! eigenbasis**; Shampoo with power 1/2 is **Adafactor in that same basis**
//! (Claim 1, after Morwani et al. 2024); GaLore is **Adam in a gradient-SVD
//! basis** (§3 / Appendix B). This module turns that observation into the
//! optimizer architecture: every optimizer is a composition
//!
//! ```text
//!   Composed = Graft? ∘ (Basis × MomentEngine)
//! ```
//!
//! - [`Basis`] — how the gradient is carried into a working space and back:
//!   [`basis::IdentityBasis`] (no rotation), [`basis::EigenBasis`] (the
//!   slowly-refreshed Kronecker-factor decomposition, orthonormal-rotation
//!   or inverse-root flavored, one/two-sided, dim-capped, QR-power-iteration
//!   or warm-`eigh`, inline or async via `precond::RefreshService`), and
//!   [`basis::GradSvdBasis`] (GaLore's current-gradient projector).
//! - [`MomentEngine`] — the update rule inside that space:
//!   [`engine::AdamEngine`], [`engine::AdafactorEngine`] (rank-1 factored),
//!   [`engine::InverseRootEngine`] (Shampoo's `L^{-1/e}·M̂·R^{-1/e}`).
//! - [`Graft`] — optional layerwise AdamW norm grafting
//!   (DistributedShampoo-style), wrapping any engine's direction.
//!
//! [`Composed`] implements [`LayerOptimizer`] over any `(Basis, Engine)`
//! pair; the named presets (`soap`, `shampoo`, `galore`, `adamw`,
//! `adafactor`) are just labeled compositions (see [`presets`]), and the
//! CLI's `--optimizer basis=…,inner=…[,graft=…]` grammar ([`spec`]) builds
//! novel combinations with zero new code. Composed presets reproduce the
//! pre-refactor monolithic optimizers bitwise (`rust/tests/golden_compose.rs`).
//!
//! # Tensor parameters (rank ≠ 2)
//!
//! Shampoo is defined for arbitrary-rank tensors (one Kronecker factor per
//! mode — Gupta et al., 2018), and the SOAP recipe prescribes how each rank
//! is treated in practice. `OptKind::build_tensor` routes a
//! [`crate::linalg::TensorShape`] accordingly:
//!
//! - **rank 1** (biases, gains): plain Adam — the paper's implementation
//!   detail 1. The rotating bases fall back to [`basis::IdentityBasis`];
//!   Shampoo still preconditions the `1×n` carrier.
//! - **rank 2**: the existing two/one-sided [`basis::EigenBasis`] path,
//!   bitwise identical to the pre-tensor code (`rust/tests/golden_tensor.rs`).
//! - **rank 3+**: [`tensor_basis::TensorEigenBasis`] — per-mode factor EMAs
//!   and eigenbases applied as a chain of mode-k products, after
//!   `merge_dims`-style adjacent-mode merging (`Hyper::merge_dims`) and with
//!   any mode larger than `Hyper::max_precond_dim` kept at identity
//!   (`d == cap` is still preconditioned — the 2-D boundary convention).
//!
//! Engines are rank-agnostic: they run over the carrier fold
//! (`TensorShape::carrier`) and talk to the basis only through
//! `project_into`/`project_back_into`, so SOAP's momentum-re-rotation,
//! factorized second moments, grafting, and the zero-allocation workspace
//! path all carry over to any rank unchanged.

pub mod basis;
pub mod engine;
pub mod spec;
pub mod state;
pub mod tensor_basis;
pub mod workspace;

pub use basis::{AnyBasis, EigenBasis, EigenFlavor, GradSvdBasis, IdentityBasis};
pub use tensor_basis::TensorEigenBasis;
pub use engine::{
    factored_normalize, AdafactorEngine, AdamEngine, AnyEngine, InverseRootEngine, MomentumSpace,
};
pub use spec::{BasisSpec, CompositionSpec, EngineSpec, GraftSpec, Sided};
pub use state::{StateMatrix, StateVec};
pub use workspace::{Scratch, Workspace};

use std::sync::Arc;

use crate::linalg::Matrix;
use crate::optim::hyper::{GuardPolicy, Hyper};
use crate::optim::LayerOptimizer;
use crate::precond::{DistBasisPort, RefreshService};

/// Serialized basis component: flag scalars + tensors, in the basis's
/// canonical order. [`Composed`] assembles these into the wire layout.
pub struct BasisState {
    pub flags: Vec<f32>,
    pub tensors: Vec<Matrix>,
}

/// Serialized engine component: first moment + second-moment tensors.
pub struct EngineState {
    pub momentum: Matrix,
    pub second: Vec<Matrix>,
}

/// How a composition's state tensors are laid out on the wire. Pinned per
/// basis kind so composed presets emit (and accept) EXACTLY the pre-refactor
/// checkpoint rows — old checkpoints keep loading.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StateLayout {
    /// `[M, second…]` — identity basis (AdamW, Adafactor rows).
    Bare,
    /// `[flags(1×5), M, basis…, second…(, graft V)]` with flags
    /// `[initialized, has_l, has_r, has_full_v, basis_step]` — rotation
    /// eigenbasis (SOAP rows; cols == 4 accepts pre-`basis_step`
    /// checkpoints).
    BasisMid,
    /// `[flags(1×2), M, L, R, L^{-1/e}, R^{-1/e}, graft V]` with flags
    /// `[initialized, basis_step]` — inverse-root eigenbasis (Shampoo rows;
    /// cols == 1 accepts pre-`basis_step` checkpoints).
    InverseRoot,
    /// `[flags(1×1 = has_p), M, second…, P?]` — gradient-SVD basis
    /// (GaLore rows).
    BasisLast,
    /// `[flags(1×(2+3r+1)), M, per-mode records…, second…(, graft V)]` with
    /// flags `[initialized, rank, (has_k, step_k, vecs_k)×r, full_v]` —
    /// per-mode tensor eigenbasis (rank-3+ rows, checkpoint format v3).
    TensorModes,
}

/// Per-layer basis state machine: carries gradients into a working space,
/// maintains whatever decomposition that requires, and schedules its
/// periodic refresh (inline or async).
///
/// `begin_step` runs before the engine computes a direction, `end_step`
/// after the weights moved — which hook does the factor bookkeeping is the
/// basis's own contract (Shampoo refreshes pre-direction, SOAP post-update).
pub trait Basis: Send {
    /// Pre-direction hook. `ws` provides the factor-product scratch
    /// (`ws.factor`, `ws.scratch.pack`) so the per-step `GGᵀ`/`GᵀG` EMAs
    /// allocate nothing in steady state.
    fn begin_step(&mut self, g: &Matrix, t: u64, ws: &mut Workspace);
    /// Post-update hook (same workspace contract).
    fn end_step(&mut self, g: &Matrix, t: u64, ws: &mut Workspace);

    /// True when `project`/`project_back` are no-ops — engines use this to
    /// skip the defensive copy on the hot path.
    fn is_identity(&self) -> bool {
        false
    }

    /// Carry `x` into the working space, writing into `out` (grow-only
    /// reuse; `scratch` supplies the two-sided intermediate and NT pack).
    fn project_into(&self, x: &Matrix, out: &mut Matrix, scratch: &mut Scratch);

    /// Carry `x` back to the original space, into `out`.
    fn project_back_into(&self, x: &Matrix, out: &mut Matrix, scratch: &mut Scratch);

    /// Allocating wrapper over [`Basis::project_into`] — the reference path
    /// (`Composed::update_legacy_alloc`) and one-off callers use it; the
    /// step path never does.
    fn project(&self, x: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(0, 0);
        let mut scratch = Scratch::new();
        self.project_into(x, &mut out, &mut scratch);
        out
    }

    /// Allocating wrapper over [`Basis::project_back_into`].
    fn project_back(&self, x: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(0, 0);
        let mut scratch = Scratch::new();
        self.project_back_into(x, &mut out, &mut scratch);
        out
    }

    /// Wall-clock spent in inline decompositions so far (Fig 7 accounting).
    fn refresh_seconds(&self) -> f64 {
        0.0
    }

    /// Route periodic refreshes through the background service. Returns
    /// `false` when there is nothing to refresh.
    fn attach_async(&mut self, service: &Arc<RefreshService>) -> bool {
        let _ = service;
        false
    }

    /// Place this basis under distributed refresh ownership. `owned` says
    /// whether THIS rank runs the layer's periodic refreshes (publishing
    /// them for broadcast) or adopts a peer's broadcasts. Returns one
    /// [`DistBasisPort`] per refreshable component in a deterministic order
    /// (the wire address is `(layer_idx, port_idx)`); empty when there is
    /// nothing to broadcast — such a basis refreshes locally as usual.
    fn attach_dist(&mut self, owned: bool) -> Vec<DistBasisPort> {
        let _ = owned;
        Vec::new()
    }

    /// True when step `t`'s refresh runs inline and feeds the SAME step's
    /// update (Shampoo's inverse-root flavor), so a distributed run must
    /// exchange the owner's publication mid-step, before non-owning ranks
    /// compute their direction. Must be a pure function of replicated state
    /// — every rank evaluates it with the same result.
    fn dist_mid_step_sync(&self, t: u64) -> bool {
        let _ = t;
        false
    }

    /// Adopt any decomposition the background service has published but the
    /// step loop has not picked up yet (adoption normally happens at the next
    /// `begin_step`). Checkpointing calls this — after draining the service —
    /// so `export` captures the same basis an uninterrupted run would use on
    /// its next step. No-op for inline bases.
    fn adopt_pending(&mut self) {}

    /// Step whose factor snapshots back the ACTIVE decomposition.
    fn basis_snapshot_step(&self) -> Option<u64> {
        None
    }

    /// Off-diagonal mass ratio of the rotated second moment at the most
    /// recent refresh (see `LayerOptimizer::whitening_offdiag`). Bases
    /// without a rotation — or that have not sampled yet — return `None`.
    fn whitening_offdiag(&self) -> Option<f64> {
        None
    }

    /// Bytes of state held by the basis (paper §7.2 accounting).
    fn state_bytes(&self) -> usize;

    fn export(&self) -> BasisState;
    fn import(
        &mut self,
        flags: &[f32],
        it: &mut dyn Iterator<Item = Matrix>,
    ) -> anyhow::Result<()>;

    /// Which wire layout compositions over this basis use.
    fn layout(&self) -> StateLayout;
}

/// Per-layer update rule inside (or around) a basis's working space.
pub trait MomentEngine: Send {
    /// Consume gradient `g` at step `t`, update the moments, and leave the
    /// un-scaled descent direction in the ORIGINAL space in `ws.dir`. The
    /// engine calls `basis.project_into`/`project_back_into` itself, so it
    /// controls which space each moment lives in. Steady-state
    /// allocation-free: all intermediates live in `ws`, and the EMA +
    /// bias-correction + `m/√v` arithmetic runs as one fused pass.
    fn direction_into(&mut self, g: &Matrix, t: u64, basis: &dyn Basis, ws: &mut Workspace);

    /// Allocating reference implementation of the same math (the frozen
    /// pre-workspace `clone`/`map`/`zip` path). `Composed::update_legacy_alloc`
    /// and the golden workspace-vs-alloc pin test run it; results are
    /// bitwise identical to [`MomentEngine::direction_into`].
    fn direction(&mut self, g: &Matrix, t: u64, basis: &dyn Basis) -> Matrix;

    /// The first moment, for norm grafting.
    fn momentum(&self) -> &Matrix;

    /// Whether the second moment is a full matrix (`V`) rather than factored
    /// — recorded in the `BasisMid` flags row for checkpoint self-description.
    fn full_v(&self) -> bool;

    /// Bytes of state held by the engine (paper §7.2 accounting).
    fn state_bytes(&self) -> usize;

    fn export(&self) -> EngineState;
    fn import(
        &mut self,
        momentum: Matrix,
        it: &mut dyn Iterator<Item = Matrix>,
    ) -> anyhow::Result<()>;
}

/// Layerwise AdamW norm grafting (DistributedShampoo default): rescale the
/// composed direction to the Frobenius norm an AdamW step would have taken
/// on the same gradient stream. Keeps the scalar step size adapting every
/// step even while the basis ages — the same argument that lets SOAP
/// tolerate a stale basis.
///
/// Grafting state is deliberately **excluded from `Hyper::state_dtype`**
/// and always stored f32: its `V` feeds a norm whose f64 accumulation is
/// bitwise-pinned against `AdamW::direction`, and grafting only ships with
/// Shampoo presets where the Kronecker factors — not this buffer — dominate
/// the §7.2 table.
pub struct Graft {
    /// Grafting can be carried (state allocated, exported) but inactive —
    /// the pre-refactor Shampoo always held `V_graft` even with
    /// `Hyper::grafting == false`.
    pub active: bool,
    pub v: Matrix,
    beta1: f32,
    beta2: f32,
    eps: f32,
}

impl Graft {
    pub fn new(rows: usize, cols: usize, h: &Hyper) -> Self {
        Self {
            active: h.grafting,
            v: Matrix::zeros(rows, cols),
            beta1: h.beta1,
            beta2: h.beta2,
            eps: h.eps,
        }
    }

    /// Rescale `dir` to AdamW's norm for this gradient; `m` is the engine's
    /// momentum (shared — grafting adds only the second moment).
    ///
    /// Fused and allocation-free: the `V` EMA, the AdamW direction, and its
    /// Frobenius norm run in one pass — the reference AdamW direction matrix
    /// (`AdamW::direction`) is never materialized, but each of its elements
    /// is computed with the identical f32 expressions, so the resulting
    /// norm (f64-accumulated, in element order) is bitwise the same.
    pub fn apply(&mut self, dir: &mut Matrix, g: &Matrix, m: &Matrix, t: u64) {
        if !self.active {
            return;
        }
        let bc1 = 1.0 - self.beta1.powi(t as i32);
        let bc2 = 1.0 - self.beta2.powi(t as i32);
        let ob2 = 1.0 - self.beta2;
        let mut norm2 = 0.0f64;
        for ((vi, &gi), &mi) in self.v.data.iter_mut().zip(&g.data).zip(&m.data) {
            *vi = self.beta2 * *vi + ob2 * (gi * gi);
            let di = (mi / bc1) / ((*vi / bc2).max(0.0).sqrt() + self.eps);
            norm2 += di as f64 * di as f64;
        }
        let target = norm2.sqrt() as f32;
        let actual = dir.frob_norm();
        if actual > 1e-30 {
            dir.scale_inplace(target / actual);
        }
    }

    pub fn state_bytes(&self) -> usize {
        self.v.numel() * 4
    }
}

/// A basis × engine composition (+ optional graft) as a [`LayerOptimizer`].
///
/// Generic over the component types; the shipped closed-world instantiation
/// is [`DynComposed`] (`AnyBasis` × `AnyEngine`), which every preset and
/// CLI-spec build returns.
pub struct Composed<B: Basis, E: MomentEngine> {
    pub basis: B,
    pub engine: E,
    pub graft: Option<Graft>,
    /// Per-layer scratch arena (see [`workspace`]): owned here, never
    /// shared — the sharded coordinator assigns each layer to exactly one
    /// worker thread.
    ws: Workspace,
    h: Hyper,
    label: &'static str,
}

/// The closed-world composition every factory returns.
pub type DynComposed = Composed<AnyBasis, AnyEngine>;

impl<B: Basis, E: MomentEngine> Composed<B, E> {
    pub fn new(basis: B, engine: E, graft: Option<Graft>, h: Hyper, label: &'static str) -> Self {
        Self { basis, engine, graft, ws: Workspace::new(), h, label }
    }

    pub fn hyper(&self) -> &Hyper {
        &self.h
    }

    /// The allocating step path, kept as the executable reference:
    /// identical math through `MomentEngine::direction`'s
    /// `clone`/`map`/`zip` chain, over the same (workspace-backed) basis
    /// hooks as the fused path. `rust/tests/golden_compose.rs` pins
    /// [`LayerOptimizer::update`] bitwise against this. Note this is NOT
    /// the pre-PR baseline — that (seed kernels + allocating everything)
    /// lives in the `step_latency` bench's `prepr` module, behind its
    /// `--legacy-alloc` flag.
    pub fn update_legacy_alloc(&mut self, w: &mut Matrix, g: &Matrix, t: u64, lr: f32) {
        self.basis.begin_step(g, t, &mut self.ws);
        let mut dir = self.engine.direction(g, t, &self.basis);
        if let Some(graft) = &mut self.graft {
            graft.apply(&mut dir, g, self.engine.momentum(), t);
        }
        w.axpy_inplace(-lr, &dir);
        if self.h.weight_decay != 0.0 {
            w.scale_inplace(1.0 - lr * self.h.weight_decay);
        }
        self.basis.end_step(g, t, &mut self.ws);
    }

    /// Direction-level numerical-health guard (`Hyper::guard`): the last
    /// line of defense before a non-finite update reaches the weights. The
    /// trainer's gradient guard catches poisoned batches before the
    /// optimizer consumes them; this backstop catches poison produced
    /// *inside* the composition (a bad decomposition slipping past the basis
    /// rejection, engine overflow). Returns whether the weight update may
    /// proceed; `Clip` sanitizes `ws.dir` in place and proceeds.
    fn guard_direction(&mut self) -> bool {
        if self.h.guard == GuardPolicy::Off {
            return true;
        }
        // |x|-sum under f64 accumulation is monotone, so it is finite iff
        // every element is — one branch-free read pass, no allocation.
        let sum: f64 = self.ws.dir.data.iter().map(|&x| (x as f64).abs()).sum();
        if sum.is_finite() {
            return true;
        }
        match self.h.guard {
            GuardPolicy::Off => true,
            GuardPolicy::SkipStep => {
                crate::telemetry::metrics::step_skipped_total().inc();
                false
            }
            GuardPolicy::Clip(max) => {
                for x in &mut self.ws.dir.data {
                    *x = if x.is_finite() { x.clamp(-max, max) } else { 0.0 };
                }
                true
            }
            GuardPolicy::Abort => {
                crate::fault::flag_guard_abort();
                false
            }
        }
    }
}

impl<B: Basis, E: MomentEngine> LayerOptimizer for Composed<B, E> {
    fn update(&mut self, w: &mut Matrix, g: &Matrix, t: u64, lr: f32) {
        self.basis.begin_step(g, t, &mut self.ws);
        self.engine.direction_into(g, t, &self.basis, &mut self.ws);
        if let Some(graft) = &mut self.graft {
            graft.apply(&mut self.ws.dir, g, self.engine.momentum(), t);
        }
        // A guard-skipped layer leaves `w` untouched but still runs
        // `end_step`: factor statistics keep accumulating from `g`, so every
        // rank of a distributed run (which sees the same post-allreduce
        // gradient, hence the same skip decision) stays in lockstep.
        if self.guard_direction() {
            w.axpy_inplace(-lr, &self.ws.dir);
            if self.h.weight_decay != 0.0 {
                w.scale_inplace(1.0 - lr * self.h.weight_decay);
            }
        }
        self.basis.end_step(g, t, &mut self.ws);
    }

    fn scratch_bytes(&self) -> usize {
        self.ws.bytes()
    }

    fn state_bytes(&self) -> usize {
        // Exactly basis + engine + graft — each component accounts for the
        // tensors it owns (§7.2).
        self.basis.state_bytes()
            + self.engine.state_bytes()
            + self.graft.as_ref().map(|g| g.state_bytes()).unwrap_or(0)
    }

    fn name(&self) -> &'static str {
        self.label
    }

    fn refresh_seconds(&self) -> f64 {
        self.basis.refresh_seconds()
    }

    fn export_state(&self) -> Vec<Matrix> {
        let bs = self.basis.export();
        let es = self.engine.export();
        let mut out = Vec::new();
        match self.basis.layout() {
            StateLayout::Bare => {
                out.push(es.momentum);
                out.extend(es.second);
            }
            StateLayout::BasisMid => {
                // Pre-refactor SOAP row: [flags(1×5), M, L?, R?, QL?, QR?,
                // V | (va, vc)] with flags [init, has_l, has_r, has_v,
                // basis_step].
                let flags = Matrix::from_vec(
                    1,
                    5,
                    vec![
                        bs.flags[0],
                        bs.flags[1],
                        bs.flags[2],
                        self.engine.full_v() as u8 as f32,
                        bs.flags[3],
                    ],
                );
                out.push(flags);
                out.push(es.momentum);
                out.extend(bs.tensors);
                out.extend(es.second);
            }
            StateLayout::InverseRoot => {
                // Shampoo row: [flags(1×3), M, L, R, L_inv, R_inv,
                // V_L?, V_R?, V_graft] with flags [init, basis_step,
                // has_vecs]. Pre-refactor rows (flags 1×1 / 1×2, no
                // warm-start eigenvector caches) still import.
                out.push(Matrix::from_vec(1, bs.flags.len(), bs.flags.clone()));
                out.push(es.momentum);
                out.extend(bs.tensors);
            }
            StateLayout::BasisLast => {
                // Pre-refactor GaLore row: [has_p(1×1), M, V, P?].
                out.push(Matrix::from_vec(1, bs.flags.len(), bs.flags.clone()));
                out.push(es.momentum);
                out.extend(es.second);
                out.extend(bs.tensors);
            }
            StateLayout::TensorModes => {
                // Rank-3+ row (checkpoint v3): the basis's self-describing
                // per-mode flags with the engine's full-V marker appended,
                // then momentum, per-mode factor records, engine second
                // moments. No legacy spelling to match — this layout is new
                // with tensor parameters.
                let mut flags = bs.flags.clone();
                flags.push(self.engine.full_v() as u8 as f32);
                out.push(Matrix::from_vec(1, flags.len(), flags));
                out.push(es.momentum);
                out.extend(bs.tensors);
                out.extend(es.second);
            }
        }
        if let Some(graft) = &self.graft {
            out.push(graft.v.clone());
        }
        out
    }

    fn import_state(&mut self, state: Vec<Matrix>) -> anyhow::Result<()> {
        // A momentum tensor of the wrong shape means the row belongs to a
        // different layer/optimizer — fail loudly instead of training on
        // corrupted state.
        fn ensure_momentum_shape(expect: &Matrix, got: &Matrix) -> anyhow::Result<()> {
            anyhow::ensure!(
                got.rows == expect.rows && got.cols == expect.cols,
                "state momentum is {}×{} but the layer expects {}×{}",
                got.rows,
                got.cols,
                expect.rows,
                expect.cols,
            );
            Ok(())
        }
        let layout = self.basis.layout();
        let mut it = state.into_iter();
        match layout {
            StateLayout::Bare => {
                let m = it.next().ok_or_else(|| anyhow::anyhow!("state missing momentum"))?;
                ensure_momentum_shape(self.engine.momentum(), &m)?;
                self.engine.import(m, &mut it)?;
            }
            StateLayout::BasisMid => {
                let flags =
                    it.next().ok_or_else(|| anyhow::anyhow!("state missing flags row"))?;
                // cols == 4 accepts pre-basis_step checkpoints (staleness
                // restarts from 0 after such a restore).
                anyhow::ensure!(
                    flags.cols == 4 || flags.cols == 5,
                    "composed state flags malformed"
                );
                let has_v = flags.data[3] != 0.0;
                anyhow::ensure!(
                    has_v == self.engine.full_v(),
                    "checkpoint second moment is {} but the composed engine expects {}",
                    if has_v { "a full V" } else { "factored (va, vc)" },
                    if self.engine.full_v() { "a full V" } else { "factored (va, vc)" },
                );
                let basis_step = if flags.cols == 5 { flags.data[4] } else { 0.0 };
                let bflags = [flags.data[0], flags.data[1], flags.data[2], basis_step];
                let m = it.next().ok_or_else(|| anyhow::anyhow!("state missing momentum"))?;
                ensure_momentum_shape(self.engine.momentum(), &m)?;
                self.basis.import(&bflags, &mut it)?;
                self.engine.import(m, &mut it)?;
            }
            StateLayout::InverseRoot => {
                let flags =
                    it.next().ok_or_else(|| anyhow::anyhow!("state missing flags row"))?;
                // cols == 1 accepts pre-basis_step checkpoints; cols == 2
                // pre-warm-cache ones (their first refresh after a restore
                // cold-starts its eigh, as pre-refactor).
                anyhow::ensure!(
                    (1..=3).contains(&flags.cols),
                    "composed state flags malformed"
                );
                let basis_step = if flags.cols >= 2 { flags.data[1] } else { 0.0 };
                let has_vecs = if flags.cols >= 3 { flags.data[2] } else { 0.0 };
                let bflags = [flags.data[0], basis_step, has_vecs];
                let m = it.next().ok_or_else(|| anyhow::anyhow!("state missing momentum"))?;
                ensure_momentum_shape(self.engine.momentum(), &m)?;
                self.basis.import(&bflags, &mut it)?;
                self.engine.import(m, &mut it)?;
            }
            StateLayout::BasisLast => {
                let flags =
                    it.next().ok_or_else(|| anyhow::anyhow!("state missing flags row"))?;
                let m = it.next().ok_or_else(|| anyhow::anyhow!("state missing momentum"))?;
                ensure_momentum_shape(self.engine.momentum(), &m)?;
                self.engine.import(m, &mut it)?;
                self.basis.import(&flags.data, &mut it)?;
            }
            StateLayout::TensorModes => {
                let flags =
                    it.next().ok_or_else(|| anyhow::anyhow!("state missing flags row"))?;
                // [initialized, rank, (has, step, vecs)×r, full_v] — at
                // least rank 2 ⇒ 9 values.
                anyhow::ensure!(flags.cols >= 9, "tensor-mode state flags malformed");
                let has_v = flags.data[flags.cols - 1] != 0.0;
                anyhow::ensure!(
                    has_v == self.engine.full_v(),
                    "checkpoint second moment is {} but the composed engine expects {}",
                    if has_v { "a full V" } else { "factored (va, vc)" },
                    if self.engine.full_v() { "a full V" } else { "factored (va, vc)" },
                );
                let m = it.next().ok_or_else(|| anyhow::anyhow!("state missing momentum"))?;
                ensure_momentum_shape(self.engine.momentum(), &m)?;
                self.basis.import(&flags.data[..flags.cols - 1], &mut it)?;
                self.engine.import(m, &mut it)?;
            }
        }
        if let Some(graft) = &mut self.graft {
            graft.v = it
                .next()
                .ok_or_else(|| anyhow::anyhow!("state missing graft second moment"))?;
        }
        // Strict arity, as pre-refactor: leftover tensors mean the row was
        // written by a different optimizer configuration.
        anyhow::ensure!(
            it.next().is_none(),
            "state row carries unexpected extra tensors for optimizer '{}'",
            self.label,
        );
        Ok(())
    }

    fn attach_async(&mut self, service: &Arc<RefreshService>) -> bool {
        self.basis.attach_async(service)
    }

    fn attach_dist(&mut self, owned: bool) -> Vec<DistBasisPort> {
        self.basis.attach_dist(owned)
    }

    fn dist_mid_step_sync(&self, t: u64) -> bool {
        self.basis.dist_mid_step_sync(t)
    }

    fn finish_pending(&mut self) {
        self.basis.adopt_pending();
    }

    fn basis_snapshot_step(&self) -> Option<u64> {
        self.basis.basis_snapshot_step()
    }

    fn update_norm(&self) -> Option<f64> {
        if self.ws.dir.numel() == 0 {
            return None;
        }
        Some(self.ws.dir.data.iter().map(|&x| x as f64 * x as f64).sum::<f64>().sqrt())
    }

    fn whitening_offdiag(&self) -> Option<f64> {
        self.basis.whitening_offdiag()
    }
}

/// Named preset constructors — the paper's optimizers as compositions. The
/// thin `optim::{soap,shampoo,galore,adamw,adafactor}` modules re-expose
/// these under the historical type names.
pub mod presets {
    use super::*;

    /// SOAP (Algorithm 3): rotation eigenbasis × Adam — or × rank-1
    /// Adafactor when `h.factorized` (§7.2.1).
    pub fn soap(rows: usize, cols: usize, h: Hyper) -> DynComposed {
        let basis = AnyBasis::Eigen(EigenBasis::rotation(rows, cols, &h));
        let engine = if h.factorized {
            AnyEngine::Adafactor(AdafactorEngine::new(rows, cols, &h, MomentumSpace::Original))
        } else {
            AnyEngine::Adam(AdamEngine::new(rows, cols, &h, MomentumSpace::Original))
        };
        Composed::new(basis, engine, None, h, "soap")
    }

    /// Shampoo (DistributedShampoo configuration): inverse-root eigenbasis ×
    /// the Kronecker sandwich, wrapped in (optionally inactive) AdamW norm
    /// grafting.
    pub fn shampoo(rows: usize, cols: usize, h: Hyper) -> DynComposed {
        let basis = AnyBasis::Eigen(EigenBasis::inverse_root(rows, cols, &h));
        let engine = AnyEngine::InverseRoot(InverseRootEngine::new(rows, cols, &h));
        let graft = Graft::new(rows, cols, &h);
        Composed::new(basis, engine, Some(graft), h, "shampoo")
    }

    /// GaLore (full-rank, Appendix B): gradient-SVD basis × Adam with the
    /// moments kept in the projected space.
    pub fn galore(rows: usize, cols: usize, h: Hyper) -> DynComposed {
        let basis = AnyBasis::GradSvd(GradSvdBasis::new(rows, cols, &h));
        let engine = AnyEngine::Adam(AdamEngine::new(rows, cols, &h, MomentumSpace::InBasis));
        Composed::new(basis, engine, None, h, "galore")
    }

    /// AdamW: identity basis × Adam.
    pub fn adamw(rows: usize, cols: usize, h: Hyper) -> DynComposed {
        let basis = AnyBasis::Identity(IdentityBasis::new());
        let engine = AnyEngine::Adam(AdamEngine::new(rows, cols, &h, MomentumSpace::InBasis));
        Composed::new(basis, engine, None, h, "adamw")
    }

    /// Adafactor: identity basis × rank-1 factored second moment.
    pub fn adafactor(rows: usize, cols: usize, h: Hyper) -> DynComposed {
        let basis = AnyBasis::Identity(IdentityBasis::new());
        let engine =
            AnyEngine::Adafactor(AdafactorEngine::new(rows, cols, &h, MomentumSpace::InBasis));
        Composed::new(basis, engine, None, h, "adafactor")
    }

    /// SOAP on a rank-3+ tensor: per-mode rotation eigenbasis × Adam (or ×
    /// rank-1 Adafactor over the carrier fold when `h.factorized`). `carrier`
    /// is the 2-D fold the gradients arrive under
    /// ([`crate::linalg::TensorShape::carrier`]); `modes` the (squeezed,
    /// merged) mode sizes the basis preconditions over — same `numel`,
    /// possibly different split.
    pub fn soap_nd(
        carrier: (usize, usize),
        modes: &crate::linalg::TensorShape,
        h: Hyper,
    ) -> DynComposed {
        let basis = AnyBasis::TensorEigen(TensorEigenBasis::rotation(modes, &h));
        let engine = if h.factorized {
            AnyEngine::Adafactor(AdafactorEngine::new(
                carrier.0,
                carrier.1,
                &h,
                MomentumSpace::Original,
            ))
        } else {
            AnyEngine::Adam(AdamEngine::new(carrier.0, carrier.1, &h, MomentumSpace::Original))
        };
        Composed::new(basis, engine, None, h, "soap")
    }

    /// Shampoo on a rank-3+ tensor: per-mode inverse-root basis × the
    /// Kronecker sandwich, with (optionally inactive) AdamW norm grafting —
    /// the Gupta et al. (2018) tensor case.
    pub fn shampoo_nd(
        carrier: (usize, usize),
        modes: &crate::linalg::TensorShape,
        h: Hyper,
    ) -> DynComposed {
        let basis = AnyBasis::TensorEigen(TensorEigenBasis::inverse_root(modes, &h));
        let engine = AnyEngine::InverseRoot(InverseRootEngine::new(carrier.0, carrier.1, &h));
        let graft = Graft::new(carrier.0, carrier.1, &h);
        Composed::new(basis, engine, Some(graft), h, "shampoo")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn h_base() -> Hyper {
        Hyper { weight_decay: 0.0, precond_freq: 5, ..Hyper::default() }
    }

    #[test]
    fn composed_presets_carry_their_names() {
        let h = h_base();
        assert_eq!(presets::soap(4, 4, h.clone()).name(), "soap");
        assert_eq!(presets::shampoo(4, 4, h.clone()).name(), "shampoo");
        assert_eq!(presets::galore(4, 4, h.clone()).name(), "galore");
        assert_eq!(presets::adamw(4, 4, h.clone()).name(), "adamw");
        assert_eq!(presets::adafactor(4, 4, h).name(), "adafactor");
    }

    #[test]
    fn state_bytes_decomposes_into_components() {
        let h = Hyper::default();
        let opt = presets::shampoo(8, 4, h);
        assert_eq!(
            opt.state_bytes(),
            opt.basis.state_bytes()
                + opt.engine.state_bytes()
                + opt.graft.as_ref().unwrap().state_bytes()
        );
    }

    #[test]
    fn novel_combo_eigen_adafactor_one_sided_runs() {
        // The acceptance combo: one-sided eigenbasis × rank-1 Adafactor.
        let h = Hyper { one_sided: true, factorized: true, weight_decay: 0.0, ..h_base() };
        let mut opt = presets::soap(4, 8, h);
        let mut rng = Rng::new(71);
        let target = Matrix::randn(&mut rng, 4, 8, 1.0);
        let mut w = Matrix::zeros(4, 8);
        for t in 1..=1500 {
            let g = w.sub(&target).scale(2.0);
            opt.update(&mut w, &g, t, 0.02);
        }
        assert!(w.max_abs_diff(&target) < 0.2, "{}", w.max_abs_diff(&target));
    }

    #[test]
    fn composed_state_roundtrips() {
        let mut rng = Rng::new(72);
        for build in [presets::soap, presets::shampoo, presets::galore, presets::adamw] {
            let h = h_base();
            let mut a = build(5, 4, h.clone());
            let mut w = Matrix::randn(&mut rng, 5, 4, 1.0);
            for t in 1..=6 {
                let g = Matrix::randn(&mut rng, 5, 4, 1.0);
                a.update(&mut w, &g, t, 0.01);
            }
            let mut b = build(5, 4, h);
            b.import_state(a.export_state()).unwrap();
            let mut wa = w.clone();
            let mut wb = w.clone();
            for t in 7..=9 {
                let g = Matrix::randn(&mut rng, 5, 4, 1.0);
                a.update(&mut wa, &g, t, 0.01);
                b.update(&mut wb, &g, t, 0.01);
            }
            for (x, y) in wa.data.iter().zip(&wb.data) {
                assert_eq!(x, y, "{} drifted after state roundtrip", a.name());
            }
        }
    }

    #[test]
    fn direction_guard_policies() {
        use crate::optim::hyper::GuardPolicy;
        let mut rng = Rng::new(74);
        let poisoned = {
            let mut g = Matrix::randn(&mut rng, 3, 3, 1.0);
            g.data[4] = f32::NAN;
            g
        };

        // SkipStep: a poisoned direction leaves the weights untouched.
        let mut opt = presets::adamw(3, 3, h_base().with_guard(GuardPolicy::SkipStep));
        let mut w = Matrix::eye(3);
        opt.update(&mut w, &poisoned, 1, 0.1);
        assert_eq!(w.data, Matrix::eye(3).data, "skipped step must not move weights");

        // Clip: non-finite elements zeroed, the update proceeds finitely.
        let mut opt = presets::adamw(3, 3, h_base().with_guard(GuardPolicy::Clip(10.0)));
        let mut w = Matrix::eye(3);
        opt.update(&mut w, &poisoned, 1, 0.1);
        assert!(w.data.iter().all(|x| x.is_finite()), "clip must keep weights finite");
        assert_ne!(w.data, Matrix::eye(3).data, "clipped update still applies");

        // Abort: weights untouched, the process-wide latch is set.
        let _ = crate::fault::take_guard_abort();
        let mut opt = presets::adamw(3, 3, h_base().with_guard(GuardPolicy::Abort));
        let mut w = Matrix::eye(3);
        opt.update(&mut w, &poisoned, 1, 0.1);
        assert_eq!(w.data, Matrix::eye(3).data, "aborted step must not move weights");
        assert!(crate::fault::take_guard_abort(), "abort policy must latch");

        // Off: the NaN propagates — pre-guard behavior preserved verbatim.
        let mut opt = presets::adamw(3, 3, h_base().with_guard(GuardPolicy::Off));
        let mut w = Matrix::eye(3);
        opt.update(&mut w, &poisoned, 1, 0.1);
        assert!(w.data.iter().any(|x| x.is_nan()), "off must not intercept");
    }

    #[test]
    fn basis_mid_import_rejects_engine_mismatch() {
        // A full-V checkpoint must not silently load into a factorized
        // (Adafactor-engine) composition.
        let h = h_base();
        let mut full = presets::soap(4, 4, h.clone());
        let mut w = Matrix::zeros(4, 4);
        let mut rng = Rng::new(73);
        let g = Matrix::randn(&mut rng, 4, 4, 1.0);
        full.update(&mut w, &g, 1, 0.01);
        let state = full.export_state();
        let mut factored = presets::soap(4, 4, Hyper { factorized: true, ..h });
        let err = factored.import_state(state).unwrap_err();
        assert!(err.to_string().contains("full V"), "{err}");
    }
}
