//! Per-layer scratch arenas for the zero-allocation optimizer step path.
//!
//! Every [`Composed`](super::Composed) optimizer owns one [`Workspace`]: a
//! set of grow-only buffers that the basis projections, the fused moment
//! kernels, and the factor-EMA products write through instead of allocating
//! fresh `Matrix` values. After a warm-up step has grown every buffer to its
//! steady-state size, a non-refresh `Composed::update` performs **zero heap
//! allocations** (asserted by `rust/tests/alloc_step.rs` with a counting
//! allocator).
//!
//! # Ownership rules
//!
//! - **One workspace per layer**, owned by that layer's `Composed` value.
//!   Buffers carry no layer state between steps — only capacity.
//! - **Never shared across threads.** The sharded coordinator gives each
//!   worker disjoint layers, so each workspace stays thread-confined; the
//!   background `RefreshService` never sees a workspace (refresh closures
//!   snapshot their inputs).
//! - Buffers are **grow-only**: `Matrix::reuse_shape` / `Vec::resize` reuse
//!   the allocation and only ever grow it, so steady state is allocation-free
//!   even when a basis alternates between differently-shaped products
//!   (`GGᵀ` then `GᵀG` through the same `factor` buffer).
//!
//! Scratch bytes are real memory and are reported via
//! [`Workspace::bytes`] → `LayerOptimizer::scratch_bytes`, kept separate
//! from `state_bytes` (persistent optimizer state, the paper's §7.2
//! accounting).

use crate::linalg::Matrix;

/// Buffers shared by basis projections: the two-sided rotation intermediate
/// and the NT kernel's `Bᵀ` packing panel. Split out of [`Workspace`] so a
/// caller can lend a projection output buffer and the scratch
/// simultaneously (disjoint field borrows).
pub struct Scratch {
    /// Projection intermediate (`QᵀX` before the right-side multiply).
    pub tmp: Matrix,
    /// Transposed-B packing buffer for `matmul_nt_into`.
    pub pack: Vec<f32>,
}

impl Default for Scratch {
    fn default() -> Self {
        Self::new()
    }
}

impl Scratch {
    pub fn new() -> Self {
        Self { tmp: Matrix::zeros(0, 0), pack: Vec::new() }
    }

    pub fn bytes(&self) -> usize {
        (self.tmp.data.capacity() + self.pack.capacity()) * 4
    }
}

/// The per-layer scratch arena threaded through `Basis` and `MomentEngine`.
pub struct Workspace {
    /// Basis-space gradient (`QᵀGQ`).
    pub rot_g: Matrix,
    /// Basis-space momentum (SOAP re-rotates M every step) / bias-corrected
    /// momentum for the inverse-root engine.
    pub rot_m: Matrix,
    /// Basis-space direction before rotating back.
    pub nrot: Matrix,
    /// Original-space direction — `Composed::update` applies this to the
    /// weights after the engine returns.
    pub dir: Matrix,
    /// Kronecker-factor product scratch (`GGᵀ` / `GᵀG` share it serially;
    /// rank-3+ bases cycle their per-mode grams through it the same way).
    pub factor: Matrix,
    /// Mode-k unfolding scratch for rank-3+ parameters (interior modes only
    /// — the first and last modes of a row-major tensor are reshapes).
    pub unfold: Matrix,
    /// Adafactor row-sum scratch (`Σⱼ g²`). f64: the allocating reference
    /// (`Matrix::row_sums`) accumulates in f64, and the fused kernel must
    /// stay bitwise identical to it.
    pub sums_row: Vec<f64>,
    /// Adafactor column-sum scratch (f64, same rationale).
    pub sums_col: Vec<f64>,
    /// Bias-corrected `A/(1−β₂ᵗ)` scratch.
    pub hat_row: Vec<f32>,
    /// Bias-corrected `C/(1−β₂ᵗ)` scratch.
    pub hat_col: Vec<f32>,
    /// Projection + NT-packing scratch.
    pub scratch: Scratch,
}

impl Workspace {
    pub fn new() -> Self {
        Self {
            rot_g: Matrix::zeros(0, 0),
            rot_m: Matrix::zeros(0, 0),
            nrot: Matrix::zeros(0, 0),
            dir: Matrix::zeros(0, 0),
            factor: Matrix::zeros(0, 0),
            unfold: Matrix::zeros(0, 0),
            sums_row: Vec::new(),
            sums_col: Vec::new(),
            hat_row: Vec::new(),
            hat_col: Vec::new(),
            scratch: Scratch::new(),
        }
    }

    /// Bytes currently held by the arena (capacities, not lengths — what the
    /// allocator actually handed out).
    pub fn bytes(&self) -> usize {
        (self.rot_g.data.capacity()
            + self.rot_m.data.capacity()
            + self.nrot.data.capacity()
            + self.dir.data.capacity()
            + self.factor.data.capacity()
            + self.unfold.data.capacity()
            + self.hat_row.capacity()
            + self.hat_col.capacity())
            * 4
            + (self.sums_row.capacity() + self.sums_col.capacity()) * 8
            + self.scratch.bytes()
    }
}

impl Default for Workspace {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_tracks_growth_and_never_shrinks() {
        let mut ws = Workspace::new();
        assert_eq!(ws.bytes(), 0);
        ws.dir.reuse_shape(8, 8);
        let grown = ws.bytes();
        assert!(grown >= 8 * 8 * 4);
        ws.dir.reuse_shape(2, 2);
        assert_eq!(ws.bytes(), grown, "grow-only arena shrank");
        ws.scratch.pack.resize(100, 0.0);
        assert!(ws.bytes() >= grown + 400);
    }
}
