//! [`TensorEigenBasis`] — the per-mode Kronecker-factor basis for rank-3+
//! tensor parameters.
//!
//! Shampoo (Gupta et al., 2018) defines one factor per tensor mode:
//! `L_k ← β·L_k + (1−β)·G₍ₖ₎G₍ₖ₎ᵀ`, with the preconditioner applied as a
//! chain of mode-k products. This basis generalizes the 2-D
//! [`EigenBasis`](super::basis::EigenBasis) to any rank with the same two
//! flavors:
//!
//! - [`EigenFlavor::Rotation`] (SOAP): per-mode orthonormal eigenvector
//!   bases `Q_k`; `project` applies `×ₖ Q_kᵀ` over all modes, `project_back`
//!   applies `×ₖ Q_k`. Factor EMAs update *after* the step (Algorithm 3),
//!   refreshed by QR power iteration or warm `eigh` per mode.
//! - [`EigenFlavor::InverseRoot`] (Shampoo): per-mode cached inverse roots
//!   `L_k^{-1/e}`; `project` applies the whole sandwich (`project_back` is
//!   the identity). Factor EMAs update *before* the direction.
//!
//! Paper implementation detail 3 applies per mode: a mode with
//! `d_k > max_precond_dim` keeps `Q_k = I` (it is simply skipped in the
//! product chain), with the boundary convention `d_k == max_precond_dim` ⇒
//! **preconditioned** — identical to the 2-D basis (pinned by boundary
//! tests on both). Mode merging (`Hyper::merge_dims`) happens *before* this
//! basis is built — see `TensorShape::effective`.
//!
//! Async refresh enqueues **one task per mode**, each with its own
//! [`BasisHandle`]: modes publish and are adopted independently, so a slow
//! large-mode decomposition never delays a cheap small-mode refresh.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use super::basis::EigenFlavor;
use super::state::StateMatrix;
use super::workspace::{Scratch, Workspace};
use super::{Basis, BasisState, StateLayout};
use crate::linalg::tensor::{mode_apply_into, mode_gram, mode_gram_into};
use crate::linalg::{eigh, eigh_warm, power_iter_refresh, roots::inv_root_from_eig, Matrix};
use crate::optim::hyper::{Hyper, RefreshMethod};
use crate::precond::{BasisHandle, BasisPayload, DistBasisPort, RefreshService};

/// Per-mode eigenbasis (rank-3+ tensors). One factor EMA, one published
/// basis matrix, and (for the inverse-root flavor) one warm-start
/// eigenvector cache **per mode**.
pub struct TensorEigenBasis {
    h: Hyper,
    pub flavor: EigenFlavor,
    /// The (squeezed, merged) mode sizes this basis preconditions over.
    dims: Vec<usize>,
    /// Per-mode factor EMAs; `None` = that mode is identity (dim-capped).
    /// Stored per [`Hyper::state_dtype`] (f32 or bf16).
    pub factors: Vec<Option<StateMatrix>>,
    /// Rotation: eigenvector bases `Q_k` (None until first init).
    /// InverseRoot: cached `L_k^{-1/e}` (identity at start).
    pub qs: Vec<Option<Matrix>>,
    /// InverseRoot only: per-mode warm-start eigenvector caches.
    vecs: Vec<Option<Matrix>>,
    pub initialized: bool,
    refresh_secs: f64,
    /// Async refresh plumbing: one handle per preconditioned mode
    /// (`None` entries for capped modes / inline operation).
    service: Option<Arc<RefreshService>>,
    handles: Vec<Option<Arc<BasisHandle>>>,
    adopted: Vec<u64>,
    /// Distributed refresh ownership for the whole layer (see the 2-D
    /// basis): `Some(false)` skips local refreshes, `Some(true)` mirrors
    /// inline refreshes into the per-mode handles for broadcast.
    dist_owned: Option<bool>,
    /// Per-mode adoption caps (aligned with `handles`), raised by the
    /// distributed executor once each publication has been exchanged.
    adopt_caps: Vec<Option<Arc<AtomicU64>>>,
    /// Step whose factor snapshot backs each mode's ACTIVE basis.
    mode_steps: Vec<u64>,
}

impl TensorEigenBasis {
    fn build(dims: &[usize], h: &Hyper, flavor: EigenFlavor) -> Self {
        assert!(dims.len() >= 2, "TensorEigenBasis needs rank ≥ 2 (got {dims:?})");
        // Boundary convention: d_k == max_precond_dim IS preconditioned —
        // the same `<=` the 2-D EigenBasis uses (see the boundary tests).
        let active: Vec<bool> = dims.iter().map(|&d| d <= h.max_precond_dim).collect();
        let factors = dims
            .iter()
            .zip(&active)
            .map(|(&d, &a)| a.then(|| StateMatrix::zeros(d, d, h.state_dtype)))
            .collect();
        let qs: Vec<Option<Matrix>> = match flavor {
            EigenFlavor::Rotation => vec![None; dims.len()],
            // Inverse roots start at identity so the sandwich is well-defined
            // before the first refresh (mirrors the 2-D basis).
            EigenFlavor::InverseRoot => dims
                .iter()
                .zip(&active)
                .map(|(&d, &a)| a.then(|| Matrix::eye(d)))
                .collect(),
        };
        let r = dims.len();
        Self {
            h: h.clone(),
            flavor,
            dims: dims.to_vec(),
            factors,
            qs,
            vecs: (0..r).map(|_| None).collect(),
            initialized: false,
            refresh_secs: 0.0,
            service: None,
            handles: (0..r).map(|_| None).collect(),
            adopted: vec![0; r],
            dist_owned: None,
            adopt_caps: (0..r).map(|_| None).collect(),
            mode_steps: vec![0; r],
        }
    }

    /// SOAP-style per-mode rotation basis.
    pub fn rotation(modes: &crate::linalg::TensorShape, h: &Hyper) -> Self {
        Self::build(modes.dims(), h, EigenFlavor::Rotation)
    }

    /// Shampoo-style per-mode inverse-root basis.
    pub fn inverse_root(modes: &crate::linalg::TensorShape, h: &Hyper) -> Self {
        Self::build(modes.dims(), h, EigenFlavor::InverseRoot)
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    fn any_active(&self) -> bool {
        self.factors.iter().any(|f| f.is_some())
    }

    /// First-step initialization (Rotation): set each `L_k` from the first
    /// gradient's mode-k gram and take a full eigendecomposition for the
    /// starting basis — the rank-2 `init_rotation` per mode.
    fn init_rotation(&mut self, g: &Matrix, t: u64) {
        let t0 = Instant::now();
        for k in 0..self.dims.len() {
            if self.factors[k].is_none() {
                continue;
            }
            // Decompose the exact f32 gram, then store it at the state dtype
            // (the basis itself stays full precision either way).
            let f = mode_gram(&g.data, &self.dims, k);
            let (_, v) = eigh(&f);
            self.factors[k] = Some(StateMatrix::from_matrix(&f, self.h.state_dtype));
            self.qs[k] = Some(v);
            self.mode_steps[k] = t;
        }
        self.initialized = true;
        self.refresh_secs += t0.elapsed().as_secs_f64();
    }

    /// One mode's rotation refresh, pure in the (factor, basis) snapshot so
    /// the inline and background paths run identical code.
    fn rotation_refresh_one(method: RefreshMethod, f: &Matrix, q: &Matrix) -> Matrix {
        match method {
            RefreshMethod::QrPowerIteration => power_iter_refresh(f, q),
            RefreshMethod::Eigh => eigh_warm(f, q).1,
        }
    }

    /// One mode's inverse-root refresh, pure in the bias-corrected factor
    /// snapshot. Returns `(L_k^{-1/e}, eigenvectors)`.
    fn root_refresh_one(
        fhat: &Matrix,
        prev: Option<&Matrix>,
        e: f32,
        eps: f32,
    ) -> (Matrix, Matrix) {
        let (w, v) = match prev {
            Some(prev) => eigh_warm(fhat, prev),
            None => eigh(fhat),
        };
        (inv_root_from_eig(&w, &v, e, eps), v)
    }

    /// Bias-corrected snapshot of mode `k`'s factor at step `t`.
    fn corrected_factor(&self, k: usize, t: u64) -> Matrix {
        let bc = 1.0 - self.h.shampoo_beta.powi(t as i32);
        self.factors[k].as_ref().expect("active mode has factor").to_matrix().scale(1.0 / bc)
    }

    /// One mode's inline refresh behind the numerical-health gate: a
    /// non-finite factor gram or decomposition result leaves the previous
    /// per-mode basis in place (stale-basis grace, as in the 2-D basis) and
    /// bumps `soap_basis_rejected_total`. Returns whether a fresh basis was
    /// installed. The caller guarantees `factors[k]` is active.
    fn refresh_mode_inline(&mut self, k: usize, t: u64) -> bool {
        let finite = |m: &Matrix| m.data.iter().all(|x| x.is_finite());
        if !self.factors[k].as_ref().expect("active mode has factor").is_finite() {
            crate::telemetry::metrics::basis_rejected_total().inc();
            return false;
        }
        match self.flavor {
            EigenFlavor::Rotation => {
                // Refresh-time decode (allocating is fine off the hot path).
                let f = self.factors[k].as_ref().expect("checked").to_matrix();
                let q_new = Self::rotation_refresh_one(
                    self.h.refresh,
                    &f,
                    self.qs[k].as_ref().expect("initialized before refresh"),
                );
                if !finite(&q_new) {
                    crate::telemetry::metrics::basis_rejected_total().inc();
                    return false;
                }
                self.qs[k] = Some(q_new);
            }
            EigenFlavor::InverseRoot => {
                let fhat = self.corrected_factor(k, t);
                let (inv, v) = Self::root_refresh_one(
                    &fhat,
                    self.vecs[k].as_ref(),
                    self.h.shampoo_exponent,
                    self.h.shampoo_eps,
                );
                if !(finite(&inv) && finite(&v)) {
                    crate::telemetry::metrics::basis_rejected_total().inc();
                    return false;
                }
                self.qs[k] = Some(inv);
                self.vecs[k] = Some(v);
            }
        }
        self.mode_steps[k] = t;
        true
    }

    /// Periodic refresh, executed inline (synchronously), all modes.
    fn refresh_inline(&mut self, t: u64) {
        let t0 = Instant::now();
        for k in 0..self.dims.len() {
            if self.factors[k].is_none() {
                continue;
            }
            self.refresh_mode_inline(k, t);
        }
        self.refresh_secs += t0.elapsed().as_secs_f64();
    }

    /// Async mode: enqueue ONE refresh task per preconditioned mode, each
    /// gated by its own handle — a mode with a refresh still in flight is
    /// skipped (load shedding), the others proceed independently.
    fn enqueue_refresh(&mut self, service: &Arc<RefreshService>, t: u64) {
        for k in 0..self.dims.len() {
            let Some(handle) = self.handles[k].clone() else { continue };
            if self.factors[k].is_none() {
                continue;
            }
            // Worker-panic fallback (see the 2-D basis): if this mode's last
            // background refresh blew up, run this one inline instead of
            // re-enqueueing onto the pool — mirror-publishing under
            // distributed ownership so peers stay in lockstep.
            if handle.take_worker_panic() {
                if self.refresh_mode_inline(k, t) && self.dist_owned == Some(true) {
                    let payload = BasisPayload {
                        left: self.qs[k].clone(),
                        right: None,
                        left_aux: self.vecs[k].clone(),
                        right_aux: None,
                    };
                    self.adopted[k] = handle.publish(payload, t);
                }
                continue;
            }
            if !handle.try_begin_refresh() {
                continue;
            }
            match self.flavor {
                EigenFlavor::Rotation => {
                    let method = self.h.refresh;
                    let f = self.factors[k].as_ref().expect("checked").to_matrix();
                    let q = self.qs[k].clone().expect("initialized before refresh");
                    service.enqueue(
                        Arc::clone(handle),
                        t,
                        Box::new(move || BasisPayload {
                            left: Some(Self::rotation_refresh_one(method, &f, &q)),
                            right: None,
                            left_aux: None,
                            right_aux: None,
                        }),
                    );
                }
                EigenFlavor::InverseRoot => {
                    let fhat = self.corrected_factor(k, t);
                    let prev = self.vecs[k].clone();
                    let e = self.h.shampoo_exponent;
                    let eps = self.h.shampoo_eps;
                    service.enqueue(
                        Arc::clone(handle),
                        t,
                        Box::new(move || {
                            let (inv, v) =
                                Self::root_refresh_one(&fhat, prev.as_ref(), e, eps);
                            BasisPayload {
                                left: Some(inv),
                                right: None,
                                left_aux: Some(v),
                                right_aux: None,
                            }
                        }),
                    );
                }
            }
        }
    }

    fn refresh_or_enqueue(&mut self, t: u64) {
        if self.dist_owned == Some(false) {
            return; // a peer owns this layer's refresh; adopt its broadcast
        }
        match self.service.clone() {
            Some(service) => self.enqueue_refresh(&service, t),
            None => {
                let t0 = Instant::now();
                for k in 0..self.dims.len() {
                    if self.factors[k].is_none() {
                        continue;
                    }
                    let installed = self.refresh_mode_inline(k, t);
                    // Mirror each mode's fresh basis into its handle so the
                    // executor can ship it; fast-forwarding `adopted` stops
                    // this rank from re-adopting its own publication. A
                    // rejected mode publishes nothing — every rank keeps
                    // that mode's previous basis.
                    if installed && self.dist_owned == Some(true) {
                        if let Some(handle) = self.handles[k].clone() {
                            let payload = BasisPayload {
                                left: self.qs[k].clone(),
                                right: None,
                                left_aux: self.vecs[k].clone(),
                                right_aux: None,
                            };
                            self.adopted[k] = handle.publish(payload, t);
                        }
                    }
                }
                self.refresh_secs += t0.elapsed().as_secs_f64();
            }
        }
    }

    /// Async mode: adopt each mode's newest published basis independently.
    /// One atomic load per mode on the no-news path; each mode's payload is
    /// adopted wholesale, so a torn per-mode basis is impossible (modes are
    /// independent factors — there is no cross-mode pair to tear).
    fn adopt_published(&mut self) {
        for k in 0..self.dims.len() {
            let Some(handle) = &self.handles[k] else { continue };
            if handle.version() <= self.adopted[k] {
                continue;
            }
            if let Some(published) = handle.latest() {
                if published.version > self.adopted[k] {
                    // Distributed: never adopt a publication the executor
                    // hasn't finished broadcasting to every peer.
                    if let Some(cap) = &self.adopt_caps[k] {
                        if published.version > cap.load(Ordering::Acquire) {
                            continue;
                        }
                    }
                    if let Some(q) = &published.payload.left {
                        self.qs[k] = Some(q.clone());
                    }
                    if self.flavor == EigenFlavor::InverseRoot {
                        // Keep the previous warm cache when the payload
                        // carries none (mirrors the 2-D adoption).
                        if let Some(v) = &published.payload.left_aux {
                            self.vecs[k] = Some(v.clone());
                        }
                    }
                    self.adopted[k] = published.version;
                    self.mode_steps[k] = published.snapshot_step;
                }
            }
        }
    }

    /// Update every active mode's factor EMA from `g`, through the workspace
    /// (zero steady-state allocations; the per-mode grams cycle through
    /// `ws.factor`/`ws.unfold` serially, exactly like the 2-D basis shares
    /// `ws.factor` between `GGᵀ` and `GᵀG`).
    fn ema_factors(&mut self, g: &Matrix, ws: &mut Workspace) {
        debug_assert_eq!(
            g.numel(),
            self.dims.iter().product::<usize>(),
            "gradient numel does not match the basis dims"
        );
        let Workspace { factor, unfold, scratch, .. } = ws;
        for k in 0..self.dims.len() {
            let Some(l) = &mut self.factors[k] else { continue };
            mode_gram_into(&g.data, &self.dims, k, factor, unfold, &mut scratch.pack);
            l.ema_inplace(factor, self.h.shampoo_beta);
        }
    }

    /// Apply the active modes' factors as a chain of mode-k products,
    /// ping-ponging between `scratch.tmp` and `out` so the final hop always
    /// lands in `out`. `transpose == true` applies `Q_kᵀ` to each fiber
    /// (into-basis), `false` applies `Q_k` (back / symmetric sandwich).
    fn apply_modes(&self, x: &Matrix, out: &mut Matrix, scratch: &mut Scratch, transpose: bool) {
        let active = self.qs.iter().filter(|q| q.is_some()).count();
        if active == 0 {
            out.copy_from(x);
            return;
        }
        let Scratch { tmp, pack } = scratch;
        out.reuse_shape(x.rows, x.cols);
        tmp.reuse_shape(x.rows, x.cols);
        let mut applied = 0usize;
        for (k, q) in self.qs.iter().enumerate() {
            let Some(q) = q else { continue };
            applied += 1;
            // Land hop `active` in `out`, alternating backwards from there.
            let to_out = (active - applied) % 2 == 0;
            if to_out {
                let src: &[f32] = if applied == 1 { &x.data } else { &tmp.data };
                mode_apply_into(src, &mut out.data, &self.dims, k, q, transpose, pack);
            } else {
                let src: &[f32] = if applied == 1 { &x.data } else { &out.data };
                mode_apply_into(src, &mut tmp.data, &self.dims, k, q, transpose, pack);
            }
        }
    }
}

impl Basis for TensorEigenBasis {
    fn begin_step(&mut self, g: &Matrix, t: u64, ws: &mut Workspace) {
        // Pure-Adam ramp: no statistics, no init, no refresh (see the 2-D
        // basis for the convention).
        if t <= self.h.adam_warmup_steps {
            return;
        }
        match self.flavor {
            EigenFlavor::Rotation => {
                if !self.initialized {
                    self.init_rotation(g, t);
                }
                // Pick up anything the background service published since
                // the last step — before projecting, so it's used now.
                self.adopt_published();
            }
            EigenFlavor::InverseRoot => {
                // Factor EMAs first (Shampoo updates them ahead of the
                // direction — the roots computed this step may use them).
                self.ema_factors(g, ws);
                self.adopt_published();
                // The first recompute always runs inline so the roots are
                // never identity-only.
                if !self.initialized {
                    self.refresh_inline(t);
                    self.initialized = true;
                } else if self.h.is_refresh_step(t) {
                    self.refresh_or_enqueue(t);
                }
            }
        }
    }

    fn end_step(&mut self, g: &Matrix, t: u64, ws: &mut Workspace) {
        if self.flavor != EigenFlavor::Rotation {
            return;
        }
        if t <= self.h.adam_warmup_steps {
            return;
        }
        // Per-mode factor EMAs + periodic refresh AFTER the step (Alg 3).
        self.ema_factors(g, ws);
        if self.h.is_refresh_step(t) {
            self.refresh_or_enqueue(t);
        }
    }

    fn project_into(&self, x: &Matrix, out: &mut Matrix, scratch: &mut Scratch) {
        match self.flavor {
            // Rotate into the eigenbasis: ×ₖ Q_kᵀ over every active mode.
            EigenFlavor::Rotation => self.apply_modes(x, out, scratch, true),
            // Apply the whole preconditioner: ×ₖ L_k^{-1/e} (symmetric).
            EigenFlavor::InverseRoot => self.apply_modes(x, out, scratch, false),
        }
    }

    fn project_back_into(&self, x: &Matrix, out: &mut Matrix, scratch: &mut Scratch) {
        match self.flavor {
            // Rotate back: ×ₖ Q_k.
            EigenFlavor::Rotation => self.apply_modes(x, out, scratch, false),
            EigenFlavor::InverseRoot => out.copy_from(x),
        }
    }

    fn refresh_seconds(&self) -> f64 {
        self.refresh_secs
    }

    fn attach_async(&mut self, service: &Arc<RefreshService>) -> bool {
        if !self.any_active() {
            return false; // every mode capped to identity ⇒ nothing to refresh
        }
        self.service = Some(Arc::clone(service));
        for k in 0..self.dims.len() {
            self.handles[k] = self.factors[k].is_some().then(|| Arc::new(BasisHandle::new()));
            self.adopted[k] = 0;
        }
        true
    }

    fn attach_dist(&mut self, owned: bool) -> Vec<DistBasisPort> {
        if !self.any_active() {
            return Vec::new(); // every mode capped ⇒ nothing to broadcast
        }
        // One port per active mode, in mode order — the deterministic
        // ordering `(layer_idx, port_idx)` wire addresses rely on. Reuse
        // async-attached handles when present.
        let mut ports = Vec::new();
        for k in 0..self.dims.len() {
            if self.factors[k].is_none() {
                continue;
            }
            let handle = match &self.handles[k] {
                Some(h) => Arc::clone(h),
                None => {
                    let h = Arc::new(BasisHandle::new());
                    self.handles[k] = Some(Arc::clone(&h));
                    h
                }
            };
            let cap = Arc::new(AtomicU64::new(handle.version()));
            self.adopt_caps[k] = Some(Arc::clone(&cap));
            ports.push(DistBasisPort { handle, adopt_cap: cap });
        }
        self.dist_owned = Some(owned);
        ports
    }

    fn dist_mid_step_sync(&self, t: u64) -> bool {
        // Shampoo's inline periodic refresh feeds the SAME step's update —
        // see the 2-D basis. Every term is replicated state.
        self.flavor == EigenFlavor::InverseRoot
            && self.dist_owned.is_some()
            && self.service.is_none()
            && self.initialized
            && t > self.h.adam_warmup_steps
            && self.h.is_refresh_step(t)
    }

    fn adopt_pending(&mut self) {
        self.adopt_published();
    }

    fn basis_snapshot_step(&self) -> Option<u64> {
        if !self.initialized {
            return None;
        }
        // The most conservative (stalest) mode bounds the whole layer.
        self.factors
            .iter()
            .zip(&self.mode_steps)
            .filter_map(|(f, &s)| f.as_ref().map(|_| s))
            .min()
    }

    fn state_bytes(&self) -> usize {
        let opt = |x: &Option<Matrix>| x.as_ref().map(|m| m.numel()).unwrap_or(0);
        let sum = |v: &[Option<Matrix>]| v.iter().map(opt).sum::<usize>();
        let factors: usize = self
            .factors
            .iter()
            .map(|f| f.as_ref().map(|m| m.state_bytes()).unwrap_or(0))
            .sum();
        factors + (sum(&self.qs) + sum(&self.vecs)) * 4
    }

    fn export(&self) -> BasisState {
        // Flags: [initialized, rank, (has_k, step_k, has_vecs_k) × rank] —
        // per-mode factor records, self-describing for checkpoint v3.
        let r = self.dims.len();
        let mut flags = Vec::with_capacity(2 + 3 * r);
        flags.push(self.initialized as u8 as f32);
        flags.push(r as f32);
        for k in 0..r {
            flags.push(self.factors[k].is_some() as u8 as f32);
            // f32 is exact up to 2^24 steps — far beyond our runs.
            flags.push(self.mode_steps[k] as f32);
            flags.push(self.vecs[k].is_some() as u8 as f32);
        }
        let mut tensors = Vec::new();
        for k in 0..r {
            if let Some(f) = &self.factors[k] {
                // bf16-stored factors decode onto the bf16 grid, so the f32
                // wire round-trips the exact stored words on import.
                tensors.push(f.to_matrix());
                if let Some(q) = &self.qs[k] {
                    tensors.push(q.clone());
                }
                if let Some(v) = &self.vecs[k] {
                    tensors.push(v.clone());
                }
            }
        }
        BasisState { flags, tensors }
    }

    fn import(
        &mut self,
        flags: &[f32],
        it: &mut dyn Iterator<Item = Matrix>,
    ) -> anyhow::Result<()> {
        // Refreshes enqueued before the restore were computed from discarded
        // factors; drain them, then skip every pre-restore publication.
        if let Some(service) = &self.service {
            service.wait_idle();
            for k in 0..self.dims.len() {
                if let Some(handle) = &self.handles[k] {
                    self.adopted[k] = handle.version();
                }
            }
        }
        anyhow::ensure!(
            flags.len() >= 2,
            "tensor basis flags row too short ({} values)",
            flags.len()
        );
        let r = flags[1] as usize;
        anyhow::ensure!(
            r == self.dims.len(),
            "tensor basis state has rank {r} but the layer preconditions rank {}",
            self.dims.len()
        );
        anyhow::ensure!(
            flags.len() == 2 + 3 * r,
            "tensor basis flags row malformed ({} values for rank {r})",
            flags.len()
        );
        self.initialized = flags[0] != 0.0;
        let mut next = |what: String| {
            it.next().ok_or_else(|| anyhow::anyhow!("tensor basis state missing {what}"))
        };
        for k in 0..r {
            let has_factor = flags[2 + 3 * k] != 0.0;
            self.mode_steps[k] = flags[2 + 3 * k + 1] as u64;
            let has_vecs = flags[2 + 3 * k + 2] != 0.0;
            if has_factor {
                let f = next(format!("mode-{k} factor"))?;
                anyhow::ensure!(
                    f.rows == self.dims[k] && f.cols == self.dims[k],
                    "mode-{k} factor is {}×{} but the mode size is {}",
                    f.rows,
                    f.cols,
                    self.dims[k]
                );
                self.factors[k] = Some(StateMatrix::from_matrix(&f, self.h.state_dtype));
                self.qs[k] = if self.initialized || self.flavor == EigenFlavor::InverseRoot {
                    Some(next(format!("mode-{k} basis"))?)
                } else {
                    None
                };
                self.vecs[k] = if has_vecs {
                    Some(next(format!("mode-{k} warm eigenvectors"))?)
                } else {
                    None
                };
            } else {
                self.factors[k] = None;
                self.qs[k] = None;
                self.vecs[k] = None;
            }
        }
        Ok(())
    }

    fn layout(&self) -> StateLayout {
        StateLayout::TensorModes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::TensorShape;
    use crate::util::rng::Rng;

    fn h_base() -> Hyper {
        Hyper { weight_decay: 0.0, precond_freq: 4, ..Hyper::default() }
    }

    fn grad3(rng: &mut Rng, dims: &[usize]) -> Matrix {
        let shape = TensorShape::new(dims.to_vec());
        let (r, c) = shape.carrier();
        Matrix::randn(rng, r, c, 1.0)
    }

    #[test]
    fn dim_cap_boundary_matches_2d_convention() {
        // Satellite fix: `d == max_precond_dim` is PRECONDITIONED (the 2-D
        // EigenBasis `<=` convention), `d == cap + 1` keeps identity — on
        // both sides of the boundary, per mode.
        let h = Hyper { max_precond_dim: 6, ..h_base() };
        let b = TensorEigenBasis::rotation(&TensorShape::new(vec![6, 7, 5]), &h);
        assert!(b.factors[0].is_some(), "d == cap must be preconditioned");
        assert!(b.factors[1].is_none(), "d == cap + 1 must stay identity");
        assert!(b.factors[2].is_some());
        // Inverse-root flavor uses the same per-mode convention.
        let b = TensorEigenBasis::inverse_root(&TensorShape::new(vec![6, 7, 5]), &h);
        assert!(b.factors[0].is_some() && b.factors[1].is_none());
        assert!(b.qs[1].is_none(), "capped mode has no root");
    }

    #[test]
    fn all_modes_capped_projects_identity() {
        let h = Hyper { max_precond_dim: 1, ..h_base() };
        let b = TensorEigenBasis::rotation(&TensorShape::new(vec![3, 4, 5]), &h);
        let mut rng = Rng::new(21);
        let x = grad3(&mut rng, &[3, 4, 5]);
        let mut out = Matrix::zeros(0, 0);
        let mut scratch = Scratch::new();
        b.project_into(&x, &mut out, &mut scratch);
        assert_eq!(out, x, "capped basis must be the identity");
        assert_eq!(b.state_bytes(), 0);
    }

    #[test]
    fn rotation_projection_is_orthogonal_after_init() {
        let h = h_base();
        let mut b = TensorEigenBasis::rotation(&TensorShape::new(vec![4, 3, 5]), &h);
        let mut rng = Rng::new(22);
        let g = grad3(&mut rng, &[4, 3, 5]);
        let mut ws = Workspace::new();
        b.begin_step(&g, 1, &mut ws);
        assert!(b.initialized);
        let x = grad3(&mut rng, &[4, 3, 5]);
        let mut rot = Matrix::zeros(0, 0);
        let mut back = Matrix::zeros(0, 0);
        let mut scratch = Scratch::new();
        b.project_into(&x, &mut rot, &mut scratch);
        // Orthogonal rotations preserve the Frobenius norm…
        assert!((rot.frob_norm() - x.frob_norm()).abs() < 1e-3 * x.frob_norm());
        // …and project ∘ project_back is the identity.
        b.project_back_into(&rot, &mut back, &mut scratch);
        assert!(back.max_abs_diff(&x) < 1e-4, "{}", back.max_abs_diff(&x));
    }

    #[test]
    fn rank2_tensor_basis_matches_eigen_basis_projection() {
        // On a rank-2 shape the per-mode chain must agree (numerically) with
        // the dedicated 2-D basis: same grams, same eigh, same rotation.
        use super::super::basis::EigenBasis;
        let h = h_base();
        let mut tb = TensorEigenBasis::rotation(&TensorShape::matrix(5, 4), &h);
        let mut eb = EigenBasis::rotation(5, 4, &h);
        let mut rng = Rng::new(23);
        let g = Matrix::randn(&mut rng, 5, 4, 1.0);
        let mut ws = Workspace::new();
        tb.begin_step(&g, 1, &mut ws);
        eb.begin_step(&g, 1, &mut ws);
        let x = Matrix::randn(&mut rng, 5, 4, 1.0);
        let (mut a, mut b) = (Matrix::zeros(0, 0), Matrix::zeros(0, 0));
        let mut scratch = Scratch::new();
        tb.project_into(&x, &mut a, &mut scratch);
        eb.project_into(&x, &mut b, &mut scratch);
        assert!(a.max_abs_diff(&b) < 1e-4, "{}", a.max_abs_diff(&b));
    }

    #[test]
    fn export_import_roundtrips_per_mode_records() {
        let h = h_base();
        let dims = TensorShape::new(vec![4, 3, 5]);
        let mut a = TensorEigenBasis::rotation(&dims, &h);
        let mut rng = Rng::new(24);
        let mut ws = Workspace::new();
        for t in 1..=5 {
            let g = grad3(&mut rng, &[4, 3, 5]);
            a.begin_step(&g, t, &mut ws);
            a.end_step(&g, t, &mut ws);
        }
        let state = a.export();
        let mut b = TensorEigenBasis::rotation(&dims, &h);
        let mut it = state.tensors.into_iter();
        b.import(&state.flags, &mut it).unwrap();
        assert!(it.next().is_none(), "import must consume every tensor");
        assert_eq!(b.initialized, a.initialized);
        assert_eq!(b.mode_steps, a.mode_steps);
        for k in 0..3 {
            assert_eq!(
                a.qs[k].as_ref().unwrap().data,
                b.qs[k].as_ref().unwrap().data,
                "mode {k} basis drifted through export/import"
            );
        }
        // A rank mismatch is a named error, not a misparse.
        let mut wrong = TensorEigenBasis::rotation(&TensorShape::matrix(4, 15), &h);
        let state = a.export();
        let err = wrong
            .import(&state.flags, &mut state.tensors.into_iter())
            .unwrap_err()
            .to_string();
        assert!(err.contains("rank"), "{err}");
    }

    #[test]
    fn inverse_root_descends_on_quadratic() {
        use super::super::presets;
        let h = Hyper { precond_freq: 3, ..h_base() };
        let shape = TensorShape::new(vec![3, 4, 5]);
        let mut opt = presets::shampoo_nd(shape.carrier(), &shape, h);
        let mut rng = Rng::new(25);
        let target = grad3(&mut rng, &[3, 4, 5]);
        let mut w = Matrix::zeros(target.rows, target.cols);
        let d0 = w.sub(&target).frob_norm();
        for t in 1..=400 {
            let g = w.sub(&target).scale(2.0);
            crate::optim::LayerOptimizer::update(&mut opt, &mut w, &g, t, 0.02);
        }
        let d1 = w.sub(&target).frob_norm();
        assert!(d1 < 0.5 * d0, "tensor shampoo failed to descend: {d0} → {d1}");
    }

    #[test]
    fn async_refresh_one_task_per_mode() {
        let h = h_base().async_refresh();
        let mut b = TensorEigenBasis::rotation(&TensorShape::new(vec![4, 3, 5]), &h);
        let svc = Arc::new(RefreshService::new(2));
        assert!(b.attach_async(&svc));
        let mut rng = Rng::new(26);
        let mut ws = Workspace::new();
        let g = grad3(&mut rng, &[4, 3, 5]);
        b.begin_step(&g, 1, &mut ws);
        b.end_step(&g, 1, &mut ws);
        // Hit the refresh step: one task PER MODE must be enqueued.
        let t = h.precond_freq;
        for step in 2..=t {
            let g = grad3(&mut rng, &[4, 3, 5]);
            b.begin_step(&g, step, &mut ws);
            b.end_step(&g, step, &mut ws);
        }
        svc.wait_idle();
        assert_eq!(svc.stats().completed, 3, "expected one refresh task per mode");
        b.adopt_pending();
        assert_eq!(b.basis_snapshot_step(), Some(t));
    }
}
