//! The `--optimizer` composition grammar: `basis=…,inner=…[,graft=…]`.
//!
//! Every named preset is a point in this grammar
//! (`basis=eigen,inner=adam` ≡ `soap`; `basis=eigen,inner=shampoo` ≡
//! `shampoo`; `basis=svd,inner=adam` ≡ `galore`; …), and novel combinations
//! — `basis=eigen:one-sided,inner=adafactor`, `basis=svd,inner=adafactor`,
//! `basis=eigen,inner=adam,graft=adam` — build working optimizers with zero
//! new code. Specs that exactly match a preset are canonicalized onto it, so
//! they share the preset's label, tuned defaults, checkpoint layout, and
//! PJRT artifact path.

use super::presets;
use super::{AnyBasis, AnyEngine, Composed, Graft};
use super::{AdafactorEngine, AdamEngine, EigenBasis, GradSvdBasis, IdentityBasis, MomentumSpace};
use crate::linalg::TensorShape;
use crate::optim::hyper::{FreqSchedule, Hyper, StateDtype};
use crate::optim::{LayerOptimizer, OptKind};

/// One-line grammar summary, embedded in parse errors and `--help`.
pub const GRAMMAR_HELP: &str = "basis=<identity|eigen[:one-sided|:two-sided]|svd>,\
inner=<adam|adafactor|shampoo>[,graft=<adam|none>]\
[,adam-warmup=<steps>][,precond-warmup=<steps>]\
[,precond-freq=<f|f@start;f@start…>][,precondition-1d=<true|false>]\
[,state-dtype=<f32|bf16>]";

/// Side selection for an eigenbasis spec. `Inherit` defers to
/// `Hyper::one_sided` (the `--one-sided` flag).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Sided {
    Inherit,
    OneSided,
    TwoSided,
}

/// Which [`super::Basis`] to build.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BasisSpec {
    Identity,
    Eigen { sided: Sided },
    GradSvd,
}

/// Which [`super::MomentEngine`] to run inside it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineSpec {
    Adam,
    Adafactor,
    InverseRoot,
}

/// Grafting wrapper selection. `Inherit` defers to `Hyper::grafting` for the
/// Shampoo family and means "no graft" elsewhere.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GraftSpec {
    Inherit,
    Adam,
    Off,
}

/// A parsed `--optimizer basis=…,inner=…[,graft=…]` composition.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CompositionSpec {
    pub basis: BasisSpec,
    pub inner: EngineSpec,
    pub graft: GraftSpec,
    /// Pure-Adam ramp length (`Hyper::adam_warmup_steps`). `None` inherits
    /// whatever the surrounding config set — the spec only overrides when
    /// the key is spelled out.
    pub adam_warmup: Option<u64>,
    /// Refresh-every-step early-phase length (`Hyper::precondition_warmup`);
    /// `None` inherits.
    pub precond_warmup: Option<u64>,
    /// Preconditioning-frequency override: a constant (`precond-freq=32`) or
    /// a piecewise schedule (`precond-freq=10@0;100@1000` — the grammar uses
    /// `;` between pieces since `,` separates grammar keys). `None` inherits.
    pub precond_freq: Option<FreqSchedule>,
    /// Precondition rank-1 params instead of the AdamW fallback
    /// (`Hyper::precondition_1d`). `None` inherits.
    pub precondition_1d: Option<bool>,
    /// Storage dtype for the dtype-routed optimizer state buffers
    /// (`Hyper::state_dtype`). `None` inherits.
    pub state_dtype: Option<StateDtype>,
}

impl CompositionSpec {
    /// Parse the grammar. The caller routes any string containing `=` here.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let mut basis = BasisSpec::Identity;
        let mut inner: Option<EngineSpec> = None;
        let mut graft = GraftSpec::Inherit;
        let mut adam_warmup: Option<u64> = None;
        let mut precond_warmup: Option<u64> = None;
        let mut precond_freq: Option<FreqSchedule> = None;
        let mut precondition_1d: Option<bool> = None;
        let mut state_dtype: Option<StateDtype> = None;
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, value) = part.split_once('=').ok_or_else(|| {
                anyhow::anyhow!(
                    "composition spec item '{part}' is not key=value; expected {GRAMMAR_HELP}"
                )
            })?;
            match key.trim().to_ascii_lowercase().as_str() {
                "basis" => {
                    basis = match value.trim().to_ascii_lowercase().as_str() {
                        "identity" | "none" | "i" => BasisSpec::Identity,
                        "eigen" | "eig" => BasisSpec::Eigen { sided: Sided::Inherit },
                        "eigen:one-sided" | "eig:one-sided" => {
                            BasisSpec::Eigen { sided: Sided::OneSided }
                        }
                        "eigen:two-sided" | "eig:two-sided" => {
                            BasisSpec::Eigen { sided: Sided::TwoSided }
                        }
                        "svd" | "grad-svd" | "gradsvd" => BasisSpec::GradSvd,
                        other => anyhow::bail!(
                            "unknown basis '{other}': expected identity, \
                             eigen, eigen:one-sided, eigen:two-sided, or svd"
                        ),
                    };
                }
                "inner" | "engine" => {
                    inner = Some(match value.trim().to_ascii_lowercase().as_str() {
                        "adam" | "adamw" => EngineSpec::Adam,
                        "adafactor" => EngineSpec::Adafactor,
                        "shampoo" | "inverse-root" | "invroot" => EngineSpec::InverseRoot,
                        other => anyhow::bail!(
                            "unknown inner engine '{other}': expected adam, \
                             adafactor, or shampoo"
                        ),
                    });
                }
                "graft" => {
                    graft = match value.trim().to_ascii_lowercase().as_str() {
                        "adam" | "adamw" => GraftSpec::Adam,
                        "none" | "off" => GraftSpec::Off,
                        other => {
                            anyhow::bail!("unknown graft '{other}': expected adam or none")
                        }
                    };
                }
                "adam-warmup" | "adam_warmup" => {
                    adam_warmup = Some(value.trim().parse().map_err(|_| {
                        anyhow::anyhow!("adam-warmup expects a step count, got '{value}'")
                    })?);
                }
                "precond-warmup" | "precond_warmup" | "precondition-warmup" => {
                    precond_warmup = Some(value.trim().parse().map_err(|_| {
                        anyhow::anyhow!("precond-warmup expects a step count, got '{value}'")
                    })?);
                }
                "precond-freq" | "precond_freq" | "precond-frequency" => {
                    let v = value.trim();
                    let sched = if v.contains('@') {
                        FreqSchedule::parse(v)?
                    } else {
                        let f: u64 = v.parse().map_err(|_| {
                            anyhow::anyhow!(
                                "precond-freq expects a step count or a \
                                 freq@start;… schedule, got '{value}'"
                            )
                        })?;
                        FreqSchedule::new(&[(0, f)])?
                    };
                    precond_freq = Some(sched);
                }
                "precondition-1d" | "precondition_1d" | "precond-1d" => {
                    precondition_1d = Some(match value.trim().to_ascii_lowercase().as_str() {
                        "true" | "on" | "1" | "yes" => true,
                        "false" | "off" | "0" | "no" => false,
                        other => anyhow::bail!(
                            "precondition-1d expects true or false, got '{other}'"
                        ),
                    });
                }
                "state-dtype" | "state_dtype" => {
                    state_dtype = Some(StateDtype::parse(value.trim())?);
                }
                other => anyhow::bail!(
                    "unknown composition key '{other}': expected {GRAMMAR_HELP}"
                ),
            }
        }
        let inner = inner
            .ok_or_else(|| anyhow::anyhow!("composition spec needs inner=…; {GRAMMAR_HELP}"))?;
        let spec = Self {
            basis,
            inner,
            graft,
            adam_warmup,
            precond_warmup,
            precond_freq,
            precondition_1d,
            state_dtype,
        };
        spec.validate()?;
        Ok(spec)
    }

    fn validate(&self) -> anyhow::Result<()> {
        if self.inner == EngineSpec::InverseRoot {
            anyhow::ensure!(
                matches!(self.basis, BasisSpec::Eigen { .. }),
                "inner=shampoo applies the Kronecker inverse roots and needs basis=eigen"
            );
            anyhow::ensure!(
                !matches!(self.basis, BasisSpec::Eigen { sided: Sided::OneSided }),
                "inner=shampoo preconditions both sides; basis=eigen:one-sided is not supported"
            );
        }
        Ok(())
    }

    /// Reject variant flags that contradict the spec's structural choices —
    /// the same policy the refresh options follow (error, never silently
    /// resolve).
    pub fn check_flag_consistency(&self, one_sided: bool, factorized: bool) -> anyhow::Result<()> {
        if matches!(self.basis, BasisSpec::Eigen { sided: Sided::TwoSided }) {
            anyhow::ensure!(!one_sided, "--one-sided contradicts basis=eigen:two-sided");
        }
        if matches!(self.basis, BasisSpec::Eigen { .. }) && self.inner == EngineSpec::Adam {
            anyhow::ensure!(
                !factorized,
                "--factorized contradicts inner=adam (use inner=adafactor)"
            );
        }
        Ok(())
    }

    /// Fold the spec's overrides into a [`Hyper`]: side selection, engine
    /// choice (`factorized`), and graft activation. Idempotent.
    pub fn apply(&self, h: &mut Hyper) {
        match self.basis {
            BasisSpec::Eigen { sided: Sided::OneSided } => h.one_sided = true,
            BasisSpec::Eigen { sided: Sided::TwoSided } => h.one_sided = false,
            _ => {}
        }
        if matches!(self.basis, BasisSpec::Eigen { .. }) {
            match self.inner {
                EngineSpec::Adam => h.factorized = false,
                EngineSpec::Adafactor => h.factorized = true,
                // `factorized` is a SOAP-family knob; the Shampoo engine
                // ignores it, so leave the flag untouched.
                EngineSpec::InverseRoot => {}
            }
        }
        if self.inner == EngineSpec::InverseRoot {
            match self.graft {
                GraftSpec::Adam => h.grafting = true,
                GraftSpec::Off => h.grafting = false,
                GraftSpec::Inherit => {}
            }
        }
        if let Some(w) = self.adam_warmup {
            h.adam_warmup_steps = w;
        }
        if let Some(w) = self.precond_warmup {
            h.precondition_warmup = w;
        }
        if let Some(sched) = self.precond_freq {
            // A single piece starting at step 0 IS the constant frequency —
            // fold it into the base field (stagger phases and the config
            // fingerprint key off `precond_freq`).
            match sched.pieces() {
                [(0, f)] => {
                    h.precond_freq = *f;
                    h.precond_freq_schedule = None;
                }
                _ => h.precond_freq_schedule = Some(sched),
            }
        }
        if let Some(on) = self.precondition_1d {
            h.precondition_1d = on;
        }
        if let Some(d) = self.state_dtype {
            h.state_dtype = d;
        }
    }

    /// The preset this spec is exactly equivalent to, if any. Canonical specs
    /// build (and label, checkpoint, tune) as that preset.
    pub fn canonical(&self) -> Option<OptKind> {
        let plain_graft = !matches!(self.graft, GraftSpec::Adam);
        match (self.basis, self.inner) {
            (BasisSpec::Identity, EngineSpec::Adam) if plain_graft => Some(OptKind::AdamW),
            (BasisSpec::Identity, EngineSpec::Adafactor) if plain_graft => {
                Some(OptKind::Adafactor)
            }
            (BasisSpec::Eigen { .. }, EngineSpec::Adam) if plain_graft => Some(OptKind::Soap),
            (BasisSpec::Eigen { .. }, EngineSpec::Adafactor) if plain_graft => {
                Some(OptKind::Soap)
            }
            (BasisSpec::Eigen { .. }, EngineSpec::InverseRoot) => Some(OptKind::Shampoo),
            (BasisSpec::GradSvd, EngineSpec::Adam) if plain_graft => Some(OptKind::Galore),
            _ => None,
        }
    }

    /// The grammar spelling of this spec — [`CompositionSpec::parse`] maps
    /// it back to an equal value, so configs can be dumped and reloaded
    /// losslessly (unlike [`CompositionSpec::label`], which is display-only).
    pub fn spec_string(&self) -> String {
        let basis = match self.basis {
            BasisSpec::Identity => "identity",
            BasisSpec::Eigen { sided: Sided::Inherit } => "eigen",
            BasisSpec::Eigen { sided: Sided::OneSided } => "eigen:one-sided",
            BasisSpec::Eigen { sided: Sided::TwoSided } => "eigen:two-sided",
            BasisSpec::GradSvd => "svd",
        };
        let inner = match self.inner {
            EngineSpec::Adam => "adam",
            EngineSpec::Adafactor => "adafactor",
            EngineSpec::InverseRoot => "shampoo",
        };
        let mut s = format!("basis={basis},inner={inner}");
        match self.graft {
            GraftSpec::Inherit => {}
            GraftSpec::Adam => s.push_str(",graft=adam"),
            GraftSpec::Off => s.push_str(",graft=none"),
        }
        if let Some(w) = self.adam_warmup {
            s.push_str(&format!(",adam-warmup={w}"));
        }
        if let Some(w) = self.precond_warmup {
            s.push_str(&format!(",precond-warmup={w}"));
        }
        if let Some(sched) = self.precond_freq {
            s.push_str(&format!(",precond-freq={}", sched.spec_string(';')));
        }
        if let Some(on) = self.precondition_1d {
            s.push_str(&format!(",precondition-1d={on}"));
        }
        if let Some(d) = self.state_dtype {
            s.push_str(&format!(",state-dtype={}", d.name()));
        }
        s
    }

    /// Stable display label: the preset name when canonical, a structural
    /// `basis+engine[+graft]` label otherwise.
    pub fn label(&self) -> &'static str {
        if let Some(kind) = self.canonical() {
            // Eigen×Adafactor is factorized SOAP; keep the variant visible.
            if matches!(
                (self.basis, self.inner),
                (BasisSpec::Eigen { .. }, EngineSpec::Adafactor)
            ) {
                return "soap-factorized";
            }
            // canonical() only ever returns preset kinds, so this cannot
            // recurse back into label().
            return kind.name();
        }
        match (self.basis, self.inner) {
            (BasisSpec::Identity, EngineSpec::Adam) => "adamw+graft",
            (BasisSpec::Identity, EngineSpec::Adafactor) => "adafactor+graft",
            (BasisSpec::Eigen { .. }, EngineSpec::Adam) => "soap+graft",
            (BasisSpec::Eigen { .. }, EngineSpec::Adafactor) => "soap-factorized+graft",
            (BasisSpec::GradSvd, EngineSpec::Adam) => "galore+graft",
            (BasisSpec::GradSvd, EngineSpec::Adafactor) => {
                if matches!(self.graft, GraftSpec::Adam) {
                    "svd+adafactor+graft"
                } else {
                    "svd+adafactor"
                }
            }
            // validate() rules out InverseRoot off the eigen basis, and
            // eigen×InverseRoot is always canonical (Shampoo).
            (_, EngineSpec::InverseRoot) => "shampoo",
        }
    }

    /// Build per-layer state for an arbitrary-rank tensor parameter — the
    /// spec-grammar analogue of `OptKind::build_tensor`: rank ≤ 2 (and
    /// carrier-preserving collapses) take the exact matrix path, rank ≥ 3
    /// eigen-basis specs precondition per mode, and bases without a
    /// per-mode generalization (identity, grad-SVD) run on the carrier fold.
    pub fn build_tensor(&self, shape: &TensorShape, h: &Hyper) -> Box<dyn LayerOptimizer> {
        let mut hr = h.clone();
        self.apply(&mut hr);
        let eff = shape.effective(hr.merge_dims);
        let carrier = shape.carrier();
        // Rank-≤1 collapses always take the carrier matrix path (no
        // per-mode structure left); rank-2 collapses only when the merge
        // preserved the carrier fold (see `OptKind::build_tensor`).
        if eff.rank() < 2 || (eff.rank() == 2 && eff.carrier() == carrier) {
            return self.build(carrier.0, carrier.1, h);
        }
        match (self.basis, self.inner) {
            (BasisSpec::Eigen { .. }, EngineSpec::InverseRoot) => {
                let mut opt = presets::shampoo_nd(carrier, &eff, hr);
                if let Some(graft) = &mut opt.graft {
                    match self.graft {
                        GraftSpec::Adam => graft.active = true,
                        GraftSpec::Off => graft.active = false,
                        GraftSpec::Inherit => {}
                    }
                }
                Box::new(opt)
            }
            // `apply` already folded the engine choice into `hr.factorized`.
            (BasisSpec::Eigen { .. }, _) => {
                let mut opt = presets::soap_nd(carrier, &eff, hr);
                if matches!(self.graft, GraftSpec::Adam) {
                    let mut g = Graft::new(carrier.0, carrier.1, opt.hyper());
                    g.active = true;
                    opt.graft = Some(g);
                }
                Box::new(opt)
            }
            // Identity / grad-SVD bases have no per-mode decomposition —
            // the carrier fold is their native space.
            (BasisSpec::Identity, _) | (BasisSpec::GradSvd, _) => {
                self.build(carrier.0, carrier.1, h)
            }
        }
    }

    /// Build per-layer state for a `rows×cols` parameter. Canonical specs
    /// route through the preset factories (same code, same label); novel
    /// combinations assemble a [`Composed`] directly.
    pub fn build(&self, rows: usize, cols: usize, h: &Hyper) -> Box<dyn LayerOptimizer> {
        let mut h = h.clone();
        self.apply(&mut h);
        // Paper implementation detail 1: rotating bases run plain AdamW on
        // 1-D parameters (the Shampoo family preconditions them instead).
        // `precondition_1d` (spec key or `Hyper` knob — already folded into
        // `h` by `apply`) opts back into preconditioning them.
        let is_1d = rows == 1 || cols == 1;
        // The knob only opens the eigenbasis path: grad-SVD stays on the
        // fallback (its projector is degenerate on rank-1 inputs, same as
        // the GaLore preset).
        let keep_1d = h.precondition_1d && matches!(self.basis, BasisSpec::Eigen { .. });
        if is_1d
            && !keep_1d
            && !matches!(self.basis, BasisSpec::Identity)
            && self.inner != EngineSpec::InverseRoot
        {
            return Box::new(presets::adamw(rows, cols, h));
        }
        if let Some(kind) = self.canonical() {
            return kind.build(rows, cols, &h);
        }
        // Novel combination: assemble directly.
        let space = match self.basis {
            BasisSpec::Eigen { .. } => MomentumSpace::Original,
            _ => MomentumSpace::InBasis,
        };
        let basis = match self.basis {
            BasisSpec::Identity => AnyBasis::Identity(IdentityBasis::new()),
            BasisSpec::Eigen { .. } => AnyBasis::Eigen(EigenBasis::rotation(rows, cols, &h)),
            BasisSpec::GradSvd => AnyBasis::GradSvd(GradSvdBasis::new(rows, cols, &h)),
        };
        let engine = match self.inner {
            EngineSpec::Adam => AnyEngine::Adam(AdamEngine::new(rows, cols, &h, space)),
            EngineSpec::Adafactor => {
                AnyEngine::Adafactor(AdafactorEngine::new(rows, cols, &h, space))
            }
            EngineSpec::InverseRoot => unreachable!("inverse-root specs are canonical"),
        };
        let graft = matches!(self.graft, GraftSpec::Adam).then(|| {
            let mut g = Graft::new(rows, cols, &h);
            g.active = true;
            g
        });
        let label = self.label();
        Box::new(Composed::new(basis, engine, graft, h, label))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_presets_and_variants() {
        let s = CompositionSpec::parse("basis=eigen,inner=adam").unwrap();
        assert_eq!(s.canonical(), Some(OptKind::Soap));
        assert_eq!(s.label(), "soap");

        let s = CompositionSpec::parse("basis=eigen:one-sided,inner=adafactor").unwrap();
        assert_eq!(s.basis, BasisSpec::Eigen { sided: Sided::OneSided });
        assert_eq!(s.canonical(), Some(OptKind::Soap));
        assert_eq!(s.label(), "soap-factorized");
        let mut h = Hyper::default();
        s.apply(&mut h);
        assert!(h.one_sided && h.factorized);

        let s = CompositionSpec::parse("basis=eigen,inner=shampoo,graft=none").unwrap();
        assert_eq!(s.canonical(), Some(OptKind::Shampoo));
        let mut h = Hyper::default();
        s.apply(&mut h);
        assert!(!h.grafting);

        let s = CompositionSpec::parse("basis=svd,inner=adam").unwrap();
        assert_eq!(s.canonical(), Some(OptKind::Galore));

        let s = CompositionSpec::parse("inner=adafactor").unwrap();
        assert_eq!(s.canonical(), Some(OptKind::Adafactor));
    }

    #[test]
    fn warmup_keys_parse_apply_and_roundtrip() {
        let s =
            CompositionSpec::parse("basis=eigen,inner=adam,adam-warmup=50,precond-warmup=9")
                .unwrap();
        assert_eq!(s.adam_warmup, Some(50));
        assert_eq!(s.precond_warmup, Some(9));
        let mut h = Hyper::default();
        s.apply(&mut h);
        assert_eq!(h.adam_warmup_steps, 50);
        assert_eq!(h.precondition_warmup, 9);
        // spec_string → parse is lossless.
        let back = CompositionSpec::parse(&s.spec_string()).unwrap();
        assert_eq!(back, s);
        // Omitted keys inherit: apply must not clobber config-set values.
        let s = CompositionSpec::parse("basis=eigen,inner=adam").unwrap();
        assert_eq!(s.adam_warmup, None);
        let mut h = Hyper::default().with_adam_warmup(7).with_precondition_warmup(3);
        s.apply(&mut h);
        assert_eq!(h.adam_warmup_steps, 7);
        assert_eq!(h.precondition_warmup, 3);
        // A malformed count is a named error.
        let e = CompositionSpec::parse("basis=eigen,inner=adam,adam-warmup=soon")
            .unwrap_err()
            .to_string();
        assert!(e.contains("step count"), "{e}");
    }

    #[test]
    fn freq_and_1d_keys_parse_apply_and_roundtrip() {
        let s = CompositionSpec::parse(
            "basis=eigen,inner=adam,precond-freq=10@0;100@1000,precondition-1d=true",
        )
        .unwrap();
        let mut h = Hyper::default();
        s.apply(&mut h);
        assert!(h.precondition_1d);
        let sched = h.precond_freq_schedule.expect("schedule installed");
        assert_eq!(sched.pieces(), &[(0, 10), (1000, 100)]);
        // spec_string → parse is lossless.
        let back = CompositionSpec::parse(&s.spec_string()).unwrap();
        assert_eq!(back, s);

        // A constant frequency folds into the base field, not a schedule.
        let s = CompositionSpec::parse("basis=eigen,inner=adam,precond-freq=32").unwrap();
        let mut h = Hyper::default();
        s.apply(&mut h);
        assert_eq!(h.precond_freq, 32);
        assert!(h.precond_freq_schedule.is_none());

        // Omitted keys inherit config-set values.
        let s = CompositionSpec::parse("basis=eigen,inner=adam").unwrap();
        let mut h = Hyper::default().with_freq(17).with_precondition_1d(true);
        s.apply(&mut h);
        assert_eq!(h.precond_freq, 17);
        assert!(h.precondition_1d);

        // Malformed values surface named errors.
        for bad in [
            "basis=eigen,inner=adam,precond-freq=soon",
            "basis=eigen,inner=adam,precond-freq=0",
            "basis=eigen,inner=adam,precondition-1d=maybe",
        ] {
            assert!(CompositionSpec::parse(bad).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn state_dtype_key_parses_applies_and_roundtrips() {
        let s = CompositionSpec::parse("basis=eigen,inner=adam,state-dtype=bf16").unwrap();
        assert_eq!(s.state_dtype, Some(StateDtype::Bf16));
        let mut h = Hyper::default();
        s.apply(&mut h);
        assert_eq!(h.state_dtype, StateDtype::Bf16);
        // spec_string → parse is lossless.
        let back = CompositionSpec::parse(&s.spec_string()).unwrap();
        assert_eq!(back, s);
        // Omitted key inherits the config-set value.
        let s = CompositionSpec::parse("basis=eigen,inner=adam").unwrap();
        assert_eq!(s.state_dtype, None);
        let mut h = Hyper::default().with_state_dtype(StateDtype::Bf16);
        s.apply(&mut h);
        assert_eq!(h.state_dtype, StateDtype::Bf16);
        // A malformed dtype is a named error.
        let e = CompositionSpec::parse("basis=eigen,inner=adam,state-dtype=fp8")
            .unwrap_err()
            .to_string();
        assert!(e.contains("f32") && e.contains("bf16"), "{e}");
    }

    #[test]
    fn precondition_1d_spec_keeps_eigen_on_rank1() {
        let h = Hyper::default();
        let s = CompositionSpec::parse("basis=eigen,inner=adam,precondition-1d=true").unwrap();
        assert_eq!(s.build(1, 64, &h).name(), "soap");
        // Grad-SVD keeps the fallback: degenerate projector on rank-1.
        let s = CompositionSpec::parse("basis=svd,inner=adam,precondition-1d=true").unwrap();
        assert_eq!(s.build(1, 64, &h).name(), "adamw");
    }

    #[test]
    fn novel_combos_have_no_canonical_preset() {
        let s = CompositionSpec::parse("basis=svd,inner=adafactor").unwrap();
        assert_eq!(s.canonical(), None);
        assert_eq!(s.label(), "svd+adafactor");
        let s = CompositionSpec::parse("basis=eigen,inner=adam,graft=adam").unwrap();
        assert_eq!(s.canonical(), None);
        assert_eq!(s.label(), "soap+graft");
    }

    #[test]
    fn parse_errors_enumerate_choices() {
        let e = CompositionSpec::parse("basis=fourier,inner=adam").unwrap_err().to_string();
        assert!(e.contains("eigen") && e.contains("svd"), "{e}");
        let e = CompositionSpec::parse("basis=eigen,inner=sgd").unwrap_err().to_string();
        assert!(e.contains("adafactor") && e.contains("shampoo"), "{e}");
        let e = CompositionSpec::parse("basis=eigen").unwrap_err().to_string();
        assert!(e.contains("inner="), "{e}");
        let e = CompositionSpec::parse("basis=svd,inner=shampoo").unwrap_err().to_string();
        assert!(e.contains("basis=eigen"), "{e}");
        let e = CompositionSpec::parse("flavor=mint,inner=adam").unwrap_err().to_string();
        assert!(e.contains("basis=") && e.contains("graft"), "{e}");
        let e = CompositionSpec::parse("basis=eigen:one-sided,inner=shampoo")
            .unwrap_err()
            .to_string();
        assert!(e.contains("both sides"), "{e}");
    }

    #[test]
    fn flag_contradictions_rejected() {
        let s = CompositionSpec::parse("basis=eigen:two-sided,inner=adam").unwrap();
        assert!(s.check_flag_consistency(true, false).is_err());
        assert!(s.check_flag_consistency(false, false).is_ok());
        let s = CompositionSpec::parse("basis=eigen,inner=adam").unwrap();
        assert!(s.check_flag_consistency(false, true).is_err());
        // Inherit defers to the flag — no contradiction.
        assert!(s.check_flag_consistency(true, false).is_ok());
        let s = CompositionSpec::parse("basis=eigen,inner=adafactor").unwrap();
        assert!(s.check_flag_consistency(false, false).is_ok());
    }

    #[test]
    fn build_routes_1d_to_adamw_for_rotating_bases() {
        let h = Hyper::default();
        let s = CompositionSpec::parse("basis=eigen,inner=adafactor").unwrap();
        assert_eq!(s.build(1, 64, &h).name(), "adamw");
        let s = CompositionSpec::parse("basis=eigen,inner=shampoo").unwrap();
        assert_eq!(s.build(1, 64, &h).name(), "shampoo");
        let s = CompositionSpec::parse("basis=identity,inner=adafactor").unwrap();
        assert_eq!(s.build(1, 64, &h).name(), "adafactor");
    }

    #[test]
    fn novel_combo_builds_and_descends() {
        use crate::linalg::Matrix;
        use crate::util::rng::Rng;
        let h = Hyper { weight_decay: 0.0, precond_freq: 3, ..Hyper::default() };
        let s = CompositionSpec::parse("basis=svd,inner=adafactor").unwrap();
        let mut opt = s.build(5, 4, &h);
        let mut rng = Rng::new(74);
        let target = Matrix::randn(&mut rng, 5, 4, 1.0);
        let mut w = Matrix::zeros(5, 4);
        let d0 = w.sub(&target).frob_norm();
        for t in 1..=800 {
            let g = w.sub(&target).scale(2.0);
            opt.update(&mut w, &g, t, 0.02);
        }
        assert!(w.sub(&target).frob_norm() < 0.5 * d0);
    }
}
