//! [`MomentEngine`](super::MomentEngine) implementations — the "which update
//! rule runs inside the basis's working space" axis of the paper's
//! factorization.
//!
//! - [`AdamEngine`] — diagonal Adam. With momentum kept in the working space
//!   (AdamW, GaLore's projected moments) or in the original space and rotated
//!   through the basis every step (SOAP Algorithm 3, where re-rotating the
//!   momentum is what distinguishes it from GaLore — §3).
//! - [`AdafactorEngine`] — the rank-1 factored second moment (Shazeer &
//!   Stern 2018, simplified per Zhai et al. 2022). In an eigenbasis this is
//!   the paper's factorized SOAP (§7.2.1) and — by Claim 1 — idealized
//!   Shampoo with power 1/2.
//! - [`InverseRootEngine`] — bias-corrected momentum pushed through the full
//!   Kronecker preconditioner `L^{-1/e} · M̂ · R^{-1/e}` (Shampoo). Requires
//!   an inverse-root flavored [`EigenBasis`](super::basis::EigenBasis).

use super::state::{StateMatrix, StateVec};
use super::workspace::Workspace;
use super::{Basis, EngineState, MomentEngine};
use crate::linalg::Matrix;
use crate::optim::hyper::Hyper;

/// Compute the factored second-moment denominator √(AᵢCⱼ/ΣA + ε) and return
/// the elementwise-normalized `num / denom`. Shared by [`AdafactorEngine`]
/// in every space it runs in (plain Adafactor and factorized SOAP alike).
pub fn factored_normalize(num: &Matrix, a: &[f32], c: &[f32], eps: f32) -> Matrix {
    let sum_a: f32 = a.iter().map(|&x| x as f64).sum::<f64>() as f32;
    let inv_sum = if sum_a > 0.0 { 1.0 / sum_a } else { 0.0 };
    Matrix::from_fn(num.rows, num.cols, |i, j| {
        let vhat = (a[i] * c[j] * inv_sum).max(0.0);
        num.at(i, j) / (vhat + eps).sqrt()
    })
}

/// Fused, allocation-free companion of `AdafactorEngine::factored_dir`: the
/// g² row/col sums (f64 accumulation, matching `Matrix::row_sums`/
/// `col_sums`), the A/C EMAs, their bias corrections, and the factored
/// normalize — with every intermediate in caller-provided scratch and the
/// numerator's `1/bc1` correction folded into the final pass. Each f32
/// expression and accumulation order matches the allocating reference, so
/// the result is bitwise identical. Under bf16 storage the EMAs encode on
/// store and the bias-corrected hats read the decoded values back — the same
/// read-back semantics the allocating path sees, so the two stay bitwise
/// equal per dtype.
#[allow(clippy::too_many_arguments)]
fn factored_dir_into(
    a: &mut StateVec,
    c: &mut StateVec,
    beta2: f32,
    eps: f32,
    gp: &Matrix,
    num: &Matrix,
    num_scale: f32,
    bc2: f32,
    sums_row: &mut Vec<f64>,
    sums_col: &mut Vec<f64>,
    hat_row: &mut Vec<f32>,
    hat_col: &mut Vec<f32>,
    out: &mut Matrix,
) {
    let (rows, cols) = (gp.rows, gp.cols);
    sums_row.clear();
    sums_row.resize(rows, 0.0);
    sums_col.clear();
    sums_col.resize(cols, 0.0);
    for i in 0..rows {
        let mut acc = 0.0f64;
        for (cj, &x) in sums_col.iter_mut().zip(gp.row(i)) {
            let x2 = x * x;
            acc += x2 as f64;
            *cj += x2 as f64;
        }
        sums_row[i] = acc;
    }
    let ob2 = 1.0 - beta2;
    a.ema_update(|i, ai| beta2 * ai + ob2 * (sums_row[i] as f32));
    c.ema_update(|i, ci| beta2 * ci + ob2 * (sums_col[i] as f32));
    hat_row.clear();
    hat_row.extend(a.iter_decoded().map(|x| x / bc2));
    hat_col.clear();
    hat_col.extend(c.iter_decoded().map(|x| x / bc2));
    // `factored_normalize`, fused with the numerator bias correction.
    let sum_a: f32 = hat_row.iter().map(|&x| x as f64).sum::<f64>() as f32;
    let inv_sum = if sum_a > 0.0 { 1.0 / sum_a } else { 0.0 };
    out.reuse_shape(rows, cols);
    for i in 0..rows {
        let ai = hat_row[i];
        let nrow = &num.data[i * cols..(i + 1) * cols];
        let orow = &mut out.data[i * cols..(i + 1) * cols];
        for ((oj, &nj), &cjv) in orow.iter_mut().zip(nrow).zip(hat_col.iter()) {
            let vhat = (ai * cjv * inv_sum).max(0.0);
            *oj = (nj * num_scale) / (vhat + eps).sqrt();
        }
    }
}

/// Where an engine's first moment lives relative to the basis.
///
/// `InBasis`: momentum accumulates in the working (projected) space and is
/// NOT re-rotated when the basis refreshes — AdamW (trivially) and GaLore
/// (deliberately, §3 difference #2). `Original`: momentum accumulates in the
/// original space and is rotated through the basis every step — SOAP's fix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MomentumSpace {
    InBasis,
    Original,
}

/// Diagonal Adam in the basis's working space.
pub struct AdamEngine {
    h: Hyper,
    pub m: Matrix,
    /// Second moment — stored per [`Hyper::state_dtype`] (f32 or bf16).
    pub v: StateMatrix,
    pub space: MomentumSpace,
}

impl AdamEngine {
    pub fn new(rows: usize, cols: usize, h: &Hyper, space: MomentumSpace) -> Self {
        Self {
            h: h.clone(),
            m: Matrix::zeros(rows, cols),
            v: StateMatrix::zeros(rows, cols, h.state_dtype),
            space,
        }
    }
}

impl MomentEngine for AdamEngine {
    fn direction_into(&mut self, g: &Matrix, t: u64, basis: &dyn Basis, ws: &mut Workspace) {
        let h = &self.h;
        let bc1 = 1.0 - h.beta1.powi(t as i32);
        let bc2 = 1.0 - h.beta2.powi(t as i32);
        let ob2 = 1.0 - h.beta2;
        match self.space {
            MomentumSpace::InBasis => {
                // Identity basis: skip the projection copies entirely and
                // write the fused update straight into `ws.dir`.
                let identity = basis.is_identity();
                if !identity {
                    let _span = crate::telemetry::span("engine.project", "engine");
                    basis.project_into(g, &mut ws.rot_g, &mut ws.scratch);
                }
                let gp: &Matrix = if identity { g } else { &ws.rot_g };
                self.m.ema_inplace(gp, h.beta1);
                let out = if identity { &mut ws.dir } else { &mut ws.nrot };
                out.reuse_shape(gp.rows, gp.cols);
                // Fused pass: V EMA + bias correction + m̂/√v̂ — the same f32
                // expressions, in the same order, as the allocating
                // `hadamard`/`ema_inplace`/`zip` chain in `direction`. The
                // consumer closure sees V's stored (read-back) value, so
                // bf16 storage keeps the two paths bitwise equal too.
                {
                    let _span = crate::telemetry::span("engine.moment", "engine");
                    let (beta2, eps) = (h.beta2, h.eps);
                    let (gd, md) = (&gp.data, &self.m.data);
                    let od = &mut out.data;
                    self.v.ema_then(
                        |i, vi| beta2 * vi + ob2 * (gd[i] * gd[i]),
                        |i, vi| od[i] = (md[i] / bc1) / ((vi / bc2).max(0.0).sqrt() + eps),
                    );
                }
                if !identity {
                    let _span = crate::telemetry::span("engine.project_back", "engine");
                    basis.project_back_into(&ws.nrot, &mut ws.dir, &mut ws.scratch);
                }
            }
            MomentumSpace::Original => {
                // SOAP Algorithm 3: momentum in the original space, G and M
                // rotated every step, V updated in the rotated space.
                self.m.ema_inplace(g, h.beta1);
                {
                    let _span = crate::telemetry::span("engine.project", "engine");
                    basis.project_into(g, &mut ws.rot_g, &mut ws.scratch);
                    basis.project_into(&self.m, &mut ws.rot_m, &mut ws.scratch);
                }
                ws.nrot.reuse_shape(ws.rot_g.rows, ws.rot_g.cols);
                // `m_hat = m_rot.scale(1/bc1)` in the reference — keep the
                // multiply-by-reciprocal form for bitwise parity.
                let inv_bc1 = 1.0 / bc1;
                {
                    let _span = crate::telemetry::span("engine.moment", "engine");
                    let (beta2, eps) = (h.beta2, h.eps);
                    let (gd, md) = (&ws.rot_g.data, &ws.rot_m.data);
                    let nd = &mut ws.nrot.data;
                    self.v.ema_then(
                        |i, vi| beta2 * vi + ob2 * (gd[i] * gd[i]),
                        |i, vi| nd[i] = (md[i] * inv_bc1) / ((vi / bc2).max(0.0).sqrt() + eps),
                    );
                }
                let _span = crate::telemetry::span("engine.project_back", "engine");
                basis.project_back_into(&ws.nrot, &mut ws.dir, &mut ws.scratch);
            }
        }
    }

    fn direction(&mut self, g: &Matrix, t: u64, basis: &dyn Basis) -> Matrix {
        let h = &self.h;
        let bc1 = 1.0 - h.beta1.powi(t as i32);
        let bc2 = 1.0 - h.beta2.powi(t as i32);
        match self.space {
            MomentumSpace::InBasis => {
                let gp_store;
                let gp: &Matrix = if basis.is_identity() {
                    g
                } else {
                    gp_store = basis.project(g);
                    &gp_store
                };
                self.m.ema_inplace(gp, h.beta1);
                let g2 = gp.hadamard(gp);
                self.v.ema_inplace(&g2, h.beta2);
                let v = self.v.to_matrix();
                let dir = self
                    .m
                    .zip(&v, |mi, vi| (mi / bc1) / ((vi / bc2).max(0.0).sqrt() + h.eps));
                if basis.is_identity() {
                    dir
                } else {
                    basis.project_back(&dir)
                }
            }
            MomentumSpace::Original => {
                // Momentum in the original space, then rotate both G and M
                // (SOAP Algorithm 3); V updates EVERY step in the rotated
                // space — the paper's fix for Shampoo's staleness.
                self.m.ema_inplace(g, h.beta1);
                let g_rot = basis.project(g);
                let m_rot = basis.project(&self.m);
                let m_hat = m_rot.scale(1.0 / bc1);
                let g2 = g_rot.hadamard(&g_rot);
                self.v.ema_inplace(&g2, h.beta2);
                let v = self.v.to_matrix();
                let n_rot = m_hat.zip(&v, |mi, vi| mi / ((vi / bc2).max(0.0).sqrt() + h.eps));
                basis.project_back(&n_rot)
            }
        }
    }

    fn momentum(&self) -> &Matrix {
        &self.m
    }

    fn full_v(&self) -> bool {
        true
    }

    fn state_bytes(&self) -> usize {
        self.m.numel() * 4 + self.v.state_bytes()
    }

    fn export(&self) -> EngineState {
        EngineState { momentum: self.m.clone(), second: vec![self.v.to_matrix()] }
    }

    fn import(
        &mut self,
        momentum: Matrix,
        it: &mut dyn Iterator<Item = Matrix>,
    ) -> anyhow::Result<()> {
        self.m = momentum;
        let v = it.next().ok_or_else(|| anyhow::anyhow!("adam engine missing v"))?;
        self.v = StateMatrix::from_matrix(&v, self.h.state_dtype);
        Ok(())
    }
}

/// Rank-1 factored second moment (Adafactor) in the basis's working space.
///
/// In `MomentumSpace::InBasis` (the standalone Adafactor preset), 1-D
/// parameters degenerate the factorization and fall back to a full Adam `V`
/// (matches practical Adafactor implementations). In
/// `MomentumSpace::Original` (factorized SOAP) the second moment stays
/// rank-1 for every shape, exactly like the pre-refactor implementation —
/// the layouts must stay checkpoint-compatible.
pub struct AdafactorEngine {
    h: Hyper,
    pub m: Matrix,
    /// Row second-moment EMA (m×1) — `A` in Adafactor's Algorithm 2. Stored
    /// per [`Hyper::state_dtype`] (f32 or bf16).
    pub a: StateVec,
    /// Column second-moment EMA (1×n) — `C`. Stored per `state_dtype`.
    pub c: StateVec,
    /// Degenerate (vector) fallback V — stored per `state_dtype`.
    pub v_1d: Option<StateMatrix>,
    pub space: MomentumSpace,
}

impl AdafactorEngine {
    pub fn new(rows: usize, cols: usize, h: &Hyper, space: MomentumSpace) -> Self {
        let is_1d = rows == 1 || cols == 1;
        Self {
            h: h.clone(),
            m: Matrix::zeros(rows, cols),
            a: StateVec::zeros(rows, h.state_dtype),
            c: StateVec::zeros(cols, h.state_dtype),
            v_1d: (is_1d && space == MomentumSpace::InBasis)
                .then(|| StateMatrix::zeros(rows, cols, h.state_dtype)),
            space,
        }
    }

    /// EMA the factored stats with `g2` and return the normalized direction
    /// for the (bias-corrected) numerator.
    fn factored_dir(&mut self, g2: &Matrix, m_hat: &Matrix, bc2: f32) -> Matrix {
        let rows = g2.row_sums();
        let cols = g2.col_sums();
        let beta2 = self.h.beta2;
        let ob2 = 1.0 - beta2;
        self.a.ema_update(|i, ai| beta2 * ai + ob2 * rows[i]);
        self.c.ema_update(|i, ci| beta2 * ci + ob2 * cols[i]);
        // Bias-correct A and C; the ΣA normalization makes the corrections
        // cancel except through ε, but we keep them for parity with Adam.
        let a_hat: Vec<f32> = self.a.iter_decoded().map(|x| x / bc2).collect();
        let c_hat: Vec<f32> = self.c.iter_decoded().map(|x| x / bc2).collect();
        factored_normalize(m_hat, &a_hat, &c_hat, self.h.eps)
    }
}

impl MomentEngine for AdafactorEngine {
    fn direction_into(&mut self, g: &Matrix, t: u64, basis: &dyn Basis, ws: &mut Workspace) {
        let bc1 = 1.0 - self.h.beta1.powi(t as i32);
        let bc2 = 1.0 - self.h.beta2.powi(t as i32);
        let (beta1, beta2, eps) = (self.h.beta1, self.h.beta2, self.h.eps);
        let Workspace {
            rot_g, rot_m, nrot, dir, sums_row, sums_col, hat_row, hat_col, scratch, ..
        } = ws;
        match self.space {
            MomentumSpace::InBasis => {
                let identity = basis.is_identity();
                if !identity {
                    let _span = crate::telemetry::span("engine.project", "engine");
                    basis.project_into(g, rot_g, scratch);
                }
                let gp: &Matrix = if identity { g } else { &*rot_g };
                self.m.ema_inplace(gp, beta1);
                let out: &mut Matrix = if identity { &mut *dir } else { &mut *nrot };
                let moment_span = crate::telemetry::span("engine.moment", "engine");
                if let Some(v) = &mut self.v_1d {
                    // Degenerate (vector) case: plain Adam second moment,
                    // fused exactly like `AdamEngine::direction_into`.
                    out.reuse_shape(gp.rows, gp.cols);
                    let ob2 = 1.0 - beta2;
                    let (gd, md) = (&gp.data, &self.m.data);
                    let od = &mut out.data;
                    v.ema_then(
                        |i, vi| beta2 * vi + ob2 * (gd[i] * gd[i]),
                        |i, vi| od[i] = (md[i] / bc1) / ((vi / bc2).max(0.0).sqrt() + eps),
                    );
                } else {
                    factored_dir_into(
                        &mut self.a,
                        &mut self.c,
                        beta2,
                        eps,
                        gp,
                        &self.m,
                        1.0 / bc1,
                        bc2,
                        sums_row,
                        sums_col,
                        hat_row,
                        hat_col,
                        out,
                    );
                }
                drop(moment_span);
                if !identity {
                    let _span = crate::telemetry::span("engine.project_back", "engine");
                    basis.project_back_into(nrot, dir, scratch);
                }
            }
            MomentumSpace::Original => {
                // Factorized SOAP (§7.2.1): rank-1 V in the eigenbasis.
                self.m.ema_inplace(g, beta1);
                {
                    let _span = crate::telemetry::span("engine.project", "engine");
                    basis.project_into(g, rot_g, scratch);
                    basis.project_into(&self.m, rot_m, scratch);
                }
                {
                    let _span = crate::telemetry::span("engine.moment", "engine");
                    factored_dir_into(
                        &mut self.a,
                        &mut self.c,
                        beta2,
                        eps,
                        rot_g,
                        rot_m,
                        1.0 / bc1,
                        bc2,
                        sums_row,
                        sums_col,
                        hat_row,
                        hat_col,
                        nrot,
                    );
                }
                let _span = crate::telemetry::span("engine.project_back", "engine");
                basis.project_back_into(nrot, dir, scratch);
            }
        }
    }

    fn direction(&mut self, g: &Matrix, t: u64, basis: &dyn Basis) -> Matrix {
        let h = self.h.clone();
        let bc1 = 1.0 - h.beta1.powi(t as i32);
        let bc2 = 1.0 - h.beta2.powi(t as i32);
        match self.space {
            MomentumSpace::InBasis => {
                let gp_store;
                let gp: &Matrix = if basis.is_identity() {
                    g
                } else {
                    gp_store = basis.project(g);
                    &gp_store
                };
                self.m.ema_inplace(gp, h.beta1);
                let dir = if let Some(v) = &mut self.v_1d {
                    // Degenerate (vector) case: plain Adam second moment.
                    let g2 = gp.hadamard(gp);
                    v.ema_inplace(&g2, h.beta2);
                    let vm = v.to_matrix();
                    self.m
                        .zip(&vm, |mi, vi| (mi / bc1) / ((vi / bc2).max(0.0).sqrt() + h.eps))
                } else {
                    let g2 = gp.hadamard(gp);
                    let m_hat = self.m.scale(1.0 / bc1);
                    self.factored_dir(&g2, &m_hat, bc2)
                };
                if basis.is_identity() {
                    dir
                } else {
                    basis.project_back(&dir)
                }
            }
            MomentumSpace::Original => {
                // Factorized SOAP (§7.2.1): Adafactor-style rank-1 V in the
                // eigenbasis — exactly the configuration Claim 1 equates
                // with power-1/2 Shampoo.
                self.m.ema_inplace(g, h.beta1);
                let g_rot = basis.project(g);
                let m_rot = basis.project(&self.m);
                let m_hat = m_rot.scale(1.0 / bc1);
                let g2 = g_rot.hadamard(&g_rot);
                let n_rot = self.factored_dir(&g2, &m_hat, bc2);
                basis.project_back(&n_rot)
            }
        }
    }

    fn momentum(&self) -> &Matrix {
        &self.m
    }

    fn full_v(&self) -> bool {
        false
    }

    fn state_bytes(&self) -> usize {
        let factored = self.a.state_bytes() + self.c.state_bytes();
        let v1d = self.v_1d.as_ref().map(|v| v.state_bytes()).unwrap_or(0);
        self.m.numel() * 4 + factored + v1d
    }

    fn export(&self) -> EngineState {
        let mut second = vec![
            Matrix::from_vec(1, self.a.len(), self.a.to_vec()),
            Matrix::from_vec(1, self.c.len(), self.c.to_vec()),
        ];
        if let Some(v) = &self.v_1d {
            second.push(v.to_matrix());
        }
        EngineState { momentum: self.m.clone(), second }
    }

    fn import(
        &mut self,
        momentum: Matrix,
        it: &mut dyn Iterator<Item = Matrix>,
    ) -> anyhow::Result<()> {
        self.m = momentum;
        let a = it.next().ok_or_else(|| anyhow::anyhow!("adafactor missing a"))?;
        self.a.assign_from(&a.data);
        let c = it.next().ok_or_else(|| anyhow::anyhow!("adafactor missing c"))?;
        self.c.assign_from(&c.data);
        if self.v_1d.is_some() {
            let v = it.next().ok_or_else(|| anyhow::anyhow!("adafactor missing v_1d"))?;
            self.v_1d = Some(StateMatrix::from_matrix(&v, self.h.state_dtype));
        }
        Ok(())
    }
}

/// Shampoo's update rule: bias-corrected momentum through the full
/// Kronecker preconditioner. The basis (inverse-root flavored `EigenBasis`)
/// owns the factor EMAs and the cached `L^{-1/e}`/`R^{-1/e}`; this engine is
/// just momentum + the sandwich.
pub struct InverseRootEngine {
    h: Hyper,
    pub m: Matrix,
}

impl InverseRootEngine {
    pub fn new(rows: usize, cols: usize, h: &Hyper) -> Self {
        Self { h: h.clone(), m: Matrix::zeros(rows, cols) }
    }
}

impl MomentEngine for InverseRootEngine {
    fn direction_into(&mut self, g: &Matrix, t: u64, basis: &dyn Basis, ws: &mut Workspace) {
        self.m.ema_inplace(g, self.h.beta1);
        let bc1 = 1.0 - self.h.beta1.powi(t as i32);
        // `m_hat = m.scale(1/bc1)` materialized into scratch (same
        // multiply-by-reciprocal expression as the reference), then the full
        // sandwich applies through `project_into`.
        let inv_bc1 = 1.0 / bc1;
        {
            let _span = crate::telemetry::span("engine.moment", "engine");
            ws.rot_m.reuse_shape(self.m.rows, self.m.cols);
            for (oi, &mi) in ws.rot_m.data.iter_mut().zip(&self.m.data) {
                *oi = mi * inv_bc1;
            }
        }
        // The whole Kronecker sandwich applies in `project` — no back-rotate.
        let _span = crate::telemetry::span("engine.project", "engine");
        basis.project_into(&ws.rot_m, &mut ws.dir, &mut ws.scratch);
    }

    fn direction(&mut self, g: &Matrix, t: u64, basis: &dyn Basis) -> Matrix {
        self.m.ema_inplace(g, self.h.beta1);
        let bc1 = 1.0 - self.h.beta1.powi(t as i32);
        let m_hat = self.m.scale(1.0 / bc1);
        // L^{-1/e} · M̂ · R^{-1/e} — the whole preconditioner applies in
        // `project`; there is no rotate-back.
        basis.project(&m_hat)
    }

    fn momentum(&self) -> &Matrix {
        &self.m
    }

    fn full_v(&self) -> bool {
        false
    }

    fn state_bytes(&self) -> usize {
        self.m.numel() * 4
    }

    fn export(&self) -> EngineState {
        EngineState { momentum: self.m.clone(), second: Vec::new() }
    }

    fn import(
        &mut self,
        momentum: Matrix,
        _it: &mut dyn Iterator<Item = Matrix>,
    ) -> anyhow::Result<()> {
        self.m = momentum;
        Ok(())
    }
}

/// Closed set of shipped engines (see [`AnyBasis`](super::basis::AnyBasis)).
// One value per model layer; the variant-size spread is irrelevant there.
#[allow(clippy::large_enum_variant)]
pub enum AnyEngine {
    Adam(AdamEngine),
    Adafactor(AdafactorEngine),
    InverseRoot(InverseRootEngine),
}

impl AnyEngine {
    pub fn as_adam(&self) -> Option<&AdamEngine> {
        match self {
            AnyEngine::Adam(e) => Some(e),
            _ => None,
        }
    }

    pub fn as_adafactor(&self) -> Option<&AdafactorEngine> {
        match self {
            AnyEngine::Adafactor(e) => Some(e),
            _ => None,
        }
    }
}

impl MomentEngine for AnyEngine {
    fn direction_into(&mut self, g: &Matrix, t: u64, basis: &dyn Basis, ws: &mut Workspace) {
        match self {
            AnyEngine::Adam(e) => e.direction_into(g, t, basis, ws),
            AnyEngine::Adafactor(e) => e.direction_into(g, t, basis, ws),
            AnyEngine::InverseRoot(e) => e.direction_into(g, t, basis, ws),
        }
    }

    fn direction(&mut self, g: &Matrix, t: u64, basis: &dyn Basis) -> Matrix {
        match self {
            AnyEngine::Adam(e) => e.direction(g, t, basis),
            AnyEngine::Adafactor(e) => e.direction(g, t, basis),
            AnyEngine::InverseRoot(e) => e.direction(g, t, basis),
        }
    }

    fn momentum(&self) -> &Matrix {
        match self {
            AnyEngine::Adam(e) => e.momentum(),
            AnyEngine::Adafactor(e) => e.momentum(),
            AnyEngine::InverseRoot(e) => e.momentum(),
        }
    }

    fn full_v(&self) -> bool {
        match self {
            AnyEngine::Adam(e) => e.full_v(),
            AnyEngine::Adafactor(e) => e.full_v(),
            AnyEngine::InverseRoot(e) => e.full_v(),
        }
    }

    fn state_bytes(&self) -> usize {
        match self {
            AnyEngine::Adam(e) => e.state_bytes(),
            AnyEngine::Adafactor(e) => e.state_bytes(),
            AnyEngine::InverseRoot(e) => e.state_bytes(),
        }
    }

    fn export(&self) -> EngineState {
        match self {
            AnyEngine::Adam(e) => e.export(),
            AnyEngine::Adafactor(e) => e.export(),
            AnyEngine::InverseRoot(e) => e.export(),
        }
    }

    fn import(
        &mut self,
        momentum: Matrix,
        it: &mut dyn Iterator<Item = Matrix>,
    ) -> anyhow::Result<()> {
        match self {
            AnyEngine::Adam(e) => e.import(momentum, it),
            AnyEngine::Adafactor(e) => e.import(momentum, it),
            AnyEngine::InverseRoot(e) => e.import(momentum, it),
        }
    }
}
