//! [`MomentEngine`](super::MomentEngine) implementations — the "which update
//! rule runs inside the basis's working space" axis of the paper's
//! factorization.
//!
//! - [`AdamEngine`] — diagonal Adam. With momentum kept in the working space
//!   (AdamW, GaLore's projected moments) or in the original space and rotated
//!   through the basis every step (SOAP Algorithm 3, where re-rotating the
//!   momentum is what distinguishes it from GaLore — §3).
//! - [`AdafactorEngine`] — the rank-1 factored second moment (Shazeer &
//!   Stern 2018, simplified per Zhai et al. 2022). In an eigenbasis this is
//!   the paper's factorized SOAP (§7.2.1) and — by Claim 1 — idealized
//!   Shampoo with power 1/2.
//! - [`InverseRootEngine`] — bias-corrected momentum pushed through the full
//!   Kronecker preconditioner `L^{-1/e} · M̂ · R^{-1/e}` (Shampoo). Requires
//!   an inverse-root flavored [`EigenBasis`](super::basis::EigenBasis).

use super::{Basis, EngineState, MomentEngine};
use crate::linalg::Matrix;
use crate::optim::hyper::Hyper;

/// Compute the factored second-moment denominator √(AᵢCⱼ/ΣA + ε) and return
/// the elementwise-normalized `num / denom`. Shared by [`AdafactorEngine`]
/// in every space it runs in (plain Adafactor and factorized SOAP alike).
pub fn factored_normalize(num: &Matrix, a: &[f32], c: &[f32], eps: f32) -> Matrix {
    let sum_a: f32 = a.iter().map(|&x| x as f64).sum::<f64>() as f32;
    let inv_sum = if sum_a > 0.0 { 1.0 / sum_a } else { 0.0 };
    Matrix::from_fn(num.rows, num.cols, |i, j| {
        let vhat = (a[i] * c[j] * inv_sum).max(0.0);
        num.at(i, j) / (vhat + eps).sqrt()
    })
}

/// Where an engine's first moment lives relative to the basis.
///
/// `InBasis`: momentum accumulates in the working (projected) space and is
/// NOT re-rotated when the basis refreshes — AdamW (trivially) and GaLore
/// (deliberately, §3 difference #2). `Original`: momentum accumulates in the
/// original space and is rotated through the basis every step — SOAP's fix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MomentumSpace {
    InBasis,
    Original,
}

/// Diagonal Adam in the basis's working space.
pub struct AdamEngine {
    h: Hyper,
    pub m: Matrix,
    pub v: Matrix,
    pub space: MomentumSpace,
}

impl AdamEngine {
    pub fn new(rows: usize, cols: usize, h: &Hyper, space: MomentumSpace) -> Self {
        Self {
            h: h.clone(),
            m: Matrix::zeros(rows, cols),
            v: Matrix::zeros(rows, cols),
            space,
        }
    }
}

impl MomentEngine for AdamEngine {
    fn direction(&mut self, g: &Matrix, t: u64, basis: &dyn Basis) -> Matrix {
        let h = &self.h;
        let bc1 = 1.0 - h.beta1.powi(t as i32);
        let bc2 = 1.0 - h.beta2.powi(t as i32);
        match self.space {
            MomentumSpace::InBasis => {
                let gp_store;
                let gp: &Matrix = if basis.is_identity() {
                    g
                } else {
                    gp_store = basis.project(g);
                    &gp_store
                };
                self.m.ema_inplace(gp, h.beta1);
                let g2 = gp.hadamard(gp);
                self.v.ema_inplace(&g2, h.beta2);
                let dir = self
                    .m
                    .zip(&self.v, |mi, vi| (mi / bc1) / ((vi / bc2).max(0.0).sqrt() + h.eps));
                if basis.is_identity() {
                    dir
                } else {
                    basis.project_back(&dir)
                }
            }
            MomentumSpace::Original => {
                // Momentum in the original space, then rotate both G and M
                // (SOAP Algorithm 3); V updates EVERY step in the rotated
                // space — the paper's fix for Shampoo's staleness.
                self.m.ema_inplace(g, h.beta1);
                let g_rot = basis.project(g);
                let m_rot = basis.project(&self.m);
                let m_hat = m_rot.scale(1.0 / bc1);
                let g2 = g_rot.hadamard(&g_rot);
                self.v.ema_inplace(&g2, h.beta2);
                let n_rot =
                    m_hat.zip(&self.v, |mi, vi| mi / ((vi / bc2).max(0.0).sqrt() + h.eps));
                basis.project_back(&n_rot)
            }
        }
    }

    fn momentum(&self) -> &Matrix {
        &self.m
    }

    fn full_v(&self) -> bool {
        true
    }

    fn state_bytes(&self) -> usize {
        (self.m.numel() + self.v.numel()) * 4
    }

    fn export(&self) -> EngineState {
        EngineState { momentum: self.m.clone(), second: vec![self.v.clone()] }
    }

    fn import(
        &mut self,
        momentum: Matrix,
        it: &mut dyn Iterator<Item = Matrix>,
    ) -> anyhow::Result<()> {
        self.m = momentum;
        self.v = it.next().ok_or_else(|| anyhow::anyhow!("adam engine missing v"))?;
        Ok(())
    }
}

/// Rank-1 factored second moment (Adafactor) in the basis's working space.
///
/// In `MomentumSpace::InBasis` (the standalone Adafactor preset), 1-D
/// parameters degenerate the factorization and fall back to a full Adam `V`
/// (matches practical Adafactor implementations). In
/// `MomentumSpace::Original` (factorized SOAP) the second moment stays
/// rank-1 for every shape, exactly like the pre-refactor implementation —
/// the layouts must stay checkpoint-compatible.
pub struct AdafactorEngine {
    h: Hyper,
    pub m: Matrix,
    /// Row second-moment EMA (m×1) — `A` in Adafactor's Algorithm 2.
    pub a: Vec<f32>,
    /// Column second-moment EMA (1×n) — `C`.
    pub c: Vec<f32>,
    pub v_1d: Option<Matrix>,
    pub space: MomentumSpace,
}

impl AdafactorEngine {
    pub fn new(rows: usize, cols: usize, h: &Hyper, space: MomentumSpace) -> Self {
        let is_1d = rows == 1 || cols == 1;
        Self {
            h: h.clone(),
            m: Matrix::zeros(rows, cols),
            a: vec![0.0; rows],
            c: vec![0.0; cols],
            v_1d: (is_1d && space == MomentumSpace::InBasis)
                .then(|| Matrix::zeros(rows, cols)),
            space,
        }
    }

    /// EMA the factored stats with `g2` and return the normalized direction
    /// for the (bias-corrected) numerator.
    fn factored_dir(&mut self, g2: &Matrix, m_hat: &Matrix, bc2: f32) -> Matrix {
        let rows = g2.row_sums();
        let cols = g2.col_sums();
        for (ai, ri) in self.a.iter_mut().zip(&rows) {
            *ai = self.h.beta2 * *ai + (1.0 - self.h.beta2) * ri;
        }
        for (ci, cj) in self.c.iter_mut().zip(&cols) {
            *ci = self.h.beta2 * *ci + (1.0 - self.h.beta2) * cj;
        }
        // Bias-correct A and C; the ΣA normalization makes the corrections
        // cancel except through ε, but we keep them for parity with Adam.
        let a_hat: Vec<f32> = self.a.iter().map(|&x| x / bc2).collect();
        let c_hat: Vec<f32> = self.c.iter().map(|&x| x / bc2).collect();
        factored_normalize(m_hat, &a_hat, &c_hat, self.h.eps)
    }
}

impl MomentEngine for AdafactorEngine {
    fn direction(&mut self, g: &Matrix, t: u64, basis: &dyn Basis) -> Matrix {
        let h = self.h.clone();
        let bc1 = 1.0 - h.beta1.powi(t as i32);
        let bc2 = 1.0 - h.beta2.powi(t as i32);
        match self.space {
            MomentumSpace::InBasis => {
                let gp_store;
                let gp: &Matrix = if basis.is_identity() {
                    g
                } else {
                    gp_store = basis.project(g);
                    &gp_store
                };
                self.m.ema_inplace(gp, h.beta1);
                let dir = if let Some(v) = &mut self.v_1d {
                    // Degenerate (vector) case: plain Adam second moment.
                    let g2 = gp.hadamard(gp);
                    v.ema_inplace(&g2, h.beta2);
                    self.m
                        .zip(v, |mi, vi| (mi / bc1) / ((vi / bc2).max(0.0).sqrt() + h.eps))
                } else {
                    let g2 = gp.hadamard(gp);
                    let m_hat = self.m.scale(1.0 / bc1);
                    self.factored_dir(&g2, &m_hat, bc2)
                };
                if basis.is_identity() {
                    dir
                } else {
                    basis.project_back(&dir)
                }
            }
            MomentumSpace::Original => {
                // Factorized SOAP (§7.2.1): Adafactor-style rank-1 V in the
                // eigenbasis — exactly the configuration Claim 1 equates
                // with power-1/2 Shampoo.
                self.m.ema_inplace(g, h.beta1);
                let g_rot = basis.project(g);
                let m_rot = basis.project(&self.m);
                let m_hat = m_rot.scale(1.0 / bc1);
                let g2 = g_rot.hadamard(&g_rot);
                let n_rot = self.factored_dir(&g2, &m_hat, bc2);
                basis.project_back(&n_rot)
            }
        }
    }

    fn momentum(&self) -> &Matrix {
        &self.m
    }

    fn full_v(&self) -> bool {
        false
    }

    fn state_bytes(&self) -> usize {
        let factored = (self.a.len() + self.c.len()) * 4;
        let v1d = self.v_1d.as_ref().map(|v| v.numel() * 4).unwrap_or(0);
        self.m.numel() * 4 + factored + v1d
    }

    fn export(&self) -> EngineState {
        let mut second = vec![
            Matrix::from_vec(1, self.a.len(), self.a.clone()),
            Matrix::from_vec(1, self.c.len(), self.c.clone()),
        ];
        if let Some(v) = &self.v_1d {
            second.push(v.clone());
        }
        EngineState { momentum: self.m.clone(), second }
    }

    fn import(
        &mut self,
        momentum: Matrix,
        it: &mut dyn Iterator<Item = Matrix>,
    ) -> anyhow::Result<()> {
        self.m = momentum;
        self.a = it.next().ok_or_else(|| anyhow::anyhow!("adafactor missing a"))?.data;
        self.c = it.next().ok_or_else(|| anyhow::anyhow!("adafactor missing c"))?.data;
        if self.v_1d.is_some() {
            self.v_1d =
                Some(it.next().ok_or_else(|| anyhow::anyhow!("adafactor missing v_1d"))?);
        }
        Ok(())
    }
}

/// Shampoo's update rule: bias-corrected momentum through the full
/// Kronecker preconditioner. The basis (inverse-root flavored `EigenBasis`)
/// owns the factor EMAs and the cached `L^{-1/e}`/`R^{-1/e}`; this engine is
/// just momentum + the sandwich.
pub struct InverseRootEngine {
    h: Hyper,
    pub m: Matrix,
}

impl InverseRootEngine {
    pub fn new(rows: usize, cols: usize, h: &Hyper) -> Self {
        Self { h: h.clone(), m: Matrix::zeros(rows, cols) }
    }
}

impl MomentEngine for InverseRootEngine {
    fn direction(&mut self, g: &Matrix, t: u64, basis: &dyn Basis) -> Matrix {
        self.m.ema_inplace(g, self.h.beta1);
        let bc1 = 1.0 - self.h.beta1.powi(t as i32);
        let m_hat = self.m.scale(1.0 / bc1);
        // L^{-1/e} · M̂ · R^{-1/e} — the whole preconditioner applies in
        // `project`; there is no rotate-back.
        basis.project(&m_hat)
    }

    fn momentum(&self) -> &Matrix {
        &self.m
    }

    fn full_v(&self) -> bool {
        false
    }

    fn state_bytes(&self) -> usize {
        self.m.numel() * 4
    }

    fn export(&self) -> EngineState {
        EngineState { momentum: self.m.clone(), second: Vec::new() }
    }

    fn import(
        &mut self,
        momentum: Matrix,
        _it: &mut dyn Iterator<Item = Matrix>,
    ) -> anyhow::Result<()> {
        self.m = momentum;
        Ok(())
    }
}

/// Closed set of shipped engines (see [`AnyBasis`](super::basis::AnyBasis)).
// One value per model layer; the variant-size spread is irrelevant there.
#[allow(clippy::large_enum_variant)]
pub enum AnyEngine {
    Adam(AdamEngine),
    Adafactor(AdafactorEngine),
    InverseRoot(InverseRootEngine),
}

impl AnyEngine {
    pub fn as_adam(&self) -> Option<&AdamEngine> {
        match self {
            AnyEngine::Adam(e) => Some(e),
            _ => None,
        }
    }

    pub fn as_adafactor(&self) -> Option<&AdafactorEngine> {
        match self {
            AnyEngine::Adafactor(e) => Some(e),
            _ => None,
        }
    }
}

impl MomentEngine for AnyEngine {
    fn direction(&mut self, g: &Matrix, t: u64, basis: &dyn Basis) -> Matrix {
        match self {
            AnyEngine::Adam(e) => e.direction(g, t, basis),
            AnyEngine::Adafactor(e) => e.direction(g, t, basis),
            AnyEngine::InverseRoot(e) => e.direction(g, t, basis),
        }
    }

    fn momentum(&self) -> &Matrix {
        match self {
            AnyEngine::Adam(e) => e.momentum(),
            AnyEngine::Adafactor(e) => e.momentum(),
            AnyEngine::InverseRoot(e) => e.momentum(),
        }
    }

    fn full_v(&self) -> bool {
        match self {
            AnyEngine::Adam(e) => e.full_v(),
            AnyEngine::Adafactor(e) => e.full_v(),
            AnyEngine::InverseRoot(e) => e.full_v(),
        }
    }

    fn state_bytes(&self) -> usize {
        match self {
            AnyEngine::Adam(e) => e.state_bytes(),
            AnyEngine::Adafactor(e) => e.state_bytes(),
            AnyEngine::InverseRoot(e) => e.state_bytes(),
        }
    }

    fn export(&self) -> EngineState {
        match self {
            AnyEngine::Adam(e) => e.export(),
            AnyEngine::Adafactor(e) => e.export(),
            AnyEngine::InverseRoot(e) => e.export(),
        }
    }

    fn import(
        &mut self,
        momentum: Matrix,
        it: &mut dyn Iterator<Item = Matrix>,
    ) -> anyhow::Result<()> {
        match self {
            AnyEngine::Adam(e) => e.import(momentum, it),
            AnyEngine::Adafactor(e) => e.import(momentum, it),
            AnyEngine::InverseRoot(e) => e.import(momentum, it),
        }
    }
}
